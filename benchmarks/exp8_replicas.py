"""Experiment 8 (beyond-paper): batched-replica engine throughput.

The enabling claim behind the mean/std/ci95/n BENCH schema is that
replicas are cheap: `engine.run_batch` vmaps R seeds through one jitted
scan, so the marginal replica should cost far less than a sequential
run. This bench measures it on the quick config and records the two
ratios that matter:

  batch_overhead   t_batch / t_single — the ISSUE-5 acceptance target
                   (< 3x one sequential run at R=8), which presumes an
                   accelerator's parallel width: R replicas are R x the
                   flops, so a CPU with a couple of cores has a hard
                   floor near R x (measured honestly, not gated
                   dishonestly — see DESIGN.md §Deviations).
  loop_ratio       t_batch / (R * t_single) — batch vs the sequential
                   seed loop it replaces. This is the invariant any
                   hardware can and must hold: batching replicas never
                   loses throughput against running them one by one.

The hard gate is therefore platform-aware: on accelerators
(jax.default_backend() != "cpu") batch_overhead < 3.0; on CPU
loop_ratio < LOOP_TOL. Both numbers land in BENCH_replicas.json either way
(CI artifact; tracked by benchmarks/compare.py — `metrics.*` are stats
dicts, so the gate's interval-separation rule applies to them).

Timing protocol: both paths are warmed first (compilation excluded —
the memoized scans are config-keyed, so the timed calls only execute),
the sequential reference is the min over 3 single-seed runs, and the
batch runs the same R seeds the sequential path ran.

    PYTHONPATH=src python benchmarks/exp8_replicas.py [quick|full]
                                                      [--replicas R]
"""
from __future__ import annotations

import json
import os
import sys
import time

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
import jax  # noqa: E402

from benchmarks.common import engine_cfg  # noqa: E402
from repro.core.engine import run, run_batch  # noqa: E402
from repro.core.stats import replica_stats, summarize  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_replicas.json")

MAX_OVERHEAD = 3.0  # accelerator gate: batch of R vs ONE sequential run
# cpu gate: batch vs the R-run sequential loop. The margin covers the
# batch's un-batched prologue (per-replica eager init — kept eager on
# purpose: a fused jitted init drifts ULPs off the sequential path and
# would break bit-identity) plus 2-core scheduling jitter.
LOOP_TOL = 1.25
SEQ_REPS = 3  # sequential reference: min over this many runs
BENCH_SCALE = {"quick": "quick", "full": "mid"}  # full stays CPU-sized


def main(scale: str = "quick", replicas=None):
    n_rep = int(replicas) if replicas else 8
    cfg = engine_cfg(BENCH_SCALE[scale])
    seeds = list(range(n_rep))

    # warm both compiled scans (config-keyed memoization: the timed
    # calls below reuse these executables)
    run(jax.random.key(10_000), cfg)
    run_batch(cfg, seeds)

    seq_times = []
    for s in seeds[:SEQ_REPS]:
        t0 = time.time()
        run(jax.random.key(s), cfg)
        seq_times.append(time.time() - t0)
    t_single = min(seq_times)

    # min over the same number of repetitions as the sequential side:
    # the container's CPU share swings with neighbor load, and an
    # asymmetric single-shot batch timing against a min-of-3 reference
    # would flake the nightly gate on share dips, not regressions
    batch_times = []
    for _ in range(SEQ_REPS):
        t0 = time.time()
        _, _, reps = run_batch(cfg, seeds)
        batch_times.append(time.time() - t0)
    t_batch = min(batch_times)

    overhead = t_batch / t_single
    loop_ratio = t_batch / (n_rep * t_single)
    on_cpu = jax.default_backend() == "cpu"
    gate_name, gate_val, gate_bound = (
        ("loop_ratio", loop_ratio, LOOP_TOL) if on_cpu
        else ("batch_overhead", overhead, MAX_OVERHEAD))
    metrics = summarize(reps, keys=("mean_lcr", "migrations", "heu_evals"),
                        ndigits=4)
    print(f"[exp8] single run {t_single:.2f}s (min of {SEQ_REPS}), "
          f"batch R={n_rep} {t_batch:.2f}s -> {overhead:.2f}x one run, "
          f"{loop_ratio:.2f}x the sequential loop")
    print(f"[exp8] mean_lcr {metrics['mean_lcr']['mean']:.4f}"
          f"±{metrics['mean_lcr']['ci95']:.4f} (n={n_rep})")

    result = {
        "experiment": "exp8_replicas",
        "config": dict(scale=scale, bench_scale=BENCH_SCALE[scale],
                       n_se=cfg.abm.n_se, timesteps=cfg.timesteps,
                       n_lp=cfg.abm.n_lp, replicas=n_rep,
                       seq_reps=SEQ_REPS,
                       backend=jax.default_backend()),
        "t_single_s": round(t_single, 3),
        "seq_times_s": [round(t, 3) for t in seq_times],
        "t_batch_s": round(t_batch, 3),
        "batch_times_s": [round(t, 3) for t in batch_times],
        "batch_overhead": round(overhead, 3),
        "batch_overhead_target": MAX_OVERHEAD,
        "batch_overhead_met": overhead < MAX_OVERHEAD,
        "loop_ratio": round(loop_ratio, 3),
        "metrics": metrics,
        "gate": {"name": gate_name, "value": round(gate_val, 3),
                 "bound": gate_bound,
                 "timing": {k: round(v, 3) for k, v in
                            replica_stats(seq_times).items()}},
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    assert gate_val < gate_bound, \
        (f"batched R={n_rep} replicas: {gate_name}={gate_val:.2f} "
         f"(gate: < {gate_bound})")
    print(f"[exp8] OK ({gate_name} {gate_val:.2f} < {gate_bound}) -> {OUT}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "full"])
    ap.add_argument("--replicas", type=int, default=None)
    a = ap.parse_args()
    main(a.scale, a.replicas)

"""Experiment 7 (beyond-paper): partitioning backends vs. adaptive GAIA.

The paper's headline claim — adaptive self-clustering beats static
partitioning — was never measured in this repo because the only static
baseline was the random round-robin map. This sweep runs the partition
registry (core/partition.py) against it on the non-uniform mobility
scenarios, in three modes per backend:

  static    the backend computes the initial map, nothing adapts
  periodic  the backend recomputes the global map every R steps
            (EngineConfig.repartition_every; deltas ride the migration
            machinery and are priced as migrations)
  gaia      GAIA ON on top of a static init (random = the paper's
            setting; kmeans = adaptive refinement of an informed start)

Each (scenario, backend, mode) cell runs `--replicas` seeds in one
batched engine pass (engine.run_batch) and serves every environment:
counters are environment-independent, only the pricing changes
(wct_env on the shm/lan/wan2/hetero presets). Metrics and gate ratios
are mean/std/ci95/n stats dicts; the ratios are *paired* per seed (all
cells run the same seed vector).

Acceptance gate (lan pricing, replica means), per non-uniform scenario:
  (a) at least one informed static/periodic backend must beat the
      random static map on TEC — the baselines are real;
  (b) the best GAIA row must beat or match (<= 2% above) the best
      *static* row — the paper's claim, measured against baselines that
      actually try. Periodic global repartitioning is deliberately NOT
      in (b)'s floor: recomputing the map every R steps is itself a
      (coarse-grained, centralized) adaptive scheme, the alternative
      GAIA should be compared to, not a static bar it must clear; the
      gaia_vs_best_anything ratio is still reported for the record.

    PYTHONPATH=src python benchmarks/exp7_partition.py [quick|full]
                                                       [--replicas R]

quick: N=1000, 300 steps (CI-sized), 5 replicas default. full:
N=10000, 1200 steps, 10 replicas default. Writes BENCH_partition.json
at the repo root (CI artifact; tracked by benchmarks/compare.py).
"""
from __future__ import annotations

import json
import os
import sys
import time

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import default_replicas  # noqa: E402
from repro.core import costmodel as cm  # noqa: E402
from repro.core.abm import ABMConfig  # noqa: E402
from repro.core.engine import EngineConfig, run_batch  # noqa: E402
from repro.core.heuristics import HeuristicConfig  # noqa: E402
from repro.core.stats import replica_stats, summarize  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_partition.json")

SCALES = {
    # n_se, timesteps, area: paper density 1e-4 SE/unit^2, like common.py
    "quick": dict(n_se=1_000, timesteps=300, area=3162.0, repart_every=50),
    "full": dict(n_se=10_000, timesteps=1200, area=10_000.0,
                 repart_every=100),
}
SCENARIOS = ("hotspot", "group")  # the gated non-uniform workloads
BACKENDS = ("random", "stripe", "kmeans", "bestresponse")
PERIODIC_BACKENDS = ("stripe", "kmeans", "bestresponse")
GAIA_INITS = ("random", "kmeans")  # paper setting / informed start
ENVS = ("shm", "lan", "wan2", "hetero")
GATE_ENV = "lan"
GAIA_MATCH_TOL = 0.02  # gaia row may be at most 2% above the best static
N_LP = 4
INTERACTION_BYTES = 100
MIGRATION_BYTES = 256


def exp_cfg(scale: str, scenario: str, backend: str, *, gaia: bool,
            repart: int = 0) -> EngineConfig:
    s = SCALES[scale]
    f = s["area"] / 10_000.0  # speed scaling, as in benchmarks/common.py
    return EngineConfig(
        abm=ABMConfig(n_se=s["n_se"], n_lp=N_LP, area=s["area"],
                      speed=11.0 * f, interaction_range=250.0,
                      p_interact=0.2, mobility=scenario, n_groups=8,
                      group_radius=250.0, partitioner=backend),
        heuristic=HeuristicConfig(mf=1.2, mt=10),
        gaia_on=gaia, repartition_every=repart, timesteps=s["timesteps"])


def one_run(cfg: EngineConfig, envs: dict, timesteps: int, seeds) -> dict:
    """One batched cell: per-replica counters -> stats dicts + raw
    per-replica TEC lists (under "_tec_reps", stripped before the JSON
    dump — the gate pairs them across cells by seed)."""
    t0 = time.time()
    _, _, reps = run_batch(cfg, seeds)
    st = summarize(reps, ndigits=4)
    tec_reps = {kind: [cm.wct_env(
        r, cm.DISTRIBUTED, env, timesteps,
        interaction_bytes=INTERACTION_BYTES,
        migration_bytes=MIGRATION_BYTES)["TEC"] for r in reps]
        for kind, env in envs.items()}
    return {
        "lcr": st["mean_lcr"],
        "migrations": st["migrations"],
        "repartitions": st.get("repartitions",
                               {"mean": 0.0, "std": 0.0, "ci95": 0.0,
                                "n": len(seeds)}),
        "grid_overflow": sum(r["grid_overflow"] for r in reps),
        "wall_s": round(time.time() - t0, 1),
        "tec": {kind: {k: round(v, 3)
                       for k, v in replica_stats(ts).items()}
                for kind, ts in tec_reps.items()},
        "_tec_reps": tec_reps,
    }


def main(scale: str = "quick", replicas=None):
    s = SCALES[scale]
    n_rep = default_replicas(scale, replicas)
    seeds = list(range(n_rep))
    envs = {kind: cm.make_env(kind, N_LP) for kind in ENVS}
    results = {}
    for scen in SCENARIOS:
        rows = {}
        for backend in BACKENDS:
            cfg = exp_cfg(scale, scen, backend, gaia=False)
            rows[f"{backend}/static"] = one_run(cfg, envs, s["timesteps"],
                                                seeds)
        for backend in PERIODIC_BACKENDS:
            cfg = exp_cfg(scale, scen, backend, gaia=False,
                          repart=s["repart_every"])
            rows[f"{backend}/periodic"] = one_run(cfg, envs,
                                                  s["timesteps"], seeds)
        for backend in GAIA_INITS:
            cfg = exp_cfg(scale, scen, backend, gaia=True)
            rows[f"{backend}/gaia"] = one_run(cfg, envs, s["timesteps"],
                                              seeds)
        results[scen] = rows
        for name, row in rows.items():
            print(f"[exp7] {scen:8s} {name:22s} "
                  f"lcr {row['lcr']['mean']:.3f}  "
                  f"TEC({GATE_ENV}) {row['tec'][GATE_ENV]['mean']:9.3f}"
                  f"±{row['tec'][GATE_ENV]['ci95']:.3f}  "
                  f"migs {row['migrations']['mean']:7.0f} "
                  f"(reparts {row['repartitions']['mean']:.0f}, n={n_rep})")

    # -- gate: measured on the lan environment, ratios paired per seed --
    gate = {"static_gain_by_scenario": {}, "gaia_vs_best_static": {},
            "gaia_vs_best_anything": {}, "static_winner": {},
            "gaia_winner": {}}
    ok_a, ok_b = [], []
    for scen, rows in results.items():
        tec = {name: row["tec"][GATE_ENV]["mean"]
               for name, row in rows.items()}
        reps = {name: row["_tec_reps"][GATE_ENV]
                for name, row in rows.items()}
        rand = tec["random/static"]
        informed = {k: v for k, v in tec.items()
                    if k.endswith(("/static", "/periodic"))
                    and k != "random/static"}
        static = {k: v for k, v in tec.items() if k.endswith("/static")}
        adaptive = {k: v for k, v in tec.items() if k.endswith("/gaia")}
        # winners chosen on replica-mean TEC; ratios then paired per seed
        best_informed = min(informed, key=informed.get)
        best_static = min(static, key=static.get)
        best_gaia = min(adaptive, key=adaptive.get)
        gate["static_gain_by_scenario"][scen] = {
            k: round(v, 4) for k, v in replica_stats(
                [(r - i) / r for r, i in
                 zip(reps["random/static"], reps[best_informed])]).items()}
        gate["gaia_vs_best_static"][scen] = {
            k: round(v, 4) for k, v in replica_stats(
                [g / st for g, st in
                 zip(reps[best_gaia], reps[best_static])]).items()}
        gate["gaia_vs_best_anything"][scen] = {
            k: round(v, 4) for k, v in replica_stats(
                [g / i for g, i in
                 zip(reps[best_gaia], reps[best_informed])]).items()}
        gate["static_winner"][scen] = best_informed
        gate["gaia_winner"][scen] = best_gaia
        ok_a.append(informed[best_informed] < rand)
        ok_b.append(adaptive[best_gaia]
                    <= min(static.values()) * (1.0 + GAIA_MATCH_TOL))
        print(f"[exp7] {scen}: best baseline {best_informed} "
              f"({gate['static_gain_by_scenario'][scen]['mean']:+.1%} vs "
              f"random), best GAIA {best_gaia} "
              f"(x{gate['gaia_vs_best_static'][scen]['mean']:.3f} of best "
              f"static, x{gate['gaia_vs_best_anything'][scen]['mean']:.3f}"
              f" of best baseline)")

    for rows in results.values():  # raw pairing lists: not for the JSON
        for row in rows.values():
            del row["_tec_reps"]
    result = {
        "experiment": "exp7_partition",
        "config": dict(s, n_lp=N_LP, scale=scale, replicas=n_rep,
                       interaction_bytes=INTERACTION_BYTES,
                       migration_bytes=MIGRATION_BYTES, gate_env=GATE_ENV,
                       gaia_match_tol=GAIA_MATCH_TOL),
        "results": results,
        "gate": gate,
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)

    for scen, rows in results.items():
        for name, row in rows.items():
            assert row["grid_overflow"] == 0.0, \
                f"grid overflow on {scen}/{name}"
    assert all(ok_a), \
        f"(a) no informed backend beat random/static on TEC({GATE_ENV}): " \
        f"{gate['static_gain_by_scenario']}"
    assert all(ok_b), \
        f"(b) GAIA failed to beat/match the best static backend on " \
        f"TEC({GATE_ENV}): {gate['gaia_vs_best_static']}"
    print(f"[exp7] OK (n={n_rep}) -> {OUT}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "full"])
    ap.add_argument("--replicas", type=int, default=None)
    a = ap.parse_args()
    main(a.scale, a.replicas)

"""Experiment 9 (beyond-paper): resident engine service under churn.

Two claims behind the PR-8 service API are measured here:

1. **Churn throughput** — `Engine.arrive`/`Engine.depart` are O(batch)
   in-device slot updates, so an open-world demo must sustain >= 10k
   arrivals+departures per second *while stepping* (the paper's
   motivating scenario: entities joining/leaving a running distributed
   simulation without a rebuild), with bounded per-step tail latency
   (p99 vs p50) and GAIA still migrating SEs under the churn.
2. **Request multiplexing** — `ReplicaService` packs queued requests
   onto the replica batch axis (PR 5), so draining Q requests through R
   slots must not lose throughput against running them one by one, and
   each request's counters must match its solo run *exactly* (the
   integer counters are bit-exact; see tests/test_service.py).
3. **Sharded churn** — the same open-world churn loop on the
   `sharding="lp_device"` layer (arrivals packed into per-device free
   slots, departures located by global id): measured in a 2-device
   subprocess (the main process owns a different device topology) and
   reported as arrivals+departures/s next to the oracle's number. No
   absolute gate — the sharded layer pays per-device slot bookkeeping
   for its memory locality, and the number is machine-sized; it is
   recorded so the ratio is visible in BENCH_service.json.

Timing protocol follows exp8: everything is warmed first (the compiled
windows are (config, length)-memoized, so the timed region only
executes), churn-loop events/s is measured over the full loop wall
(arrive + depart + step), and the service/sequential ratio uses the
same jobs on both paths. The churn gate (>= EVENTS_TARGET events/s) is
the ISSUE-8 acceptance bar and applies on every backend; the service
ratio gate is platform-aware like exp8's (CPU has no parallel width to
win with, so it only has to not *lose*).

Results land in BENCH_service.json (CI artifact; churn.p99_over_p50
and service.service_vs_sequential are tracked by benchmarks/compare.py
against BENCH_baseline/).

    PYTHONPATH=src python benchmarks/exp9_service.py [quick|full]
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import engine_cfg  # noqa: E402
from repro.core.service import Engine, ReplicaService  # noqa: E402
from repro.core.stats import percentile  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_service.json")

EVENTS_TARGET = 10_000  # ISSUE-8 bar: arrivals+departures/s while stepping
P99_BOUND = 20.0  # step-latency tail: p99 may not exceed 20x p50
# service gate: drain wall vs the sequential solo loop it replaces. On
# CPU batching R slots is ~R x the flops on the same cores, so the gate
# is "do not lose" with scheduling slack; accelerators must win.
SERVICE_TOL_CPU = 1.35
SERVICE_TOL_ACC = 0.75

CHURN_BATCH = 200  # departures (then arrivals) per loop iteration
CHURN_ITERS = {"quick": 50, "full": 120}
N_SLOTS = 4  # ReplicaService replica slots
REQUEST_STEPS = 60  # per request; equal lengths keep one window compile
TIME_REPS = 2  # service/sequential walls: min over this many reps


def churn_section(scale: str):
    """Open-world churn loop on the resident oracle engine: depart
    CHURN_BATCH live SEs, admit CHURN_BATCH fresh ones, advance one
    step — population holds at n_active while every iteration recycles
    slots through the free pool."""
    iters = CHURN_ITERS[scale]
    cfg = dataclasses.replace(
        engine_cfg("quick"), open_world=True,
        n_active=engine_cfg("quick").abm.n_se - CHURN_BATCH)
    rng = np.random.default_rng(0)
    area = cfg.abm.area

    e = Engine(cfg).init(seed=0)
    # warm all three compiled paths (arrive/depart jits are padded to
    # pow2 batch shapes, so the timed calls reuse these executables)
    e.step(1)
    warm_ids = e.arrive({"pos": rng.uniform(0, area, (CHURN_BATCH, 2))})
    e.depart(warm_ids)

    step_times = []
    migrations = 0.0
    t0 = time.time()
    for _ in range(iters):
        victims = rng.choice(e.live_ids(), CHURN_BATCH, replace=False)
        e.depart(victims)
        e.arrive({"pos": rng.uniform(0, area, (CHURN_BATCH, 2))})
        ts = time.time()
        migrations += e.step(1)["migrations"]
        step_times.append(time.time() - ts)
    wall = time.time() - t0

    events = 2 * CHURN_BATCH * iters
    events_per_s = events / wall
    p50 = percentile(step_times, 50.0)
    p99 = percentile(step_times, 99.0)
    print(f"[exp9] churn: {events} events in {wall:.2f}s -> "
          f"{events_per_s:,.0f} events/s (target {EVENTS_TARGET:,}), "
          f"step p50 {p50 * 1e3:.1f}ms p99 {p99 * 1e3:.1f}ms, "
          f"{migrations:.0f} migrations, pop {e.population()}")
    return {
        "batch": CHURN_BATCH, "iters": iters, "events": events,
        "wall_s": round(wall, 3),
        "events_per_s": round(events_per_s, 1),
        "events_target": EVENTS_TARGET,
        "p50_step_ms": round(p50 * 1e3, 3),
        "p99_step_ms": round(p99 * 1e3, 3),
        "p99_over_p50": round(p99 / max(p50, 1e-9), 3),
        "migrations": migrations,
        "population": e.population(),
    }


SHARDED_DEVS = 2
SHARDED_ITERS = {"quick": 25, "full": 60}

# child process template (exp5 protocol: own XLA device topology, one
# RESULT line on stdout). Runs the churn_section loop on the sharded
# layer: depart CHURN_BATCH by global id, admit CHURN_BATCH into
# per-device free slots, advance one step.
_SHARDED_CHURN_CODE = """
import dataclasses, json, time
import numpy as np
from benchmarks.common import engine_cfg
from repro.core.service import Engine

batch, iters = {batch}, {iters}
cfg = dataclasses.replace(
    engine_cfg("quick"), sharding="lp_device", n_devices={n_dev},
    open_world=True, n_active=engine_cfg("quick").abm.n_se - batch)
rng = np.random.default_rng(0)
area = cfg.abm.area

e = Engine(cfg).init(seed=0)
e.step(1)
warm = e.arrive({{"pos": rng.uniform(0, area, (batch, 2))}})
e.depart(warm)

migrations = 0.0
t0 = time.time()
for _ in range(iters):
    victims = rng.choice(e.live_ids(), batch, replace=False)
    e.depart(victims)
    e.arrive({{"pos": rng.uniform(0, area, (batch, 2))}})
    migrations += e.step(1)["migrations"]
wall = time.time() - t0
events = 2 * batch * iters
print("RESULT " + json.dumps({{
    "n_devices": {n_dev}, "batch": batch, "iters": iters,
    "events": events, "wall_s": round(wall, 3),
    "events_per_s": round(events / wall, 1),
    "migrations": migrations, "population": e.population(),
}}))
"""

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_child(code: str, n_dev: int) -> dict:
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(_REPO, "src"), _REPO,
             os.environ.get("PYTHONPATH", "")]),
        XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
        XLA_PYTHON_CLIENT_PREALLOCATE="false",
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=3600, env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stdout + r.stderr)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in: {r.stdout!r}")


def sharded_churn_section(scale: str):
    """Churn loop on the LP-per-device layer, in a SHARDED_DEVS-device
    subprocess."""
    row = _run_child(
        _SHARDED_CHURN_CODE.format(batch=CHURN_BATCH,
                                   iters=SHARDED_ITERS[scale],
                                   n_dev=SHARDED_DEVS),
        SHARDED_DEVS)
    print(f"[exp9] sharded churn (D={SHARDED_DEVS}): {row['events']} "
          f"events in {row['wall_s']:.2f}s -> "
          f"{row['events_per_s']:,.0f} events/s, "
          f"{row['migrations']:.0f} migrations, pop {row['population']}")
    assert row["migrations"] > 0, \
        "sharded GAIA made no migrations under churn — heuristic dead?"
    return row


def service_section():
    """Q = 2R equal-length requests drained through R slots vs the same
    jobs run solo, with an exact integer-counter cross-check."""
    cfg = dataclasses.replace(engine_cfg("quick"),
                              timesteps=REQUEST_STEPS)
    jobs = [(seed, REQUEST_STEPS) for seed in range(2 * N_SLOTS)]

    # warm both compiled paths: the solo window and the batched window
    # at the (only) chunk length the drain will use
    Engine(cfg).run(seed=10_000)
    warm = ReplicaService(cfg, N_SLOTS)
    for s in range(N_SLOTS):
        warm.submit(seed=10_000 + s, steps=REQUEST_STEPS)
    warm.drain()

    # min over TIME_REPS repetitions on both sides: the container's CPU
    # share swings with neighbor load (same flake-avoidance protocol as
    # exp8's sequential reference)
    seq_times, solo = [], {}
    for _ in range(TIME_REPS):
        t0 = time.time()
        for seed, steps in jobs:
            _, _, c = Engine(cfg).run(seed=seed)
            solo[seed] = c
        seq_times.append(time.time() - t0)
    t_seq = min(seq_times)

    svc_times = []
    for _ in range(TIME_REPS):
        svc = ReplicaService(cfg, N_SLOTS)
        rids = {svc.submit(seed=seed, steps=steps): seed
                for seed, steps in jobs}
        t0 = time.time()
        results = svc.drain()
        svc_times.append(time.time() - t0)
    t_service = min(svc_times)

    mismatches = []
    for rid, seed in rids.items():
        for key in ("migrations", "heu_evals", "local_msgs",
                    "remote_msgs"):
            if results[rid][key] != solo[seed][key]:
                mismatches.append((seed, key, results[rid][key],
                                   solo[seed][key]))
    ratio = t_service / t_seq
    print(f"[exp9] service: {len(jobs)} requests x {REQUEST_STEPS} steps "
          f"through {N_SLOTS} slots {t_service:.2f}s vs sequential "
          f"{t_seq:.2f}s -> {ratio:.2f}x, "
          f"{'EXACT' if not mismatches else 'MISMATCH'} counters")
    assert not mismatches, \
        f"service counters diverged from solo runs: {mismatches[:4]}"
    return {
        "n_slots": N_SLOTS, "requests": len(jobs),
        "steps_per_request": REQUEST_STEPS,
        "t_service_s": round(t_service, 3),
        "service_times_s": [round(t, 3) for t in svc_times],
        "t_sequential_s": round(t_seq, 3),
        "seq_times_s": [round(t, 3) for t in seq_times],
        "service_vs_sequential": round(ratio, 3),
        "exact_counters": not mismatches,
    }


def main(scale: str = "quick"):
    churn = churn_section(scale)
    sharded = sharded_churn_section(scale)
    service = service_section()

    on_cpu = jax.default_backend() == "cpu"
    svc_bound = SERVICE_TOL_CPU if on_cpu else SERVICE_TOL_ACC
    result = {
        "experiment": "exp9_service",
        "config": dict(scale=scale, backend=jax.default_backend(),
                       n_se=engine_cfg("quick").abm.n_se,
                       churn_batch=CHURN_BATCH),
        "churn": churn,
        "sharded_churn": sharded,
        "service": service,
        "gate": {
            "events_per_s": {"value": churn["events_per_s"],
                             "bound": EVENTS_TARGET, "dir": "higher"},
            "p99_over_p50": {"value": churn["p99_over_p50"],
                             "bound": P99_BOUND, "dir": "lower"},
            "service_vs_sequential": {
                "value": service["service_vs_sequential"],
                "bound": svc_bound, "dir": "lower"},
        },
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)

    assert churn["events_per_s"] >= EVENTS_TARGET, \
        (f"churn throughput {churn['events_per_s']:,.0f} events/s "
         f"below the {EVENTS_TARGET:,} bar")
    assert churn["p99_over_p50"] <= P99_BOUND, \
        f"step p99/p50 {churn['p99_over_p50']:.1f} > {P99_BOUND}"
    assert churn["migrations"] > 0, \
        "GAIA made no migrations under churn — heuristic dead?"
    assert service["service_vs_sequential"] < svc_bound, \
        (f"service drain {service['service_vs_sequential']:.2f}x "
         f"sequential (gate: < {svc_bound})")
    print(f"[exp9] OK -> {OUT}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "full"])
    a = ap.parse_args()
    main(a.scale)

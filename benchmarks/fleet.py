"""Scenario fleet runner: the whole benchmark matrix as ONE invocation.

ReFrame-style split between *what* runs and *where* it runs: a fleet is
a list of declarative `FleetCell`s — matrix points over
{scenario x partitioner x device-count}, each priced on every
ExecutionEnvironment preset inside the cell — and an `Executor` decides
how a cell becomes a process. The local executor forks one subprocess
per cell (its own XLA runtime, its own forced host-device count for the
D axis) with bounded parallelism and collects each child's
``RESULT <json>`` line, the same protocol exp5 uses for its device
sweeps. Container/Kubernetes executors are declared behind the same
interface and raise NotImplementedError until a scheduler exists to
back them — the fleet definition will not change when they do.

Cells come in two kinds:

  * ``tec``  — the paired GAIA on/off TEC cell (exp6_scenarios.run_cell)
               for one scenario at one partitioner setting; the
               D=1/random-partitioner lane of these rows IS exp6's
               output and feeds the acceptance gate.
  * ``identity`` — oracle vs lp_device byte-equality for one scenario
               at one device count: the sharded-transparency invariant
               (tests/test_workloads.py proves it at unit scale; these
               cells re-prove it at benchmark scale on every nightly).

The merged document keeps exp6's BENCH_scenarios.json schema exactly
(results rows + gate.tec_gain_by_scenario, so benchmarks/compare.py and
the committed baselines keep working) and adds a ``fleet`` block with
every matrix point. This is the single nightly invocation: running
``fleet.py quick`` replaces the ad-hoc per-benchmark exp6 step.

    PYTHONPATH=src python benchmarks/fleet.py [quick|full]
        [--replicas R] [--workers W] [--executor local]
    # child mode (spawned by LocalExecutor, one per cell):
    PYTHONPATH=src python benchmarks/fleet.py --cell '<json>'
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

#: state keys compared by identity cells (tests/test_sharding.py's
#: equivalence list plus the epidemic flag that reshards with the row)
IDENTITY_STATE_KEYS = ("pos", "waypoint", "mob", "mob_g", "lp", "ring",
                       "ptr", "since_eval", "last_mig", "epi")
IDENTITY_SERIES_KEYS = ("local_msgs", "remote_msgs", "migrations", "lcr")
IDENTITY_TIMESTEPS = {"quick": 60, "full": 120}
CHILD_TIMEOUT_S = 3600


@dataclasses.dataclass(frozen=True)
class FleetCell:
    """One declarative matrix point. `gate=True` marks the D=1 /
    random-partitioner lane whose rows become exp6's results + gate."""
    kind: str                    # "tec" | "identity"
    scale: str
    scenario: str
    partitioner: str = "random"
    repartition_every: int = 0
    n_devices: int = 1
    seeds: tuple = (0,)
    gate: bool = False

    @property
    def name(self) -> str:
        return (f"{self.kind}:{self.scenario}:{self.partitioner}"
                f":d{self.n_devices}")

    def payload(self) -> dict:
        return dict(dataclasses.asdict(self), seeds=list(self.seeds))


def build_matrix(scale: str, n_rep: int) -> list:
    """The quick/full fleet matrix.

    * gate lane: every scenario x random partitioner x D=1, full
      replica set (exp6's historical sweep, now one cell each);
    * partitioner axis: the two workload families under periodic
      voronoi repartitioning (exercises informed repartition + the
      warm-started seeds) — reported, not gated;
    * D axis: the two workload families at 2 and 4 devices as identity
      cells (byte-equality vs the oracle at bench scale).
    """
    from benchmarks import exp6_scenarios as exp6
    seeds = tuple(range(n_rep))
    cells = [FleetCell("tec", scale, s, seeds=seeds, gate=True)
             for s in exp6.SCENARIOS]
    cells += [FleetCell("tec", scale, s, partitioner="voronoi",
                        repartition_every=50,
                        seeds=seeds[:max(2, n_rep // 2)])
              for s in exp6.WORKLOAD_SCENARIOS]
    cells += [FleetCell("identity", scale, s, n_devices=d, seeds=(7,))
              for s in exp6.WORKLOAD_SCENARIOS for d in (2, 4)]
    return cells


# ---------------------------------------------------------------------------
# Child side: one cell -> one RESULT dict
# ---------------------------------------------------------------------------


def run_cell_payload(payload: dict) -> dict:
    """Execute one cell in THIS process (the subprocess entrypoint; also
    callable inline for tests). Returns the cell's RESULT dict."""
    kind, scale = payload["kind"], payload["scale"]
    scen, seeds = payload["scenario"], list(payload["seeds"])
    meta = {"cell": f"{kind}:{scen}:{payload['partitioner']}"
                    f":d{payload['n_devices']}",
            "kind": kind, "scenario": scen, "scale": scale,
            "partitioner": payload["partitioner"],
            "repartition_every": payload["repartition_every"],
            "n_devices": payload["n_devices"], "seeds": seeds,
            "gate": bool(payload.get("gate"))}
    from benchmarks import exp6_scenarios as exp6
    if kind == "tec":
        row = exp6.run_cell(scale, scen, seeds,
                            partitioner=payload["partitioner"],
                            repartition_every=payload["repartition_every"])
        return dict(meta, row=row)
    if kind != "identity":
        raise ValueError(f"unknown cell kind {kind!r}")

    import jax
    import numpy as np
    from repro.core.engine import run
    cfg = dataclasses.replace(
        exp6.scenario_cfg(scale, scen, gaia=True),
        timesteps=IDENTITY_TIMESTEPS[scale])
    t0 = time.time()
    st0, s0, c0 = run(jax.random.key(seeds[0]), cfg)
    st1, s1, c1 = run(jax.random.key(seeds[0]), dataclasses.replace(
        cfg, sharding="lp_device", n_devices=payload["n_devices"]))
    mismatch = [k for k in IDENTITY_STATE_KEYS
                if not np.array_equal(np.asarray(st0[k]),
                                      np.asarray(st1[k]))]
    mismatch += [f"series:{k}" for k in IDENTITY_SERIES_KEYS
                 if not np.array_equal(np.asarray(s0[k]),
                                       np.asarray(s1[k]))]
    return dict(meta, match=not mismatch, mismatch=mismatch,
                shard_overflow=float(c1["shard_overflow"]),
                mean_lcr=round(float(c1["mean_lcr"]), 4),
                migrations=float(c1["migrations"]),
                timesteps=cfg.timesteps,
                wall_s=round(time.time() - t0, 1))


# ---------------------------------------------------------------------------
# Executors: how a cell becomes a process
# ---------------------------------------------------------------------------


class Executor:
    """Scheduler/launcher interface. `run` maps cells to their RESULT
    dicts, order-preserving; a cell whose process fails raises (the
    fleet is exact-or-loud, like every gate in this repo)."""

    kind = "abstract"

    def run(self, cells: list) -> list:
        raise NotImplementedError


class LocalExecutor(Executor):
    """One subprocess per cell on this host, at most `workers` alive at
    once. Each child gets its own XLA runtime with the cell's forced
    host-device count — the only way to vary the device mesh per cell,
    since a process's device count is fixed at first jax import."""

    kind = "local"

    def __init__(self, workers: int | None = None):
        self.workers = int(workers or max(1, (os.cpu_count() or 1) // 2))

    def _launch(self, cell: FleetCell):
        env = dict(
            os.environ,
            PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""),
            XLA_FLAGS="--xla_force_host_platform_device_count="
                      f"{max(cell.n_devices, 1)}",
            XLA_PYTHON_CLIENT_PREALLOCATE="false",
        )
        return subprocess.Popen(
            [sys.executable, os.path.abspath(__file__),
             "--cell", json.dumps(cell.payload())],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True, env=env)

    def run(self, cells: list) -> list:
        pending = list(enumerate(cells))
        live: dict = {}  # index -> (cell, proc, t0)
        results: list = [None] * len(cells)
        deadline = time.time() + CHILD_TIMEOUT_S
        while pending or live:
            while pending and len(live) < self.workers:
                i, cell = pending.pop(0)
                live[i] = (cell, self._launch(cell), time.time())
                print(f"[fleet] launch {cell.name} "
                      f"({len(live)} live, {len(pending)} queued)",
                      flush=True)
            if time.time() > deadline:
                for _, p, _ in live.values():
                    p.kill()
                raise TimeoutError(
                    f"fleet exceeded {CHILD_TIMEOUT_S}s with "
                    f"{len(live)} cells still running")
            time.sleep(0.2)
            for i in [i for i, (_, p, _) in live.items()
                      if p.poll() is not None]:
                cell, proc, t0 = live.pop(i)
                out, err = proc.communicate()
                if proc.returncode != 0:
                    raise RuntimeError(
                        f"cell {cell.name} failed "
                        f"(rc={proc.returncode}):\n{out}{err}")
                results[i] = parse_result(out, cell.name)
                print(f"[fleet] done   {cell.name} "
                      f"({time.time() - t0:.0f}s)", flush=True)
        return results


class ContainerExecutor(Executor):
    """Launch each cell in an OCI container (one image, one cell per
    container, host networking for the result stream). Declared so
    fleet definitions can already target it; wiring needs a container
    runtime on the bench host."""

    kind = "container"

    def __init__(self, image: str = "repro-bench:latest"):
        self.image = image

    def run(self, cells: list) -> list:
        raise NotImplementedError(
            "container executor: no container runtime is wired up yet — "
            "use --executor local (the cell protocol is identical)")


class K8sExecutor(Executor):
    """Submit each cell as a Kubernetes Job and collect RESULT lines
    from the pod logs. Same declarative cells, cluster-scale fan-out."""

    kind = "k8s"

    def __init__(self, namespace: str = "bench"):
        self.namespace = namespace

    def run(self, cells: list) -> list:
        raise NotImplementedError(
            "k8s executor: no cluster credentials are wired up yet — "
            "use --executor local (the cell protocol is identical)")


EXECUTORS = {"local": LocalExecutor, "container": ContainerExecutor,
             "k8s": K8sExecutor}


def parse_result(stdout: str, name: str) -> dict:
    for line in stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"cell {name}: no RESULT line in {stdout!r}")


# ---------------------------------------------------------------------------
# Merge + gate
# ---------------------------------------------------------------------------


def merge(cells: list, results: list, scale: str, n_rep: int) -> dict:
    """Fold cell RESULTs into the BENCH_scenarios.json document: gate
    cells become exp6's results rows (schema-identical to a sequential
    exp6 run); everything else lands under "fleet" and the identity
    cells are asserted byte-equal right here."""
    from benchmarks import exp6_scenarios as exp6
    gate_rows = [r["row"] for c, r in zip(cells, results) if c.gate]
    fleet = {
        "executor": "local",
        "cells": [{k: v for k, v in r.items() if k != "row"}
                  for r in results],
        "extra_tec": [r["row"] for c, r in zip(cells, results)
                      if c.kind == "tec" and not c.gate],
        "identity": [r for c, r in zip(cells, results)
                     if c.kind == "identity"],
    }
    for r in fleet["identity"]:
        assert r["shard_overflow"] == 0.0, \
            f"{r['cell']}: shard overflow at bench scale"
        assert r["match"], \
            f"{r['cell']}: sharded run diverged from oracle on " \
            f"{r['mismatch']}"
    return exp6.assemble(gate_rows, scale, n_rep, fleet=fleet)


def main(scale: str = "quick", replicas=None, executor: str = "local",
         workers: int | None = None):
    from benchmarks import exp6_scenarios as exp6
    from benchmarks.common import default_replicas
    n_rep = default_replicas(scale, replicas)
    cells = build_matrix(scale, n_rep)
    print(f"[fleet] {len(cells)} cells ({scale}, n={n_rep}) on "
          f"executor={executor}")
    t0 = time.time()
    results = EXECUTORS[executor](workers) if executor == "local" \
        else EXECUTORS[executor]()
    results = results.run(cells)
    doc = merge(cells, results, scale, n_rep)
    doc["fleet"]["wall_s"] = round(time.time() - t0, 1)
    for row in doc["results"]:
        exp6.print_row(row)
    for r in doc["fleet"]["identity"]:
        print(f"[fleet] {r['cell']:24s} identity OK "
              f"(lcr {r['mean_lcr']}, {r['wall_s']}s)")
    return exp6.write_and_gate(doc)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "full"])
    ap.add_argument("--replicas", type=int, default=None)
    ap.add_argument("--executor", default="local",
                    choices=sorted(EXECUTORS))
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--cell", default=None,
                    help="(internal) run one cell payload and print "
                         "its RESULT line")
    a = ap.parse_args()
    if a.cell is not None:
        print("RESULT " + json.dumps(run_cell_payload(json.loads(a.cell))))
    else:
        main(a.scale, a.replicas, a.executor, a.workers)

"""Experiment 5 (beyond-paper): LP-per-device sharded engine scaling.

Measures per-step wall-clock of the GAIA engine under
`sharding="none"` (single-device oracle) vs `sharding="lp_device"`
(parallel/lp_shard.py) at 1/2/4/8 forced host-platform devices, plus
the halo-shrink trajectory that shows GAIA physically reducing
inter-shard communication. Results land in BENCH_sharded.json at the
repo root (uploaded as a CI artifact).

Each device count runs in a fresh subprocess: XLA pins the device count
at first init, so `XLA_FLAGS=--xla_force_host_platform_device_count=N`
must be set before jax imports.

Honest-measurement notes:
  * every "device" here is a thread on the same CPU. On a single-core
    host, D>1 rows measure *orchestration overhead* (shard_map,
    collectives, slot indirection), not parallel speedup, so the
    unconditional acceptance gate is overhead at D=1: the sharded
    engine must not be slower than the oracle on one device. When the
    host has >= 2 cores (os.cpu_count), a second gate requires
    sharded D=4 to beat the oracle outright.
  * bytes_on_wire is the sparse exchange's exact transport count (see
    lp_shard's wire-accounting rules); the halo-shrink child asserts it
    falls monotonically (within ci95) as GAIA clusters the hotspot
    scenario.
  * timing excludes compilation (one full warm-up scan first) and uses
    a jitted fixed-length scan, the same shape the engine runs under.

    PYTHONPATH=src python benchmarks/exp5_sharded.py [quick|full]

quick: N=10k (CI-sized). full: N=50k (the gate scale).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_sharded.json")

SCALES = {"quick": 10_000, "full": 50_000}
DEVICE_COUNTS = (1, 2, 4, 8)
STEPS = 3  # timed steps per measurement (one warm-up scan first)

_TIMING_CODE = """
import json, time
import jax
import jax.numpy as jnp
from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig, init_engine, step
from repro.core.heuristics import HeuristicConfig

mode, n_dev, n_se, steps = {mode!r}, {n_dev}, {n_se}, {steps}
cfg = EngineConfig(
    abm=ABMConfig(n_se=n_se, n_lp=8, area=10_000.0, speed=11.0,
                  interaction_range=250.0, p_interact=0.2),
    heuristic=HeuristicConfig(mf=1.2, mt=10),
    gaia_on=True, timesteps=steps, sharding=mode, n_devices=n_dev,
    mig_capacity=max(512, n_se // 4))  # early burst: ~N/8 admissions/step
st = init_engine(jax.random.key(0), cfg)

if mode == "lp_device":
    from repro.parallel import lp_shard
    spec = lp_shard.make_shard_spec(cfg)
    mesh = lp_shard.make_mesh(spec)
    def body(s, _):
        return lp_shard.step_sharded(s, cfg, spec, mesh)
else:
    def body(s, _):
        return step(s, cfg)

scan = jax.jit(lambda s: jax.lax.scan(body, s, None, length=steps))
# two warm-ups: the first compiles; feeding its output back changes the
# input shardings (device-committed arrays) and compiles a second cache
# entry — the steady-state executable every later call reuses
st2, series = scan(st)
jax.block_until_ready(st2)
st2, series = scan(st2)
jax.block_until_ready(st2)
# min over repetitions: the container's CPU share swings ~2x with
# neighbor load, and min is the standard noise-robust estimator for the
# ratio gates; the full rep distribution is also reported as
# mean/std/ci95/n (the BENCH schema)
from repro.core.stats import replica_stats
times = []
for _ in range(3):
    t0 = time.time()
    st2, series = scan(st2)
    jax.block_until_ready(st2)
    times.append((time.time() - t0) / steps)
dt = min(times)
out = dict(mode=mode, n_dev=n_dev, n_se=n_se, per_step_s=round(dt, 4),
           per_step_stats={{k: round(v, 4)
                            for k, v in replica_stats(times).items()}},
           devices=len(jax.devices()))
if mode == "lp_device":
    out["slots_per_dev"] = spec.cap
    out["overflow"] = float(series["shard_overflow"].sum())
    out["halo_frac"] = round(float(series["halo_frac"].mean()), 4)
    # exact transport bytes for one steady-state scan (halo rows +
    # migration rows + heuristic gathers; see lp_shard's accounting)
    out["bytes_on_wire"] = float(series["bytes_on_wire"].sum())
print("RESULT " + json.dumps(out))
"""

_HALO_CODE = """
import json
import jax
from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig
import dataclasses, numpy as np

from repro.core.stats import replica_stats

cfg = EngineConfig(
    abm=ABMConfig(n_se={n_se}, n_lp=8, area=10_000.0, speed=11.0,
                  interaction_range=250.0, p_interact=0.2,
                  mobility="hotspot", n_groups=8, group_radius=900.0),
    heuristic=HeuristicConfig(mf=1.2, mt=10),
    gaia_on=True, timesteps=80, sharding="lp_device", n_devices=4,
    mig_capacity=512)

def window_stats(x, w=10):
    return [{{k: round(v, 4) for k, v in replica_stats(
        [float(u) for u in x[i:i + w]]).items()}}
            for i in range(0, len(x), w)]

rows = {{}}
for gaia in (True, False):
    _, series, c = run(jax.random.key(1),
                       dataclasses.replace(cfg, gaia_on=gaia))
    h = np.asarray(series["halo_frac"])
    b = np.asarray(series["bytes_on_wire"])
    rows["gaia_on" if gaia else "gaia_off"] = dict(
        halo_frac_first10=window_stats(h)[0],
        halo_frac_last10=window_stats(h)[-1],
        bytes_on_wire_first10=window_stats(b)[0],
        bytes_on_wire_last10=window_stats(b)[-1],
        bytes_on_wire_windows=window_stats(b),
        mean_lcr=round(c["mean_lcr"], 4),
        overflow=c["shard_overflow"])
print("RESULT " + json.dumps(rows))
"""


def _run_child(code: str, n_dev: int) -> dict:
    env = dict(
        os.environ,
        PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""),
        XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
        XLA_PYTHON_CLIENT_PREALLOCATE="false",
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=3600, env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stdout + r.stderr)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in: {r.stdout!r}")


def main(scale: str = "full"):
    n_se = SCALES[scale]
    rows = []
    row = _run_child(_TIMING_CODE.format(mode="none", n_dev=1, n_se=n_se,
                                         steps=STEPS), 1)
    print(f"[exp5] none      D=1 {row['per_step_s']:.3f}s/step")
    rows.append(row)
    for d in DEVICE_COUNTS:
        row = _run_child(_TIMING_CODE.format(mode="lp_device", n_dev=d,
                                             n_se=n_se, steps=STEPS), d)
        print(f"[exp5] lp_device D={d} {row['per_step_s']:.3f}s/step "
              f"(halo_frac {row['halo_frac']}, overflow {row['overflow']})")
        assert row["overflow"] == 0.0, row
        rows.append(row)

    halo = _run_child(_HALO_CODE.format(n_se=min(n_se, 10_000)), 4)
    g_on = halo["gaia_on"]
    print(f"[exp5] halo shrink (D=4 hotspot, GAIA on): "
          f"{g_on['halo_frac_first10']['mean']} -> "
          f"{g_on['halo_frac_last10']['mean']}; wire "
          f"{g_on['bytes_on_wire_first10']['mean']:.0f} -> "
          f"{g_on['bytes_on_wire_last10']['mean']:.0f} B/step")
    # the neighbor-only exchange's physical claim: as GAIA clusters the
    # hotspot scenario, the measured bytes fall monotonically (within
    # each window's ci95 — single-seed windows are noisy)
    bw = g_on["bytes_on_wire_windows"]
    for a, b in zip(bw, bw[1:]):
        assert b["mean"] <= a["mean"] + a["ci95"] + b["ci95"], (a, b)
    assert (bw[-1]["mean"] + bw[-1]["ci95"]
            < bw[0]["mean"] - bw[0]["ci95"]), (bw[0], bw[-1])

    base = rows[0]["per_step_s"]
    sharded1 = next(r for r in rows if r["mode"] == "lp_device"
                    and r["n_dev"] == 1)["per_step_s"]
    sharded4 = next(r for r in rows if r["mode"] == "lp_device"
                    and r["n_dev"] == 4)["per_step_s"]
    result = {
        "experiment": "exp5_sharded",
        "config": dict(n_se=n_se, n_lp=8, steps=STEPS, scale=scale,
                       cpu_count=os.cpu_count(),
                       note="host devices share the host CPU: D>1 rows "
                            "only measure speedup when cores >= devices"),
        "results": rows,
        "halo_shrink_d4": halo,
        "sharded_overhead_at_d1": round(sharded1 / base, 3),
        "speedup_at_d4": round(base / sharded4, 3),
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    # acceptance gate: sharded on one device is no slower than the oracle
    assert sharded1 <= base * 1.05, (sharded1, base)
    # on parallel hardware the sparse halo must turn devices into actual
    # speedup; on a single-core container D>1 only measures orchestration
    # overhead, so the gate is conditional on the host having cores
    if (os.cpu_count() or 1) >= 2:
        assert sharded4 < base, (sharded4, base)
        print(f"[exp5] D=4 speedup {result['speedup_at_d4']}x")
    print(f"[exp5] OK (D=1 overhead {result['sharded_overhead_at_d1']}x) "
          f"-> {OUT}")
    return result


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "full")

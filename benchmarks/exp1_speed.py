"""Experiment 1 (paper Fig. 5): LCR and migrations vs. node speed x MF.

Paper claim: at low speed, few migrations push LCR from the static 25%
(4 LPs) to ~90%; higher speed needs ever more migrations for the same
clustering level. Each cell runs `--replicas` seeds in one batched pass
(engine.run_batch) and reports mean/std/ci95/n; the trend assertions
test the replica means.
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
from benchmarks.common import (default_replicas, engine_cfg,  # noqa: E402
                               fmt_stat, run_cfg, write_csv)


def main(scale: str = "quick", replicas=None):
    n_rep = default_replicas(scale, replicas)
    speeds = [1, 5, 11, 19, 29]
    mfs = [1.1, 1.5, 3.0, 19.0]
    rows = []
    for speed in speeds:
        for mf in mfs:
            c = run_cfg(engine_cfg(scale, speed=speed, mf=mf),
                        replicas=n_rep)
            lcr, mig = c["stats"]["mean_lcr"], c["stats"]["migrations"]
            rows.append((speed, mf, round(lcr["mean"], 4),
                         round(lcr["std"], 4), round(lcr["ci95"], 4),
                         round(mig["mean"], 1), round(mig["ci95"], 1),
                         n_rep, round(c["migration_ratio"], 2),
                         round(c["wall_s"], 1)))
            print(f"[exp1] speed={speed:<3} MF={mf:<5} "
                  f"LCR={fmt_stat(lcr)} migs={fmt_stat(mig, 0)}")
    path = write_csv("exp1.csv",
                     "speed,mf,mean_lcr,lcr_std,lcr_ci95,migrations,"
                     "migrations_ci95,n,mr,wall_s", rows)

    # paper-claim checks (trends, on replica means)
    by = {(r[0], r[1]): r for r in rows}
    slow_aggr = by[(1, 1.1)]
    slow_off = by[(1, 19.0)]
    fast_aggr = by[(29, 1.1)]
    assert slow_aggr[2] > 0.55, f"low-speed clustering too weak: {slow_aggr}"
    assert slow_aggr[2] > slow_off[2] + 0.2, "MF sweep has no effect"
    assert fast_aggr[5] > slow_aggr[5], "fast nodes should need more migs"
    print(f"[exp1] OK (n={n_rep}) -> {path}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "mid", "paper"])
    ap.add_argument("--replicas", type=int, default=None)
    a = ap.parse_args()
    main(a.scale, a.replicas)

"""Experiment 1 (paper Fig. 5): LCR and migrations vs. node speed x MF.

Paper claim: at low speed, few migrations push LCR from the static 25%
(4 LPs) to ~90%; higher speed needs ever more migrations for the same
clustering level.
"""
from __future__ import annotations

from benchmarks.common import engine_cfg, run_cfg, write_csv


def main(scale: str = "quick", seeds=(0,)):
    speeds = [1, 5, 11, 19, 29]
    mfs = [1.1, 1.5, 3.0, 19.0]
    rows = []
    for speed in speeds:
        for mf in mfs:
            for seed in seeds:
                c = run_cfg(engine_cfg(scale, speed=speed, mf=mf), seed)
                rows.append((speed, mf, seed, round(c["mean_lcr"], 4),
                             int(c["migrations"]),
                             round(c["migration_ratio"], 2),
                             round(c["wall_s"], 1)))
                print(f"[exp1] speed={speed:<3} MF={mf:<5} seed={seed} "
                      f"LCR={c['mean_lcr']:.3f} migs={int(c['migrations'])}")
    path = write_csv("exp1.csv",
                     "speed,mf,seed,mean_lcr,migrations,mr,wall_s", rows)

    # paper-claim checks (trends)
    by = {(s, m): r for (s, m, *_), r in zip([(r[0], r[1]) for r in rows],
                                             rows)}
    slow_aggr = by[(1, 1.1)]
    slow_off = by[(1, 19.0)]
    fast_aggr = by[(29, 1.1)]
    assert slow_aggr[3] > 0.55, f"low-speed clustering too weak: {slow_aggr}"
    assert slow_aggr[3] > slow_off[3] + 0.2, "MF sweep has no effect"
    assert fast_aggr[4] > slow_aggr[4], "fast nodes should need more migs"
    print(f"[exp1] OK -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "quick")

"""Assemble the §Roofline table from the dry-run campaign results.

Reads results/dryrun/*.json (written by benchmarks/dryrun_all.py) and the
component-pass corrections (launch/costs.py) when available, and prints /
writes the per-(arch x shape x mesh) roofline terms:

    compute    = HLO_FLOPs / (chips x 197e12)
    memory     = HLO_bytes / (chips x 819e9)
    collective = collective_bytes / (chips x 50e9)

plus dominant term, MODEL_FLOPS/HLO_FLOPs and the memory-fit columns.
"""
from __future__ import annotations

import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN = os.path.join(ROOT, "results", "dryrun")
GIB = 2 ** 30


def load_cells(pattern="*.json"):
    """Base cell JSONs, with roofline terms overridden by the component
    pass (*_comp.json) when present — the component pass corrects XLA's
    count-while-bodies-once FLOP undercount (DESIGN.md §8)."""
    cells = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, pattern))):
        if p.endswith("_f32probe.json") or p.endswith("_comp.json"):
            continue
        # canonical cells only — perf-iteration variants carry a tag
        # after the mesh segment ({arch}_{shape}_{mesh}_{tag}.json)
        if os.path.basename(p)[:-len(".json")].rsplit("_", 1)[-1] \
                not in ("single", "multi"):
            continue
        with open(p) as f:
            d = json.load(f)
        comp_p = p[:-len(".json")] + "_comp.json"
        if os.path.exists(comp_p):
            with open(comp_p) as f:
                c = json.load(f)
            if c.get("status") == "ok":
                for k in ("t_compute_s", "t_memory_s", "t_collective_s",
                          "dominant", "useful_flop_ratio",
                          "roofline_fraction", "components"):
                    if k in c:
                        d[k] = c[k]
                d["terms_source"] = "component-pass"
        cells.append(d)
    return cells


def fmt_row(d):
    if d["status"] != "ok":
        return None
    peak = d.get("peak_bytes_per_dev_bf16_bound",
                 d.get("peak_bytes_per_dev_tpu_est",
                       d.get("peak_bytes_per_dev", 0)))
    fit = "Y" if peak <= 16 * GIB else "OVER"
    return (d["arch"], d["shape"], d["mesh"],
            f"{d['t_compute_s']:.3e}", f"{d['t_memory_s']:.3e}",
            f"{d['t_collective_s']:.3e}", d["dominant"],
            f"{d.get('useful_flop_ratio', 0):.2f}",
            f"{d.get('roofline_fraction', 0):.3f}",
            f"{d.get('peak_bytes_per_dev', 0)/GIB:.2f}",
            f"{peak/GIB:.2f}", fit)


HDR = ("arch", "shape", "mesh", "t_compute", "t_memory", "t_coll",
       "dominant", "useful", "roofline_frac", "peak_raw_GiB",
       "peak_est_GiB", "fits16G")


def main(out_csv="results/paper/roofline.csv"):
    cells = load_cells()
    rows = [r for r in (fmt_row(d) for d in cells) if r]
    skipped = [(d["arch"], d["shape"], d["mesh"]) for d in cells
               if d["status"] == "skipped"]
    bad = [(d["arch"], d["shape"], d["mesh"], d.get("detail", "")[:120])
           for d in cells if d["status"] not in ("ok", "skipped")]
    os.makedirs(os.path.dirname(os.path.join(ROOT, out_csv)), exist_ok=True)
    with open(os.path.join(ROOT, out_csv), "w") as f:
        f.write(",".join(HDR) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    w = [20, 12, 7, 10, 10, 10, 11, 7, 9, 8, 8, 5]
    print(" ".join(h.ljust(x) for h, x in zip(HDR, w)))
    for r in rows:
        print(" ".join(str(v).ljust(x) for v, x in zip(r, w)))
    print(f"\nok={len(rows)} skipped={len(skipped)} failed={len(bad)}")
    for b in bad:
        print("FAILED:", b)
    return len(bad) == 0


if __name__ == "__main__":
    sys.exit(0 if main() else 1)

"""Beyond-paper benchmark: GAIA self-clustering as MoE expert placement.

Simulates drifting, group-skewed routing traffic (the MoE analogue of
the ABM's mobility) and measures the all-to-all payload with a static
placement vs. GAIA's adaptive placement, charging every expert move at
its real MigComm price (Eq. 6).  The paper's trade — pay MigC to convert
remote traffic into local traffic — reproduced at the expert level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.core import gaia_moe as gm


def drifting_traffic(key, cfg, step, drift_every=200, tokens=4096):
    """(G, E) token counts: each expert has a 'home' group that rotates
    every `drift_every` steps (locality that moves, like RWP)."""
    E, G = cfg.num_experts, cfg.num_groups
    phase = step // drift_every
    home = (jnp.arange(E) + phase) % G
    base = jax.random.uniform(jax.random.fold_in(key, step), (G, E))
    w = base + 10.0 * (jnp.arange(G)[:, None] == home[None, :])
    w = w / w.sum()
    return w * tokens




def main(scale: str = "quick", steps=600, drift_every=200):
    cfg = gm.GaiaMoEConfig(num_experts=64, num_groups=8, mf=1.2, mt=50,
                           window=8, interval=25)
    d_model, d_expert, token_bytes = 2048, 768, 2 * 2048
    key = jax.random.key(0)

    st = gm.init_state(cfg)
    static_pl = st["placement"]
    upd = jax.jit(lambda s, tr: gm.maybe_update(cfg, s, tr))
    a2a = jax.jit(lambda pl, tr: gm.a2a_bytes(pl, tr, token_bytes))
    traffic = jax.jit(lambda t: drifting_traffic(key, cfg, t, drift_every))
    rows = []
    a2a_static = a2a_gaia = mig_bytes = moves = 0.0
    for t in range(steps):
        tr = traffic(jnp.int32(t))
        a2a_static += float(a2a(static_pl, tr))
        a2a_gaia += float(a2a(st["placement"], tr))
        st, n = upd(st, tr)
        n = int(n)
        moves += n
        mig_bytes += float(gm.migration_bytes(n, d_model, d_expert))
        if (t + 1) % 100 == 0:
            rows.append((t + 1, a2a_static, a2a_gaia, mig_bytes, moves))
            print(f"[gaia-moe] step {t+1}: a2a static={a2a_static/1e9:.2f}GB "
                  f"gaia={a2a_gaia/1e9:.2f}GB migs={int(moves)} "
                  f"migbytes={mig_bytes/1e9:.3f}GB")
    path = write_csv("gaia_moe.csv",
                     "step,a2a_static_bytes,a2a_gaia_bytes,mig_bytes,moves",
                     rows)
    total_static = a2a_static
    total_gaia = a2a_gaia + mig_bytes  # charge migrations at full price
    gain = 100 * (total_static - total_gaia) / total_static
    print(f"[gaia-moe] total comms: static {total_static/1e9:.2f}GB vs "
          f"gaia {total_gaia/1e9:.2f}GB  (gain {gain:+.1f}%)")
    assert moves > 0, "no expert migrations happened"
    assert gain > 10.0, f"GAIA-MoE should cut a2a traffic: {gain}%"
    print(f"[gaia-moe] OK -> {path}")
    return gain


if __name__ == "__main__":
    main()

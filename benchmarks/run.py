"""Benchmark harness entrypoint: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale quick|mid|paper]
                                            [--only exp1,exp2,...]
                                            [--replicas R]

Experiments (see DESIGN.md §Per-experiment index):
    exp1      Fig. 5  — LCR & migrations vs. speed x MF
    exp2      Fig. 6  — ΔLCR vs. #LPs
    exp3      Fig. 7  — ΔLCR vs. interaction range
    exp4      beyond-paper: proximity-backend scaling (BENCH_proximity)
    exp5      beyond-paper: LP-per-device sharded engine (BENCH_sharded)
    exp6      beyond-paper: mobility scenarios x environments
              (BENCH_scenarios)
    exp7      beyond-paper: partitioning backends vs adaptive GAIA
              (BENCH_partition)
    exp8      beyond-paper: batched-replica engine throughput
              (BENCH_replicas)
    exp9      beyond-paper: resident engine service — open-world churn
              throughput + request multiplexing (BENCH_service)
    tables23  Tables 2-3 + Figs. 8-9 — ΔWCT via the calibrated cost model
    gaiamoe   beyond-paper: adaptive MoE expert placement traffic
    roofline  assemble the §Roofline table from results/dryrun

`--replicas` sets the replica count for the statistical experiments
(exp1/2/3/6/7, tables23 — and the batch size of exp8); the default is 5
in quick mode and 10 at mid/paper scale. Replicas run in one batched
device pass (engine.run_batch) and every reported metric carries
mean/std/ci95/n (see README §Benchmarks).

The dry-run campaign itself (benchmarks/dryrun_all.py) is run separately
(it spawns one 512-device subprocess per cell).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick",
                    choices=["quick", "mid", "paper"])
    ap.add_argument("--only", default="")
    ap.add_argument("--replicas", type=int, default=None,
                    help="replica count for the statistical experiments "
                         "(default: 5 quick, 10 mid/paper)")
    args = ap.parse_args()

    from benchmarks import (exp1_speed, exp2_lps, exp3_range, exp4_scaling,
                            exp5_sharded, exp6_scenarios, exp7_partition,
                            exp8_replicas, exp9_service, tables23,
                            gaia_moe_bench, roofline, selftune_bench)
    # exp4..exp8 expose quick|full: paper-scale maps to their full sweep
    qf = "quick" if args.scale == "quick" else "full"
    rep = args.replicas
    benches = {
        "exp1": lambda: exp1_speed.main(args.scale, rep),
        "exp2": lambda: exp2_lps.main(args.scale, rep),
        "exp3": lambda: exp3_range.main(args.scale, rep),
        "exp4": lambda: exp4_scaling.main(qf),
        "exp5": lambda: exp5_sharded.main(qf),
        "exp6": lambda: exp6_scenarios.main(qf, rep),
        "exp7": lambda: exp7_partition.main(qf, rep),
        "exp8": lambda: exp8_replicas.main(qf, rep),
        "exp9": lambda: exp9_service.main(qf),
        "tables23": lambda: tables23.main(args.scale, rep),
        "gaiamoe": lambda: gaia_moe_bench.main(args.scale),
        "selftune": lambda: selftune_bench.main(args.scale),
        "roofline": lambda: roofline.main(),
    }
    only = [s for s in args.only.split(",") if s] or list(benches)
    failures = []
    for name in only:
        t0 = time.time()
        print(f"\n===== {name} ({args.scale}) =====", flush=True)
        try:
            benches[name]()
            print(f"===== {name}: PASS ({time.time()-t0:.0f}s) =====")
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"===== {name}: FAIL ({time.time()-t0:.0f}s) =====")
    if failures:
        print(f"\nFAILED: {failures}")
        return 1
    print("\nAll benchmarks passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())

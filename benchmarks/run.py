"""Benchmark harness entrypoint: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale quick|mid|paper]
                                            [--only exp1,exp2,...]
                                            [--replicas R]
                                            [--trace [N_STEPS]]

Experiments (see DESIGN.md §Per-experiment index):
    exp1      Fig. 5  — LCR & migrations vs. speed x MF
    exp2      Fig. 6  — ΔLCR vs. #LPs
    exp3      Fig. 7  — ΔLCR vs. interaction range
    exp4      beyond-paper: proximity-backend scaling (BENCH_proximity)
    exp5      beyond-paper: LP-per-device sharded engine (BENCH_sharded)
    exp6      beyond-paper: mobility scenarios x environments
              (BENCH_scenarios)
    exp7      beyond-paper: partitioning backends vs adaptive GAIA
              (BENCH_partition)
    exp8      beyond-paper: batched-replica engine throughput
              (BENCH_replicas)
    exp9      beyond-paper: resident engine service — open-world churn
              throughput + request multiplexing (BENCH_service)
    exp10     beyond-paper: telemetry overhead + step-phase trace
              export (BENCH_obs)
    tables23  Tables 2-3 + Figs. 8-9 — ΔWCT via the calibrated cost model
    gaiamoe   beyond-paper: adaptive MoE expert placement traffic
    roofline  assemble the §Roofline table from results/dryrun

`--trace` skips the benchmark sweep and exports step-phase trace
timelines instead (repro.obs.trace): one Chrome-trace/Perfetto JSON per
execution layer — results/trace_oracle.json and, on a >= 2-device
topology (forced automatically on CPU), results/trace_lp_device.json —
openable directly at https://ui.perfetto.dev or chrome://tracing. The
optional argument is the number of steps to trace (default 8).

`--replicas` sets the replica count for the statistical experiments
(exp1/2/3/6/7, tables23 — and the batch size of exp8); the default is 5
in quick mode and 10 at mid/paper scale. Replicas run in one batched
device pass (engine.run_batch) and every reported metric carries
mean/std/ci95/n (see README §Benchmarks).

The dry-run campaign itself (benchmarks/dryrun_all.py) is run separately
(it spawns one 512-device subprocess per cell).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def trace_main(n_steps: int) -> int:
    """Export step-phase Perfetto timelines for both execution layers
    (the --trace mode). Must run before any bench import pulls in jax:
    the sharded trace needs a multi-device topology, which on CPU is an
    env var that only counts before the first jax import."""
    if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count=4")
    import dataclasses

    import jax

    from benchmarks.common import engine_cfg
    from repro.obs import trace_run

    results = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results")
    os.makedirs(results, exist_ok=True)
    base = dataclasses.replace(engine_cfg("quick"), timesteps=n_steps)
    layers = [("oracle", base)]
    n_dev = jax.device_count()
    if n_dev >= 2:
        layers.append(("lp_device", dataclasses.replace(
            base, sharding="lp_device", n_devices=min(n_dev, 4))))
    else:
        print("[trace] single-device topology: skipping the lp_device "
              "timeline")
    for name, cfg in layers:
        rec = trace_run(cfg, seed=0)
        path = rec.save(os.path.join(results, f"trace_{name}.json"))
        phases = rec.phase_summary()
        total = sum(st["total"] for st in phases.values())
        print(f"[trace] {name}: {n_steps} steps, "
              f"{len(phases)} phases, {total:.3f}s total -> {path}")
    print("[trace] open at https://ui.perfetto.dev or chrome://tracing")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick",
                    choices=["quick", "mid", "paper"])
    ap.add_argument("--only", default="")
    ap.add_argument("--replicas", type=int, default=None,
                    help="replica count for the statistical experiments "
                         "(default: 5 quick, 10 mid/paper)")
    ap.add_argument("--trace", nargs="?", type=int, const=8, default=None,
                    metavar="N_STEPS",
                    help="export step-phase Perfetto timelines instead of "
                         "running benchmarks (default 8 steps)")
    args = ap.parse_args()

    if args.trace is not None:
        return trace_main(args.trace)

    from benchmarks import (exp1_speed, exp2_lps, exp3_range, exp4_scaling,
                            exp5_sharded, exp6_scenarios, exp7_partition,
                            exp8_replicas, exp9_service, exp10_obs, fleet,
                            tables23, gaia_moe_bench, roofline,
                            selftune_bench)
    # exp4..exp8 expose quick|full: paper-scale maps to their full sweep
    qf = "quick" if args.scale == "quick" else "full"
    rep = args.replicas
    benches = {
        "exp1": lambda: exp1_speed.main(args.scale, rep),
        "exp2": lambda: exp2_lps.main(args.scale, rep),
        "exp3": lambda: exp3_range.main(args.scale, rep),
        "exp4": lambda: exp4_scaling.main(qf),
        "exp5": lambda: exp5_sharded.main(qf),
        "exp6": lambda: exp6_scenarios.main(qf, rep),
        # the fleet runs exp6's matrix (plus the partitioner/device
        # axes) as subprocess cells and writes the same
        # BENCH_scenarios.json — so it replaces exp6 when selected and
        # is excluded from the run-everything default to avoid running
        # the sweep twice
        "fleet": lambda: fleet.main(qf, rep),
        "exp7": lambda: exp7_partition.main(qf, rep),
        "exp8": lambda: exp8_replicas.main(qf, rep),
        "exp9": lambda: exp9_service.main(qf),
        "exp10": lambda: exp10_obs.main(qf),
        "tables23": lambda: tables23.main(args.scale, rep),
        "gaiamoe": lambda: gaia_moe_bench.main(args.scale),
        "selftune": lambda: selftune_bench.main(args.scale),
        "roofline": lambda: roofline.main(),
    }
    only = [s for s in args.only.split(",") if s] or \
        [k for k in benches if k != "fleet"]
    failures = []
    for name in only:
        t0 = time.time()
        print(f"\n===== {name} ({args.scale}) =====", flush=True)
        try:
            benches[name]()
            print(f"===== {name}: PASS ({time.time()-t0:.0f}s) =====")
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"===== {name}: FAIL ({time.time()-t0:.0f}s) =====")
    if failures:
        print(f"\nFAILED: {failures}")
        return 1
    print("\nAll benchmarks passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())

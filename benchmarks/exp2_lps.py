"""Experiment 2 (paper Fig. 6): ΔLCR vs. Migration Ratio across #LPs.

Paper claim: with few LPs the self-clustering gains are large; splitting
the same model over more LPs lowers the achievable ΔLCR but stays > 0.
"""
from __future__ import annotations

from benchmarks.common import engine_cfg, run_cfg, write_csv


def main(scale: str = "quick", seeds=(0,)):
    lps = [2, 4, 8, 16, 32, 50]
    rows = []
    for n_lp in lps:
        for seed in seeds:
            on = run_cfg(engine_cfg(scale, n_lp=n_lp, speed=11.0, mf=1.2),
                         seed)
            off = run_cfg(engine_cfg(scale, n_lp=n_lp, speed=11.0,
                                     gaia=False), seed)
            dlcr = on["mean_lcr"] - off["mean_lcr"]
            rows.append((n_lp, seed, round(off["mean_lcr"], 4),
                         round(on["mean_lcr"], 4), round(dlcr, 4),
                         round(on["migration_ratio"], 2)))
            print(f"[exp2] LPs={n_lp:<3} seed={seed} LCR {off['mean_lcr']:.3f}"
                  f" -> {on['mean_lcr']:.3f} (dLCR {dlcr:+.3f}, "
                  f"MR {on['migration_ratio']:.1f})")
    path = write_csv("exp2.csv", "n_lp,seed,lcr_off,lcr_on,dlcr,mr", rows)

    d = {r[0]: r[4] for r in rows}
    assert d[2] > 0.2 and d[4] > 0.2, f"few-LP gains too small: {d}"
    assert d[2] > d[32], "dLCR should shrink with more LPs"
    assert all(v > 0 for v in d.values()), f"dLCR must stay positive: {d}"
    print(f"[exp2] OK -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "quick")

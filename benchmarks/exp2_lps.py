"""Experiment 2 (paper Fig. 6): ΔLCR vs. Migration Ratio across #LPs.

Paper claim: with few LPs the self-clustering gains are large; splitting
the same model over more LPs lowers the achievable ΔLCR but stays > 0.
ΔLCR is a *paired* per-seed difference (ON and OFF run the same seeds),
so its ci95 excludes between-seed variance.
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
from benchmarks.common import (default_replicas, engine_cfg,  # noqa: E402
                               fmt_stat, paired_stats, run_cfg, write_csv)


def main(scale: str = "quick", replicas=None):
    n_rep = default_replicas(scale, replicas)
    lps = [2, 4, 8, 16, 32, 50]
    rows = []
    for n_lp in lps:
        on = run_cfg(engine_cfg(scale, n_lp=n_lp, speed=11.0, mf=1.2),
                     replicas=n_rep)
        off = run_cfg(engine_cfg(scale, n_lp=n_lp, speed=11.0, gaia=False),
                      replicas=n_rep)
        dlcr = paired_stats(on["reps"], off["reps"],
                            lambda a, b: a["mean_lcr"] - b["mean_lcr"])
        rows.append((n_lp, round(off["mean_lcr"], 4),
                     round(on["mean_lcr"], 4), round(dlcr["mean"], 4),
                     round(dlcr["ci95"], 4), n_rep,
                     round(on["migration_ratio"], 2)))
        print(f"[exp2] LPs={n_lp:<3} LCR {off['mean_lcr']:.3f} -> "
              f"{on['mean_lcr']:.3f} (dLCR {fmt_stat(dlcr)}, "
              f"MR {on['migration_ratio']:.1f})")
    path = write_csv("exp2.csv",
                     "n_lp,lcr_off,lcr_on,dlcr,dlcr_ci95,n,mr", rows)

    d = {r[0]: r[3] for r in rows}
    assert d[2] > 0.2 and d[4] > 0.2, f"few-LP gains too small: {d}"
    assert d[2] > d[32], "dLCR should shrink with more LPs"
    assert all(v > 0 for v in d.values()), f"dLCR must stay positive: {d}"
    print(f"[exp2] OK (n={n_rep}) -> {path}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "mid", "paper"])
    ap.add_argument("--replicas", type=int, default=None)
    a = ap.parse_args()
    main(a.scale, a.replicas)

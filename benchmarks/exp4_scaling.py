"""Experiment 4 (beyond-paper): proximity-backend scaling sweep.

The paper's hot spot is O(N^2) proximity matching; this sweep measures
one `interaction_counts` evaluation per backend across N (paper
defaults: area 10000, range 250, 4 LPs, pi 0.2) and records the results
in BENCH_proximity.json at the repo root.

Backends:
  dense        the O(N^2) oracle; row-chunked above `DENSE_CHUNK_ABOVE`
               SEs (same flop count, O(chunk*N) memory — the full pair
               matrix would not fit at 50k+)
  grid         cell-list neighbor search, O(N*k)
  pallas[...]  the TPU kernels; interpret mode on CPU executes the
               kernel body per tile in Python, so they are only timed at
               small N (they measure kernel *correctness* on CPU,
               kernel *speed* on TPU — see DESIGN.md §Adaptations)

Acceptance gate (tentpole): grid >= 5x faster than dense at N = 50k.

    PYTHONPATH=src python benchmarks/exp4_scaling.py [quick|full|scale]

quick: dense up to 50k, grid up to 100k, no pallas (a few minutes on one
CPU core). full: adds 100k dense and small-N pallas backends. scale:
quick plus the million-SE tier — grid-only cells at SCALE_NS, run at the
paper's *constant* density (the fixed-area sweep above densifies with N,
which is a different experiment), two decades past the old 50k ceiling.
Scale cells run the CSR candidate path under a hard memory budget and
record the grid_overflow flag so the curve is exact-or-loud.
"""
from __future__ import annotations

import json
import math
import os
import time

import jax

from repro.core.abm import ABMConfig, interaction_counts, \
    interaction_counts_overflow
from repro.core.engine import clear_compiled_caches
from repro.core.neighbors import dense_lp_counts_chunked
from repro.core.stats import replica_stats

OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_proximity.json")

NS = (1_000, 10_000, 50_000, 100_000)
#: million-SE tier: two decades past the 50k gate, constant density
SCALE_NS = (500_000, 1_000_000, 5_000_000)
#: paper density 1e-4 SE/unit^2 (10k SEs on the 10_000^2 torus):
#: area(n) = sqrt(n / density) = 100 * sqrt(n)
SCALE_DENSITY = 1e-4
SCALE_BUDGET_MB = 512  # hard candidate-memory budget for scale cells
DENSE_CHUNK_ABOVE = 4096  # row-chunk the dense sweep past this N
PAPER = dict(n_lp=4, area=10_000.0, speed=11.0, interaction_range=250.0,
             p_interact=0.2)


def _inputs(n, seed=0, area=None):
    k = jax.random.key(seed)
    pos = jax.random.uniform(jax.random.fold_in(k, 0), (n, 2),
                             maxval=area or PAPER["area"])
    lp = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0,
                            PAPER["n_lp"])
    sender = jax.random.bernoulli(jax.random.fold_in(k, 2),
                                  PAPER["p_interact"], (n,))
    return pos, lp, sender


def _bench(fn, args, reps):
    fn(*args)  # compile + warm caches
    times = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        times.append(time.time() - t0)
    return times


def measure(n: int, backend: str, reps: int) -> dict:
    cfg = ABMConfig(n_se=n, proximity_backend=backend, **PAPER)
    args = _inputs(n)
    # arrays are jit *arguments*, never closed over: a closure would bake
    # them into the HLO as constants and invite constant folding, timing
    # dispatch overhead instead of the sweep
    if backend == "dense" and n > DENSE_CHUNK_ABOVE:
        fn = jax.jit(lambda p, l, s: dense_lp_counts_chunked(
            p, l, s, cfg.n_lp, cfg.area, cfg.interaction_range))
        note = "row-chunked"
    else:
        fn = jax.jit(lambda p, l, s: interaction_counts(p, l, s, cfg))
        note = ""
    times = _bench(fn, args, reps)
    stats = replica_stats(times)
    mean_s = stats["mean"]
    row = {"n": n, "backend": backend, "mean_s": round(mean_s, 4),
           "time_s": {k: round(v, 4) for k, v in stats.items()},
           "reps": reps, "pairs_per_s": round(n * n / mean_s)}
    if note:
        row["note"] = note
    spec = cfg.grid_spec()
    if backend in ("grid", "pallas_grid") and spec is not None:
        row["grid"] = {"ncell": spec.ncell, "capacity": spec.capacity}
    return row


def measure_scale(n: int, reps: int) -> dict:
    """One constant-density grid cell of the million-SE tier: CSR
    candidate path under `SCALE_BUDGET_MB`, overflow flag recorded (the
    curve is only meaningful where it is exact)."""
    area = 100.0 * math.sqrt(n)  # n / area^2 == SCALE_DENSITY
    cfg = ABMConfig(n_se=n, proximity_backend="grid",
                    mem_budget_mb=SCALE_BUDGET_MB,
                    **dict(PAPER, area=area))
    args = _inputs(n, area=area)
    fn = jax.jit(lambda p, l, s: interaction_counts(p, l, s, cfg))
    times = _bench(fn, args, reps)
    stats = replica_stats(times)
    mean_s = stats["mean"]
    _, ovf = interaction_counts_overflow(*args, cfg)
    spec = cfg.grid_spec()
    return {"n": n, "backend": "grid", "mean_s": round(mean_s, 4),
            "time_s": {k: round(v, 4) for k, v in stats.items()},
            "reps": reps, "pairs_per_s": round(n * n / mean_s),
            "area": round(area, 1), "density": SCALE_DENSITY,
            "mem_budget_mb": SCALE_BUDGET_MB,
            "grid_overflow": bool(ovf),
            "grid": {"ncell": spec.ncell, "capacity": spec.capacity}}


def main(scale: str = "quick"):
    # reps >= 3 everywhere: BENCH time_s entries must carry a real
    # ci95 (the n >= 3 schema requirement), dense@50k included
    plan = []  # (n, backend, reps)
    for n in NS:
        if n < 100_000 or scale == "full":
            plan.append((n, "dense", 3))
        plan.append((n, "grid", 5 if n <= 10_000 else 3))
    if scale == "full":
        plan += [(1_000, "pallas", 1), (1_000, "pallas_grid", 1)]

    rows = []
    for n, backend, reps in plan:
        row = measure(n, backend, reps)
        rows.append(row)
        print(f"[exp4] N={n:<7} {backend:<12} {row['mean_s']:.4f}s "
              f"({row['pairs_per_s']:.3g} pair/s)")

    scale_rows = []
    if scale == "scale":
        for n in SCALE_NS:
            # drop every compiled program from the previous cell: the
            # sweep's peak RSS must be one cell's, not the sum of all
            clear_compiled_caches()
            jax.clear_caches()
            row = measure_scale(n, reps=2 if n < 5_000_000 else 1)
            scale_rows.append(row)
            print(f"[exp4] N={n:<9} grid(scale)  {row['mean_s']:.4f}s "
                  f"({row['pairs_per_s']:.3g} pair/s, "
                  f"overflow={row['grid_overflow']})")
        assert not any(r["grid_overflow"] for r in scale_rows), \
            "scale tier overflowed its budgeted capacity (curve not exact)"

    by = {(r["n"], r["backend"]): r["mean_s"] for r in rows}
    speedups = {str(n): round(by[(n, "dense")] / by[(n, "grid")], 2)
                for n in NS if (n, "dense") in by and (n, "grid") in by}
    result = {
        "experiment": "exp4_scaling",
        "config": dict(PAPER, dense_chunk_above=DENSE_CHUNK_ABOVE,
                       scale=scale),
        "device": str(jax.devices()[0]),
        "results": rows,
        "grid_speedup_over_dense": speedups,
    }
    if scale_rows:
        result["scale_tier"] = {
            "density_se_per_unit2": SCALE_DENSITY,
            "mem_budget_mb": SCALE_BUDGET_MB,
            "results": scale_rows,
        }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    s50 = speedups.get("50000")
    assert s50 is not None and s50 >= 5.0, \
        f"grid speedup at 50k below the 5x gate: {s50}"
    print(f"[exp4] OK (50k speedup {s50}x) -> {OUT}")
    return result


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "quick")

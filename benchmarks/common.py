"""Shared helpers for the paper-experiment benchmarks.

The paper's full scale (#SE=10000, 3600 timesteps, O(N^2) proximity) is
sized for a 16-core Xeon; this container is one CPU core, so every
experiment has a `scale` knob: "quick" (CI-sized, minutes) and "paper"
(the published parameters). Trends — not absolute seconds — are the
reproduction target either way; see DESIGN.md §Deviations.

Replicas: every performance claim in the paper is a statement about the
*expected* behaviour of a stochastic simulation, so the statistical
experiments take a `replicas` count (CLI `--replicas`; default 5 in
quick mode, 10 at mid/paper scale). The R seeds run in ONE batched
device pass (`Engine.run(seeds=...)`, vmap over the seed axis — replica
r is
bit-identical to a sequential run on seed r), and every reported metric
carries mean/std/ci95/n (src/repro/core/stats.py).
"""
from __future__ import annotations

import copy
import functools
import os
import time

from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig
from repro.core.heuristics import HeuristicConfig
from repro.core.service import Engine
from repro.core.stats import replica_stats, summarize

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "paper")


SCALES = {
    # n_se, timesteps, area (density kept at the paper's 1e-4 SE/unit^2)
    "quick": dict(n_se=1000, timesteps=400, area=3162.0),
    "mid": dict(n_se=3000, timesteps=900, area=5477.0),
    "paper": dict(n_se=10_000, timesteps=3600, area=10_000.0),
}

#: default replica counts per scale (n >= 3 in CI, which passes
#: --replicas 3 explicitly to bound the nightly budget). "full" is
#: exp6/exp7/exp8's name for their paper-sized sweep.
DEFAULT_REPLICAS = {"quick": 5, "mid": 10, "paper": 10, "full": 10}


def default_replicas(scale: str, override=None) -> int:
    """CLI --replicas override, else the per-scale default."""
    return int(override) if override else DEFAULT_REPLICAS.get(scale, 5)


def engine_cfg(scale: str, *, n_lp=4, speed=11.0, rng=250.0, pi=0.2,
               mf=1.2, mt=10, gaia=True, kind=1, timesteps=None,
               backend="grid"):
    """`speed` is in PAPER units (10000-side torus) and is scaled by
    side/10000 so the scaled-down world preserves the paper's *relative*
    dynamics (an SE crosses the world in the same number of timesteps —
    this is what sets the migration rate). `rng` stays absolute: SE
    density matches the paper's 1e-4/unit^2, so an absolute range keeps
    the paper's expected neighbor count (~19.6 at rng=250)."""
    s = SCALES[scale]
    f = s["area"] / 10_000.0
    return EngineConfig(
        abm=ABMConfig(n_se=s["n_se"], n_lp=n_lp, area=s["area"],
                      speed=speed * f, interaction_range=rng,
                      p_interact=pi, proximity_backend=backend),
        heuristic=HeuristicConfig(kind=kind, mf=mf, mt=mt),
        gaia_on=gaia,
        timesteps=timesteps or s["timesteps"],
    )


@functools.lru_cache(maxsize=None)
def _batch_counters(cfg: EngineConfig, seeds: tuple):
    """Hoisted cross-benchmark run cache: one batched engine run per
    distinct (config, seed-vector) per process. exp1's speed x MF grid
    overlaps tables23's MF sweep, and tables23 re-prices the same run
    across 9 (interaction, migration)-size combinations — pricing is
    cost-model arithmetic and must never re-run the engine. run_cfg
    deep-copies on the way out, so callers can never corrupt the
    cached counters."""
    _, _, reps = Engine(cfg).run(seeds=seeds)
    return reps


def run_cfg(cfg: EngineConfig, seed=0, replicas=1):
    """Run `replicas` consecutive seeds (seed..seed+R-1) in one batched
    pass. Returns a counters dict carrying

      * the replica-*mean* at every scalar metric key (trend code keeps
        reading c["mean_lcr"] / c["migrations"]),
      * "stats": {metric: {mean, std, ci95, n}} (the BENCH schema),
      * "reps": the per-replica counter dicts (matrix flow counters
        included — price each replica, then aggregate the prices),
      * "wall_s": wall time of this call (0 on a cache hit).
    """
    t0 = time.time()
    # deep copy: the cache's dicts are shared across benchmarks, and a
    # caller annotating/rounding a counters dict in place must corrupt
    # its own copy, never a later cache hit
    reps = copy.deepcopy(
        _batch_counters(cfg, tuple(range(seed, seed + replicas))))
    stats = summarize(reps)
    out = {k: v["mean"] for k, v in stats.items()}
    out["stats"] = stats
    out["reps"] = reps
    out["wall_s"] = time.time() - t0
    return out


def paired_stats(a_reps, b_reps, fn):
    """Stats of a per-replica *paired* derived metric: fn(a_r, b_r) per
    seed (e.g. dLCR or TEC gain ON vs OFF on the same seed) — pairing
    removes the between-seed variance the unpaired difference would
    carry."""
    return replica_stats([fn(a, b) for a, b in zip(a_reps, b_reps)])


def fmt_stat(st: dict, nd: int = 3) -> str:
    """'mean±ci95 (n=N)' log formatting for a stats dict."""
    return f"{st['mean']:.{nd}f}±{st['ci95']:.{nd}f}(n={st['n']})"


def write_csv(name: str, header: str, rows):
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path

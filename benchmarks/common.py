"""Shared helpers for the paper-experiment benchmarks.

The paper's full scale (#SE=10000, 3600 timesteps, O(N^2) proximity) is
sized for a 16-core Xeon; this container is one CPU core, so every
experiment has a `scale` knob: "quick" (CI-sized, minutes) and "paper"
(the published parameters). Trends — not absolute seconds — are the
reproduction target either way; see DESIGN.md §Deviations.
"""
from __future__ import annotations

import os
import time

import jax

from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "paper")


SCALES = {
    # n_se, timesteps, area (density kept at the paper's 1e-4 SE/unit^2)
    "quick": dict(n_se=1000, timesteps=400, area=3162.0),
    "mid": dict(n_se=3000, timesteps=900, area=5477.0),
    "paper": dict(n_se=10_000, timesteps=3600, area=10_000.0),
}


def engine_cfg(scale: str, *, n_lp=4, speed=11.0, rng=250.0, pi=0.2,
               mf=1.2, mt=10, gaia=True, kind=1, timesteps=None,
               backend="grid"):
    """`speed` is in PAPER units (10000-side torus) and is scaled by
    side/10000 so the scaled-down world preserves the paper's *relative*
    dynamics (an SE crosses the world in the same number of timesteps —
    this is what sets the migration rate). `rng` stays absolute: SE
    density matches the paper's 1e-4/unit^2, so an absolute range keeps
    the paper's expected neighbor count (~19.6 at rng=250)."""
    s = SCALES[scale]
    f = s["area"] / 10_000.0
    return EngineConfig(
        abm=ABMConfig(n_se=s["n_se"], n_lp=n_lp, area=s["area"],
                      speed=speed * f, interaction_range=rng,
                      p_interact=pi, proximity_backend=backend),
        heuristic=HeuristicConfig(kind=kind, mf=mf, mt=mt),
        gaia_on=gaia,
        timesteps=timesteps or s["timesteps"],
    )


def run_cfg(cfg, seed=0):
    t0 = time.time()
    _, series, counters = run(jax.random.key(seed), cfg)
    counters["wall_s"] = time.time() - t0
    return counters


def write_csv(name: str, header: str, rows):
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path

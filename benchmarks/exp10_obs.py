"""Experiment 10 (beyond-paper): telemetry overhead + trace export.

Measures the ISSUE-9 observability acceptance bars:

1. **Overhead** — the per-step metrics ledger (ring buffer in the scan
   carry, one async `jax.debug.callback` per `drain_every=10` steps,
   host-side ingestion into the streaming ledger) must cost < 10% wall
   clock on the quick config: `obs.overhead_ratio = t_on / t_off`, min
   over reps on both sides (exp8/exp9 flake-avoidance protocol). The
   timed region includes the host callback work — that is the cost a
   resident deployment actually pays.
2. **Non-perturbation** — the obs-on run must be *bit-identical* to the
   obs-off run on the same seed (per-step series compared exactly), and
   the drained ledger must reproduce the series it mirrors. Asserted
   here so the nightly gate re-proves it at bench scale, not just at
   test scale (tests/test_obs.py).
3. **Trace export** — a 2-device subprocess traces a short sharded run
   phase-by-phase and writes a Chrome-trace/Perfetto JSON
   (results/exp10_trace.json, CI artifact); the parent validates the
   timeline structure (per-device rows, step-phase spans). The events
   JSONL from the overhead run lands next to it
   (results/exp10_events.jsonl).

Results land in BENCH_obs.json; `obs.overhead_ratio` is tracked by
benchmarks/compare.py against BENCH_baseline/ (a time/time ratio —
TIMING_TOL width, machine-independent shape).

    PYTHONPATH=src python benchmarks/exp10_obs.py [quick|full]
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import engine_cfg  # noqa: E402
from repro.core.service import Engine  # noqa: E402
from repro.obs import ObsConfig, Telemetry, runtime  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(REPO, "BENCH_obs.json")
RESULTS_DIR = os.path.join(REPO, "results")
TRACE_OUT = os.path.join(RESULTS_DIR, "exp10_trace.json")
EVENTS_OUT = os.path.join(RESULTS_DIR, "exp10_events.jsonl")

OVERHEAD_BOUND = 1.10  # ISSUE-9 bar: < 10% wall overhead at drain_every=10
DRAIN_EVERY = 10
TIME_REPS = {"quick": 3, "full": 5}
TRACE_DEVS = 2
TRACE_STEPS = 6

SERIES_KEYS = ("lcr", "local_msgs", "remote_msgs", "migrations",
               "heu_evals")


def overhead_section(scale: str):
    """Same seed, same config, obs off vs on: wall ratio + bit-identity
    + ledger-vs-series cross-check."""
    reps = TIME_REPS[scale]
    cfg_off = engine_cfg("quick")
    cfg_on = dataclasses.replace(
        cfg_off, obs=ObsConfig(enabled=True, drain_every=DRAIN_EVERY))

    # warm both compiled scans (they compile apart: the on-path carries
    # the ring; the off-path is the historical program)
    Engine(cfg_off).run(seed=0)
    Engine(cfg_on).run(seed=0)
    runtime.set_current(None)

    t_off, series_off = [], None
    for _ in range(reps):
        t0 = time.time()
        _, series_off, _ = Engine(cfg_off).run(seed=0)
        jax.block_until_ready(series_off)
        t_off.append(time.time() - t0)

    t_on, tele, series_on = [], None, None
    for _ in range(reps):
        eng = Engine(cfg_on)
        tele = eng.telemetry
        t0 = time.time()
        _, series_on, _ = eng.run(seed=0)
        jax.block_until_ready(series_on)
        jax.effects_barrier()  # count the in-flight drains too
        t_on.append(time.time() - t0)
        runtime.set_current(None)

    for k in SERIES_KEYS:  # bit-identity: telemetry never perturbs
        np.testing.assert_array_equal(
            np.asarray(series_off[k]), np.asarray(series_on[k]),
            err_msg=f"obs-on diverged from obs-off on {k}")
    # drain completeness: one ledger row per step, counters exact
    assert len(tele.ledger) == cfg_on.timesteps, \
        f"ledger {len(tele.ledger)} rows != {cfg_on.timesteps} steps"
    np.testing.assert_array_equal(
        tele.ledger.column("migrations"),
        np.asarray(series_on["migrations"], np.float64))

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(EVENTS_OUT, "w", encoding="utf-8") as fh:
        for ev in tele.events.records():
            fh.write(json.dumps(ev.as_dict()) + "\n")

    ratio = min(t_on) / min(t_off)
    print(f"[exp10] overhead: off {min(t_off):.2f}s on {min(t_on):.2f}s "
          f"-> {ratio:.3f}x (bound < {OVERHEAD_BOUND}), "
          f"{len(tele.ledger)} ledger rows, "
          f"{len(tele.events.records())} events -> {EVENTS_OUT}")
    return {
        "drain_every": DRAIN_EVERY,
        "timesteps": cfg_on.timesteps,
        "t_off_s": [round(t, 3) for t in t_off],
        "t_on_s": [round(t, 3) for t in t_on],
        "overhead_ratio": round(ratio, 4),
        "overhead_bound": OVERHEAD_BOUND,
        "ledger_rows": len(tele.ledger),
        "events": len(tele.events.records()),
        "bit_identical": True,  # the asserts above would have raised
    }


# 2-device child (exp5 protocol): trace a short sharded run phase-by-
# phase and save the Perfetto JSON; RESULT carries the phase summary.
_TRACE_CODE = """
import dataclasses, json
from benchmarks.common import engine_cfg
from repro.obs import trace_run

cfg = dataclasses.replace(engine_cfg("quick"), timesteps={steps},
                          sharding="lp_device", n_devices={n_dev})
rec = trace_run(cfg, seed=0)
rec.save({out!r})
print("RESULT " + json.dumps({{
    "n_devices": {n_dev}, "steps": {steps},
    "spans": sum(1 for e in rec.events if e.get("ph") == "X"),
    "phase_summary": rec.phase_summary(),
}}))
"""


def _run_child(code: str, n_dev: int) -> dict:
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), REPO,
             os.environ.get("PYTHONPATH", "")]),
        XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
        XLA_PYTHON_CLIENT_PREALLOCATE="false",
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=3600, env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stdout + r.stderr)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in: {r.stdout!r}")


def trace_section():
    """Sharded step-phase timeline in a TRACE_DEVS-device subprocess;
    the parent re-opens the saved JSON and validates the Perfetto
    structure it promises CI consumers."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    row = _run_child(
        _TRACE_CODE.format(steps=TRACE_STEPS, n_dev=TRACE_DEVS,
                           out=TRACE_OUT),
        TRACE_DEVS)
    with open(TRACE_OUT, encoding="utf-8") as fh:
        doc = json.load(fh)
    spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert spans, "trace exported no phase spans"
    assert {e["tid"] for e in spans} == set(range(TRACE_DEVS)), \
        "trace missing per-device timeline rows"
    names = {e["name"] for e in spans}
    assert {"migrate", "mobility", "halo_exchange", "proximity",
            "finalize"} <= names, f"phases missing from trace: {names}"
    phases = row["phase_summary"]
    print(f"[exp10] trace: {row['spans']} spans over {TRACE_STEPS} steps "
          f"x {TRACE_DEVS} devices -> {TRACE_OUT}")
    for name, st in sorted(phases.items(),
                           key=lambda kv: -kv[1]["total"]):
        print(f"[exp10]   {name:14s} mean {st['mean'] * 1e3:7.2f}ms "
              f"total {st['total']:.3f}s (n={st['n']})")
    return {
        "n_devices": TRACE_DEVS, "steps": TRACE_STEPS,
        "spans": row["spans"], "trace_path": os.path.relpath(
            TRACE_OUT, REPO),
        "phase_summary": {k: {kk: round(vv, 6) for kk, vv in st.items()}
                          for k, st in phases.items()},
    }


def main(scale: str = "quick"):
    overhead = overhead_section(scale)
    trace = trace_section()

    result = {
        "experiment": "exp10_obs",
        "config": dict(scale=scale, backend=jax.default_backend(),
                       n_se=engine_cfg("quick").abm.n_se,
                       drain_every=DRAIN_EVERY),
        "obs": overhead,
        "trace": trace,
        "gate": {
            "overhead_ratio": {"value": overhead["overhead_ratio"],
                               "bound": OVERHEAD_BOUND, "dir": "lower"},
        },
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)

    assert overhead["overhead_ratio"] < OVERHEAD_BOUND, \
        (f"telemetry overhead {overhead['overhead_ratio']:.3f}x "
         f"exceeds the {OVERHEAD_BOUND}x bar")
    print(f"[exp10] OK -> {OUT}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "full"])
    a = ap.parse_args()
    main(a.scale)

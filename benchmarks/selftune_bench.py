"""§5.5 self-tuning benchmark: does intra-run MF tuning recover the
offline-sweep optimum without the sweep?

Compares priced TEC of (a) the best fixed MF found by the Fig. 8-style
offline sweep, (b) the intra-run self-tuner started from a bad MF, and
(c) the bad fixed MF itself — on the same model/seed.
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import engine_cfg, write_csv
from repro.core.costmodel import SETUPS, wct
from repro.core.engine import run
from repro.core.selftune import SelfTuneConfig, intra_run_tune


def main(scale: str = "quick"):
    cfg = engine_cfg(scale, speed=5.0, mf=0.0)  # mf set per variant
    ts = cfg.timesteps
    params = SETUPS["distributed"]
    def price(c):
        return wct(c, params, cfg.abm.n_lp, ts,
                   interaction_bytes=1024, migration_bytes=32)["TEC"]
    key = jax.random.key(0)

    # (a) offline sweep (the paper's method)
    sweep = {}
    for mf in (1.1, 1.5, 3.0, 8.0):
        c = dataclasses.replace(cfg, heuristic=dataclasses.replace(
            cfg.heuristic, mf=mf))
        _, _, counters = run(key, c)
        sweep[mf] = price(counters)
        print(f"[selftune] fixed MF={mf:<4}: TEC {sweep[mf]:8.2f}s")
    best_mf = min(sweep, key=sweep.get)

    # (b) intra-run tuner from a bad start
    tc = SelfTuneConfig(window=max(50, ts // 8), mf0=8.0,
                        setup="distributed", interaction_bytes=1024,
                        migration_bytes=32)
    _, hist = intra_run_tune(key, cfg, tc, total_steps=ts)
    tuned_tec = sum(h[3] for h in hist) * tc.window
    steady = sum(h[3] for h in hist[-3:]) / 3 * ts  # post-warm-up rate
    print(f"[selftune] tuned (from MF=8): total TEC {tuned_tec:8.2f}s, "
          f"steady-state rate {steady:8.2f}s/run-equiv "
          f"(MF trajectory {[round(h[1], 2) for h in hist]})")

    rows = [("fixed_" + str(mf), tec) for mf, tec in sweep.items()]
    rows.append(("self_tuned_from_8.0_total", tuned_tec))
    rows.append(("self_tuned_steady_state", steady))
    path = write_csv("selftune.csv", "variant,tec_s",
                     [(n, round(t, 3)) for n, t in rows])

    # the tuner must beat its bad start decisively, and its post-warm-up
    # steady state must approach the offline-sweep optimum
    assert tuned_tec < sweep[8.0] * 0.9, (tuned_tec, sweep)
    assert steady < sweep[best_mf] * 1.15, (steady, sweep)
    print(f"[selftune] OK -> {path} (sweep best MF={best_mf})")


if __name__ == "__main__":
    main()

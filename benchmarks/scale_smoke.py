"""Million-SE memory smoke (nightly): bounded memory at N = 10^6.

The scale tier's claim is not "it is fast" but "it fits and it is
exact": a 1M-SE hotspot workload (constant paper density, clustered —
the layout that used to blow up the dense candidate matrix) must run
through the real engine window with

  * peak RSS under a hard ceiling — the CSR candidate path plus the
    `mem_budget_mb` knob bound every transient, so memory is O(N) with
    a small constant, never O(N * 9 * capacity) materialized at once;
  * `grid_overflow == 0` — the budget did not buy memory by silently
    undercounting neighbors (the exact-or-loud contract).

Writes BENCH_scale.json with the two tracked metrics
(`rss_per_se_bytes`, `grid_overflow_steps`) plus timing context.
benchmarks/compare.py gates both against BENCH_baseline/: the zero
overflow baseline makes any tripped step a failure, and bytes/SE moving
past its tolerance means the memory model regressed.

    PYTHONPATH=src python benchmarks/scale_smoke.py [--n N] [--steps S]

Defaults are the CI nightly configuration (~3 engine steps at 1M SEs,
a few minutes on one CPU core). `--n` exists for quicker local runs;
BENCH_scale.json records the n it was produced with.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import resource
import sys
import time

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
import jax  # noqa: E402

from repro.core.abm import ABMConfig  # noqa: E402
from repro.core.engine import (EngineConfig, clear_compiled_caches,  # noqa: E402
                               init_engine, run_window)
from repro.core.heuristics import HeuristicConfig  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_scale.json")

N_SE = 1_000_000
STEPS = 3
MEM_BUDGET_MB = 512  # hard candidate/halo memory budget (EngineConfig)
#: peak-RSS gate. Measured ~1.25 GB on the reference box (jax runtime +
#: XLA compile workspace + one budgeted window at 1M); the ceiling is
#: ~2.5x that — a regression back toward a dense candidate matrix
#: (~ (N, 9*cap) i32 = GBs at 1M before the first query even runs)
#: clears it immediately, while allocator/runner noise does not.
RSS_CEILING_MB = 3072


def scale_cfg(n: int) -> EngineConfig:
    """Constant paper density (1e-4 SE/unit^2), hotspot mobility: the
    clustered layout is the adversarial one for per-cell capacity, and
    the mobility-aware auto capacity + budget clamp must hold it."""
    area = 100.0 * math.sqrt(n)
    abm = ABMConfig(n_se=n, n_lp=4, area=area, speed=11.0,
                    interaction_range=250.0, p_interact=0.2,
                    mobility="hotspot", n_groups=max(4, n // 4000),
                    group_radius=area * 0.08)
    return EngineConfig(abm=abm, heuristic=HeuristicConfig(mf=1.2, mt=5),
                        gaia_on=False, timesteps=STEPS,
                        mem_budget_mb=MEM_BUDGET_MB)


def peak_rss_bytes() -> int:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=N_SE)
    ap.add_argument("--steps", type=int, default=STEPS)
    args = ap.parse_args(argv)

    cfg = scale_cfg(args.n)
    spec = cfg.abm.grid_spec()
    print(f"[scale] N={args.n} area={cfg.abm.area:.0f} "
          f"grid={spec.ncell}x{spec.ncell} capacity={spec.capacity} "
          f"budget={MEM_BUDGET_MB}MB")

    clear_compiled_caches()
    t0 = time.time()
    st = init_engine(jax.random.key(0), cfg)
    jax.block_until_ready(st["pos"])
    t_init = time.time() - t0

    t0 = time.time()
    st, counters = run_window(st, cfg, args.steps)
    t_window = time.time() - t0

    rss = peak_rss_bytes()
    result = {
        "experiment": "scale_smoke",
        "n_se": args.n,
        "steps": args.steps,
        "mem_budget_mb": MEM_BUDGET_MB,
        "grid": {"ncell": spec.ncell, "capacity": spec.capacity},
        "device": str(jax.devices()[0]),
        "rss_peak_mb": round(rss / 2**20, 1),
        "rss_per_se_bytes": round(rss / args.n, 1),
        "grid_overflow_steps": counters["grid_overflow"],
        "init_s": round(t_init, 2),
        "window_s": round(t_window, 2),
        "step_s": round(t_window / args.steps, 2),
        "migrations": counters["migrations"],
        "mean_lcr": round(counters["mean_lcr"], 4),
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[scale] {args.steps} steps in {t_window:.1f}s "
          f"({result['step_s']}s/step), peak RSS {result['rss_peak_mb']}MB "
          f"({result['rss_per_se_bytes']} B/SE), "
          f"overflow={result['grid_overflow_steps']} -> {OUT}")

    assert result["grid_overflow_steps"] == 0, \
        "grid overflow tripped: the budgeted capacity undercounted (loud)"
    if args.n >= N_SE:  # the ceiling is sized for the nightly config
        assert rss <= RSS_CEILING_MB * 2**20, \
            f"peak RSS {result['rss_peak_mb']}MB over the " \
            f"{RSS_CEILING_MB}MB ceiling"
    print("[scale] OK")
    return result


if __name__ == "__main__":
    main()

"""Experiment 6 (beyond-paper): scenario x environment sweep.

The paper claims self-clustering pays off across "various configurations
of the simulation model and the execution environment"; the earlier
experiments only exercise uniform RWP on homogeneous devices — the
friendliest case. This sweep runs the non-uniform mobility workloads
(hotspot attractors, RPGM-style groups, emergent flocking) plus the two
*workload families* beyond pure mobility — `trace` (hub-clustered
commuter traces replayed through the data pipeline) and `epidemic`
(SI/SIS diffusion whose epi_boost send weight follows the infection
wave, not the density map) — with GAIA on and off, prices each run on
every ExecutionEnvironment preset (shared-memory / LAN / two-site WAN /
heterogeneous speeds) with the per-LP-pair cost layer, and records
everything in BENCH_scenarios.json at the repo root (uploaded as a CI
artifact and tracked by the bench-regression gate,
benchmarks/compare.py).

Each (scenario, gaia) cell runs `--replicas` seeds in ONE batched
engine pass (engine.run_batch) and serves all environments: counters
are environment-independent; only the *pricing* changes (that is the
point of the §3 cost layer). Every reported metric is a
mean/std/ci95/n stats dict (src/repro/core/stats.py); TEC gains are
paired per seed (ON and OFF run the same seeds).

The per-scenario cell is exposed as `run_cell` so the fleet runner
(benchmarks/fleet.py) can execute the same cells in isolated
subprocesses — one per {scenario x partitioner x device-count} matrix
point — and merge the rows back into this file's output schema. Running
this module directly is the single-process equivalent of the fleet's
D=1 gate lane.

Acceptance gate: on the LAN environment GAIA must reduce mean TEC vs
static partitioning on >= 2 of the 3 non-uniform mobility scenarios,
and no replica may overflow the proximity grid (the clustered
auto-capacity must hold). The workload families' gains are reported in
the same stats schema and regression-tracked by compare.py.

    PYTHONPATH=src python benchmarks/exp6_scenarios.py [quick|full]
                                                       [--replicas R]

quick: N=1000, 300 steps (CI-sized), 5 replicas default. full:
N=10000, 1200 steps, 10 replicas default.
"""
from __future__ import annotations

import json
import os
import sys
import time

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np  # noqa: E402

from benchmarks.common import default_replicas  # noqa: E402
from repro.core import costmodel as cm  # noqa: E402
from repro.core.abm import ABMConfig  # noqa: E402
from repro.core.engine import EngineConfig, run_batch  # noqa: E402
from repro.core.heuristics import HeuristicConfig  # noqa: E402
from repro.core.stats import replica_stats, summarize  # noqa: E402
from repro.data import pipeline as dpipe  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_scenarios.json")

SCALES = {
    # n_se, timesteps, area: paper density 1e-4 SE/unit^2, like common.py
    "quick": dict(n_se=1_000, timesteps=300, area=3162.0),
    "full": dict(n_se=10_000, timesteps=1200, area=10_000.0),
}
#: rwp = uniform reference row; hotspot/group/flock = non-uniform
#: mobility; trace/epidemic = the workload families beyond pure mobility
SCENARIOS = ("rwp", "hotspot", "group", "flock", "trace", "epidemic")
NEW_SCENARIOS = ("hotspot", "group", "flock")  # the >=2-wins gate set
WORKLOAD_SCENARIOS = ("trace", "epidemic")  # reported + tracked, not gated
ENVS = ("shm", "lan", "wan2", "hetero")
GATE_ENV = "lan"
N_LP = 4
INTERACTION_BYTES = 100
MIGRATION_BYTES = 256

#: SIS parameters of the epidemic cell: slow wave (beta), endemic
#: turnover (gamma) so the hot region keeps moving instead of
#: saturating, and a strong send-weight boost — the load follows the
#: wave, which is the property no pure-mobility scenario has
EPI = dict(workload="epidemic", epi_beta=0.05, epi_gamma=0.08,
           epi_seed_frac=0.05, epi_boost=5.0)


def ensure_trace(scale: str) -> str:
    """Register (idempotently) the deterministic commuter trace backing
    the `trace` scenario at this scale and return its registry name.

    Length is timesteps + 1 frames: step t replays frame t + 1, so the
    'exact' policy covers the full horizon with no loop seam (a seam
    jump would inflate the halo dilation radius for nothing). Any
    process that runs a trace cell — this module or a fleet child —
    calls this before building the engine."""
    s = SCALES[scale]
    name = f"exp6-{scale}"
    if name not in dpipe.trace_names():
        spec = dpipe.TraceSpec(
            n_se=s["n_se"], area=s["area"], timesteps=s["timesteps"] + 1,
            speed=11.0 * s["area"] / 10_000.0, n_hubs=8, seed=0)
        dpipe.register_trace(name, dpipe.synthetic_trace(spec))
    return name


def scenario_cfg(scale: str, scenario: str, gaia: bool,
                 partitioner: str = "random",
                 repartition_every: int = 0,
                 n_devices: int = 1) -> EngineConfig:
    s = SCALES[scale]
    f = s["area"] / 10_000.0  # speed scaling, as in benchmarks/common.py
    abm_kw = dict(n_se=s["n_se"], n_lp=N_LP, area=s["area"],
                  speed=11.0 * f, interaction_range=250.0,
                  p_interact=0.2, mobility=scenario, n_groups=8,
                  group_radius=250.0, partitioner=partitioner)
    if scenario == "trace":
        abm_kw.update(mobility="trace", trace_name=ensure_trace(scale),
                      trace_policy="exact")
    elif scenario == "epidemic":
        abm_kw.update(mobility="rwp", **EPI)
    eng_kw = dict(heuristic=HeuristicConfig(mf=1.2, mt=10),
                  gaia_on=gaia, timesteps=s["timesteps"],
                  repartition_every=repartition_every)
    if n_devices > 1:
        eng_kw.update(sharding="lp_device", n_devices=n_devices)
    return EngineConfig(abm=ABMConfig(**abm_kw), **eng_kw)


def density_stats(pos, cfg: EngineConfig) -> dict:
    """How non-uniform did the workload actually get? Peak cell
    occupancy over the uniform mean (1.0 = perfectly uniform), on one
    replica's final positions."""
    spec = cfg.abm.grid_spec()
    if spec is None:
        return {}
    pos = np.asarray(pos)
    cell = (np.floor(pos[:, 0] / spec.cell).astype(int)
            % spec.ncell) * spec.ncell + \
        (np.floor(pos[:, 1] / spec.cell).astype(int) % spec.ncell)
    occ = np.bincount(cell, minlength=spec.ncell ** 2)
    mean = cfg.abm.n_se / spec.ncell ** 2
    return {"peak_cell_over_uniform": round(float(occ.max() / mean), 2),
            "grid_capacity": spec.capacity}


def run_cell(scale: str, scenario: str, seeds,
             partitioner: str = "random",
             repartition_every: int = 0) -> dict:
    """One benchmark cell: the paired GAIA on/off batched runs for one
    scenario, priced on every environment. Returns the BENCH row dict.

    This is the unit the fleet runner forks into a subprocess; keeping
    it a pure function of (scale, scenario, seeds, partitioner) is what
    makes the fleet's merged output identical to a sequential run."""
    s = SCALES[scale]
    n_rep = len(seeds)
    row = {"scenario": scenario, "n": n_rep}
    if partitioner != "random" or repartition_every:
        row["partitioner"] = partitioner
        row["repartition_every"] = repartition_every
    reps_by_gaia = {}
    for gaia in (True, False):
        cfg = scenario_cfg(scale, scenario, gaia, partitioner,
                           repartition_every)
        t0 = time.time()
        states, _, reps = run_batch(cfg, seeds)
        reps_by_gaia[gaia] = reps
        tag = "on" if gaia else "off"
        row[f"wall_s_{tag}"] = round(time.time() - t0, 1)
        st = summarize(reps, ndigits=4)
        row[f"lcr_{tag}"] = st["mean_lcr"]
        row[f"grid_overflow_{tag}"] = sum(r["grid_overflow"] for r in reps)
        if gaia:
            row["migrations"] = st["migrations"]
            if scenario == "epidemic":
                row["infected"] = st.get("mean_infected", {})
            row.update(density_stats(states["pos"][0], cfg))
    row["tec"] = {}
    for kind in ENVS:
        env = cm.make_env(kind, N_LP)
        per_rep = {}
        for gaia in (True, False):
            per_rep["on" if gaia else "off"] = [
                cm.wct_env(r, cm.DISTRIBUTED, env, s["timesteps"],
                           interaction_bytes=INTERACTION_BYTES,
                           migration_bytes=MIGRATION_BYTES)["TEC"]
                for r in reps_by_gaia[gaia]]
        gain = replica_stats([(off - on) / off for on, off in
                              zip(per_rep["on"], per_rep["off"])])
        row["tec"][kind] = {
            "on": {k: round(v, 3) for k, v
                   in replica_stats(per_rep["on"]).items()},
            "off": {k: round(v, 3) for k, v
                    in replica_stats(per_rep["off"]).items()},
            "gain": {k: round(v, 4) for k, v in gain.items()},
        }
    return row


def print_row(row: dict) -> None:
    g = row["tec"][GATE_ENV]["gain"]
    print(f"[exp6] {row['scenario']:8s} "
          f"lcr {row['lcr_off']['mean']:.3f} -> "
          f"{row['lcr_on']['mean']:.3f}  peak-density "
          f"{row.get('peak_cell_over_uniform', '-')}x  "
          f"TEC({GATE_ENV}) gain {g['mean']:+.1%}±{g['ci95']:.1%} "
          f"(n={row['n']})")


def assemble(rows: list, scale: str, n_rep: int,
             fleet: dict | None = None) -> dict:
    """Fold per-scenario rows into the BENCH_scenarios.json document.
    `rows` must hold the D=1/random-partitioner gate lane (one row per
    SCENARIOS entry); the fleet runner passes its extra matrix points
    via `fleet`, which lands verbatim under the "fleet" key."""
    wins = [r["scenario"] for r in rows
            if r["scenario"] in NEW_SCENARIOS + WORKLOAD_SCENARIOS
            and r["tec"][GATE_ENV]["gain"]["mean"] > 0]
    result = {
        "experiment": "exp6_scenarios",
        "config": dict(SCALES[scale], n_lp=N_LP, scale=scale,
                       replicas=n_rep,
                       interaction_bytes=INTERACTION_BYTES,
                       migration_bytes=MIGRATION_BYTES,
                       gate_env=GATE_ENV, epidemic=EPI),
        "results": rows,
        "gate": {
            "gaia_wins_on": wins,
            "n_new_scenarios_gaia_wins": len(
                [w for w in wins if w in NEW_SCENARIOS]),
            # machine-independent paired gains (mean/std/ci95/n stats
            # dicts) tracked by benchmarks/compare.py, which fails only
            # when the baseline and candidate intervals separate
            "tec_gain_by_scenario": {
                r["scenario"]: r["tec"][GATE_ENV]["gain"] for r in rows},
        },
    }
    if fleet is not None:
        result["fleet"] = fleet
    return result


def write_and_gate(result: dict) -> dict:
    """Persist the document, then enforce the acceptance gate (after
    writing, so a gate failure still leaves the evidence on disk)."""
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    for r in result["results"]:
        assert r["grid_overflow_on"] == 0.0 and r["grid_overflow_off"] == 0.0, \
            f"grid overflow on {r['scenario']}: clustered capacity too tight"
    mob_wins = [w for w in result["gate"]["gaia_wins_on"]
                if w in NEW_SCENARIOS]
    assert len(mob_wins) >= 2, \
        f"GAIA won TEC({GATE_ENV}) only on {mob_wins}; need >= 2 of " \
        f"{NEW_SCENARIOS}"
    print(f"[exp6] OK (GAIA wins on {result['gate']['gaia_wins_on']}, "
          f"n={result['config']['replicas']}) -> {OUT}")
    return result


def main(scale: str = "quick", replicas=None):
    n_rep = default_replicas(scale, replicas)
    seeds = list(range(n_rep))
    rows = []
    for scen in SCENARIOS:
        row = run_cell(scale, scen, seeds)
        rows.append(row)
        print_row(row)
    return write_and_gate(assemble(rows, scale, n_rep))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "full"])
    ap.add_argument("--replicas", type=int, default=None)
    a = ap.parse_args()
    main(a.scale, a.replicas)

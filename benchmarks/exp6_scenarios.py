"""Experiment 6 (beyond-paper): scenario x environment sweep.

The paper claims self-clustering pays off across "various configurations
of the simulation model and the execution environment"; the earlier
experiments only exercise uniform RWP on homogeneous devices — the
friendliest case. This sweep runs the non-uniform mobility workloads
(hotspot attractors, RPGM-style groups, emergent flocking) with GAIA on
and off, prices each run on every ExecutionEnvironment preset
(shared-memory / LAN / two-site WAN / heterogeneous speeds) with the
per-LP-pair cost layer, and records everything in BENCH_scenarios.json
at the repo root (uploaded as a CI artifact and tracked by the
bench-regression gate, benchmarks/compare.py).

One engine run per (scenario, gaia) serves all environments: counters
are environment-independent; only the *pricing* changes (that is the
point of the §3 cost layer).

Acceptance gate: on the LAN environment GAIA must reduce TEC vs static
partitioning on >= 2 of the 3 non-uniform scenarios, and no run may
overflow the proximity grid (the clustered auto-capacity must hold).

    PYTHONPATH=src python benchmarks/exp6_scenarios.py [quick|full]

quick: N=1000, 300 steps (CI-sized). full: N=10000, 1200 steps.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

from repro.core import costmodel as cm
from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig

OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_scenarios.json")

SCALES = {
    # n_se, timesteps, area: paper density 1e-4 SE/unit^2, like common.py
    "quick": dict(n_se=1_000, timesteps=300, area=3162.0),
    "full": dict(n_se=10_000, timesteps=1200, area=10_000.0),
}
SCENARIOS = ("rwp", "hotspot", "group", "flock")  # rwp = reference row
NEW_SCENARIOS = ("hotspot", "group", "flock")
ENVS = ("shm", "lan", "wan2", "hetero")
GATE_ENV = "lan"
N_LP = 4
INTERACTION_BYTES = 100
MIGRATION_BYTES = 256


def scenario_cfg(scale: str, mobility: str, gaia: bool) -> EngineConfig:
    s = SCALES[scale]
    f = s["area"] / 10_000.0  # speed scaling, as in benchmarks/common.py
    return EngineConfig(
        abm=ABMConfig(n_se=s["n_se"], n_lp=N_LP, area=s["area"],
                      speed=11.0 * f, interaction_range=250.0,
                      p_interact=0.2, mobility=mobility, n_groups=8,
                      group_radius=250.0),
        heuristic=HeuristicConfig(mf=1.2, mt=10),
        gaia_on=gaia, timesteps=s["timesteps"])


def density_stats(state, cfg: EngineConfig) -> dict:
    """How non-uniform did the workload actually get? Peak cell
    occupancy over the uniform mean (1.0 = perfectly uniform)."""
    spec = cfg.abm.grid_spec()
    if spec is None:
        return {}
    pos = np.asarray(state["pos"])
    cell = (np.floor(pos[:, 0] / spec.cell).astype(int)
            % spec.ncell) * spec.ncell + \
        (np.floor(pos[:, 1] / spec.cell).astype(int) % spec.ncell)
    occ = np.bincount(cell, minlength=spec.ncell ** 2)
    mean = cfg.abm.n_se / spec.ncell ** 2
    return {"peak_cell_over_uniform": round(float(occ.max() / mean), 2),
            "grid_capacity": spec.capacity}


def main(scale: str = "quick"):
    s = SCALES[scale]
    envs = {kind: cm.make_env(kind, N_LP) for kind in ENVS}
    rows = []
    for scen in SCENARIOS:
        row = {"scenario": scen}
        counters = {}
        for gaia in (True, False):
            cfg = scenario_cfg(scale, scen, gaia)
            t0 = time.time()
            st, _, c = run(jax.random.key(0), cfg)
            c["wall_s"] = round(time.time() - t0, 1)
            counters[gaia] = c
            tag = "on" if gaia else "off"
            row[f"lcr_{tag}"] = round(c["mean_lcr"], 4)
            row[f"grid_overflow_{tag}"] = c["grid_overflow"]
            if gaia:
                row["migrations"] = c["migrations"]
                row.update(density_stats(st, cfg))
        row["tec"] = {}
        for kind, env in envs.items():
            tec = {}
            for gaia in (True, False):
                tec["on" if gaia else "off"] = cm.wct_env(
                    counters[gaia], cm.DISTRIBUTED, env, s["timesteps"],
                    interaction_bytes=INTERACTION_BYTES,
                    migration_bytes=MIGRATION_BYTES)["TEC"]
            row["tec"][kind] = {
                "on": round(tec["on"], 3), "off": round(tec["off"], 3),
                "gain": round((tec["off"] - tec["on"]) / tec["off"], 4),
            }
        rows.append(row)
        g = row["tec"][GATE_ENV]["gain"]
        print(f"[exp6] {scen:8s} lcr {row['lcr_off']:.3f} -> "
              f"{row['lcr_on']:.3f}  peak-density "
              f"{row.get('peak_cell_over_uniform', '-')}x  "
              f"TEC({GATE_ENV}) gain {g:+.1%}")

    wins = [r["scenario"] for r in rows
            if r["scenario"] in NEW_SCENARIOS
            and r["tec"][GATE_ENV]["gain"] > 0]
    result = {
        "experiment": "exp6_scenarios",
        "config": dict(SCALES[scale], n_lp=N_LP, scale=scale,
                       interaction_bytes=INTERACTION_BYTES,
                       migration_bytes=MIGRATION_BYTES,
                       gate_env=GATE_ENV),
        "results": rows,
        "gate": {
            "gaia_wins_on": wins,
            "n_new_scenarios_gaia_wins": len(wins),
            # machine-independent gains tracked by benchmarks/compare.py
            "tec_gain_by_scenario": {
                r["scenario"]: r["tec"][GATE_ENV]["gain"] for r in rows},
        },
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)

    for r in rows:
        assert r["grid_overflow_on"] == 0.0 and r["grid_overflow_off"] == 0.0, \
            f"grid overflow on {r['scenario']}: clustered capacity too tight"
    assert len(wins) >= 2, \
        f"GAIA won TEC({GATE_ENV}) only on {wins}; need >= 2 of " \
        f"{NEW_SCENARIOS}"
    print(f"[exp6] OK (GAIA wins on {wins}) -> {OUT}")
    return result


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "quick")

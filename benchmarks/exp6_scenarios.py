"""Experiment 6 (beyond-paper): scenario x environment sweep.

The paper claims self-clustering pays off across "various configurations
of the simulation model and the execution environment"; the earlier
experiments only exercise uniform RWP on homogeneous devices — the
friendliest case. This sweep runs the non-uniform mobility workloads
(hotspot attractors, RPGM-style groups, emergent flocking) with GAIA on
and off, prices each run on every ExecutionEnvironment preset
(shared-memory / LAN / two-site WAN / heterogeneous speeds) with the
per-LP-pair cost layer, and records everything in BENCH_scenarios.json
at the repo root (uploaded as a CI artifact and tracked by the
bench-regression gate, benchmarks/compare.py).

Each (scenario, gaia) cell runs `--replicas` seeds in ONE batched
engine pass (engine.run_batch) and serves all environments: counters
are environment-independent; only the *pricing* changes (that is the
point of the §3 cost layer). Every reported metric is a
mean/std/ci95/n stats dict (src/repro/core/stats.py); TEC gains are
paired per seed (ON and OFF run the same seeds).

Acceptance gate: on the LAN environment GAIA must reduce mean TEC vs
static partitioning on >= 2 of the 3 non-uniform scenarios, and no
replica may overflow the proximity grid (the clustered auto-capacity
must hold).

    PYTHONPATH=src python benchmarks/exp6_scenarios.py [quick|full]
                                                       [--replicas R]

quick: N=1000, 300 steps (CI-sized), 5 replicas default. full:
N=10000, 1200 steps, 10 replicas default.
"""
from __future__ import annotations

import json
import os
import sys
import time

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np  # noqa: E402

from benchmarks.common import default_replicas  # noqa: E402
from repro.core import costmodel as cm  # noqa: E402
from repro.core.abm import ABMConfig  # noqa: E402
from repro.core.engine import EngineConfig, run_batch  # noqa: E402
from repro.core.heuristics import HeuristicConfig  # noqa: E402
from repro.core.stats import replica_stats, summarize  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_scenarios.json")

SCALES = {
    # n_se, timesteps, area: paper density 1e-4 SE/unit^2, like common.py
    "quick": dict(n_se=1_000, timesteps=300, area=3162.0),
    "full": dict(n_se=10_000, timesteps=1200, area=10_000.0),
}
SCENARIOS = ("rwp", "hotspot", "group", "flock")  # rwp = reference row
NEW_SCENARIOS = ("hotspot", "group", "flock")
ENVS = ("shm", "lan", "wan2", "hetero")
GATE_ENV = "lan"
N_LP = 4
INTERACTION_BYTES = 100
MIGRATION_BYTES = 256


def scenario_cfg(scale: str, mobility: str, gaia: bool) -> EngineConfig:
    s = SCALES[scale]
    f = s["area"] / 10_000.0  # speed scaling, as in benchmarks/common.py
    return EngineConfig(
        abm=ABMConfig(n_se=s["n_se"], n_lp=N_LP, area=s["area"],
                      speed=11.0 * f, interaction_range=250.0,
                      p_interact=0.2, mobility=mobility, n_groups=8,
                      group_radius=250.0),
        heuristic=HeuristicConfig(mf=1.2, mt=10),
        gaia_on=gaia, timesteps=s["timesteps"])


def density_stats(pos, cfg: EngineConfig) -> dict:
    """How non-uniform did the workload actually get? Peak cell
    occupancy over the uniform mean (1.0 = perfectly uniform), on one
    replica's final positions."""
    spec = cfg.abm.grid_spec()
    if spec is None:
        return {}
    pos = np.asarray(pos)
    cell = (np.floor(pos[:, 0] / spec.cell).astype(int)
            % spec.ncell) * spec.ncell + \
        (np.floor(pos[:, 1] / spec.cell).astype(int) % spec.ncell)
    occ = np.bincount(cell, minlength=spec.ncell ** 2)
    mean = cfg.abm.n_se / spec.ncell ** 2
    return {"peak_cell_over_uniform": round(float(occ.max() / mean), 2),
            "grid_capacity": spec.capacity}


def main(scale: str = "quick", replicas=None):
    s = SCALES[scale]
    n_rep = default_replicas(scale, replicas)
    seeds = list(range(n_rep))
    envs = {kind: cm.make_env(kind, N_LP) for kind in ENVS}
    rows = []
    for scen in SCENARIOS:
        row = {"scenario": scen, "n": n_rep}
        reps_by_gaia = {}
        for gaia in (True, False):
            cfg = scenario_cfg(scale, scen, gaia)
            t0 = time.time()
            states, _, reps = run_batch(cfg, seeds)
            reps_by_gaia[gaia] = reps
            tag = "on" if gaia else "off"
            row[f"wall_s_{tag}"] = round(time.time() - t0, 1)
            st = summarize(reps, ndigits=4)
            row[f"lcr_{tag}"] = st["mean_lcr"]
            row[f"grid_overflow_{tag}"] = sum(r["grid_overflow"]
                                              for r in reps)
            if gaia:
                row["migrations"] = st["migrations"]
                row.update(density_stats(states["pos"][0], cfg))
        row["tec"] = {}
        for kind, env in envs.items():
            per_rep = {}
            for gaia in (True, False):
                per_rep["on" if gaia else "off"] = [
                    cm.wct_env(r, cm.DISTRIBUTED, env, s["timesteps"],
                               interaction_bytes=INTERACTION_BYTES,
                               migration_bytes=MIGRATION_BYTES)["TEC"]
                    for r in reps_by_gaia[gaia]]
            gain = replica_stats([(off - on) / off for on, off in
                                  zip(per_rep["on"], per_rep["off"])])
            row["tec"][kind] = {
                "on": {k: round(v, 3) for k, v
                       in replica_stats(per_rep["on"]).items()},
                "off": {k: round(v, 3) for k, v
                        in replica_stats(per_rep["off"]).items()},
                "gain": {k: round(v, 4) for k, v in gain.items()},
            }
        rows.append(row)
        g = row["tec"][GATE_ENV]["gain"]
        print(f"[exp6] {scen:8s} lcr {row['lcr_off']['mean']:.3f} -> "
              f"{row['lcr_on']['mean']:.3f}  peak-density "
              f"{row.get('peak_cell_over_uniform', '-')}x  "
              f"TEC({GATE_ENV}) gain {g['mean']:+.1%}±{g['ci95']:.1%} "
              f"(n={n_rep})")

    wins = [r["scenario"] for r in rows
            if r["scenario"] in NEW_SCENARIOS
            and r["tec"][GATE_ENV]["gain"]["mean"] > 0]
    result = {
        "experiment": "exp6_scenarios",
        "config": dict(SCALES[scale], n_lp=N_LP, scale=scale,
                       replicas=n_rep,
                       interaction_bytes=INTERACTION_BYTES,
                       migration_bytes=MIGRATION_BYTES,
                       gate_env=GATE_ENV),
        "results": rows,
        "gate": {
            "gaia_wins_on": wins,
            "n_new_scenarios_gaia_wins": len(wins),
            # machine-independent paired gains (mean/std/ci95/n stats
            # dicts) tracked by benchmarks/compare.py, which fails only
            # when the baseline and candidate intervals separate
            "tec_gain_by_scenario": {
                r["scenario"]: r["tec"][GATE_ENV]["gain"] for r in rows},
        },
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)

    for r in rows:
        assert r["grid_overflow_on"] == 0.0 and r["grid_overflow_off"] == 0.0, \
            f"grid overflow on {r['scenario']}: clustered capacity too tight"
    assert len(wins) >= 2, \
        f"GAIA won TEC({GATE_ENV}) only on {wins}; need >= 2 of " \
        f"{NEW_SCENARIOS}"
    print(f"[exp6] OK (GAIA wins on {wins}, n={n_rep}) -> {OUT}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "full"])
    ap.add_argument("--replicas", type=int, default=None)
    a = ap.parse_args()
    main(a.scale, a.replicas)

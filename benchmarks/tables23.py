"""Tables 2 & 3 + Figures 8 & 9 (paper §5.4): ΔWCT of GAIA ON vs OFF on
the parallel and distributed setups, across interaction size, migration
(SE state) size and interaction probability π, sweeping MF.

The 2016 testbeds are modeled by the paper's own cost analysis (Eq. 5/6,
core/costmodel.py) calibrated per setup; the engine counters (local/
remote deliveries, migrations, heuristic evaluations) come from real
simulation runs. One engine run per (π, MF) serves BOTH setups and all
size combinations — hardware and payload sizes enter only through the
cost model, exactly as in Eq. 5/6.
"""
from __future__ import annotations

from benchmarks.common import engine_cfg, run_cfg, write_csv
from repro.core.costmodel import SETUPS, wct

MFS = [1.1, 1.2, 1.5, 2.0, 3.0, 6.0, 10.0, 19.0]
INTER_SIZES = [1, 100, 1024]
MIG_SIZES = [32, 20480, 81920]
PIS = [0.2, 0.5]


def collect_counters(scale: str, seed=0):
    """Engine counters for OFF and each (π, MF)."""
    out = {}
    for pi in PIS:
        out[("off", pi)] = run_cfg(engine_cfg(scale, pi=pi, gaia=False),
                                   seed)
        for mf in MFS:
            out[(mf, pi)] = run_cfg(engine_cfg(scale, pi=pi, mf=mf), seed)
            c = out[(mf, pi)]
            print(f"[tables23] pi={pi} MF={mf:<5} LCR={c['mean_lcr']:.3f} "
                  f"migs={int(c['migrations'])}")
    return out


def main(scale: str = "quick", seed=0):
    counters = collect_counters(scale, seed)
    ts = engine_cfg(scale).timesteps
    rows = []
    best = {}
    for setup_name, params in SETUPS.items():
        for pi in PIS:
            for isz in INTER_SIZES:
                off_tec = wct(counters[("off", pi)], params, 4, ts,
                              interaction_bytes=isz)["TEC"]
                for msz in MIG_SIZES:
                    # best MF for this configuration (paper reports the
                    # per-config optimum)
                    tecs = {mf: wct(counters[(mf, pi)], params, 4, ts,
                                    interaction_bytes=isz,
                                    migration_bytes=msz)["TEC"]
                            for mf in MFS}
                    mf_star = min(tecs, key=tecs.get)
                    gain = 100.0 * (off_tec - tecs[mf_star]) / off_tec
                    rows.append((setup_name, pi, isz, msz,
                                 round(off_tec, 2), round(tecs[mf_star], 2),
                                 mf_star, round(gain, 2)))
                    best[(setup_name, pi, isz, msz)] = gain
        # Fig 8/9: full MF sweep for best and worst configuration
        sweeps = []
        cfgs = {"best": (0.5, 1024, 32), "worst": (0.2, 1, 81920)}
        for tag, (pi, isz, msz) in cfgs.items():
            off_tec = wct(counters[("off", pi)], params, 4, ts,
                          interaction_bytes=isz)["TEC"]
            for mf in MFS:
                tec = wct(counters[(mf, pi)], params, 4, ts,
                          interaction_bytes=isz, migration_bytes=msz)["TEC"]
                sweeps.append((tag, mf, round(100 * (off_tec - tec)
                                              / off_tec, 2)))
        write_csv(f"fig89_{setup_name}.csv", "config,mf,gain_pct", sweeps)

    path = write_csv("tables23.csv",
                     "setup,pi,inter_size,mig_size,tec_off,tec_on,"
                     "mf_star,gain_pct", rows)
    for r in rows:
        print(f"[{r[0]:<11}] pi={r[1]} inter={r[2]:<5} mig={r[3]:<6} "
              f"gain={r[7]:+6.2f}% (MF*={r[6]})")

    # paper-claim checks (sign/ordering trends of Tables 2 & 3)
    assert best[("parallel", 0.5, 1024, 32)] > 5.0
    # the paper's worst parallel cell (inter=1, mig=81920) is also ours;
    # at quick scale it straddles zero (paper: +1.67%) — assert it is the
    # worst and near zero rather than pinning the sign
    worst_par = best[("parallel", 0.2, 1, 81920)]
    assert worst_par == min(g for (s, *_), g in best.items()
                            if s == "parallel")
    assert worst_par > -4.0, worst_par
    assert best[("distributed", 0.5, 1024, 32)] > 20.0
    assert best[("distributed", 0.2, 1024, 32)] > \
        best[("distributed", 0.2, 1, 32)], "big interactions gain more"
    # Table 3's signature: huge-state migrations on the LAN flip the sign
    assert best[("distributed", 0.2, 1, 81920)] < 0.5
    assert best[("distributed", 0.5, 1024, 32)] > 50.0
    print(f"[tables23] OK -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "quick")

"""Tables 2 & 3 + Figures 8 & 9 (paper §5.4): ΔWCT of GAIA ON vs OFF on
the parallel and distributed setups, across interaction size, migration
(SE state) size and interaction probability π, sweeping MF.

The 2016 testbeds are modeled by the paper's own cost analysis (Eq. 5/6,
core/costmodel.py) calibrated per setup; the engine counters (local/
remote deliveries, migrations, heuristic evaluations) come from real
simulation runs. One *batched* engine run per (π, MF) — `--replicas`
seeds in a single vmapped pass — serves BOTH setups and all 9
(interaction, migration)-size combinations: hardware and payload sizes
enter only through the cost model, exactly as in Eq. 5/6, so pricing
re-reads the cached counters instead of re-running the engine (the run
cache is hoisted into benchmarks/common.run_cfg and shared with exp1's
overlapping speed x MF grid). Gains are paired per seed (ON and OFF
price the same seeds) and reported as mean/ci95/n.
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
from benchmarks.common import (default_replicas, engine_cfg,  # noqa: E402
                               paired_stats, run_cfg, write_csv)
from repro.core.costmodel import SETUPS, wct  # noqa: E402

MFS = [1.1, 1.2, 1.5, 2.0, 3.0, 6.0, 10.0, 19.0]
INTER_SIZES = [1, 100, 1024]
MIG_SIZES = [32, 20480, 81920]
PIS = [0.2, 0.5]


def collect_counters(scale: str, n_rep: int):
    """Batched engine counters for OFF and each (π, MF) — every cell is
    one run_cfg call against the hoisted cross-benchmark cache, so a
    config that exp1 already ran (or a re-invocation at the same scale)
    executes zero new engine steps."""
    out = {}
    for pi in PIS:
        out[("off", pi)] = run_cfg(engine_cfg(scale, pi=pi, gaia=False),
                                   replicas=n_rep)
        for mf in MFS:
            out[(mf, pi)] = run_cfg(engine_cfg(scale, pi=pi, mf=mf),
                                    replicas=n_rep)
            c = out[(mf, pi)]
            print(f"[tables23] pi={pi} MF={mf:<5} n={n_rep} "
                  f"LCR={c['mean_lcr']:.3f}"
                  f"±{c['stats']['mean_lcr']['ci95']:.3f} "
                  f"migs={int(c['migrations'])}")
    return out


def _gain_stats(on, off, params, n_lp, ts, isz, msz):
    """Paired per-seed ΔTEC% of GAIA ON vs OFF at one size combination."""
    def gain(a, b):
        off_tec = wct(b, params, n_lp, ts, interaction_bytes=isz)["TEC"]
        on_tec = wct(a, params, n_lp, ts, interaction_bytes=isz,
                     migration_bytes=msz)["TEC"]
        return 100.0 * (off_tec - on_tec) / off_tec
    return paired_stats(on["reps"], off["reps"], gain)


def main(scale: str = "quick", replicas=None):
    n_rep = default_replicas(scale, replicas)
    counters = collect_counters(scale, n_rep)
    ts = engine_cfg(scale).timesteps
    n_lp = 4
    rows = []
    best = {}
    for setup_name, params in SETUPS.items():
        for pi in PIS:
            off = counters[("off", pi)]
            for isz in INTER_SIZES:
                for msz in MIG_SIZES:
                    # best MF for this configuration (paper reports the
                    # per-config optimum), chosen on the replica-mean TEC
                    tecs = {}
                    for mf in MFS:
                        per_rep = [wct(r, params, n_lp, ts,
                                       interaction_bytes=isz,
                                       migration_bytes=msz)["TEC"]
                                   for r in counters[(mf, pi)]["reps"]]
                        tecs[mf] = sum(per_rep) / len(per_rep)
                    mf_star = min(tecs, key=tecs.get)
                    g = _gain_stats(counters[(mf_star, pi)], off, params,
                                    n_lp, ts, isz, msz)
                    rows.append((setup_name, pi, isz, msz,
                                 round(tecs[mf_star], 2), mf_star,
                                 round(g["mean"], 2), round(g["ci95"], 2),
                                 n_rep))
                    best[(setup_name, pi, isz, msz)] = g["mean"]
        # Fig 8/9: full MF sweep for best and worst configuration
        sweeps = []
        cfgs = {"best": (0.5, 1024, 32), "worst": (0.2, 1, 81920)}
        for tag, (pi, isz, msz) in cfgs.items():
            for mf in MFS:
                g = _gain_stats(counters[(mf, pi)], counters[("off", pi)],
                                params, n_lp, ts, isz, msz)
                sweeps.append((tag, mf, round(g["mean"], 2),
                               round(g["ci95"], 2), n_rep))
        write_csv(f"fig89_{setup_name}.csv",
                  "config,mf,gain_pct,gain_ci95,n", sweeps)

    path = write_csv("tables23.csv",
                     "setup,pi,inter_size,mig_size,tec_on,mf_star,"
                     "gain_pct,gain_ci95,n", rows)
    for r in rows:
        print(f"[{r[0]:<11}] pi={r[1]} inter={r[2]:<5} mig={r[3]:<6} "
              f"gain={r[6]:+6.2f}%±{r[7]:.2f} (MF*={r[5]}, n={r[8]})")

    # paper-claim checks (sign/ordering trends of Tables 2 & 3)
    assert best[("parallel", 0.5, 1024, 32)] > 5.0
    # the paper's worst parallel cell (inter=1, mig=81920) is also ours;
    # at quick scale it straddles zero (paper: +1.67%) — assert it is the
    # worst and near zero rather than pinning the sign
    worst_par = best[("parallel", 0.2, 1, 81920)]
    assert worst_par == min(g for (s, *_), g in best.items()
                            if s == "parallel")
    assert worst_par > -4.0, worst_par
    assert best[("distributed", 0.5, 1024, 32)] > 20.0
    assert best[("distributed", 0.2, 1024, 32)] > \
        best[("distributed", 0.2, 1, 32)], "big interactions gain more"
    # Table 3's signature: huge-state migrations on the LAN flip the sign
    assert best[("distributed", 0.2, 1, 81920)] < 0.5
    assert best[("distributed", 0.5, 1024, 32)] > 50.0
    print(f"[tables23] OK (n={n_rep}) -> {path}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "mid", "paper"])
    ap.add_argument("--replicas", type=int, default=None)
    a = ap.parse_args()
    main(a.scale, a.replicas)

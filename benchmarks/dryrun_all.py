"""Drive the full dry-run campaign: every (arch x shape x mesh) cell in a
fresh subprocess (each needs its own 512-device XLA init; a fresh process
also bounds compiler memory).

Usage: PYTHONPATH=src python benchmarks/dryrun_all.py [--mesh single multi]
Writes results/dryrun/<arch>_<shape>_<mesh>.json and a campaign log.

`--bench exp4 exp5 exp6 exp7 exp8` additionally runs the named quick-mode
engine benchmarks (the BENCH_*.json producers, see benchmarks/run.py)
each in its own subprocess before the dry-run cells — the same
isolation rationale: every cell/bench gets a fresh XLA, and one OOM or
compiler blow-up cannot take down the whole campaign.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs import ARCHS, SHAPES  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--only-arch", default="")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--components", action="store_true",
                    help="run the component roofline pass per cell "
                         "(writes *_comp.json; §Roofline table input)")
    ap.add_argument("--bench", nargs="*", default=[],
                    help="quick-mode engine benchmarks to run first, each "
                         "in a fresh subprocess (e.g. exp4 exp5 exp6 exp7 exp8)")
    args = ap.parse_args()

    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    for bench in args.bench:
        t0 = time.time()
        try:
            r = subprocess.run(
                [sys.executable, "-m", "benchmarks.run", "--scale", "quick",
                 "--only", bench],
                env=env, cwd=ROOT, capture_output=True, text=True,
                timeout=args.timeout)
            ok = r.returncode == 0
            tail = (r.stdout + r.stderr).strip().splitlines()[-1:] or [""]
        except subprocess.TimeoutExpired:
            ok, tail = False, ["TIMEOUT"]
        print(f"[bench {bench}] {'ok' if ok else 'FAIL'} "
              f"({time.time()-t0:.0f}s) {tail[0][-200:]}", flush=True)
        if not ok:
            sys.exit(1)

    cells = []
    for arch, cfg in ARCHS.items():
        if args.only_arch and arch != args.only_arch:
            continue
        for shape in SHAPES:  # includes inapplicable cells -> recorded skips
            for mesh in args.mesh:
                cells.append((arch, shape, mesh))

    logp = os.path.join(ROOT, args.out, "campaign.log")
    os.makedirs(os.path.dirname(logp), exist_ok=True)
    done = 0
    for arch, shape, mesh in cells:
        suffix = "_comp.json" if args.components else ".json"
        outf = os.path.join(ROOT, args.out, f"{arch}_{shape}_{mesh}{suffix}")
        if args.skip_existing and os.path.exists(outf):
            done += 1
            continue
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--out",
               os.path.join(ROOT, args.out)]
        if args.components:
            cmd.append("--components")
        env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
        try:
            r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                               timeout=args.timeout)
            status = "ok" if r.returncode == 0 else "FAIL"
            tail = (r.stdout + r.stderr).strip().splitlines()[-1:] or [""]
        except subprocess.TimeoutExpired:
            status, tail = "TIMEOUT", [""]
        if status != "ok" and not os.path.exists(outf):
            with open(outf, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": status.lower(), "detail": tail[0][-2000:]},
                          f)
        done += 1
        msg = (f"[{done}/{len(cells)}] {arch} x {shape} x {mesh}: {status} "
               f"({time.time()-t0:.0f}s) {tail[0][-200:]}")
        print(msg, flush=True)
        with open(logp, "a") as f:
            f.write(msg + "\n")

        # Memory probe: XLA:CPU emulates bf16 with f32 buffers, inflating
        # the measured peak. For cells whose raw peak exceeds the 16 GiB
        # HBM budget, re-lower everything in f32 (no emulation converts,
        # same shapes): peak_f32 / 2 bounds the true bf16 TPU peak.
        try:
            with open(outf) as f:
                res = json.load(f)
        except Exception:
            res = {}
        if res.get("status") == "ok" and \
                res.get("peak_bytes_per_dev", 0) > 16 * 2 ** 30:
            probe = os.path.join(ROOT, args.out,
                                 f"{arch}_{shape}_{mesh}_f32probe.json")
            cmd2 = cmd + ["--tag", "f32probe", "--grad-dtype", "f32"]
            env2 = dict(env, REPRO_FORCE_F32="1")
            try:
                subprocess.run(cmd2, env=env2, capture_output=True,
                               text=True, timeout=args.timeout)
                with open(probe) as f:
                    pres = json.load(f)
                res["peak_bytes_per_dev_f32probe"] = \
                    pres["peak_bytes_per_dev"]
                res["peak_bytes_per_dev_bf16_bound"] = \
                    pres["peak_bytes_per_dev"] / 2
                with open(outf, "w") as f:
                    json.dump(res, f, indent=1)
                pk = pres["peak_bytes_per_dev"] / 2 ** 31
                print(f"    f32-probe: bf16-true peak <= {pk:.2f} GiB",
                      flush=True)
            except Exception as e:  # probe is best-effort
                print(f"    f32-probe failed: {e}", flush=True)


if __name__ == "__main__":
    main()

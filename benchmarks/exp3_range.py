"""Experiment 3 (paper Fig. 7): ΔLCR vs. threshold interaction range.

Paper claim: tiny ranges make unstable micro-clusters (many migrations,
mediocre ΔLCR); mid ranges cluster best; very large ranges overlap
everyone's neighborhoods and clustering quality degrades again.
"""
from __future__ import annotations

from benchmarks.common import SCALES, engine_cfg, run_cfg, write_csv


def main(scale: str = "quick", seeds=(0,)):
    # ranges scale with the area (the paper's 50..1600 on a 10k-side torus)
    side = SCALES[scale]["area"]
    fracs = [0.005, 0.01, 0.02, 0.04, 0.08, 0.16]
    rows = []
    for frac in fracs:
        rng = side * frac
        for seed in seeds:
            on = run_cfg(engine_cfg(scale, rng=rng, mf=1.2), seed)
            off = run_cfg(engine_cfg(scale, rng=rng, gaia=False), seed)
            dlcr = on["mean_lcr"] - off["mean_lcr"]
            rows.append((round(rng, 1), seed, round(dlcr, 4),
                         round(on["migration_ratio"], 2)))
            print(f"[exp3] range={rng:7.1f} seed={seed} dLCR {dlcr:+.3f} "
                  f"MR {on['migration_ratio']:.1f}")
    path = write_csv("exp3.csv", "range,seed,dlcr,mr", rows)

    d = {r[0]: r[2] for r in rows}
    vals = [d[round(side * f, 1)] for f in fracs]
    mid = max(vals[1:4])
    assert mid > vals[-1], f"huge ranges should degrade clustering: {vals}"
    assert mid > 0.15, f"mid-range clustering too weak: {vals}"
    print(f"[exp3] OK -> {path}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "quick")

"""Experiment 3 (paper Fig. 7): ΔLCR vs. threshold interaction range.

Paper claim: tiny ranges make unstable micro-clusters (many migrations,
mediocre ΔLCR); mid ranges cluster best; very large ranges overlap
everyone's neighborhoods and clustering quality degrades again. ΔLCR is
paired per seed, as in exp2.
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # script invocation: python benchmarks/...
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
from benchmarks.common import (SCALES, default_replicas,  # noqa: E402
                               engine_cfg, fmt_stat, paired_stats, run_cfg,
                               write_csv)


def main(scale: str = "quick", replicas=None):
    n_rep = default_replicas(scale, replicas)
    # ranges scale with the area (the paper's 50..1600 on a 10k-side torus)
    side = SCALES[scale]["area"]
    fracs = [0.005, 0.01, 0.02, 0.04, 0.08, 0.16]
    rows = []
    for frac in fracs:
        rng = side * frac
        on = run_cfg(engine_cfg(scale, rng=rng, mf=1.2), replicas=n_rep)
        off = run_cfg(engine_cfg(scale, rng=rng, gaia=False),
                      replicas=n_rep)
        dlcr = paired_stats(on["reps"], off["reps"],
                            lambda a, b: a["mean_lcr"] - b["mean_lcr"])
        rows.append((round(rng, 1), round(dlcr["mean"], 4),
                     round(dlcr["ci95"], 4), n_rep,
                     round(on["migration_ratio"], 2)))
        print(f"[exp3] range={rng:7.1f} dLCR {fmt_stat(dlcr)} "
              f"MR {on['migration_ratio']:.1f}")
    path = write_csv("exp3.csv", "range,dlcr,dlcr_ci95,n,mr", rows)

    d = {r[0]: r[1] for r in rows}
    vals = [d[round(side * f, 1)] for f in fracs]
    mid = max(vals[1:4])
    assert mid > vals[-1], f"huge ranges should degrade clustering: {vals}"
    assert mid > 0.15, f"mid-range clustering too weak: {vals}"
    print(f"[exp3] OK (n={n_rep}) -> {path}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("scale", nargs="?", default="quick",
                    choices=["quick", "mid", "paper"])
    ap.add_argument("--replicas", type=int, default=None)
    a = ap.parse_args()
    main(a.scale, a.replicas)

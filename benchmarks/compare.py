"""Bench-regression gate: compare current BENCH_*.json against the
committed BENCH_baseline/ snapshots.

Tracked metrics are deliberately *machine-independent ratios* (speedup
over a same-machine oracle, overhead factors, halo fractions, TEC gain
fractions) rather than absolute seconds: the baselines were recorded on
one box and the nightly job runs on whatever runner GitHub hands out,
so wall-clock numbers would flap while ratios only move when the code's
behavior moves.

Two layers decide a regression:

  1. **Tolerance** (the legacy rule): a tracked metric may move at most
     its tolerance in the worsening direction relative to its baseline
     mean — REL_TOL (20%) for counter-derived metrics, TIMING_TOL (60%)
     for the two ratios that divide one *measured time* by another.
  2. **Interval separation** (the replica-aware rule): metrics in the
     mean/std/ci95/n schema (benchmarks emit them since the batched-
     replica engine; see src/repro/core/stats.py) only FAIL when, in
     addition, the 95% confidence intervals of baseline and candidate
     do not overlap: |Δmean| > ci95_base + ci95_cur. A worsened mean
     inside overlapping intervals is reported as "ok (within noise)" —
     single-seed point estimates could not make that distinction, which
     is exactly how seed luck used to masquerade as a regression.

Legacy point-estimate metrics (plain floats) have zero-width intervals,
so rule 2 degenerates to rule 1. An *old-schema baseline* compared
against a new-schema current value still works (means compared, the
baseline interval taken as zero-width) but emits a DeprecationWarning:
refresh BENCH_baseline/ to the stats schema in the PR that migrates the
benchmark.

Used by the nightly CI job after the quick-mode exp4..exp8 runs
(--replicas 3: every statistical metric carries n >= 3), and runnable
locally:

    PYTHONPATH=src python -m benchmarks.run --scale quick \
        --only exp4,exp5,exp6,exp7,exp8 --replicas 3
    python benchmarks/compare.py

Refreshing baselines after an intentional change:

    cp BENCH_proximity.json BENCH_sharded.json BENCH_scenarios.json \
        BENCH_partition.json BENCH_replicas.json BENCH_service.json \
        BENCH_obs.json BENCH_baseline/
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import warnings

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
REL_TOL = 0.20  # counter-derived metrics: deterministic given the code
TIMING_TOL = 0.60  # time/time ratios: structural regressions only
ABS_TOL = 0.05  # slack when the baseline is ~zero

#: file -> {dotted.metric.path: (direction, tolerance)} with direction
#: "higher" | "lower" ("higher" = larger is better; the gate fires on
#: the *worsening* direction only). A path may resolve to a plain float
#: (legacy) or a mean/std/ci95/n stats dict (replica schema).
TRACKED = {
    "BENCH_proximity.json": {
        "grid_speedup_over_dense.10000": ("higher", TIMING_TOL),
        "grid_speedup_over_dense.50000": ("higher", TIMING_TOL),
    },
    # exp5: halo_frac is the *fraction* of remote rows exchanged;
    # bytes_on_wire_last10 is the sparse transport's absolute per-step
    # byte count once GAIA has clustered the hotspot scenario — the
    # physical quantity the neighbor-only exchange exists to shrink
    # (both are stats dicts over the last-10-step window)
    "BENCH_sharded.json": {
        "sharded_overhead_at_d1": ("lower", TIMING_TOL),
        "halo_shrink_d4.gaia_on.halo_frac_last10": ("lower", REL_TOL),
        "halo_shrink_d4.gaia_on.bytes_on_wire_last10": ("lower", REL_TOL),
    },
    # note: exp6's own >=2-of-3 win-count gate is asserted by the bench
    # itself; tracking the per-scenario gains here (rather than the win
    # count) keeps one consistent threshold per scenario. trace and
    # epidemic are the workload families beyond pure mobility (fleet
    # cells since the scenario-fleet PR): their gains are tracked the
    # same way but carry no sign gate of their own
    "BENCH_scenarios.json": {
        "gate.tec_gain_by_scenario.hotspot": ("higher", REL_TOL),
        "gate.tec_gain_by_scenario.group": ("higher", REL_TOL),
        "gate.tec_gain_by_scenario.flock": ("higher", REL_TOL),
        "gate.tec_gain_by_scenario.trace": ("higher", REL_TOL),
        "gate.tec_gain_by_scenario.epidemic": ("higher", REL_TOL),
    },
    # exp7: the informed-baseline gain over random/static must not decay,
    # and GAIA's TEC relative to the best *static* backend must not
    # drift upward (1.0 = parity; the bench itself gates at 1.02;
    # periodic repartitioners are deliberately excluded from that floor
    # — see exp7_partition.py — so a periodic-kmeans improvement moves
    # static_gain_by_scenario, not gaia_vs_best_static)
    "BENCH_partition.json": {
        "gate.static_gain_by_scenario.hotspot": ("higher", REL_TOL),
        "gate.static_gain_by_scenario.group": ("higher", REL_TOL),
        "gate.gaia_vs_best_static.hotspot": ("lower", REL_TOL),
        "gate.gaia_vs_best_static.group": ("lower", REL_TOL),
    },
    # exp8: loop_ratio (batch vs the sequential seed loop) is a
    # time/time ratio; the engine metrics are stats dicts, so their
    # gate runs the interval-separation rule
    "BENCH_replicas.json": {
        "loop_ratio": ("lower", TIMING_TOL),
        "metrics.mean_lcr": ("higher", REL_TOL),
    },
    # scale smoke (benchmarks/scale_smoke.py, nightly): the million-SE
    # hotspot tier must stay *exact* (grid_overflow_steps ~ 0 — the
    # zero baseline makes ABS_TOL the effective bound, so any tripped
    # step fails) and inside its per-SE memory envelope. bytes/SE is
    # machine-sized but allocator-stable on the linux runners; the wide
    # TIMING_TOL absorbs allocator/runner variance, not leaks — an
    # O(N^2)-shaped regression blows past 60% immediately.
    "BENCH_scale.json": {
        "rss_per_se_bytes": ("lower", TIMING_TOL),
        "grid_overflow_steps": ("lower", REL_TOL),
    },
    # exp9 (resident service): the step-latency tail under churn and
    # the drain-vs-sequential wall ratio are both time/time ratios —
    # machine-independent shape, TIMING_TOL width. The absolute
    # events/s bar is gated by the bench itself (ISSUE-8 acceptance),
    # not here, because it is machine-sized.
    "BENCH_service.json": {
        "churn.p99_over_p50": ("lower", TIMING_TOL),
        "service.service_vs_sequential": ("lower", TIMING_TOL),
    },
    # exp10 (telemetry): wall ratio of the instrumented run over the
    # bare run at drain_every=10 — a time/time ratio, TIMING_TOL width.
    # The absolute < 1.10 bar is asserted by the bench itself; this
    # entry catches slower drift that stays under the hard bar.
    "BENCH_obs.json": {
        "obs.overhead_ratio": ("lower", TIMING_TOL),
    },
}


def dig(obj, path: str):
    for part in path.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj


def as_stats(v):
    """Normalize a tracked value to (mean, ci95, is_legacy): a
    mean/std/ci95/n stats dict passes through; a plain number becomes a
    zero-width interval (the legacy point-estimate behaviour).

    The detection rule (all four schema keys present) mirrors
    `repro.core.stats.is_stats`, re-stated here because this gate must
    run without PYTHONPATH=src (keep the two in sync). Anything else —
    a partial dict, a nested result block — raises via float(), which
    is the desired loud failure for a mis-pointed TRACKED path."""
    if isinstance(v, dict) and {"mean", "std", "ci95", "n"} <= set(v):
        return float(v["mean"]), float(v["ci95"]), False
    return float(v), 0.0, True


def check_metric(direction: str, tol: float, cur, base):
    """Returns (ok, bound, note) for cur against base in the given
    direction. A metric FAILS only if the candidate mean is beyond the
    tolerance bound AND the 95% confidence intervals separate
    (|Δmean| > ci95_cur + ci95_base); point estimates have zero-width
    intervals, so legacy metrics keep the pure-tolerance rule."""
    cur_m, cur_ci, _ = as_stats(cur)
    base_m, base_ci, _ = as_stats(base)
    if abs(base_m) < 1e-9:
        bound = -ABS_TOL if direction == "higher" else ABS_TOL
    elif direction == "higher":
        bound = base_m - abs(base_m) * tol
    else:
        bound = base_m + abs(base_m) * tol
    beyond = not (cur_m >= bound if direction == "higher"
                  else cur_m <= bound)
    separated = abs(cur_m - base_m) > (cur_ci + base_ci)
    note = ""
    if beyond and not separated:
        note = (" [within noise: CIs overlap, "
                f"|Δ|={abs(cur_m - base_m):.4g} <= "
                f"{cur_ci + base_ci:.4g}]")
    return (not beyond) or (not separated), bound, note


def _fmt(v):
    m, ci, legacy = as_stats(v)
    return f"{m:.4g}" if legacy or ci == 0.0 else f"{m:.4g}±{ci:.4g}"


def _interval(v) -> str:
    m, ci, _ = as_stats(v)
    return f"[{m - ci:.4g}, {m + ci:.4g}]"


def fail_line(metric: str, direction: str, cur, base) -> str:
    """The one-line gate-failure summary: the tracked-key path plus
    both 95% confidence intervals, so a CI log grep ("GATE FAIL")
    yields everything needed to judge the regression without opening
    either JSON."""
    return (f"GATE FAIL {metric}: candidate {_fmt(cur)} "
            f"ci95 {_interval(cur)} vs baseline {_fmt(base)} "
            f"ci95 {_interval(base)} ({direction} is better)")


def compare_file(cur_path: str, base_path: str, metrics: dict):
    """Yields (metric, status, message) rows for one benchmark file.

    A missing baseline (file or metric) is a FAILURE, not a skip: it
    would otherwise silently disarm the gate — add the snapshot (or
    refresh BENCH_baseline/) in the PR that changes the benchmark."""
    name = os.path.basename(cur_path)
    if not os.path.exists(base_path):
        yield name, "fail", f"no baseline snapshot at {base_path}"
        return
    if not os.path.exists(cur_path):
        yield name, "fail", "current result missing (bench did not run?)"
        return
    with open(cur_path) as f:
        cur_doc = json.load(f)
    with open(base_path) as f:
        base_doc = json.load(f)
    warned_legacy = False
    for path, (direction, tol) in metrics.items():
        base = dig(base_doc, path)
        cur = dig(cur_doc, path)
        if base is None:
            yield f"{name}:{path}", "fail", \
                "metric missing from baseline (refresh BENCH_baseline/)"
            continue
        if cur is None:
            yield f"{name}:{path}", "fail", "metric missing from current run"
            continue
        base_legacy = as_stats(base)[2]
        cur_legacy = as_stats(cur)[2]
        if base_legacy and not cur_legacy and not warned_legacy:
            warnings.warn(
                f"{name}: baseline for {path} is an old-schema point "
                "estimate but the current run reports mean/std/ci95/n — "
                "comparing means with a zero-width baseline interval; "
                "refresh BENCH_baseline/ to the stats schema",
                DeprecationWarning, stacklevel=2)
            warned_legacy = True
        ok, bound, note = check_metric(direction, tol, cur, base)
        word = ">=" if direction == "higher" else "<="
        msg = (f"{_fmt(cur)} (baseline {_fmt(base)}, "
               f"needs {word} {bound:.4g}){note}")
        if not ok:
            msg += "\n[compare] " + fail_line(f"{name}:{path}",
                                              direction, cur, base)
        yield f"{name}:{path}", "ok" if ok else "fail", msg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if any tracked benchmark metric regressed "
                    f">{REL_TOL:.0%} (counters) / >{TIMING_TOL:.0%} "
                    "(timing ratios) vs the committed baseline AND the "
                    "95% confidence intervals separate")
    ap.add_argument("--baseline-dir",
                    default=os.path.join(REPO, "BENCH_baseline"))
    ap.add_argument("--current-dir", default=REPO)
    ap.add_argument("files", nargs="*", default=[],
                    help="restrict to these BENCH_*.json names")
    args = ap.parse_args(argv)

    names = args.files or sorted(TRACKED)
    failures = 0
    for fname in names:
        metrics = TRACKED.get(os.path.basename(fname))
        if metrics is None:
            print(f"[compare] {fname}: not a tracked benchmark "
                  f"(known: {sorted(TRACKED)})")
            failures += 1
            continue
        for metric, status, msg in compare_file(
                os.path.join(args.current_dir, os.path.basename(fname)),
                os.path.join(args.baseline_dir, os.path.basename(fname)),
                metrics):
            print(f"[compare] {status.upper():4s} {metric}: {msg}")
            failures += status == "fail"
    if failures:
        print(f"[compare] {failures} regression(s) vs baseline")
        return 1
    print("[compare] all tracked metrics within tolerance of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())

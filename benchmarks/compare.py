"""Bench-regression gate: compare current BENCH_*.json against the
committed BENCH_baseline/ snapshots.

Tracked metrics are deliberately *machine-independent ratios* (speedup
over a same-machine oracle, overhead factors, halo fractions, TEC gain
fractions) rather than absolute seconds: the baselines were recorded on
one box and the nightly job runs on whatever runner GitHub hands out,
so wall-clock numbers would flap while ratios only move when the code's
behavior moves. A tracked metric may regress at most its tolerance
relative to its baseline before the gate fails: REL_TOL (20%) for the
counter-derived metrics, which are deterministic given the code, and
TIMING_TOL (60%) for the two ratios that divide one *measured time* by
another — same-machine ratios still shift with CPU generation and rep
noise, so their gate only catches structural regressions (e.g. the
grid path degenerating toward dense), not jitter.

Used by the nightly CI job after the quick-mode exp4/exp5/exp6 runs,
and runnable locally:

    PYTHONPATH=src python -m benchmarks.run --scale quick \
        --only exp4,exp5,exp6
    python benchmarks/compare.py

Refreshing baselines after an intentional change:

    cp BENCH_proximity.json BENCH_sharded.json BENCH_scenarios.json \
        BENCH_baseline/
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
REL_TOL = 0.20  # counter-derived metrics: deterministic given the code
TIMING_TOL = 0.60  # time/time ratios: structural regressions only
ABS_TOL = 0.05  # slack when the baseline is ~zero

#: file -> {dotted.metric.path: (direction, tolerance)} with direction
#: "higher" | "lower" ("higher" = larger is better; the gate fires on
#: the *worsening* direction only)
TRACKED = {
    "BENCH_proximity.json": {
        "grid_speedup_over_dense.10000": ("higher", TIMING_TOL),
        "grid_speedup_over_dense.50000": ("higher", TIMING_TOL),
    },
    "BENCH_sharded.json": {
        "sharded_overhead_at_d1": ("lower", TIMING_TOL),
        "halo_shrink_d4.gaia_on.halo_frac_last10": ("lower", REL_TOL),
    },
    # note: exp6's own >=2-of-3 win-count gate is asserted by the bench
    # itself; tracking the per-scenario gains here (rather than the win
    # count) keeps one consistent threshold per scenario
    "BENCH_scenarios.json": {
        "gate.tec_gain_by_scenario.hotspot": ("higher", REL_TOL),
        "gate.tec_gain_by_scenario.group": ("higher", REL_TOL),
        "gate.tec_gain_by_scenario.flock": ("higher", REL_TOL),
    },
    # exp7: the informed-baseline gain over random/static must not decay,
    # and GAIA's TEC relative to the best *static* backend must not
    # drift upward (1.0 = parity; the bench itself gates at 1.02;
    # periodic repartitioners are deliberately excluded from that floor
    # — see exp7_partition.py — so a periodic-kmeans improvement moves
    # static_gain_by_scenario, not gaia_vs_best_static)
    "BENCH_partition.json": {
        "gate.static_gain_by_scenario.hotspot": ("higher", REL_TOL),
        "gate.static_gain_by_scenario.group": ("higher", REL_TOL),
        "gate.gaia_vs_best_static.hotspot": ("lower", REL_TOL),
        "gate.gaia_vs_best_static.group": ("lower", REL_TOL),
    },
}


def dig(obj, path: str):
    for part in path.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj


def check_metric(direction: str, tol: float, cur: float, base: float):
    """Returns (ok, bound) for cur against base in the given direction."""
    if abs(base) < 1e-9:
        bound = -ABS_TOL if direction == "higher" else ABS_TOL
    elif direction == "higher":
        bound = base - abs(base) * tol
    else:
        bound = base + abs(base) * tol
    ok = cur >= bound if direction == "higher" else cur <= bound
    return ok, bound


def compare_file(cur_path: str, base_path: str, metrics: dict):
    """Yields (metric, status, message) rows for one benchmark file.

    A missing baseline (file or metric) is a FAILURE, not a skip: it
    would otherwise silently disarm the gate — add the snapshot (or
    refresh BENCH_baseline/) in the PR that changes the benchmark."""
    name = os.path.basename(cur_path)
    if not os.path.exists(base_path):
        yield name, "fail", f"no baseline snapshot at {base_path}"
        return
    if not os.path.exists(cur_path):
        yield name, "fail", "current result missing (bench did not run?)"
        return
    with open(cur_path) as f:
        cur_doc = json.load(f)
    with open(base_path) as f:
        base_doc = json.load(f)
    for path, (direction, tol) in metrics.items():
        base = dig(base_doc, path)
        cur = dig(cur_doc, path)
        if base is None:
            yield f"{name}:{path}", "fail", \
                "metric missing from baseline (refresh BENCH_baseline/)"
            continue
        if cur is None:
            yield f"{name}:{path}", "fail", "metric missing from current run"
            continue
        ok, bound = check_metric(direction, tol, float(cur), float(base))
        word = ">=" if direction == "higher" else "<="
        msg = (f"{float(cur):.4g} (baseline {float(base):.4g}, "
               f"needs {word} {bound:.4g})")
        yield f"{name}:{path}", "ok" if ok else "fail", msg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if any tracked benchmark metric regressed "
                    f">{REL_TOL:.0%} (counters) / >{TIMING_TOL:.0%} "
                    "(timing ratios) vs the committed baseline")
    ap.add_argument("--baseline-dir",
                    default=os.path.join(REPO, "BENCH_baseline"))
    ap.add_argument("--current-dir", default=REPO)
    ap.add_argument("files", nargs="*", default=[],
                    help="restrict to these BENCH_*.json names")
    args = ap.parse_args(argv)

    names = args.files or sorted(TRACKED)
    failures = 0
    for fname in names:
        metrics = TRACKED.get(os.path.basename(fname))
        if metrics is None:
            print(f"[compare] {fname}: not a tracked benchmark "
                  f"(known: {sorted(TRACKED)})")
            failures += 1
            continue
        for metric, status, msg in compare_file(
                os.path.join(args.current_dir, os.path.basename(fname)),
                os.path.join(args.baseline_dir, os.path.basename(fname)),
                metrics):
            print(f"[compare] {status.upper():4s} {metric}: {msg}")
            failures += status == "fail"
    if failures:
        print(f"[compare] {failures} regression(s) vs baseline")
        return 1
    print("[compare] all tracked metrics within tolerance of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())

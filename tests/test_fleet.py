"""Fleet runner: matrix construction, executor contract, result merge.

These tests cover the deterministic scaffolding — the declarative cell
matrix, the RESULT-line child protocol, the schema of the merged
BENCH document, and the stub executors' loud refusal — without paying
for engine subprocesses; the cells themselves re-use
exp6_scenarios.run_cell, whose physics is covered by
tests/test_workloads.py and the nightly fleet run.
"""
import json

import pytest

from benchmarks import exp6_scenarios as exp6
from benchmarks import fleet


def test_matrix_covers_the_declared_axes():
    cells = fleet.build_matrix("quick", n_rep=4)
    assert cells == fleet.build_matrix("quick", n_rep=4)  # deterministic
    gate = [c for c in cells if c.gate]
    assert [c.scenario for c in gate] == list(exp6.SCENARIOS)
    assert all(c.kind == "tec" and c.n_devices == 1
               and c.partitioner == "random" and len(c.seeds) == 4
               for c in gate)
    part_axis = [c for c in cells if c.kind == "tec" and not c.gate]
    assert {c.scenario for c in part_axis} == set(exp6.WORKLOAD_SCENARIOS)
    assert all(c.partitioner == "voronoi" and c.repartition_every > 0
               for c in part_axis)
    ident = [c for c in cells if c.kind == "identity"]
    assert {(c.scenario, c.n_devices) for c in ident} == {
        (s, d) for s in exp6.WORKLOAD_SCENARIOS for d in (2, 4)}


def test_cell_payload_round_trips_through_json():
    cell = fleet.build_matrix("quick", 3)[0]
    payload = json.loads(json.dumps(cell.payload()))
    assert payload["scenario"] == cell.scenario
    assert payload["seeds"] == list(cell.seeds)
    assert payload["gate"] is True


def test_parse_result_protocol():
    out = "noise\nRESULT {\"x\": 1}\ntrailing\n"
    assert fleet.parse_result(out, "c") == {"x": 1}
    with pytest.raises(RuntimeError, match="no RESULT line"):
        fleet.parse_result("compile log only\n", "c")


def test_stub_executors_refuse_loudly():
    with pytest.raises(NotImplementedError, match="container executor"):
        fleet.ContainerExecutor().run([])
    with pytest.raises(NotImplementedError, match="k8s executor"):
        fleet.K8sExecutor().run([])
    with pytest.raises(NotImplementedError):
        fleet.Executor().run([])
    assert set(fleet.EXECUTORS) == {"local", "container", "k8s"}


def _fake_row(scenario, gain):
    stats = {"mean": gain, "std": 0.0, "ci95": 0.0, "n": 2}
    return {"scenario": scenario, "n": 2,
            "grid_overflow_on": 0.0, "grid_overflow_off": 0.0,
            "tec": {env: {"gain": dict(stats)} for env in exp6.ENVS}}


def _fake_fleet_results():
    cells = fleet.build_matrix("quick", 2)
    results = []
    for c in cells:
        if c.kind == "tec":
            results.append({"cell": c.name, "kind": "tec", "gate": c.gate,
                            "row": _fake_row(c.scenario, 0.1)})
        else:
            results.append({"cell": c.name, "kind": "identity",
                            "match": True, "mismatch": [],
                            "shard_overflow": 0.0, "mean_lcr": 0.9,
                            "migrations": 3.0, "timesteps": 60,
                            "wall_s": 1.0})
    return cells, results


def test_merge_keeps_exp6_schema_and_adds_fleet_block():
    cells, results = _fake_fleet_results()
    doc = fleet.merge(cells, results, "quick", 2)
    # the compare.py-tracked surface is intact
    assert doc["experiment"] == "exp6_scenarios"
    assert [r["scenario"] for r in doc["results"]] == list(exp6.SCENARIOS)
    gains = doc["gate"]["tec_gain_by_scenario"]
    assert set(gains) == set(exp6.SCENARIOS)
    assert all({"mean", "std", "ci95", "n"} <= set(g)
               for g in gains.values())
    # the fleet block carries every matrix point, rows stripped
    assert len(doc["fleet"]["cells"]) == len(cells)
    assert all("row" not in c for c in doc["fleet"]["cells"])
    assert len(doc["fleet"]["identity"]) == 4
    assert len(doc["fleet"]["extra_tec"]) == len(exp6.WORKLOAD_SCENARIOS)


def test_merge_asserts_identity_divergence():
    cells, results = _fake_fleet_results()
    for r in results:
        if r["kind"] == "identity":
            r["match"], r["mismatch"] = False, ["pos"]
            break
    with pytest.raises(AssertionError, match="diverged from oracle"):
        fleet.merge(cells, results, "quick", 2)


def test_run_cell_payload_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown cell kind"):
        fleet.run_cell_payload({"kind": "nope", "scale": "quick",
                                "scenario": "rwp", "seeds": [0],
                                "partitioner": "random",
                                "repartition_every": 0, "n_devices": 1})

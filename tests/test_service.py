"""Resident engine service (PR 8): open-world churn, queries, facade.

Contracts enforced here:

* zero churn + full population => bit-identical to the closed-world
  engine, on BOTH execution layers (the open-world masks must be pure
  selection when every slot is live);
* churn equivalence across layers: after the same arrive/step/depart
  script, the oracle and the sharded engine agree on every live row;
* slot lifecycle: depart frees a clean slot (no heuristic history
  leaks to the next occupant), overflow is loud, never silent;
* queries are served from device state and match a host-side recompute;
* the Engine facade's windowed stepping reproduces the one-shot run,
  and the six legacy free functions warn but still delegate exactly.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig, _run
from repro.core.heuristics import HeuristicConfig
from repro.core.partition import PartitionConfig
from repro.core.service import Engine, ReplicaService


def small_cfg(**kw):
    abm_kw = kw.pop("abm", {})
    abm = ABMConfig(n_se=160, n_lp=4, area=3162.0, speed=11.0,
                    interaction_range=250.0, p_interact=0.2, **abm_kw)
    base = dict(abm=abm, heuristic=HeuristicConfig(mf=1.2, mt=5),
                gaia_on=True, timesteps=40)
    base.update(kw)
    return EngineConfig(**base)


def leaf_bytes(x):
    if jnp.issubdtype(x.dtype, jax.dtypes.prng_key):
        x = jax.random.key_data(x)
    return np.asarray(x).tobytes()


# ---------------------------------------------------------------------------
# zero-churn bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sharding", ["none", "lp_device"])
def test_zero_churn_bit_identical(sharding):
    cfg = small_cfg(sharding=sharding)
    st_c, ser_c, _ = _run(jax.random.key(0), cfg)
    st_o, ser_o, c_o = _run(jax.random.key(0),
                            dataclasses.replace(cfg, open_world=True))
    for k in st_c:
        assert leaf_bytes(st_c[k]) == leaf_bytes(st_o[k]), f"state {k}"
    for k in ser_c:
        assert leaf_bytes(ser_c[k]) == leaf_bytes(ser_o[k]), f"series {k}"
    # the open-world run additionally reports its live population
    assert c_o["mean_pop"] == pytest.approx(cfg.abm.n_se, rel=1e-5)


# ---------------------------------------------------------------------------
# churn: cross-layer equivalence on live rows
# ---------------------------------------------------------------------------


def _drive(cfg, pos1, pos2):
    e = Engine(cfg).init(seed=0)
    e.step(8)
    ids = e.arrive({"pos": pos1})
    e.step(8)
    e.depart(ids[: len(ids) // 2])
    e.arrive({"pos": pos2})
    e.step(8)
    return e


def _live_rows(e):
    pos, lp, ext, valid = e._universe()
    gid = np.asarray(ext)
    loc = {int(g): i for i, g in enumerate(gid) if g >= 0}
    live = sorted(e._live)
    rows = np.asarray([loc[i] for i in live])
    return live, np.asarray(pos)[rows], np.asarray(lp)[rows]


def test_churn_oracle_vs_sharded_live_rows():
    rng = np.random.default_rng(3)
    p1 = (rng.random((8, 2)) * 3000).astype(np.float32)
    p2 = (rng.random((4, 2)) * 3000).astype(np.float32)
    base = small_cfg(open_world=True, n_active=120)
    eo = _drive(base, p1, p2)
    es = _drive(dataclasses.replace(base, sharding="lp_device"), p1, p2)
    live_o, pos_o, lp_o = _live_rows(eo)
    live_s, pos_s, lp_s = _live_rows(es)
    assert live_o == live_s
    assert pos_o.tobytes() == pos_s.tobytes()
    assert lp_o.tobytes() == lp_s.tobytes()


def test_depart_then_arrive_reuses_clean_slot():
    cfg = small_cfg(open_world=True, n_active=160)  # no free slot spare
    e = Engine(cfg).init(seed=0)
    e.step(12)  # accumulate heuristic history
    st = e.state
    victim = 7
    assert np.asarray(st["ring"])[:, victim, :].sum() >= 0
    e.depart([victim])
    st = e.state
    assert int(np.asarray(st["lp"])[victim]) == -1
    assert np.asarray(st["ring"])[:, victim, :].sum() == 0
    assert int(np.asarray(st["pending_dst"])[victim]) == -1
    assert int(np.asarray(st["last_mig"])[victim]) == -10**6
    # the freed slot is the only one available: the arrival must land in
    # it with a clean row
    [nid] = e.arrive({"pos": np.asarray([[1.0, 1.0]], np.float32)})
    assert nid == victim
    st = e.state
    assert int(np.asarray(st["lp"])[victim]) >= 0
    assert np.asarray(st["ring"])[:, victim, :].sum() == 0
    np.testing.assert_allclose(np.asarray(st["pos"])[victim], [1.0, 1.0])


def test_arrive_overflow_is_loud():
    cfg = small_cfg(open_world=True, n_active=158)
    e = Engine(cfg).init(seed=0)
    with pytest.raises(RuntimeError, match="free slots"):
        e.arrive({"pos": np.zeros((3, 2), np.float32)})
    assert e.population() == 158  # state untouched


def test_sharded_device_overflow_is_loud():
    # 60 universe free slots, but LP 0's device (capacity 48, ~25 live
    # residents) cannot absorb a 30-arrival burst aimed at it: the
    # universe check passes, the per-device admission must refuse loudly
    cfg = small_cfg(open_world=True, n_active=100,
                    sharding="lp_device", shard_capacity=48)
    e = Engine(cfg).init(seed=0)
    pos = np.zeros((30, 2), np.float32) + 5.0
    with pytest.raises(RuntimeError, match="shard_capacity"):
        e.arrive({"pos": pos, "lp": np.zeros((30,), np.int32)})


def test_depart_unknown_id_raises():
    cfg = small_cfg(open_world=True, n_active=100)
    e = Engine(cfg).init(seed=0)
    with pytest.raises(KeyError):
        e.depart([150])  # never admitted
    with pytest.raises(KeyError):
        e.depart([3, 3])  # duplicate in one batch
    assert e.population() == 100


# ---------------------------------------------------------------------------
# queries vs host-side recompute
# ---------------------------------------------------------------------------


def _host_neighbors(pos, valid, ids, area, rng):
    out = {}
    for i in ids:
        d = np.abs(pos - pos[i])
        d = np.minimum(d, area - d)
        d2 = (d ** 2).sum(axis=1)
        hit = valid & (d2 <= rng * rng)
        hit[i] = False
        out[i] = sorted(int(j) for j in np.nonzero(hit)[0])
    return out


@pytest.mark.parametrize("backend", ["grid", "dense"])
def test_query_neighbors_matches_host(backend):
    cfg = small_cfg(open_world=True, n_active=140,
                    abm=dict(proximity_backend=backend))
    e = Engine(cfg).init(seed=0)
    e.step(10)
    ids = sorted(e._live)[:5]
    got = e.query_neighbors(ids)
    pos = np.asarray(e.state["pos"])
    valid = np.asarray(e.state["lp"]) >= 0
    want = _host_neighbors(pos, valid, ids, cfg.abm.area,
                           cfg.abm.interaction_range)
    assert got == want


def test_query_lcr_matches_host():
    cfg = small_cfg(open_world=True, n_active=140)
    e = Engine(cfg).init(seed=0)
    e.step(10)
    pos = np.asarray(e.state["pos"])
    lp = np.asarray(e.state["lp"])
    valid = lp >= 0
    local = total = 0
    n = pos.shape[0]
    for i in range(n):
        if not valid[i]:
            continue
        d = np.abs(pos - pos[i])
        d = np.minimum(d, cfg.abm.area - d)
        hit = valid & ((d ** 2).sum(axis=1)
                       <= cfg.abm.interaction_range ** 2)
        hit[i] = False
        total += hit.sum()
        local += (hit & (lp == lp[i])).sum()
    assert e.query_lcr() == pytest.approx(local / max(total, 1))


def test_query_region_wraps():
    cfg = small_cfg(open_world=True, n_active=140)
    e = Engine(cfg).init(seed=0)
    pos = np.asarray(e.state["pos"])
    valid = np.asarray(e.state["lp"]) >= 0
    a = cfg.abm.area
    got = e.query_region((a - 500.0, 0.0, 500.0, a))  # wraps the seam
    in_x = (pos[:, 0] >= a - 500.0) | (pos[:, 0] <= 500.0)
    want = sorted(np.nonzero(valid & in_x)[0].tolist())
    assert got == want


# ---------------------------------------------------------------------------
# facade stepping + legacy shims
# ---------------------------------------------------------------------------


def test_facade_windows_match_one_shot():
    cfg = small_cfg()
    _, _, solo = _run(jax.random.key(0), cfg)
    e = Engine(cfg).init(seed=0)
    e.step(15)
    e.step(25)
    m = e.metrics()
    for k in ("migrations", "local_msgs", "remote_msgs", "heu_evals"):
        assert m[k] == solo[k]
    assert m["mean_lcr"] == pytest.approx(solo["mean_lcr"], rel=1e-6)
    assert m["migration_ratio"] == pytest.approx(solo["migration_ratio"],
                                                 rel=1e-6)


def test_facade_batched_run_matches_legacy():
    cfg = small_cfg()
    _, _, reps = Engine(cfg).run(seeds=[0, 1])
    _, _, solo = Engine(cfg).run(seed=1)
    for k in ("migrations", "local_msgs"):
        assert reps[1][k] == solo[k]


def test_replica_service_counters_exact():
    cfg = small_cfg()
    svc = ReplicaService(cfg, n_slots=2)
    jobs = [(0, 30), (1, 18), (2, 24)]
    rids = [svc.submit(seed=s, steps=n) for s, n in jobs]
    res = svc.drain()
    for (s, n), rid in zip(jobs, rids):
        _, _, solo = Engine(
            dataclasses.replace(cfg, timesteps=n)).run(seed=s)
        for k in ("migrations", "local_msgs", "remote_msgs", "heu_evals"):
            assert res[rid][k] == solo[k], (rid, k)


def test_legacy_functions_warn_and_delegate():
    from repro.core import engine as E
    cfg = small_cfg(timesteps=10)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _, _, c1 = E.run(jax.random.key(0), cfg)
        st = E.init_engine(jax.random.key(0), cfg)
        st, _ = E.run_window(st, cfg, 5)
        sts = E.init_batch(cfg, [0, 1])
        sts, _ = E.run_window_batch(sts, cfg, 5)
        _, _, reps = E.run_batch(cfg, [0])
    assert sum(1 for x in w
               if issubclass(x.category, DeprecationWarning)) >= 6
    _, _, c2 = Engine(cfg).run(seed=0)
    assert c1["migrations"] == c2["migrations"]


# ---------------------------------------------------------------------------
# config validation (__post_init__ raises, not mid-run surprises)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(timesteps=-1),
    dict(migration_delay=0),
    dict(n_devices=-1),
    dict(repartition_every=-1),
    dict(sharding="rows"),
    dict(balance="magic"),
    dict(n_active=10),  # needs open_world
    dict(open_world=True, n_active=10**9),
])
def test_engine_config_validation(kw):
    with pytest.raises(ValueError):
        small_cfg(**kw)


def test_open_world_rejects_pallas():
    with pytest.raises(ValueError, match="open_world"):
        small_cfg(open_world=True, abm=dict(proximity_backend="pallas"))


@pytest.mark.parametrize("kw", [
    dict(n_se=0), dict(n_lp=0), dict(area=0.0),
    dict(interaction_range=-1.0), dict(p_interact=1.5),
    dict(speed=-1.0), dict(grid_capacity=-1),
])
def test_abm_config_validation(kw):
    base = dict(n_se=64, n_lp=2, area=500.0, interaction_range=100.0)
    base.update(kw)
    with pytest.raises(ValueError):
        ABMConfig(**base)


@pytest.mark.parametrize("kw", [
    dict(kind=5), dict(mf=-0.1), dict(mt=-1),
    dict(kappa=0), dict(omega=0), dict(zeta=0),
])
def test_heuristic_config_validation(kw):
    with pytest.raises(ValueError):
        HeuristicConfig(**kw)


@pytest.mark.parametrize("kw", [
    dict(n_lp=0), dict(area=0.0), dict(interaction_range=0.0),
    dict(iters=0), dict(backend="magic"),
])
def test_partition_config_validation(kw):
    base = dict(n_lp=4, area=1000.0, interaction_range=100.0)
    base.update(kw)
    with pytest.raises(ValueError):
        PartitionConfig(**base)

"""Fault-tolerance integration tests: checkpoint atomicity/integrity,
crash-restart bit-exactness, watchdog, elastic reshape.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.watchdog import Watchdog


# ---------------------------------------------------------------------------
# Checkpoint manager
# ---------------------------------------------------------------------------


def _tree(key=0):
    k = jax.random.key(key)
    return {"w": jax.random.normal(k, (8, 8), jnp.float32),
            "b": jnp.arange(5, dtype=jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    t = _tree()
    m.save(3, t)
    got, step = m.restore(jax.eval_shape(lambda: t))
    assert step == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, got)


def test_restore_picks_latest_committed(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, _tree(1))
    m.save(5, _tree(5))
    # a torn save (crash mid-write) leaves only a .tmp dir — ignored
    os.makedirs(tmp_path / "step_9.tmp")
    assert m.latest_step() == 5
    _, step = m.restore(jax.eval_shape(lambda: _tree()))
    assert step == 5


def test_corruption_detected(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(2, _tree())
    # flip bytes in a leaf file
    leaf = tmp_path / "step_2" / "leaf_0.npy"
    data = bytearray(leaf.read_bytes())
    data[-1] ^= 0xFF
    leaf.write_bytes(bytes(data))
    with pytest.raises(IOError):
        m.restore(jax.eval_shape(lambda: _tree()))


def test_async_save_equivalent(tmp_path):
    m = CheckpointManager(str(tmp_path))
    t = _tree(4)
    m.save(7, t, blocking=False)
    m.wait()
    got, _ = m.restore(jax.eval_shape(lambda: t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, got)


def test_retention_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _tree(s))
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_3", "step_4"]


def test_elastic_reshape_restore(tmp_path):
    """Restore with explicit shardings (single-device here) — the arrays
    come back device_put onto the new layout."""
    m = CheckpointManager(str(tmp_path))
    t = _tree()
    m.save(1, t)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    shardings = jax.tree.map(lambda _: sh, t)
    got, _ = m.restore(jax.eval_shape(lambda: t), shardings=shardings)
    assert got["w"].sharding == sh


# ---------------------------------------------------------------------------
# Trainer crash/restart
# ---------------------------------------------------------------------------


def _toy_trainer(ckpt_dir, total=12):
    """Tiny pure-jax 'model': w learns the batch mean."""
    data_cfg = DataConfig(vocab_size=32, seq_len=8, global_batch=2, seed=3)

    def init_state():
        return ({"w": jnp.zeros((8,), jnp.float32)},
                {"v": jnp.zeros((8,), jnp.float32)}, {})

    @jax.jit
    def step_fn(params, opt, extras, batch):
        x = batch["tokens"].astype(jnp.float32).mean(0)
        grad = params["w"] - x
        v = 0.9 * opt["v"] + grad
        w = params["w"] - 0.1 * v
        return {"w": w}, {"v": v}, extras, {"loss": jnp.sum(grad ** 2)}

    cfg = TrainerConfig(total_steps=total, checkpoint_every=4,
                        checkpoint_dir=str(ckpt_dir), log_every=100,
                        async_save=False)
    return Trainer(cfg, step_fn, init_state, data_cfg, log=lambda s: None)


def test_crash_restart_bit_exact(tmp_path):
    d1, d2 = tmp_path / "a", tmp_path / "b"
    # uninterrupted run
    ref = _toy_trainer(d1).run()
    # crashed at step 7 (checkpoint exists at 4), then resumed
    with pytest.raises(RuntimeError):
        _toy_trainer(d2).run(fail_at=7)
    out = _toy_trainer(d2).run()
    np.testing.assert_array_equal(np.asarray(ref["params"]["w"]),
                                  np.asarray(out["params"]["w"]))


def test_resume_starts_from_checkpoint(tmp_path):
    tr = _toy_trainer(tmp_path, total=8)
    tr.run()
    assert tr.ckpt.latest_step() == 8
    logs = []
    tr2 = _toy_trainer(tmp_path, total=8)
    tr2.log = logs.append
    tr2.run()  # nothing left to do; resumes at 8 and saves final
    assert any("resumed from step 8" in l for l in logs)


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------


def test_watchdog_flags_straggler_and_hang():
    wd = Watchdog(min_samples=3, straggler_factor=2.0, hang_factor=5.0)
    for i in range(5):
        assert wd.observe(i, 1.0) == "ok"
    assert wd.observe(5, 2.5) == "straggler"
    assert wd.stragglers == 1
    assert wd.observe(6, 50.0) == "hang"
    # clamped EMA: one hang doesn't poison the baseline
    assert wd.ema < 3.0
    assert wd.observe(7, 1.0) == "ok"


def test_watchdog_deadline():
    wd = Watchdog(min_samples=2, hang_factor=4.0)
    assert wd.deadline() == float("inf")
    wd.observe(0, 1.0)
    wd.observe(1, 1.0)
    assert wd.deadline() == pytest.approx(4.0, rel=0.3)

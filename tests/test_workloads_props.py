"""Property-based tests for the epidemic workload kernel.

Hypothesis-drawn layouts and flag vectors pin the row-update algebra
the fixed-seed tests (tests/test_workloads.py) spot-check:

  * flags are closed over {0, 1} for any exposure/draw combination;
  * the SI update is monotone in *both* arguments — exposure and the
    susceptible set: infecting more rows or raising exposure never
    un-infects anyone (with gamma = 0);
  * recovery acts only on infectious rows, infection only on
    susceptible ones, so the per-row transition matrix is exactly the
    SIS chain's;
  * the 2-class exposure sweep is bit-identical between the grid and
    dense proximity backends on arbitrary layouts with dead rows.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional dev dependency "
    "`hypothesis` (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.abm import (ABMConfig, epidemic_draws,  # noqa: E402
                            epidemic_exposure_overflow,
                            epidemic_row_update)

settings.register_profile("ci", deadline=None, max_examples=30)
settings.load_profile("ci")

CFG = ABMConfig(n_se=96, n_lp=4, area=1000.0, speed=5.0,
                interaction_range=80.0, p_interact=0.3,
                workload="epidemic", epi_beta=0.4, epi_boost=4.0,
                epi_seed_frac=0.05)


def _layout(draw, n_max=24):
    n = draw(st.integers(1, n_max))
    epi = draw(st.lists(st.integers(0, 1), min_size=n, max_size=n))
    exposure = draw(st.lists(st.integers(0, 6), min_size=n, max_size=n))
    seed = draw(st.integers(0, 2 ** 16))
    return (jnp.asarray(epi, jnp.int32), jnp.asarray(exposure, jnp.int32),
            epidemic_draws(jax.random.key(seed), n, CFG), seed)


@given(st.data(), st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_flags_stay_binary(data, beta, gamma):
    epi, exposure, _, seed = _layout(data.draw)
    cfg = dataclasses.replace(CFG, epi_beta=beta, epi_gamma=gamma)
    draws = epidemic_draws(jax.random.key(seed), epi.shape[0], cfg)
    out = np.asarray(epidemic_row_update(epi, exposure, draws, cfg))
    assert set(np.unique(out)) <= {0, 1}


@given(st.data())
def test_si_never_uninfects_and_is_monotone(data):
    """gamma = 0: out >= epi pointwise, and raising any row's exposure
    can only add infections under the same draws."""
    epi, exposure, draws, _ = _layout(data.draw)
    out1 = np.asarray(epidemic_row_update(epi, exposure, draws, CFG))
    assert (out1 >= np.asarray(epi)).all()
    bumped = exposure + data.draw(st.integers(0, 5))
    out2 = np.asarray(epidemic_row_update(epi, bumped, draws, CFG))
    assert ((out1 == 1) <= (out2 == 1)).all()


@given(st.data())
def test_monotone_in_the_infected_set(data):
    """Seeding extra infectious rows (same exposure, same draws) never
    removes anyone from the final infected set with gamma = 0."""
    epi, exposure, draws, _ = _layout(data.draw)
    extra = data.draw(st.lists(st.integers(0, 1),
                               min_size=epi.shape[0],
                               max_size=epi.shape[0]))
    epi_more = jnp.maximum(epi, jnp.asarray(extra, jnp.int32))
    o1 = np.asarray(epidemic_row_update(epi, exposure, draws, CFG))
    o2 = np.asarray(epidemic_row_update(epi_more, exposure, draws, CFG))
    assert ((o1 == 1) <= (o2 == 1)).all()


@given(st.data(), st.floats(0.01, 1.0))
def test_sis_transitions_respect_compartments(data, gamma):
    """Only S -> I (needs exposure) and I -> S (needs gamma draw) edges
    exist: a row that changed state moved along exactly one of them."""
    epi, exposure, _, seed = _layout(data.draw)
    cfg = dataclasses.replace(CFG, epi_gamma=gamma)
    draws = epidemic_draws(jax.random.key(seed), epi.shape[0], cfg)
    out = np.asarray(epidemic_row_update(epi, exposure, draws, cfg))
    e, x = np.asarray(epi), np.asarray(exposure)
    newly_inf = (e == 0) & (out == 1)
    assert (x[newly_inf] > 0).all()  # infection needs contact
    recovered = (e == 1) & (out == 0)
    assert (np.asarray(draws["u_rec"])[recovered] < gamma).all()


@given(st.integers(0, 2 ** 16), st.integers(8, 64))
def test_exposure_backends_agree_on_random_layouts(seed, n):
    k = jax.random.key(seed)
    pos = jax.random.uniform(k, (n, 2), maxval=CFG.area)
    valid = jax.random.uniform(jax.random.fold_in(k, 1), (n,)) < 0.85
    inf = jax.random.uniform(jax.random.fold_in(k, 2), (n,)) < 0.3
    labels = jnp.where(valid, inf.astype(jnp.int32), -1)
    qmask = valid & (labels == 0)
    dense = dataclasses.replace(CFG, proximity_backend="dense")
    eg, _ = epidemic_exposure_overflow(pos, labels, qmask, CFG, valid=valid)
    ed, _ = epidemic_exposure_overflow(pos, labels, qmask, dense,
                                       valid=valid)
    np.testing.assert_array_equal(np.asarray(eg), np.asarray(ed))

"""Exchange-soundness suite for the sparse neighbor-only halo.

The sparse transport (parallel/lp_shard.py) is only exact if the
one-step-stale, dilation-covered `halo_need` bitmaps are a *superset*
of the true need: every SE pair within interaction range across a
device boundary must have the remote row present in the receiver's
halo buffer — a silently dropped neighbor would corrupt interaction
counts without tripping any capacity alarm. This file locks that down
from three directions:

  1. the soundness property itself, checked directly against the
     `halo_need_bitmaps` reference on randomized layouts with
     adversarial one-step motion (numpy brute force over all pairs;
     a hypothesis generalization runs when the optional dev dependency
     is installed);
  2. end-to-end bit-identity of the sparse path vs the
     `sharding="none"` oracle at D=1/2/4 across mobility models —
     including a *tight* `halo_capacity`, where the contract is
     "exact or loudly overflowing", never silently wrong;
  3. the `bytes_on_wire` accounting (hand-counted on a frozen 2-device
     toy; shrinking under GAIA on a hotspot scenario) and the
     migration/resharding edge cases (zero-migration runs, mig_capacity
     saturation, repartition landing on a halo-swap step).

The D=8 variants force 8 host devices in a subprocess (XLA pins the
device count at first init) and are marked `slow` for the nightly job.
"""
import dataclasses
import functools
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import neighbors
from repro.core.abm import ABMConfig, max_step_displacement
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig
from repro.parallel import lp_shard

ABM = ABMConfig(n_se=96, n_lp=4, area=1000.0, speed=5.0,
                interaction_range=80.0, p_interact=0.3)
CFG = EngineConfig(abm=ABM, heuristic=HeuristicConfig(mf=1.2, mt=5),
                   gaia_on=True, timesteps=16)

STATE_KEYS = ("pos", "waypoint", "mob", "mob_g", "lp", "pending_dst",
              "pending_eta", "ring", "ptr", "since_eval", "last_mig")
SERIES_KEYS = ("local_msgs", "remote_msgs", "migrations", "heu_evals",
               "lcr", "lp_flows", "mig_flows")


@functools.lru_cache(maxsize=None)
def _run(cfg: EngineConfig, seed=7):
    return run(jax.random.key(seed), cfg)


def _assert_bit_identical(cfg, n_devices, seed=7):
    st0, s0, c0 = _run(cfg, seed)
    st1, s1, c1 = _run(dataclasses.replace(cfg, sharding="lp_device",
                                           n_devices=n_devices), seed)
    assert c1["shard_overflow"] == 0.0
    for k in STATE_KEYS:
        np.testing.assert_array_equal(np.asarray(st0[k]), np.asarray(st1[k]),
                                      err_msg=k)
    for k in SERIES_KEYS:
        np.testing.assert_array_equal(np.asarray(s0[k]), np.asarray(s1[k]),
                                      err_msg=k)
    return s1, c1


# ---------------------------------------------------------------------------
# 1. the soundness property against the bitmap reference
# ---------------------------------------------------------------------------


def _toroidal_d2_np(pos, area):
    d = np.abs(pos[:, None, :] - pos[None, :, :])
    d = np.minimum(d, area - d)
    return (d ** 2).sum(-1)


def _check_soundness(spec, abm, pos, valid, pending, rng):
    """One adversarial round: bitmaps from (pos, valid, pending), then
    arrivals land and every row moves up to the model's displacement
    bound — every cross-device in-range pair must be covered."""
    S = spec.n_slots
    need = np.asarray(lp_shard.halo_need_bitmaps(
        jnp.asarray(pos), jnp.asarray(valid), jnp.asarray(pending),
        spec, abm))
    src_dev = np.arange(S) // spec.cap
    dst_dev = np.asarray(lp_shard.dev_of_lp(
        jnp.maximum(jnp.asarray(pending), 0), spec))
    disp = max_step_displacement(abm)
    delta = rng.uniform(-disp, disp, (S, 2))
    new_pos = (pos + delta) % abm.area
    cell = np.asarray(neighbors.cell_ids(jnp.asarray(new_pos), spec.grid))
    d2 = _toroidal_d2_np(new_pos, abm.area)
    # a pending row may or may not arrive next step (its eta decides);
    # the bitmaps must be sound either way
    for owner in (src_dev, np.where(pending >= 0, dst_dev, src_dev)):
        in_range = (valid[:, None] & valid[None, :]
                    & (owner[:, None] != owner[None, :])
                    & (d2 <= abm.interaction_range ** 2))
        covered = need[owner][:, cell]  # (S recv, S send)
        missing = in_range & ~covered
        assert not missing.any(), (
            f"{missing.sum()} in-range cross-device pairs missing from "
            f"the receiver's halo need (first: {np.argwhere(missing)[0]})")


def _random_layout(rng, spec, abm):
    S = spec.n_slots
    valid = rng.random(S) < 0.8
    pos = (rng.random((S, 2)) * abm.area).astype(np.float32)
    pending = np.full(S, -1, np.int32)
    pend = valid & (rng.random(S) < 0.25)
    pending[pend] = rng.integers(0, spec.n_lp, int(pend.sum()))
    return pos, valid, pending


@pytest.mark.parametrize("n_devices", [2, 4])
@pytest.mark.parametrize("mobility", ["rwp", "hotspot", "group", "flock"])
def test_halo_need_soundness(mobility, n_devices):
    abm = dataclasses.replace(ABM, mobility=mobility, n_groups=4,
                              group_radius=120.0)
    cfg = dataclasses.replace(CFG, abm=abm, sharding="lp_device",
                              n_devices=n_devices)
    spec = lp_shard.make_shard_spec(cfg)
    assert spec.grid is not None
    for seed in range(5):
        rng = np.random.default_rng(seed)
        _check_soundness(spec, abm, *_random_layout(rng, spec, abm), rng)


def test_halo_need_soundness_at_displacement_bound():
    """Every row teleports exactly the displacement bound along one
    axis — the worst case the dilation radius must absorb."""
    abm = dataclasses.replace(ABM, mobility="hotspot")  # largest bound
    cfg = dataclasses.replace(CFG, abm=abm, sharding="lp_device",
                              n_devices=4)
    spec = lp_shard.make_shard_spec(cfg)
    rng = np.random.default_rng(11)
    pos, valid, pending = _random_layout(rng, spec, abm)

    class _Extremal:
        def uniform(self, lo, hi, shape):
            sign = rng.integers(0, 2, shape) * 2 - 1
            return sign * hi
    _check_soundness(spec, abm, pos, valid, pending, _Extremal())


def test_dilate_mask_matches_brute_force():
    rng = np.random.default_rng(3)
    for ncell, r in ((7, 1), (8, 2), (5, 3), (4, 4)):
        occ = rng.random((ncell, ncell)) < 0.2
        got = np.asarray(neighbors.dilate_mask(jnp.asarray(occ), r))
        want = np.zeros_like(occ)
        for dx in range(-r, r + 1):
            for dy in range(-r, r + 1):
                want |= np.roll(occ, (dx, dy), (0, 1))
        np.testing.assert_array_equal(got, want, err_msg=f"{ncell},{r}")


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency (pip install -e .[dev])
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    settings.register_profile("halo", deadline=None, max_examples=25)
    settings.load_profile("halo")

    @given(seed=st.integers(0, 2**31 - 1),
           n_devices=st.sampled_from([2, 4]),
           mobility=st.sampled_from(["rwp", "hotspot", "group", "flock"]),
           density=st.floats(0.05, 1.0))
    def test_halo_need_soundness_hypothesis(seed, n_devices, mobility,
                                            density):
        abm = dataclasses.replace(ABM, mobility=mobility, n_groups=4,
                                  group_radius=120.0)
        cfg = dataclasses.replace(CFG, abm=abm, sharding="lp_device",
                                  n_devices=n_devices)
        spec = lp_shard.make_shard_spec(cfg)
        rng = np.random.default_rng(seed)
        S = spec.n_slots
        valid = rng.random(S) < density
        pos = (rng.random((S, 2)) * abm.area).astype(np.float32)
        pending = np.full(S, -1, np.int32)
        pend = valid & (rng.random(S) < 0.25)
        pending[pend] = rng.integers(0, spec.n_lp, int(pend.sum()))
        _check_soundness(spec, abm, pos, valid, pending, rng)


# ---------------------------------------------------------------------------
# 2. end-to-end bit-identity of the sparse path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_devices", [1, 2, 4])
@pytest.mark.parametrize("mobility", ["rwp", "hotspot", "flock"])
def test_sparse_halo_bit_identity(mobility, n_devices):
    """The receiver-side proof of soundness: if any in-range neighbor
    were missing from a halo buffer, the interaction counts (and with
    them lp_flows, LCR, the migration sequence, final positions) would
    diverge from the oracle."""
    cfg = dataclasses.replace(
        CFG, abm=dataclasses.replace(ABM, mobility=mobility, n_groups=4,
                                     group_radius=120.0),
        timesteps=14)
    s1, c1 = _assert_bit_identical(cfg, n_devices)
    if n_devices > 1:
        assert float(np.asarray(s1["bytes_on_wire"]).sum()) > 0


def test_tight_halo_capacity_exact_or_loud():
    """Shrinking `halo_capacity` must never be silently wrong: every
    setting either stays bit-identical to the oracle (capacity bounds
    the true per-pair need) or raises the shard_overflow alarm."""
    saw_overflow = saw_exact = False
    for hc in (96, 32, 8, 2):
        cfg = dataclasses.replace(CFG, halo_capacity=hc, timesteps=10)
        _, s1, c1 = _run(dataclasses.replace(cfg, sharding="lp_device",
                                             n_devices=4))
        if c1["shard_overflow"] > 0:
            saw_overflow = True
            continue
        saw_exact = True
        _assert_bit_identical(cfg, 4)  # halo_capacity rides along in cfg
    assert saw_exact, "no halo_capacity in the sweep was sufficient"
    assert saw_overflow, ("even halo_capacity=2 bounded the need — "
                          "sweep too loose to exercise the alarm")


# ---------------------------------------------------------------------------
# 3a. bytes_on_wire accounting
# ---------------------------------------------------------------------------


def test_bytes_on_wire_matches_hand_count():
    """Frozen 2-device toy (speed=0, GAIA off): the only traffic is the
    halo, so wire_flows must equal the slot count a hand replay of the
    exchange rule derives from the need bitmaps, times 12 B/row."""
    abm = ABMConfig(n_se=24, n_lp=2, area=4000.0, speed=0.0,
                    interaction_range=250.0, p_interact=1.0)
    cfg = EngineConfig(abm=abm, heuristic=HeuristicConfig(mf=1.2, mt=5),
                       gaia_on=False, timesteps=2, sharding="lp_device",
                       n_devices=2)
    spec = lp_shard.make_shard_spec(cfg)
    assert spec.grid is not None and spec.n_dev == 2
    st = lp_shard.init_sharded(jax.random.key(5), cfg, spec)

    need = np.asarray(st["halo_need"])  # (2, ncell^2)
    valid = np.asarray(st["gid"]) >= 0
    dev = np.arange(spec.n_slots) // spec.cap
    cell = np.asarray(neighbors.cell_ids(st["pos"], spec.grid))
    expected = np.zeros((2, 2), np.int64)
    for recv in range(2):
        send_rows = valid & (dev != recv) & need[recv][cell]
        for src in range(2):
            expected[src, recv] = (
                (send_rows & (dev == src)).sum() * lp_shard.HALO_ROW_BYTES)
    assert expected.sum() > 0  # non-vacuous toy

    mesh = lp_shard.make_mesh(spec)
    st1, m1 = lp_shard.step_sharded(st, cfg, spec, mesh)
    np.testing.assert_array_equal(np.asarray(m1["wire_flows"]), expected)
    assert float(m1["bytes_on_wire"]) == expected.sum()
    # frozen positions, no migrations: step 2 moves the same bytes
    _, m2 = lp_shard.step_sharded(st1, cfg, spec, mesh)
    np.testing.assert_array_equal(np.asarray(m2["wire_flows"]), expected)


def test_bytes_on_wire_shrinks_as_gaia_clusters_hotspot():
    """The wire finally tracks halo_frac: as GAIA clusters the hotspot
    scenario, the measured bytes must fall with the halo — and end
    strictly below the GAIA-off run's plateau."""
    abm = dataclasses.replace(ABM, mobility="hotspot", n_groups=4,
                              group_radius=120.0)
    base = EngineConfig(abm=abm, heuristic=HeuristicConfig(mf=1.2, mt=5),
                        gaia_on=True, timesteps=48, sharding="lp_device",
                        n_devices=4)
    _, s_on, c_on = _run(base, seed=3)
    _, s_off, c_off = _run(dataclasses.replace(base, gaia_on=False), seed=3)
    assert c_on["shard_overflow"] == 0.0 == c_off["shard_overflow"]
    b_on = np.asarray(s_on["bytes_on_wire"])
    b_off = np.asarray(s_off["bytes_on_wire"])
    h_on = np.asarray(s_on["halo_frac"])
    assert h_on[-8:].mean() < h_on[:8].mean()  # GAIA clusters
    assert b_on[-8:].mean() < b_on[:8].mean()  # ...and the wire follows
    assert b_on[-8:].mean() < b_off[-8:].mean()  # below the static plateau


# ---------------------------------------------------------------------------
# 3b. migration / resharding edge cases
# ---------------------------------------------------------------------------


def test_zero_migration_run_bit_identical():
    """GAIA off, no repartition: not a single resharding op fires, the
    exchange alone carries every step."""
    cfg = dataclasses.replace(CFG, gaia_on=False, timesteps=12)
    s1, c1 = _assert_bit_identical(cfg, 4)
    assert float(np.asarray(s1["migrations"]).sum()) == 0.0
    assert c1["shard_overflow"] == 0.0


def test_mig_capacity_saturation_exact_or_deferring():
    """Descending migration-buffer capacities: a capacity that still
    bounds the true per-step demand stays bit-identical; one that
    saturates must defer (population preserved) and raise the alarm —
    never silently drop an SE."""
    saw_clean = saw_saturated = False
    for cap in (48, 1):
        cfg = dataclasses.replace(CFG, mig_capacity=cap, timesteps=20)
        st1, s1, c1 = _run(dataclasses.replace(cfg, sharding="lp_device",
                                               n_devices=4))
        if c1["shard_overflow"] == 0.0:
            saw_clean = True
            _assert_bit_identical(cfg, 4)
        else:
            saw_saturated = True
            # every SE still alive and hosted exactly once
            assert (np.asarray(st1["lp"]) >= 0).sum() == ABM.n_se
            assert int(np.unique(np.asarray(st1["lp"])).size) <= ABM.n_lp
            # the alarm fired but the run kept going: later steps still
            # migrate within the 1-row budget
            assert float(np.asarray(s1["migrations"]).sum()) > 0
    assert saw_clean and saw_saturated, (saw_clean, saw_saturated)


def test_repartition_coincides_with_halo_swap():
    """A periodic repartition whose cadence equals the migration delay:
    repartition grants, their arrivals, and the per-step halo swap all
    land on the same steps — still bit-for-bit with the oracle."""
    cfg = dataclasses.replace(
        CFG, abm=dataclasses.replace(ABM, mobility="hotspot", n_groups=4,
                                     group_radius=120.0,
                                     partitioner="kmeans"),
        repartition_every=5, migration_delay=5, timesteps=16)
    s1, _ = _assert_bit_identical(cfg, 4)
    assert float(np.asarray(s1["repartitions"]).sum()) > 0


# ---------------------------------------------------------------------------
# D=8 forced-host-device variants (fresh subprocess: XLA pins the
# device count at first init) — nightly job
# ---------------------------------------------------------------------------

_D8_CODE = """
import dataclasses, json
import jax, numpy as np
from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig

abm = ABMConfig(n_se=96, n_lp=8, area=1000.0, speed=5.0,
                interaction_range=80.0, p_interact=0.3,
                mobility={mobility!r}, n_groups=4, group_radius=120.0)
cfg = EngineConfig(abm=abm, heuristic=HeuristicConfig(mf=1.2, mt=5),
                   gaia_on=True, timesteps=14)
st0, s0, c0 = run(jax.random.key(7), cfg)
st1, s1, c1 = run(jax.random.key(7), dataclasses.replace(
    cfg, sharding="lp_device", n_devices=8))
assert len(jax.devices()) == 8, jax.devices()
assert c1["shard_overflow"] == 0.0
for k in ("pos", "lp", "ring", "last_mig"):
    np.testing.assert_array_equal(np.asarray(st0[k]), np.asarray(st1[k]),
                                  err_msg=k)
for k in ("lp_flows", "mig_flows", "migrations"):
    np.testing.assert_array_equal(np.asarray(s0[k]), np.asarray(s1[k]),
                                  err_msg=k)
print("RESULT " + json.dumps(dict(
    bytes_on_wire=c1["bytes_on_wire"], halo=c1["mean_halo_frac"])))
"""


@pytest.mark.slow
@pytest.mark.parametrize("mobility", ["rwp", "hotspot"])
def test_bit_identity_d8_subprocess(mobility):
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ,
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c",
                        _D8_CODE.format(mobility=mobility)],
                       capture_output=True, text=True, timeout=1200, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    line = next(l for l in r.stdout.splitlines() if l.startswith("RESULT "))
    out = json.loads(line[len("RESULT "):])
    assert out["bytes_on_wire"] > 0


# ---------------------------------------------------------------------------
# multi-host entry point
# ---------------------------------------------------------------------------


def _multihost(extra, timeout=600):
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ,
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)  # the launcher sets its own device count
    return subprocess.run(
        [sys.executable, "-m", "repro.parallel.multihost",
         "--n-se", "400", "--steps", "3"] + extra,
        capture_output=True, text=True, timeout=timeout, env=env)


def test_multihost_single_process_smoke():
    """--processes 1 runs the full launcher path (config, spec, scan,
    counters) on the local devices; the sparse exchange must report
    traffic at D=4."""
    r = _multihost(["--processes", "1", "--local-devices", "4"])
    assert r.returncode == 0, r.stdout + r.stderr
    line = next(l for l in r.stdout.splitlines() if l.startswith("RESULT "))
    out = json.loads(line[len("RESULT "):])
    assert out["devices"] == 4
    assert out["bytes_on_wire"] > 0
    assert out["shard_overflow"] == 0.0


@pytest.mark.slow
def test_multihost_spawn_two_processes():
    """2-rank spawn on one machine: either the backend supports
    cross-process collectives and the run completes, or the launcher's
    probe must exit with the dedicated code and a clear message —
    never a hang or a mid-scan crash (current CPU jaxlib takes the
    latter path)."""
    r = _multihost(["--spawn", "--processes", "2", "--local-devices", "2",
                    "--coordinator", "127.0.0.1:9931"])
    if r.returncode == 0:
        assert any(l.startswith("RESULT ") for l in r.stdout.splitlines())
    else:
        assert r.returncode == 3, r.stdout + r.stderr
        assert "cannot run cross-process computations" in (
            r.stdout + r.stderr)

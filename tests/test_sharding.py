"""Sharded-execution equivalence (parallel/lp_shard.py tentpole contract).

`sharding="lp_device"` must be *bit-identical* to the single-device
oracle on the same seed: positions, interaction accounting, LCR,
migration sequence, heuristic windows — the §4.2 transparency invariant
extended to the execution layer. conftest forces 4 host-platform
devices, so 1/2/4-device meshes run in-process.
"""
import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig
from repro.parallel import lp_shard

ABM = ABMConfig(n_se=96, n_lp=4, area=1000.0, speed=5.0,
                interaction_range=80.0, p_interact=0.3)
SYM = EngineConfig(abm=ABM, heuristic=HeuristicConfig(mf=1.2, mt=5),
                   gaia_on=True, timesteps=24)
ASYM = EngineConfig(abm=ABM, heuristic=HeuristicConfig(mf=0.8, mt=2),
                    gaia_on=True, balance="asymmetric",
                    capacity=(0.4, 0.3, 0.2, 0.1), timesteps=24)

STATE_KEYS = ("pos", "waypoint", "mob", "mob_g", "lp", "pending_dst",
              "pending_eta", "ring", "ptr", "since_eval", "last_mig")
SERIES_KEYS = ("local_msgs", "remote_msgs", "migrations", "heu_evals", "lcr",
               "lp_flows", "mig_flows")


@functools.lru_cache(maxsize=None)
def _run(cfg: EngineConfig, seed=7):
    return run(jax.random.key(seed), cfg)


def _assert_equivalent(cfg, n_devices):
    st0, s0, c0 = _run(cfg)
    st1, s1, c1 = _run(dataclasses.replace(cfg, sharding="lp_device",
                                           n_devices=n_devices))
    assert c1["shard_overflow"] == 0.0
    for k in STATE_KEYS:
        np.testing.assert_array_equal(np.asarray(st0[k]), np.asarray(st1[k]),
                                      err_msg=k)
    # per-step series equality pins the whole trajectory, including the
    # migration sequence (admissions per step + final lp/last_mig above)
    for k in SERIES_KEYS:
        np.testing.assert_array_equal(np.asarray(s0[k]), np.asarray(s1[k]),
                                      err_msg=k)
    assert c0["mean_lcr"] == c1["mean_lcr"]
    assert c1["migrations"] > 0  # both non-trivial runs


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_symmetric_equivalence(n_devices):
    _assert_equivalent(SYM, n_devices)


@pytest.mark.parametrize("n_devices", [2, 4])
def test_asymmetric_equivalence(n_devices):
    _assert_equivalent(ASYM, n_devices)


@pytest.mark.parametrize("n_devices", [1, 2, 4])
@pytest.mark.parametrize("mobility", ["hotspot", "group", "flock"])
def test_mobility_scenario_equivalence(mobility, n_devices):
    """The tentpole contract extended to the non-uniform mobility
    models: per-SE mobility state (`mob`) reshards with the SE, the
    replicated global rows (`mob_g`) advance identically everywhere,
    and the whole trajectory stays byte-identical to the oracle."""
    cfg = dataclasses.replace(
        SYM, abm=dataclasses.replace(ABM, mobility=mobility, n_groups=4,
                                     group_radius=120.0),
        timesteps=20)
    _assert_equivalent(cfg, n_devices)


def test_dense_backend_equivalence():
    cfg = dataclasses.replace(
        SYM, abm=dataclasses.replace(ABM, proximity_backend="dense"),
        timesteps=20)
    _assert_equivalent(cfg, 4)


def test_event_window_heuristic_equivalence():
    """#2's per-SE ring pointers must travel with migrating SEs."""
    cfg = dataclasses.replace(
        SYM, heuristic=HeuristicConfig(kind=2, mf=1.2, mt=5, omega=8),
        timesteps=20)
    _assert_equivalent(cfg, 4)


def test_halo_shrinks_as_gaia_clusters():
    """The physically-real communication story: GAIA's migrations make
    each shard's LPs spatially coherent, so the halo (remote agents a
    shard actually needs) shrinks relative to the static partitioning."""
    _, s_on, c_on = _run(dataclasses.replace(SYM, sharding="lp_device",
                                             n_devices=4, timesteps=48))
    _, s_off, c_off = _run(dataclasses.replace(SYM, sharding="lp_device",
                                               n_devices=4, timesteps=48,
                                               gaia_on=False))
    late_on = float(np.asarray(s_on["halo_frac"])[-8:].mean())
    late_off = float(np.asarray(s_off["halo_frac"])[-8:].mean())
    assert late_on < late_off - 0.05, (late_on, late_off)


def test_overflow_defers_instead_of_destroying_ses():
    """A migration burst past mig_capacity (or past the destination's
    free slots) must defer the move to a later step, never delete the
    SE: the population stays n_se every step even while the
    shard_overflow alarm fires (the alarm still marks divergence from
    the capacity-free oracle)."""
    cfg = dataclasses.replace(
        SYM, heuristic=HeuristicConfig(mf=0.5, mt=0), timesteps=25,
        sharding="lp_device", n_devices=4, mig_capacity=1)
    _, series, c = _run(cfg)
    assert c["shard_overflow"] > 0  # the burst really overflowed
    # heu_evals counts valid SEs across shards each step: pop intact
    np.testing.assert_array_equal(np.asarray(series["heu_evals"]),
                                  np.full(cfg.timesteps, ABM.n_se, np.float32))


def test_shard_spec_validation():
    spec = lp_shard.make_shard_spec(
        dataclasses.replace(SYM, sharding="lp_device", n_devices=4))
    assert spec.n_dev == 4 and spec.n_dev * spec.cap >= ABM.n_se
    # more devices than visible -> error
    with pytest.raises(ValueError):
        lp_shard.make_shard_spec(
            dataclasses.replace(SYM, sharding="lp_device", n_devices=64))
    # pallas proximity backends are single-device kernels
    with pytest.raises(NotImplementedError):
        lp_shard.make_shard_spec(dataclasses.replace(
            SYM, sharding="lp_device",
            abm=dataclasses.replace(ABM, proximity_backend="pallas")))
    with pytest.raises(ValueError):
        dataclasses.replace(SYM, sharding="rowwise")


def test_budgeted_shard_buffers():
    """mem_budget_mb sizes the halo/migration slot buffers instead of
    the worst case. At real scale (spec arithmetic only — no arrays) a
    modest budget must shrink both buffers below the capacity bound,
    stay above the usefulness floors, and grow monotonically with the
    budget; an explicit halo/mig capacity always wins over the budget."""
    big = dataclasses.replace(
        SYM, abm=dataclasses.replace(ABM, n_se=2_000_000, n_lp=8,
                                     area=100_000.0, grid_capacity=64),
        sharding="lp_device", n_devices=4)
    free = lp_shard.make_shard_spec(big)
    assert free.halo_cap == free.cap  # unbudgeted worst case
    tight = lp_shard.make_shard_spec(dataclasses.replace(
        big, mem_budget_mb=8))
    assert 32 <= tight.halo_cap < free.halo_cap
    assert 16 <= tight.mig_cap < free.mig_cap
    assert tight.cap == free.cap  # slot capacity is not the budget's job
    roomy = lp_shard.make_shard_spec(dataclasses.replace(
        big, mem_budget_mb=64))
    assert tight.halo_cap < roomy.halo_cap <= free.halo_cap
    assert tight.mig_cap < roomy.mig_cap <= free.mig_cap
    explicit = lp_shard.make_shard_spec(dataclasses.replace(
        big, mem_budget_mb=8, halo_capacity=777, mig_capacity=555))
    assert explicit.halo_cap == 777 and explicit.mig_cap == 555


def test_generous_budget_sharded_bit_identical():
    """A budget roomy enough not to clamp any buffer must leave the
    sharded trajectory bit-identical to the budget-free oracle — the
    knob trades memory for overflow risk, never simulation content."""
    budgeted = dataclasses.replace(SYM, mem_budget_mb=256)
    spec0 = lp_shard.make_shard_spec(
        dataclasses.replace(SYM, sharding="lp_device", n_devices=4))
    spec1 = lp_shard.make_shard_spec(
        dataclasses.replace(budgeted, sharding="lp_device", n_devices=4))
    assert spec0 == spec1  # 256 MB is roomy at n=96: nothing clamps
    st0, s0, c0 = _run(SYM)
    st1, s1, c1 = _run(dataclasses.replace(budgeted, sharding="lp_device",
                                           n_devices=4))
    assert c1["shard_overflow"] == 0.0
    for k in STATE_KEYS:
        np.testing.assert_array_equal(np.asarray(st0[k]), np.asarray(st1[k]),
                                      err_msg=k)
    for k in SERIES_KEYS:
        np.testing.assert_array_equal(np.asarray(s0[k]), np.asarray(s1[k]),
                                      err_msg=k)


def test_selftune_runs_sharded():
    """run_window dispatches on cfg.sharding: the §5.5 intra-run tuner
    drives the sharded engine transparently."""
    from repro.core.engine import init_engine, run_window
    cfg = dataclasses.replace(SYM, sharding="lp_device", n_devices=2,
                              timesteps=10)
    st = init_engine(jax.random.key(1), cfg)
    st, counters = run_window(st, cfg, 10)
    assert counters["shard_overflow"] == 0.0
    assert counters["local_msgs"] + counters["remote_msgs"] > 0

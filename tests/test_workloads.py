"""Workload families: epidemic diffusion (SI/SIS) + trace replay.

Three layers of the scenario-fleet contract:

  * pure-function properties of the epidemic kernel on randomized
    layouts (flags stay binary, exposure is monotone, zero exposure
    never transitions, grid == dense bit-identically);
  * oracle engine dynamics (SI monotone growth, SIS recovery, the
    infected series matching the state flags exactly);
  * the §4.2 transparency invariant extended to both workloads:
    `sharding="lp_device"` stays *byte-identical* to the single-device
    oracle at 1/2/4 devices — the epi flag reshards with its row, the
    trace frame counter advances identically everywhere — including
    through a mid-run informed repartition (voronoi, warm-started
    seeds), the hardest resharding event the engine has.

Randomized-strategy variants of the kernel properties live in
tests/test_workloads_props.py (hypothesis, optional dev dep).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abm import (ABMConfig, epidemic_draws,
                            epidemic_exposure_overflow, epidemic_init,
                            epidemic_row_update, epidemic_send_prob)
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig
from repro.data import pipeline as dpipe

TRACE_NAME = "test-workloads"
dpipe.register_trace(TRACE_NAME, dpipe.synthetic_trace(
    dpipe.TraceSpec(n_se=96, area=1000.0, timesteps=40, speed=8.0,
                    n_hubs=4, seed=3)))

SI = ABMConfig(n_se=96, n_lp=4, area=1000.0, speed=5.0,
               interaction_range=80.0, p_interact=0.3,
               workload="epidemic", epi_beta=0.4, epi_boost=4.0,
               epi_seed_frac=0.05)
SIS = dataclasses.replace(SI, epi_gamma=0.15)
TRACE = dataclasses.replace(
    SI, workload="none", mobility="trace", trace_name=TRACE_NAME,
    trace_policy="exact")


def _cfg(abm, ts=24, **kw):
    return EngineConfig(abm=abm, heuristic=HeuristicConfig(mf=1.2, mt=5),
                        gaia_on=True, timesteps=ts, **kw)


@functools.lru_cache(maxsize=None)
def _run(cfg, seed=7):
    return run(jax.random.key(seed), cfg)


# ---------------------------------------------------------------------------
# Epidemic kernel properties (randomized layouts, fixed seeds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_epidemic_init_seeds_a_patch(seed):
    """Exactly k = max(1, round(frac*n)) flags, and they form a spatial
    patch: every seeded SE is nearer the patch center than every
    unseeded one (that is what 'k nearest to one origin' means)."""
    n = 200
    pos = jax.random.uniform(jax.random.key(seed), (n, 2), maxval=SI.area)
    epi = np.asarray(epidemic_init(jax.random.key(seed + 10), pos, SI))
    k = max(1, round(SI.epi_seed_frac * n))
    assert epi.sum() == k and set(np.unique(epi)) <= {0, 1}
    # patch property via the centroid surrogate: max distance of an
    # infected SE to the infected centroid < distance of the nearest
    # susceptible-excluded ring is not guaranteed on the torus, so
    # assert the direct definition instead: recompute the threshold
    p = np.asarray(pos)
    inf = p[epi == 1]
    assert inf.shape[0] == k


@pytest.mark.parametrize("seed", [0, 3])
def test_row_update_zero_exposure_is_identity(seed):
    """Dead/padded rows carry exposure 0 by construction — they must
    never transition (SI; with SIS only recovery may act)."""
    n = 64
    epi = (jax.random.uniform(jax.random.key(seed), (n,)) < 0.3) \
        .astype(jnp.int32)
    draws = epidemic_draws(jax.random.key(seed + 1), n, SI)
    out = epidemic_row_update(epi, jnp.zeros((n,), jnp.int32), draws, SI)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(epi))


@pytest.mark.parametrize("seed", [0, 1, 4])
def test_row_update_monotone_in_exposure(seed):
    """With the same draws, more in-range infectious senders can only
    grow the set of new infections (p = 1-(1-beta)^e is monotone)."""
    n = 64
    k = jax.random.key(seed)
    epi = jnp.zeros((n,), jnp.int32)
    e1 = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, 4)
    e2 = e1 + jax.random.randint(jax.random.fold_in(k, 2), (n,), 0, 3)
    draws = epidemic_draws(jax.random.fold_in(k, 3), n, SI)
    o1 = np.asarray(epidemic_row_update(epi, e1, draws, SI))
    o2 = np.asarray(epidemic_row_update(epi, e2, draws, SI))
    assert ((o1 == 1) <= (o2 == 1)).all()  # catching set is monotone


@pytest.mark.parametrize("seed", [0, 2])
def test_exposure_grid_matches_dense(seed):
    """The 2-class candidate walk is bit-identical across proximity
    backends, dead rows (-1 labels, valid mask) excluded from both."""
    n = 160
    k = jax.random.key(seed)
    pos = jax.random.uniform(k, (n, 2), maxval=SI.area)
    valid = jax.random.uniform(jax.random.fold_in(k, 1), (n,)) < 0.9
    infectious = (jax.random.uniform(jax.random.fold_in(k, 2), (n,)) < 0.2)
    labels = jnp.where(valid, infectious.astype(jnp.int32), -1)
    qmask = valid & (labels == 0)
    grid_cfg = SI
    dense_cfg = dataclasses.replace(SI, proximity_backend="dense")
    assert grid_cfg.grid_spec() is not None  # actually two backends
    eg, _ = epidemic_exposure_overflow(pos, labels, qmask, grid_cfg,
                                       valid=valid)
    ed, _ = epidemic_exposure_overflow(pos, labels, qmask, dense_cfg,
                                       valid=valid)
    np.testing.assert_array_equal(np.asarray(eg), np.asarray(ed))
    assert np.asarray(eg)[~np.asarray(qmask)].sum() == 0


def test_send_prob_bounds_and_targets():
    epi = jnp.asarray([0, 1, 0, 1], jnp.int32)
    p = np.asarray(epidemic_send_prob(epi, SI))
    assert p[0] == p[2] == SI.p_interact
    assert p[1] == p[3] == min(1.0, SI.p_interact * SI.epi_boost)
    hot = dataclasses.replace(SI, epi_boost=100.0)
    assert np.asarray(epidemic_send_prob(epi, hot)).max() == 1.0


# ---------------------------------------------------------------------------
# Oracle dynamics
# ---------------------------------------------------------------------------


def test_si_monotone_growth():
    """SI has no recovery: the infected series never decreases, starts
    at the seeded patch size, and the final count matches the flags."""
    st, series, c = _run(_cfg(SI))
    inf = np.asarray(series["infected"])
    assert (np.diff(inf) >= 0).all()
    assert inf[0] >= max(1, round(SI.epi_seed_frac * SI.n_se))
    assert inf[-1] <= SI.n_se
    assert float((np.asarray(st["epi"]) > 0).sum()) == inf[-1] == \
        c["final_infected"]
    assert inf[-1] > inf[0]  # the wave actually traveled


def test_sis_recovers_and_stays_binary():
    """SIS conservation: flags stay in {0, 1} and every SE is always in
    exactly one compartment (S + I = N); recovery must both be visible
    step-to-step and cap the epidemic below the SI endpoint."""
    st, series, _ = _run(_cfg(SIS))
    st_si, series_si, _ = _run(_cfg(SI))
    epi = np.asarray(st["epi"])
    assert set(np.unique(epi)) <= {0, 1}
    inf = np.asarray(series["infected"])
    assert ((inf >= 0) & (inf <= SIS.n_se)).all()  # S+I=N, both >= 0
    assert (np.diff(inf) < 0).any()  # recovery visibly fired
    assert inf[-1] <= np.asarray(series_si["infected"])[-1]


# ---------------------------------------------------------------------------
# Oracle <-> sharded byte-identity (the fleet's D axis, at unit scale)
# ---------------------------------------------------------------------------

STATE_KEYS = ("pos", "waypoint", "mob", "mob_g", "lp", "pending_dst",
              "pending_eta", "ring", "ptr", "since_eval", "last_mig", "epi")
SERIES_KEYS = ("local_msgs", "remote_msgs", "migrations", "heu_evals",
               "lcr", "lp_flows", "mig_flows")

#: SIS under a mid-run informed repartition: voronoi (warm-started
#: seeds via the prev map) every 10 steps reshards every row while the
#: wave is in flight — epi flags must ride the resharding byte-exactly
REPART = dict(repartition_every=10)


def _assert_equivalent(cfg, n_devices):
    st0, s0, c0 = _run(cfg)
    st1, s1, c1 = _run(dataclasses.replace(cfg, sharding="lp_device",
                                           n_devices=n_devices))
    assert c1["shard_overflow"] == 0.0
    for k in STATE_KEYS:
        if k not in st0:
            continue
        np.testing.assert_array_equal(np.asarray(st0[k]),
                                      np.asarray(st1[k]), err_msg=k)
    for k in SERIES_KEYS + (("infected",) if "infected" in s0 else ()):
        np.testing.assert_array_equal(np.asarray(s0[k]),
                                      np.asarray(s1[k]), err_msg=k)
    assert c0["mean_lcr"] == c1["mean_lcr"]


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_epidemic_si_equivalence(n_devices):
    _assert_equivalent(_cfg(SI), n_devices)
    _, series, _ = _run(_cfg(SI))
    assert np.asarray(series["infected"])[-1] > \
        np.asarray(series["infected"])[0]  # non-trivial dynamics


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_epidemic_sis_repartition_equivalence(n_devices):
    cfg = _cfg(dataclasses.replace(SIS, partitioner="voronoi"), **REPART)
    _assert_equivalent(cfg, n_devices)
    _, series, _ = _run(cfg)
    assert np.asarray(series["repartitions"]).sum() > 0


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_trace_equivalence(n_devices):
    _assert_equivalent(_cfg(TRACE), n_devices)


@pytest.mark.parametrize("n_devices", [2, 4])
def test_trace_plus_epidemic_equivalence(n_devices):
    """The combined cell: replayed mobility driving the diffusion. The
    trace replay pins positions, so any epi divergence would be purely
    a resharding bug — the sharpest version of the invariant."""
    cfg = _cfg(dataclasses.replace(
        TRACE, workload="epidemic", epi_beta=0.4, epi_boost=4.0,
        epi_seed_frac=0.05))
    _assert_equivalent(cfg, n_devices)


def test_trace_replay_matches_frames():
    """After t steps the engine sits exactly on frame t (step k replays
    frame k+1) — replay is bit-equal to the registered stack."""
    frames = dpipe.get_trace(TRACE_NAME).frames
    for ts in (1, 5, 24):
        st, _, _ = _run(_cfg(TRACE, ts=ts))
        np.testing.assert_array_equal(np.asarray(st["pos"]), frames[ts])

"""Load-balancing constraint tests (paper §4.4) — includes hypothesis
property tests of the core invariants:

  symmetric: migrations never change any LP's SE count;
  quota: admitted migrations per (src, dst) never exceed the grant;
  asymmetric: grants drain SEs toward the capacity profile, never past it.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional dev dependency "
    "`hypothesis` (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core import balance as bal

settings.register_profile("ci", deadline=None, max_examples=40)
settings.load_profile("ci")


def _random_case(draw, n_se_max=60, n_lp_max=5):
    n_lp = draw(st.integers(2, n_lp_max))
    n_se = draw(st.integers(n_lp, n_se_max))
    lp = draw(st.lists(st.integers(0, n_lp - 1), min_size=n_se,
                       max_size=n_se))
    dest = draw(st.lists(st.integers(0, n_lp - 1), min_size=n_se,
                         max_size=n_se))
    cand = draw(st.lists(st.booleans(), min_size=n_se, max_size=n_se))
    alpha = draw(st.lists(st.floats(0.0, 100.0, allow_nan=False),
                          min_size=n_se, max_size=n_se))
    lp = jnp.asarray(lp, jnp.int32)
    dest = jnp.asarray(dest, jnp.int32)
    cand = jnp.asarray(cand) & (dest != lp)  # a migration must move
    return n_lp, lp, dest, cand, jnp.asarray(alpha, jnp.float32)


case = st.builds(lambda d: d, st.data())


@given(st.data())
def test_symmetric_preserves_lp_counts(data):
    n_lp, lp, dest, cand, alpha = _random_case(data.draw)
    cmat = bal.candidate_matrix(cand, lp, dest, n_lp)
    grants = bal.symmetric_grants(cmat)
    admit = bal.select_migrations(cand, lp, dest, alpha, grants, n_lp)
    new_lp = jnp.where(admit, dest, lp)
    before = np.bincount(np.asarray(lp), minlength=n_lp)
    after = np.bincount(np.asarray(new_lp), minlength=n_lp)
    np.testing.assert_array_equal(before, after)


@given(st.data())
def test_admissions_respect_grants_and_candidacy(data):
    n_lp, lp, dest, cand, alpha = _random_case(data.draw)
    cmat = bal.candidate_matrix(cand, lp, dest, n_lp)
    grants = bal.symmetric_grants(cmat)
    admit = np.asarray(
        bal.select_migrations(cand, lp, dest, alpha, grants, n_lp))
    assert not np.any(admit & ~np.asarray(cand))
    # per-(src,dst) admitted count <= grant
    g = np.asarray(grants)
    for s in range(n_lp):
        for d in range(n_lp):
            m = admit & (np.asarray(lp) == s) & (np.asarray(dest) == d)
            assert m.sum() <= g[s, d]


@given(st.data())
def test_candidate_matrix_counts(data):
    n_lp, lp, dest, cand, alpha = _random_case(data.draw)
    cmat = np.asarray(bal.candidate_matrix(cand, lp, dest, n_lp))
    for s in range(n_lp):
        for d in range(n_lp):
            want = int(np.sum(np.asarray(cand) & (np.asarray(lp) == s)
                              & (np.asarray(dest) == d)))
            assert cmat[s, d] == want


def test_symmetric_grants_are_pairwise_min():
    cand = jnp.array([[0, 5, 1], [3, 0, 0], [2, 4, 0]], jnp.int32)
    g = np.asarray(bal.symmetric_grants(cand))
    assert g[0, 1] == 3 and g[1, 0] == 3
    assert g[0, 2] == 1 and g[2, 0] == 1
    assert g[1, 2] == 0 and g[2, 1] == 0
    assert np.all(np.diag(g) == 0)


def test_select_prefers_higher_alpha():
    # 3 candidates LP0->LP1 but only 1 reverse candidate: quota 1 each way.
    lp = jnp.array([0, 0, 0, 1], jnp.int32)
    dest = jnp.array([1, 1, 1, 0], jnp.int32)
    cand = jnp.array([True, True, True, True])
    alpha = jnp.array([1.5, 9.0, 2.5, 3.0], jnp.float32)
    cmat = bal.candidate_matrix(cand, lp, dest, 2)
    grants = bal.symmetric_grants(cmat)
    admit = np.asarray(bal.select_migrations(cand, lp, dest, alpha, grants, 2))
    np.testing.assert_array_equal(admit, [False, True, False, True])


@given(st.data())
def test_asymmetric_never_overshoots_targets(data):
    n_lp, lp, dest, cand, alpha = _random_case(data.draw)
    current = jnp.bincount(lp, length=n_lp)
    capacity = jnp.ones((n_lp,), jnp.float32) / n_lp
    cmat = bal.candidate_matrix(cand, lp, dest, n_lp)
    grants = bal.asymmetric_grants(cmat, current, capacity)
    admit = bal.select_migrations(cand, lp, dest, alpha, grants, n_lp)
    new_lp = jnp.where(admit, dest, lp)
    total = int(current.sum())
    target = np.round(np.asarray(capacity) * total).astype(int)
    before = np.asarray(current)
    after = np.bincount(np.asarray(new_lp), minlength=n_lp)
    # sources above target may only shed down to (at worst) their target;
    # never *below* target - shed (the symmetric core keeps pairs even).
    for l in range(n_lp):
        if before[l] > target[l]:
            assert after[l] >= target[l] - 0  # drain is capped by surplus
        # destinations below target must not be pushed above it by the
        # extra one-way grants (pairwise swaps keep counts even).
        if before[l] < target[l]:
            assert after[l] <= target[l]


def test_asymmetric_drains_toward_capacity():
    """A 2-LP system with all SEs on LP0 and capacity 50/50: one-way
    grants must move SEs to LP1 even with no reverse candidates."""
    n = 20
    lp = jnp.zeros((n,), jnp.int32)
    dest = jnp.ones((n,), jnp.int32)
    cand = jnp.ones((n,), bool)
    alpha = jnp.arange(n, dtype=jnp.float32)
    current = jnp.bincount(lp, length=2)
    cap = jnp.array([0.5, 0.5], jnp.float32)
    cmat = bal.candidate_matrix(cand, lp, dest, 2)
    grants = bal.asymmetric_grants(cmat, current, cap)
    admit = bal.select_migrations(cand, lp, dest, alpha, grants, 2)
    moved = int(admit.sum())
    assert 0 < moved <= 10  # drains toward the 10/10 target, never past

"""MoE layer semantics vs. a naive per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models.moe import _capacity, init_moe, moe_fwd
from repro.parallel.ctx import make_ctx

PX = make_ctx(None)


def _naive_moe(p, x, m):
    """Per-token dense evaluation of the same routing (no capacity)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D).astype(jnp.float32)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = jnp.take_along_axis(probs, top_e, axis=-1)
    if m.norm_topk_prob:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(xt)
    wg = p["w_gate"].astype(jnp.float32)
    wu = p["w_up"].astype(jnp.float32)
    wd = p["w_down"].astype(jnp.float32)
    for kslot in range(m.top_k):
        e = top_e[:, kslot]
        w = top_p[:, kslot]
        g = jnp.einsum("td,tdf->tf", xt, wg[e])
        u = jnp.einsum("td,tdf->tf", xt, wu[e])
        h = jax.nn.silu(g) * u
        y = jnp.einsum("tf,tfd->td", h, wd[e])
        out = out + w[:, None] * y
    if "shared" in p:
        g = xt @ p["shared"]["w_gate"].astype(jnp.float32)
        u = xt @ p["shared"]["w_up"].astype(jnp.float32)
        out = out + (jax.nn.silu(g) * u) @ p["shared"]["w_down"].astype(
            jnp.float32)
    return out.reshape(B, S, D)


@pytest.mark.parametrize("shared", [0, 1])
def test_moe_matches_naive_when_capacity_ample(shared):
    m = MoEConfig(num_experts=8, top_k=2, d_expert=16, capacity_factor=8.0,
                  num_shared_experts=shared, d_shared=16 if shared else 0)
    key = jax.random.key(0)
    p = init_moe(key, 12, m)
    x = (jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 12))
         * 0.5).astype(jnp.bfloat16)
    got, metrics = moe_fwd(p, x, m=m, px=PX, batch_entry=None)
    assert int(metrics["moe_dropped"]) == 0
    want = _naive_moe(p, x, m)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=0.06, rtol=0.08)


@pytest.mark.slow
def test_capacity_drops_overflow_tokens():
    m = MoEConfig(num_experts=4, top_k=1, d_expert=8, capacity_factor=0.25)
    key = jax.random.key(2)
    p = init_moe(key, 8, m)
    # selection bias forces every token onto expert 0 (combine weights
    # still from the unbiased probs — nonzero)
    bias = jnp.array([100.0, 0.0, 0.0, 0.0], jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 8),
                          jnp.bfloat16)
    out, metrics = moe_fwd(p, x, m=m, px=PX, batch_entry=None,
                           router_bias=bias)
    assert int(metrics["moe_dropped"]) > 0
    # dropped tokens contribute zero from routed experts
    C = max(2 * m.top_k, _capacity(64, m))
    kept = np.asarray(out, np.float32)
    n_zero_rows = int((np.abs(kept.reshape(-1, 8)).sum(-1) < 1e-6).sum())
    assert n_zero_rows == 64 - C


def test_router_bias_changes_selection_not_weights():
    """Aux-free bias shifts WHICH experts are picked, but the combine
    weights still come from the unbiased probabilities (DeepSeek-V3)."""
    m = MoEConfig(num_experts=4, top_k=1, d_expert=8, capacity_factor=4.0,
                  norm_topk_prob=False)
    key = jax.random.key(3)
    p = init_moe(key, 8, m)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 32, 8),
                          jnp.bfloat16)
    bias = jnp.array([0.0, 0.0, 0.0, 10.0], jnp.float32)
    _, m0 = moe_fwd(p, x, m=m, px=PX, batch_entry=None)
    _, m1 = moe_fwd(p, x, m=m, px=PX, batch_entry=None, router_bias=bias)
    c0 = np.asarray(m0["expert_counts"])
    c1 = np.asarray(m1["expert_counts"])
    assert c1[3] == 32  # bias forces expert 3 for everyone
    assert c0[3] < 32


def test_expert_counts_and_group_counts_consistent():
    m = MoEConfig(num_experts=8, top_k=2, d_expert=8, capacity_factor=4.0)
    key = jax.random.key(4)
    p = init_moe(key, 8, m)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 8),
                          jnp.bfloat16)
    _, met = moe_fwd(p, x, m=m, px=PX, batch_entry=None)
    assert int(met["expert_counts"].sum()) == 4 * 8 * m.top_k
    np.testing.assert_array_equal(
        np.asarray(met["group_expert_counts"].sum(0)),
        np.asarray(met["expert_counts"]))


def test_aux_loss_penalizes_imbalance():
    m = MoEConfig(num_experts=4, top_k=1, d_expert=8, capacity_factor=4.0)
    key = jax.random.key(5)
    p = init_moe(key, 8, m)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 8),
                          jnp.bfloat16)
    _, balanced = moe_fwd(p, x, m=m, px=PX, batch_entry=None)
    p_skew = dict(p, router=jnp.zeros((8, 4), jnp.float32).at[:, 0].set(5.0))
    _, skewed = moe_fwd(p_skew, x, m=m, px=PX, batch_entry=None)
    assert float(skewed["moe_aux_loss"]) > float(balanced["moe_aux_loss"])

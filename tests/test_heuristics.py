"""Unit tests for the self-clustering heuristics (paper §4.3).

Hand-stepped traces verify the window semantics of #1/#2/#3 and the
MF/MT gating exactly as specified.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.heuristics import HeuristicConfig, init_state, update_window, evaluate


def _push(cfg, st, counts, senders, t):
    return update_window(cfg, st, jnp.asarray(counts, jnp.int32),
                         jnp.asarray(senders, bool), t)


def test_h1_candidate_when_external_dominates():
    # 2 SEs, 2 LPs. SE0 on LP0 talks mostly to LP1 -> candidate.
    cfg = HeuristicConfig(kind=1, mf=1.5, mt=0, kappa=4)
    st = init_state(cfg, n_se=2, n_lp=2)
    lp = jnp.array([0, 0], jnp.int32)
    for t in range(4):
        st = _push(cfg, st, [[1, 4], [3, 1]], [True, True], t)
    cand, dest, alpha, st, n_evals = evaluate(cfg, st, lp, 4)
    np.testing.assert_array_equal(np.asarray(cand), [True, False])
    assert int(dest[0]) == 1
    # alpha = eps/iota = 16/4 for SE0; 4/12 for SE1
    np.testing.assert_allclose(np.asarray(alpha), [4.0, 4 / 12], rtol=1e-6)
    assert int(n_evals) == 2


def test_h1_window_expires_old_events():
    """#1's window covers the last kappa timesteps only."""
    cfg = HeuristicConfig(kind=1, mf=1.0, mt=0, kappa=2)
    st = init_state(cfg, n_se=1, n_lp=2)
    lp = jnp.array([0], jnp.int32)
    st = _push(cfg, st, [[0, 9]], [True], 0)  # heavy remote burst
    # two silent steps: the burst leaves the 2-step window
    st = _push(cfg, st, [[0, 0]], [True], 1)
    st = _push(cfg, st, [[0, 0]], [True], 2)
    cand, _, _, _, _ = evaluate(cfg, st, lp, 3)
    assert not bool(cand[0])


def test_h2_event_window_keeps_old_events_for_rare_senders():
    """#2 retains the last omega *sending events* regardless of age —
    the paper's stated difference from #1."""
    cfg1 = HeuristicConfig(kind=1, mf=1.0, mt=0, kappa=2)
    cfg2 = HeuristicConfig(kind=2, mf=1.0, mt=0, omega=2)
    st1 = init_state(cfg1, 1, 2)
    st2 = init_state(cfg2, 1, 2)
    lp = jnp.array([0], jnp.int32)
    st1 = _push(cfg1, st1, [[0, 5]], [True], 0)
    st2 = _push(cfg2, st2, [[0, 5]], [True], 0)
    for t in range(1, 6):  # five idle timesteps (not senders)
        st1 = _push(cfg1, st1, [[0, 0]], [False], t)
        st2 = _push(cfg2, st2, [[0, 0]], [False], t)
    c1, *_ = evaluate(cfg1, st1, lp, 6)
    c2, *_ = evaluate(cfg2, st2, lp, 6)
    assert not bool(c1[0])  # timestep window forgot the burst...
    assert bool(c2[0])  # ...the event window did not


def test_h2_ring_overwrites_oldest():
    cfg = HeuristicConfig(kind=2, mf=0.5, mt=0, omega=2)
    st = init_state(cfg, 1, 2)
    lp = jnp.array([0], jnp.int32)
    st = _push(cfg, st, [[0, 8]], [True], 0)
    st = _push(cfg, st, [[4, 0]], [True], 1)
    st = _push(cfg, st, [[4, 0]], [True], 2)  # evicts the remote burst
    cand, _, alpha, _, _ = evaluate(cfg, st, lp, 3)
    assert not bool(cand[0])
    assert float(alpha[0]) == 0.0


def test_h3_evaluates_only_after_zeta_interactions():
    cfg = HeuristicConfig(kind=3, mf=1.0, mt=0, omega=4, zeta=6)
    st = init_state(cfg, 1, 2)
    lp = jnp.array([0], jnp.int32)
    st = _push(cfg, st, [[0, 4]], [True], 0)  # 4 interactions < zeta
    cand, _, _, st, n = evaluate(cfg, st, lp, 1)
    assert int(n) == 0 and not bool(cand[0])
    st = _push(cfg, st, [[0, 4]], [True], 1)  # cumulative 8 >= zeta
    cand, _, _, st, n = evaluate(cfg, st, lp, 2)
    assert int(n) == 1 and bool(cand[0])
    # counter reset after the evaluation
    cand, _, _, st, n = evaluate(cfg, st, lp, 3)
    assert int(n) == 0


def test_mt_blocks_recent_migrants():
    cfg = HeuristicConfig(kind=1, mf=1.0, mt=10, kappa=2)
    st = init_state(cfg, 1, 2)
    st["last_mig"] = jnp.array([5], jnp.int32)
    lp = jnp.array([0], jnp.int32)
    st = _push(cfg, st, [[1, 9]], [True], 6)
    cand, *_ = evaluate(cfg, st, lp, 7)  # 7 - 5 < 10
    assert not bool(cand[0])
    cand, *_ = evaluate(cfg, st, lp, 15)  # 15 - 5 >= 10
    assert bool(cand[0])


def test_mf_threshold_is_strict():
    cfg = HeuristicConfig(kind=1, mf=2.0, mt=0, kappa=1)
    st = init_state(cfg, 2, 2)
    lp = jnp.array([0, 0], jnp.int32)
    # SE0: alpha = 2.0 exactly (not > MF); SE1: alpha = 2.5
    st = _push(cfg, st, [[2, 4], [2, 5]], [True, True], 0)
    cand, *_ = evaluate(cfg, st, lp, 1)
    np.testing.assert_array_equal(np.asarray(cand), [False, True])


def test_zero_local_traffic_uses_iota_floor():
    """iota=0 must not divide by zero; any external traffic clears MF."""
    cfg = HeuristicConfig(kind=1, mf=1.5, mt=0, kappa=1)
    st = init_state(cfg, 1, 3)
    lp = jnp.array([0], jnp.int32)
    st = _push(cfg, st, [[0, 0, 2]], [True], 0)
    cand, dest, alpha, _, _ = evaluate(cfg, st, lp, 1)
    assert bool(cand[0]) and int(dest[0]) == 2
    assert np.isfinite(float(alpha[0]))

"""Runtime telemetry subsystem (repro.obs) contract tests.

Two hard invariants from DESIGN.md §Observability:

* telemetry-off is a true zero-op — an obs-disabled config shares the
  memoized compiled executable with a config that never heard of
  telemetry (cache identity, not just equal results);
* telemetry-on never perturbs the run — state and per-step series stay
  *bit-identical* with obs on vs off, on both execution layers (the
  ring rides the scan carry; the step math never reads it).

Plus the drain correctness surface: the async ring-drain ledger must
reproduce the per-step series exactly (every step filed once, correct
stamps) for any window/drain_every alignment, events must carry exact
step stamps, and the trace exporter must emit Perfetto-loadable JSON.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.core.abm import ABMConfig
from repro.core.engine import (EngineConfig, _compiled_window, run,
                               run_window, window_key_cfg)
from repro.core.heuristics import HeuristicConfig
from repro.obs import (EVENT_KINDS, JsonlSink, MemorySink, ObsConfig,
                       Telemetry, ledger_keys, prometheus_text, runtime,
                       trace_run)
from repro.core.service import Engine

ABM = ABMConfig(n_se=96, n_lp=4, area=1000.0, speed=5.0,
                interaction_range=80.0, p_interact=0.3)
BASE = EngineConfig(abm=ABM, heuristic=HeuristicConfig(mf=1.2, mt=5),
                    gaia_on=True, timesteps=24)
OBS = ObsConfig(enabled=True, drain_every=5)

STATE_KEYS = ("pos", "waypoint", "lp", "ring", "ptr", "last_mig")
SERIES_KEYS = ("lcr", "local_msgs", "remote_msgs", "migrations",
               "heu_evals")


def _obs_run(cfg, seed=7):
    """run() with a telemetry session current; returns (result, tele)."""
    tele = Telemetry(cfg)
    with runtime.use(tele):
        out = run(jax.random.key(seed), cfg)
    return out, tele


# ---------------------------------------------------------------------------
# invariant 1: telemetry-on is invisible to the simulation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [
    BASE,
    dataclasses.replace(BASE, sharding="lp_device", n_devices=2),
    dataclasses.replace(BASE, sharding="lp_device", n_devices=4),
], ids=["oracle", "lp_device-2", "lp_device-4"])
def test_bit_identity_on_vs_off(cfg):
    st0, s0, c0 = run(jax.random.key(7), cfg)
    (st1, s1, c1), tele = _obs_run(
        dataclasses.replace(cfg, obs=OBS), seed=7)
    for k in STATE_KEYS:
        np.testing.assert_array_equal(np.asarray(st0[k]),
                                      np.asarray(st1[k]), err_msg=k)
    for k in SERIES_KEYS:
        np.testing.assert_array_equal(np.asarray(s0[k]),
                                      np.asarray(s1[k]), err_msg=k)
    assert c0["mean_lcr"] == c1["mean_lcr"]
    assert len(tele.ledger) == cfg.timesteps  # and it actually observed


# ---------------------------------------------------------------------------
# invariant 2: telemetry-off is a zero-op (compiled-cache identity)
# ---------------------------------------------------------------------------

def test_disabled_obs_shares_compiled_executable():
    """A config carrying a *disabled* ObsConfig with non-default knobs
    must hit the very same memoized executable as the pristine config:
    window_key_cfg normalizes disabled obs away, so telemetry-off is
    provably not "the same program with dead branches" but the
    identical compiled object."""
    pristine = window_key_cfg(BASE)
    tweaked = window_key_cfg(dataclasses.replace(
        BASE, obs=ObsConfig(enabled=False, drain_every=3, mig_burst=50)))
    assert tweaked == pristine
    assert _compiled_window(tweaked, 8) is _compiled_window(pristine, 8)


def test_enabled_obs_compiles_apart():
    on = window_key_cfg(dataclasses.replace(BASE, obs=OBS))
    assert on != window_key_cfg(BASE)


# ---------------------------------------------------------------------------
# ledger drain correctness
# ---------------------------------------------------------------------------

def test_ledger_reproduces_series():
    """Drained rows must equal the per-step series the scan returns
    anyway — same counters, exact step stamps, one row per step."""
    cfg = dataclasses.replace(BASE, obs=OBS)
    (_, series, _), tele = _obs_run(cfg)
    led = tele.ledger
    assert tuple(led.keys) == ledger_keys(cfg)
    np.testing.assert_array_equal(led.column("step"),
                                  np.arange(cfg.timesteps, dtype=float))
    for k in ("lcr", "local_msgs", "remote_msgs", "migrations",
              "heu_evals"):
        np.testing.assert_array_equal(led.column(k),
                                      np.asarray(series[k], np.float64),
                                      err_msg=k)
    # per-LP slot load: closed world, so loads partition the population
    loads = np.stack([led.column(f"lp_load_{i}")
                      for i in range(cfg.abm.n_lp)])
    np.testing.assert_array_equal(loads.sum(axis=0),
                                  np.full(cfg.timesteps, cfg.abm.n_se))
    st = led.summary()["lcr"]
    assert st["n"] == cfg.timesteps
    # streaming mean accumulates in f64 over f32 rows; the series mean
    # reduces in f32 — equal up to f32 rounding only
    assert abs(st["mean"] - float(np.mean(series["lcr"]))) < 1e-6


def test_drain_every_is_only_batching():
    """drain_every changes *when* rows reach the host, never *what*
    rows: ledgers at drain_every=1 and =10 must be identical."""
    rows = []
    for de in (1, 10):
        cfg = dataclasses.replace(
            BASE, obs=ObsConfig(enabled=True, drain_every=de))
        _, tele = _obs_run(cfg)
        rows.append(tele.ledger.rows())
    np.testing.assert_array_equal(rows[0], rows[1])


def test_misaligned_windows_drain_exactly_once():
    """Windows whose length is not a multiple of drain_every exercise
    the tail flush and the stamp filter: stale slots from the previous
    window must not re-file, and no step may be lost or duplicated."""
    cfg = dataclasses.replace(BASE, timesteps=0,
                              obs=ObsConfig(enabled=True, drain_every=5))
    from repro.core.engine import _init_engine
    state = _init_engine(jax.random.key(7), cfg)
    tele = Telemetry(cfg)
    with runtime.use(tele):
        for _ in range(3):
            state, _ = run_window(state, cfg, 7)  # 7 % 5 != 0
    np.testing.assert_array_equal(tele.ledger.column("step"),
                                  np.arange(21, dtype=float))


def test_no_session_drops_blocks_without_error():
    cfg = dataclasses.replace(BASE, timesteps=10, obs=OBS)
    before = runtime.dropped_blocks
    run(jax.random.key(3), cfg)  # no session current
    jax.effects_barrier()
    assert runtime.dropped_blocks > before
    runtime.emit_event("tuner_move", 0, mf=1.0)  # silently ignored


# ---------------------------------------------------------------------------
# event log
# ---------------------------------------------------------------------------

def test_threshold_events_have_exact_stamps():
    cfg = dataclasses.replace(
        BASE, repartition_every=8,
        obs=ObsConfig(enabled=True, drain_every=5, mig_burst=1))
    (_, series, _), tele = _obs_run(cfg)
    migs = np.asarray(series["migrations"])
    burst_steps = [e.step for e in tele.events.records("migration_burst")]
    assert burst_steps == [t for t in range(cfg.timesteps) if migs[t] >= 1]
    repart_steps = {e.step for e in tele.events.records("repartition")}
    # repartitions fire on the configured cadence (steps t > 0 with
    # t % every == 0); every emitted stamp must sit on it
    assert repart_steps and all(t > 0 and t % 8 == 0 for t in repart_steps)


def test_unknown_event_kind_rejected():
    tele = Telemetry(dataclasses.replace(BASE, obs=OBS))
    with pytest.raises(ValueError):
        tele.emit("not_a_kind", 0)
    assert "migration_burst" in EVENT_KINDS


def test_jsonl_sink_round_trip(tmp_path):
    path = tmp_path / "events.jsonl"
    cfg = dataclasses.replace(BASE, timesteps=10,
                              obs=ObsConfig(enabled=True, drain_every=5,
                                            mig_burst=1))
    tele = Telemetry(cfg, sinks=[JsonlSink(str(path))])
    with runtime.use(tele):
        run(jax.random.key(7), cfg)
    tele.close()
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(lines) == len(tele.events.records())
    assert all(ln["kind"] in EVENT_KINDS and isinstance(ln["step"], int)
               for ln in lines)


# ---------------------------------------------------------------------------
# service surface
# ---------------------------------------------------------------------------

def test_engine_service_telemetry_and_churn_events():
    cfg = dataclasses.replace(
        BASE, timesteps=0, open_world=True, n_active=80,
        obs=ObsConfig(enabled=True, drain_every=5))
    eng = Engine(cfg, obs_sinks=[MemorySink()]).init(seed=0)
    eng.step(7)
    ids = eng.arrive({"pos": np.full((4, 2), 100.0)})
    eng.step(3)
    eng.depart(ids[:2])
    eng.step(2)
    led = eng.ledger()
    assert len(led) == 12
    pop = led.column("pop")
    assert pop[6] == 80 and pop[7] == 84 and pop[-1] == 82
    arrivals = eng.events("arrive")
    departs = eng.events("depart")
    assert [e.step for e in arrivals] == [7] and arrivals[0].data["count"] == 4
    assert [e.step for e in departs] == [10] and departs[0].data["count"] == 2
    text = eng.prometheus()
    assert "# TYPE gaia_lcr gauge" in text
    assert 'gaia_lp_load{lp="0"}' in text
    assert "gaia_population" in text and "gaia_events_total" in text
    eng.close()
    assert runtime.get_current() is not eng.telemetry


def test_engine_without_obs_has_no_telemetry_surface():
    eng = Engine(dataclasses.replace(BASE, timesteps=0)).init(seed=0)
    assert eng.telemetry is None
    with pytest.raises(RuntimeError):
        eng.ledger()
    with pytest.raises(RuntimeError):
        eng.prometheus()


def test_prometheus_text_shape():
    cfg = dataclasses.replace(BASE, timesteps=10, obs=OBS)
    _, tele = _obs_run(cfg)
    text = prometheus_text(tele, extra={"steps_total": 10})
    assert text.endswith("\n")
    assert "gaia_steps_total 10" in text
    assert "gaia_lcr_mean" in text
    for line in text.splitlines():
        assert line.startswith("# TYPE") or " " in line


# ---------------------------------------------------------------------------
# trace timelines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sharded", [False, True],
                         ids=["oracle", "lp_device"])
def test_trace_perfetto_structure(sharded):
    cfg = dataclasses.replace(BASE, timesteps=3, repartition_every=2)
    n_dev = 1
    if sharded:
        cfg = dataclasses.replace(cfg, sharding="lp_device", n_devices=2)
        n_dev = 2
    rec = trace_run(cfg, seed=0, warmup=1)
    doc = json.loads(json.dumps(rec.as_dict()))  # JSON-serializable
    evs = doc["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {e["tid"] for e in spans} == set(range(n_dev))
    assert any(e["name"] == "thread_name" for e in meta)
    names = {e["name"] for e in spans}
    assert {"migrate", "mobility", "proximity", "finalize",
            "repartition"} <= names
    assert ("halo_exchange" in names) == sharded
    assert all(e["dur"] >= 0 and "step" in e["args"] for e in spans)
    if sharded:
        assert all("n_valid" in e["args"] for e in spans
                   if e["name"] == "finalize")
    summ = rec.phase_summary()
    assert summ["mobility"]["n"] == cfg.timesteps

"""Backend parity for the proximity hot spot (tentpole contract).

Every `proximity_backend` must produce BIT-IDENTICAL counts to the dense
jnp oracle — the engine's transparency invariant (§4.2) extends to the
neighbor-search implementation: switching backends may change the speed,
never the simulation. Cases deliberately include agents straddling the
torus seam, a range larger than the grid cell side, worlds too small to
tessellate (dense fallback), and clustered (non-uniform) positions that
stress the fixed per-cell capacity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import neighbors
from repro.core.abm import (ABMConfig, PROXIMITY_BACKENDS, _dense_counts,
                            interaction_counts)
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig

BACKENDS = [b for b in PROXIMITY_BACKENDS if b != "dense"]


def _case(seed, n, n_lp, area, rng, seam=False):
    k = jax.random.key(seed)
    pos = jax.random.uniform(jax.random.fold_in(k, 0), (n, 2), maxval=area)
    if seam:
        # band of width area/10 straddling the wrap line on both axes
        pos = (pos * 0.1 - area * 0.05) % area
    lp = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, n_lp)
    sender = jax.random.bernoulli(jax.random.fold_in(k, 2), 0.4, (n,))
    return pos, lp, sender


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n,n_lp,area,rng,seam", [
    (200, 4, 1000.0, 80.0, False),
    pytest.param(300, 3, 1000.0, 60.0, True,
                 marks=pytest.mark.slow),  # seam cluster, odd N (nightly)
    pytest.param(128, 8, 500.0, 90.0, False, marks=pytest.mark.slow),
    (96, 2, 100.0, 45.0, False),  # area/rng < 3: dense fallback path
    (150, 4, 300.0, 40.0, True),  # seam + ncell >= 3
    (64, 3, 1000.0, 400.0, False),  # range > cell side forces ncell=2 -> dense
])
def test_backend_parity_bit_identical(backend, n, n_lp, area, rng, seam):
    pos, lp, sender = _case(n + int(seam), n, n_lp, area, rng, seam)
    # seam cases pile every SE into ~1% of the area: give the grid an
    # overflow-proof capacity there (auto capacity assumes ~uniform
    # density; its adequacy is what the uniform cases exercise)
    cfg = ABMConfig(n_se=n, n_lp=n_lp, area=area, interaction_range=rng,
                    grid_capacity=n if seam else 0)
    ref = _dense_counts(pos, lp, sender, cfg)
    got = interaction_counts(
        pos, lp, sender, dataclasses.replace(cfg, proximity_backend=backend))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("backend", ["grid", "pallas_grid"])
def test_parity_under_clustering_with_explicit_capacity(backend):
    """All SEs piled into one corner cell: auto capacity would overflow,
    but an explicit grid_capacity=n keeps the grid exact."""
    n, area, rng = 120, 1000.0, 100.0
    k = jax.random.key(11)
    pos = jax.random.uniform(k, (n, 2), maxval=40.0)  # one cell's worth
    lp = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, 4)
    sender = jnp.ones((n,), bool)
    cfg = ABMConfig(n_se=n, n_lp=4, area=area, interaction_range=rng)
    ref = _dense_counts(pos, lp, sender, cfg)
    got = interaction_counts(pos, lp, sender, dataclasses.replace(
        cfg, proximity_backend=backend, grid_capacity=n))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_overflow_path_on_hotspot_distribution():
    """The satellite contract for clustered inputs: on a hotspot
    distribution the *uniform* auto-capacity overflows (flag raised, and
    the resulting counts really are undercounted — the failure is not
    hypothetical), while an explicit ABMConfig.grid_capacity override
    restores exact parity with the dense oracle. The mobility-aware
    auto-capacity must also hold on its own."""
    from repro.core.abm import init_abm

    cfg = ABMConfig(n_se=300, n_lp=4, area=2000.0, interaction_range=100.0,
                    mobility="hotspot", n_groups=3, group_radius=100.0)
    st = init_abm(jax.random.key(2), cfg)
    pos, lp = st["pos"], st["lp"]
    sender = jnp.ones((cfg.n_se,), bool)

    # uniform-density capacity (what RWP would use): overflows on blobs
    uniform_spec = neighbors.make_grid_spec(cfg.n_se, cfg.area,
                                            cfg.interaction_range)
    assert bool(neighbors.build_grid(pos, uniform_spec)["overflow"])
    under = neighbors.grid_lp_counts(pos, lp, sender, cfg.n_lp, cfg.area,
                                     cfg.interaction_range, uniform_spec)
    ref = _dense_counts(pos, lp, sender, cfg)
    assert int(np.asarray(under).sum()) < int(np.asarray(ref).sum())

    # the clustered auto-capacity holds, and explicit override is exact
    assert not bool(neighbors.build_grid(pos, cfg.grid_spec())["overflow"])
    got_auto = interaction_counts(pos, lp, sender, cfg)
    np.testing.assert_array_equal(np.asarray(got_auto), np.asarray(ref))
    got = interaction_counts(pos, lp, sender,
                             dataclasses.replace(cfg, grid_capacity=cfg.n_se))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_grid_spec_geometry():
    spec = neighbors.make_grid_spec(10_000, 10_000.0, 250.0)
    assert spec.ncell == 40 and spec.cell >= 250.0
    # too small to tessellate -> None (callers go dense)
    assert neighbors.make_grid_spec(100, 100.0, 40.0) is None
    assert neighbors.make_grid_spec(100, 300.0, 150.0) is None
    # explicit capacity wins over the density heuristic
    assert neighbors.make_grid_spec(1000, 1000.0, 100.0, capacity=7).capacity == 7


def test_build_grid_overflow_flag():
    n, area = 64, 1000.0
    pos = jnp.full((n, 2), 5.0)  # everyone in cell (0, 0)
    tight = neighbors.GridSpec(ncell=10, cell=100.0, capacity=8)
    roomy = neighbors.GridSpec(ncell=10, cell=100.0, capacity=64)
    assert bool(neighbors.build_grid(pos, tight)["overflow"])
    assert not bool(neighbors.build_grid(pos, roomy)["overflow"])


def test_build_grid_layout():
    k = jax.random.key(3)
    pos = jax.random.uniform(k, (200, 2), maxval=1000.0)
    spec = neighbors.make_grid_spec(200, 1000.0, 100.0)
    g = neighbors.build_grid(pos, spec)
    counts = np.asarray(g["counts"])
    assert counts.sum() == 200
    # member table agrees with the per-cell counts and holds each SE once
    table = np.asarray(g["table"])
    members = table[table >= 0]
    assert sorted(members.tolist()) == list(range(200))
    for c in range(spec.ncell ** 2):
        assert (table[c] >= 0).sum() == counts[c]


def test_dense_chunked_matches_oracle():
    pos, lp, sender = _case(5, 230, 4, 1000.0, 120.0)
    cfg = ABMConfig(n_se=230, n_lp=4, area=1000.0, interaction_range=120.0)
    ref = _dense_counts(pos, lp, sender, cfg)
    got = neighbors.dense_lp_counts_chunked(pos, lp, sender, 4, 1000.0,
                                            120.0, chunk=64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_engine_evolution_identical_across_backends():
    """Full engine runs (scan + self-clustering) must be bit-identical
    under backend switch — speed knobs never touch the simulation."""
    results = {}
    for backend in ("dense", "grid"):
        abm = ABMConfig(n_se=120, n_lp=4, area=1000.0, speed=5.0,
                        interaction_range=80.0, p_interact=0.3,
                        proximity_backend=backend)
        cfg = EngineConfig(abm=abm, heuristic=HeuristicConfig(mf=1.2, mt=5),
                           gaia_on=True, timesteps=50)
        st, series, _ = run(jax.random.key(7), cfg)
        results[backend] = (st, series)
    st_d, series_d = results["dense"]
    st_g, series_g = results["grid"]
    np.testing.assert_array_equal(np.asarray(st_d["pos"]),
                                  np.asarray(st_g["pos"]))
    np.testing.assert_array_equal(np.asarray(st_d["lp"]),
                                  np.asarray(st_g["lp"]))
    for k in ("local_msgs", "remote_msgs", "migrations"):
        np.testing.assert_array_equal(np.asarray(series_d[k]),
                                      np.asarray(series_g[k]))


def test_use_pallas_removed_fails_loudly():
    # the PR-4 shim era is over: stale call sites must fail with a
    # message naming the replacement knob, not silently ignore the flag
    with pytest.raises(TypeError, match="proximity_backend"):
        ABMConfig(n_se=64, n_lp=2, area=500.0, interaction_range=100.0,
                  use_pallas=True)


def test_invalid_backend_rejected():
    with pytest.raises(ValueError):
        ABMConfig(proximity_backend="voronoi")


# ---------------------------------------------------------------------------
# CSR candidate path (million-SE tier): bit-identity under any memory budget
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("budget_entries", [1, 37, 4096])
def test_csr_chunk_budget_bit_identical(budget_entries):
    """The lax.map chunk size is a pure memory knob: any budget — down to
    one candidate entry (one row) per chunk, forcing ~200 sequential
    chunks — must reproduce the dense oracle bit-for-bit."""
    n, n_lp, area, rng = 200, 4, 1000.0, 80.0
    pos, lp, sender = _case(9, n, n_lp, area, rng)
    cfg = ABMConfig(n_se=n, n_lp=n_lp, area=area, interaction_range=rng)
    ref = _dense_counts(pos, lp, sender, cfg)
    spec = cfg.grid_spec()
    got = neighbors.grid_lp_counts(pos, lp, sender, n_lp, area, rng, spec,
                                   budget_entries=budget_entries)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("mobility", ["hotspot", "group", "flock"])
def test_csr_parity_across_mobility_models(mobility):
    """Property contract for the sparse candidate path: on every mobility
    model's (clustered, non-uniform) initial layout, the CSR sweep with
    the mobility-aware auto capacity is bit-identical to the dense
    oracle."""
    from repro.core.abm import init_abm

    cfg = ABMConfig(n_se=256, n_lp=4, area=2000.0, interaction_range=100.0,
                    mobility=mobility, n_groups=4, group_radius=150.0)
    st = init_abm(jax.random.key(17), cfg)
    pos, lp = st["pos"], st["lp"]
    sender = jnp.ones((cfg.n_se,), bool)
    ref = _dense_counts(pos, lp, sender, cfg)
    got = interaction_counts(pos, lp, sender, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_csr_overflow_drop_set_matches_table_oracle():
    """Adversarial layout that overflows the uniform capacity: the CSR
    sweep must drop EXACTLY the members the padded candidate-table
    oracle drops (both keep the first `capacity` members of each cell in
    sorted-id order), so even the overflowed counts — not just the flag —
    are bit-identical across representations."""
    n, n_lp, area, rng = 240, 4, 1000.0, 100.0
    k = jax.random.key(21)
    # three tight blobs -> uniform capacity is guaranteed to overflow
    centers = jnp.array([[100.0, 100.0], [500.0, 900.0], [900.0, 400.0]])
    pos = (centers[jnp.arange(n) % 3]
           + jax.random.normal(k, (n, 2)) * 15.0) % area
    lp = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, n_lp)
    sender = jnp.ones((n,), bool)
    spec = neighbors.make_grid_spec(n, area, rng)
    assert bool(neighbors.build_grid(pos, spec)["overflow"])

    cand, _ = neighbors.candidate_table(pos, spec)
    idx = jnp.arange(n, dtype=jnp.int32)
    table_counts = neighbors.rows_counts_chunked(
        pos, lp, n_lp, area, rng, pos, idx, sender, cand)
    csr_counts = neighbors.grid_lp_counts(pos, lp, sender, n_lp, area, rng,
                                          spec)
    np.testing.assert_array_equal(np.asarray(csr_counts),
                                  np.asarray(table_counts))
    # and both really are undercounts (the overflow is not hypothetical)
    cfg = ABMConfig(n_se=n, n_lp=n_lp, area=area, interaction_range=rng)
    ref = _dense_counts(pos, lp, sender, cfg)
    assert int(np.asarray(csr_counts).sum()) < int(np.asarray(ref).sum())


def test_mem_budget_clamp_is_loud():
    """A hard memory budget may shrink the per-cell capacity below what
    a clustered layout needs — the contract is exact-or-loud: the clamp
    must trip the overflow flag, never silently undercount."""
    from repro.core.abm import init_abm, interaction_counts_overflow

    cfg = ABMConfig(n_se=1024, n_lp=4, area=4000.0, interaction_range=100.0,
                    mobility="hotspot", n_groups=1, group_radius=100.0,
                    mem_budget_mb=1)
    spec = cfg.grid_spec()
    unclamped = dataclasses.replace(cfg, mem_budget_mb=0).grid_spec()
    assert spec.capacity == neighbors.budget_capacity(spec.ncell, 1)
    assert spec.capacity < unclamped.capacity
    st = init_abm(jax.random.key(4), cfg)
    sender = jnp.ones((cfg.n_se,), bool)
    _, overflow = interaction_counts_overflow(st["pos"], st["lp"], sender,
                                              cfg)
    assert bool(overflow)
    # the unclamped (budget-free) spec is exact on the same layout
    assert not bool(neighbors.build_grid(st["pos"], unclamped)["overflow"])


def test_generous_budget_leaves_simulation_bit_identical():
    """mem_budget_mb is a speed/memory knob, never a simulation knob: a
    budget roomy enough not to clamp capacity must give bit-identical
    engine trajectories (chunk boundaries move; counts must not)."""
    abm = ABMConfig(n_se=150, n_lp=4, area=1000.0, speed=5.0,
                    interaction_range=80.0, p_interact=0.3)
    base = EngineConfig(abm=abm, heuristic=HeuristicConfig(mf=1.2, mt=5),
                        gaia_on=True, timesteps=25)
    st0, s0, _ = run(jax.random.key(13), base)
    st1, s1, _ = run(jax.random.key(13),
                     dataclasses.replace(base, mem_budget_mb=256))
    np.testing.assert_array_equal(np.asarray(st0["pos"]),
                                  np.asarray(st1["pos"]))
    np.testing.assert_array_equal(np.asarray(st0["lp"]),
                                  np.asarray(st1["lp"]))
    for k in ("local_msgs", "remote_msgs", "migrations"):
        np.testing.assert_array_equal(np.asarray(s0[k]), np.asarray(s1[k]))


def test_budget_helpers():
    # 0 = unlimited -> the fixed default chunk budget
    assert neighbors.chunk_entries(0) == neighbors._CHUNK_BUDGET
    # 1 MB / 20 bytes per candidate entry, floored at 4096 entries
    assert neighbors.chunk_entries(1) == (1 << 20) // 20
    assert neighbors.chunk_entries(-5) == neighbors._CHUNK_BUDGET
    # capacity budget is monotone in the budget and never below 1
    caps = [neighbors.budget_capacity(400, mb) for mb in (1, 8, 64)]
    assert caps == sorted(caps) and caps[0] >= 1


def test_engine_budget_propagates_to_abm():
    abm = ABMConfig(n_se=64, n_lp=2, area=500.0, interaction_range=100.0)
    cfg = EngineConfig(abm=abm, heuristic=HeuristicConfig(),
                       mem_budget_mb=64)
    assert cfg.abm.mem_budget_mb == 64
    # an explicit per-ABM budget is not overridden by the engine knob
    abm2 = dataclasses.replace(abm, mem_budget_mb=8)
    cfg2 = EngineConfig(abm=abm2, heuristic=HeuristicConfig(),
                        mem_budget_mb=64)
    assert cfg2.abm.mem_budget_mb == 8

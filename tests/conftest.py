"""Shared fixtures.

The main test process forces FOUR host-platform devices (before the
first jax import) so tests/test_sharding.py can exercise the
LP-per-device engine on real 1/2/4-device meshes in-process. Engine
math is device-count-independent for every other test (sharding="none"
runs on device 0 regardless). The launch dry-run subprocesses still set
their own XLA_FLAGS (512 fake chips) — they override this value.
"""
import os

# Determinism + keep XLA from grabbing all RAM for test workers.
os.environ.setdefault("XLA_PYTHON_CLIENT_PREALLOCATE", "false")
# respect an explicit device count from the caller (e.g. 8-device runs)
if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=4")
# Persistent compilation cache: the suite is compile-dominated, so warm
# reruns (the common local dev loop) skip straight to execution. The
# env var propagates to the subprocess-mesh tests too.
os.environ.setdefault(
    "JAX_COMPILATION_CACHE_DIR",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 ".jax_cache"))

import jax
import pytest


@pytest.fixture
def key():
    return jax.random.key(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test "
                            "(excluded from tier-1; nightly CI job)")

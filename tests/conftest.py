"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single
real CPU device; only the dry-run subprocesses fake a 512-chip mesh."""
import os

# Determinism + keep XLA from grabbing all RAM for test workers.
os.environ.setdefault("XLA_PYTHON_CLIENT_PREALLOCATE", "false")

import jax
import pytest


@pytest.fixture
def key():
    return jax.random.key(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")

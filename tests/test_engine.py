"""Integration tests for the GAIA engine (paper §4–§5).

The headline invariant is *transparency* (§4.2): adaptive partitioning
must not change the simulation results — only where deliveries land.

Speed discipline (tier-1 budget): engine runs are memoized via
`_run(...)` (EngineConfig is frozen/hashable), so tests share scans
instead of recompiling them, and every scenario uses the smallest
(n_se, timesteps) that still exercises its logic.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abm import ABMConfig, init_abm, interaction_counts, rwp_step
from repro.core.engine import EngineConfig, init_engine, run, step
from repro.core.heuristics import HeuristicConfig

SMALL = ABMConfig(n_se=120, n_lp=4, area=1000.0, speed=5.0,
                  interaction_range=80.0, p_interact=0.3)


@functools.lru_cache(maxsize=None)
def _run_cfg(cfg: EngineConfig):
    return run(jax.random.key(7), cfg)


def _run(gaia_on, ts=60, heuristic=None, **abm_kw):
    cfg = EngineConfig(abm=ABMConfig(**{**SMALL.__dict__, **abm_kw}),
                       heuristic=heuristic or HeuristicConfig(mf=1.2, mt=5),
                       gaia_on=gaia_on, timesteps=ts)
    return _run_cfg(cfg)


def test_transparency_gaia_does_not_change_model_evolution():
    st_on, series_on, _ = _run(True)
    st_off, series_off, _ = _run(False)
    np.testing.assert_allclose(np.asarray(st_on["pos"]),
                               np.asarray(st_off["pos"]), rtol=0, atol=0)
    # total interaction volume identical: partitioning relabels local vs
    # remote, never creates/destroys deliveries
    tot_on = np.asarray(series_on["local_msgs"] + series_on["remote_msgs"])
    tot_off = np.asarray(series_off["local_msgs"] + series_off["remote_msgs"])
    np.testing.assert_array_equal(tot_on, tot_off)


def test_gaia_improves_lcr():
    _, _, c_on = _run(True)
    _, _, c_off = _run(False)
    assert c_on["migrations"] > 0
    assert c_on["mean_lcr"] > c_off["mean_lcr"] + 0.05, (c_on, c_off)


def test_static_lcr_matches_random_assignment():
    """With GAIA OFF and random equal assignment, LCR ~= 1/n_lp (paper
    §5.2: '25% with 4 LPs')."""
    _, _, c = _run(False)
    assert abs(c["mean_lcr"] - 0.25) < 0.05


def test_migration_protocol_delay():
    """An admitted migration becomes effective exactly migration_delay
    steps later (Fig. 4 + 2 LB steps), never earlier."""
    cfg = EngineConfig(abm=SMALL, heuristic=HeuristicConfig(mf=0.5, mt=0),
                       gaia_on=True, migration_delay=5, timesteps=1)
    st = init_engine(jax.random.key(0), cfg)
    jstep = jax.jit(lambda s: step(s, cfg))
    # run steps manually; track a pending migration
    for _ in range(30):
        prev_lp = st["lp"]
        pend_prev = st["pending_dst"] >= 0
        eta_prev = st["pending_eta"]
        t_prev = st["t"]
        st, _ = jstep(st)
        newly_admitted = (st["pending_dst"] >= 0) & ~pend_prev
        if bool(newly_admitted.any()):
            idx = int(jnp.argmax(newly_admitted))
            assert int(st["pending_eta"][idx]) == int(t_prev) + 5
        # arrivals: lp changes only when eta == t
        changed = st["lp"] != prev_lp
        if bool(changed.any()):
            idx = np.where(np.asarray(changed))[0]
            np.testing.assert_array_equal(np.asarray(eta_prev)[idx],
                                          int(t_prev))


def test_symmetric_balance_preserves_counts_through_run():
    st, _, c = _run(True)
    counts = np.bincount(np.asarray(st["lp"]), minlength=SMALL.n_lp)
    assert c["migrations"] > 0
    np.testing.assert_array_equal(counts, [SMALL.n_se // SMALL.n_lp] * SMALL.n_lp)


def test_asymmetric_balance_drifts_to_capacity():
    cfg = EngineConfig(
        abm=SMALL, heuristic=HeuristicConfig(mf=0.8, mt=2),
        gaia_on=True, balance="asymmetric",
        capacity=(0.4, 0.3, 0.2, 0.1), timesteps=100)
    st, _, _ = run(jax.random.key(3), cfg)
    counts = np.bincount(np.asarray(st["lp"]), minlength=4) / SMALL.n_se
    # allocation drifted toward the capacity profile (LP0 > LP3)
    assert counts[0] > 0.3 and counts[3] < 0.2, counts


def test_faster_movement_needs_more_migrations():
    """Paper Fig. 5 trend: higher speed -> more migrations for the same
    clustering level."""
    _, _, slow = _run(True, speed=2.0)
    _, _, fast = _run(True, speed=40.0)
    assert fast["migrations"] > slow["migrations"]


def test_heuristics_2_and_3_also_cluster():
    _, _, c_off = _run(False)
    for kind, kw in ((2, dict(omega=8)), (3, dict(omega=8, zeta=8))):
        _, _, c = _run(True,
                       heuristic=HeuristicConfig(kind=kind, mf=1.2, mt=5, **kw))
        assert c["mean_lcr"] > c_off["mean_lcr"] + 0.02, (kind, c, c_off)
    # h3 evaluates strictly fewer SEs than h2
    _, _, c2 = _run(True,
                    heuristic=HeuristicConfig(kind=2, mf=1.2, mt=5, omega=8))
    _, _, c3 = _run(True,
                    heuristic=HeuristicConfig(kind=3, mf=1.2, mt=5, omega=8,
                                              zeta=16))
    assert c3["heu_evals"] < c2["heu_evals"]


def test_mf_sweep_monotone_migrations():
    """Higher MF -> fewer migrations (Fig. 8/9 x-axis mechanics)."""
    migs = []
    for mf in (0.8, 3.0, 8.0):
        _, _, c = _run(True, heuristic=HeuristicConfig(mf=mf, mt=5))
        migs.append(c["migrations"])
    assert migs == sorted(migs, reverse=True), migs
    assert migs[-1] < migs[0]


# ---------------------------------------------------------------------------
# ABM building blocks
# ---------------------------------------------------------------------------


def test_rwp_step_moves_at_speed():
    cfg = ABMConfig(n_se=50, area=1000.0, speed=7.0)
    st = init_abm(jax.random.key(1), cfg)
    pos2, wp2 = rwp_step(jax.random.key(2), st["pos"], st["waypoint"], cfg)
    d = np.linalg.norm(np.asarray(
        jnp.minimum(jnp.abs(pos2 - st["pos"]),
                    cfg.area - jnp.abs(pos2 - st["pos"]))), axis=-1)
    assert np.all(d <= cfg.speed + 1e-3)


def test_interaction_counts_match_bruteforce():
    cfg = ABMConfig(n_se=64, n_lp=3, area=500.0, interaction_range=90.0)
    k = jax.random.key(5)
    pos = jax.random.uniform(k, (64, 2), maxval=500.0)
    lp = jax.random.randint(jax.random.key(6), (64,), 0, 3)
    sender = jax.random.bernoulli(jax.random.key(7), 0.5, (64,))
    got = np.asarray(interaction_counts(pos, lp, sender, cfg))
    p = np.asarray(pos)
    d = np.abs(p[:, None, :] - p[None, :, :])
    d = np.minimum(d, 500.0 - d)
    mask = (d ** 2).sum(-1) <= 90.0 ** 2
    np.fill_diagonal(mask, False)
    mask &= np.asarray(sender)[:, None]
    onehot = np.asarray(lp)[:, None] == np.arange(3)[None, :]
    want = (mask.astype(np.int64) @ onehot.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_toroidal_wraparound():
    cfg = ABMConfig(n_se=2, n_lp=2, area=100.0, interaction_range=15.0)
    pos = jnp.array([[1.0, 1.0], [99.0, 99.0]])  # 2*sqrt(2) apart on torus
    lp = jnp.array([0, 1], jnp.int32)
    counts = np.asarray(interaction_counts(
        pos, lp, jnp.array([True, True]), cfg))
    assert counts[0, 1] == 1 and counts[1, 0] == 1


# ---------------------------------------------------------------------------
# compiled-program caches (bounded + clearable)
# ---------------------------------------------------------------------------


def test_compiled_caches_bounded_and_clearable():
    """The per-(cfg, n_steps) compiled-window caches used to be
    unbounded lru_caches: a benchmark sweeping N leaked every XLA
    executable it ever built. They must be bounded, and
    `clear_compiled_caches()` must empty every one of them — including
    the sharded mirrors when lp_shard has been imported."""
    from repro.core import engine
    from repro.parallel import lp_shard

    for fn in (engine._compiled_window_cached, engine._compiled_batch_cached,
               lp_shard._compiled_window_sharded,
               lp_shard._compiled_batch_sharded):
        assert fn.cache_info().maxsize == engine.COMPILED_CACHE_SIZE

    from repro.core.engine import run_window
    cfg = EngineConfig(abm=SMALL, heuristic=HeuristicConfig(mf=1.2, mt=5),
                       gaia_on=False, timesteps=4)
    st = init_engine(jax.random.key(3), cfg)
    run_window(st, cfg, 4)
    assert engine._compiled_window_cached.cache_info().currsize > 0
    engine.clear_compiled_caches()
    for fn in (engine._compiled_window_cached, engine._compiled_batch_cached,
               lp_shard._compiled_window_sharded,
               lp_shard._compiled_batch_sharded):
        assert fn.cache_info().currsize == 0
    # cleared, not broken: the next call recompiles and still runs
    st2, counters = run_window(st, cfg, 4)
    assert counters["local_msgs"] + counters["remote_msgs"] >= 0
    assert engine._compiled_window_cached.cache_info().currsize == 1

"""Data-pipeline tests: determinism, restart replay, prefetch liveness."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM, make_pipeline

CFG = DataConfig(vocab_size=64, seq_len=32, global_batch=4, seed=7)


def test_batch_is_pure_function_of_seed_and_step():
    a = SyntheticLM(CFG).batch_at(13)
    b = SyntheticLM(CFG).batch_at(13)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(DataConfig(**{**CFG.__dict__, "seed": 8})).batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_restart_replays_identical_stream():
    it = make_pipeline(CFG, start_step=0)
    first = [next(it) for _ in range(6)]
    it.close()
    resumed = make_pipeline(CFG, start_step=3)
    for want in first[3:]:
        got = next(resumed)
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
    resumed.close()


def test_markov_structure_is_learnable_signal():
    """Most next-tokens follow the deterministic rule — the synthetic task
    has structure a model can learn (both orders)."""
    for order, rule in ((1, lambda t: (t[:, 1:-1] * 31 + 7) % 64),
                        (2, lambda t: (t[:, 1:-1] * 31 + t[:, :-2] * 17 + 7)
                         % 64)):
        b = SyntheticLM(DataConfig(vocab_size=64, seq_len=256, global_batch=8,
                                   structure=0.9, order=order)).batch_at(0)
        t = b["tokens"]
        frac = float(np.mean(rule(t) == t[:, 2:]))
        assert frac > 0.8, (order, frac)


def test_tokens_in_vocab_range():
    b = SyntheticLM(CFG).batch_at(2)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < CFG.vocab_size
    assert b["tokens"].dtype == np.int32
    assert b["loss_mask"].shape == b["tokens"].shape

"""Data-pipeline tests: determinism, restart replay, prefetch liveness —
plus the mobility-trace round trip (generator -> writer -> loader ->
engine replay, bit-equal end to end)."""
import dataclasses

import numpy as np
import pytest

from repro.data.pipeline import (DataConfig, SyntheticLM, Trace, TraceSpec,
                                 load_trace, make_pipeline, register_trace,
                                 resample_trace, save_trace,
                                 synthetic_trace)

CFG = DataConfig(vocab_size=64, seq_len=32, global_batch=4, seed=7)


def test_batch_is_pure_function_of_seed_and_step():
    a = SyntheticLM(CFG).batch_at(13)
    b = SyntheticLM(CFG).batch_at(13)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(DataConfig(**{**CFG.__dict__, "seed": 8})).batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_restart_replays_identical_stream():
    it = make_pipeline(CFG, start_step=0)
    first = [next(it) for _ in range(6)]
    it.close()
    resumed = make_pipeline(CFG, start_step=3)
    for want in first[3:]:
        got = next(resumed)
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
    resumed.close()


def test_markov_structure_is_learnable_signal():
    """Most next-tokens follow the deterministic rule — the synthetic task
    has structure a model can learn (both orders)."""
    for order, rule in ((1, lambda t: (t[:, 1:-1] * 31 + 7) % 64),
                        (2, lambda t: (t[:, 1:-1] * 31 + t[:, :-2] * 17 + 7)
                         % 64)):
        b = SyntheticLM(DataConfig(vocab_size=64, seq_len=256, global_batch=8,
                                   structure=0.9, order=order)).batch_at(0)
        t = b["tokens"]
        frac = float(np.mean(rule(t) == t[:, 2:]))
        assert frac > 0.8, (order, frac)


def test_tokens_in_vocab_range():
    b = SyntheticLM(CFG).batch_at(2)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < CFG.vocab_size
    assert b["tokens"].dtype == np.int32
    assert b["loss_mask"].shape == b["tokens"].shape


# ---------------------------------------------------------------------------
# Mobility traces
# ---------------------------------------------------------------------------

SPEC = TraceSpec(n_se=48, area=500.0, timesteps=30, speed=8.0, n_hubs=3,
                 seed=11)


def test_synthetic_trace_is_deterministic_and_bounded():
    a, b = synthetic_trace(SPEC), synthetic_trace(SPEC)
    np.testing.assert_array_equal(a.frames, b.frames)
    assert a.frames.shape == (SPEC.timesteps, SPEC.n_se, 2)
    assert a.frames.dtype == np.float32
    # the commute honors the declared speed bound (torus metric),
    # excluding the loop seam, which only the `loop` policy pays for
    assert a.max_step_displacement(include_seam=False) <= SPEC.speed + 1e-3


def test_trace_crosses_the_torus_seam():
    """Hub commutes take the torus-shortest path, so some consecutive
    frames differ by nearly the whole area on an axis (a wrap) while
    the torus displacement stays speed-bounded — the property replay's
    wrap handling is tested against."""
    tr = synthetic_trace(SPEC)
    naive = np.abs(np.diff(tr.frames.astype(np.float64), axis=0))
    assert naive.max() > SPEC.area / 2  # a seam crossing exists
    assert tr.max_step_displacement() <= SPEC.speed + 1e-3


def test_save_load_round_trip_is_bit_exact(tmp_path):
    tr = synthetic_trace(SPEC)
    path = save_trace(tr, str(tmp_path / "trace.npz"))
    back = load_trace(path)
    np.testing.assert_array_equal(back.frames, tr.frames)
    assert back.area == tr.area


def test_trace_validation_is_loud():
    with pytest.raises(ValueError, match=r"\(T>=1, N, 2\)"):
        Trace(np.zeros((4, 2), np.float32), 100.0)
    with pytest.raises(ValueError, match="inside"):
        Trace(np.full((2, 3, 2), 150.0, np.float32), 100.0)  # off-torus
    bad = np.zeros((2, 3, 2), np.float32)
    bad[1, 0, 0] = np.nan
    with pytest.raises(ValueError, match="finite"):
        Trace(bad, 100.0)


def test_resample_exact_rows_verbatim_and_torus_lerp():
    """A sample row AT a step time comes back bit-equal; between
    samples the lerp takes the torus-shortest path (a midpoint across
    the seam lands near the seam, not mid-area)."""
    area = 100.0
    times = np.array([0.0, 1.0, 2.5, 4.0])
    positions = np.zeros((4, 1, 2), np.float32)
    positions[0, 0] = (98.0, 50.0)
    positions[1, 0] = (97.123456, 50.0)  # exact row, awkward float
    positions[2, 0] = (99.0, 50.0)
    positions[3, 0] = (3.0, 50.0)  # seam crossing 99 -> 3
    tr = resample_trace(times, positions, area, n_steps=5)
    np.testing.assert_array_equal(tr.frames[0], positions[0])
    np.testing.assert_array_equal(tr.frames[1], positions[1])
    np.testing.assert_array_equal(tr.frames[4], positions[3])
    # step 3 is 1/3 of the way 2.5 -> 4.0: 99 + (4/3) on the torus
    assert abs(tr.frames[3, 0, 0] - (99.0 + 4.0 / 3.0) % area) < 1e-4
    # an integer-step log resamples to itself exactly
    grid_t = np.arange(4, dtype=np.float64)
    tr2 = resample_trace(grid_t, positions, area, n_steps=4)
    np.testing.assert_array_equal(tr2.frames, positions)


def test_resample_never_extrapolates():
    pos = np.zeros((2, 1, 2), np.float32)
    with pytest.raises(ValueError, match="never extrapolates"):
        resample_trace([0.0, 3.0], pos, 100.0, n_steps=6)
    with pytest.raises(ValueError, match="strictly increasing"):
        resample_trace([1.0, 1.0], pos, 100.0, n_steps=2)


def _trace_engine_cfg(name, policy, ts):
    import repro.core.abm as abm
    import repro.core.engine as eng
    import repro.core.heuristics as heu
    return eng.EngineConfig(
        abm=abm.ABMConfig(n_se=SPEC.n_se, n_lp=4, area=SPEC.area,
                          speed=5.0, interaction_range=60.0,
                          p_interact=0.3, mobility="trace",
                          trace_name=name, trace_policy=policy),
        heuristic=heu.HeuristicConfig(mf=1.2, mt=5), gaia_on=True,
        timesteps=ts)


def test_engine_replay_round_trip_bit_equal(tmp_path):
    """The full satellite contract: synthetic -> save -> load ->
    register -> engine replay, and the replayed positions equal the
    loaded frames byte-for-byte at every probed horizon."""
    import jax

    from repro.core.engine import run
    path = save_trace(synthetic_trace(SPEC), str(tmp_path / "rt.npz"))
    loaded = load_trace(path)
    register_trace("test-data-rt", loaded)
    for ts in (1, 7):
        cfg = _trace_engine_cfg("test-data-rt", "exact", ts)
        st, _, _ = run(jax.random.key(3), cfg)
        np.testing.assert_array_equal(np.asarray(st["pos"]),
                                      loaded.frames[ts])


def test_short_trace_policies_hold_loop_exact():
    """A trace shorter than the horizon: `hold` freezes on the last
    frame, `loop` wraps to the top, `exact` refuses to run — the three
    declared policies, exercised through the engine."""
    import jax

    from repro.core.engine import run
    short = Trace(synthetic_trace(SPEC).frames[:10], SPEC.area)
    register_trace("test-data-short", short)
    ts = 14  # past the 10 frames
    st_h, _, _ = run(jax.random.key(3),
                     _trace_engine_cfg("test-data-short", "hold", ts))
    np.testing.assert_array_equal(np.asarray(st_h["pos"]), short.frames[-1])
    st_l, _, _ = run(jax.random.key(3),
                     _trace_engine_cfg("test-data-short", "loop", ts))
    np.testing.assert_array_equal(np.asarray(st_l["pos"]),
                                  short.frames[ts % 10])
    with pytest.raises(ValueError, match="trace_policy='exact'"):
        run(jax.random.key(3),
            _trace_engine_cfg("test-data-short", "exact", ts))


def test_trace_config_validation():
    import repro.core.abm as abm
    with pytest.raises(ValueError, match="needs trace_name"):
        abm.ABMConfig(n_se=8, n_lp=2, area=100.0, speed=1.0,
                      interaction_range=10.0, p_interact=0.1,
                      mobility="trace")
    register_trace("test-data-val", synthetic_trace(SPEC))
    cfg = _trace_engine_cfg("test-data-val", "exact", 4).abm
    with pytest.raises(ValueError, match="but ABMConfig.n_se"):
        abm.trace_frames(dataclasses.replace(cfg, n_se=SPEC.n_se + 1))
    with pytest.raises(ValueError, match="torus"):
        abm.trace_frames(dataclasses.replace(cfg, area=SPEC.area * 2))

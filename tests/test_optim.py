"""Optimizer-layer tests: AdamW, Adafactor (+lean/stochastic rounding),
q8 error-feedback compression, DiLoCo outer loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamWConfig, adamw_apply, adamw_init, lr_at
from repro.optim.adafactor import (adafactor_apply, adafactor_init,
                                   adafactor_lean_apply, adafactor_lean_init,
                                   _stochastic_round_bf16)
from repro.optim.compress import dequantize_q8, quantize_q8
from repro.optim.diloco import DiLoCoConfig, diloco_init, outer_step


OPT = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=10_000, weight_decay=0.0)


def _quadratic_losses(apply_fn, init_fn, steps=60):
    """Minimize ||w - target||^2 from w=0; returns loss trajectory."""
    target = jnp.array([1.0, -2.0, 3.0], jnp.float32)
    params = {"w": jnp.zeros(3, jnp.float32)}
    state = init_fn(params)
    losses = []
    for _ in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = apply_fn(OPT, grads, state, params)
        losses.append(float(jnp.sum((params["w"] - target) ** 2)))
    return losses


def test_adamw_converges_on_quadratic():
    losses = _quadratic_losses(adamw_apply, adamw_init)
    assert losses[-1] < 1e-2 * losses[0]


def test_adafactor_converges_on_quadratic():
    losses = _quadratic_losses(adafactor_apply, adafactor_init)
    assert losses[-1] < 0.1 * losses[0]


def test_adafactor_lean_converges_on_quadratic():
    losses = _quadratic_losses(adafactor_lean_apply, adafactor_lean_init)
    assert losses[-1] < 0.1 * losses[0]


def test_adamw_grad_clip():
    params = {"w": jnp.zeros(4, jnp.float32)}
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e9, jnp.float32)}
    p2, _, m = adamw_apply(AdamWConfig(lr=0.1, warmup_steps=0, clip_norm=1.0),
                           huge, state, params)
    assert float(m["grad_norm"]) == pytest.approx(2e9)
    assert np.all(np.isfinite(np.asarray(p2["w"])))
    assert np.abs(np.asarray(p2["w"])).max() < 1.0


def test_lr_schedule_warmup_and_cosine():
    c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(c, 1)) < float(lr_at(c, 10))
    assert float(lr_at(c, 10)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_at(c, 100)) == pytest.approx(0.1, rel=1e-3)  # floor 10%


def test_stochastic_rounding_unbiased():
    x = jnp.full((20000,), 1.0 + 2 ** -10, jnp.float32)  # between bf16 grid
    r = _stochastic_round_bf16(jax.random.key(0), x).astype(jnp.float32)
    vals = np.unique(np.asarray(r))
    assert len(vals) == 2  # rounds to the two neighbours only
    mean = float(r.mean())
    assert abs(mean - float(x[0])) < 2e-4  # unbiased in expectation


def test_adafactor_lean_state_is_small():
    params = {"w": jnp.zeros((64, 64), jnp.bfloat16)}
    lean = adafactor_lean_init(params)
    full = adafactor_init(params)
    bytes_of = lambda t: sum(l.size * l.dtype.itemsize
                             for l in jax.tree.leaves(t))
    assert bytes_of(lean) < 0.05 * bytes_of(full)


# ---------------------------------------------------------------------------
# q8 compression
# ---------------------------------------------------------------------------


def test_q8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(1), (1000,), jnp.float32) * 3
    q, s = quantize_q8(x)
    y = dequantize_q8(q, s, x.shape)
    # error bounded by half a quantization step per block
    step = np.asarray(s).max()
    assert float(jnp.abs(x - y).max()) <= step / 2 + 1e-6


def test_error_feedback_accumulates_residual():
    """With a constant tiny gradient, plain q8 loses it entirely; EF
    recovers it over steps (the residual accumulates until it crosses a
    quantization step)."""
    g = jnp.full((256,), 1e-4, jnp.float32)
    # an outlier in the block makes the quantization step >> |g|: plain
    # q8 transmits exactly 0 for the small entries every single step
    g = g.at[0].set(0.1)
    q0, s0 = quantize_q8(g)
    assert float(dequantize_q8(q0, s0, g.shape)[1:].max()) == 0.0
    e = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    steps = 200
    for _ in range(steps):
        target = g + e
        q, s = quantize_q8(target)
        deq = dequantize_q8(q, s, g.shape)
        e = target - deq
        total = total + deq
    # EF: the mean transmitted value approaches the true gradient
    np.testing.assert_allclose(np.asarray(total[1:]) / steps,
                               np.asarray(g[1:]), rtol=0.1)


# ---------------------------------------------------------------------------
# DiLoCo
# ---------------------------------------------------------------------------


def test_diloco_outer_moves_toward_pod_mean():
    params = {"w": jnp.zeros(3, jnp.float32)}
    st = diloco_init(params)
    pod_mean = {"w": jnp.array([1.0, 1.0, 1.0])}  # pods agreed: move +1
    cfg = DiLoCoConfig(outer_lr=0.7, outer_momentum=0.0)
    st2, new_global = outer_step(cfg, st, pod_mean)
    np.testing.assert_allclose(np.asarray(new_global["w"]),
                               [0.7, 0.7, 0.7], rtol=1e-6)


def test_diloco_momentum_accelerates():
    params = {"w": jnp.zeros(1, jnp.float32)}
    cfg_m = DiLoCoConfig(outer_lr=0.3, outer_momentum=0.9)
    cfg_0 = DiLoCoConfig(outer_lr=0.3, outer_momentum=0.0)
    sm, s0 = diloco_init(params), diloco_init(params)
    gm, g0 = params, params
    for _ in range(5):  # pods keep reporting +1 past the global
        sm, gm = outer_step(cfg_m, sm, {"w": gm["w"] + 1})
        s0, g0 = outer_step(cfg_0, s0, {"w": g0["w"] + 1})
    assert float(gm["w"][0]) > float(g0["w"][0])

"""Scenario-subsystem tests (mobility models + execution environments).

Contracts, per mobility model:
  * §4.2 transparency: GAIA on/off leaves the model evolution
    (positions, mobility state, total interaction volume) byte-identical;
  * proximity-backend parity: dense and grid trajectories byte-identical
    (the clustered auto-capacity must hold, or the grid undercounts);
  * the workloads are genuinely non-uniform (that is their purpose) and
    per-step displacement stays bounded by the configured speed.

Sharded bit-identity for the same scenarios lives in test_sharding.py;
the heterogeneous pricing itself in test_costmodel.py.
"""
import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core.abm import (ABMConfig, MOBILITY_MODELS, init_abm,
                            mobility_step)
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig
from repro.data import pipeline as dpipe

NEW_MODELS = [m for m in MOBILITY_MODELS if m != "rwp"]

# the trace model replays data, so the generic per-model contracts need
# a registered trace: same universe as _abm, speed-matched, long enough
# that a 40-step run never crosses the loop seam
TRACE_NAME = "test-scenarios"
dpipe.register_trace(TRACE_NAME, dpipe.synthetic_trace(
    dpipe.TraceSpec(n_se=120, area=1000.0, timesteps=48, speed=5.0,
                    n_hubs=4, seed=5)))


def _abm(mobility, **kw):
    base = dict(n_se=120, n_lp=4, area=1000.0, speed=5.0,
                interaction_range=80.0, p_interact=0.3,
                mobility=mobility, n_groups=4, group_radius=120.0)
    if mobility == "trace":
        base["trace_name"] = TRACE_NAME
    return ABMConfig(**{**base, **kw})


def _cfg(mobility, gaia=True, ts=40, **kw):
    return EngineConfig(abm=_abm(mobility, **kw),
                        heuristic=HeuristicConfig(mf=1.2, mt=5),
                        gaia_on=gaia, timesteps=ts)


@functools.lru_cache(maxsize=None)
def _run(cfg: EngineConfig, seed=7):
    return run(jax.random.key(seed), cfg)


def _bytes(x):
    return np.ascontiguousarray(np.asarray(x)).tobytes()


def test_mobility_config_validation():
    with pytest.raises(ValueError):
        ABMConfig(mobility="teleport")
    with pytest.raises(ValueError):
        ABMConfig(mobility="hotspot", n_groups=0)


@pytest.mark.parametrize("mobility", NEW_MODELS)
def test_transparency_gaia_does_not_change_model_evolution(mobility):
    st_on, s_on, _ = _run(_cfg(mobility, True))
    st_off, s_off, _ = _run(_cfg(mobility, False))
    for k in ("pos", "waypoint", "mob", "mob_g"):
        assert _bytes(st_on[k]) == _bytes(st_off[k]), k
    tot_on = np.asarray(s_on["local_msgs"]) + np.asarray(s_on["remote_msgs"])
    tot_off = (np.asarray(s_off["local_msgs"])
               + np.asarray(s_off["remote_msgs"]))
    np.testing.assert_array_equal(tot_on, tot_off)


@pytest.mark.parametrize("mobility", NEW_MODELS)
def test_dense_grid_trajectories_bit_identical(mobility):
    """The whole-run parity contract: with the clustered auto-capacity
    the grid backend must reproduce the dense oracle byte-for-byte on
    the non-uniform workloads too."""
    cfg = _cfg(mobility, True)
    dense = dataclasses.replace(
        cfg, abm=dataclasses.replace(cfg.abm, proximity_backend="dense"))
    st_g, s_g, c_g = _run(cfg)
    st_d, s_d, c_d = _run(dense)
    for k in ("pos", "lp", "ring", "last_mig"):
        assert _bytes(st_g[k]) == _bytes(st_d[k]), k
    np.testing.assert_array_equal(np.asarray(s_g["lp_flows"]),
                                  np.asarray(s_d["lp_flows"]))
    assert c_g["grid_overflow"] == 0.0  # capacity held, else parity is luck


@pytest.mark.parametrize("mobility", ["hotspot", "group"])
def test_clustered_workloads_are_nonuniform_and_gaia_still_wins(mobility):
    st, _, c_on = _run(_cfg(mobility, True))
    _, _, c_off = _run(_cfg(mobility, False))
    # non-uniform: peak cell occupancy well above the uniform mean
    spec = _abm(mobility).grid_spec()
    pos = np.asarray(st["pos"])
    cell = (np.floor(pos[:, 0] / spec.cell).astype(int) % spec.ncell) \
        * spec.ncell + np.floor(pos[:, 1] / spec.cell).astype(int) \
        % spec.ncell
    occ = np.bincount(cell, minlength=spec.ncell ** 2)
    assert occ.max() > 3.0 * 120 / spec.ncell ** 2, occ.max()
    # and self-clustering still converts remote traffic to local
    assert c_on["migrations"] > 0
    assert c_on["mean_lcr"] > c_off["mean_lcr"] + 0.05, (c_on, c_off)


def test_clustered_auto_capacity_exceeds_uniform_bound():
    from repro.core import neighbors
    uni = ABMConfig(n_se=400, area=1000.0, interaction_range=80.0)
    hot = dataclasses.replace(uni, mobility="hotspot", n_groups=4,
                              group_radius=120.0)
    assert hot.grid_spec().capacity > uni.grid_spec().capacity
    # explicit override still wins
    assert dataclasses.replace(hot, grid_capacity=9).grid_spec().capacity == 9
    spec = uni.grid_spec()
    assert neighbors.clustered_capacity(
        400, spec.ncell, spec.cell, 4, 120.0) <= 400


def test_grid_overflow_metric_fires_when_capacity_too_tight():
    """The engine's per-step alarm: a deliberately tiny capacity on a
    clustered workload must raise grid_overflow (silent undercounting is
    the failure mode it guards against)."""
    _, _, c = _run(_cfg("hotspot", True, ts=10, grid_capacity=4))
    assert c["grid_overflow"] > 0


@pytest.mark.parametrize("mobility", MOBILITY_MODELS)
def test_per_step_displacement_bounded(mobility):
    """No mobility model teleports: toroidal per-step displacement stays
    within speed x (1 + noise amplitude)."""
    cfg = _abm(mobility)
    st = init_abm(jax.random.key(1), cfg)
    pos, wp, mob, mob_g = st["pos"], st["waypoint"], st["mob"], st["mob_g"]
    for i in range(3):
        new_pos, wp, mob, mob_g = mobility_step(
            jax.random.fold_in(jax.random.key(2), i), pos, wp, mob, mob_g,
            cfg)
        d = np.asarray(jnp_tor_dist(new_pos, pos, cfg.area))
        assert d.max() <= cfg.speed * 1.8 + 1e-3, (mobility, d.max())
        pos = new_pos


def jnp_tor_dist(a, b, area):
    import jax.numpy as jnp
    d = jnp.abs(a - b)
    d = jnp.minimum(d, area - d)
    return jnp.linalg.norm(d, axis=-1)


def test_group_members_track_their_leader():
    """RPGM coherence: after a burn-in, members sit near
    (leader + offset) — the whole group moves as one."""
    cfg = _abm("group")
    st = init_abm(jax.random.key(3), cfg)
    pos, wp, mob, mob_g = st["pos"], st["waypoint"], st["mob"], st["mob_g"]
    for i in range(30):
        pos, wp, mob, mob_g = mobility_step(
            jax.random.fold_in(jax.random.key(4), i), pos, wp, mob, mob_g,
            cfg)
    target = (np.asarray(mob_g)[np.arange(cfg.n_se) % cfg.n_groups, :2]
              + np.asarray(mob)) % cfg.area
    d = np.asarray(jnp_tor_dist(pos, target, cfg.area))
    assert np.median(d) < 3.0 * cfg.speed, np.median(d)


def test_env_supplies_asymmetric_capacity_profile():
    """EngineConfig.env stands in for explicit capacity shares: the
    allocation drifts toward the environment's speed profile."""
    env = cm.make_env("hetero", 4)  # speeds (2, 1, 1, 0.5)
    cfg = EngineConfig(abm=_abm("rwp"),
                       heuristic=HeuristicConfig(mf=0.8, mt=2),
                       balance="asymmetric", env=env, timesteps=60)
    assert cfg.effective_capacity() == pytest.approx(env.capacity_shares())
    st, _, _ = _run(cfg, seed=3)
    counts = np.bincount(np.asarray(st["lp"]), minlength=4) / 120
    assert counts[0] > counts[3] + 0.1, counts


def test_env_n_lp_mismatch_rejected():
    with pytest.raises(ValueError):
        EngineConfig(abm=_abm("rwp"), env=cm.make_env("lan", 8))
    with pytest.raises(ValueError):
        EngineConfig(abm=_abm("rwp"), balance="asymmetric")

"""Partitioning-backend tests (core/partition.py).

Three contracts:

  * seed compatibility — the default `partitioner="random"` reproduces
    the pre-registry `init_abm` round-robin line bit-identically, so
    every existing seed (and every earlier benchmark/test expectation)
    is untouched;
  * execution-layer parity — each backend, static and with the periodic
    repartition hook active, is bit-identical between sharding="none"
    and "lp_device" (the §4.2 transparency invariant extended to the
    partitioner subsystem);
  * hypothesis properties — every SE gets exactly one valid LP, per-LP
    load stays within the declared capacity bound, maps are
    deterministic for a fixed key, and the geometry-driven backends
    (stripe/kmeans) are permutation-equivariant.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import partition as part
from repro.core.abm import ABMConfig, init_abm
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig

# the property tests (bottom section) need the optional dev dependency
# `hypothesis`; the seed-compat and sharding-parity contracts must run
# regardless, so only that section is gated.
try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("partition", deadline=None, max_examples=25)
    settings.load_profile("partition")
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ABM = ABMConfig(n_se=96, n_lp=4, area=1000.0, speed=5.0,
                interaction_range=80.0, p_interact=0.3)
ENGINE = EngineConfig(abm=ABM, heuristic=HeuristicConfig(mf=1.2, mt=5),
                      gaia_on=False, timesteps=18)


# ---------------------------------------------------------------------------
# seed compatibility
# ---------------------------------------------------------------------------


def test_random_default_reproduces_pre_registry_assignment():
    """The registry's "random" backend must consume its key exactly like
    the pre-PR hardcoded line: lp = permutation(k3, arange(n) % n_lp)
    with k3 the third split of the init key. Bit-identical, not just
    statistically equivalent."""
    key = jax.random.key(123)
    st_ = init_abm(key, ABM)
    _, _, k3 = jax.random.split(key, 3)
    legacy = jax.random.permutation(k3, jnp.arange(ABM.n_se) % ABM.n_lp)
    np.testing.assert_array_equal(np.asarray(st_["lp"]), np.asarray(legacy))
    assert st_["lp"].dtype == jnp.int32


def test_random_ignores_geometry():
    """Same key, different positions -> same map (the baseline must not
    silently become informed)."""
    cfg = part.PartitionConfig(backend="random", n_lp=4, area=1000.0)
    k = jax.random.key(3)
    w = jnp.ones((64,))
    p1 = jax.random.uniform(jax.random.key(1), (64, 2), maxval=1000.0)
    p2 = jax.random.uniform(jax.random.key(2), (64, 2), maxval=1000.0)
    np.testing.assert_array_equal(np.asarray(part.partition(k, p1, w, cfg)),
                                  np.asarray(part.partition(k, p2, w, cfg)))


# ---------------------------------------------------------------------------
# execution-layer parity (sharding="none" vs "lp_device")
# ---------------------------------------------------------------------------

STATE_KEYS = ("pos", "waypoint", "mob", "mob_g", "lp", "pending_dst",
              "pending_eta", "ring", "ptr", "since_eval", "last_mig")
SERIES_KEYS = ("local_msgs", "remote_msgs", "migrations", "heu_evals", "lcr",
               "lp_flows", "mig_flows", "repartitions")


@functools.lru_cache(maxsize=None)
def _run(cfg: EngineConfig, seed=11):
    return run(jax.random.key(seed), cfg)


def _assert_sharding_parity(cfg):
    st0, s0, c0 = _run(cfg)
    st1, s1, c1 = _run(dataclasses.replace(cfg, sharding="lp_device",
                                           n_devices=4))
    assert c1["shard_overflow"] == 0.0
    for k in STATE_KEYS:
        np.testing.assert_array_equal(np.asarray(st0[k]), np.asarray(st1[k]),
                                      err_msg=k)
    for k in SERIES_KEYS:
        np.testing.assert_array_equal(np.asarray(s0[k]), np.asarray(s1[k]),
                                      err_msg=k)


@pytest.mark.parametrize("backend", part.PARTITION_BACKENDS)
def test_backend_bit_identical_across_sharding(backend):
    """Static init through each backend: identical states and series on
    the single-device oracle and the 4-device mesh."""
    _assert_sharding_parity(dataclasses.replace(
        ENGINE, abm=dataclasses.replace(ABM, partitioner=backend)))


@pytest.mark.parametrize("backend,mobility", [
    ("kmeans", "rwp"), ("random", "rwp"),
    # hotspot exercises the sharded hook's other gather path: it reuses
    # the id-order gid gather the non-RWP mobility branch already did
    ("kmeans", "hotspot"),
    # voronoi exercises the prev-lp gather (uses_prev): the sharded hook
    # must reassemble the id-order map before the fuzzy recompute
    ("voronoi", "rwp"), ("voronoi", "hotspot"),
])
def test_periodic_repartition_bit_identical_across_sharding(backend,
                                                            mobility):
    """The repartition hook recomputes the global map mid-run on every
    device; the pending/migration path must reshard the deltas into the
    exact oracle trajectory (and actually fire: repartitions > 0)."""
    cfg = dataclasses.replace(
        ENGINE, abm=dataclasses.replace(ABM, partitioner=backend,
                                        mobility=mobility, n_groups=4,
                                        group_radius=120.0),
        repartition_every=6, gaia_on=True)
    _assert_sharding_parity(cfg)
    _, _, c = _run(cfg)
    assert c["repartitions"] > 0


def test_repartition_rides_migration_machinery():
    """Repartition deltas must be *in-flight* migrations, counted in
    migrations/mig_flows so the cost model prices the state transfer.
    With repartition_every=6 (partitioner "random": a fresh permutation
    each time, so deltas are guaranteed) the bulk moves are issued
    exactly at steps 6 and 12 — never in between — and every issued
    move appears in the per-pair flow matrix."""
    cfg = dataclasses.replace(
        ENGINE, abm=dataclasses.replace(ABM, partitioner="random"),
        repartition_every=6, timesteps=14)
    _, series, counters = _run(cfg)
    reparts = np.asarray(series["repartitions"])
    migs = np.asarray(series["migrations"])
    assert (reparts == migs).all()  # gaia_off: all migrations are reparts
    fired = np.nonzero(reparts)[0].tolist()
    assert fired == [6, 12], reparts
    # flow matrix totals match the issued moves (priced by wct_env)
    mig_flows = np.asarray(series["mig_flows"]).sum(axis=(1, 2))
    np.testing.assert_array_equal(mig_flows, migs)


def test_repartition_applies_after_protocol_delay():
    """The Fig. 4 in-flight protocol must gate the map change: a delta
    issued at step 6 with migration_delay=5 becomes active at step 11 —
    the lp map is untouched on steps 6..10 and changed at 11."""
    cfg = dataclasses.replace(
        ENGINE, abm=dataclasses.replace(ABM, partitioner="random"),
        repartition_every=6, migration_delay=5)
    from repro.core.engine import init_engine, step
    step_fn = jax.jit(step, static_argnums=1)
    st = init_engine(jax.random.key(11), cfg)
    lp0 = np.asarray(st["lp"])
    lp_at = {}
    for t in range(13):
        st, _ = step_fn(st, cfg)
        lp_at[t] = np.asarray(st["lp"])
    for t in range(11):  # map frozen while deltas are in flight
        np.testing.assert_array_equal(lp_at[t], lp0, err_msg=str(t))
    assert (lp_at[11] != lp0).any()  # ...and lands at 6 + 5


def test_repartition_improves_lcr_on_hotspot():
    """Sanity of the whole point: on a clustered workload a periodic
    kmeans repartition must beat the static random map on LCR."""
    abm = dataclasses.replace(ABM, mobility="hotspot", n_groups=4,
                              group_radius=120.0)
    base = dataclasses.replace(ENGINE, abm=abm, timesteps=30)
    _, _, c_rand = _run(base)
    _, _, c_km = _run(dataclasses.replace(
        base, abm=dataclasses.replace(abm, partitioner="kmeans"),
        repartition_every=10))
    assert c_km["mean_lcr"] > c_rand["mean_lcr"] + 0.2, (
        c_km["mean_lcr"], c_rand["mean_lcr"])


def test_partitioner_validation():
    with pytest.raises(ValueError):
        dataclasses.replace(ABM, partitioner="metis")
    with pytest.raises(ValueError):
        part.PartitionConfig(backend="nope")
    with pytest.raises(ValueError):
        part.PartitionConfig(shares=(0.5, 0.5), n_lp=4)
    with pytest.raises(ValueError):
        dataclasses.replace(ENGINE, repartition_every=-1)
    with pytest.raises(ValueError):
        part.PartitionConfig(fuzzy_m=1.0)  # must be > 1 (m=1 is hard)
    with pytest.raises(ValueError):
        part.PartitionConfig(hysteresis=-0.1)


# ---------------------------------------------------------------------------
# voronoi / fuzzy backend
# ---------------------------------------------------------------------------


def test_voronoi_registered_and_uses_prev():
    assert "voronoi" in part.PARTITION_BACKENDS
    assert part.uses_prev(part.PartitionConfig(backend="voronoi"))
    assert not part.uses_prev(part.PartitionConfig(backend="kmeans"))


def test_voronoi_hysteresis_reduces_churn():
    """The fuzzy-membership bonus on the previous assignment must cut
    migration churn: re-partitioning slightly-moved positions with the
    old map as `prev` keeps strictly more SEs in place than a memoryless
    recompute."""
    n, n_lp, area = 256, 4, 1000.0
    k = jax.random.key(5)
    pos = jax.random.uniform(k, (n, 2), maxval=area)
    w = jnp.ones((n,), jnp.float32)
    cfg = part.PartitionConfig(backend="voronoi", n_lp=n_lp, area=area,
                               iters=5, hysteresis=0.3)
    lp0 = part.partition(jax.random.key(7), pos, w, cfg)
    # small drift, fresh seed key: plenty of borderline SEs to flip
    pos2 = (pos + jax.random.normal(jax.random.fold_in(k, 1), (n, 2)) * 5.0
            ) % area
    k2 = jax.random.key(8)
    churn_free = int((part.partition(k2, pos2, w, cfg) != lp0).sum())
    churn_held = int((part.partition(k2, pos2, w, cfg, prev=lp0) != lp0)
                     .sum())
    assert churn_held < churn_free, (churn_held, churn_free)


def test_voronoi_seed_carry_reduces_churn():
    """Seed carry-over, isolated from the membership bonus
    (hysteresis=0): warm-starting the tessellation from `prev`'s per-LP
    centroids must keep more SEs in place across consecutive
    repartitions than cold key-drawn seeds — the two maps now share a
    tessellation, not only an assignment. Carry stays deterministic:
    same (key, pos, weights, prev) -> same map."""
    n, n_lp, area = 256, 4, 1000.0
    k = jax.random.key(5)
    pos = jax.random.uniform(k, (n, 2), maxval=area)
    w = jnp.ones((n,), jnp.float32)
    cfg = part.PartitionConfig(backend="voronoi", n_lp=n_lp, area=area,
                               iters=5, hysteresis=0.0)
    lp0 = part.partition(jax.random.key(7), pos, w, cfg)
    pos2 = (pos + jax.random.normal(jax.random.fold_in(k, 1), (n, 2)) * 5.0
            ) % area
    # an adversarial fresh key: cold seeds land in an unrelated layout,
    # so the memoryless recompute relabels wholesale
    k2 = jax.random.key(8)
    churn_cold = int((part.partition(k2, pos2, w, cfg) != lp0).sum())
    warm = part.partition(k2, pos2, w, cfg, prev=lp0)
    churn_warm = int((warm != lp0).sum())
    assert churn_warm < churn_cold, (churn_warm, churn_cold)
    np.testing.assert_array_equal(
        np.asarray(warm),
        np.asarray(part.partition(k2, pos2, w, cfg, prev=lp0)))


def test_voronoi_geometry_informed():
    """Fuzzy Voronoi must actually read the geometry: on four tight
    blobs it should recover a near-perfect blob->LP map (every blob
    dominated by one LP), which the random baseline cannot do."""
    n_per, n_lp, area = 64, 4, 1000.0
    centers = jnp.array([[200.0, 200.0], [800.0, 200.0],
                         [200.0, 800.0], [800.0, 800.0]])
    k = jax.random.key(9)
    pos = (jnp.repeat(centers, n_per, axis=0)
           + jax.random.normal(k, (4 * n_per, 2)) * 20.0) % area
    w = jnp.ones((4 * n_per,), jnp.float32)
    cfg = part.PartitionConfig(backend="voronoi", n_lp=n_lp, area=area,
                               iters=10)
    lp = np.asarray(part.partition(jax.random.key(1), pos, w, cfg))
    purity = 0
    for b in range(4):
        blob = lp[b * n_per:(b + 1) * n_per]
        purity += np.bincount(blob, minlength=n_lp).max()
    assert purity >= 0.9 * 4 * n_per, purity / (4 * n_per)


# ---------------------------------------------------------------------------
# hypothesis properties (section gated: `hypothesis` is an optional dev
# dependency; the contracts above must run without it)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    def _case(draw):
        n_lp = draw(st.integers(2, 5))
        n = draw(st.integers(n_lp, 80))
        seed = draw(st.integers(0, 2**16))
        backend = draw(st.sampled_from(part.PARTITION_BACKENDS))
        cfg = part.PartitionConfig(
            backend=backend, n_lp=n_lp, area=1000.0, interaction_range=120.0,
            iters=3, imbalance=draw(st.sampled_from([0.0, 0.1])))
        # positions from a PRNG draw: continuous, collision-free (exact ties
        # would make greedy tie-breaking order-dependent by design)
        pos = jax.random.uniform(jax.random.key(seed), (n, 2), maxval=cfg.area)
        return cfg, jax.random.key(seed + 1), pos, jnp.ones((n,), jnp.float32)


    @given(st.data())
    def test_every_se_gets_exactly_one_valid_lp(data):
        cfg, key, pos, w = _case(data.draw)
        lp = np.asarray(part.partition(key, pos, w, cfg))
        assert lp.shape == (pos.shape[0],)
        assert ((lp >= 0) & (lp < cfg.n_lp)).all(), (cfg.backend, lp)


    @given(st.data())
    def test_load_within_declared_capacity_bound(data):
        cfg, key, pos, w = _case(data.draw)
        lp = np.asarray(part.partition(key, pos, w, cfg))
        loads = np.bincount(lp, minlength=cfg.n_lp)
        caps = np.asarray(part.capacity_bounds(cfg, float(w.sum())))
        assert (loads <= caps).all(), (cfg.backend, loads, caps)


    @given(st.data())
    def test_deterministic_for_fixed_key(data):
        cfg, key, pos, w = _case(data.draw)
        a = np.asarray(part.partition(key, pos, w, cfg))
        b = np.asarray(part.partition(key, pos, w, cfg))
        np.testing.assert_array_equal(a, b)


    @given(st.data())
    def test_kmeans_stripe_permutation_equivariant(data):
        """Relabeling the SEs must relabel the map: lp(perm(pos)) ==
        perm(lp(pos)) for the geometry-only backends (random is a
        permutation by design; bestresponse's graph sampling shares the
        greedy core but is exempted only because its affinity ties are
        integer-valued and genuinely order-broken)."""
        cfg, key, pos, w = _case(data.draw)
        cfg = dataclasses.replace(cfg,
                                  backend=data.draw(st.sampled_from(
                                      ("stripe", "kmeans"))))
        perm = np.asarray(jax.random.permutation(
            jax.random.key(99), jnp.arange(pos.shape[0])))
        lp1 = np.asarray(part.partition(key, pos, w, cfg))
        lp2 = np.asarray(part.partition(key, pos[perm], w[perm], cfg))
        np.testing.assert_array_equal(lp1[perm], lp2, err_msg=cfg.backend)

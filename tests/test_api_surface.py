"""Pin the supported public surface of `repro.core`.

`repro.core.__all__` is the contract the README documents and the
deprecation policy protects: names leave it only through a deprecation
cycle, and new names join it deliberately. This snapshot makes either
move an explicit diff in review instead of an accident.
"""
import warnings

import repro.core

#: the pinned surface — update ONLY alongside README §Service API
PINNED = sorted([
    # configs
    "ABMConfig", "EngineConfig", "HeuristicConfig", "PartitionConfig",
    # the resident engine service
    "Engine", "ReplicaService",
    # registries
    "MOBILITY_MODELS", "PROXIMITY_BACKENDS", "PARTITION_BACKENDS",
    "SETUPS", "DISTRIBUTED", "PARALLEL",
    # cost model
    "CostParams", "ExecutionEnvironment", "make_env", "wct", "wct_env",
    "wire_cost",
    # neighbor search
    "GridSpec", "build_grid", "grid_lp_counts", "make_grid_spec",
    # statistics
    "merge_counters", "percentile", "replica_stats", "summarize",
])


def test_public_surface_is_pinned():
    assert sorted(repro.core.__all__) == PINNED


def test_every_public_name_resolves():
    for name in repro.core.__all__:
        assert getattr(repro.core, name) is not None, name


def test_public_names_do_not_warn_on_access():
    # touching the supported surface must never trip a DeprecationWarning
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        for name in repro.core.__all__:
            getattr(repro.core, name)


def test_legacy_names_remain_importable_outside_all():
    # the shims stay importable for one deprecation cycle, but are
    # deliberately NOT part of the supported surface
    for legacy in ("run", "run_batch"):
        assert hasattr(repro.core, legacy)
        assert legacy not in repro.core.__all__

"""Property-based tests for the §4.3 self-clustering heuristics.

Randomized traces pin the window semantics the hand-stepped unit tests
(test_heuristics.py) only spot-check:

  * #2 with omega = kappa degenerates to #1 on one-event-per-step
    traces (every SE sends every timestep — the windows hold exactly
    the same kappa histograms);
  * the alpha > MF gate is monotone: raising MF never admits a new
    candidate;
  * MT is never violated: an emitted candidate always has
    t - last_mig >= mt.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional dev dependency "
    "`hypothesis` (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core.heuristics import (HeuristicConfig, evaluate, init_state,
                                   update_window)

settings.register_profile("ci", deadline=None, max_examples=30)
settings.load_profile("ci")


def _trace(draw, n_se_max=6, n_lp_max=4, t_max=8, all_senders=False):
    n_lp = draw(st.integers(2, n_lp_max))
    n_se = draw(st.integers(1, n_se_max))
    steps = draw(st.integers(1, t_max))
    counts = draw(st.lists(
        st.lists(st.lists(st.integers(0, 5), min_size=n_lp, max_size=n_lp),
                 min_size=n_se, max_size=n_se),
        min_size=steps, max_size=steps))
    if all_senders:
        senders = [[True] * n_se] * steps
    else:
        senders = draw(st.lists(
            st.lists(st.booleans(), min_size=n_se, max_size=n_se),
            min_size=steps, max_size=steps))
    lp = draw(st.lists(st.integers(0, n_lp - 1), min_size=n_se,
                       max_size=n_se))
    return (n_se, n_lp, jnp.asarray(counts, jnp.int32),
            jnp.asarray(senders, bool), jnp.asarray(lp, jnp.int32))


def _push_trace(cfg, n_se, n_lp, counts, senders):
    s = init_state(cfg, n_se, n_lp)
    for t in range(counts.shape[0]):
        s = update_window(cfg, s, counts[t], senders[t], t)
    return s


@given(st.data())
def test_h2_equals_h1_on_one_event_per_step_traces(data):
    """omega = kappa and every SE sends every step: the event window IS
    the timestep window, so #1 and #2 agree on candidates/dest/alpha."""
    n_se, n_lp, counts, senders, lp = _trace(data.draw, all_senders=True)
    w = data.draw(st.integers(1, 5))
    cfg1 = HeuristicConfig(kind=1, mf=1.2, mt=0, kappa=w)
    cfg2 = HeuristicConfig(kind=2, mf=1.2, mt=0, omega=w)
    s1 = _push_trace(cfg1, n_se, n_lp, counts, senders)
    s2 = _push_trace(cfg2, n_se, n_lp, counts, senders)
    t = counts.shape[0]
    c1, d1, a1, _, _ = evaluate(cfg1, s1, lp, t)
    c2, d2, a2, _, _ = evaluate(cfg2, s2, lp, t)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    # dest only meaningful where some external traffic exists
    ext = np.asarray(a1) > 0
    np.testing.assert_array_equal(np.asarray(d1)[ext], np.asarray(d2)[ext])


@given(st.data())
def test_alpha_threshold_monotone_in_mf(data):
    """Candidates at a higher MF are a subset of those at a lower MF."""
    n_se, n_lp, counts, senders, lp = _trace(data.draw)
    mf_lo = data.draw(st.floats(0.1, 5.0, allow_nan=False))
    mf_hi = mf_lo + data.draw(st.floats(0.1, 5.0, allow_nan=False))
    kind = data.draw(st.sampled_from([1, 2]))
    base = dict(kind=kind, mt=0, kappa=4, omega=4)
    s = _push_trace(HeuristicConfig(mf=mf_lo, **base), n_se, n_lp,
                    counts, senders)
    t = counts.shape[0]
    c_lo, *_ = evaluate(HeuristicConfig(mf=mf_lo, **base), s, lp, t)
    c_hi, *_ = evaluate(HeuristicConfig(mf=mf_hi, **base), s, lp, t)
    assert not np.any(np.asarray(c_hi) & ~np.asarray(c_lo))


@given(st.data())
def test_mt_never_violated_by_candidates(data):
    """No emitted candidate migrated fewer than mt steps ago."""
    n_se, n_lp, counts, senders, lp = _trace(data.draw)
    mt = data.draw(st.integers(0, 12))
    t_eval = counts.shape[0]
    last_mig = jnp.asarray(
        data.draw(st.lists(st.integers(-5, t_eval), min_size=n_se,
                           max_size=n_se)), jnp.int32)
    kind = data.draw(st.sampled_from([1, 2, 3]))
    cfg = HeuristicConfig(kind=kind, mf=0.0, mt=mt, kappa=4, omega=4,
                          zeta=1)
    s = _push_trace(cfg, n_se, n_lp, counts, senders)
    s = dict(s, last_mig=last_mig)
    cand, *_ = evaluate(cfg, s, lp, t_eval)
    cand = np.asarray(cand)
    ok = (t_eval - np.asarray(last_mig)) >= mt
    assert not np.any(cand & ~ok)

"""Per-architecture smoke tests (assignment requirement): a REDUCED
config of the same family runs one real train step and one decode step
on CPU; outputs have the right shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.configs.base import ShapeConfig
from repro.launch.steps import build_serve_step, build_train_step
from repro.parallel.ctx import make_ctx

PX = make_ctx(None, q_block=32, kv_block=32)
TRAIN = ShapeConfig("smoke_train", seq_len=32, global_batch=2, kind="train")
DECODE = ShapeConfig("smoke_dec", seq_len=64, global_batch=2, kind="decode")

# tier-1 keeps one dense + one MoE representative; the heavy smoke
# compiles (6-30s each) run in the nightly `slow` job
_SLOW_TRAIN = {"deepseek-v3-671b", "zamba2-1.2b", "qwen2-7b",
               "seamless-m4t-medium", "rwkv6-1.6b", "internvl2-2b",
               "yi-9b", "qwen3-moe-30b-a3b"}


def _train_params():
    return [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_TRAIN
            else a for a in sorted(ARCHS)]


def _materialize(tree):
    return jax.tree.map(
        lambda s: (jax.random.normal(jax.random.key(hash(s.shape) % 2**31),
                                     s.shape, jnp.float32) * 0.02
                   ).astype(s.dtype)
        if jnp.issubdtype(s.dtype, jnp.floating)
        else jnp.zeros(s.shape, s.dtype), tree)


def _batch_for(sds):
    out = {}
    for k, s in sds.items():
        if k == "tokens":
            out[k] = jnp.abs(jax.random.randint(jax.random.key(1), s.shape,
                                                0, 100)).astype(s.dtype)
        elif k == "loss_mask":
            out[k] = jnp.ones(s.shape, s.dtype)
        else:
            out[k] = jnp.ones(s.shape, s.dtype) * 0.1
    return out


@pytest.mark.parametrize("arch", _train_params())
def test_train_step_smoke(arch):
    from repro.models import lm as lm_mod
    from repro.optim.adamw import adamw_init
    cfg = get_smoke(arch)
    b = build_train_step(cfg, TRAIN, PX)
    params = lm_mod.init_params(jax.random.key(0), cfg)
    opt_state = adamw_init(params)
    extras = lm_mod.init_extras(cfg)
    batch = _batch_for(b.in_sds[3])
    fn = jax.jit(b.fn)
    p2, o2, e2, metrics = fn(params, opt_state, extras, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, loss
    # params actually moved
    moved = jax.tree.map(lambda a, b_: float(jnp.abs(a.astype(jnp.float32)
                                                     - b_.astype(jnp.float32)
                                                     ).max()), params, p2)
    assert max(jax.tree.leaves(moved)) > 0
    for leaf in jax.tree.leaves(p2):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow)
    if a != "tinyllama-1.1b" else a for a in sorted(ARCHS)])
def test_serve_step_smoke(arch):
    cfg = get_smoke(arch)
    if not ARCHS[arch].has_decoder:
        pytest.skip("no decoder")
    from repro.models import lm as lm_mod
    b = build_serve_step(cfg, DECODE, PX)
    params = lm_mod.init_params(jax.random.key(0), cfg)
    extras = lm_mod.init_extras(cfg)
    cache = _materialize(b.in_sds[2])
    tokens = jnp.zeros(b.in_sds[3].shape, jnp.int32) + 5
    pos = jnp.int32(3)
    fn = jax.jit(b.fn)
    new_cache, next_tokens = fn(params, extras, cache, tokens, pos)
    assert next_tokens.shape == (DECODE.global_batch,)
    assert np.all(np.asarray(next_tokens) >= 0)
    assert np.all(np.asarray(next_tokens) < cfg.padded_vocab)
    # cache structurally unchanged
    jax.tree.map(lambda a, b_: None if a.shape == b_.shape else 1 / 0,
                 b.in_sds[2], new_cache)


def test_decode_matches_prefill_logits():
    """Greedy decode after prefill reproduces the full-forward logits of
    the next position (dense smoke arch) — the KV cache is consistent."""
    from repro.models import lm as lm_mod
    cfg = get_smoke("tinyllama-1.1b")
    key = jax.random.key(0)
    params = lm_mod.init_params(key, cfg)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (2, 16), 0, 200)
    # full forward over S+1 tokens gives logits at position S-1
    batch_full = {"tokens": toks}
    cache, logits_prefill = lm_mod.prefill(params, batch_full, cfg, PX,
                                           cache_len=32)
    # decode one token: feed token S-1... logits should match a prefill
    # that included it (teacher forcing)
    nxt = toks[:, -1]
    new_cache, logits_dec = lm_mod.decode_step(
        params, cache, nxt, jnp.int32(16), lm_mod.init_extras(cfg), cfg, PX)
    batch2 = {"tokens": jnp.concatenate(
        [toks, nxt[:, None]], axis=1)}
    _, logits_ref = lm_mod.prefill(params, batch2, cfg, PX, cache_len=32)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_ref[:, 0], np.float32),
                               atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("arch", [
    "rwkv6-1.6b", pytest.param("zamba2-1.2b", marks=pytest.mark.slow)])
def test_recurrent_decode_matches_prefill(arch):
    """Chunked-prefill state == step-by-step decode state for the
    recurrent families (rwkv6 / mamba2-hybrid)."""
    from repro.models import lm as lm_mod
    cfg = get_smoke(arch)
    key = jax.random.key(2)
    params = lm_mod.init_params(key, cfg)
    S = 16
    toks = jax.random.randint(jax.random.fold_in(key, 3), (1, S), 0, 200)
    cache, logits_pre = lm_mod.prefill(params, {"tokens": toks}, cfg, PX,
                                       cache_len=S + 8)
    # continue decoding one step; must not NaN and must be deterministic
    nc, logits = lm_mod.decode_step(params, cache, toks[:, -1],
                                    jnp.int32(S), {}, cfg, PX)
    nc2, logits2 = lm_mod.decode_step(params, cache, toks[:, -1],
                                      jnp.int32(S), {}, cfg, PX)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits2))

"""Per-kernel shape/dtype sweeps: Pallas (interpret mode, which executes
the kernel body on CPU) vs. the pure-jnp oracle in each kernel's ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.flash_decode import flash_decode
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.moe_gate.moe_gate import moe_gate
from repro.kernels.moe_gate.ref import moe_gate_ref
from repro.kernels.proximity.proximity import proximity_lp_counts
from repro.kernels.proximity.ref import proximity_lp_counts_ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,s,d", [(2, 128, 64), (4, 256, 64), (1, 512, 128),
                                    (3, 384, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(bh, s, d, causal, dtype):
    k = jax.random.key(bh * s + d + causal)
    q = _rand(jax.random.fold_in(k, 0), (bh, s, d), dtype)
    kk = _rand(jax.random.fold_in(k, 1), (bh, s, d), dtype)
    v = _rand(jax.random.fold_in(k, 2), (bh, s, d), dtype)
    out = flash_attention(q, kk, v, causal=causal, interpret=True)
    ref = attention_ref(q, kk, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_cross_lengths():
    """Skv != Sq (cross-attention / enc-dec shapes)."""
    k = jax.random.key(9)
    q = _rand(jax.random.fold_in(k, 0), (2, 128, 64), jnp.float32)
    kk = _rand(jax.random.fold_in(k, 1), (2, 384, 64), jnp.float32)
    v = _rand(jax.random.fold_in(k, 2), (2, 384, 64), jnp.float32)
    out = flash_attention(q, kk, v, causal=False, interpret=True)
    ref = attention_ref(q, kk, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,hkv,s,d", [(2, 8, 2, 512, 64), (1, 4, 4, 1024, 64),
                                         (3, 8, 1, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(b, h, hkv, s, d, dtype):
    k = jax.random.key(b + h + s)
    q = _rand(jax.random.fold_in(k, 0), (b, h, d), dtype)
    kc = _rand(jax.random.fold_in(k, 1), (b, s, hkv, d), dtype)
    vc = _rand(jax.random.fold_in(k, 2), (b, s, hkv, d), dtype)
    for pos in (0, s // 3, s - 1):
        out = flash_decode(q, kc, vc, jnp.int32(pos), interpret=True)
        ref = decode_ref(q, kc, vc, jnp.int32(pos))
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# moe gate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,e,k", [(256, 16, 2), (512, 64, 8), (128, 8, 1)])
@pytest.mark.parametrize("use_bias", [False, True])
@pytest.mark.parametrize("norm_topk", [True, False])
def test_moe_gate_sweep(t, e, k, use_bias, norm_topk):
    key = jax.random.key(t + e + k)
    logits = jax.random.normal(key, (t, e), jnp.float32) * 2.0
    bias = (jax.random.normal(jax.random.fold_in(key, 1), (e,), jnp.float32)
            * 0.1 if use_bias else None)
    p1, e1, c1 = moe_gate(logits, k, bias=bias, norm_topk=norm_topk,
                          interpret=True)
    p0, e0, c0 = moe_gate_ref(logits, k, bias=bias, norm_topk=norm_topk)
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e0))
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p0), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c0))


# ---------------------------------------------------------------------------
# proximity (the ABM hot spot)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,n_lp,rng", [(128, 4, 60.0), (256, 8, 120.0),
                                        (192, 3, 250.0)])
def test_proximity_sweep(n, n_lp, rng):
    key = jax.random.key(n + n_lp)
    pos = jax.random.uniform(key, (n, 2), maxval=1000.0)
    lp = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, n_lp)
    sender = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.4, (n,))
    got = proximity_lp_counts(pos, lp, sender, n_lp, 1000.0, rng,
                              interpret=True)
    ref = proximity_lp_counts_ref(pos, lp, sender, n_lp, 1000.0, rng)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_proximity_toroidal_edge():
    """Pairs straddling the wrap line must count (distance via the torus)."""
    pos = jnp.array([[2.0, 2.0], [998.0, 998.0], [500.0, 500.0]])
    lp = jnp.array([0, 1, 1], jnp.int32)
    sender = jnp.array([True, True, True])
    got = np.asarray(proximity_lp_counts(pos, lp, sender, 2, 1000.0, 10.0,
                                         interpret=True))
    assert got[0, 1] == 1 and got[1, 0] == 1 and got[2].sum() == 0


def test_proximity_nonsenders_zero():
    key = jax.random.key(3)
    pos = jax.random.uniform(key, (64, 2), maxval=100.0)
    lp = jnp.zeros((64,), jnp.int32)
    sender = jnp.zeros((64,), bool)
    got = np.asarray(proximity_lp_counts(pos, lp, sender, 2, 100.0, 50.0,
                                         interpret=True))
    assert got.sum() == 0

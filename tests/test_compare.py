"""Unit tests for the bench-regression gate (benchmarks/compare.py).

The gate's decision rule has two layers: the legacy tolerance bound on
the metric mean, and — for metrics in the mean/std/ci95/n replica
schema — interval separation: a worsened mean only fails when the 95%
confidence intervals of baseline and candidate do not overlap. These
tests pin both layers plus the old-schema compatibility path (plain
floats keep the pure-tolerance behaviour; old-schema baselines against
new-schema currents warn but still compare).
"""
import json

import pytest

from benchmarks import compare


def _stats(mean, ci95, n=5):
    return {"mean": mean, "std": ci95, "ci95": ci95, "n": n}


# ---------------------------------------------------------------------------
# check_metric: the decision rule
# ---------------------------------------------------------------------------


def test_legacy_point_estimates_keep_tolerance_rule():
    # within tolerance: ok
    ok, bound, _ = compare.check_metric("higher", 0.20, 0.9, 1.0)
    assert ok and bound == pytest.approx(0.8)
    # beyond tolerance: zero-width intervals always "separate" -> fail
    ok, _, _ = compare.check_metric("higher", 0.20, 0.7, 1.0)
    assert not ok
    ok, _, _ = compare.check_metric("lower", 0.20, 1.3, 1.0)
    assert not ok
    ok, _, _ = compare.check_metric("lower", 0.20, 1.1, 1.0)
    assert ok


def test_interval_overlap_suppresses_regression():
    """Mean beyond the bound, but wide CIs overlap: the gate must read
    it as noise, not regression — the whole point of replicas."""
    cur, base = _stats(0.70, ci95=0.25), _stats(1.0, ci95=0.25)
    ok, _, note = compare.check_metric("higher", 0.20, cur, base)
    assert ok and "within noise" in note


def test_interval_separation_fires():
    cur, base = _stats(0.70, ci95=0.05), _stats(1.0, ci95=0.05)
    ok, _, note = compare.check_metric("higher", 0.20, cur, base)
    assert not ok and note == ""


def test_within_tolerance_needs_no_separation():
    """A mean inside the tolerance band passes regardless of interval
    width (the gate only ever *relaxes* with replicas, never
    tightens)."""
    ok, _, _ = compare.check_metric("higher", 0.20, _stats(0.9, 0.001),
                                    _stats(1.0, 0.001))
    assert ok


def test_mixed_schema_uses_available_interval():
    # legacy current vs stats baseline: baseline interval alone can
    # still cover the delta
    ok, _, _ = compare.check_metric("higher", 0.20, 0.7,
                                    _stats(1.0, ci95=0.4))
    assert ok
    ok, _, _ = compare.check_metric("higher", 0.20, 0.7,
                                    _stats(1.0, ci95=0.1))
    assert not ok


# ---------------------------------------------------------------------------
# compare_file: schema compatibility + missing-data discipline
# ---------------------------------------------------------------------------


def _write(path, doc):
    path.write_text(json.dumps(doc))


def test_old_schema_baseline_warns_but_compares(tmp_path):
    cur = tmp_path / "BENCH_x.json"
    base = tmp_path / "base" / "BENCH_x.json"
    base.parent.mkdir()
    _write(cur, {"gate": {"m": _stats(0.95, ci95=0.1)}})
    _write(base, {"gate": {"m": 1.0}})  # old point-estimate schema
    with pytest.warns(DeprecationWarning, match="old-schema"):
        rows = list(compare.compare_file(str(cur), str(base),
                                         {"gate.m": ("higher", 0.20)}))
    assert [s for _, s, _ in rows] == ["ok"]


def test_new_schema_baseline_does_not_warn(tmp_path):
    cur = tmp_path / "BENCH_x.json"
    base = tmp_path / "base" / "BENCH_x.json"
    base.parent.mkdir()
    _write(cur, {"gate": {"m": _stats(0.5, ci95=0.01)}})
    _write(base, {"gate": {"m": _stats(1.0, ci95=0.01)}})
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error", DeprecationWarning)
        rows = list(compare.compare_file(str(cur), str(base),
                                         {"gate.m": ("higher", 0.20)}))
    assert [s for _, s, _ in rows] == ["fail"]  # separated regression


def test_missing_baseline_metric_fails(tmp_path):
    cur = tmp_path / "BENCH_x.json"
    base = tmp_path / "base" / "BENCH_x.json"
    base.parent.mkdir()
    _write(cur, {"gate": {"m": 1.0}})
    _write(base, {"gate": {}})
    rows = list(compare.compare_file(str(cur), str(base),
                                     {"gate.m": ("higher", 0.20)}))
    assert [s for _, s, _ in rows] == ["fail"]


def test_is_stats_as_stats_stay_in_sync():
    """`repro.core.stats.is_stats` and `compare.as_stats` re-state the
    same schema-detection rule on opposite sides of the PYTHONPATH
    boundary (compare.py must import without src/). One shared fixture
    sweeps the cases: whenever `as_stats` accepts a value, its
    is_legacy flag must be the exact negation of `is_stats`; whenever
    `as_stats` rejects (loud float() failure), `is_stats` must already
    have said 'not a stats dict'."""
    from repro.core import stats

    fixtures = [
        1.0,                                              # legacy float
        3,                                                # legacy int
        _stats(0.5, ci95=0.1),                            # full schema
        {"mean": 7.5, "std": 0.0, "ci95": 0.0, "n": 1},   # n=1 point est.
        {**_stats(0.5, ci95=0.1), "unit": "s"},           # extra keys ok
        {"mean": 1.0},                                    # partial dict
        {"mean": 1.0, "std": 0.0, "ci95": 0.0},           # missing n
        {"any": True, "count": 1, "n": 3},                # flag shape
        {"nested": {"mean": 1.0}},                        # mis-pointed path
    ]
    for v in fixtures:
        try:
            _, _, legacy = compare.as_stats(v)
        except (TypeError, ValueError):
            assert not stats.is_stats(v), v
        else:
            assert stats.is_stats(v) == (not legacy), v
    # and the n=1 degenerate case really is a zero-width interval
    mean, ci95, legacy = compare.as_stats(stats.replica_stats([7.5]))
    assert (mean, ci95, legacy) == (7.5, 0.0, False)


def test_main_exit_codes(tmp_path, capsys):
    basedir = tmp_path / "BENCH_baseline"
    basedir.mkdir()
    doc = {"loop_ratio": 1.05,
           "metrics": {"mean_lcr": _stats(0.75, ci95=0.02)}}
    _write(tmp_path / "BENCH_replicas.json", doc)
    _write(basedir / "BENCH_replicas.json", doc)
    argv = ["--baseline-dir", str(basedir), "--current-dir", str(tmp_path),
            "BENCH_replicas.json"]
    assert compare.main(argv) == 0
    # candidate collapses far below the interval: gate must fire
    bad = {"loop_ratio": 1.05,
           "metrics": {"mean_lcr": _stats(0.30, ci95=0.02)}}
    _write(tmp_path / "BENCH_replicas.json", bad)
    assert compare.main(argv) == 1

"""Cost-model tests (paper §3, Eqs. 1–6) and the heterogeneous
execution-environment layer (per-LP speeds + pairwise link classes)."""
import numpy as np
import pytest

from repro.core.costmodel import (DISTRIBUTED, PARALLEL, ExecutionEnvironment,
                                  amdahl, hetero_speed_env, homogeneous_env,
                                  make_env, two_site_env, wct, wct_env)


BASE = {"local_msgs": 1e6, "remote_msgs": 1e6, "migrations": 0.0,
        "heu_evals": 0.0}


def _flows(n_lp=4, local=2.5e5, remote=None, total_remote=3e6):
    """Balanced (L, L) flow matrix: `local` on the diagonal, the remote
    volume spread evenly off-diagonal."""
    remote = total_remote / (n_lp * (n_lp - 1)) if remote is None else remote
    f = np.full((n_lp, n_lp), remote)
    np.fill_diagonal(f, local)
    return f.tolist()


def test_amdahl_bounds():
    assert amdahl(1, 0.05) == pytest.approx(1.0)
    for n in (2, 4, 16):
        assert 1.0 < amdahl(n, 0.05) < n
    # s -> 0 recovers linear speedup
    assert amdahl(8, 0.0) == pytest.approx(8.0)


def test_tec_decomposition_sums():
    out = wct(dict(BASE, migrations=1e3, heu_evals=1e5), PARALLEL,
              n_lp=4, timesteps=1200, interaction_bytes=100,
              migration_bytes=20480)
    parts = (out["MCC"] + out["LCC"] + out["RCC"] + out["SC"] + out["MMC"]
             + out["MigCPU"] + out["MigComm"] + out["Heu"])
    assert out["TEC"] == pytest.approx(parts)
    assert out["MigC"] == pytest.approx(
        out["MigCPU"] + out["MigComm"] + out["Heu"])


def test_remote_messages_cost_more_than_local():
    """Paper §3: remote interactions cost more than local ones, with the
    separation growing from shared memory to the LAN (batched-delivery
    calibration: marshaling + bandwidth, latency in the barrier)."""
    for p, floor in ((PARALLEL, 1.0), (DISTRIBUTED, 5.0)):
        local = wct(dict(BASE, remote_msgs=0.0), p, 4, 1200)["LCC"]
        remote = wct(dict(BASE, local_msgs=0.0), p, 4, 1200)["RCC"]
        assert remote > floor * local, (p.name, remote, local)
    # LAN remote messages cost much more than shared-memory remote ones,
    # and the per-byte separation is ~45x (GbE path vs memcpy)
    kw = dict(interaction_bytes=1024)
    r_par = wct(dict(BASE, local_msgs=0.0), PARALLEL, 4, 1200, **kw)["RCC"]
    r_dis = wct(dict(BASE, local_msgs=0.0), DISTRIBUTED, 4, 1200, **kw)["RCC"]
    assert r_dis > 10 * r_par


def test_clustering_tradeoff_sign():
    """Converting remote->local deliveries must lower TEC when MigC is
    small, and a huge migration payload can flip the sign (Table 3's
    negative rows)."""
    before = wct(BASE, DISTRIBUTED, 4, 1200, interaction_bytes=1024)
    clustered = dict(BASE, local_msgs=1.8e6, remote_msgs=0.2e6,
                     migrations=5e3, heu_evals=1e6)
    after_cheap = wct(clustered, DISTRIBUTED, 4, 1200,
                      interaction_bytes=1024, migration_bytes=32)
    assert after_cheap["TEC"] < before["TEC"]
    # per-migration byte cost high enough to erase the gain
    after_heavy = wct(dict(clustered, migrations=4e5), DISTRIBUTED, 4, 1200,
                      interaction_bytes=1, migration_bytes=81920)
    assert after_heavy["TEC"] > wct(BASE, DISTRIBUTED, 4, 1200,
                                    interaction_bytes=1)["TEC"]


def test_heuristic_cost_scales_with_evals():
    a = wct(dict(BASE, heu_evals=1e6), PARALLEL, 4, 1200)
    b = wct(dict(BASE, heu_evals=2e6), PARALLEL, 4, 1200)
    assert b["Heu"] == pytest.approx(2 * a["Heu"])
    assert b["TEC"] > a["TEC"]


def test_more_lps_cut_compute_term():
    t4 = wct(BASE, PARALLEL, 4, 1200)["MCC"]
    t16 = wct(BASE, PARALLEL, 16, 1200)["MCC"]
    assert t16 < t4


# ---------------------------------------------------------------------------
# heterogeneous execution environments
# ---------------------------------------------------------------------------


def test_env_validation():
    with pytest.raises(ValueError):  # unknown link class
        ExecutionEnvironment("x", (1.0, 1.0),
                             (("shm", "carrier-pigeon"),) * 2)
    with pytest.raises(ValueError):  # non-square link matrix
        ExecutionEnvironment("x", (1.0, 1.0), (("shm",),) * 2)
    with pytest.raises(ValueError):  # non-positive speed
        ExecutionEnvironment("x", (1.0, 0.0), (("shm", "shm"),) * 2)
    with pytest.raises(ValueError):
        make_env("fog", 4)
    assert sum(hetero_speed_env(6).capacity_shares()) == pytest.approx(1.0)


def test_homogeneous_env_reduces_to_scalar_model():
    """On balanced flows and equal unit speeds, wct_env == wct: the
    per-LP bottleneck collapses to Amdahl and the link pricing to the
    scalar remote path (shm == PARALLEL, lan == DISTRIBUTED)."""
    c = dict(BASE, local_msgs=1e6, remote_msgs=3e6,
             lp_flows=_flows(local=2.5e5, total_remote=3e6))
    for p, link in ((PARALLEL, "shm"), (DISTRIBUTED, "lan")):
        env = homogeneous_env(4, link=link)
        got = wct_env(c, p, env, 1200, interaction_bytes=100)
        want = wct(c, p, 4, 1200, interaction_bytes=100)
        for k in ("MCC", "LCC", "RCC", "SC", "MMC", "TEC"):
            assert got[k] == pytest.approx(want[k]), (link, k)


def test_wan_site_split_prices_cross_flows_higher():
    """Same flows: a two-site WAN environment must cost strictly more
    than the all-LAN one (cross-site link + RTT-dominated barrier)."""
    c = dict(BASE, lp_flows=_flows())
    lan = wct_env(c, DISTRIBUTED, make_env("lan", 4), 1200,
                  interaction_bytes=100)
    wan = wct_env(c, DISTRIBUTED, make_env("wan2", 4), 1200,
                  interaction_bytes=100)
    assert wan["RCC"] > lan["RCC"]
    assert wan["SC"] > lan["SC"]
    assert wan["TEC"] > lan["TEC"]
    # flows kept inside a site dodge the WAN premium entirely
    intra = np.zeros((4, 4))
    intra[0, 1] = intra[1, 0] = 1e6  # LPs 0,1 are co-sited
    cross = np.zeros((4, 4))
    cross[0, 2] = cross[2, 0] = 1e6  # sites A <-> B
    c_intra = dict(BASE, lp_flows=intra.tolist())
    c_cross = dict(BASE, lp_flows=cross.tolist())
    env = two_site_env(4)
    assert wct_env(c_cross, DISTRIBUTED, env, 1200)["RCC"] > \
        wct_env(c_intra, DISTRIBUTED, env, 1200)["RCC"]


def test_slow_lp_is_the_compute_bottleneck():
    """Events landing on a half-speed LP dominate MCC; the same volume
    on the double-speed LP is cheap."""
    env = hetero_speed_env(4)  # speeds (2, 1, 1, 0.5)
    on_fast = np.zeros((4, 4))
    on_fast[1, 0] = 4e6
    on_slow = np.zeros((4, 4))
    on_slow[1, 3] = 4e6
    fast = wct_env(dict(BASE, lp_flows=on_fast.tolist()), DISTRIBUTED,
                   env, 1200)["MCC"]
    slow = wct_env(dict(BASE, lp_flows=on_slow.tolist()), DISTRIBUTED,
                   env, 1200)["MCC"]
    assert slow > 3.0 * fast, (slow, fast)


def test_migrations_priced_on_their_pair_link():
    env = two_site_env(4)
    intra_mig = np.zeros((4, 4))
    intra_mig[0, 1] = 1e4
    cross_mig = np.zeros((4, 4))
    cross_mig[0, 2] = 1e4
    base = dict(BASE, lp_flows=_flows(), migrations=1e4)
    a = wct_env(dict(base, mig_flows=intra_mig.tolist()), DISTRIBUTED, env,
                1200, migration_bytes=20480)
    b = wct_env(dict(base, mig_flows=cross_mig.tolist()), DISTRIBUTED, env,
                1200, migration_bytes=20480)
    assert b["MigComm"] > a["MigComm"]
    # without mig_flows the fallback prices every migration on the most
    # expensive link present — an upper bound on both
    c = wct_env(base, DISTRIBUTED, env, 1200, migration_bytes=20480)
    assert c["MigComm"] >= b["MigComm"] >= a["MigComm"]


def test_wct_env_rejects_bad_flow_shape():
    with pytest.raises(ValueError):
        wct_env(dict(BASE, lp_flows=[[1.0]]), DISTRIBUTED,
                make_env("lan", 4), 1200)


def test_wct_env_single_lp_without_mig_flows():
    """Degenerate 1-LP environment: no remote links exist, so the
    migration fallback must price zero instead of crashing on an empty
    link set (regression)."""
    out = wct_env(dict(BASE, remote_msgs=0.0, lp_flows=[[1e6]]),
                  DISTRIBUTED, homogeneous_env(1), 1200)
    assert out["MigComm"] == 0.0 and out["RCC"] == 0.0
    assert out["TEC"] > 0.0

"""Cost-model tests (paper §3, Eqs. 1–6)."""
import pytest

from repro.core.costmodel import DISTRIBUTED, PARALLEL, amdahl, wct


BASE = {"local_msgs": 1e6, "remote_msgs": 1e6, "migrations": 0.0,
        "heu_evals": 0.0}


def test_amdahl_bounds():
    assert amdahl(1, 0.05) == pytest.approx(1.0)
    for n in (2, 4, 16):
        assert 1.0 < amdahl(n, 0.05) < n
    # s -> 0 recovers linear speedup
    assert amdahl(8, 0.0) == pytest.approx(8.0)


def test_tec_decomposition_sums():
    out = wct(dict(BASE, migrations=1e3, heu_evals=1e5), PARALLEL,
              n_lp=4, timesteps=1200, interaction_bytes=100,
              migration_bytes=20480)
    parts = (out["MCC"] + out["LCC"] + out["RCC"] + out["SC"] + out["MMC"]
             + out["MigCPU"] + out["MigComm"] + out["Heu"])
    assert out["TEC"] == pytest.approx(parts)
    assert out["MigC"] == pytest.approx(
        out["MigCPU"] + out["MigComm"] + out["Heu"])


def test_remote_messages_cost_more_than_local():
    """Paper §3: remote interactions cost more than local ones, with the
    separation growing from shared memory to the LAN (batched-delivery
    calibration: marshaling + bandwidth, latency in the barrier)."""
    for p, floor in ((PARALLEL, 1.0), (DISTRIBUTED, 5.0)):
        local = wct(dict(BASE, remote_msgs=0.0), p, 4, 1200)["LCC"]
        remote = wct(dict(BASE, local_msgs=0.0), p, 4, 1200)["RCC"]
        assert remote > floor * local, (p.name, remote, local)
    # LAN remote messages cost much more than shared-memory remote ones,
    # and the per-byte separation is ~45x (GbE path vs memcpy)
    kw = dict(interaction_bytes=1024)
    r_par = wct(dict(BASE, local_msgs=0.0), PARALLEL, 4, 1200, **kw)["RCC"]
    r_dis = wct(dict(BASE, local_msgs=0.0), DISTRIBUTED, 4, 1200, **kw)["RCC"]
    assert r_dis > 10 * r_par


def test_clustering_tradeoff_sign():
    """Converting remote->local deliveries must lower TEC when MigC is
    small, and a huge migration payload can flip the sign (Table 3's
    negative rows)."""
    before = wct(BASE, DISTRIBUTED, 4, 1200, interaction_bytes=1024)
    clustered = dict(BASE, local_msgs=1.8e6, remote_msgs=0.2e6,
                     migrations=5e3, heu_evals=1e6)
    after_cheap = wct(clustered, DISTRIBUTED, 4, 1200,
                      interaction_bytes=1024, migration_bytes=32)
    assert after_cheap["TEC"] < before["TEC"]
    # per-migration byte cost high enough to erase the gain
    after_heavy = wct(dict(clustered, migrations=4e5), DISTRIBUTED, 4, 1200,
                      interaction_bytes=1, migration_bytes=81920)
    assert after_heavy["TEC"] > wct(BASE, DISTRIBUTED, 4, 1200,
                                    interaction_bytes=1)["TEC"]


def test_heuristic_cost_scales_with_evals():
    a = wct(dict(BASE, heu_evals=1e6), PARALLEL, 4, 1200)
    b = wct(dict(BASE, heu_evals=2e6), PARALLEL, 4, 1200)
    assert b["Heu"] == pytest.approx(2 * a["Heu"])
    assert b["TEC"] > a["TEC"]


def test_more_lps_cut_compute_term():
    t4 = wct(BASE, PARALLEL, 4, 1200)["MCC"]
    t16 = wct(BASE, PARALLEL, 16, 1200)["MCC"]
    assert t16 < t4

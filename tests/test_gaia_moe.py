"""GAIA self-clustering adapted to MoE expert placement (beyond-paper).

Key invariants:
  * the symmetric balancer keeps exactly E/G experts per shard;
  * skewed traffic drives placement changes that reduce all-to-all bytes;
  * the physical migration (weights stored in segment order) is a
    permutation: outputs are bit-identical before/after a migration —
    the paper's transparency requirement at the expert level.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gaia_moe as gm


def _skewed_traffic(key, cfg, hot_group=0):
    """Traffic where each expert is hammered by one specific group."""
    E, G = cfg.num_experts, cfg.num_groups
    base = jax.random.uniform(key, (G, E)) * 5.0
    hot = jnp.arange(E) % G  # expert e's hot group
    boost = jnp.zeros((G, E)).at[hot, jnp.arange(E)].set(100.0)
    return base + boost


def test_placement_counts_invariant():
    cfg = gm.GaiaMoEConfig(num_experts=16, num_groups=4, mf=1.1, mt=0,
                           window=2, interval=1)
    st = gm.init_state(cfg)
    key = jax.random.key(0)
    for i in range(6):
        st = gm.observe(cfg, st, _skewed_traffic(jax.random.fold_in(key, i),
                                                 cfg))
        st, n = gm.evaluate(cfg, st)
        counts = np.bincount(np.asarray(st["placement"]), minlength=4)
        np.testing.assert_array_equal(counts, [4, 4, 4, 4])


def test_migrations_reduce_a2a_bytes():
    cfg = gm.GaiaMoEConfig(num_experts=16, num_groups=4, mf=1.05, mt=0,
                           window=1, interval=1)
    st = gm.init_state(cfg)
    key = jax.random.key(1)
    # adversarial start: expert e lives on shard e%G but its hot group is
    # (e+1)%G  -> everything is remote
    st["placement"] = (jnp.arange(16, dtype=jnp.int32) + 1) % 4
    tr = _skewed_traffic(key, cfg)
    before = float(gm.a2a_bytes(st["placement"], tr, token_bytes=2))
    total_migs = 0
    for _ in range(4):
        st = gm.observe(cfg, st, tr)
        st, n = gm.evaluate(cfg, st)
        total_migs += int(n)
    after = float(gm.a2a_bytes(st["placement"], tr, token_bytes=2))
    assert total_migs > 0
    assert after < before, (before, after)


def test_mt_throttles_expert_moves():
    cfg = gm.GaiaMoEConfig(num_experts=8, num_groups=2, mf=1.05, mt=1000,
                           window=1, interval=1)
    st = gm.init_state(cfg)
    st["placement"] = (jnp.arange(8, dtype=jnp.int32) + 1) % 2
    st["last_mig"] = jnp.zeros((8,), jnp.int32)  # all just moved
    st = gm.observe(cfg, st, _skewed_traffic(jax.random.key(2),
                                             gm.GaiaMoEConfig(8, 2)))
    st, n = gm.evaluate(cfg, st)
    assert int(n) == 0


def test_placement_permutation_roundtrip():
    placement_shard = jnp.array([1, 0, 1, 0], jnp.int32)  # expert -> shard
    perm, order = gm.placement_permutation(placement_shard, 4)
    # order: segment -> expert, shard-major: shard0 gets experts 1,3
    np.testing.assert_array_equal(np.asarray(order), [1, 3, 0, 2])
    np.testing.assert_array_equal(np.asarray(perm)[np.asarray(order)],
                                  np.arange(4))
    # with 2 segments per shard, segment s belongs to shard s // 2
    seg_shard = np.asarray(perm) // 2
    np.testing.assert_array_equal(seg_shard, np.asarray(placement_shard))


def test_apply_migration_transparency():
    """Permuting stored weights + routing ids leaves the MoE layer's
    output unchanged (paper §4.2 transparency, expert edition)."""
    from repro.models.moe import moe_fwd
    from repro.configs.base import MoEConfig
    from repro.parallel.ctx import make_ctx

    m = MoEConfig(num_experts=8, top_k=2, d_expert=16, capacity_factor=8.0)
    px = make_ctx(None)
    key = jax.random.key(3)
    from repro.models.moe import init_moe
    p = init_moe(key, 12, m)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 12),
                          jnp.bfloat16)

    ident = jnp.arange(8, dtype=jnp.int32)
    out0, met0 = moe_fwd(p, x, m=m, px=px, batch_entry=None, placement=ident)

    # migrate: new placement permutation (expert e -> segment perm[e])
    perm = jnp.array([3, 0, 1, 2, 7, 4, 6, 5], jnp.int32)
    order = jnp.argsort(perm)  # segment -> expert
    idx = gm.migration_index(ident, order)
    p2 = dict(p)
    for k in ("w_gate", "w_up", "w_down"):
        p2[k] = gm.apply_migration(p[k], idx)
    out1, met1 = moe_fwd(p2, x, m=m, px=px, batch_entry=None, placement=perm)
    np.testing.assert_array_equal(np.asarray(out0, np.float32),
                                  np.asarray(out1, np.float32))
    # traffic metrics are reported per *expert id*, so they match too
    np.testing.assert_array_equal(np.asarray(met0["expert_counts"]),
                                  np.asarray(met1["expert_counts"]))


def test_count_moves():
    idx = jnp.array([[0, 1, 2, 3], [1, 0, 2, 3]], jnp.int32)
    assert int(gm.count_moves(idx)) == 2


def test_maybe_update_interval():
    cfg = gm.GaiaMoEConfig(num_experts=8, num_groups=2, mf=0.5, mt=0,
                           window=1, interval=3)
    st = gm.init_state(cfg)
    st["placement"] = (jnp.arange(8, dtype=jnp.int32) + 1) % 2
    tr = _skewed_traffic(jax.random.key(4), cfg)
    moves = []
    for _ in range(6):
        st, n = gm.maybe_update(cfg, st, tr)
        moves.append(int(n))
    # evaluations fire only on steps 3 and 6
    assert moves[0] == 0 and moves[1] == 0
    assert sum(1 for mv in moves if mv > 0) <= 2
    assert any(mv > 0 for mv in moves)

"""Self-tuning adaptive partitioning tests (paper §5.5).

Tier-1 runs the smallest configs that still show real tuner descent
(6 windows x 40 steps); the paper-sized 400-step run is `slow` (nightly).
"""
import dataclasses

import jax
import pytest

from repro.core import costmodel as cm
from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig
from repro.core.heuristics import HeuristicConfig
from repro.core.selftune import (SelfTuneConfig, inter_run_tune,
                                 intra_run_tune, intra_run_tune_batch)

CFG = EngineConfig(
    abm=ABMConfig(n_se=100, n_lp=4, area=1000.0, speed=4.0,
                  interaction_range=90.0, p_interact=0.3),
    heuristic=HeuristicConfig(mf=4.0, mt=5),
    gaia_on=True, timesteps=180)


def test_intra_run_tuner_descends_mf():
    """In a clustering-friendly scenario the gain curve is monotone in
    migrations (paper Fig. 8), so the tuner must walk MF down from a
    too-conservative start and improve both LCR and priced TEC."""
    tc = SelfTuneConfig(window=30, mf0=8.0, setup="distributed",
                        interaction_bytes=1024, migration_bytes=32)
    _, hist = intra_run_tune(jax.random.key(0), CFG, tc)
    assert len(hist) == CFG.timesteps // tc.window
    first_mf, last_mf = hist[0][1], hist[-1][1]
    assert last_mf < first_mf * 0.7, hist
    # priced per-step cost improved vs the first window
    assert hist[-1][3] < hist[0][3], hist
    # and clustering actually got better
    assert hist[-1][2] > hist[0][2] + 0.05, hist


def test_intra_run_tuner_respects_bounds():
    tc = SelfTuneConfig(window=30, mf0=1.1, step0=0.9, min_mf=1.05,
                        max_mf=19.0)
    _, hist = intra_run_tune(jax.random.key(1), CFG, tc, total_steps=120)
    for _, mf, _, _ in hist:
        assert 1.05 <= mf <= 19.0


def test_batched_tuner_matches_solo_trajectories():
    """The batched tuner must be R *independent* tuners: each replica's
    (MF, LCR, TEC) history reproduces a solo intra_run_tune on that
    replica's seed bit-for-bit — per-replica MF rides the batched scan
    as a dynamic vector, so one replica's hill descent never perturbs
    another's — and different seeds produce different trajectories."""
    cfg = dataclasses.replace(CFG, timesteps=90)
    tc = SelfTuneConfig(window=30, mf0=8.0, setup="distributed",
                        interaction_bytes=1024, migration_bytes=32)
    _, hists = intra_run_tune_batch(cfg, tc, seeds=(0, 4))
    for seed, hist in zip((0, 4), hists):
        _, solo = intra_run_tune(jax.random.key(seed), cfg, tc)
        assert hist == solo, (seed, hist, solo)
    assert hists[0] != hists[1]


def test_inter_run_tuner_finds_low_mf_region():
    """Full-run golden-section bracketing lands in the aggressive-MF
    region where Figs. 8/9 put the optimum for cheap migrations."""
    cfg = dataclasses.replace(CFG, timesteps=90)
    tc = SelfTuneConfig(setup="distributed", interaction_bytes=1024,
                        migration_bytes=32)
    best_mf, trials = inter_run_tune(jax.random.key(2), cfg, tc,
                                     n_probes=4)
    assert len(trials) == 4
    assert best_mf < 6.0, trials


def test_env_pricing_steers_mf_differently():
    """Regression for `_price` ignoring cfg.env: the tuner must optimize
    the objective the run executes on. With 2 KiB migration payloads,
    the homogeneous "distributed" pricing (LAN-cost remote messages)
    rewards aggressive migration and walks MF down; on a shared-memory
    environment remote delivery is nearly free, so the same migrations
    are pure cost and the tuner must back MF off instead. The old code
    priced both runs identically and picked the LAN answer on shm."""
    tc = SelfTuneConfig(window=30, mf0=8.0, setup="distributed",
                        interaction_bytes=1024, migration_bytes=2048)
    _, h_scalar = intra_run_tune(jax.random.key(0), CFG, tc)
    cfg_shm = dataclasses.replace(CFG, env=cm.make_env("shm", CFG.abm.n_lp))
    _, h_shm = intra_run_tune(jax.random.key(0), cfg_shm, tc)
    # identical engine trajectories (env only reprices), divergent MF:
    assert h_scalar[-1][1] < 2.0, h_scalar  # LAN pricing: migrate hard
    assert h_shm[-1][1] > tc.mf0, h_shm  # shm pricing: back off
    # and the priced windows really differ (wct_env was actually used)
    assert h_shm[0][3] < h_scalar[0][3]


@pytest.mark.slow
def test_intra_run_tuner_descends_mf_full_scale():
    """The original 400-step, 50-step-window descent (nightly tier)."""
    cfg = EngineConfig(
        abm=ABMConfig(n_se=150, n_lp=4, area=1200.0, speed=4.0,
                      interaction_range=90.0, p_interact=0.3),
        heuristic=HeuristicConfig(mf=4.0, mt=5),
        gaia_on=True, timesteps=400)
    tc = SelfTuneConfig(window=50, mf0=8.0, setup="distributed",
                        interaction_bytes=1024, migration_bytes=32)
    _, hist = intra_run_tune(jax.random.key(0), cfg, tc)
    assert len(hist) == 8
    assert hist[-1][1] < hist[0][1] * 0.7, hist
    assert hist[-1][3] < hist[0][3], hist
    assert hist[-1][2] > hist[0][2] + 0.05, hist

"""Parallelism-layer tests that run on real (subprocess-faked) multi-device
meshes: sharding rules, GPipe pipeline, q8 cross-pod collective, and a
miniature end-to-end dry-run. Each multi-device case runs in a fresh
subprocess because jax pins the device count at first init.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> str:
    pre = (f"import os\n"
           f"os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count={n}'\n")
    r = subprocess.run([sys.executable, "-c", pre + textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600,
                       env=dict(os.environ, PYTHONPATH=SRC))
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


# ---------------------------------------------------------------------------
# sharding rules (no devices needed: specs are pure metadata)
# ---------------------------------------------------------------------------


def _mesh_like():
    """A fake mesh object exposing .shape for spec math on 1 device."""
    return None


@pytest.mark.slow
def test_dense_mlp_is_tensor_parallel_not_expert_sharded():
    """Regression: stacked dense (L, d, f) must never be treated as MoE
    experts (L-dim sharding) — w_gate shards f, w_down shards its f dim."""
    out = run_with_devices("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.launch.steps import _param_sds
        from repro.parallel import sharding as sh
        from repro.parallel.ctx import make_ctx
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        px = make_ctx(mesh)
        for arch, leaf_checks in [
            # stacked dense (L, d, f): TP over f / over f-in for w_down;
            # attention (L, d, H, Dh): heads over model
            ("yi-9b", {("layers","mlp","w_gate"): P(None, None, "model"),
                       ("layers","mlp","w_down"): P(None, "model", None),
                       ("layers","attn","wq"): P(None, None, "model", None)}),
            # MoE experts (L, E, d, f): EP over E; shared experts dense-TP
            ("deepseek-v3-671b",
                      {("layers","moe","w_gate"): P(None, "model", None, None),
                       ("layers","moe","shared","w_gate"):
                           P(None, None, "model")}),
        ]:
            cfg = get_arch(arch)
            sds = _param_sds(cfg)
            spec = sh.param_specs(sds, px)
            for path, want in leaf_checks.items():
                node = spec
                for k in path: node = node[k]
                assert node == want, (arch, path, node, want)
        print("OK")
    """)
    assert "OK" in out


def test_zero1_adds_data_axis():
    out = run_with_devices("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.parallel.sharding import zero1_spec
        from repro.parallel.ctx import make_ctx
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        px = make_ctx(mesh)
        s = zero1_spec(P(None, "model"), (64, 8), px)
        assert s == P("data", "model"), s
        # indivisible dims stay untouched
        s2 = zero1_spec(P(), (7, 3), px)
        assert s2 == P(), s2
        print("OK")
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# distributed semantics on an 8-device host
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The same smoke train step gives identical loss on a (2,2) mesh and
    on one device — GSPMD partitioning is semantics-preserving."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import get_smoke
        from repro.configs.base import ShapeConfig
        from repro.launch.steps import build_train_step
        from repro.models import lm as lm_mod
        from repro.optim.adamw import adamw_init
        from repro.parallel import sharding as shard_mod
        from repro.parallel.ctx import make_ctx

        cfg = get_smoke("yi-9b")
        shape = ShapeConfig("t", 32, 4, "train")
        params = lm_mod.init_params(jax.random.key(0), cfg)
        opt = adamw_init(params)
        batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 32), 0, 200),
                 "loss_mask": jnp.ones((4, 32), jnp.float32)}

        losses = {}
        for name, mesh in [("single", None),
                           ("mesh", jax.make_mesh((2, 2), ("data", "model")))]:
            px = make_ctx(mesh, q_block=16, kv_block=16)
            b = build_train_step(cfg, shape, px)
            if mesh is None:
                fn = jax.jit(b.fn)
            else:
                in_sh = jax.tree.map(
                    lambda s: shard_mod.to_shardings(s, px), b.in_specs,
                    is_leaf=lambda x: x is None or isinstance(
                        x, jax.sharding.PartitionSpec))
                fn = jax.jit(b.fn, in_shardings=in_sh)
            p2, o2, e2, m = fn(params, opt, {}, batch)
            losses[name] = float(m["loss"])
        assert abs(losses["single"] - losses["mesh"]) < 0.05, losses
        print("OK", losses)
    """, n=4)
    assert "OK" in out


@pytest.mark.slow
def test_gpipe_matches_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.parallel.pipeline import gpipe

        mesh = jax.make_mesh((4,), ("pod",))
        P_STAGES, N_MICRO, D = 4, 8, 16
        k = jax.random.key(0)
        Ws = jax.random.normal(k, (P_STAGES, D, D), jnp.float32) * 0.3
        xs = jax.random.normal(jax.random.fold_in(k, 1), (N_MICRO, 2, D))

        def stage_fn(W, x):
            return jnp.tanh(x @ W)

        pipe = gpipe(stage_fn, mesh, "pod", N_MICRO)
        got = pipe({"w": Ws}["w"] if False else Ws, xs)
        want = xs
        for i in range(P_STAGES):
            want = jax.vmap(lambda x: stage_fn(Ws[i], x))(want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        print("OK")
    """, n=4)
    assert "OK" in out


@pytest.mark.slow
def test_q8_cross_pod_mean_matches_uncompressed_within_tol():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.optim.compress import q8_cross_pod_mean

        mesh = jax.make_mesh((2,), ("pod",))
        k = jax.random.key(0)
        g = jax.random.normal(k, (2, 64), jnp.float32)  # stacked per-pod
        e = jnp.zeros((2, 64), jnp.float32)
        mean, new_e = q8_cross_pod_mean(g, e, mesh, "pod")
        want = jnp.broadcast_to(g.mean(0), (2, 64))
        got = np.asarray(mean)
        scale = np.abs(np.asarray(g)).max() / 127
        assert np.abs(got - np.asarray(want)).max() <= scale + 1e-6
        # residual holds the quantization error
        assert np.abs(np.asarray(new_e)).max() <= scale + 1e-6
        print("OK")
    """, n=2)
    assert "OK" in out


@pytest.mark.slow
def test_ep2d_matches_grouped_ep():
    """2-D expert parallelism is semantics-preserving: the MoE layer
    gives the same output with ep2d on/off on a (2,2) mesh."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        import numpy as np
        import dataclasses
        from repro.configs.base import MoEConfig
        from repro.models.moe import init_moe, moe_fwd
        from repro.parallel.ctx import make_ctx

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        m = MoEConfig(num_experts=8, top_k=2, d_expert=16,
                      capacity_factor=8.0)
        key = jax.random.key(0)
        p = init_moe(key, 32, m)
        x = (jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 32))
             * 0.5).astype(jnp.bfloat16)
        outs = {}
        for flag in (False, True):
            px = make_ctx(mesh, ep2d=flag)
            fn = jax.jit(lambda p_, x_: moe_fwd(p_, x_, m=m, px=px,
                                                batch_entry="data")[0])
            outs[flag] = np.asarray(fn(p, x), np.float32)
        np.testing.assert_allclose(outs[False], outs[True],
                                   atol=0.03, rtol=0.05)
        print("OK")
    """, n=4)
    assert "OK" in out


@pytest.mark.slow
def test_mini_dryrun_multipod_mesh():
    """End-to-end miniature of the production dry-run: 2x2x2 pod mesh,
    lower+compile the smoke arch, memory analysis returns sane numbers."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke
        from repro.configs.base import ShapeConfig
        from repro.launch.steps import build_train_step
        from repro.parallel import sharding as shard_mod
        from repro.parallel.ctx import make_ctx

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        px = make_ctx(mesh, q_block=16, kv_block=16)
        cfg = get_smoke("qwen3-moe-30b-a3b")
        shape = ShapeConfig("t", 32, 8, "train")
        b = build_train_step(cfg, shape, px)
        in_sh = jax.tree.map(lambda s: shard_mod.to_shardings(s, px), b.in_specs,
            is_leaf=lambda x: x is None or isinstance(x, jax.sharding.PartitionSpec))
        low = jax.jit(b.fn, in_shardings=in_sh,
                      donate_argnums=b.donate).lower(*b.in_sds)
        comp = low.compile()
        ma = comp.memory_analysis()
        assert ma.argument_size_in_bytes > 0
        assert "all-reduce" in comp.as_text() or "all-gather" in comp.as_text()
        print("OK")
    """, n=8)
    assert "OK" in out

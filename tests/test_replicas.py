"""Batched multi-replica execution contract (engine.run_batch tentpole).

`run_batch(cfg, seeds)` vmaps the memoized jitted scan over a leading
seed axis; the contract is *per-seed bit-identity*: replica r of a
batch — state, per-step series, aggregate counters — is byte-identical
to a sequential `run(jax.random.key(seeds[r]), cfg)`, on both execution
layers (oracle and LP-per-device sharded at 1/2/4 devices). Replicas
are independent by construction (vmap never mixes rows), pinned here
via seed-permutation equivariance and a hypothesis invariant.

Speed discipline: the engine/sharding configs reuse
tests/test_sharding.py's shapes, so the sequential reference runs share
those tests' compiled scans; batched scans are memoized per config.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stats
from repro.core.abm import ABMConfig
from repro.core.engine import (EngineConfig, init_batch, run, run_batch,
                               run_window, run_window_batch)
from repro.core.heuristics import HeuristicConfig

ABM = ABMConfig(n_se=96, n_lp=4, area=1000.0, speed=5.0,
                interaction_range=80.0, p_interact=0.3)
CFG = EngineConfig(abm=ABM, heuristic=HeuristicConfig(mf=1.2, mt=5),
                   gaia_on=True, timesteps=24)

STATE_KEYS = ("pos", "waypoint", "mob", "mob_g", "lp", "pending_dst",
              "pending_eta", "ring", "ptr", "since_eval", "last_mig")
SERIES_KEYS = ("local_msgs", "remote_msgs", "migrations", "heu_evals", "lcr",
               "lp_flows", "mig_flows")


@functools.lru_cache(maxsize=None)
def _run(cfg: EngineConfig, seed: int):
    return run(jax.random.key(seed), cfg)


@functools.lru_cache(maxsize=None)
def _run_batch(cfg: EngineConfig, seeds: tuple):
    return run_batch(cfg, seeds)


def _assert_replicas_match_sequential(cfg, seeds):
    states, series, reps = _run_batch(cfg, tuple(seeds))
    for r, seed in enumerate(seeds):
        st, ser, c = _run(cfg, seed)
        for k in STATE_KEYS:
            np.testing.assert_array_equal(
                np.asarray(st[k]), np.asarray(states[k][r]),
                err_msg=f"seed {seed} state {k}")
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(st["key"])),
            np.asarray(jax.random.key_data(states["key"][r])))
        for k in SERIES_KEYS:
            np.testing.assert_array_equal(
                np.asarray(ser[k]), np.asarray(series[k][:, r]),
                err_msg=f"seed {seed} series {k}")
        assert set(c) == set(reps[r])
        for k in c:
            assert np.array_equal(c[k], reps[r][k]), (seed, k)


def test_batch_matches_sequential_oracle():
    _assert_replicas_match_sequential(CFG, (3, 7, 11))


def test_batch_matches_sequential_oracle_mobility():
    """Per-SE mobility state (`mob`) and the replicated global rows
    (`mob_g`) ride the batch axis too."""
    cfg = dataclasses.replace(
        CFG, abm=dataclasses.replace(ABM, mobility="hotspot", n_groups=4,
                                     group_radius=120.0),
        timesteps=16)
    _assert_replicas_match_sequential(cfg, (3, 7))


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_batch_matches_sequential_sharded(n_devices):
    """The sharded batch vmaps *inside* each shard: replicas must stay
    bit-identical to the sequential sharded run per seed (which is
    itself bit-identical to the oracle, test_sharding.py)."""
    cfg = dataclasses.replace(CFG, sharding="lp_device",
                              n_devices=n_devices)
    _assert_replicas_match_sequential(cfg, (3, 7))


def test_seed_permutation_permutes_replicas():
    """Replica independence: permuting the seed vector permutes the
    outputs and changes nothing else (no cross-replica leakage)."""
    sa, ser_a, reps_a = _run_batch(CFG, (3, 7, 11))
    sb, ser_b, reps_b = _run_batch(CFG, (11, 3, 7))
    perm = [1, 2, 0]  # position of (3, 7, 11)'s replicas inside batch b
    for k in STATE_KEYS:
        np.testing.assert_array_equal(np.asarray(sa[k]),
                                      np.asarray(sb[k])[perm], err_msg=k)
    for k in SERIES_KEYS:
        np.testing.assert_array_equal(np.asarray(ser_a[k]),
                                      np.asarray(ser_b[k])[:, perm],
                                      err_msg=k)
    for r, p in enumerate(perm):
        assert reps_a[r] == reps_b[p]
    # distinct seeds really are distinct trajectories
    assert reps_a[0] != reps_a[1]


def test_per_replica_mf_vector():
    """run_window_batch threads a per-replica MF vector: each replica
    runs its own Migration Factor (the batched §5.5 tuner's contract)
    and reproduces a solo run_window at that MF bit-for-bit."""
    mfs = (0.6, 8.0)
    states = init_batch(CFG, (5, 5))  # same seed: only MF differs
    states, reps = run_window_batch(states, CFG, 16,
                                    mf=jnp.asarray(mfs, jnp.float32))
    from repro.core.engine import init_engine
    for r, mf in enumerate(mfs):
        st = init_engine(jax.random.key(5), CFG)
        _, solo = run_window(st, CFG, 16, mf=mf)
        assert solo == reps[r], (mf, solo, reps[r])
    # aggressive MF migrates strictly more than conservative MF
    assert reps[0]["migrations"] > reps[1]["migrations"]


# ---------------------------------------------------------------------------
# replica statistics (core/stats.py)
# ---------------------------------------------------------------------------


def test_replica_stats_schema():
    st = stats.replica_stats([1.0, 2.0, 3.0, 4.0])
    assert st["n"] == 4 and st["mean"] == 2.5
    np.testing.assert_allclose(st["std"], np.std([1, 2, 3, 4], ddof=1))
    # t(df=3) = 3.182, not z = 1.96: small-n intervals must widen
    np.testing.assert_allclose(st["ci95"], 3.182 * st["std"] / 2.0)
    one = stats.replica_stats([7.5])
    assert one == {"mean": 7.5, "std": 0.0, "ci95": 0.0, "n": 1}
    assert stats.t95(40) == 1.96 and stats.t95(1) == 12.706
    with pytest.raises(ValueError):
        stats.replica_stats([])


def test_summarize_skips_matrix_counters():
    reps = [{"mean_lcr": 0.5, "migrations": 10.0, "lp_flows": [[1, 2]]},
            {"mean_lcr": 0.7, "migrations": 14.0, "lp_flows": [[3, 4]]}]
    out = stats.summarize(reps)
    assert set(out) == {"mean_lcr", "migrations"}
    assert out["migrations"]["mean"] == 12.0 and out["migrations"]["n"] == 2
    assert stats.is_stats(out["mean_lcr"])
    assert not stats.is_stats({"mean": 1.0})


def test_summarize_reports_bool_flags_not_stats():
    """Regression: `bool` is an `int` subclass, so the naive numeric
    test used to average alarm flags (grid_overflow etc.) into a
    mean/std/ci95 — a meaningless 'mean overflow of 0.33'. Flags must
    come out as any/count/n, a shape `is_stats` rejects, while genuine
    int counters keep the replica-stats schema."""
    reps = [{"grid_overflow": False, "migrations": 10},
            {"grid_overflow": True, "migrations": 14},
            {"grid_overflow": False, "migrations": 12}]
    out = stats.summarize(reps)
    assert out["grid_overflow"] == {"any": True, "count": 1, "n": 3}
    assert not stats.is_stats(out["grid_overflow"])
    assert stats.is_stats(out["migrations"])
    assert out["migrations"]["mean"] == 12.0
    # all-clear flags keep the shape (any=False), so dashboards can
    # tell "never tripped" from "not recorded"
    clear = stats.summarize([{"f": False}, {"f": False}])
    assert clear["f"] == {"any": False, "count": 0, "n": 2}
    # explicit key selection goes through the same flag path
    sel = stats.summarize(reps, keys=["grid_overflow"])
    assert sel["grid_overflow"]["count"] == 1


# ---------------------------------------------------------------------------
# hypothesis invariant: batched counters == stack of per-seed counters
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency; contract still covered
    HAVE_HYPOTHESIS = False  # by the explicit bit-identity tests above

if HAVE_HYPOTHESIS:
    TINY = dataclasses.replace(
        CFG, abm=dataclasses.replace(ABM, n_se=48), timesteps=8)

    @settings(deadline=None, max_examples=8)
    @given(hyp_st.lists(hyp_st.integers(0, 12), min_size=1, max_size=4,
                        unique=True))
    def test_batched_counters_equal_per_seed_stack(seeds):
        """For ANY seed vector, the batch's per-replica counters equal
        the stack of sequential per-seed counters — no metric mixes
        information across the replica axis."""
        _, _, reps = _run_batch(TINY, tuple(seeds))
        for r, seed in enumerate(seeds):
            _, _, c = _run(TINY, seed)
            assert c == reps[r], (seed, c, reps[r])

"""Determinism regression (standalone, quick — was a side-assert inside
long engine tests).

Two contracts:
  * same seed + config => byte-identical final engine state and series
    across two independent runs;
  * the §4.2 transparency invariant: the model-evolution fields
    (positions, waypoints, total interaction volume) are byte-identical
    with GAIA ON and OFF — partitioning decides WHERE events land,
    never WHAT happens.

The configs deliberately match tests/test_engine.py's SMALL scenario so
both modules share one memoized compiled scan per gaia flag
(engine._compiled_window) instead of compiling private variants.
"""
import dataclasses

import jax
import numpy as np

from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig, run
from repro.core.heuristics import HeuristicConfig

CFG = EngineConfig(
    abm=ABMConfig(n_se=120, n_lp=4, area=1000.0, speed=5.0,
                  interaction_range=80.0, p_interact=0.3),
    heuristic=HeuristicConfig(mf=1.2, mt=5), gaia_on=True, timesteps=60)


def _bytes(x):
    return np.ascontiguousarray(np.asarray(x)).tobytes()


def test_same_seed_same_config_is_byte_identical():
    st1, s1, c1 = run(jax.random.key(11), CFG)
    st2, s2, c2 = run(jax.random.key(11), CFG)
    for k in ("pos", "waypoint", "lp", "pending_dst", "pending_eta",
              "ring", "ptr", "since_eval", "last_mig"):
        assert _bytes(st1[k]) == _bytes(st2[k]), k
    assert _bytes(jax.random.key_data(st1["key"])) == \
           _bytes(jax.random.key_data(st2["key"]))
    for k in s1:
        assert _bytes(s1[k]) == _bytes(s2[k]), k
    assert c1 == c2


def test_gaia_transparency_on_model_evolution_fields():
    st_on, s_on, _ = run(jax.random.key(5), CFG)
    st_off, s_off, _ = run(jax.random.key(5),
                           dataclasses.replace(CFG, gaia_on=False))
    for k in ("pos", "waypoint"):
        assert _bytes(st_on[k]) == _bytes(st_off[k]), k
    tot_on = np.asarray(s_on["local_msgs"]) + np.asarray(s_on["remote_msgs"])
    tot_off = (np.asarray(s_off["local_msgs"])
               + np.asarray(s_off["remote_msgs"]))
    np.testing.assert_array_equal(tot_on, tot_off)

"""The paper's evaluation model (§5.1): an agent-based model on a toroidal
2-D space. Agents move by Random Waypoint (min speed = max speed, sleep 0,
as in Experiment 1) and interact by proximity: each sender's interaction
reaches every agent within the threshold range.

Vectorized over all SEs; the pairwise proximity/LP-histogram hot spot has
a Pallas kernel (repro/kernels/proximity) — the jnp path here is its
oracle and the CPU default.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ABMConfig:
    n_se: int = 10_000
    n_lp: int = 4
    area: float = 10_000.0  # toroidal square side (spaceunits)
    speed: float = 11.0  # spaceunits/timestep (min = max, Exp. 1)
    interaction_range: float = 250.0
    p_interact: float = 0.2  # pi: P(SE sends an interaction this timestep)
    use_pallas: bool = False


def init_abm(key, cfg: ABMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    pos = jax.random.uniform(k1, (cfg.n_se, 2), maxval=cfg.area)
    wp = jax.random.uniform(k2, (cfg.n_se, 2), maxval=cfg.area)
    # round-robin random assignment: equal SEs per LP (paper: random but
    # equal-sized)
    lp = jax.random.permutation(k3, jnp.arange(cfg.n_se) % cfg.n_lp)
    return {"pos": pos, "waypoint": wp, "lp": lp.astype(jnp.int32)}


def toroidal_delta(a, b, area):
    """Shortest per-axis displacement on the torus."""
    d = jnp.abs(a - b)
    return jnp.minimum(d, area - d)


def rwp_step(key, pos, waypoint, cfg: ABMConfig):
    """One Random-Waypoint move: advance `speed` toward the waypoint
    (torus-aware); on arrival draw a new waypoint (sleep time 0)."""
    delta = waypoint - pos
    # shortest direction on the torus
    delta = jnp.where(delta > cfg.area / 2, delta - cfg.area, delta)
    delta = jnp.where(delta < -cfg.area / 2, delta + cfg.area, delta)
    dist = jnp.linalg.norm(delta, axis=-1, keepdims=True)
    arrived = dist[:, 0] <= cfg.speed
    step = jnp.where(dist > 0, delta / jnp.maximum(dist, 1e-9), 0.0)
    new_pos = jnp.where(arrived[:, None], waypoint,
                        (pos + step * cfg.speed) % cfg.area)
    new_wp = jnp.where(arrived[:, None],
                       jax.random.uniform(key, waypoint.shape,
                                          maxval=cfg.area),
                       waypoint)
    return new_pos % cfg.area, new_wp


def interaction_counts(pos, lp, sender_mask, cfg: ABMConfig):
    """Per-sender histogram of recipient LPs.

    Returns counts (N, n_lp) int32: counts[i, l] = number of SEs within
    `interaction_range` of sender i currently allocated on LP l (self
    excluded). Rows of non-senders are zero.

    O(N^2) pairwise — the paper's hot spot; see kernels/proximity for the
    TPU tiling.
    """
    if cfg.use_pallas:
        from repro.kernels.proximity.ops import proximity_lp_counts
        return proximity_lp_counts(pos, lp, sender_mask, cfg.n_lp,
                                   cfg.area, cfg.interaction_range)
    n = pos.shape[0]
    dx = toroidal_delta(pos[:, None, 0], pos[None, :, 0], cfg.area)
    dy = toroidal_delta(pos[:, None, 1], pos[None, :, 1], cfg.area)
    in_range = (dx * dx + dy * dy) <= cfg.interaction_range ** 2
    in_range = in_range & ~jnp.eye(n, dtype=bool)
    in_range = in_range & sender_mask[:, None]
    onehot = jax.nn.one_hot(lp, cfg.n_lp, dtype=jnp.float32)
    counts = in_range.astype(jnp.float32) @ onehot
    return counts.astype(jnp.int32)

"""The paper's evaluation model (§5.1): an agent-based model on a toroidal
2-D space. Agents move by Random Waypoint (min speed = max speed, sleep 0,
as in Experiment 1) and interact by proximity: each sender's interaction
reaches every agent within the threshold range.

Vectorized over all SEs. The proximity/LP-histogram hot spot — the O(N^2)
pairwise matching the paper names as the model's dominant cost — has four
interchangeable backends selected by `ABMConfig.proximity_backend`:

  "dense"        full O(N^2) jnp sweep; the exact-parity oracle
  "grid"         cell-list neighbor search (core/neighbors.py), O(N*k);
                 the default — bit-identical to dense
  "pallas"       dense-sweep Pallas TPU kernel (kernels/proximity)
  "pallas_grid"  grid-candidate Pallas TPU kernel (kernels/proximity)

All four return bit-identical counts (tests/test_neighbors.py); "grid"
and "pallas_grid" fall back to the dense math when the world is too
small to tessellate (area / interaction_range < 3 cells per side).
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from repro.core import neighbors

PROXIMITY_BACKENDS = ("dense", "grid", "pallas", "pallas_grid")


@dataclasses.dataclass(frozen=True)
class ABMConfig:
    n_se: int = 10_000
    n_lp: int = 4
    area: float = 10_000.0  # toroidal square side (spaceunits)
    speed: float = 11.0  # spaceunits/timestep (min = max, Exp. 1)
    interaction_range: float = 250.0
    p_interact: float = 0.2  # pi: P(SE sends an interaction this timestep)
    proximity_backend: str = "grid"  # see PROXIMITY_BACKENDS
    grid_capacity: int = 0  # per-cell member cap; 0 = auto from density
    use_pallas: bool = False  # DEPRECATED: use proximity_backend="pallas"

    def __post_init__(self):
        if self.proximity_backend not in PROXIMITY_BACKENDS:
            raise ValueError(
                f"proximity_backend={self.proximity_backend!r} not in "
                f"{PROXIMITY_BACKENDS}")
        if self.use_pallas and self.proximity_backend != "grid":
            # the shim must never silently override an explicit choice
            raise ValueError(
                "use_pallas=True (deprecated) conflicts with "
                f"proximity_backend={self.proximity_backend!r}; drop "
                "use_pallas and set proximity_backend only")

    def resolved_backend(self) -> str:
        """Backend after the `use_pallas` deprecation shim."""
        if self.use_pallas:
            warnings.warn(
                "ABMConfig.use_pallas is deprecated; use "
                "proximity_backend='pallas' (or 'pallas_grid').",
                DeprecationWarning, stacklevel=2)
            return "pallas"
        return self.proximity_backend

    def grid_spec(self):
        """Cell-list geometry for this config, or None if the world is
        too small to tessellate (grid backends then use dense math)."""
        return neighbors.make_grid_spec(self.n_se, self.area,
                                        self.interaction_range,
                                        capacity=self.grid_capacity)


def init_abm(key, cfg: ABMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    pos = jax.random.uniform(k1, (cfg.n_se, 2), maxval=cfg.area)
    wp = jax.random.uniform(k2, (cfg.n_se, 2), maxval=cfg.area)
    # round-robin random assignment: equal SEs per LP (paper: random but
    # equal-sized)
    lp = jax.random.permutation(k3, jnp.arange(cfg.n_se) % cfg.n_lp)
    return {"pos": pos, "waypoint": wp, "lp": lp.astype(jnp.int32)}


def toroidal_delta(a, b, area):
    """Shortest per-axis displacement on the torus."""
    d = jnp.abs(a - b)
    return jnp.minimum(d, area - d)


def rwp_draws(key, n: int, cfg: ABMConfig):
    """The fresh-waypoint draw for all n SEs, indexed by global SE id.

    Factored out of `rwp_step` so the sharded engine can compute the
    *same* (n, 2) array on every device and gather each shard's rows by
    SE id — the draw for SE i must be identical no matter which device
    currently hosts it (bit-identity with the single-device oracle)."""
    return jax.random.uniform(key, (n, 2), maxval=cfg.area)


def rwp_apply(pos, waypoint, new_wp, cfg: ABMConfig):
    """The deterministic half of a Random-Waypoint move: advance `speed`
    toward the waypoint (torus-aware); on arrival switch to the
    pre-drawn fresh waypoint `new_wp` (sleep time 0)."""
    delta = waypoint - pos
    # shortest direction on the torus
    delta = jnp.where(delta > cfg.area / 2, delta - cfg.area, delta)
    delta = jnp.where(delta < -cfg.area / 2, delta + cfg.area, delta)
    dist = jnp.linalg.norm(delta, axis=-1, keepdims=True)
    arrived = dist[:, 0] <= cfg.speed
    step = jnp.where(dist > 0, delta / jnp.maximum(dist, 1e-9), 0.0)
    new_pos = jnp.where(arrived[:, None], waypoint,
                        (pos + step * cfg.speed) % cfg.area)
    next_wp = jnp.where(arrived[:, None], new_wp, waypoint)
    return new_pos % cfg.area, next_wp


def rwp_step(key, pos, waypoint, cfg: ABMConfig):
    """One Random-Waypoint move (draw + apply; see rwp_draws/rwp_apply)."""
    return rwp_apply(pos, waypoint, rwp_draws(key, pos.shape[0], cfg), cfg)


def _dense_counts(pos, lp, sender_mask, cfg: ABMConfig):
    return neighbors.dense_lp_counts(pos, lp, sender_mask, cfg.n_lp,
                                     cfg.area, cfg.interaction_range)


def interaction_counts(pos, lp, sender_mask, cfg: ABMConfig):
    """Per-sender histogram of recipient LPs.

    Returns counts (N, n_lp) int32: counts[i, l] = number of SEs within
    `interaction_range` of sender i currently allocated on LP l (self
    excluded). Rows of non-senders are zero.

    Dispatches on `cfg.proximity_backend`; every backend is bit-identical
    (dense is the oracle — see tests/test_neighbors.py and DESIGN.md
    §Adaptations for the trade-offs).
    """
    backend = cfg.resolved_backend()
    spec = cfg.grid_spec() if backend in ("grid", "pallas_grid") else None
    if backend in ("grid", "pallas_grid") and spec is None:
        backend = "dense"  # world too small to tessellate: exact fallback
    if backend == "grid":
        return neighbors.grid_lp_counts(pos, lp, sender_mask, cfg.n_lp,
                                        cfg.area, cfg.interaction_range,
                                        spec)
    if backend == "pallas":
        from repro.kernels.proximity.ops import proximity_lp_counts
        return proximity_lp_counts(pos, lp, sender_mask, cfg.n_lp,
                                   cfg.area, cfg.interaction_range)
    if backend == "pallas_grid":
        from repro.kernels.proximity.ops import proximity_lp_counts_grid
        return proximity_lp_counts_grid(pos, lp, sender_mask, cfg.n_lp,
                                        cfg.area, cfg.interaction_range,
                                        spec)
    return _dense_counts(pos, lp, sender_mask, cfg)

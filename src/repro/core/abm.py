"""The paper's evaluation model (§5.1): an agent-based model on a toroidal
2-D space. Agents interact by proximity: each sender's interaction
reaches every agent within the threshold range.

Mobility is pluggable (`ABMConfig.mobility` — the paper's claim is that
self-clustering pays off across "various configurations of the
simulation model", so the workloads must go beyond uniform RWP):

  "rwp"      Random Waypoint (min speed = max speed, sleep 0, Exp. 1).
             Near-uniform stationary density — the friendliest case.
  "hotspot"  K moving attractors (themselves doing RWP); SEs are pulled
             toward their attractor with per-step noise. Sustained
             non-uniform density: K dense blobs wandering the torus.
  "group"    RPGM-style group mobility: K leader points do RWP, each SE
             chases (leader + its fixed member offset). Groups migrate
             coherently across the space.
  "flock"    flocking-lite: each SE steers by alignment + cohesion
             toward the centroid/mean-heading of its 3x3 cell-list
             neighborhood (reusing the proximity grid geometry), plus
             noise. Clusters *emerge* instead of being imposed.
  "trace"    trace replay: positions come frame-by-frame from a
             registered GPS/taxi-style trace (repro.data.pipeline —
             `register_trace`, `synthetic_trace`, `resample_trace`).
             Step t replays frame t+1 (frame 0 is the initial state);
             when the trace is shorter than the horizon,
             `trace_policy` picks loop / hold-last / exact-or-raise.
             Consumes no PRNG and is row-local, so the sharded engine
             replays it gather-free and bit-identically.

Orthogonally to *where SEs move*, `ABMConfig.workload` adds a model of
*what they compute*: "epidemic" spreads an SI/SIS infection flag (the
`epi` state field) over the proximity graph each step — susceptible
SEs catch with p = 1-(1-beta)^exposure from in-range infectious
senders, infectious SEs interact `epi_boost`x more often — so event
load follows the infection wave instead of the density map. That is
the dynamic-load regime (Kurve et al., Boulmier et al.) pure mobility
cannot produce, and the reason GAIA's self-clustering is stressed by
it.

Every model is a pure function of (key, state) in global-SE-id order, so
the sharded engine reproduces it bit-exactly wherever an SE is hosted
(see parallel/lp_shard.py). Per-SE mobility state lives in two fields
that travel with the SE: `waypoint` (rwp target) and `mob` (member
offset for "group", unit heading for "flock"); global mobility state
(attractor/leader rows) lives in `mob_g`, replicated everywhere.

Vectorized over all SEs. The proximity/LP-histogram hot spot — the O(N^2)
pairwise matching the paper names as the model's dominant cost — has four
interchangeable backends selected by `ABMConfig.proximity_backend`:

  "dense"        full O(N^2) jnp sweep; the exact-parity oracle
  "grid"         cell-list neighbor search (core/neighbors.py), O(N*k);
                 the default — bit-identical to dense
  "pallas"       dense-sweep Pallas TPU kernel (kernels/proximity)
  "pallas_grid"  grid-candidate Pallas TPU kernel (kernels/proximity)

All four return bit-identical counts (tests/test_neighbors.py); "grid"
and "pallas_grid" fall back to the dense math when the world is too
small to tessellate (area / interaction_range < 3 cells per side).
Non-uniform mobility breaks the grid's uniform-density auto-capacity:
`grid_spec()` switches to a clustered-density bound for the non-RWP
models (see neighbors.clustered_capacity), and the engine surfaces the
per-step `grid_overflow` metric so runs can assert exactness.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import neighbors
from repro.core import partition as part

PROXIMITY_BACKENDS = ("dense", "grid", "pallas", "pallas_grid")
MOBILITY_MODELS = ("rwp", "hotspot", "group", "flock", "trace")
WORKLOADS = ("none", "epidemic")
TRACE_POLICIES = ("loop", "hold", "exact")

#: PRNG salts of the epidemic workload's independent streams (fold_in
#: off the step key, like the repartition/init salts — the epidemic
#: consumes no draw any existing stream sees, so workload="none" runs
#: stay bit-identical to pre-epidemic seeds)
EPI_SEED_SALT = 0x390a
EPI_INFECT_SALT = 0x3911
EPI_RECOVER_SALT = 0x3912

#: attractor ("hotspot") / leader ("group") speed relative to SE speed —
#: slower than the SEs chasing them, so clusters stay coherent in motion
_GLOBAL_SPEED_FACTOR = 0.5


@dataclasses.dataclass(frozen=True)
class ABMConfig:
    n_se: int = 10_000
    n_lp: int = 4
    area: float = 10_000.0  # toroidal square side (spaceunits)
    speed: float = 11.0  # spaceunits/timestep (min = max, Exp. 1)
    interaction_range: float = 250.0
    p_interact: float = 0.2  # pi: P(SE sends an interaction this timestep)
    proximity_backend: str = "grid"  # see PROXIMITY_BACKENDS
    grid_capacity: int = 0  # per-cell member cap; 0 = auto from density
    # hard memory budget (MiB) for the proximity data structures: sizes
    # the CSR sweep's chunk transients and clamps the auto grid capacity
    # (neighbors.budget_capacity). 0 = unbudgeted (historical defaults).
    # A budget too small for the true density is loud, never silent: the
    # clamped capacity trips `grid_overflow`, exactness is re-checkable.
    mem_budget_mb: int = 0
    # --- mobility scenario (see module docstring) -----------------------
    mobility: str = "rwp"  # see MOBILITY_MODELS
    n_groups: int = 8  # K attractors ("hotspot") / groups ("group")
    group_radius: float = 250.0  # cluster spatial scale (spaceunits)
    # --- trace replay (mobility == "trace") -----------------------------
    # the trace itself is data, not config: `trace_name` keys into the
    # repro.data.pipeline registry so this dataclass stays hashable for
    # the compiled-scan memo; frames become jit constants at trace time
    trace_name: str = ""
    trace_policy: str = "loop"  # see TRACE_POLICIES
    # --- interacting workload (see module docstring) --------------------
    workload: str = "none"  # see WORKLOADS
    epi_beta: float = 0.3  # per-contact per-step infection probability
    epi_gamma: float = 0.0  # per-step recovery probability (0=SI, >0=SIS)
    epi_seed_frac: float = 0.02  # initially infectious fraction (a patch)
    epi_boost: float = 4.0  # send-probability multiplier while infectious
    # --- initial SE -> LP map (core/partition.py registry) --------------
    partitioner: str = "random"  # see partition.PARTITION_BACKENDS
    # REMOVED (was a PR 1 boolean, deprecated since PR 1/PR 5): passing
    # it raises a TypeError naming `proximity_backend`. An InitVar keeps
    # the keyword accepted long enough to fail with that message instead
    # of dataclasses' generic "unexpected keyword argument".
    use_pallas: dataclasses.InitVar[object] = None

    def __post_init__(self, use_pallas=None):
        if use_pallas is not None:
            raise TypeError(
                "ABMConfig.use_pallas was removed; set "
                "proximity_backend='pallas' (or 'pallas_grid') instead")
        if self.proximity_backend not in PROXIMITY_BACKENDS:
            raise ValueError(
                f"proximity_backend={self.proximity_backend!r} not in "
                f"{PROXIMITY_BACKENDS}")
        if self.partitioner not in part.PARTITION_BACKENDS:
            raise ValueError(
                f"partitioner={self.partitioner!r} not in "
                f"{part.PARTITION_BACKENDS}")
        if self.mobility not in MOBILITY_MODELS:
            raise ValueError(
                f"mobility={self.mobility!r} not in {MOBILITY_MODELS}")
        if self.mobility in ("hotspot", "group") and self.n_groups < 1:
            raise ValueError("n_groups must be >= 1 for clustered mobility")
        if self.n_se < 1 or self.n_lp < 1:
            raise ValueError(
                f"n_se={self.n_se} and n_lp={self.n_lp} must be >= 1")
        if self.area <= 0 or self.interaction_range <= 0:
            raise ValueError(
                f"area={self.area} and interaction_range="
                f"{self.interaction_range} must be > 0")
        if self.speed < 0 or self.group_radius <= 0:
            raise ValueError("speed must be >= 0 and group_radius > 0")
        if not 0.0 <= self.p_interact <= 1.0:
            raise ValueError(
                f"p_interact={self.p_interact} must be a probability")
        if self.grid_capacity < 0 or self.mem_budget_mb < 0:
            raise ValueError(
                "grid_capacity and mem_budget_mb must be >= 0 (0 = auto)")
        if self.mobility == "trace" and not self.trace_name:
            raise ValueError(
                "mobility='trace' needs trace_name — a key registered "
                "via repro.data.pipeline.register_trace")
        if self.trace_policy not in TRACE_POLICIES:
            raise ValueError(
                f"trace_policy={self.trace_policy!r} not in "
                f"{TRACE_POLICIES}")
        if self.workload not in WORKLOADS:
            raise ValueError(
                f"workload={self.workload!r} not in {WORKLOADS}")
        if self.workload == "epidemic":
            if self.proximity_backend not in ("dense", "grid"):
                raise ValueError(
                    "workload='epidemic' implements its exposure sweep "
                    "on the dense/grid proximity backends only")
            for nm, v in (("epi_beta", self.epi_beta),
                          ("epi_gamma", self.epi_gamma)):
                if not 0.0 <= v <= 1.0:
                    raise ValueError(f"{nm}={v} must be a probability")
            if not 0.0 < self.epi_seed_frac <= 1.0:
                raise ValueError(
                    f"epi_seed_frac={self.epi_seed_frac} must be in "
                    "(0, 1]")
            if self.epi_boost < 1.0:
                raise ValueError(
                    f"epi_boost={self.epi_boost} must be >= 1 (1 = no "
                    "load shift)")

    def resolved_backend(self) -> str:
        """The proximity backend (kept for callers of the historical
        `use_pallas`-shim API; the field itself is gone)."""
        return self.proximity_backend

    def grid_spec(self):
        """Cell-list geometry for this config, or None if the world is
        too small to tessellate (grid backends then use dense math).

        An explicit `grid_capacity` always wins (never budget-clamped).
        Otherwise the auto capacity is density-adaptive in two stages:
        the mobility model picks the density bound — RWP keeps the
        uniform Poisson bound; the clustered models size for K blobs of
        n/K SEs at the model's spatial scale (attractor dwell radius /
        member offset radius / a cell for emergent flocks), where the
        uniform bound would overflow and silently undercount — and then
        a positive `mem_budget_mb` clamps it to what the budget affords
        (neighbors.budget_capacity). The clamp keeps the exact-or-loud
        contract: an underbudgeted capacity trips `grid_overflow`."""
        spec = neighbors.make_grid_spec(self.n_se, self.area,
                                        self.interaction_range,
                                        capacity=self.grid_capacity)
        if spec is None or self.grid_capacity > 0:
            return spec
        if self.mobility == "trace":
            # the frames are known in full, so the density bound is not
            # a heuristic: the exact peak cell occupancy over every
            # frame (positions each step ARE a frame, so nothing can
            # exceed it)
            cap = trace_frames(self).peak_cell_occupancy(spec.ncell)
            spec = dataclasses.replace(spec,
                                       capacity=max(spec.capacity, cap))
        elif self.mobility != "rwp":
            radius = {"hotspot": 0.5 * self.group_radius,
                      "group": self.group_radius,
                      "flock": spec.cell}[self.mobility]
            cap = neighbors.clustered_capacity(self.n_se, spec.ncell,
                                               spec.cell, self.n_groups,
                                               radius)
            spec = dataclasses.replace(spec,
                                       capacity=max(spec.capacity, cap))
        if self.mem_budget_mb > 0:
            cap = min(spec.capacity,
                      neighbors.budget_capacity(spec.ncell,
                                                self.mem_budget_mb))
            spec = dataclasses.replace(spec, capacity=cap)
        return spec


def mobility_globals(cfg: ABMConfig) -> int:
    """Rows of the replicated global mobility state `mob_g` (attractors
    for "hotspot", leaders for "group"; 1 row otherwise so shapes stay
    static — "trace" rides its frame counter in that row's [0, 0])."""
    return cfg.n_groups if cfg.mobility in ("hotspot", "group") else 1


def trace_frames(cfg: ABMConfig):
    """Resolve cfg.trace_name to its registered Trace, validated against
    the config (exact-or-loud: a trace of the wrong shape or world size
    would replay garbage silently)."""
    from repro.data import pipeline as dpipe
    tr = dpipe.get_trace(cfg.trace_name)
    if tr.n_se != cfg.n_se:
        raise ValueError(
            f"trace {cfg.trace_name!r} holds {tr.n_se} SEs but "
            f"ABMConfig.n_se={cfg.n_se}")
    if abs(tr.area - cfg.area) > 1e-6 * max(cfg.area, 1.0):
        raise ValueError(
            f"trace {cfg.trace_name!r} lives on an area={tr.area} torus "
            f"but ABMConfig.area={cfg.area}")
    return tr


def check_trace_horizon(cfg: ABMConfig, t0: int, n_steps: int) -> None:
    """Host-side guard for trace_policy='exact': every step of the
    window [t0, t0 + n_steps) must read a real frame (step t replays
    frame t+1). Called by the engine runners before tracing — raising
    here beats silently holding the last frame, which is exactly what
    'exact' exists to forbid."""
    if n_steps <= 0 or cfg.mobility != "trace" \
            or cfg.trace_policy != "exact":
        return
    T = trace_frames(cfg).timesteps
    need = t0 + n_steps  # the last step of the window reads this frame
    if need > T - 1:
        raise ValueError(
            f"trace {cfg.trace_name!r} has {T} frames but steps "
            f"[{t0}, {t0 + n_steps}) need frame {need} under "
            "trace_policy='exact'; shorten the horizon, extend the "
            "trace, or pick trace_policy='loop'/'hold'")


def init_abm(key, cfg: ABMConfig):
    """Initial model state, in global-SE-id order.

    Besides pos/waypoint/lp this now carries the mobility state: `mob`
    (N, 2) per-SE (member offsets / headings; zeros when unused) and
    `mob_g` (G, 4) global rows [pos | waypoint] for attractors/leaders.
    The k1/k2/k3 consumption is unchanged from the RWP-only version, so
    existing RWP seeds reproduce bit-identically; clustered models remap
    the same k1 uniforms into their blob offsets (initial density is
    non-uniform from step 0, which is the point of those scenarios).

    The SE -> LP map comes from the configured partitioning backend
    (`cfg.partitioner`, core/partition.py) fed with the *final* initial
    positions, so informed backends see the clustered density. The
    default "random" backend consumes k3 exactly as the pre-registry
    round-robin line did — existing seeds reproduce bit-identically.
    """
    n, G = cfg.n_se, mobility_globals(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    pos = jax.random.uniform(k1, (n, 2), maxval=cfg.area)
    wp = jax.random.uniform(k2, (n, 2), maxval=cfg.area)
    mob = jnp.zeros((n, 2), jnp.float32)
    mob_g = jnp.zeros((G, 4), jnp.float32)
    if cfg.mobility in ("hotspot", "group"):
        kg = jax.random.fold_in(key, 0x6b0a)
        mob_g = jax.random.uniform(kg, (G, 4), maxval=cfg.area)
        anchor = mob_g[jnp.arange(n) % G, :2]
        # remap the uniform k1 draw into a per-blob square of side
        # 2 * group_radius around each SE's anchor
        jitter = (pos / cfg.area - 0.5) * (2.0 * cfg.group_radius)
        if cfg.mobility == "group":
            ko = jax.random.fold_in(key, 0x6b0b)
            mob = (jax.random.uniform(ko, (n, 2)) - 0.5) * \
                (2.0 * cfg.group_radius)
            anchor = anchor + mob
            jitter = jitter * 0.1  # members start tight on their slot
        pos = (anchor + jitter) % cfg.area
    elif cfg.mobility == "flock":
        kh = jax.random.fold_in(key, 0x6b0c)
        theta = jax.random.uniform(kh, (n,), maxval=2.0 * jnp.pi)
        mob = jnp.stack([jnp.cos(theta), jnp.sin(theta)], axis=1)
    elif cfg.mobility == "trace":
        # k1/k2 are drawn (and discarded) above so the split pattern
        # stays uniform across models; frame 0 is the initial layout
        pos = jnp.asarray(trace_frames(cfg).frames[0])
    lp = part.partition(k3, pos, jnp.ones((n,), jnp.float32),
                        part.from_abm(cfg))
    epi = epidemic_init(key, pos, cfg) if cfg.workload == "epidemic" \
        else jnp.zeros((n,), jnp.int32)
    return {"pos": pos, "waypoint": wp, "lp": lp,
            "mob": mob.astype(jnp.float32), "mob_g": mob_g, "epi": epi}


def toroidal_delta(a, b, area):
    """Shortest per-axis displacement on the torus."""
    d = jnp.abs(a - b)
    return jnp.minimum(d, area - d)


def toroidal_signed_delta(frm, to, area):
    """Signed shortest per-axis displacement frm -> to on the torus."""
    return (to - frm + area / 2.0) % area - area / 2.0


def _unit(v, eps=1e-9):
    """Row-wise unit vector (zero rows stay zero)."""
    norm = jnp.linalg.norm(v, axis=-1, keepdims=True)
    return v / jnp.maximum(norm, eps)


def rwp_draws(key, n: int, cfg: ABMConfig):
    """The fresh-waypoint draw for all n SEs, indexed by global SE id.

    Factored out of `rwp_step` so the sharded engine can compute the
    *same* (n, 2) array on every device and gather each shard's rows by
    SE id — the draw for SE i must be identical no matter which device
    currently hosts it (bit-identity with the single-device oracle)."""
    return jax.random.uniform(key, (n, 2), maxval=cfg.area)


def rwp_apply(pos, waypoint, new_wp, cfg: ABMConfig, speed=None):
    """The deterministic half of a Random-Waypoint move: advance `speed`
    toward the waypoint (torus-aware); on arrival switch to the
    pre-drawn fresh waypoint `new_wp` (sleep time 0). `speed` overrides
    cfg.speed (attractor/leader rows move slower than their SEs)."""
    speed = cfg.speed if speed is None else speed
    delta = waypoint - pos
    # shortest direction on the torus
    delta = jnp.where(delta > cfg.area / 2, delta - cfg.area, delta)
    delta = jnp.where(delta < -cfg.area / 2, delta + cfg.area, delta)
    dist = jnp.linalg.norm(delta, axis=-1, keepdims=True)
    arrived = dist[:, 0] <= speed
    step = jnp.where(dist > 0, delta / jnp.maximum(dist, 1e-9), 0.0)
    new_pos = jnp.where(arrived[:, None], waypoint,
                        (pos + step * speed) % cfg.area)
    next_wp = jnp.where(arrived[:, None], new_wp, waypoint)
    return new_pos % cfg.area, next_wp


def rwp_step(key, pos, waypoint, cfg: ABMConfig):
    """One Random-Waypoint move (draw + apply; see rwp_draws/rwp_apply)."""
    return rwp_apply(pos, waypoint, rwp_draws(key, pos.shape[0], cfg), cfg)


def _globals_step(key, mob_g, cfg: ABMConfig):
    """Advance attractor/leader rows by RWP at a fraction of SE speed.
    Pure in (key, mob_g): every device computes the identical update."""
    g = mob_g.shape[0]
    draw = jax.random.uniform(key, (g, 2), maxval=cfg.area)
    gpos, gwp = rwp_apply(mob_g[:, :2], mob_g[:, 2:], draw, cfg,
                          speed=cfg.speed * _GLOBAL_SPEED_FACTOR)
    return jnp.concatenate([gpos, gwp], axis=1)


def _hotspot_apply(pos, anchor, noise, cfg: ABMConfig):
    """Row-local half of the hotspot move: pull toward the SE's
    attractor, saturating at `speed` beyond the dwell radius; uniform
    noise keeps the blob from collapsing. The stationary blob radius is
    ~0.4 * group_radius. Elementwise per row, so the sharded engine can
    run it on any row subset (anchor/noise gathered by SE id) and still
    match the oracle bit-for-bit."""
    delta = toroidal_signed_delta(pos, anchor, cfg.area)
    dist = jnp.linalg.norm(delta, axis=-1, keepdims=True)
    pull = _unit(delta) * cfg.speed * jnp.minimum(
        1.0, dist / jnp.float32(cfg.group_radius))
    return (pos + pull + noise) % cfg.area


def _group_apply(pos, target, noise, cfg: ABMConfig):
    """Row-local half of the RPGM-lite move: chase (leader + fixed
    member offset) at up to `speed`, with small jitter. Groups migrate
    coherently behind their leader."""
    delta = toroidal_signed_delta(pos, target, cfg.area)
    dist = jnp.linalg.norm(delta, axis=-1, keepdims=True)
    step = _unit(delta) * jnp.minimum(dist, cfg.speed)
    return (pos + step + noise) % cfg.area


def row_local_mobility(cfg: ABMConfig) -> bool:
    """True iff the model factors into (full-size id-order draws) x
    (elementwise per-row apply) — rwp/hotspot/group. The sharded engine
    then moves each shard's rows without any position gather; "flock"
    reads global cell aggregates (a float scatter-add whose reduction
    order must match the oracle), so it stays gather-reconstruct."""
    return cfg.mobility in ("rwp", "hotspot", "group", "trace")


def mobility_row_draws(key, n: int, mob_g, cfg: ABMConfig):
    """Full-size (n, 2) id-order draw arrays for the row-local models,
    plus the advanced global rows. Pure in (key, mob_g): every device
    computes the identical arrays and gathers its own shard's rows by SE
    id, so the draw an SE sees is independent of which device hosts it
    (the bit-identity requirement — same contract as `rwp_draws`).

    Returns (draws, mob_g): draws is {"wp"} for rwp, {"anchor",
    "noise"} for hotspot/group (anchor = the SE's attractor position /
    its group leader's position, noise = the per-step jitter), {"tp"}
    for trace (the next frame, PRNG-free — the frame counter rides
    mob_g[0, 0], a float32 exact for any practical horizon)."""
    if cfg.mobility == "rwp":
        return {"wp": rwp_draws(key, n, cfg)}, mob_g
    if cfg.mobility == "trace":
        frames = jnp.asarray(trace_frames(cfg).frames)
        T = frames.shape[0]
        nxt = mob_g[0, 0].astype(jnp.int32) + 1
        if cfg.trace_policy == "loop":
            idx = nxt % T
        else:  # "hold"; "exact" windows are pre-checked host-side
            idx = jnp.minimum(nxt, T - 1)
        return {"tp": frames[idx]}, mob_g.at[0, 0].add(1.0)
    k_glob = jax.random.fold_in(key, 1)
    k_noise = jax.random.fold_in(key, 2)
    mob_g = _globals_step(k_glob, mob_g, cfg)
    anchor = mob_g[jnp.arange(n) % mob_g.shape[0], :2]
    scale = cfg.speed if cfg.mobility == "hotspot" else 0.5 * cfg.speed
    noise = (jax.random.uniform(k_noise, (n, 2)) - 0.5) * scale
    return {"anchor": anchor, "noise": noise}, mob_g


def mobility_row_apply(pos, waypoint, mob, draws, cfg: ABMConfig):
    """Elementwise per-row half of the row-local models: advance any row
    subset given its rows of the `mobility_row_draws` arrays. Returns
    (pos, waypoint) — `mob` is read-only here (the group member
    offset)."""
    if cfg.mobility == "rwp":
        return rwp_apply(pos, waypoint, draws["wp"], cfg)
    if cfg.mobility == "trace":
        return draws["tp"], waypoint  # replay is the whole move
    if cfg.mobility == "hotspot":
        return _hotspot_apply(pos, draws["anchor"], draws["noise"],
                              cfg), waypoint
    target = (draws["anchor"] + mob) % cfg.area  # group
    return _group_apply(pos, target, draws["noise"], cfg), waypoint


def max_step_displacement(cfg: ABMConfig) -> float:
    """Upper bound on any SE's per-axis displacement in one mobility
    step — the halo-need dilation radius derives from it (see
    parallel/lp_shard.py). rwp/flock move exactly `speed` along a unit
    direction; hotspot adds up to 0.5*speed of per-axis noise on top of
    a speed-capped pull, group up to 0.25*speed on a speed-capped
    chase; trace measures its exact frame-to-frame bound (the `loop`
    policy additionally pays for the trace's wrap-seam jump)."""
    if cfg.mobility == "trace":
        return trace_frames(cfg).max_step_displacement(
            include_seam=cfg.trace_policy == "loop")
    return {"rwp": cfg.speed, "hotspot": 1.5 * cfg.speed,
            "group": 1.25 * cfg.speed, "flock": cfg.speed}[cfg.mobility]


def _flock_step(k_noise, pos, mob, cfg: ABMConfig, valid=None):
    """Flocking-lite over the cell-list grid: steer by inertia +
    alignment with the 3x3-neighborhood mean heading + cohesion toward
    its centroid + noise; move at constant `speed` along the heading.
    Degenerate worlds (no grid) flock against the global mean. `valid`
    (open-world engine) keeps departed rows out of the flock's cell
    aggregates — a dead row must influence nobody."""
    n = pos.shape[0]
    spec = cfg.grid_spec()
    if spec is not None:
        (cdelta, hmean) = neighbors.cell_block_mean(pos, mob, spec,
                                                    cfg.area, valid=valid)
    else:  # un-tessellatable world: one global "cell" (non-toroidal mean)
        if valid is not None:
            vpos = jnp.where(valid[:, None], pos, 0.0)
            vmob = jnp.where(valid[:, None], mob, 0.0)
            csum = vpos.sum(0) - vpos
            hsum = vmob.sum(0) - vmob
            cnt = jnp.maximum(valid.sum() - 1, 1)
        else:
            csum = pos.sum(0) - pos
            hsum = mob.sum(0) - mob
            cnt = jnp.maximum(n - 1, 1)
        cdelta = csum / cnt - pos
        hmean = hsum / cnt
    cohere = _unit(cdelta) * jnp.minimum(
        1.0, jnp.linalg.norm(cdelta, axis=-1, keepdims=True)
        / jnp.float32(cfg.interaction_range))
    noise = (jax.random.uniform(k_noise, (n, 2)) - 0.5) * 2.0
    heading = _unit(mob + 0.8 * _unit(hmean) + 0.6 * cohere + 0.4 * noise)
    # a fully cancelled steer (zero vector) keeps the old heading
    heading = jnp.where(jnp.linalg.norm(heading, axis=-1,
                                        keepdims=True) > 0.5, heading, mob)
    return (pos + heading * cfg.speed) % cfg.area, heading


def mobility_step(key, pos, waypoint, mob, mob_g, cfg: ABMConfig,
                  valid=None):
    """One mobility timestep for all N SEs, in global-SE-id order.

    Returns (pos, waypoint, mob, mob_g). Pure in (key, state): the
    sharded engine reconstructs id-order state, calls this very
    function, and scatters rows back to its slots, so trajectories are
    bit-identical to the single-device oracle by construction (see
    parallel/lp_shard.py). Fields a model does not use pass through
    untouched. `valid` (open-world engine) masks departed rows out of
    any *global* aggregate a model reads (flock's cell means); the
    row-local models ignore it — the caller discards dead rows' moves.
    """
    if row_local_mobility(cfg):
        draws, mob_g = mobility_row_draws(key, pos.shape[0], mob_g, cfg)
        pos, waypoint = mobility_row_apply(pos, waypoint, mob, draws, cfg)
        return pos, waypoint, mob, mob_g
    k_noise = jax.random.fold_in(key, 2)  # flock
    pos, mob = _flock_step(k_noise, pos, mob, cfg, valid=valid)
    return pos, waypoint, mob, mob_g


def _dense_counts(pos, lp, sender_mask, cfg: ABMConfig):
    return neighbors.dense_lp_counts(pos, lp, sender_mask, cfg.n_lp,
                                     cfg.area, cfg.interaction_range)


def interaction_counts_overflow(pos, lp, sender_mask, cfg: ABMConfig,
                                valid=None):
    """Per-sender histogram of recipient LPs, plus the grid's overflow
    alarm.

    Returns (counts, overflow): counts (N, n_lp) int32 with
    counts[i, l] = number of SEs within `interaction_range` of sender i
    currently allocated on LP l (self excluded; non-sender rows zero),
    and overflow () bool — True iff a grid cell exceeded its capacity
    this call, which silently undercounts neighbors (the non-uniform
    mobility models are exactly the workloads that can trip it; the
    engine surfaces it as the per-step `grid_overflow` metric). The
    default grid backend reads the flag off the grid build it performs
    anyway; dense backends are always exact (False).

    `valid` (open-world engine) masks departed rows out of the grid
    build entirely: a dead row with lp = -1 already contributes to no
    LP column (and must not be a sender — the caller folds `valid` into
    `sender_mask`), but keeping it out of the cells also stops stale
    positions from occupying capacity slots or tripping `overflow`. The
    Pallas backends table every row, so they stay closed-world only
    (EngineConfig validation rejects the combination).

    Dispatches on `cfg.proximity_backend`; every backend is bit-identical
    (dense is the oracle — see tests/test_neighbors.py and DESIGN.md
    §Adaptations for the trade-offs).
    """
    backend = cfg.resolved_backend()
    spec = cfg.grid_spec() if backend in ("grid", "pallas_grid") else None
    if backend in ("grid", "pallas_grid") and spec is None:
        backend = "dense"  # world too small to tessellate: exact fallback
    n = pos.shape[0]
    if backend == "grid":
        # CSR sweep in sorted cell order (see neighbors.grid_lp_counts):
        # no member table, no (N, 9 * capacity) candidate matrix — peak
        # memory is bounded by the chunk budget regardless of N
        grid = neighbors.build_grid(pos, spec, valid=valid,
                                    with_table=False)
        order = grid["order"]
        out = neighbors.rows_grid_counts(
            pos, lp, cfg.n_lp, cfg.area, cfg.interaction_range, spec, grid,
            pos[order], order.astype(jnp.int32), sender_mask[order],
            neighbors.chunk_entries(cfg.mem_budget_mb))
        counts = jnp.zeros((n, cfg.n_lp), jnp.int32).at[order].set(out)
        return counts, grid["overflow"]
    if backend == "pallas":
        from repro.kernels.proximity.ops import proximity_lp_counts
        return proximity_lp_counts(pos, lp, sender_mask, cfg.n_lp,
                                   cfg.area, cfg.interaction_range), \
            jnp.bool_(False)
    if backend == "pallas_grid":
        from repro.kernels.proximity.ops import proximity_lp_counts_grid
        # the kernel builds its own table; one O(N) bincount yields the
        # same occupancy flag the grid build would have reported
        occ = jnp.zeros((spec.ncell * spec.ncell,), jnp.int32).at[
            neighbors.cell_ids(pos, spec)].add(1)
        return proximity_lp_counts_grid(pos, lp, sender_mask, cfg.n_lp,
                                        cfg.area, cfg.interaction_range,
                                        spec), occ.max() > spec.capacity
    return _dense_counts(pos, lp, sender_mask, cfg), jnp.bool_(False)


def interaction_counts(pos, lp, sender_mask, cfg: ABMConfig):
    """`interaction_counts_overflow` without the alarm (same contract)."""
    return interaction_counts_overflow(pos, lp, sender_mask, cfg)[0]


# ---------------------------------------------------------------------------
# Epidemic/gossip diffusion workload (ABMConfig.workload == "epidemic")
# ---------------------------------------------------------------------------
# State is one int32 flag per SE (`epi`: 0 susceptible, 1 infectious)
# that travels with the row through migrations and resharding. The
# update factors exactly like the row-local mobility models do —
# full-size id-order draw arrays x an elementwise per-row transition —
# so the sharded engine gathers each shard's draw rows by SE id and
# stays bit-identical to the oracle wherever a row is hosted.


def epidemic_init(key, pos, cfg: ABMConfig):
    """Initial infection flags: the k = max(1, round(epi_seed_frac*n))
    SEs nearest (torus metric) to one key-drawn origin start
    infectious — a spatial patch, not a uniform sprinkle, so the wave
    has somewhere to travel *from* and load genuinely shifts across
    LPs as it spreads. Deterministic in (key, pos): every device
    computes the identical flags."""
    n = pos.shape[0]
    k = max(1, int(round(cfg.epi_seed_frac * n)))
    origin = jax.random.uniform(jax.random.fold_in(key, EPI_SEED_SALT),
                                (2,), maxval=cfg.area)
    d = toroidal_delta(pos, origin[None, :], cfg.area)
    d2 = d[:, 0] ** 2 + d[:, 1] ** 2
    thresh = jnp.sort(d2)[k - 1]
    return (d2 <= thresh).astype(jnp.int32)


def epidemic_send_prob(epi, cfg: ABMConfig):
    """Per-SE interaction probability: infectious SEs send
    `epi_boost`x more often (capped at 1). This is the load-shift
    mechanism — event weight follows the infection wave, not the
    density map, which is what stresses self-clustering beyond any
    pure-mobility scenario."""
    p = jnp.float32(cfg.p_interact)
    hot = jnp.minimum(p * jnp.float32(cfg.epi_boost), jnp.float32(1.0))
    return jnp.where(epi > 0, hot, p)


def epidemic_draws(key, n: int, cfg: ABMConfig):
    """Full-size (n,) id-order uniforms for the infection (and, when
    epi_gamma > 0, recovery) trials — same device-independence
    contract as `mobility_row_draws`. Streams are salted off the step
    key, so no existing draw moves."""
    d = {"u_inf": jax.random.uniform(
        jax.random.fold_in(key, EPI_INFECT_SALT), (n,))}
    if cfg.epi_gamma > 0.0:
        d["u_rec"] = jax.random.uniform(
            jax.random.fold_in(key, EPI_RECOVER_SALT), (n,))
    return d


def epidemic_row_update(epi, exposure, draws, cfg: ABMConfig):
    """Elementwise SI/SIS transition for any row subset: a susceptible
    row with `exposure` in-range infectious senders catches with
    p = 1 - (1-beta)^exposure (independent per-contact trials); with
    SIS (epi_gamma > 0) an infectious row recovers to susceptible with
    gamma. Zero exposure gives p = 0, so dead/padded rows (exposure 0
    by construction) never transition."""
    p_inf = 1.0 - jnp.power(jnp.float32(1.0 - cfg.epi_beta),
                            exposure.astype(jnp.float32))
    catch = (epi == 0) & (draws["u_inf"] < p_inf)
    out = jnp.where(catch, 1, epi)
    if cfg.epi_gamma > 0.0:
        rec = (epi > 0) & (draws["u_rec"] < jnp.float32(cfg.epi_gamma))
        out = jnp.where(rec, 0, out)
    return out


def epidemic_exposure_overflow(pos, labels, query_mask, cfg: ABMConfig,
                               valid=None):
    """exposure[i] = #{j != i in interaction_range with labels[j] == 1}
    for rows with `query_mask` (zeros elsewhere), plus the grid
    overflow alarm. `labels` carries 1 on the infectious rows that
    actually sent this step, 0 on other live rows, and -1 on dead rows
    (one_hot drops them from the dense path; `valid` keeps them out of
    the grid build).

    This is the proximity phase's candidate walk with a 2-class label
    array instead of the LP map — grid and dense stay bit-identical by
    the same argument, and the one extra sweep is the entire cost of
    the workload."""
    backend = cfg.resolved_backend()
    spec = cfg.grid_spec() if backend == "grid" else None
    n = pos.shape[0]
    if spec is not None:
        grid = neighbors.build_grid(pos, spec, valid=valid,
                                    with_table=False)
        order = grid["order"]
        out = neighbors.rows_grid_counts(
            pos, labels, 2, cfg.area, cfg.interaction_range, spec, grid,
            pos[order], order.astype(jnp.int32), query_mask[order],
            neighbors.chunk_entries(cfg.mem_budget_mb))
        counts = jnp.zeros((n, 2), jnp.int32).at[order].set(out)
        return counts[:, 1], grid["overflow"]
    counts = neighbors.dense_lp_counts(pos, labels, query_mask, 2,
                                       cfg.area, cfg.interaction_range)
    return counts[:, 1], jnp.bool_(False)

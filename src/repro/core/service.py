"""Resident engine service: the unified stepping API (PR 8).

Historically the engine was a batch artifact — six free functions
(`run`, `run_window`, `run_batch`, `init_engine`, `init_batch`,
`run_window_batch`) that init, scan, and return. This module makes the
engine a *resident service* around the same memoized jitted scans:

- `Engine` — one facade over init / stepping / open-world churn /
  device-state queries, on both execution layers ("none" and
  "lp_device") and both replica shapes (single seed or a batch). The
  state stays on device between calls; `step` windows reuse the
  compiled-scan memo, so an interactive session pays tracing once.

- **Open-world churn** (cfg.open_world): `arrive(rows)` / `depart(ids)`
  are O(batch) in-device slot updates — the oracle keeps a fixed
  universe of `abm.n_se` slots with `lp >= 0` marking live rows (the
  generalization of the sharded layer's `gid >= 0` free-slot
  machinery), and the sharded layer packs arrivals into per-device free
  slots exactly like cross-device migrations land. Exact-or-loud: a
  batch that outgrows the free pool (or a device's `shard_capacity`)
  raises before (or without) corrupting state. With zero churn and a
  full population the trajectory is bit-identical to the closed-world
  engine on both layers (tests/test_service.py).

- **Queries** served from device state — `query_neighbors` (the PR 7
  CSR cell list, reused as a read-only index), `query_lcr` (the
  would-be flow matrix if every live SE sent now), `query_region`
  (wrap-aware bbox filter). No unshard: sharded queries run on the
  slot-major global view.

- `ReplicaService` — request multiplexing over the PR 5 batch axis:
  R resident replica slots advance together in batched windows sized
  to the nearest request boundary (continuous batching); a finished
  slot is refilled from the queue while the others keep their state,
  so the device never idles between requests. Each request's merged
  counters are exactly what a solo run of that seed reports.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import engine as _eng
from repro.core import neighbors
from repro.core.abm import interaction_counts_overflow
from repro.core.engine import EngineConfig
from repro.core.stats import merge_counters
from repro.obs import runtime as obs_runtime
from repro.obs.ledger import Telemetry


def _pad_pow2(b: int) -> int:
    """Round a churn batch up to a power of two so repeated interactive
    batches of drifting sizes hit a handful of compiled shapes."""
    return 1 << max(0, b - 1).bit_length()


_jit_oracle_arrive = jax.jit(_eng.oracle_arrive)
_jit_oracle_depart = jax.jit(_eng.oracle_depart)


class Engine:
    """Resident facade over the GAIA engine (see module docstring).

    >>> eng = Engine(cfg).init(seed=0)
    >>> eng.step(200)                      # window counters
    >>> ids = eng.arrive({"pos": new_pos}) # open_world only
    >>> eng.query_neighbors(ids[:2])
    >>> eng.metrics()                      # accumulated run counters

    Batched replicas: `init(seeds=[...])` — `step` then returns one
    counters dict per replica. Churn and queries are single-replica
    (they address one resident world); a batched engine raises on them.
    """

    def __init__(self, cfg: EngineConfig, obs_sinks=None):
        self.cfg = cfg
        self.state = None
        self._batched = False
        self._parts = []  # per-window counters (or lists, batched)
        self._weights = []
        self._steps = 0
        self._live = set()
        self._free = []
        # telemetry session (cfg.obs.enabled): the ledger fills from the
        # device ring drain during single-replica `step` windows (the
        # batched scans stay un-instrumented — engine.strip_obs), the
        # event log additionally hears churn batches and tuner moves
        # host-side. Compiled executables are shared across Engine
        # instances, so the session is re-asserted current around every
        # windowed call (repro.obs.runtime routing).
        self.telemetry = (Telemetry(cfg, sinks=obs_sinks)
                          if cfg.obs.enabled else None)

    # -- lifecycle -------------------------------------------------------

    def init(self, seeds=None, *, seed: int = 0) -> "Engine":
        """Materialize resident device state: one replica from `seed`,
        or R stacked replicas from `seeds` (overrides `seed`)."""
        if seeds is not None:
            self.state = _eng._init_batch(self.cfg, list(seeds))
            self._batched = True
        else:
            self.state = _eng._init_engine(jax.random.key(int(seed)),
                                           self.cfg)
            self._batched = False
        self._parts, self._weights, self._steps = [], [], 0
        live = self.cfg.initial_live()
        self._live = set(range(live))
        self._free = list(range(self.cfg.abm.n_se - 1, live - 1, -1))
        return self

    def run(self, seeds=None, *, seed: int = 0):
        """One-shot convenience (the old `run` / `run_batch` contract):
        returns (final_state, per-step series, counters) — counters is a
        list with `seeds`. Does not touch this engine's resident
        state."""
        if self.telemetry is not None:
            obs_runtime.set_current(self.telemetry)
        if seeds is not None:
            # batched scans are un-instrumented (engine.strip_obs): the
            # ledger covers the single-replica paths
            return _eng._run_batch(self.cfg, list(seeds))
        return _eng._run(jax.random.key(int(seed)), self.cfg)

    def _require_state(self):
        if self.state is None:
            raise RuntimeError("Engine.init() first — no resident state")

    def _single(self, what: str):
        self._require_state()
        if self._batched:
            raise RuntimeError(
                f"{what} addresses one resident world; this Engine holds "
                "a replica batch (init(seed=...) for a single one)")

    # -- stepping --------------------------------------------------------

    def step(self, n: int = 1, mf=None):
        """Advance the resident state n timesteps through the memoized
        compiled window scan. Returns this window's counters (a list of
        per-replica dicts when batched) and accumulates them into
        `metrics()`. `mf` overrides the Migration Factor for the window
        (per-replica vector allowed when batched) — the §5.5 tuners'
        contract, unchanged."""
        self._require_state()
        if self.telemetry is not None:
            obs_runtime.set_current(self.telemetry)
        if self._batched:
            self.state, counters = _eng._run_window_batch(
                self.state, self.cfg, n, mf=mf)
        else:
            self.state, counters = _eng._run_window(
                self.state, self.cfg, n, mf=mf)
        self._parts.append(counters)
        self._weights.append(n)
        self._steps += n
        return counters

    def metrics(self) -> dict:
        """Counters accumulated over every `step` window so far, plus
        the Eq. 8 migration_ratio over the stepped span (a list of
        per-replica dicts when batched)."""
        self._require_state()
        if not self._parts:
            return [] if self._batched else {}
        per_k = self.cfg.abm.n_se * (max(self._steps, 1) / 1000.0)
        if self._batched:
            out = []
            for r in range(len(self._parts[0])):
                c = merge_counters([p[r] for p in self._parts],
                                   self._weights)
                c["migration_ratio"] = c["migrations"] / per_k
                out.append(c)
            return out
        c = merge_counters(self._parts, self._weights)
        c["migration_ratio"] = c["migrations"] / per_k
        return c

    # -- telemetry views (cfg.obs.enabled) -------------------------------

    def _require_obs(self, what: str):
        if self.telemetry is None:
            raise RuntimeError(
                f"{what} needs EngineConfig(obs=ObsConfig(enabled=True))")

    def ledger(self):
        """The per-step :class:`~repro.obs.ledger.MetricsLedger` filled
        by the device ring drain (rows()/column()/summary()/latest())."""
        self._require_obs("ledger")
        return self.telemetry.ledger

    def events(self, kind=None) -> list:
        """Telemetry events recorded so far, newest last, optionally
        filtered by kind (see repro.obs.events.EVENT_KINDS)."""
        self._require_obs("events")
        return self.telemetry.events.records(kind)

    def prometheus(self) -> str:
        """Prometheus text exposition of the session: latest per-step
        gauges + whole-run means from the ledger, event counts, and the
        facade's own occupancy."""
        from repro.obs.prom import prometheus_text
        self._require_obs("prometheus")
        extra = {"steps_total": self._steps}
        if self.cfg.open_world:
            extra["population"] = self.population()
        return prometheus_text(self.telemetry, extra=extra)

    def close(self) -> None:
        """Flush and close telemetry sinks (file sinks in particular);
        the engine remains usable, events simply stop being written to
        closed sinks."""
        if self.telemetry is not None:
            if obs_runtime.get_current() is self.telemetry:
                obs_runtime.set_current(None)
            self.telemetry.close()

    # -- open-world churn ------------------------------------------------

    def _require_open(self, what: str):
        self._single(what)
        if not self.cfg.open_world:
            raise RuntimeError(
                f"{what} needs EngineConfig(open_world=True)")

    def population(self) -> int:
        """Live SEs (host-side view of the free-slot pool)."""
        return len(self._live)

    def live_ids(self) -> list:
        """Sorted ids of the live SEs (the valid depart targets)."""
        return sorted(self._live)

    def arrive(self, rows) -> list:
        """Admit a batch of SEs. `rows["pos"]` (B, 2) is required;
        optional "lp" (default: the x-stripe LP of the position),
        "waypoint", "mob", "epi" (infection flag, default susceptible).
        Returns the B assigned SE ids. Raises
        RuntimeError, state untouched, if the universe has fewer than B
        free slots; on the sharded layer a destination device without a
        free slot raises too (naming shard_capacity), with the admitted
        prefix of the batch applied and reported."""
        import numpy as np
        self._require_open("arrive")
        pos = np.asarray(rows["pos"], np.float32).reshape(-1, 2)
        b = pos.shape[0]
        if b == 0:
            return []
        if b > len(self._free):
            raise RuntimeError(
                f"arrive: batch of {b} exceeds the {len(self._free)} "
                f"free slots of the n_se={self.cfg.abm.n_se} universe; "
                "raise abm.n_se (the slot universe) or depart SEs first")
        abm = self.cfg.abm
        if "lp" in rows:
            lps = np.asarray(rows["lp"], np.int32).reshape(-1)
        else:
            lps = np.clip((pos[:, 0] / abm.area * abm.n_lp).astype(
                np.int32), 0, abm.n_lp - 1)
        ids = [self._free.pop() for _ in range(b)]
        bp = _pad_pow2(b)
        pad_ids = np.full((bp,), -1, np.int32)
        pad_ids[:b] = ids
        pad_pos = np.zeros((bp, 2), np.float32)
        pad_pos[:b] = pos
        pad_lp = np.zeros((bp,), np.int32)
        pad_lp[:b] = lps
        prows = {"pos": pad_pos, "lp": pad_lp}
        for k in ("waypoint", "mob"):
            if k in rows:
                buf = np.zeros((bp, 2), np.float32)
                buf[:b] = np.asarray(rows[k], np.float32).reshape(-1, 2)
                prows[k] = buf
        if "epi" in rows:
            buf = np.zeros((bp,), np.int32)
            buf[:b] = np.asarray(rows["epi"], np.int32).reshape(-1)
            prows["epi"] = buf
        if self.cfg.sharding == "lp_device":
            from repro.parallel import lp_shard
            self.state, adm = lp_shard.arrive_sharded(
                self.state, self.cfg, pad_ids, prows)
            adm = np.asarray(adm)[:b]
            if not adm.all():
                refused = [i for i, ok in zip(ids, adm) if not ok]
                self._free.extend(reversed(refused))
                admitted = [i for i, ok in zip(ids, adm) if ok]
                self._live.update(admitted)
                raise RuntimeError(
                    f"arrive: {len(refused)} of {b} arrivals refused — "
                    "their destination devices have no free slot; raise "
                    "EngineConfig.shard_capacity (admitted: "
                    f"{len(admitted)} rows, already applied)")
        else:
            self.state = _jit_oracle_arrive(self.state, pad_ids, prows)
        self._live.update(ids)
        if self.telemetry is not None:
            self.telemetry.emit("arrive", self._steps, count=b,
                                population=len(self._live))
        return ids

    def depart(self, ids) -> None:
        """Remove the SEs `ids` (an O(batch) in-device update). Their
        slots return to the free pool. Raises KeyError, state untouched,
        if any id is not live."""
        import numpy as np
        self._require_open("depart")
        ids = [int(i) for i in ids]
        if not ids:
            return
        missing = [i for i in ids if i not in self._live]
        if missing or len(set(ids)) != len(ids):
            raise KeyError(
                f"depart: not live (or duplicated in batch): "
                f"{sorted(set(missing or ids))[:8]}")
        b = len(ids)
        pad_ids = np.full((_pad_pow2(b),), -1, np.int32)
        pad_ids[:b] = ids
        if self.cfg.sharding == "lp_device":
            from repro.parallel import lp_shard
            self.state, found = lp_shard.depart_sharded(
                self.state, self.cfg, pad_ids)
            if not np.asarray(found)[:b].all():
                raise RuntimeError(
                    "depart: live-set bookkeeping and device state "
                    "disagree — some ids were not found in any slot")
        else:
            self.state = _jit_oracle_depart(self.state, pad_ids)
        self._live.difference_update(ids)
        self._free.extend(reversed(ids))
        if self.telemetry is not None:
            self.telemetry.emit("depart", self._steps, count=b,
                                population=len(self._live))

    # -- device-state queries -------------------------------------------

    def _universe(self):
        """(pos, lp, ext, valid) on the slot universe — id-order for the
        oracle (ext = arange), slot-major for the sharded layer
        (ext = gid). Queries never unshard."""
        st = self.state
        if self.cfg.sharding == "lp_device":
            ext = st["gid"]
            return st["pos"], st["lp"], ext, ext >= 0
        n = self.cfg.abm.n_se
        ext = jnp.arange(n, dtype=jnp.int32)
        return st["pos"], st["lp"], ext, st["lp"] >= 0

    def query_neighbors(self, ids) -> dict:
        """{id: sorted list of live SE ids within interaction_range} —
        served from device state via the CSR cell list (dense fallback
        when the world is too small to tessellate). Raises KeyError for
        ids that are not live."""
        self._single("query_neighbors")
        ids = [int(i) for i in ids]
        missing = [i for i in ids if i not in self._live]
        if missing:
            raise KeyError(f"query_neighbors: not live: {missing[:8]}")
        if not ids:
            return {}
        abm = self.cfg.abm
        pos, lp, ext, valid = self._universe()
        q = jnp.asarray(ids, jnp.int32)
        if self.cfg.sharding == "lp_device":
            rows = jnp.argmax(ext[None, :] == q[:, None], axis=1)
        else:
            rows = q
        rows = rows.astype(jnp.int32)
        qpos = pos[rows]
        spec = abm.grid_spec() if abm.resolved_backend() in (
            "grid", "pallas_grid") else None
        if spec is not None:
            grid = neighbors.build_grid(pos, spec, valid=valid,
                                        with_table=False)
            cols = neighbors.rows_grid_neighbor_ids(
                pos, abm.area, abm.interaction_range, spec, grid, qpos,
                rows)
        else:
            d2 = neighbors.toroidal_d2(qpos[:, None, :], pos[None, :, :],
                                       abm.area)
            r2 = abm.interaction_range * abm.interaction_range
            j = jnp.arange(pos.shape[0], dtype=jnp.int32)
            ok = valid[None, :] & (d2 <= r2) & (j[None, :] != rows[:, None])
            cols = jnp.where(ok, j[None, :], -1)
        nbr = jnp.where(cols >= 0, ext[jnp.clip(cols, 0, None)], -1)
        import numpy as np
        nbr = np.asarray(nbr)
        return {i: sorted(int(x) for x in row if x >= 0)
                for i, row in zip(ids, nbr)}

    def query_lcr(self) -> float:
        """Instantaneous LCR of the current placement: the fraction of
        interactions that would be LP-local if every live SE sent now —
        the heuristics' objective read off device state, no stepping."""
        self._single("query_lcr")
        abm = self.cfg.abm
        pos, lp, ext, valid = self._universe()
        counts, _ = interaction_counts_overflow(pos, lp, valid, abm,
                                                valid=valid)
        safe_lp = jnp.clip(lp, 0, abm.n_lp - 1)
        flows = jnp.zeros((abm.n_lp, abm.n_lp), jnp.int32).at[
            safe_lp].add(counts)
        total = flows.sum()
        return float(jnp.trace(flows) / jnp.maximum(total, 1))

    def query_region(self, bbox) -> list:
        """Sorted live SE ids with position inside `bbox` = (x0, y0,
        x1, y1), inclusive and wrap-aware per axis (x0 > x1 selects the
        interval wrapping through the torus seam)."""
        self._single("query_region")
        x0, y0, x1, y1 = (float(v) for v in bbox)
        pos, lp, ext, valid = self._universe()

        def axis(v, lo, hi):
            if lo <= hi:
                return (v >= lo) & (v <= hi)
            return (v >= lo) | (v <= hi)

        hit = valid & axis(pos[:, 0], x0, x1) & axis(pos[:, 1], y0, y1)
        return sorted(int(i) for i in ext[hit])


class ReplicaService:
    """Continuous batching of independent simulation requests over the
    replica axis.

    R resident slots share one batched compiled scan; `submit` enqueues
    (seed, steps, mf) requests and `drain` advances all slots together
    in windows sized to the nearest request boundary, refilling each
    finished slot from the queue (the other slots keep their state and
    their own t — per-slot time rides the batch axis). A request's
    merged counters are exactly a solo run's: the batched step is
    bit-identical per replica (PR 5), and window merging preserves the
    counter sums (stats.merge_counters).
    """

    def __init__(self, cfg: EngineConfig, n_slots: int):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.cfg = cfg
        self.n_slots = n_slots
        self._queue = []  # pending (rid, seed, steps, mf)
        self._next_rid = 0
        self.results = {}

    def submit(self, seed: int, steps: int, mf=None) -> int:
        """Enqueue a request; returns its request id (the `results`
        key after `drain`)."""
        if steps < 1:
            raise ValueError("steps must be >= 1")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, int(seed), int(steps), mf))
        return rid

    def prometheus(self) -> str:
        """Prometheus text exposition of the service: queue depth, slot
        count, completed-request count, and the mean LCR / migrations
        over completed requests. The replica scans are un-instrumented
        (the per-step ledger covers single-replica Engines), so this
        reports request-level aggregates only."""
        lines = []

        def gauge(name, value):
            name = f"gaia_service_{name}"
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {value:g}")

        gauge("slots", self.n_slots)
        gauge("queue_depth", len(self._queue))
        gauge("requests_completed", len(self.results))
        done = list(self.results.values())
        if done:
            gauge("mean_lcr", sum(c["mean_lcr"] for c in done) / len(done))
            gauge("mean_migrations",
                  sum(c["migrations"] for c in done) / len(done))
        return "\n".join(lines) + "\n"

    @staticmethod
    def _set_replica(states, r: int, sub):
        """Overwrite replica r of a stacked state with a fresh
        single-replica state (PRNG-key leaves routed through
        key_data/wrap_key_data — typed keys have no .at updates)."""
        def setr(b, s):
            if jnp.issubdtype(b.dtype, jax.dtypes.prng_key):
                bd = jax.random.key_data(b)
                return jax.random.wrap_key_data(
                    bd.at[r].set(jax.random.key_data(s)))
            return b.at[r].set(s)
        return jax.tree.map(setr, states, sub)

    def drain(self) -> dict:
        """Run every queued request to completion; returns {rid:
        counters} (also kept in `self.results`). Idle slots (queue
        exhausted) ride along and are discarded."""
        if not self._queue:
            return self.results
        R = self.n_slots
        slot = [None] * R  # per-slot [rid, remaining, mf, parts, weights]
        states = None

        def refill(states, r):
            rid, seed, steps, mf = self._queue.pop(0)
            sub = _eng._init_engine(jax.random.key(seed), self.cfg)
            if states is None:
                states = _eng.stack_states([sub] * R)
            else:
                states = self._set_replica(states, r, sub)
            slot[r] = [rid, steps, mf, [], []]
            return states

        for r in range(R):
            if self._queue:
                states = refill(states, r)
        while any(s is not None for s in slot):
            active = [s for s in slot if s is not None]
            chunk = min(s[1] for s in active)
            mfs = jnp.asarray(
                [float(s[2] if s is not None and s[2] is not None
                       else self.cfg.heuristic.mf) for s in slot],
                jnp.float32)
            states, counters = _eng._run_window_batch(
                states, self.cfg, chunk, mf=mfs)
            for r in range(R):
                if slot[r] is None:
                    continue
                slot[r][3].append(counters[r])
                slot[r][4].append(chunk)
                slot[r][1] -= chunk
                if slot[r][1] == 0:
                    rid, _, _, parts, weights = slot[r]
                    c = merge_counters(parts, weights)
                    c["migration_ratio"] = c["migrations"] / (
                        self.cfg.abm.n_se * (sum(weights) / 1000.0))
                    self.results[rid] = c
                    slot[r] = None
                    if self._queue:
                        states = refill(states, r)
        return self.results

"""The GAIA adaptive-partitioning engine (paper §4), vectorized in JAX.

One `lax.scan` step = one simulation timestep:

  1. apply migrations whose protocol delay has elapsed (the SE becomes
     active on the destination LP — paper Fig. 4: decision at t,
     notifications at t/t+1, migration message in flight, active at t+2;
     with symmetric load balancing two more negotiation steps precede it)
  2. move agents (RWP), draw senders, deliver proximity interactions
  3. account local vs remote deliveries (LCR numerator/denominator)
  4. update the heuristic window; evaluate candidates
  5. constrain candidates through the load balancer; admitted SEs enter
     the in-flight state

Correctness invariant (tested): the model evolution (positions,
interaction sets) is identical with GAIA ON and OFF — the partitioning
layer only changes WHERE events are delivered, never WHAT happens, which
is the paper's transparency requirement (§4.2).

Execution layers (EngineConfig.sharding): "none" runs every LP inside
one device's scan (this module); "lp_device" maps LPs onto a JAX device
mesh where each device owns its LPs' SE rows and GAIA migrations
physically reshard state (parallel/lp_shard.py) — bit-identical to
"none" on the same seed (tests/test_sharding.py).

A third transparent layer batches replicas: `run_batch(cfg, seeds)`
vmaps the memoized jitted scan over a leading seed axis, with per-seed
bit-identity to sequential runs on both execution layers
(tests/test_replicas.py) — the substrate of every mean/std/ci95/n
number the benchmarks report (core/stats.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import balance as bal
from repro.core import partition as part
from repro.core.abm import (ABMConfig, check_trace_horizon,
                            epidemic_draws, epidemic_exposure_overflow,
                            epidemic_row_update, epidemic_send_prob,
                            init_abm, interaction_counts_overflow,
                            mobility_step)
from repro.core.costmodel import ExecutionEnvironment
from repro.core.heuristics import HeuristicConfig
from repro.core import heuristics as heu
from repro.obs.config import ObsConfig
from repro.obs import ledger as obs_ledger
from repro.obs import runtime as obs_runtime


SHARDINGS = ("none", "lp_device")

#: PRNG salt for the periodic-repartition stream: folded into the
#: per-step k_move, so the default path (repartition_every=0) consumes
#: the main key stream exactly as before (bit-identical seeds)
REPART_SALT = 0x7a47


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    abm: ABMConfig = ABMConfig()
    heuristic: HeuristicConfig = HeuristicConfig()
    gaia_on: bool = True
    balance: str = "symmetric"  # "symmetric" | "asymmetric"
    migration_delay: int = 5  # 2 (LB negotiation) + 3 (protocol, Fig. 4)
    timesteps: int = 1200
    capacity: Optional[tuple] = None  # asymmetric LP capacity shares
    # execution environment (costmodel.ExecutionEnvironment): prices the
    # run's flows offline (wct_env) and, when `capacity` is unset,
    # supplies the asymmetric balancer's capacity profile (per-LP
    # relative speed, paper §4.4)
    env: Optional[ExecutionEnvironment] = None
    # --- sharded execution (parallel/lp_shard.py) -----------------------
    # "none": every LP inside one device's scan (the oracle).
    # "lp_device": LPs mapped onto a device mesh; each device owns its
    # LPs' SE rows, GAIA migrations physically reshard. Bit-identical
    # to "none" on the same seed (tests/test_sharding.py).
    sharding: str = "none"
    n_devices: int = 0  # 0 = all visible devices (capped at n_lp)
    shard_capacity: int = 0  # SE slots per device; 0 = auto (2x share)
    mig_capacity: int = 0  # migration-buffer rows/device/step; 0 = auto
    # halo-exchange buffer rows per (src, dst) device pair per step; the
    # exchange is exact as long as no device needs more than this many
    # rows from any single peer (overflow raises the shard_overflow
    # alarm, like the other capacities). 0 = auto (= shard capacity,
    # safe for arbitrary partitions); tighten once GAIA has clustered
    # the shards to shrink the static all_to_all transport.
    halo_capacity: int = 0
    # --- periodic global repartition (core/partition.py) ----------------
    # every R steps the abm.partitioner backend recomputes the SE -> LP
    # map from current geometry; the delta rides the normal migration
    # machinery (pending_dst/pending_eta, full-row resharding under
    # "lp_device") and is counted in migrations/mig_flows, so the state
    # transfer is priced by wct/wct_env exactly like GAIA migrations.
    # 0 = never (the default path is bit-identical to pre-registry runs).
    repartition_every: int = 0
    # hard memory budget (MiB) for the scale tier: propagated into
    # abm.mem_budget_mb (CSR chunk transients + grid-capacity clamp) and
    # into the sharded layout's halo/migration slot buffers
    # (lp_shard.make_shard_spec). 0 = unbudgeted historical defaults; an
    # explicit abm.mem_budget_mb wins over the engine-level knob.
    mem_budget_mb: int = 0
    # --- open-world churn (core/service.py) -----------------------------
    # open_world=True turns the fixed-N state into a slot universe of
    # abm.n_se rows with a live/dead mask (lp >= 0 marks live; the
    # sharded layer reuses its gid >= 0 mask): SEs arrive into and
    # depart from free slots mid-run (Engine.arrive/.depart), every step
    # phase masks dead rows, and with zero churn + a full population the
    # trajectory stays bit-identical to the closed-world engine.
    # n_active caps the initial live population (0 = all n_se live);
    # abm.n_se - n_active slots start free for arrivals.
    open_world: bool = False
    n_active: int = 0
    # --- runtime telemetry (repro.obs) ----------------------------------
    # obs.enabled=True threads the per-step metrics ledger through the
    # compiled scan (ring buffer + async drain every obs.drain_every
    # steps) and lets the service layer synthesize events. Disabled, the
    # compiled program is byte-identical to a config without the field
    # (window_key_cfg normalizes a disabled ObsConfig away); enabled, it
    # legitimately changes the traced scan and so splits the cache.
    # Either way results are bit-identical (tests/test_obs.py).
    obs: ObsConfig = ObsConfig()

    def __post_init__(self):
        if self.mem_budget_mb > 0 and self.abm.mem_budget_mb == 0:
            object.__setattr__(self, "abm", dataclasses.replace(
                self.abm, mem_budget_mb=self.mem_budget_mb))
        if self.sharding not in SHARDINGS:
            raise ValueError(
                f"sharding={self.sharding!r} not in {SHARDINGS}")
        if self.balance not in ("symmetric", "asymmetric"):
            raise ValueError(
                f"balance={self.balance!r} not in ('symmetric', "
                "'asymmetric')")
        if self.timesteps < 0 or self.migration_delay < 1:
            raise ValueError("timesteps must be >= 0 and migration_delay "
                             ">= 1")
        if min(self.n_devices, self.shard_capacity, self.mig_capacity,
               self.halo_capacity, self.mem_budget_mb) < 0:
            raise ValueError("n_devices and the shard/mig/halo/memory "
                             "capacities must be >= 0 (0 = auto)")
        if self.repartition_every < 0:
            raise ValueError("repartition_every must be >= 0")
        if self.halo_capacity > 0 and self.mem_budget_mb > 0 and \
                self.halo_capacity * 48 > (self.mem_budget_mb << 18):
            # explicit halo_capacity wins over the budget-derived auto
            # size (lp_shard.make_shard_spec), so the two knobs can
            # contradict: reject a capacity whose per-pair send+recv
            # buffers (2 peers x 2 buffers x 12 B/row) already exceed
            # the quarter-budget the halo is allotted
            raise ValueError(
                f"halo_capacity={self.halo_capacity} needs more than "
                f"mem_budget_mb={self.mem_budget_mb} affords the halo "
                "buffers; raise the budget or drop one of the knobs")
        if self.env is not None and self.env.n_lp != self.abm.n_lp:
            raise ValueError(
                f"env {self.env.name!r} has {self.env.n_lp} LPs but "
                f"abm.n_lp={self.abm.n_lp}")
        if self.balance == "asymmetric" and self.effective_capacity() is None:
            raise ValueError("asymmetric balance needs `capacity` or an "
                             "`env` to derive it from")
        if not 0 <= self.n_active <= self.abm.n_se:
            raise ValueError(
                f"n_active={self.n_active} must be in [0, n_se="
                f"{self.abm.n_se}] (0 = all live)")
        if self.n_active > 0 and not self.open_world:
            raise ValueError("n_active needs open_world=True")
        if self.open_world and \
                self.abm.proximity_backend.startswith("pallas"):
            raise ValueError(
                "open_world=True needs proximity_backend 'grid' or "
                "'dense' (the Pallas kernels table every row and have "
                "no dead-slot mask)")

    def effective_capacity(self) -> Optional[tuple]:
        """Asymmetric capacity shares: explicit `capacity` wins, else the
        environment's relative LP speeds (normalized), else None."""
        if self.capacity is not None:
            return tuple(self.capacity)
        if self.env is not None:
            return self.env.capacity_shares()
        return None

    def initial_live(self) -> int:
        """Live SEs at t=0: `n_active` under open_world (0 = full), the
        whole population otherwise."""
        if self.open_world and self.n_active > 0:
            return self.n_active
        return self.abm.n_se


def _init_engine(key, cfg: EngineConfig):
    if cfg.sharding == "lp_device":
        from repro.parallel import lp_shard
        return lp_shard.init_sharded(key, cfg, lp_shard.make_shard_spec(cfg))
    k1, k2 = jax.random.split(key)
    st = init_abm(k1, cfg.abm)
    n, L = cfg.abm.n_se, cfg.abm.n_lp
    st.update(heu.init_state(cfg.heuristic, n, L))
    st.update({
        "key": k2,
        "t": jnp.int32(0),
        "pending_dst": jnp.full((n,), -1, jnp.int32),
        "pending_eta": jnp.full((n,), -1, jnp.int32),
    })
    live = cfg.initial_live()
    if cfg.open_world and live < n:
        # slots [live, n) start free: lp < 0 is THE oracle dead mask
        # (mirroring the sharded layer's gid < 0). The PRNG consumption
        # above is unchanged, so the live prefix is bit-identical to
        # the closed-world rows 0..live-1 of the same seed.
        dead = jnp.arange(n) >= live
        st["lp"] = jnp.where(dead, -1, st["lp"])
    return st


def step_phases(cfg: EngineConfig):
    """Ordered (name, fn) phase decomposition of one oracle timestep.

    Each phase is a pure function over a growing "phase context" dict
    `px` (state under "st", plus the intermediates earlier phases
    added). `step` composes the phases fused — same ops, same order, so
    the compiled scan is the historical program — while the trace
    executor (repro.obs.trace) jits each phase separately to time it
    and emit per-phase timeline spans. Inactive phases (repartition
    with repartition_every=0, heuristic with gaia_on=False) are simply
    absent from the list."""
    n, L = cfg.abm.n_se, cfg.abm.n_lp
    ow = cfg.open_world

    def ph_migrate(px):
        # 1. complete in-flight migrations
        st = px["st"]
        t = st["t"]
        key, k_move, k_send = jax.random.split(st["key"], 3)
        arrive = st["pending_eta"] == t
        lp = jnp.where(arrive, st["pending_dst"], st["lp"])
        pending_dst = jnp.where(arrive, -1, st["pending_dst"])
        pending_eta = jnp.where(arrive, -1, st["pending_eta"])
        valid = (lp >= 0) if ow else None
        return dict(px, t=t, key=key, k_move=k_move, k_send=k_send, lp=lp,
                    pending_dst=pending_dst, pending_eta=pending_eta,
                    valid=valid)

    def ph_mobility(px):
        # 2. model evolution (identical regardless of partitioning)
        st, valid = px["st"], px["valid"]
        pos, wp, mob, mob_g = mobility_step(
            px["k_move"], st["pos"], st["waypoint"], st["mob"],
            st["mob_g"], cfg.abm, valid=valid)
        if ow:  # dead rows hold their slot state (pure selection: no
            # bits of any live row change when every row is live)
            pos = jnp.where(valid[:, None], pos, st["pos"])
            wp = jnp.where(valid[:, None], wp, st["waypoint"])
            mob = jnp.where(valid[:, None], mob, st["mob"])
        if cfg.abm.workload == "epidemic":
            # infectious SEs (last step's flags — this step's infections
            # are decided by ph_workload *from* these senders) interact
            # epi_boost x more often: the draw becomes an explicit
            # uniform against a per-SE probability. Static branch: the
            # non-epidemic path keeps the exact historical bernoulli.
            sender = jax.random.uniform(px["k_send"], (n,)) \
                < epidemic_send_prob(st["epi"], cfg.abm)
        else:
            sender = jax.random.bernoulli(px["k_send"],
                                          cfg.abm.p_interact, (n,))
        if ow:
            sender = valid & sender
        return dict(px, pos=pos, wp=wp, mob=mob, mob_g=mob_g, sender=sender)

    def ph_proximity(px):
        counts, grid_ovf = interaction_counts_overflow(
            px["pos"], px["lp"], px["sender"], cfg.abm,
            valid=px["valid"])  # (N, L), () bool
        return dict(px, counts=counts, grid_ovf=grid_ovf)

    def ph_workload(px):
        # epidemic diffusion over the proximity graph: susceptible SEs
        # count the in-range infectious rows that sent this step (one
        # more candidate walk with a 2-class label array) and run the
        # SI/SIS transition on full-size id-order draws — the same
        # draws x elementwise-apply factoring as row-local mobility,
        # so the sharded mirror is bit-identical by construction
        st, valid = px["st"], px["valid"]
        epi = st["epi"]
        eis = (epi > 0) & px["sender"]
        labels = eis.astype(jnp.int32)
        if ow:  # dead rows drop out of the label sweep entirely
            labels = jnp.where(valid, labels, -1)
        qmask = (epi == 0) & valid if ow else (epi == 0)
        exposure, ovf = epidemic_exposure_overflow(
            px["pos"], labels, qmask, cfg.abm, valid=valid)
        draws = epidemic_draws(px["k_move"], n, cfg.abm)
        epi = epidemic_row_update(epi, exposure, draws, cfg.abm)
        infected = ((epi > 0) & valid if ow else (epi > 0)).sum()
        return dict(px, epi=epi, infected=infected,
                    grid_ovf=px["grid_ovf"] | ovf)

    def ph_account(px):
        # 3. communication accounting: the per-pair flow matrix (src LP
        # -> dst LP; integer scatter-add, so sharded psum reproduces it
        # exactly) is the single source of truth — the scalar LCR terms
        # are its trace and total. Dead rows' counts are all-zero, so
        # clipping their lp = -1 to row 0 adds nothing.
        lp = px["lp"]
        safe_lp = jnp.clip(lp, 0, L - 1) if ow else lp
        flows = jnp.zeros((L, L), jnp.int32).at[safe_lp].add(px["counts"])
        local = jnp.trace(flows)
        total = flows.sum()
        st = px["st"]
        hstate = {k: st[k] for k in ("ring", "ptr", "since_eval",
                                     "last_mig")}
        return dict(px, safe_lp=safe_lp, flows=flows, local=local,
                    total=total, remote=total - local, hstate=hstate,
                    migs=jnp.int32(0), n_evals=jnp.int32(0),
                    mig_flows=jnp.zeros((L, L), jnp.int32),
                    reparts=jnp.int32(0))

    def ph_repartition(px):
        # every R steps the configured backend recomputes the global map
        # from current geometry; the delta enters the ordinary in-flight
        # migration machinery (and the migration counters, so wct/wct_env
        # price the state transfer). SEs already in flight are skipped —
        # their pending move completes first.
        lp, valid, pos, t = px["lp"], px["valid"], px["pos"], px["t"]
        pending_dst, pending_eta = px["pending_dst"], px["pending_eta"]
        pcfg = part.from_engine(cfg)
        k_rep = jax.random.fold_in(px["k_move"], REPART_SALT)
        do = (t > 0) & (t % cfg.repartition_every == 0)
        # hysteresis-aware backends (part.uses_prev) see the current map;
        # the others get prev=None so their dispatch is byte-identical
        # to the historical call (and so the sharded mirror only pays
        # the id-order LP gather when the backend actually reads it)
        prev = lp if part.uses_prev(pcfg) else None
        # open world: dead rows get zero weight AND zero position, so
        # the partitioner sees byte-identical inputs on both execution
        # layers (the sharded mirror reconstructs dead ids as zeros)
        weights = (valid.astype(jnp.float32) if ow
                   else jnp.ones((n,), jnp.float32))
        ppos = jnp.where(valid[:, None], pos, 0.0) if ow else pos
        new_lp = jax.lax.cond(
            do,
            lambda: part.partition(k_rep, ppos, weights, pcfg, prev=prev),
            lambda: lp)
        move = (new_lp != lp) & (pending_dst < 0)
        if ow:  # free slots never enter the migration machinery
            move = move & valid
        pending_dst = jnp.where(move, new_lp, pending_dst)
        pending_eta = jnp.where(move, t + cfg.migration_delay, pending_eta)
        hstate = dict(px["hstate"],
                      last_mig=jnp.where(move, t, px["hstate"]["last_mig"]))
        reparts = move.sum()
        mig_flows = px["mig_flows"].at[px["safe_lp"], new_lp].add(
            move.astype(jnp.int32))
        return dict(px, pending_dst=pending_dst, pending_eta=pending_eta,
                    hstate=hstate, reparts=reparts,
                    migs=px["migs"] + reparts, mig_flows=mig_flows)

    def ph_heuristic(px):
        # 4/5. self-clustering: window update, evaluation, balancing
        lp, valid, t, safe_lp = px["lp"], px["valid"], px["t"], px["safe_lp"]
        pending_dst, pending_eta = px["pending_dst"], px["pending_eta"]
        hstate = heu.update_window(cfg.heuristic, px["hstate"],
                                   px["counts"], px["sender"], t)
        cand, dest, alpha, hstate, n_evals = heu.evaluate(
            cfg.heuristic, hstate, lp, t, valid=valid, mf=px["mf"])
        cand = cand & (pending_dst < 0)  # not already in flight
        cmat = bal.candidate_matrix(cand, safe_lp, dest, L)
        if cfg.balance == "asymmetric":
            cap = jnp.asarray(cfg.effective_capacity(), jnp.float32)
            # lp = -1 buckets into the extra row L, then drops
            current = jnp.bincount(jnp.where(lp < 0, L, lp),
                                   length=L + 1)[:L] if ow else \
                jnp.bincount(lp, length=L)
            grants = bal.asymmetric_grants(cmat, current, cap)
        else:
            grants = bal.symmetric_grants(cmat)
        admit = bal.select_migrations(cand, safe_lp, dest, alpha, grants, L)
        pending_dst = jnp.where(admit, dest, pending_dst)
        pending_eta = jnp.where(admit, t + cfg.migration_delay, pending_eta)
        hstate = dict(hstate, last_mig=jnp.where(admit, t,
                                                 hstate["last_mig"]))
        mig_flows = px["mig_flows"].at[safe_lp, dest].add(
            admit.astype(jnp.int32))
        return dict(px, pending_dst=pending_dst, pending_eta=pending_eta,
                    hstate=hstate, n_evals=n_evals,
                    migs=px["migs"] + admit.sum(), mig_flows=mig_flows)

    def ph_finalize(px):
        new_state = dict(px["st"], key=px["key"], t=px["t"] + 1,
                         pos=px["pos"], waypoint=px["wp"], lp=px["lp"],
                         mob=px["mob"], mob_g=px["mob_g"],
                         pending_dst=px["pending_dst"],
                         pending_eta=px["pending_eta"], **px["hstate"])
        local, total = px["local"], px["total"]
        metrics = {
            "local_msgs": local.astype(jnp.float32),
            "remote_msgs": px["remote"].astype(jnp.float32),
            "migrations": px["migs"].astype(jnp.float32),
            "heu_evals": px["n_evals"].astype(jnp.float32),
            "lcr": local.astype(jnp.float32)
                   / jnp.maximum(total.astype(jnp.float32), 1.0),
            "lp_flows": px["flows"],
            "mig_flows": px["mig_flows"],
            # bulk moves issued by the periodic global repartition (a
            # subset of `migrations`: same machinery and pricing)
            "repartitions": px["reparts"].astype(jnp.float32),
            # exactness alarm: a grid cell over capacity silently
            # undercounts neighbors — the clustered mobility models are
            # what can trip it
            "grid_overflow": px["grid_ovf"].astype(jnp.float32),
        }
        if ow:
            # live population after this step's migration completions —
            # the churn service's occupancy signal (-> mean_pop)
            metrics["pop"] = px["valid"].sum().astype(jnp.float32)
        if cfg.abm.workload == "epidemic":
            new_state["epi"] = px["epi"]
            metrics["infected"] = px["infected"].astype(jnp.float32)
        return dict(px, new_state=new_state, metrics=metrics)

    phases = [("migrate", ph_migrate), ("mobility", ph_mobility),
              ("proximity", ph_proximity), ("accounting", ph_account)]
    if cfg.abm.workload == "epidemic":
        phases.insert(3, ("workload", ph_workload))
    if cfg.repartition_every > 0:
        phases.append(("repartition", ph_repartition))
    if cfg.gaia_on:
        phases.append(("heuristic", ph_heuristic))
    phases.append(("finalize", ph_finalize))
    return phases


def step(state, cfg: EngineConfig, mf=None):
    """One timestep. Returns (state, per-step metrics). `mf` optionally
    overrides cfg.heuristic.mf with a traced value (see run_window).

    Open world (cfg.open_world): rows with lp < 0 are free slots — they
    draw the same per-id randomness (shapes never depend on the
    population, which is what keeps zero-churn runs bit-identical to
    the closed-world path) but are masked out of every effect: they
    never move, never send, never receive (lp = -1 one-hots to no
    column and `valid` keeps them out of the grid), never evaluate, and
    never migrate.

    The body is the fused composition of `step_phases` (the named-scope
    annotations show up in jax.profiler timelines; they add no ops)."""
    px = {"st": state, "mf": mf}
    for name, fn in step_phases(cfg):
        with jax.named_scope(f"step.{name}"):
            px = fn(px)
    return px["new_state"], px["metrics"]


# ---------------------------------------------------------------------------
# open-world churn ops (oracle layer; sharded mirrors in parallel/lp_shard)
# ---------------------------------------------------------------------------


def _clear_slot_history(st, tgt):
    """Reset the per-slot protocol + heuristic history at rows `tgt`
    (index n = dropped padding) to their init_state values, so a reused
    slot carries nothing of its previous occupant."""
    st["pending_dst"] = st["pending_dst"].at[tgt].set(-1, mode="drop")
    st["pending_eta"] = st["pending_eta"].at[tgt].set(-1, mode="drop")
    st["ring"] = st["ring"].at[:, tgt, :].set(0, mode="drop")
    st["ptr"] = st["ptr"].at[tgt].set(0, mode="drop")
    st["since_eval"] = st["since_eval"].at[tgt].set(0, mode="drop")
    st["last_mig"] = st["last_mig"].at[tgt].set(-10**6, mode="drop")
    return st


def oracle_arrive(state, ids, rows):
    """Insert a batch of SEs into free slots `ids` (int32; -1 entries
    are padding and write nothing). `rows` supplies per-arrival "pos"
    (B, 2) and "lp" (B,), optionally "waypoint" / "mob" (default: the
    arrival position / zeros). O(B) scatter into device state — the
    free-slot pool and overflow accounting are host-side
    (core/service.py: Engine.arrive)."""
    n = state["lp"].shape[0]
    tgt = jnp.where(ids >= 0, ids, n)
    pos = jnp.asarray(rows["pos"], jnp.float32)
    st = dict(state)
    st["pos"] = st["pos"].at[tgt].set(pos, mode="drop")
    st["waypoint"] = st["waypoint"].at[tgt].set(
        jnp.asarray(rows.get("waypoint", pos), jnp.float32), mode="drop")
    st["mob"] = st["mob"].at[tgt].set(
        jnp.asarray(rows.get("mob", jnp.zeros_like(pos)), jnp.float32),
        mode="drop")
    st["lp"] = st["lp"].at[tgt].set(
        jnp.asarray(rows["lp"], jnp.int32), mode="drop")
    st["epi"] = st["epi"].at[tgt].set(
        jnp.asarray(rows.get("epi", jnp.zeros(pos.shape[:1], jnp.int32)),
                    jnp.int32), mode="drop")
    return _clear_slot_history(st, tgt)


def oracle_depart(state, ids):
    """Remove the SEs in slots `ids` (int32; -1 = padding): lp = -1
    frees the slot, and the slot history resets so the next occupant
    starts clean. O(B) scatter."""
    n = state["lp"].shape[0]
    tgt = jnp.where(ids >= 0, ids, n)
    st = dict(state)
    st["lp"] = st["lp"].at[tgt].set(-1, mode="drop")
    st["epi"] = st["epi"].at[tgt].set(0, mode="drop")
    return _clear_slot_history(st, tgt)


def series_counters(series) -> dict:
    """Aggregate a per-step metrics series into run counters — the one
    place the counter/series key contract lives (the sharded runner
    layers its extra metrics on top). Matrix-valued series (the per-pair
    flow counters) aggregate to nested lists in int64 so long runs
    cannot wrap int32."""
    counters = {k: float(series[k].sum()) for k in
                ("local_msgs", "remote_msgs", "migrations", "heu_evals")}
    counters["mean_lcr"] = float(series["lcr"].mean())
    if "pop" in series:
        counters["mean_pop"] = float(series["pop"].mean())
    if "infected" in series:
        counters["mean_infected"] = float(series["infected"].mean())
        counters["final_infected"] = float(series["infected"][-1])
    for k in ("grid_overflow", "repartitions"):
        if k in series:
            counters[k] = float(series[k].sum())
    for k in ("lp_flows", "mig_flows"):
        if k in series:
            counters[k] = np.asarray(series[k]).sum(
                axis=0, dtype=np.int64).tolist()
    return counters


def _trace_guard(state, cfg: EngineConfig, n_steps: int) -> None:
    """Window-runner front door of `abm.check_trace_horizon`: reads the
    resident state's step counter (lockstep across replicas and
    replicated across shards, so any element is THE clock) and
    validates the window before anything is traced."""
    if cfg.abm.mobility != "trace" or cfg.abm.trace_policy != "exact":
        return
    t0 = int(np.asarray(jax.device_get(state["t"])).reshape(-1)[0])
    check_trace_horizon(cfg.abm, t0, n_steps)


def window_key_cfg(cfg: EngineConfig) -> EngineConfig:
    """Normalize a config to its compiled-scan cache key: MF is a
    dynamic argument and the scan length comes from n_steps, so neither
    may split the cache. A *disabled* ObsConfig is normalized to the
    default one — whatever drain/threshold knobs it carries are host
    policy that never reaches the traced program, so configs differing
    only there share one executable (this identity is also the
    telemetry-off zero-op proof tests/test_obs.py leans on). An
    *enabled* ObsConfig stays: it legitimately changes the program.
    Shared by the oracle and sharded runners."""
    return dataclasses.replace(
        cfg, timesteps=0,
        heuristic=dataclasses.replace(cfg.heuristic, mf=0.0),
        obs=cfg.obs if cfg.obs.enabled else ObsConfig())


def strip_obs(cfg: EngineConfig) -> EngineConfig:
    """Drop telemetry from a config: the batched replica scans and the
    sharded churn kernels are deliberately un-instrumented (the ledger
    covers the single-replica resident paths — see DESIGN.md
    §Observability), so their compiled-cache keys must not split when a
    resident engine turns telemetry on."""
    if not cfg.obs.enabled:
        return cfg
    return dataclasses.replace(cfg, obs=ObsConfig())


#: bound on each compiled-scan memo (engine window/batch + their sharded
#: mirrors in parallel/lp_shard.py): a benchmark sweep leaks one compiled
#: executable per (cfg shape, n_steps) under the old maxsize=None, which
#: the extended scaling matrix turns from a nuisance into gigabytes —
#: LRU eviction keeps the working set of any one sweep while old shapes
#: age out. Harnesses that iterate many shapes call
#: `clear_compiled_caches()` between cells instead of relying on it.
COMPILED_CACHE_SIZE = 32


def clear_compiled_caches() -> None:
    """Drop every memoized compiled scan (oracle + batched, and the
    sharded mirrors if parallel/lp_shard.py has been imported). The
    benchmark harness calls this between config cells so a sweep's peak
    memory is one cell's executables, not the whole matrix's."""
    import sys
    _compiled_window_cached.cache_clear()
    _compiled_batch_cached.cache_clear()
    lp_shard = sys.modules.get("repro.parallel.lp_shard")
    if lp_shard is not None:
        lp_shard._compiled_window_sharded.cache_clear()
        lp_shard._compiled_batch_sharded.cache_clear()


@functools.lru_cache(maxsize=COMPILED_CACHE_SIZE)
def _compiled_window_cached(cfg: EngineConfig, n_steps: int):
    if not cfg.obs.enabled:
        # telemetry off: this branch is chosen by a static Python `if`,
        # so the traced program is byte-for-byte the historical one —
        # no ring carry, no callback, no extra outputs
        def fn(state, mf):
            def body(s, _):
                return step(s, cfg, mf=mf)
            return jax.lax.scan(body, state, None, length=n_steps)
        return jax.jit(fn)

    # telemetry on: thread a (drain_every, K) f32 ring through the scan
    # carry; each step writes its ledger row into slot t % drain_every,
    # and when the ring wraps one async unordered jax.debug.callback
    # ships the block to the host (repro.obs.runtime routes it to the
    # current session). The step itself is untouched — the ring write
    # reads counters the step already computed, and the PRNG stream
    # never sees the ring, so results stay bit-identical.
    de = cfg.obs.drain_every
    n_cols = len(obs_ledger.ledger_keys(cfg))

    def fn(state, mf):
        def body(carry, _):
            s, ring = carry
            s2, m = step(s, cfg, mf=mf)
            t = s["t"]  # the step that just executed
            ring = ring.at[t % de].set(obs_ledger.ledger_row(cfg, s2, m, t))
            jax.lax.cond(
                (t + 1) % de == 0,
                lambda r, tt: jax.debug.callback(obs_runtime.on_block,
                                                 r, tt, ordered=False),
                lambda r, tt: None,
                ring, t)
            return (s2, ring), m
        # -1 init: slots a short window never writes (and slots left
        # over from a previous window of this resident state) carry an
        # impossible step stamp, which the host-side stamp-match filter
        # drops — see Telemetry._ingest_stamped
        ring0 = jnp.full((de, n_cols), -1.0, jnp.float32)
        (s, ring), series = jax.lax.scan(body, (state, ring0), None,
                                         length=n_steps)
        return s, ring, series
    return jax.jit(fn)


def _compiled_window(cfg: EngineConfig, n_steps: int):
    """One jitted n_steps-scan per config shape, with MF dynamic.

    Eager `lax.scan` re-traces (and recompiles) on every call because
    the body closure is fresh each time; memoizing the jitted scan by
    the hashable config makes repeated runs — and the §5.5 tuner's
    per-window MF re-parameterization — reuse one executable. An MF
    sweep over otherwise-identical configs compiles exactly once (see
    window_key_cfg)."""
    return _compiled_window_cached(window_key_cfg(cfg), n_steps)


def _run_window(state, cfg: EngineConfig, n_steps: int, mf=None):
    """Advance an existing state by n_steps; returns (state, counters).

    Used by the §5.5 intra-run self-tuner, which re-parameterizes the
    heuristic between windows — pass the window's MF via `mf` (a
    dynamic argument: no recompilation between windows). Sharded states
    (from a sharded init_engine) advance through the sharded step and
    stay slot-major."""
    _trace_guard(state, cfg, n_steps)
    if cfg.sharding == "lp_device":
        from repro.parallel import lp_shard
        return lp_shard.run_window_sharded(state, cfg, n_steps, mf=mf)

    mf_val = jnp.float32(cfg.heuristic.mf if mf is None else mf)
    if cfg.obs.enabled:
        t0 = int(state["t"])
        state, ring, series = _compiled_window(cfg, n_steps)(state, mf_val)
        obs_runtime.flush_tail(ring, t0, t0 + n_steps)
    else:
        state, series = _compiled_window(cfg, n_steps)(state, mf_val)
    return state, series_counters(series)


def _run(key, cfg: EngineConfig):
    """Run the full simulation; returns (final_state, stacked metrics,
    aggregate counters). With cfg.sharding="lp_device" the run executes
    LP-per-device on the JAX mesh (bit-identical result; extra
    halo_frac/shard_overflow metrics)."""
    check_trace_horizon(cfg.abm, 0, cfg.timesteps)
    if cfg.sharding == "lp_device":
        from repro.parallel import lp_shard
        return lp_shard.run_sharded(key, cfg)
    st = _init_engine(key, cfg)
    if cfg.obs.enabled:
        st, ring, series = _compiled_window(cfg, cfg.timesteps)(
            st, jnp.float32(cfg.heuristic.mf))
        obs_runtime.flush_tail(ring, 0, cfg.timesteps)
    else:
        st, series = _compiled_window(cfg, cfg.timesteps)(
            st, jnp.float32(cfg.heuristic.mf))
    counters = series_counters(series)
    counters["migration_ratio"] = _migration_ratio(counters, cfg)
    return st, series, counters


# ---------------------------------------------------------------------------
# batched multi-replica execution (vmap over seeds)
# ---------------------------------------------------------------------------


def _migration_ratio(counters, cfg: EngineConfig) -> float:
    return counters["migrations"] / (cfg.abm.n_se *
                                     (cfg.timesteps / 1000.0))  # Eq. 8


def replica_keys(seeds):
    """Seeds (ints) -> one PRNG key per replica. A replica's key is
    exactly `jax.random.key(seed)`, so replica r of a batch reproduces
    a sequential `run(jax.random.key(seeds[r]), cfg)` bit-for-bit."""
    return [jax.random.key(int(s)) for s in seeds]


def stack_states(states):
    """Stack per-replica state pytrees along a new leading replica axis
    (PRNG keys included — key arrays stack like any other leaf)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def _init_batch(cfg: EngineConfig, seeds):
    """Stacked engine state for R replicas: every leaf of the single-
    replica state gains a leading replica axis (including `t`, which
    stays lockstep across replicas — they advance together).

    The per-replica inits run through the very same (eager) init
    a sequential run uses, then stack — deliberately NOT a vmapped
    jitted init: jit fuses the clustered-mobility position arithmetic
    with FMA and drifts ULPs off the eager path, which would break the
    per-seed bit-identity contract (tests/test_replicas.py). Init is a
    one-off O(N) cost; the scan is where batching pays."""
    return stack_states([_init_engine(k, cfg) for k in replica_keys(seeds)])


def _mf_vector(cfg: EngineConfig, mf, n_rep: int):
    """Per-replica Migration Factors: scalar/None broadcasts; an (R,)
    array lets each replica run its own MF (the batched §5.5 tuner)."""
    mf = cfg.heuristic.mf if mf is None else mf
    return jnp.broadcast_to(jnp.asarray(mf, jnp.float32), (n_rep,))


def replica_series(series, r: int):
    """Slice replica r out of a batched (T, R, ...) metrics series,
    yielding the (T, ...) series a sequential run would have produced."""
    return {k: v[:, r] for k, v in series.items()}


@functools.lru_cache(maxsize=COMPILED_CACHE_SIZE)
def _compiled_batch_cached(cfg: EngineConfig, n_steps: int):
    def fn(states, mfs):
        def body(s, _):
            return jax.vmap(lambda st, m: step(st, cfg, mf=m))(s, mfs)
        return jax.lax.scan(body, states, None, length=n_steps)
    return jax.jit(fn)


def _compiled_batch(cfg: EngineConfig, n_steps: int):
    """One jitted batched scan per config shape: `jax.vmap` of the
    single-replica step over the leading replica axis, MF dynamic and
    per-replica. jit re-specializes per replica count, so the cache key
    stays (config shape, n_steps) like `_compiled_window`. Batched
    scans are un-instrumented (strip_obs): the ledger covers the
    single-replica resident paths."""
    return _compiled_batch_cached(window_key_cfg(strip_obs(cfg)), n_steps)


def _run_window_batch(states, cfg: EngineConfig, n_steps: int, mf=None):
    """Advance R stacked replica states by n_steps in one batched scan.

    `mf` may be a scalar (all replicas) or an (R,) vector — the batched
    §5.5 tuner descends each replica's MF independently, so MF rides as
    a per-replica dynamic argument of the one compiled scan. Returns
    (states, [per-replica counters])."""
    _trace_guard(states, cfg, n_steps)
    if cfg.sharding == "lp_device":
        from repro.parallel import lp_shard
        return lp_shard.run_window_batch_sharded(states, cfg, n_steps,
                                                 mf=mf)
    n_rep = states["t"].shape[0]
    states, series = _compiled_batch(cfg, n_steps)(
        states, _mf_vector(cfg, mf, n_rep))
    return states, [series_counters(replica_series(series, r))
                    for r in range(n_rep)]


def _run_batch(cfg: EngineConfig, seeds):
    """Run R independent replicas (one per seed) in a single batched
    device pass: `jax.vmap` over the leading seed axis of the memoized
    jitted scan. Heuristic windows, mobility state, pending migrations —
    the whole engine state — ride the batch axis, so replicas never
    interact; replica r is bit-identical to a sequential
    `jax.random.key(seeds[r])` run (tests/test_replicas.py).

    Returns (states, series, reps): stacked final states (leading
    replica axis), the batched per-step metrics series (T, R, ...), and
    one aggregate-counters dict per replica (the exact schema the
    single-replica runner returns, `migration_ratio` included). With
    cfg.sharding="lp_device" the batch axis is vmapped *inside* each
    shard (parallel/lp_shard.py), so sharded replicas stay bit-identical
    to oracle replicas per seed."""
    check_trace_horizon(cfg.abm, 0, cfg.timesteps)
    if cfg.sharding == "lp_device":
        from repro.parallel import lp_shard
        return lp_shard.run_batch_sharded(cfg, seeds)
    states = _init_batch(cfg, seeds)
    states, series = _compiled_batch(cfg, cfg.timesteps)(
        states, _mf_vector(cfg, None, len(seeds)))
    reps = []
    for r in range(len(seeds)):
        c = series_counters(replica_series(series, r))
        c["migration_ratio"] = _migration_ratio(c, cfg)
        reps.append(c)
    return states, series, reps


# ---------------------------------------------------------------------------
# deprecated free-function API (PR 8): the six runners collapsed into
# the repro.core.Engine facade (core/service.py). The shims delegate so
# old callers keep their exact bits; new code goes through Engine.
# ---------------------------------------------------------------------------


def _deprecated(old: str, hint: str):
    import warnings
    warnings.warn(
        f"repro.core.engine.{old} is deprecated; use {hint} "
        "(see README §Service API)",
        DeprecationWarning, stacklevel=3)


def init_engine(key, cfg: EngineConfig):
    """Deprecated: use `repro.core.Engine(cfg).init(seed=...)`."""
    _deprecated("init_engine", "repro.core.Engine(cfg).init()")
    return _init_engine(key, cfg)


def run_window(state, cfg: EngineConfig, n_steps: int, mf=None):
    """Deprecated: use `repro.core.Engine.step(n, mf=...)`."""
    _deprecated("run_window", "repro.core.Engine.step(n)")
    return _run_window(state, cfg, n_steps, mf=mf)


def run(key, cfg: EngineConfig):
    """Deprecated: use `repro.core.Engine(cfg).init().step(...)`."""
    _deprecated("run", "repro.core.Engine(cfg).run()")
    return _run(key, cfg)


def init_batch(cfg: EngineConfig, seeds):
    """Deprecated: use `repro.core.Engine(cfg).init(seeds=[...])`."""
    _deprecated("init_batch", "repro.core.Engine(cfg).init(seeds=[...])")
    return _init_batch(cfg, seeds)


def run_window_batch(states, cfg: EngineConfig, n_steps: int, mf=None):
    """Deprecated: use `repro.core.Engine.step(n, mf=...)` on a batched
    Engine (`init(seeds=[...])`)."""
    _deprecated("run_window_batch", "repro.core.Engine.step(n)")
    return _run_window_batch(states, cfg, n_steps, mf=mf)


def run_batch(cfg: EngineConfig, seeds):
    """Deprecated: use `repro.core.Engine(cfg).run(seeds=[...])`."""
    _deprecated("run_batch", "repro.core.Engine(cfg).run(seeds=[...])")
    return _run_batch(cfg, seeds)

"""The GAIA adaptive-partitioning engine (paper §4), vectorized in JAX.

One `lax.scan` step = one simulation timestep:

  1. apply migrations whose protocol delay has elapsed (the SE becomes
     active on the destination LP — paper Fig. 4: decision at t,
     notifications at t/t+1, migration message in flight, active at t+2;
     with symmetric load balancing two more negotiation steps precede it)
  2. move agents (RWP), draw senders, deliver proximity interactions
  3. account local vs remote deliveries (LCR numerator/denominator)
  4. update the heuristic window; evaluate candidates
  5. constrain candidates through the load balancer; admitted SEs enter
     the in-flight state

Correctness invariant (tested): the model evolution (positions,
interaction sets) is identical with GAIA ON and OFF — the partitioning
layer only changes WHERE events are delivered, never WHAT happens, which
is the paper's transparency requirement (§4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import balance as bal
from repro.core.abm import ABMConfig, init_abm, interaction_counts, rwp_step
from repro.core.heuristics import HeuristicConfig
from repro.core import heuristics as heu


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    abm: ABMConfig = ABMConfig()
    heuristic: HeuristicConfig = HeuristicConfig()
    gaia_on: bool = True
    balance: str = "symmetric"  # "symmetric" | "asymmetric"
    migration_delay: int = 5  # 2 (LB negotiation) + 3 (protocol, Fig. 4)
    timesteps: int = 1200
    capacity: Optional[tuple] = None  # asymmetric LP capacity shares


def init_engine(key, cfg: EngineConfig):
    k1, k2 = jax.random.split(key)
    st = init_abm(k1, cfg.abm)
    n, L = cfg.abm.n_se, cfg.abm.n_lp
    st.update(heu.init_state(cfg.heuristic, n, L))
    st.update({
        "key": k2,
        "t": jnp.int32(0),
        "pending_dst": jnp.full((n,), -1, jnp.int32),
        "pending_eta": jnp.full((n,), -1, jnp.int32),
    })
    return st


def step(state, cfg: EngineConfig):
    """One timestep. Returns (state, per-step metrics)."""
    n, L = cfg.abm.n_se, cfg.abm.n_lp
    t = state["t"]
    key, k_move, k_send = jax.random.split(state["key"], 3)

    # 1. complete in-flight migrations
    arrive = state["pending_eta"] == t
    lp = jnp.where(arrive, state["pending_dst"], state["lp"])
    pending_dst = jnp.where(arrive, -1, state["pending_dst"])
    pending_eta = jnp.where(arrive, -1, state["pending_eta"])

    # 2. model evolution (identical regardless of partitioning)
    pos, wp = rwp_step(k_move, state["pos"], state["waypoint"], cfg.abm)
    sender = jax.random.bernoulli(k_send, cfg.abm.p_interact, (n,))
    counts = interaction_counts(pos, lp, sender, cfg.abm)  # (N, L)

    # 3. communication accounting
    local = jnp.take_along_axis(counts, lp[:, None], 1)[:, 0].sum()
    total = counts.sum()
    remote = total - local

    # 4/5. self-clustering
    hstate = {k: state[k] for k in ("ring", "ptr", "since_eval", "last_mig")}
    migs = jnp.int32(0)
    n_evals = jnp.int32(0)
    if cfg.gaia_on:
        hstate = heu.update_window(cfg.heuristic, hstate, counts, sender, t)
        cand, dest, alpha, hstate, n_evals = heu.evaluate(
            cfg.heuristic, hstate, lp, t)
        cand = cand & (pending_dst < 0)  # not already in flight
        cmat = bal.candidate_matrix(cand, lp, dest, L)
        if cfg.balance == "asymmetric":
            cap = jnp.asarray(cfg.capacity, jnp.float32)
            current = jnp.bincount(lp, length=L)
            grants = bal.asymmetric_grants(cmat, current, cap)
        else:
            grants = bal.symmetric_grants(cmat)
        admit = bal.select_migrations(cand, lp, dest, alpha, grants, L)
        pending_dst = jnp.where(admit, dest, pending_dst)
        pending_eta = jnp.where(admit, t + cfg.migration_delay, pending_eta)
        hstate = dict(hstate, last_mig=jnp.where(admit, t,
                                                 hstate["last_mig"]))
        migs = admit.sum()

    new_state = dict(state, key=key, t=t + 1, pos=pos, waypoint=wp, lp=lp,
                     pending_dst=pending_dst, pending_eta=pending_eta,
                     **hstate)
    metrics = {
        "local_msgs": local.astype(jnp.float32),
        "remote_msgs": remote.astype(jnp.float32),
        "migrations": migs.astype(jnp.float32),
        "heu_evals": n_evals.astype(jnp.float32),
        "lcr": local.astype(jnp.float32)
               / jnp.maximum(total.astype(jnp.float32), 1.0),
    }
    return new_state, metrics


def run_window(state, cfg: EngineConfig, n_steps: int):
    """Advance an existing state by n_steps; returns (state, counters).

    Used by the §5.5 intra-run self-tuner, which re-parameterizes the
    heuristic between windows."""
    def body(s, _):
        return step(s, cfg)

    state, series = jax.lax.scan(body, state, None, length=n_steps)
    counters = {k: float(series[k].sum()) for k in
                ("local_msgs", "remote_msgs", "migrations", "heu_evals")}
    counters["mean_lcr"] = float(series["lcr"].mean())
    return state, counters


def run(key, cfg: EngineConfig):
    """Run the full simulation; returns (final_state, stacked metrics,
    aggregate counters)."""
    st = init_engine(key, cfg)

    def body(s, _):
        return step(s, cfg)

    st, series = jax.lax.scan(body, st, None, length=cfg.timesteps)
    counters = {k: float(series[k].sum()) for k in
                ("local_msgs", "remote_msgs", "migrations", "heu_evals")}
    counters["mean_lcr"] = float(series["lcr"].mean())
    counters["migration_ratio"] = (counters["migrations"] /
                                   (cfg.abm.n_se *
                                    (cfg.timesteps / 1000.0)))  # Eq. 8
    return st, series, counters

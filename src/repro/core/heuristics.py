"""Self-clustering heuristics #1/#2/#3 (paper §4.3).

All three share the same core (paper §4.3.4): per SE, compare the
external-interaction count toward the most-contacted remote LP (epsilon)
against the internal count (iota); migrate when alpha = eps/iota > MF and
at least MT timesteps passed since the SE's last migration. They differ
only in the accounting window:

  #1 sliding window over the last kappa *timesteps*
  #2 sliding window over the last omega *sending events*
  #3 = #2, but evaluated only after zeta interactions since last eval

Evaluation uses only LP-local data (each LP sees its own SEs' outgoing
counts) — vectorized here over all SEs at once, which is equivalent
because rows never mix across LPs.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HeuristicConfig:
    kind: int = 1  # 1 | 2 | 3
    mf: float = 1.2  # Migration Factor (alpha threshold)
    mt: int = 10  # Migration Threshold (timesteps between migrations)
    kappa: int = 10  # #1: window length in timesteps
    omega: int = 8  # #2/#3: window length in sending events
    zeta: int = 16  # #3: interactions between evaluations

    def __post_init__(self):
        if self.kind not in (1, 2, 3):
            raise ValueError(f"heuristic kind={self.kind} not in (1, 2, 3)")
        if self.mf < 0:
            raise ValueError("mf (Migration Factor) must be >= 0")
        if self.mt < 0:
            raise ValueError("mt (Migration Threshold) must be >= 0")
        if self.kappa < 1 or self.omega < 1 or self.zeta < 1:
            raise ValueError("window parameters kappa/omega/zeta must "
                             "be >= 1")


def init_state(cfg: HeuristicConfig, n_se: int, n_lp: int):
    w = cfg.kappa if cfg.kind == 1 else cfg.omega
    return {
        "ring": jnp.zeros((w, n_se, n_lp), jnp.int32),
        "ptr": jnp.zeros((n_se,), jnp.int32),  # #2/#3 event write pointer
        "since_eval": jnp.zeros((n_se,), jnp.int32),  # #3 counter
        "last_mig": jnp.full((n_se,), -10**6, jnp.int32),
    }


def update_window(cfg: HeuristicConfig, state, counts, sender_mask, t):
    """Push this timestep's per-SE destination histogram into the window."""
    ring = state["ring"]
    if cfg.kind == 1:
        # timestep window: every SE's slot advances each step
        ring = ring.at[t % cfg.kappa].set(
            jnp.where(sender_mask[:, None], counts, 0))
        return dict(state, ring=ring)
    # event window: only senders advance their own pointer
    n = counts.shape[0]
    idx = jnp.arange(n)
    ptr = state["ptr"]
    cur = ring[ptr, idx]  # (N, L)
    new = jnp.where(sender_mask[:, None], counts, cur)
    ring = ring.at[ptr, idx].set(new)
    ptr = jnp.where(sender_mask, (ptr + 1) % cfg.omega, ptr)
    since = state["since_eval"] + jnp.where(sender_mask,
                                            counts.sum(-1), 0)
    return dict(state, ring=ring, ptr=ptr, since_eval=since)


def evaluate(cfg: HeuristicConfig, state, lp, t,
             valid=None, mf=None) -> Tuple[jax.Array, jax.Array,
                                           jax.Array, dict, jax.Array]:
    """Returns (candidate (N,), dest_lp (N,), alpha (N,), new_state,
    n_evals).

    Also counts heuristic evaluations (the Heu term of Eq. 6). `valid`
    masks rows that hold no SE (empty slots in the sharded engine's
    fixed-capacity buffers): they are never evaluated and never counted.
    `mf` optionally overrides cfg.mf with a *traced* value — the §5.5
    intra-run tuner re-parameterizes MF every window, and threading it
    as a dynamic argument lets one compiled scan serve every window
    instead of recompiling per MF value.
    """
    if mf is None:
        mf = cfg.mf
    n, L = state["ring"].shape[1:]
    window = state["ring"].sum(axis=0)  # (N, L)
    safe_lp = jnp.clip(lp, 0, L - 1)  # lp = -1 marks empty slots
    local = jnp.take_along_axis(window, safe_lp[:, None], axis=1)[:, 0]
    ext = window.at[jnp.arange(n), safe_lp].set(0)
    eps = ext.max(axis=-1)
    dest = ext.argmax(axis=-1).astype(jnp.int32)
    alpha = eps.astype(jnp.float32) / jnp.maximum(local, 1).astype(jnp.float32)

    if valid is None:
        valid = jnp.ones((n,), bool)
    eligible = valid & ((t - state["last_mig"]) >= cfg.mt)
    if cfg.kind == 3:
        do_eval = valid & (state["since_eval"] >= cfg.zeta)
        n_evals = do_eval.sum()
        state = dict(state, since_eval=jnp.where(do_eval, 0,
                                                 state["since_eval"]))
    else:
        do_eval = valid
        n_evals = valid.sum().astype(jnp.int32)
    candidate = do_eval & eligible & (alpha > mf) & (eps > 0)
    return candidate, dest, alpha, dict(state), n_evals

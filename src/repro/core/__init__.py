"""GAIA self-clustering core — the paper's contribution.

Paper -> module map (see README.md for the full table):

- abm: the evaluation model, §5.1 (pluggable mobility scenarios: RWP /
  hotspot / group / flock + proximity interactions, with selectable
  proximity backends)
- neighbors: spatial-grid (cell-list) neighbor search — the O(N*k)
  backend behind the §5.1 proximity hot spot
- heuristics: self-clustering heuristics #1/#2/#3, §4.3
- balance: symmetric/asymmetric load balancing, §4.4
- partition: pluggable static/periodic partitioning backends (random /
  stripe / kmeans / bestresponse) — the baselines GAIA is measured
  against, and the engine's periodic global-repartition hook
- engine: the timestepped adaptive-partitioning engine, §4
- costmodel: the paper's TEC/MigC cost analysis, §3 Eqs. 1-6, plus the
  heterogeneous ExecutionEnvironment pricing layer (per-LP speeds +
  pairwise shm/lan/wan link classes)
- selftune: intra-run heuristic re-parameterization, §5.5 (solo and
  batched per-replica tuners)
- stats: replica statistics — the mean/std/ci95/n schema every
  benchmark metric carries (§5: repeated trials behind every number)
- service: the resident engine facade (PR 8) — `Engine` unifies init /
  stepping / open-world churn / device-state queries, `ReplicaService`
  multiplexes requests over the replica batch axis
- gaia_moe: the technique adapted to MoE expert placement (beyond-paper)

The supported public surface is `__all__` (pinned by
tests/test_api_surface.py); the old engine free functions (`run`,
`run_batch`, ...) remain importable as DeprecationWarning shims but are
no longer part of it.
"""
from repro.core.abm import (ABMConfig, MOBILITY_MODELS,  # noqa: F401
                            PROXIMITY_BACKENDS)
from repro.core.costmodel import (DISTRIBUTED, PARALLEL, SETUPS,  # noqa: F401
                                  CostParams, ExecutionEnvironment,
                                  make_env, wct, wct_env, wire_cost)
from repro.core.engine import (EngineConfig, run,  # noqa: F401
                               run_batch)
from repro.core.service import Engine, ReplicaService  # noqa: F401
from repro.core.stats import (merge_counters, percentile,  # noqa: F401
                              replica_stats, summarize)
from repro.core.heuristics import HeuristicConfig  # noqa: F401
from repro.core.neighbors import (GridSpec, build_grid,  # noqa: F401
                                  grid_lp_counts, make_grid_spec)
# NOTE: the bare `partition` function is deliberately not re-exported —
# it would shadow the `repro.core.partition` submodule attribute; use
# `from repro.core.partition import partition`.
from repro.core.partition import (PARTITION_BACKENDS,  # noqa: F401
                                  PartitionConfig)

__all__ = [
    # configs
    "ABMConfig", "EngineConfig", "HeuristicConfig", "PartitionConfig",
    # the resident engine service (the one stepping API)
    "Engine", "ReplicaService",
    # registries
    "MOBILITY_MODELS", "PROXIMITY_BACKENDS", "PARTITION_BACKENDS",
    "SETUPS", "DISTRIBUTED", "PARALLEL",
    # cost model
    "CostParams", "ExecutionEnvironment", "make_env", "wct", "wct_env",
    "wire_cost",
    # neighbor search
    "GridSpec", "build_grid", "grid_lp_counts", "make_grid_spec",
    # statistics
    "merge_counters", "percentile", "replica_stats", "summarize",
]

"""GAIA self-clustering core — the paper's contribution.

- abm: the evaluation model (RWP mobility + proximity interactions)
- heuristics: self-clustering heuristics #1/#2/#3
- balance: symmetric/asymmetric load balancing
- engine: the timestepped adaptive-partitioning engine
- costmodel: the paper's TEC/MigC cost analysis (Eqs. 1-6)
- gaia_moe: the technique adapted to MoE expert placement (beyond-paper)
"""
from repro.core.abm import ABMConfig  # noqa: F401
from repro.core.costmodel import (DISTRIBUTED, PARALLEL, SETUPS,  # noqa: F401
                                  CostParams, wct)
from repro.core.engine import EngineConfig, run  # noqa: F401
from repro.core.heuristics import HeuristicConfig  # noqa: F401

"""Self-tuning adaptive partitioning (paper §5.5).

The paper leaves MF tuning to an offline sweep and sketches two
mechanisms: "inter-run" (exploit the stability across independent
replicas — pick MF from previous runs) and "intra-run" (observe the
simulator for a time interval, tune, repeat). Both are implemented here
on top of the cost model, exploiting exactly the property the paper
calls out: the gain-vs-MF curve is monotone up to a tipping point
(Figs. 8–9), so 1-D hill descent converges.

Intra-run: the run is split into windows of `window` timesteps; after
each window the controller prices the window with Eq. 5/6 (per-timestep
TEC) and hill-climbs MF multiplicatively — if the last move made the
window more expensive, reverse direction and halve the step. MF changes
re-parameterize the heuristic between windows only (within a window the
jitted scan is fixed), which is how a real LP would deploy it: the
controller runs at the LP level on local counters, no centralization.

Inter-run: golden-section-style bracketing on full-run TEC across
replicas (different seeds), reusing the monotone-then-worse structure.

Batched: `intra_run_tune_batch` runs R independent intra-run tuners in
one batched scan (engine.run_window_batch) — each replica prices its
own windows and descends its own MF, so trajectories reproduce solo
runs bit-for-bit while sharing one compiled executable.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.costmodel import CostParams, SETUPS, wct, wct_env
from repro.core.engine import (EngineConfig, _init_batch, _init_engine,
                               _run_window, _run_window_batch)
from repro.obs import runtime as obs_runtime


@dataclasses.dataclass(frozen=True)
class SelfTuneConfig:
    window: int = 100  # timesteps per observation interval
    mf0: float = 4.0  # initial Migration Factor
    step0: float = 0.5  # initial multiplicative step (mf *= 1 +/- step)
    min_mf: float = 1.05
    max_mf: float = 19.0
    setup: str = "distributed"  # cost-model pricing of a window
    interaction_bytes: int = 1024
    migration_bytes: int = 32


def _price(counters, p: CostParams, cfg: EngineConfig, n_steps: int,
           tc: SelfTuneConfig) -> float:
    """Window/probe TEC on the objective the run actually executes on:
    when an ExecutionEnvironment is set, price the per-pair flow
    counters with `wct_env` (per-LP speeds + link classes) instead of
    the homogeneous scalar model — an MF that is optimal on homogeneous
    pricing can be the wrong one on a heterogeneous cluster (tested in
    tests/test_selftune.py)."""
    if cfg.env is not None:
        return wct_env(counters, p, cfg.env, n_steps,
                       interaction_bytes=tc.interaction_bytes,
                       migration_bytes=tc.migration_bytes)["TEC"]
    return wct(counters, p, cfg.abm.n_lp, n_steps,
               interaction_bytes=tc.interaction_bytes,
               migration_bytes=tc.migration_bytes)["TEC"]


def intra_run_tune(key, cfg: EngineConfig, tc: SelfTuneConfig,
                   total_steps: Optional[int] = None):
    """Run `cfg` with MF hill-descended every `window` steps.

    Returns (final_state, history) where history rows are
    (window_index, mf, window_lcr, window_tec_per_step)."""
    total = total_steps or cfg.timesteps
    params = SETUPS[tc.setup]
    state = _init_engine(key, cfg)
    mf = tc.mf0
    step = tc.step0
    direction = -1.0  # start by migrating more aggressively
    prev: Optional[float] = None
    history: List[Tuple[int, float, float, float]] = []

    n_windows = total // tc.window
    for w in range(n_windows):
        # mf rides as a dynamic argument: every window (and every MF the
        # hill descent visits) reuses one compiled window scan
        state, counters = _run_window(state, cfg, tc.window, mf=mf)
        tec = _price(counters, params, cfg, tc.window, tc) / tc.window
        history.append((w, mf, counters["mean_lcr"], tec))
        if prev is not None and tec > prev * 1.001:
            direction = -direction  # worse: back off
            step = max(step * 0.5, 0.02)
        prev = tec
        new_mf = float(min(max(mf * (1.0 + direction * step), tc.min_mf),
                           tc.max_mf))
        if new_mf != mf:
            # telemetry (no-op without a current session): the tuner's
            # decision, stamped with the first step the new MF governs
            obs_runtime.emit_event("tuner_move", (w + 1) * tc.window,
                                   mf=new_mf, prev_mf=mf, window=w,
                                   tec_per_step=tec)
        mf = new_mf
    if cfg.sharding == "lp_device":
        # return the oracle's gid-order layout, like engine.run does
        from repro.parallel import lp_shard
        state = lp_shard.unshard_state(state, lp_shard.make_shard_spec(cfg))
    return state, history


def intra_run_tune_batch(cfg: EngineConfig, tc: SelfTuneConfig, seeds,
                         total_steps: Optional[int] = None):
    """R independent intra-run tuners in one batched pass.

    Each replica observes its own windows, prices them, and
    hill-descends its own MF: the per-replica MF vector rides the
    batched scan as a dynamic argument (engine.run_window_batch), so MF
    trajectories stay fully independent — replica r reproduces a solo
    `intra_run_tune(jax.random.key(seeds[r]), cfg, tc)` bit-for-bit
    (tests/test_selftune.py) at batched cost. Returns (final_states,
    histories) with one solo-format history per replica."""
    total = total_steps or cfg.timesteps
    params = SETUPS[tc.setup]
    n_rep = len(seeds)
    states = _init_batch(cfg, seeds)
    mf = [tc.mf0] * n_rep
    step = [tc.step0] * n_rep
    direction = [-1.0] * n_rep
    prev: List[Optional[float]] = [None] * n_rep
    histories: List[List[Tuple[int, float, float, float]]] = \
        [[] for _ in range(n_rep)]

    for w in range(total // tc.window):
        states, reps = _run_window_batch(
            states, cfg, tc.window, mf=jnp.asarray(mf, jnp.float32))
        for r, counters in enumerate(reps):
            tec = _price(counters, params, cfg, tc.window, tc) / tc.window
            histories[r].append((w, mf[r], counters["mean_lcr"], tec))
            if prev[r] is not None and tec > prev[r] * 1.001:
                direction[r] = -direction[r]  # worse: back off
                step[r] = max(step[r] * 0.5, 0.02)
            prev[r] = tec
            mf[r] = float(min(max(mf[r] * (1.0 + direction[r] * step[r]),
                                  tc.min_mf), tc.max_mf))
    if cfg.sharding == "lp_device":
        from repro.parallel import lp_shard
        states = lp_shard.unshard_batch(states,
                                        lp_shard.make_shard_spec(cfg))
    return states, histories


def inter_run_tune(key, cfg: EngineConfig, tc: SelfTuneConfig,
                   n_probes: int = 6):
    """Pick MF from full independent replicas (paper: use the multiple
    runs you must do anyway for confidence intervals).

    Golden-section-style bracket on [min_mf, max_mf] in log space; each
    probe is one full run priced by the cost model. Returns
    (best_mf, [(mf, tec), ...])."""
    import math
    params = SETUPS[tc.setup]
    lo, hi = math.log(tc.min_mf), math.log(tc.max_mf)
    gr = (math.sqrt(5) - 1) / 2
    trials = []

    def probe(log_mf, i):
        mf = math.exp(log_mf)
        # one full replica per probe, MF dynamic: all probes share one
        # compiled scan (a fresh run() per probe would recompile each)
        state = _init_engine(jax.random.fold_in(key, i), cfg)
        _, counters = _run_window(state, cfg, cfg.timesteps, mf=mf)
        tec = _price(counters, params, cfg, cfg.timesteps, tc)
        trials.append((mf, tec))
        return tec

    a, b = lo, hi
    c, d = b - gr * (b - a), a + gr * (b - a)
    fc, fd = probe(c, 0), probe(d, 1)
    for i in range(2, n_probes):
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - gr * (b - a)
            fc = probe(c, i)
        else:
            a, c, fc = c, d, fd
            d = a + gr * (b - a)
            fd = probe(d, i)
    best = min(trials, key=lambda t: t[1])
    return best[0], trials

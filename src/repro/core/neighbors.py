"""Spatial-grid (cell-list) neighbor search on the toroidal square.

The paper's evaluation model (§5.1) is dominated by proximity interaction
matching, which the dense path in `abm.interaction_counts` resolves as an
O(N^2) pairwise sweep. This module provides the standard cell-list fix:
bin SEs into a `ncell x ncell` grid of square cells whose side is at
least `interaction_range`, so every in-range neighbor of an SE lies in
the 3x3 block of cells around it — O(N*k) candidate tests instead of
O(N^2), with k the mean cell occupancy.

Layout (all shapes static so the whole thing JITs and runs under
`lax.scan` inside the engine):

  * SEs are sorted by cell id (`argsort`), giving contiguous per-cell
    segments; `searchsorted` yields per-cell start offsets and counts —
    a CSR layout of the grid (`order` = column indices, `starts` = row
    pointers). The hot candidate sweep (`rows_grid_counts`) works
    directly off this CSR form: for each of the 9 neighbor offsets it
    gathers one `capacity`-wide segment window per row, chunked under a
    memory budget, so peak candidate memory is O(chunk * capacity)
    regardless of N — never the padded (N, 9 * capacity) matrix
    (`candidate_table`, kept for the Pallas kernels and as a parity
    oracle in tests).
  * A fixed-capacity member table `table[c, k]` (padded with -1) can be
    scattered from the sorted order (`build_grid(..., with_table=True)`;
    the CSR sweep does not need it). `capacity` must bound the true max
    cell occupancy for exact results; `build_grid` returns an `overflow`
    flag so callers outside jit can verify. The auto capacity
    (`default_capacity`) is sized many Poisson standard deviations above
    the uniform-density mean, which covers RWP mobility comfortably.

Exactness: candidate cells are distinct (requires `ncell >= 3`, see
`make_grid_spec`) and the per-pair toroidal distance test is the same
expression the dense oracle uses, so counts are bit-identical to the
dense path — the parity contract tested in tests/test_neighbors.py.
When the world is too small to tessellate (`area / range < 3`)
`make_grid_spec` returns None and callers fall back to the dense sweep.

See DESIGN.md §Adaptations for the grid-vs-dense trade-off discussion.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

#: offsets of the 3x3 neighborhood, row-major
_NEIGH_OFFSETS = [(di, dj) for di in (-1, 0, 1) for dj in (-1, 0, 1)]

#: auto-chunking target: max candidate-matrix entries resident at once
_CHUNK_BUDGET = 1 << 22

#: resident bytes per (row, candidate-slot) entry of one chunked sweep:
#: the ~5 live (chunk, capacity) i32/f32 intermediates (indices, validity,
#: gathered positions, distances, mask) — what `chunk_entries` divides a
#: byte budget by to size the chunk
_BYTES_PER_CAND_ENTRY = 20


def chunk_entries(mem_budget_mb: int) -> int:
    """Candidate-entry budget for the chunked sweeps from a byte budget.

    0 (no budget set) keeps the historical `_CHUNK_BUDGET` default
    (~84 MB of transients); a positive budget divides by the resident
    bytes per entry, floored so a chunk always holds at least one row of
    any sane capacity."""
    if mem_budget_mb <= 0:
        return _CHUNK_BUDGET
    return max(1 << 12, (mem_budget_mb << 20) // _BYTES_PER_CAND_ENTRY)


def budget_capacity(ncell: int, mem_budget_mb: int) -> int:
    """Largest member-table capacity whose (ncell^2, capacity) i32 table
    fits in half the byte budget (the other half is the chunked sweep's
    transients). Callers clamp the density-derived capacity with this;
    a clamp below the true peak occupancy is *loud* (the `grid_overflow`
    flag / metric fires), never a silent undercount."""
    return max(1, (mem_budget_mb << 19) // (4 * ncell * ncell))


def toroidal_d2(a, b, area: float):
    """Squared toroidal distance between (..., 2) position arrays.

    THE canonical per-pair expression: every backend (dense oracle,
    cell-list, Pallas kernels) must evaluate exactly this so the
    bit-identical parity contract is meaningful."""
    d = jnp.abs(a - b)
    d = jnp.minimum(d, area - d)
    return d[..., 0] ** 2 + d[..., 1] ** 2


def dense_lp_counts(pos, lp, sender_mask, n_lp: int, area: float,
                    rng: float):
    """The dense O(N^2) oracle: counts[i, l] = #{j != i :
    toroidal_dist(i, j) <= rng, lp[j] == l}, zeroed for non-senders.
    Single source of truth — abm's dense backend and the kernel ref
    both delegate here."""
    n = pos.shape[0]
    in_range = toroidal_d2(pos[:, None, :], pos[None, :, :],
                           area) <= rng * rng
    in_range = in_range & ~jnp.eye(n, dtype=bool) & sender_mask[:, None]
    onehot = jax.nn.one_hot(lp, n_lp, dtype=jnp.float32)
    return (in_range.astype(jnp.float32) @ onehot).astype(jnp.int32)


def default_capacity(n: int, ncell: int) -> int:
    """Static per-cell capacity bound for n uniform SEs on ncell^2 cells.

    Mean occupancy plus 8 Poisson standard deviations plus slack: the
    probability any of ncell^2 cells exceeds this under uniform placement
    is negligible, and RWP mobility keeps the stationary distribution
    close to uniform (it mildly favors the center on a bounded square,
    but on the torus there is no boundary bias at all)."""
    mean = n / float(ncell * ncell)
    return int(math.ceil(mean + 8.0 * math.sqrt(mean) + 8.0))


def clustered_capacity(n: int, ncell: int, cell: float, n_clusters: int,
                       radius: float) -> int:
    """Static per-cell capacity bound for K-blob clustered placement.

    The uniform bound (`default_capacity`) assumes RWP's near-uniform
    stationary density; the hotspot/group/flock mobility models
    concentrate ~n/K SEs into blobs of the given radius, so the peak
    cell occupancy is the blob population times the fraction of the blob
    one cell covers. Factor 3 absorbs two blobs overlapping one cell
    plus center-peaking (blob density is not uniform either), and the
    uniform-background terms ride on top. Runs surface the
    `grid_overflow` metric, so an underestimate is loud, not silent."""
    per_blob = -(-n // max(n_clusters, 1))
    blob_area = math.pi * max(radius, cell / 2.0) ** 2
    peak = 3.0 * per_blob * min(1.0, cell * cell / blob_area)
    mean = n / float(ncell * ncell)
    return min(n, int(math.ceil(peak + mean + 8.0 * math.sqrt(max(mean, 1.0))
                                + 16.0)))


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static geometry of the cell grid (hashable: safe as a jit static)."""
    ncell: int  # cells per side
    cell: float  # cell side length, >= interaction_range
    capacity: int  # fixed member-table width (max SEs per cell)


def make_grid_spec(n: int, area: float, rng: float,
                   capacity: int = 0) -> Optional[GridSpec]:
    """Largest grid whose cell side still covers `rng`, or None.

    `ncell = floor(area / rng)` maximizes resolution subject to
    `cell >= rng` (the 3x3-coverage requirement). Below ncell=3 the 3x3
    sweep would alias cells through the torus wrap (the same cell would
    be visited more than once, double-counting pairs), so we return None
    and the caller uses the dense sweep — exact either way.
    """
    ncell = int(area // rng)
    if ncell < 3:
        return None
    cap = capacity if capacity > 0 else default_capacity(n, ncell)
    return GridSpec(ncell=ncell, cell=area / ncell, capacity=cap)


def cell_ids(pos, spec: GridSpec):
    """(N,) i32 cell id per position. THE binning expression — every
    consumer (grid build, sharded row queries) must use it so row cells
    and table cells always agree."""
    cxy = jnp.floor(pos / spec.cell).astype(jnp.int32)
    # pos < area, but pos/cell can round up to ncell at the seam
    cxy = jnp.clip(cxy, 0, spec.ncell - 1)
    return cxy[:, 0] * spec.ncell + cxy[:, 1]


def build_grid(pos, spec: GridSpec, valid=None, with_table=True):
    """Bin positions; returns dict with the sorted (CSR) layout and,
    optionally, the scattered member table.

    Keys: cell (N,) i32 cell id per SE; order (N,) the sort permutation;
    starts/counts (ncell^2,) segment offsets; table (ncell^2, capacity)
    member indices padded with -1 (only when `with_table`, which the
    O(N)-memory CSR sweep does not need — `rows_grid_counts` reads
    order/starts/counts directly); overflow () bool — True iff some cell
    holds more than `capacity` SEs (members beyond capacity are dropped
    from the table / the CSR segment window, so exactness requires
    overflow == False).

    `valid` (N,) bool optionally masks rows out of the structure
    entirely: invalid rows bin to the virtual cell ncell^2, so they
    occupy no member-table slot, count toward no cell, and can never
    trip `overflow`. The sharded engine uses this to build its local
    view grid over (own slots + received halo rows) where empty slots
    and halo padding are dead rows — `capacity` then only has to bound
    the density of *live* SEs. Invalid rows' `cell` entries hold the
    virtual id (callers must not index cell-shaped arrays with them).
    """
    n = pos.shape[0]
    ncells = spec.ncell * spec.ncell
    cell = cell_ids(pos, spec)
    if valid is not None:
        cell = jnp.where(valid, cell, ncells)
    order = jnp.argsort(cell)
    cell_sorted = cell[order]
    cids = jnp.arange(ncells, dtype=cell_sorted.dtype)
    starts = jnp.searchsorted(cell_sorted, cids)
    counts = jnp.searchsorted(cell_sorted, cids, side="right") - starts
    out = {
        "cell": cell,
        "order": order,
        "starts": starts,
        "counts": counts,
        "overflow": counts.max() > spec.capacity,
    }
    if with_table:
        # virtual-cell rows sort to the tail; their rank value is
        # irrelevant because the scatter below drops their out-of-bounds
        # cell id
        rank = jnp.arange(n) - starts[jnp.minimum(cell_sorted, ncells - 1)]
        table = jnp.full((ncells, spec.capacity), -1, jnp.int32)
        # ranks beyond capacity fall outside the table and are dropped
        out["table"] = table.at[cell_sorted, rank].set(
            order.astype(jnp.int32), mode="drop")
    return out


def neighbor_cells(cell, spec: GridSpec):
    """(N, 9) cell ids of the toroidal 3x3 neighborhood of each SE's cell."""
    cx, cy = cell // spec.ncell, cell % spec.ncell
    cols = [((cx + di) % spec.ncell) * spec.ncell + (cy + dj) % spec.ncell
            for di, dj in _NEIGH_OFFSETS]
    return jnp.stack(cols, axis=1)


def candidate_table(pos, spec: GridSpec, grid=None):
    """Per-SE candidate list: indices of every SE in the 3x3 neighborhood.

    Returns (cand, grid): cand (N, 9*capacity) i32, padded with -1 (the
    pad also covers the SE itself — self-exclusion is the caller's
    mask `cand != i`). This is the gather the pallas_grid kernel tiles.

    Overflowing `spec.capacity` would silently undercount (dropped
    members never become candidates), so it is reported loudly at
    runtime via jax.debug.print — it costs one comparison per call and
    fires only when the exactness contract is actually broken.
    """
    grid = grid if grid is not None else build_grid(pos, spec)
    jax.lax.cond(
        grid["overflow"],
        lambda mx: jax.debug.print(
            "WARNING repro.core.neighbors: max cell occupancy {mx} exceeds "
            "grid capacity %d — neighbor counts are UNDERCOUNTED; raise "
            "ABMConfig.grid_capacity or use the dense backend" % spec.capacity,
            mx=mx),
        lambda mx: None,
        grid["counts"].max())
    neigh = neighbor_cells(grid["cell"], spec)  # (N, 9)
    cand = grid["table"][neigh]  # (N, 9, capacity)
    return cand.reshape(cand.shape[0], -1), grid


def _counts_for_rows(pos, lp, n_lp: int, area: float, rng: float,
                     row_pos, row_idx, row_sender, row_cand):
    """Exact LP histogram for one chunk of senders given candidate lists.

    The histogram is n_lp masked vector reductions rather than a
    scatter-add: XLA lowers scatters serially on CPU, which would eat
    the entire cell-list win (n_lp is single-digit, the reductions
    vectorize)."""
    valid = (row_cand >= 0) & (row_cand != row_idx[:, None])
    j = jnp.clip(row_cand, 0, pos.shape[0] - 1)
    in_range = toroidal_d2(row_pos[:, None, :], pos[j], area) <= rng * rng
    mask = (in_range & valid & row_sender[:, None]).astype(jnp.int32)
    lpj = lp[j]
    cols = [jnp.sum(mask * (lpj == l), axis=1) for l in range(n_lp)]
    return jnp.stack(cols, axis=1)


def rows_counts_chunked(pos, lp, n_lp: int, area: float, rng: float,
                        row_pos, row_idx, row_sender, row_cand):
    """Exact LP histograms for an arbitrary *row set* of senders against
    the global (pos, lp) reference arrays, given per-row candidate lists.

    `row_idx` holds each row's index into the reference arrays (for
    self-exclusion). Rows are processed in chunks sized so the candidate
    matrix stays within a fixed budget, via `lax.map` — peak memory is
    O(chunk * width) rather than O(R * width). This is the query core
    shared by the single-device grid backend and the per-shard (halo)
    path in parallel/lp_shard.py.
    """
    r = row_pos.shape[0]
    width = row_cand.shape[1]
    chunk = max(1, _CHUNK_BUDGET // max(width, 1))
    if r <= chunk:
        return _counts_for_rows(pos, lp, n_lp, area, rng, row_pos,
                                row_idx, row_sender, row_cand)
    n_chunks = -(-r // chunk)
    pad = n_chunks * chunk - r
    row_pos = jnp.pad(row_pos, ((0, pad), (0, 0)))
    row_idx = jnp.pad(row_idx, (0, pad), constant_values=-1)
    row_sender = jnp.pad(row_sender, (0, pad))  # padded rows: not senders
    row_cand = jnp.pad(row_cand, ((0, pad), (0, 0)), constant_values=-1)

    def one(args):
        rp, ri, rs, rc = args
        return _counts_for_rows(pos, lp, n_lp, area, rng, rp, ri, rs, rc)

    out = jax.lax.map(one, (row_pos.reshape(n_chunks, chunk, 2),
                            row_idx.reshape(n_chunks, chunk),
                            row_sender.reshape(n_chunks, chunk),
                            row_cand.reshape(n_chunks, chunk, width)))
    return out.reshape(n_chunks * chunk, n_lp)[:r]


def rows_grid_counts(pos, lp, n_lp: int, area: float, rng: float,
                     spec: GridSpec, grid, row_pos, row_idx, row_sender,
                     budget_entries: int = 0):
    """Cell-list counts for a row subset against a prebuilt global grid,
    via the CSR segment sweep — O(chunk * capacity) peak memory.

    For each of the 9 static neighbor offsets, every row gathers one
    `capacity`-wide window of the sorted order starting at its neighbor
    cell's segment offset (`order[starts[c] : starts[c] + capacity]`,
    masked by the segment count) and folds the in-range tests into the
    per-LP histogram immediately. Nothing the size of the old padded
    (R, 9 * capacity) candidate matrix is ever materialized: rows are
    processed in `lax.map` chunks sized so one offset's transients stay
    within `budget_entries` candidate entries (default `_CHUNK_BUDGET`;
    see `chunk_entries` for the byte-budget mapping).

    Segment windows are truncated at `capacity` exactly like the member
    table was (first `capacity` members in sorted order), so results are
    bit-identical to the dense oracle whenever `grid["overflow"]` is
    False and identically-undercounted (loud, never silent) when it is
    not. This is the query core of both the single-device grid backend
    and the per-shard halo path in parallel/lp_shard.py."""
    n = pos.shape[0]
    nc, cap = spec.ncell, spec.capacity
    order = grid["order"].astype(jnp.int32)
    starts = grid["starts"]
    # parity with the member table: members past `capacity` are dropped
    seg_cnt = jnp.minimum(grid["counts"], cap)
    row_cell = cell_ids(row_pos, spec)
    karange = jnp.arange(cap)

    def counts_for(rp, ri, rs, rc):
        cx, cy = rc // nc, rc % nc
        acc = jnp.zeros((rp.shape[0], n_lp), jnp.int32)
        for di, dj in _NEIGH_OFFSETS:
            ncid = ((cx + di) % nc) * nc + (cy + dj) % nc
            idx = starts[ncid][:, None] + karange[None, :]
            valid = karange[None, :] < seg_cnt[ncid][:, None]
            j = order[jnp.clip(idx, 0, n - 1)]
            valid = valid & (j != ri[:, None])
            in_range = toroidal_d2(rp[:, None, :], pos[j],
                                   area) <= rng * rng
            mask = (in_range & valid & rs[:, None]).astype(jnp.int32)
            lpj = lp[j]
            # n_lp masked reductions, not a scatter-add: XLA lowers
            # scatters serially on CPU (see _counts_for_rows)
            acc = acc + jnp.stack(
                [jnp.sum(mask * (lpj == l), axis=1) for l in range(n_lp)],
                axis=1)
        return acc

    r = row_pos.shape[0]
    budget = budget_entries if budget_entries > 0 else _CHUNK_BUDGET
    chunk = max(1, budget // max(cap, 1))
    if r <= chunk:
        return counts_for(row_pos, row_idx, row_sender, row_cell)
    n_chunks = -(-r // chunk)
    pad = n_chunks * chunk - r
    rp = jnp.pad(row_pos, ((0, pad), (0, 0)))
    ri = jnp.pad(row_idx, (0, pad), constant_values=-1)
    rs = jnp.pad(row_sender, (0, pad))  # padded rows: not senders
    rc = jnp.pad(row_cell, (0, pad))
    out = jax.lax.map(lambda a: counts_for(*a),
                      (rp.reshape(n_chunks, chunk, 2),
                       ri.reshape(n_chunks, chunk),
                       rs.reshape(n_chunks, chunk),
                       rc.reshape(n_chunks, chunk)))
    return out.reshape(n_chunks * chunk, n_lp)[:r]


def rows_grid_neighbor_ids(pos, area: float, rng: float, spec: GridSpec,
                           grid, q_pos, q_row):
    """Indices (into `pos`) of every agent within `rng` of each query
    point, via the CSR cell list: (Q, 9 * capacity) i32, padded with -1.

    `q_row` is each query's own row index in `pos` (or -1), excluded
    from its result. Rows masked out of `grid` at build time (the
    open-world engine's dead slots) occupy no segment, so they can
    never appear. Segment windows truncate at `capacity` exactly like
    the counting sweep, so results are exact whenever
    `grid["overflow"]` is False. This is the query core of the service
    API's `query_neighbors` (repro.core.service) — Q is a request
    batch, not the population, so no chunking is needed."""
    n = pos.shape[0]
    nc, cap = spec.ncell, spec.capacity
    order = grid["order"].astype(jnp.int32)
    starts = grid["starts"]
    seg_cnt = jnp.minimum(grid["counts"], cap)
    rc = cell_ids(q_pos, spec)
    cx, cy = rc // nc, rc % nc
    karange = jnp.arange(cap)
    cols = []
    for di, dj in _NEIGH_OFFSETS:
        ncid = ((cx + di) % nc) * nc + (cy + dj) % nc
        idx = starts[ncid][:, None] + karange[None, :]
        ok = karange[None, :] < seg_cnt[ncid][:, None]
        j = order[jnp.clip(idx, 0, n - 1)]
        ok = ok & (j != q_row[:, None])
        ok = ok & (toroidal_d2(q_pos[:, None, :], pos[j], area) <= rng * rng)
        cols.append(jnp.where(ok, j, -1))
    return jnp.concatenate(cols, axis=1)


def grid_lp_counts(pos, lp, sender_mask, n_lp: int, area: float, rng: float,
                   spec: GridSpec, budget_entries: int = 0):
    """Cell-list version of the dense LP histogram — bit-identical output.

    counts[i, l] = #{j != i : toroidal_dist(i, j) <= rng, lp[j] == l},
    zeroed for non-senders. Delegates to the CSR segment sweep with every
    agent as a row, visited in sorted cell order (the sort is free — the
    grid build computes it — and gives the sweep's segment gathers
    spatial locality); the scatter back to id order is exact, and the
    counts are integers, so row order never perturbs the result.
    """
    n = pos.shape[0]
    grid = build_grid(pos, spec, with_table=False)
    order = grid["order"]
    out = rows_grid_counts(pos, lp, n_lp, area, rng, spec, grid,
                           pos[order], order.astype(jnp.int32),
                           sender_mask[order], budget_entries)
    return jnp.zeros((n, n_lp), jnp.int32).at[order].set(out)


def halo_mask(cell_ref, row_cell, row_valid, spec: GridSpec):
    """Which reference agents lie in the halo of a row set?

    Returns a boolean mask over `cell_ref` (global per-agent cell ids):
    True for agents inside the 3x3 neighborhood of any cell occupied by
    a valid row. This is the *exact* halo set of the sharded engine —
    the agents a shard actually needs to resolve its own proximity
    queries, which the `halo_frac` metric counts (the sparse exchange
    transports a dilated superset of it; GAIA's clustering shrinks
    both, see parallel/lp_shard.py).
    """
    occ = jnp.zeros((spec.ncell * spec.ncell,), bool)
    safe_cell = jnp.where(row_valid, row_cell, spec.ncell * spec.ncell)
    occ = occ.at[safe_cell].set(True, mode="drop")
    occ2d = occ.reshape(spec.ncell, spec.ncell)
    halo2d = jnp.zeros_like(occ2d)
    for di, dj in _NEIGH_OFFSETS:
        halo2d = halo2d | jnp.roll(occ2d, (di, dj), axis=(0, 1))
    return halo2d.reshape(-1)[cell_ref]


def dilate_mask(occ, r: int):
    """Chebyshev (L-inf) dilation of a boolean cell mask by radius r on
    the torus: out[i, j] is True iff any cell within r rows AND r
    columns (wrapping) is True. r=1 is exactly the 3x3 neighborhood the
    proximity sweep visits; the sharded engine dilates by 1 + the
    per-step cell-displacement bound to turn "cells my SEs occupy now"
    into "cells whose occupants I may query next step" (the halo-need
    bitmap, see parallel/lp_shard.py).

    The L-inf ball is a square, so the dilation is separable: dilate
    rows, then columns. Works on any (..., ncell, ncell) batch; when
    2r+1 >= ncell a roll chain wraps all the way around and any occupied
    input correctly saturates the axis (need-everything)."""
    out = occ
    for axis in (-2, -1):
        acc = out
        for s in range(1, r + 1):
            acc = acc | jnp.roll(out, s, axis) | jnp.roll(out, -s, axis)
        out = acc
    return out


def cell_block_mean(pos, vec, spec: GridSpec, area: float, valid=None):
    """Per-SE mean of positions and of `vec` over the 3x3 cell block.

    The flocking-lite sensing kernel: returns (cdelta, vmean) where
    cdelta (N, 2) is the displacement from each SE to the centroid of
    the *other* SEs in its 3x3 neighborhood (zero when alone) and vmean
    (N, 2) is their mean `vec` (e.g. heading). O(N + ncell^2): one
    scatter-add binning pass plus nine rolled-grid accumulations — no
    member table, so grid capacity is irrelevant here.

    `valid` (open-world engine) drops dead rows from every aggregate
    (they bin to an out-of-bounds cell the scatter discards); their own
    output rows are garbage the caller must mask. With valid=None (or
    all True) results are unchanged.

    Torus correctness: position sums from cells rolled across the seam
    are shifted by ±area on the wrapped axis, so every block is summed
    in its center cell's locally-contiguous frame and `centroid - pos`
    is the true shortest displacement (needs ncell >= 3, which GridSpec
    guarantees). Determinism: the scatter-add consumes the same id-
    ordered arrays in the oracle and in the sharded engine's
    reconstructed state, so both reduce in the same order — the sharded
    bit-identity tests enforce this.
    """
    n, nc = pos.shape[0], spec.ncell
    cell = cell_ids(pos, spec)
    if valid is not None:
        cell = jnp.where(valid, cell, nc * nc)  # out of bounds -> dropped

    def bin2d(vals):
        return jnp.zeros((nc * nc,), jnp.float32).at[cell].add(
            vals, mode="drop").reshape(nc, nc)

    cnt = bin2d(jnp.ones((n,), jnp.float32))
    sx, sy = bin2d(pos[:, 0]), bin2d(pos[:, 1])
    vx, vy = bin2d(vec[:, 0]), bin2d(vec[:, 1])

    acc = [jnp.zeros((nc, nc), jnp.float32) for _ in range(5)]
    for di, dj in _NEIGH_OFFSETS:
        rc = jnp.roll(cnt, (di, dj), (0, 1))
        rsx = jnp.roll(sx, (di, dj), (0, 1))
        rsy = jnp.roll(sy, (di, dj), (0, 1))
        # unwrap the seam: cells rolled across it contribute coordinates
        # shifted by +-area on the rolled axis
        if di == 1:
            rsx = rsx.at[0, :].add(-area * rc[0, :])
        elif di == -1:
            rsx = rsx.at[-1, :].add(area * rc[-1, :])
        if dj == 1:
            rsy = rsy.at[:, 0].add(-area * rc[:, 0])
        elif dj == -1:
            rsy = rsy.at[:, -1].add(area * rc[:, -1])
        parts = (rc, rsx, rsy, jnp.roll(vx, (di, dj), (0, 1)),
                 jnp.roll(vy, (di, dj), (0, 1)))
        acc = [a + p for a, p in zip(acc, parts)]

    flat = [a.reshape(-1)[cell] for a in acc]
    others = jnp.maximum(flat[0] - 1.0, 1.0)  # exclude self; guard alone
    alone = (flat[0] - 1.0) <= 0.0
    csum = jnp.stack([flat[1], flat[2]], axis=1) - pos
    vsum = jnp.stack([flat[3], flat[4]], axis=1) - vec
    cdelta = jnp.where(alone[:, None], 0.0, csum / others[:, None] - pos)
    vmean = jnp.where(alone[:, None], 0.0, vsum / others[:, None])
    return cdelta, vmean


def rows_dense_counts(pos, lp, n_lp: int, area: float, rng: float,
                      row_pos, row_idx, row_sender, chunk: int = 2048):
    """Dense-sweep counts for a row subset against the global reference
    arrays — the sharded engine's fallback when the world is too small to
    tessellate. Reference entries with lp < 0 (empty shard slots) one-hot
    to zero and so never contribute, exactly like the grid path's
    candidate masking."""
    r = row_pos.shape[0]
    s = pos.shape[0]
    n_chunks = -(-r // chunk)
    pad = n_chunks * chunk - r
    row_pos = jnp.pad(row_pos, ((0, pad), (0, 0)))
    row_idx = jnp.pad(row_idx, (0, pad), constant_values=-1)
    row_sender = jnp.pad(row_sender, (0, pad))
    onehot = jax.nn.one_hot(lp, n_lp, dtype=jnp.float32)

    def one(args):
        rp, ri, rs = args
        in_range = toroidal_d2(rp[:, None, :], pos[None, :, :],
                               area) <= rng * rng
        not_self = ri[:, None] != jnp.arange(s)[None, :]
        mask = (in_range & not_self & rs[:, None]).astype(jnp.float32)
        return (mask @ onehot).astype(jnp.int32)

    out = jax.lax.map(one, (row_pos.reshape(n_chunks, chunk, 2),
                            row_idx.reshape(n_chunks, chunk),
                            row_sender.reshape(n_chunks, chunk)))
    return out.reshape(n_chunks * chunk, n_lp)[:r]


def dense_lp_counts_chunked(pos, lp, sender_mask, n_lp: int, area: float,
                            rng: float, chunk: int = 2048):
    """Row-chunked O(N^2) sweep: the dense oracle's math with O(chunk*N)
    peak memory instead of O(N^2), so it scales to N where materializing
    the full pair matrix would not fit. Used as the honest dense baseline
    in benchmarks/exp4_scaling.py (same flop count as the oracle)."""
    n = pos.shape[0]
    n_chunks = -(-n // chunk)
    pad = n_chunks * chunk - n
    row_pos = jnp.pad(pos, ((0, pad), (0, 0)))
    row_idx = jnp.arange(n + pad, dtype=jnp.int32)
    row_sender = jnp.pad(sender_mask, (0, pad))
    onehot = jax.nn.one_hot(lp, n_lp, dtype=jnp.float32)

    def one(args):
        rp, ri, rs = args
        in_range = toroidal_d2(rp[:, None, :], pos[None, :, :],
                               area) <= rng * rng
        not_self = ri[:, None] != jnp.arange(n)[None, :]
        mask = (in_range & not_self & rs[:, None]).astype(jnp.float32)
        return (mask @ onehot).astype(jnp.int32)

    out = jax.lax.map(one, (row_pos.reshape(n_chunks, chunk, 2),
                            row_idx.reshape(n_chunks, chunk),
                            row_sender.reshape(n_chunks, chunk)))
    return out.reshape(n_chunks * chunk, n_lp)[:n]

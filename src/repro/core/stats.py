"""Replica statistics for benchmark reporting.

Every performance claim in the paper (§5, Tables 2-3, Figs. 5-9) is a
statement about the *expected* behaviour of a stochastic simulation, so
every number this repo publishes in a BENCH_*.json must carry its
uncertainty. The shared schema for one reported metric is

    {"mean": m, "std": s, "ci95": h, "n": n}

where `std` is the sample standard deviation (ddof=1) over the n
replicas (or timing repetitions) and `ci95` is the half-width of the
95% confidence interval of the mean, using the Student-t critical value
for n-1 degrees of freedom (n is single-digit in CI, where a normal
z=1.96 would understate the interval by ~2x at n=3). With n=1 the
spread terms are 0 — a point estimate in the same schema, which
`benchmarks/compare.py` treats as a zero-width interval (the legacy
behaviour).

Kept dependency-free (math only): `benchmarks/compare.py` must stay
importable without jax/numpy, and the engine itself never needs these.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence

#: two-sided 95% Student-t critical values, df = 1..30 (df > 30 ~ z)
_T95 = (12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262,
        2.228, 2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101,
        2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052,
        2.048, 2.045, 2.042)


def t95(df: int) -> float:
    """Two-sided 95% Student-t critical value for `df` degrees of
    freedom (df > 30 falls back to the normal 1.96)."""
    if df < 1:
        raise ValueError(f"df must be >= 1, got {df}")
    return _T95[df - 1] if df <= len(_T95) else 1.96


def replica_stats(values: Sequence[float]) -> Dict[str, float]:
    """mean/std/ci95/n over independent replica measurements.

    n=1 degenerates to a point estimate (std = ci95 = 0) so callers can
    emit the same schema regardless of replica count.
    """
    xs = [float(v) for v in values]
    n = len(xs)
    if n == 0:
        raise ValueError("replica_stats needs at least one value")
    mean = sum(xs) / n
    if n < 2:
        return {"mean": mean, "std": 0.0, "ci95": 0.0, "n": n}
    var = sum((x - mean) ** 2 for x in xs) / (n - 1)
    std = math.sqrt(var)
    return {"mean": mean, "std": std,
            "ci95": t95(n - 1) * std / math.sqrt(n), "n": n}


def is_stats(obj) -> bool:
    """Is `obj` a mean/std/ci95/n stats dict (the BENCH metric schema)?

    benchmarks/compare.py re-states this rule in `as_stats` (it must
    run without PYTHONPATH=src) — keep the two in sync."""
    return isinstance(obj, dict) and {"mean", "std", "ci95", "n"} <= set(obj)


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy's default method), math
    only — the service benchmark reports p50/p99 step latency with it
    and `benchmarks/compare.py` must stay importable without numpy."""
    xs = sorted(float(v) for v in values)
    if not xs:
        raise ValueError("percentile needs at least one value")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100], got {q}")
    k = (len(xs) - 1) * (q / 100.0)
    lo = math.floor(k)
    hi = math.ceil(k)
    return xs[lo] + (xs[hi] - xs[lo]) * (k - lo)


class StreamingStats:
    """Welford one-pass mean/variance accumulator in the replica schema.

    The telemetry ledger (`repro.obs`) drains per-step metric rows from
    a resident engine indefinitely; storing every row to call
    `replica_stats` at the end would grow without bound, so summaries
    accumulate incrementally instead: O(1) state per metric, numerically
    stable (Welford's update), and `as_dict()` emits the same
    mean/std/ci95/n schema as `replica_stats` so ledger summaries plug
    straight into BENCH files and `benchmarks/compare.py`."""

    __slots__ = ("n", "mean", "_m2", "min", "max")

    def __init__(self):
        self.n = 0
        self.mean = 0.0
        self._m2 = 0.0
        self.min = math.inf
        self.max = -math.inf

    def add(self, x: float) -> None:
        x = float(x)
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self._m2 += d * (x - self.mean)
        self.min = min(self.min, x)
        self.max = max(self.max, x)

    @property
    def std(self) -> float:
        return math.sqrt(self._m2 / (self.n - 1)) if self.n > 1 else 0.0

    def as_dict(self) -> Dict[str, float]:
        ci = (t95(self.n - 1) * self.std / math.sqrt(self.n)
              if self.n > 1 else 0.0)
        return {"mean": self.mean, "std": self.std, "ci95": ci, "n": self.n}


#: run-counter keys that aggregate as step-weighted means when windows
#: merge (everything else numeric sums; nested lists add elementwise)
_MEAN_KEYS = ("mean_lcr", "mean_halo_frac", "mean_pop")


def merge_counters(parts: Sequence[Dict], weights: Sequence[float]) -> Dict:
    """Merge per-window run-counter dicts into one run's counters.

    The resident engine (`repro.core.service.Engine`) advances in
    windows, each yielding the `engine.series_counters` schema; merging
    w windows must reproduce what one (sum-of-lengths)-step window would
    have reported: counter keys sum, `mean_*` keys combine as
    window-length-weighted means, and matrix counters (nested lists —
    the per-pair flow matrices) add elementwise. Integer-sum counters
    merge exactly; weighted means are float-associative only, so they
    can differ from a single window in the last ulp."""
    if not parts:
        raise ValueError("merge_counters needs at least one window")
    if len(parts) != len(weights):
        raise ValueError("one weight (window length) per counters dict")
    out: Dict = {}
    total_w = float(sum(weights))
    for c, w in zip(parts, weights):
        for k, v in c.items():
            if isinstance(v, list):
                if k not in out:
                    out[k] = [row[:] for row in v]
                else:
                    out[k] = [[a + b for a, b in zip(ra, rb)]
                              for ra, rb in zip(out[k], v)]
            elif k in _MEAN_KEYS:
                out[k] = out.get(k, 0.0) + float(v) * (w / max(total_w, 1.0))
            else:
                out[k] = out.get(k, 0.0) + float(v)
    return out


def summarize(reps: List[Dict], keys: Optional[Iterable[str]] = None,
              ndigits: Optional[int] = None) -> Dict[str, Dict[str, float]]:
    """Per-metric `replica_stats` over a list of per-replica counter
    dicts (engine `run_batch` output). Defaults to every scalar metric
    present in the first replica; matrix counters (nested lists) are
    skipped. `ndigits` optionally rounds for JSON friendliness.

    Boolean counters are *flags*, not measurements — `bool` is an `int`
    subclass in Python, so the naive numeric test would silently average
    alarm flags like `grid_overflow`/`shard_overflow` into a meaningless
    mean/std/ci95 dict. Flags are instead reported as
    `{"any": bool, "count": int, "n": int}` (any replica tripped / how
    many / out of how many) — a shape `is_stats` rejects, so the
    regression gate can never mistake a flag for a statistic."""
    if not reps:
        raise ValueError("summarize needs at least one replica")
    if keys is None:
        keys = [k for k, v in reps[0].items() if isinstance(v, (int, float))]
    out = {}
    for k in keys:
        vals = [r[k] for r in reps]
        if isinstance(reps[0][k], bool):
            out[k] = {"any": any(vals),
                      "count": sum(1 for v in vals if v), "n": len(vals)}
            continue
        st = replica_stats(vals)
        if ndigits is not None:
            st = {kk: (round(v, ndigits) if kk != "n" else v)
                  for kk, v in st.items()}
        out[k] = st
    return out

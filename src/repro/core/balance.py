"""Load-balancing constraints on the self-clustering outcome (paper §4.4).

Symmetric: per-LP inbound migrations must equal outbound (the paper's
"forbid migrations that would cause imbalances" — totals per LP, not per
pair). Implemented as flow decomposition on the candidate matrix:
pairwise swaps g[s,d] = min(cand[s,d], cand[d,s]) first, then ring
rotations at every shift (handles cyclic wish patterns a pairwise-only
matcher deadlocks on), then a final swap pass on the residual. Every
granted unit is part of a swap or a rotation, so each LP's SE count is
exactly invariant.

Asymmetric: each LP has a capacity share (relative PEU speed, possibly
measured at runtime); grants additionally drain over-target LPs toward
under-target ones, so the allocation drifts to the capacity profile.

Candidate selection within a granted (s,d) quota takes the highest-alpha
SEs first.
"""
from __future__ import annotations


import jax.numpy as jnp


def candidate_matrix(candidate, lp, dest, n_lp: int):
    """cand[s, d] = number of SEs on LP s wanting to migrate to LP d."""
    pair = lp * n_lp + dest
    flat = jnp.where(candidate, pair, n_lp * n_lp)
    counts = jnp.bincount(flat, length=n_lp * n_lp + 1)[:-1]
    return counts.reshape(n_lp, n_lp)


def _swap_pass(cand):
    g = jnp.minimum(cand, cand.T)
    return g * (1 - jnp.eye(g.shape[0], dtype=g.dtype))


def symmetric_grants(cand):
    """Count-preserving grants <= cand: swaps + full-ring rotations.

    Each unit of grant lies on a 2-cycle or an L-cycle, so per-LP
    in == out holds exactly (tested property)."""
    L = cand.shape[0]
    cand = cand * (1 - jnp.eye(L, dtype=cand.dtype))
    g = _swap_pass(cand)
    resid = cand - g
    rows = jnp.arange(L)
    for k in range(1, L):  # ring s -> (s+k) % L, flow = min edge
        idx = (rows + k) % L
        f = resid[rows, idx].min()
        g = g.at[rows, idx].add(f)
        resid = resid.at[rows, idx].add(-f)
    extra = _swap_pass(resid)
    return g + extra


def asymmetric_grants(cand, current, capacity):
    """Symmetric core + extra one-way grants draining toward the target
    allocation n_se * capacity (capacity sums to 1)."""
    g = symmetric_grants(cand)
    n_lp = cand.shape[0]
    total = current.sum()
    target = jnp.round(capacity * total).astype(jnp.int32)
    surplus = jnp.maximum(current - target, 0)
    deficit = jnp.maximum(target - current, 0)
    room = jnp.maximum(cand - g, 0)  # remaining unidirectional wishes
    # proportional fill of each destination's deficit from willing sources
    colsum = jnp.maximum(room.sum(axis=0), 1)
    extra = jnp.floor(room * jnp.minimum(deficit, colsum)[None, :]
                      / colsum[None, :]).astype(cand.dtype)
    # a source may not give away more than its surplus
    rowsum = jnp.maximum(extra.sum(axis=1), 1)
    scale = jnp.minimum(surplus, rowsum) / rowsum
    extra = jnp.floor(extra * scale[:, None]).astype(cand.dtype)
    return g + extra * (1 - jnp.eye(n_lp, dtype=cand.dtype))


def select_migrations(candidate, lp, dest, alpha, grants, n_lp: int,
                      tiebreak=None):
    """Admit the top-alpha candidates within each (src,dst) grant quota.

    Returns a boolean (N,) mask of admitted migrations. The order is a
    total lexicographic one — (pair asc, alpha desc, tiebreak asc) — so
    the admitted set is exactly determined. `tiebreak` defaults to the
    array index; the sharded engine passes global SE ids so that each
    shard, selecting only among the candidates of the LPs it owns,
    admits exactly the set the single-device oracle would (every (s, d)
    pair's candidates live wholly on the shard owning LP s, so per-pair
    ranking is shard-local by construction).
    """
    n = candidate.shape[0]
    pair = (lp * n_lp + dest).astype(jnp.int32)
    pair = jnp.where(candidate, pair, n_lp * n_lp)
    if tiebreak is None:
        tiebreak = jnp.arange(n, dtype=jnp.int32)
    # rank candidates within their pair by descending alpha, ties by id
    order = jnp.lexsort((tiebreak, -alpha, pair))
    sp = pair[order]
    counts = jnp.bincount(pair, length=n_lp * n_lp + 1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n, dtype=jnp.int32) - starts[sp].astype(jnp.int32)
    quota = grants.reshape(-1)
    admit_sorted = (sp < n_lp * n_lp) & (rank < quota[jnp.minimum(sp, n_lp * n_lp - 1)])
    admit = jnp.zeros((n,), bool).at[order].set(admit_sorted)
    return admit & candidate

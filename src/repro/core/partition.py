"""Pluggable partitioning backends: the static story GAIA competes with.

The paper's core claim is that *adaptive* self-clustering beats static
partitioning, but its only static baseline is the random round-robin
assignment of §5.1. This module supplies the baselines the claim should
be measured against (benchmarks/exp7_partition.py), behind one API:

    partition(key, pos, weights, cfg) -> lp   # (N,) int32

Every backend is a pure, jittable function of its inputs — determinism
for a fixed key is a tested invariant, and the sharded engine relies on
it to recompute the identical map on every device. Backends:

  "random"        the paper's baseline: a random permutation of the
                  round-robin assignment (equal-sized LPs). Bit-identical
                  to the pre-registry `init_abm` line, so existing seeds
                  reproduce exactly. Ignores pos/weights.
  "stripe"        spatial slabs: SEs ranked along x (ties by y, then
                  index) and cut into contiguous blocks at the capacity
                  shares' cumulative-weight boundaries. The cheapest
                  geometry-aware placement (Boulmier et al.,
                  arXiv:2108.11099, distill the informed-placement idea
                  to its 1-D core).
  "kmeans"        balanced Lloyd iterations: toroidal-distance
                  assignment under per-LP capacity bounds, circular-mean
                  centroid update. The geometric "self-clustering done
                  offline" baseline.
  "bestresponse"  iterative node-level best-response over the sampled
                  proximity-interaction graph (Kurve et al.,
                  arXiv:1111.0875): each round every SE scores each LP
                  by the interaction weight it would keep local, and the
                  capacity-constrained assignment admits moves by
                  descending score — simultaneous best responses with
                  load feasibility enforced by construction rather than
                  by a price term (see DESIGN.md §Partitioning backends).
  "voronoi"       toroidal Voronoi tessellation with fuzzy (c-means)
                  membership, after Alrabeei et al. (arXiv:2103.16278:
                  Voronoi + fuzzy clustering for large-scale fish
                  schooling). Seeds relax by fuzzy c-means (membership
                  u[i, l] ~ (1/d2)^(1/(m-1)), circular-mean seed update
                  weighted by u^m); the hard assignment admits by
                  descending membership under the capacity bounds. The
                  *fuzzy margin is the migration hysteresis*: when the
                  previous map `prev` is passed, each SE's current LP
                  gets a membership bonus (`hysteresis`), so only SEs
                  whose Voronoi membership clearly favours another LP
                  move — boundary SEs with near-tied memberships stop
                  ping-ponging between repartitions.

Capacity discipline: all backends (except the exactly-balanced
"random") bound per-LP load by `capacity_bounds(cfg, total_weight)` —
ceil(share * total * (1 + imbalance)) — which tests/test_partition.py
enforces as a property. `weights` is the per-SE load weight (the engine
passes ones; a calibrated per-SE event cost would slot in here).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import neighbors

PARTITION_BACKENDS = ("random", "stripe", "kmeans", "bestresponse",
                      "voronoi")

#: backends whose map depends on the previous SE -> LP assignment (the
#: hysteresis input `prev`); the sharded repartition hook only pays the
#: id-order LP gather for these
_USES_PREV = frozenset({"voronoi"})


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    """Static parameters of one partitioning problem (hashable, so it
    can close over a jitted engine step)."""
    backend: str = "random"
    n_lp: int = 4
    area: float = 10_000.0  # toroidal square side
    interaction_range: float = 250.0  # bestresponse affinity-graph radius
    iters: int = 8  # Lloyd / best-response / fuzzy c-means rounds
    imbalance: float = 0.0  # allowed load slack over the capacity share
    shares: Optional[Tuple[float, ...]] = None  # per-LP capacity shares
    # --- voronoi (fuzzy c-means) ----------------------------------------
    fuzzy_m: float = 2.0  # fuzzifier (> 1; -> 1 is hard Voronoi)
    # membership bonus on an SE's previous LP when `prev` is passed to
    # partition(): memberships are normalized to sum 1, so 0.1 means an
    # SE only migrates when another LP's membership beats its current
    # LP's by more than 0.1 — boundary churn suppression
    hysteresis: float = 0.1

    def __post_init__(self):
        if self.backend not in PARTITION_BACKENDS:
            raise ValueError(f"partition backend {self.backend!r} not in "
                             f"{PARTITION_BACKENDS}")
        if self.n_lp < 1:
            raise ValueError(f"n_lp={self.n_lp} must be >= 1")
        if self.area <= 0 or self.interaction_range <= 0:
            raise ValueError("area and interaction_range must be > 0")
        if self.iters < 1:
            raise ValueError(f"iters={self.iters} must be >= 1")
        if self.shares is not None and len(self.shares) != self.n_lp:
            raise ValueError(f"shares has {len(self.shares)} entries for "
                             f"n_lp={self.n_lp}")
        if self.imbalance < 0:
            raise ValueError("imbalance must be >= 0")
        if self.fuzzy_m <= 1.0:
            raise ValueError("fuzzy_m must be > 1 (the c-means fuzzifier)")
        if self.hysteresis < 0:
            raise ValueError("hysteresis must be >= 0")

    def share_array(self):
        if self.shares is None:
            return jnp.full((self.n_lp,), 1.0 / self.n_lp, jnp.float32)
        return jnp.asarray(self.shares, jnp.float32)


def from_abm(abm, shares: Optional[Tuple[float, ...]] = None,
             iters: int = 8) -> PartitionConfig:
    """PartitionConfig for an ABMConfig-shaped object (duck-typed to
    avoid a circular import: abm.py dispatches through this module)."""
    return PartitionConfig(backend=abm.partitioner, n_lp=abm.n_lp,
                           area=abm.area,
                           interaction_range=abm.interaction_range,
                           iters=iters, shares=shares)


def from_engine(cfg) -> PartitionConfig:
    """PartitionConfig for an EngineConfig: the engine's effective
    asymmetric capacity shares (explicit `capacity` or the environment's
    relative LP speeds) become the partitioner's load shares, so a
    periodic repartition targets the same allocation the balancer
    drifts toward."""
    return from_abm(cfg.abm, shares=cfg.effective_capacity())


def capacity_bounds(cfg: PartitionConfig, total_weight):
    """Declared per-LP load bound: ceil(share * total * (1 + imbalance)).

    With ceil and imbalance >= 0 the bounds always sum to >= total, so a
    feasible assignment exists; the property tests assert every backend
    stays within this bound."""
    return jnp.ceil(cfg.share_array() * total_weight
                    * (1.0 + cfg.imbalance)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# capacity-constrained assignment (shared by kmeans / bestresponse)
# ---------------------------------------------------------------------------


def capacity_assign(cost, weights, caps):
    """Greedy capacity-constrained assignment: admit (SE, LP) pairs in
    ascending `cost` order; an SE takes the first LP whose remaining
    capacity fits its weight. Deterministic (ties break on the flat
    (i * L + l) index via stable sort). SEs no LP can fit (possible only
    with heterogeneous weights and tight caps) fall back to the LP with
    the most remaining capacity.

    cost (N, L) float, weights (N,) float, caps (L,) float ->
    assignment (N,) int32. O(N * L) scan — partitioning runs at init and
    every `repartition_every` steps, not per timestep.
    """
    n, L = cost.shape
    order = jnp.argsort(cost.reshape(-1), stable=True)

    def body(carry, flat_idx):
        assigned, fill = carry
        i, l = flat_idx // L, flat_idx % L
        ok = (assigned[i] < 0) & (fill[l] + weights[i] <= caps[l])
        assigned = assigned.at[i].set(jnp.where(ok, l, assigned[i]))
        fill = fill.at[l].add(jnp.where(ok, weights[i], 0.0))
        return (assigned, fill), None

    init = (jnp.full((n,), -1, jnp.int32), jnp.zeros((L,), jnp.float32))
    (assigned, fill), _ = jax.lax.scan(body, init, order)
    fallback = jnp.argmax(caps - fill).astype(jnp.int32)
    return jnp.where(assigned < 0, fallback, assigned)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


def _random(key, pos, weights, cfg: PartitionConfig, prev=None):
    # the paper's §5.1 baseline, verbatim from the pre-registry init_abm
    # line: a permuted round-robin (random but equal-sized). The exact
    # expression is a seed-compat contract (tests/test_partition.py).
    n = pos.shape[0]
    return jax.random.permutation(key, jnp.arange(n) % cfg.n_lp)


def _stripe(key, pos, weights, cfg: PartitionConfig, prev=None):
    # 1-D informed placement: rank along x (ties by y, then index) and
    # cut the ranked line into slabs at the shares' cumulative-weight
    # boundaries. Key unused: the map is a pure function of geometry.
    n = pos.shape[0]
    order = jnp.lexsort((jnp.arange(n), pos[:, 1], pos[:, 0]))
    w_sorted = weights[order]
    start_w = jnp.cumsum(w_sorted) - w_sorted  # weight strictly before
    bounds = jnp.cumsum(cfg.share_array()) * weights.sum()
    lp_sorted = jnp.clip(
        jnp.searchsorted(bounds, start_w, side="right"), 0, cfg.n_lp - 1)
    return jnp.zeros((n,), jnp.int32).at[order].set(
        lp_sorted.astype(jnp.int32))


def _toroidal_dist2(pos, cent, area):
    d = jnp.abs(pos[:, None, :] - cent[None, :, :])
    d = jnp.minimum(d, area - d)
    return (d ** 2).sum(-1)  # (N, L)


def _kmeans(key, pos, weights, cfg: PartitionConfig, prev=None):
    # Balanced Lloyd: capacity-constrained toroidal-distance assignment,
    # circular-mean centroid update (the mean of points on a torus is
    # the per-axis circular mean — a Euclidean mean would tear blobs
    # that straddle the wrap seam). Centroids init uniformly from the
    # key, NOT from data rows, so the map is permutation-equivariant
    # (a data-seeded init would depend on SE order).
    L = cfg.n_lp
    caps = capacity_bounds(cfg, weights.sum())
    cent = jax.random.uniform(key, (L, 2), maxval=cfg.area)
    two_pi = 2.0 * jnp.pi

    def lloyd(_, cent):
        assign = capacity_assign(_toroidal_dist2(pos, cent, cfg.area),
                                 weights, caps)
        onehot = (assign[:, None] == jnp.arange(L)[None, :]) \
            * weights[:, None]  # (N, L)
        ang = pos * (two_pi / cfg.area)  # (N, 2)
        s = onehot.T @ jnp.sin(ang)  # (L, 2)
        c = onehot.T @ jnp.cos(ang)
        new = (jnp.arctan2(s, c) % two_pi) * (cfg.area / two_pi)
        # an empty cluster (possible only for tiny N) keeps its centroid
        return jnp.where(onehot.sum(0)[:, None] > 0, new, cent)

    cent = jax.lax.fori_loop(0, cfg.iters, lloyd, cent)
    return capacity_assign(_toroidal_dist2(pos, cent, cfg.area),
                           weights, caps)


def _bestresponse(key, pos, weights, cfg: PartitionConfig, prev=None):
    # Kurve-style iterative node-level best response on the sampled
    # interaction graph: the proximity graph at the current positions IS
    # the expected interaction graph (every in-range SE is a recipient),
    # so affinity[i, l] = weighted in-range neighbors of i on LP l —
    # exactly the quantity each SE would keep local by sitting on l.
    # Each round all SEs respond simultaneously; feasibility (the load
    # term of Kurve's cost) is enforced by the capacity-constrained
    # admission (descending affinity) instead of a tuned price. Seeded
    # from "stripe" so round 0 responds to an informed placement rather
    # than noise. Key unused: deterministic in the geometry.
    caps = capacity_bounds(cfg, weights.sum())
    everyone = jnp.ones((pos.shape[0],), bool)

    def respond(_, lp):
        aff = neighbors.dense_lp_counts_chunked(
            pos, lp, everyone, cfg.n_lp, cfg.area,
            cfg.interaction_range).astype(jnp.float32) * weights[:, None]
        return capacity_assign(-aff, weights, caps)

    return jax.lax.fori_loop(0, cfg.iters, respond, _stripe(key, pos,
                                                            weights, cfg))


def _fuzzy_memberships(pos, seeds, cfg: PartitionConfig):
    """(N, L) fuzzy c-means memberships of each SE in each Voronoi seed:
    u[i, l] ~ (1 / d2(i, l))^(1 / (m - 1)), rows normalized to sum 1.
    The epsilon regularizes an SE sitting exactly on a seed (its row
    then concentrates on that seed, as the limit prescribes)."""
    d2 = _toroidal_dist2(pos, seeds, cfg.area)
    inv = (d2 + 1e-9) ** (-1.0 / (cfg.fuzzy_m - 1.0))
    return inv / inv.sum(axis=1, keepdims=True)


def _voronoi(key, pos, weights, cfg: PartitionConfig, prev=None):
    # Toroidal Voronoi seeds relaxed by fuzzy c-means (Alrabeei et al.):
    # soft memberships instead of Lloyd's hard assignment, circular-mean
    # seed update weighted by u^m * weight. Cold seeds init uniformly
    # from the key (permutation-equivariance, like _kmeans). The final
    # map is the capacity-constrained admission by descending
    # membership; with `prev`, the previous LP's membership gets the
    # hysteresis bonus, so only clear wins migrate (see the module
    # docstring).
    #
    # Seed carry-over: with `prev`, the tessellation warm-starts from
    # the previous map's per-LP circular-mean centroids instead of
    # fresh key draws — consecutive repartitions then relax the *same*
    # tessellation rather than re-deriving an unrelated one, so seeds
    # (and with them the cell boundaries) drift with the model instead
    # of jumping, and repartition churn drops beyond what the
    # membership bonus alone suppresses (tests/test_partition.py::
    # test_voronoi_seed_carry_reduces_churn). An LP with no weight in
    # `prev` falls back to its key-drawn seed. Both execution layers
    # pass byte-identical `prev`, so the warm start preserves the
    # oracle <-> sharded bit-identity contract.
    L = cfg.n_lp
    caps = capacity_bounds(cfg, weights.sum())
    seeds = jax.random.uniform(key, (L, 2), maxval=cfg.area)
    two_pi = 2.0 * jnp.pi
    if prev is not None:
        prev = jnp.asarray(prev)
        hold = (prev >= 0) & (prev < L)  # unassigned rows carry nothing
        onehot = jax.nn.one_hot(jnp.clip(prev, 0, L - 1), L,
                                dtype=jnp.float32) \
            * jnp.where(hold, weights, 0.0)[:, None]  # (N, L)
        ang = pos * (two_pi / cfg.area)
        s = onehot.T @ jnp.sin(ang)  # (L, 2)
        c = onehot.T @ jnp.cos(ang)
        warm = (jnp.arctan2(s, c) % two_pi) * (cfg.area / two_pi)
        seeds = jnp.where(onehot.sum(0)[:, None] > 0, warm, seeds)

    def relax(_, seeds):
        um = (_fuzzy_memberships(pos, seeds, cfg) ** cfg.fuzzy_m) \
            * weights[:, None]  # (N, L)
        ang = pos * (two_pi / cfg.area)
        s = um.T @ jnp.sin(ang)  # (L, 2)
        c = um.T @ jnp.cos(ang)
        new = (jnp.arctan2(s, c) % two_pi) * (cfg.area / two_pi)
        # a weightless seed (tiny N) stays put, like an empty k-means
        # cluster
        return jnp.where(um.sum(0)[:, None] > 1e-12, new, seeds)

    seeds = jax.lax.fori_loop(0, cfg.iters, relax, seeds)
    u = _fuzzy_memberships(pos, seeds, cfg)
    if prev is not None:
        prev = jnp.asarray(prev)
        hold = (prev >= 0) & (prev < L)  # unassigned rows get no bonus
        bonus = jnp.where(hold[:, None],
                          jax.nn.one_hot(jnp.clip(prev, 0, L - 1), L,
                                         dtype=u.dtype) * cfg.hysteresis,
                          0.0)
        u = u + bonus
    return capacity_assign(-u, weights, caps)


_REGISTRY = {
    "random": _random,
    "stripe": _stripe,
    "kmeans": _kmeans,
    "bestresponse": _bestresponse,
    "voronoi": _voronoi,
}


def uses_prev(cfg: PartitionConfig) -> bool:
    """Does this backend read the previous SE -> LP map (`prev`)?
    Callers that must *pay* for id-order LP reconstruction (the sharded
    repartition hook) gate the gather on this, so prev-blind backends
    keep their exact historical wire accounting."""
    return cfg.backend in _USES_PREV


def partition(key, pos, weights, cfg: PartitionConfig, prev=None):
    """Dispatch to the configured backend: (key, pos (N, 2),
    weights (N,), cfg[, prev (N,) int32]) -> lp (N,) int32. Pure and
    deterministic — the sharded engine recomputes the identical map on
    every device. `prev` is the current map for hysteresis-aware
    backends (see `uses_prev`); the others ignore it, so passing it
    never perturbs their output."""
    lp = _REGISTRY[cfg.backend](key, pos,
                                jnp.asarray(weights, jnp.float32), cfg,
                                prev=prev)
    return lp.astype(jnp.int32)

"""The paper's cost analysis (§3, Eqs. 1-6) as an executable model.

    TEC = MCC/f(N) + (SC + LCC + RCC + MMC) + MigC          (Eq. 5)
    MigC = MigCPU + MigComm + Heu                           (Eq. 6)

f(N) is the parallel speedup. The paper's text says "f(N) > N ... there
is a sequential fraction that can not be parallelized", which is
internally inconsistent (a sequential fraction implies speedup < N); we
implement Amdahl's law, f(N) = 1/(s + (1-s)/N) <= N, and note the
discrepancy in DESIGN.md §Deviations.

Two calibrated parameter sets model the paper's testbeds: PARALLEL
(shared-memory multicore, §5.4 Table 2) and DISTRIBUTED (GbE LAN cluster,
Table 3). Calibration targets the OFF-row wall-clock structure of the
paper's tables (latency-dominated remote messages on the LAN; memory-
bandwidth-bound local delivery in shared memory).

Beyond the scalar model, `ExecutionEnvironment` + `wct_env` price a
*heterogeneous* cluster: per-LP speed factors and a pairwise link-class
matrix (shared-memory / LAN / WAN, the §3 distinctions made
load-bearing), fed by the engine's per-LP-pair flow counters
(`lp_flows` / `mig_flows`). The scalar `wct` stays as the calibrated
homogeneous fast path; `wct_env` reduces to it on a homogeneous
environment with balanced flows (tested).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CostParams:
    name: str
    # communication (per interaction message)
    t_local_msg: float  # s per intra-LP delivery
    t_local_byte: float
    t_remote_msg: float  # s per inter-LP delivery (latency term)
    t_remote_byte: float  # s per payload byte (bandwidth term)
    # model computation per delivered event
    t_event_cpu: float
    # synchronization + middleware per LP per timestep
    t_sync: float
    t_mmc: float
    # migration
    t_mig_cpu: float  # serialize/deserialize per migration
    t_mig_msg: float  # transfer latency per migration message
    t_mig_byte: float
    # heuristic evaluation (per SE evaluation) — the Heu term
    t_heu: float
    serial_frac: float  # Amdahl


# Calibrated against the OFF rows of Table 2 (parallel: DELL R620,
# shared memory) and Table 3 (distributed: GbE cluster), 1200 timesteps,
# ~47M deliveries (10k SEs x pi=0.2 x ~19.6 proximity neighbors):
#
#   parallel     94.87 / 98.48 / 130.11 s at 1 / 100 / 1024 B
#   distributed 741.00 / 849.23 / 2698.50 s
#
# Key structural fact (matches the tables, and why per-message LAN
# latency does NOT appear): time-stepped PADS middleware batches all
# messages for a given LP into one network send per timestep, so the
# remote path costs per-message *marshaling* (~us) plus *bandwidth*
# (~45 ns/B effective on the 2003-era GbE cluster; ~1 ns/B through
# shared memory), while the per-timestep barrier carries the latency.
# This is what makes Table 3's inter=1 gains small (~5%) and lets an
# 80 KiB migration payload flip the sign — the reproduction target.
PARALLEL = CostParams(
    name="parallel",
    t_local_msg=3.0e-7, t_local_byte=0.0,  # intra-LP: pointer hand-off
    t_remote_msg=5.0e-7, t_remote_byte=1.0e-9,
    t_event_cpu=1.2e-6,
    t_sync=5.0e-5, t_mmc=1.0e-5,
    t_mig_cpu=3.0e-6, t_mig_msg=3.0e-6, t_mig_byte=1.0e-9,
    t_heu=5.0e-8,
    serial_frac=0.05,
)

DISTRIBUTED = CostParams(
    name="distributed",
    t_local_msg=3.0e-7, t_local_byte=0.0,
    t_remote_msg=3.0e-6, t_remote_byte=4.5e-8,
    t_event_cpu=1.2e-6,
    t_sync=1.0e-3, t_mmc=2.0e-5,  # per-timestep LAN barrier
    t_mig_cpu=5.0e-6, t_mig_msg=3.0e-6, t_mig_byte=4.5e-8,
    t_heu=5.0e-8,
    serial_frac=0.05,
)

SETUPS: Dict[str, CostParams] = {"parallel": PARALLEL,
                                 "distributed": DISTRIBUTED}


def amdahl(n_lp: int, s: float) -> float:
    return 1.0 / (s + (1.0 - s) / n_lp)


def wct(counters: Dict[str, float], p: CostParams, n_lp: int,
        timesteps: int, interaction_bytes: int = 1,
        migration_bytes: int = 32) -> Dict[str, float]:
    """Estimate wall-clock time from engine counters.

    counters: local_msgs, remote_msgs, migrations, heu_evals (floats).
    Returns the component breakdown of Eq. 5/6.
    """
    local = float(counters["local_msgs"])
    remote = float(counters["remote_msgs"])
    migs = float(counters["migrations"])
    evals = float(counters["heu_evals"])

    mcc = (local + remote) * p.t_event_cpu / amdahl(n_lp, p.serial_frac)
    lcc = local * (p.t_local_msg + interaction_bytes * p.t_local_byte)
    rcc = remote * (p.t_remote_msg + interaction_bytes * p.t_remote_byte)
    sc = timesteps * p.t_sync
    mmc = timesteps * p.t_mmc
    mig_cpu = migs * p.t_mig_cpu
    mig_comm = migs * (p.t_mig_msg + migration_bytes * p.t_mig_byte)
    heu = evals * p.t_heu
    total = mcc + lcc + rcc + sc + mmc + mig_cpu + mig_comm + heu
    return {
        "MCC": mcc, "LCC": lcc, "RCC": rcc, "SC": sc, "MMC": mmc,
        "MigCPU": mig_cpu, "MigComm": mig_comm, "Heu": heu,
        "MigC": mig_cpu + mig_comm + heu,
        "TEC": total,
    }


# ---------------------------------------------------------------------------
# Heterogeneous execution environments (per-LP speeds + pairwise links)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkClass:
    """One §3 interconnect class: per-message marshaling cost plus
    per-payload-byte bandwidth cost (the per-message *latency* rides in
    the per-timestep barrier — see the calibration note above)."""
    name: str
    t_msg: float
    t_byte: float


#: "shm"/"lan" reuse the PARALLEL/DISTRIBUTED remote-path calibration;
#: "wan" models an inter-site path: heavier marshaling (TLS/tunneling)
#: and ~1/3 of the GbE effective bandwidth. WAN *latency* belongs in the
#: barrier — see ExecutionEnvironment.t_sync and the two_site preset.
LINK_CLASSES: Dict[str, LinkClass] = {
    "shm": LinkClass("shm", t_msg=5.0e-7, t_byte=1.0e-9),
    "lan": LinkClass("lan", t_msg=3.0e-6, t_byte=4.5e-8),
    "wan": LinkClass("wan", t_msg=6.0e-6, t_byte=1.5e-7),
}

#: per-timestep barrier cost of a WAN-crossing synchronization (RTT-
#: dominated; ~order 10 ms round trips per timestepped barrier)
WAN_SYNC_S = 2.0e-2


@dataclasses.dataclass(frozen=True)
class ExecutionEnvironment:
    """A heterogeneous cluster: per-LP speed factors and a pairwise
    link-class matrix. Frozen + tuple-typed so it is hashable and can
    ride inside EngineConfig (the engine uses `speed` as the default
    asymmetric-balance capacity profile; `wct_env` prices flows with
    the link matrix)."""
    name: str
    speed: Tuple[float, ...]  # relative PEU speed per LP (1.0 = calibrated)
    link: Tuple[Tuple[str, ...], ...]  # link-class name per (src, dst) pair
    t_sync: Optional[float] = None  # per-timestep barrier override

    def __post_init__(self):
        L = len(self.speed)
        if any(s <= 0 for s in self.speed):
            raise ValueError(f"speed factors must be > 0: {self.speed}")
        if len(self.link) != L or any(len(row) != L for row in self.link):
            raise ValueError(f"link matrix must be {L}x{L}")
        for s in range(L):
            for d in range(L):
                if s != d and self.link[s][d] not in LINK_CLASSES:
                    raise ValueError(
                        f"unknown link class {self.link[s][d]!r} at "
                        f"({s}, {d}); known: {sorted(LINK_CLASSES)}")

    @property
    def n_lp(self) -> int:
        return len(self.speed)

    def capacity_shares(self) -> Tuple[float, ...]:
        """speed factors normalized to sum 1 — the asymmetric-balance
        capacity profile this environment implies (paper §4.4: capacity
        = relative PEU speed)."""
        tot = sum(self.speed)
        return tuple(s / tot for s in self.speed)


def homogeneous_env(n_lp: int, link: str = "shm",
                    name: Optional[str] = None) -> ExecutionEnvironment:
    """All LPs equal, one link class everywhere (diag is intra-LP)."""
    row = (link,) * n_lp
    return ExecutionEnvironment(name=name or f"homog-{link}",
                                speed=(1.0,) * n_lp,
                                link=(row,) * n_lp)


def two_site_env(n_lp: int, intra: str = "lan", cross: str = "wan",
                 split: Optional[int] = None,
                 speed: Optional[Tuple[float, ...]] = None,
                 name: Optional[str] = None) -> ExecutionEnvironment:
    """LPs [0, split) on site A, the rest on site B: `intra` links
    within a site, `cross` links between sites, WAN barrier cost when
    the cross link is WAN."""
    split = n_lp // 2 if split is None else split
    site = [0 if l < split else 1 for l in range(n_lp)]
    link = tuple(tuple(intra if site[s] == site[d] else cross
                       for d in range(n_lp)) for s in range(n_lp))
    return ExecutionEnvironment(
        name=name or f"two-site-{intra}-{cross}",
        speed=speed or (1.0,) * n_lp, link=link,
        t_sync=WAN_SYNC_S if cross == "wan" else None)


def hetero_speed_env(n_lp: int, link: str = "lan",
                     name: Optional[str] = None) -> ExecutionEnvironment:
    """One link class, but PEU speeds spanning 4x (fast half, slow
    tail) — the pure compute-heterogeneity case for the asymmetric
    balancer."""
    pattern = (2.0, 1.0, 1.0, 0.5)
    speed = tuple(pattern[l % len(pattern)] for l in range(n_lp))
    row = (link,) * n_lp
    return ExecutionEnvironment(name=name or f"hetero-{link}", speed=speed,
                                link=(row,) * n_lp)


ENV_PRESETS = {
    "shm": homogeneous_env,
    "lan": lambda n_lp: homogeneous_env(n_lp, link="lan", name="lan"),
    "wan2": lambda n_lp: two_site_env(n_lp, name="wan2"),
    "hetero": hetero_speed_env,
}


def make_env(kind: str, n_lp: int) -> ExecutionEnvironment:
    """Build a preset environment ("shm" | "lan" | "wan2" | "hetero")."""
    if kind not in ENV_PRESETS:
        raise ValueError(f"env kind {kind!r} not in {sorted(ENV_PRESETS)}")
    return ENV_PRESETS[kind](n_lp)


def wire_cost(wire_flows, env: ExecutionEnvironment) -> float:
    """Price a sharded run's *physical* transport on `env`: the engine's
    `wire_flows` counter is the (n_dev, n_dev) matrix of useful payload
    bytes each device pair exchanged (sparse halo rows + migrated SE
    rows + reconstruction gathers — see lp_shard's wire accounting).
    Devices host contiguous LP blocks (`lp_shard.dev_of_lp`), so a
    device pair is priced with the link class joining the first LPs of
    the two blocks. Returns seconds of bandwidth cost (the per-timestep
    marshaling/latency already rides in SC/RCC)."""
    w = np.asarray(wire_flows, dtype=np.float64)
    if w.ndim != 2 or w.shape[0] != w.shape[1]:
        raise ValueError(f"wire_flows must be square, got {w.shape}")
    n_dev = w.shape[0]
    L = env.n_lp
    if n_dev > L:
        raise ValueError(f"wire_flows has {n_dev} devices but env has "
                         f"only {L} LPs")
    first_lp = [-(-a * L // n_dev) for a in range(n_dev)]
    return sum(
        w[a, b] * LINK_CLASSES[env.link[first_lp[a]][first_lp[b]]].t_byte
        for a in range(n_dev) for b in range(n_dev)
        if a != b and w[a, b])


def wct_env(counters: Dict, p: CostParams, env: ExecutionEnvironment,
            timesteps: int, interaction_bytes: int = 1,
            migration_bytes: int = 32) -> Dict[str, float]:
    """Heterogeneous Eq. 5/6: price engine counters on `env`.

    Requires the engine's per-pair counters: `lp_flows` (L, L) delivered
    interactions src->dst and (optionally) `mig_flows` (L, L) migrations
    src->dst; the scalar keys are as in `wct`. Differences from the
    scalar model:

      * LCC/RCC price each (s, d) flow with that pair's link class;
      * MCC is the per-LP bottleneck: each LP's delivered events cost
        t_event_cpu / speed[l], the serial fraction of the total work is
        unparallelizable, the rest finishes when the slowest LP does
        (reduces to Amdahl on balanced, equal-speed LPs);
      * MigComm prices each migration on its pair's link (falling back
        to the most expensive link present if only the scalar
        `migrations` counter is available);
      * SC uses env.t_sync when set (WAN barriers are RTT-dominated);
      * when the sharded engine's `wire_flows` counter is present, its
        measured per-device-pair bytes are priced by `wire_cost` and
        reported as `WireC`. WireC is the physical-transport view of
        the same traffic RCC/MigComm estimate from logical message
        counts, so it is reported alongside TEC rather than added to
        it (summing both would double-count the interaction payload).
    """
    L = env.n_lp
    flows = np.asarray(counters["lp_flows"], dtype=np.float64)
    if flows.shape != (L, L):
        raise ValueError(f"lp_flows shape {flows.shape} != ({L}, {L})")
    links = [[None if s == d else LINK_CLASSES[env.link[s][d]]
              for d in range(L)] for s in range(L)]

    lcc = float(np.trace(flows)) * (p.t_local_msg
                                    + interaction_bytes * p.t_local_byte)
    rcc = sum(flows[s, d] * (links[s][d].t_msg
                             + interaction_bytes * links[s][d].t_byte)
              for s in range(L) for d in range(L) if s != d)

    per_lp = flows.sum(axis=0) * p.t_event_cpu / np.asarray(env.speed)
    work = float(per_lp.sum())
    mcc = p.serial_frac * work + (1.0 - p.serial_frac) * float(per_lp.max())

    sc = timesteps * (p.t_sync if env.t_sync is None else env.t_sync)
    mmc = timesteps * p.t_mmc

    if "mig_flows" in counters:
        mf = np.asarray(counters["mig_flows"], dtype=np.float64)
        migs = float(mf.sum())
        mig_comm = sum(
            mf[s, d] * (links[s][d].t_msg + migration_bytes
                        * links[s][d].t_byte)
            for s in range(L) for d in range(L) if s != d)
    else:
        migs = float(counters["migrations"])
        remote_links = [links[s][d] for s in range(L) for d in range(L)
                        if s != d]
        if migs and remote_links:
            worst = max(remote_links, key=lambda c: c.t_msg)
            mig_comm = migs * (worst.t_msg + migration_bytes * worst.t_byte)
        else:  # no migrations, or a 1-LP env with nowhere to migrate
            mig_comm = 0.0
    mig_cpu = migs * p.t_mig_cpu
    heu = float(counters["heu_evals"]) * p.t_heu

    wirec = (wire_cost(counters["wire_flows"], env)
             if "wire_flows" in counters else 0.0)

    total = mcc + lcc + rcc + sc + mmc + mig_cpu + mig_comm + heu
    return {
        "MCC": mcc, "LCC": lcc, "RCC": float(rcc), "SC": sc, "MMC": mmc,
        "MigCPU": mig_cpu, "MigComm": float(mig_comm), "Heu": heu,
        "MigC": mig_cpu + float(mig_comm) + heu,
        "TEC": total,
        "WireC": float(wirec),
        "per_lp_compute_s": per_lp.tolist(),
    }

"""The paper's cost analysis (§3, Eqs. 1-6) as an executable model.

    TEC = MCC/f(N) + (SC + LCC + RCC + MMC) + MigC          (Eq. 5)
    MigC = MigCPU + MigComm + Heu                           (Eq. 6)

f(N) is the parallel speedup. The paper's text says "f(N) > N ... there
is a sequential fraction that can not be parallelized", which is
internally inconsistent (a sequential fraction implies speedup < N); we
implement Amdahl's law, f(N) = 1/(s + (1-s)/N) <= N, and note the
discrepancy in DESIGN.md §Deviations.

Two calibrated parameter sets model the paper's testbeds: PARALLEL
(shared-memory multicore, §5.4 Table 2) and DISTRIBUTED (GbE LAN cluster,
Table 3). Calibration targets the OFF-row wall-clock structure of the
paper's tables (latency-dominated remote messages on the LAN; memory-
bandwidth-bound local delivery in shared memory).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CostParams:
    name: str
    # communication (per interaction message)
    t_local_msg: float  # s per intra-LP delivery
    t_local_byte: float
    t_remote_msg: float  # s per inter-LP delivery (latency term)
    t_remote_byte: float  # s per payload byte (bandwidth term)
    # model computation per delivered event
    t_event_cpu: float
    # synchronization + middleware per LP per timestep
    t_sync: float
    t_mmc: float
    # migration
    t_mig_cpu: float  # serialize/deserialize per migration
    t_mig_msg: float  # transfer latency per migration message
    t_mig_byte: float
    # heuristic evaluation (per SE evaluation) — the Heu term
    t_heu: float
    serial_frac: float  # Amdahl


# Calibrated against the OFF rows of Table 2 (parallel: DELL R620,
# shared memory) and Table 3 (distributed: GbE cluster), 1200 timesteps,
# ~47M deliveries (10k SEs x pi=0.2 x ~19.6 proximity neighbors):
#
#   parallel     94.87 / 98.48 / 130.11 s at 1 / 100 / 1024 B
#   distributed 741.00 / 849.23 / 2698.50 s
#
# Key structural fact (matches the tables, and why per-message LAN
# latency does NOT appear): time-stepped PADS middleware batches all
# messages for a given LP into one network send per timestep, so the
# remote path costs per-message *marshaling* (~us) plus *bandwidth*
# (~45 ns/B effective on the 2003-era GbE cluster; ~1 ns/B through
# shared memory), while the per-timestep barrier carries the latency.
# This is what makes Table 3's inter=1 gains small (~5%) and lets an
# 80 KiB migration payload flip the sign — the reproduction target.
PARALLEL = CostParams(
    name="parallel",
    t_local_msg=3.0e-7, t_local_byte=0.0,  # intra-LP: pointer hand-off
    t_remote_msg=5.0e-7, t_remote_byte=1.0e-9,
    t_event_cpu=1.2e-6,
    t_sync=5.0e-5, t_mmc=1.0e-5,
    t_mig_cpu=3.0e-6, t_mig_msg=3.0e-6, t_mig_byte=1.0e-9,
    t_heu=5.0e-8,
    serial_frac=0.05,
)

DISTRIBUTED = CostParams(
    name="distributed",
    t_local_msg=3.0e-7, t_local_byte=0.0,
    t_remote_msg=3.0e-6, t_remote_byte=4.5e-8,
    t_event_cpu=1.2e-6,
    t_sync=1.0e-3, t_mmc=2.0e-5,  # per-timestep LAN barrier
    t_mig_cpu=5.0e-6, t_mig_msg=3.0e-6, t_mig_byte=4.5e-8,
    t_heu=5.0e-8,
    serial_frac=0.05,
)

SETUPS: Dict[str, CostParams] = {"parallel": PARALLEL,
                                 "distributed": DISTRIBUTED}


def amdahl(n_lp: int, s: float) -> float:
    return 1.0 / (s + (1.0 - s) / n_lp)


def wct(counters: Dict[str, float], p: CostParams, n_lp: int,
        timesteps: int, interaction_bytes: int = 1,
        migration_bytes: int = 32) -> Dict[str, float]:
    """Estimate wall-clock time from engine counters.

    counters: local_msgs, remote_msgs, migrations, heu_evals (floats).
    Returns the component breakdown of Eq. 5/6.
    """
    local = float(counters["local_msgs"])
    remote = float(counters["remote_msgs"])
    migs = float(counters["migrations"])
    evals = float(counters["heu_evals"])

    mcc = (local + remote) * p.t_event_cpu / amdahl(n_lp, p.serial_frac)
    lcc = local * (p.t_local_msg + interaction_bytes * p.t_local_byte)
    rcc = remote * (p.t_remote_msg + interaction_bytes * p.t_remote_byte)
    sc = timesteps * p.t_sync
    mmc = timesteps * p.t_mmc
    mig_cpu = migs * p.t_mig_cpu
    mig_comm = migs * (p.t_mig_msg + migration_bytes * p.t_mig_byte)
    heu = evals * p.t_heu
    total = mcc + lcc + rcc + sc + mmc + mig_cpu + mig_comm + heu
    return {
        "MCC": mcc, "LCC": lcc, "RCC": rcc, "SC": sc, "MMC": mmc,
        "MigCPU": mig_cpu, "MigComm": mig_comm, "Heu": heu,
        "MigC": mig_cpu + mig_comm + heu,
        "TEC": total,
    }

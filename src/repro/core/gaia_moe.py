"""GAIA self-clustering adapted to MoE expert placement (beyond-paper).

Mapping from the paper's objects to the training framework:

  SE       -> expert                (the migratable unit)
  LP       -> EP shard              (expert-parallel rank, model axis)
  message  -> routed token          (dispatch all-to-all traffic)
  MigComm  -> expert weight move    (3 * d * d_expert bytes, bf16)

The same heuristic-#1 core applies: for each expert, compare the token
traffic arriving from its own shard's token groups (iota — these tokens
need no all-to-all hop) against the max traffic from any other group
(epsilon). When alpha = eps/iota > MF (and MT steps since the expert
last moved), the expert is a migration candidate toward the hottest
group; a symmetric load balancer (pairwise swaps, same code path as the
paper's §4.4) keeps every shard serving exactly E/G experts.

The placement is applied as a permutation in the router (models/moe.py),
so migrating expert e is one weight gather along the expert axis —
cost-accounted via MigC exactly as in Eq. 6.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import balance as bal


@dataclasses.dataclass(frozen=True)
class GaiaMoEConfig:
    num_experts: int = 64
    num_groups: int = 8  # EP shards
    mf: float = 1.2
    mt: int = 200  # steps between migrations of one expert
    window: int = 8  # EMA-ish window of traffic snapshots
    interval: int = 100  # evaluate placement every `interval` steps


def init_state(cfg: GaiaMoEConfig):
    E, G = cfg.num_experts, cfg.num_groups
    assert E % G == 0, (E, G)
    return {
        "placement": jnp.arange(E, dtype=jnp.int32) % G,  # expert -> shard
        "traffic": jnp.zeros((cfg.window, G, E), jnp.float32),
        "ptr": jnp.int32(0),
        "last_mig": jnp.full((E,), -10**6, jnp.int32),
        "step": jnp.int32(0),
    }


def observe(cfg: GaiaMoEConfig, state, group_expert_counts):
    """Push a (G, E) token-traffic snapshot (from moe_fwd metrics)."""
    tr = state["traffic"].at[state["ptr"] % cfg.window].set(
        group_expert_counts.astype(jnp.float32))
    return dict(state, traffic=tr, ptr=state["ptr"] + 1,
                step=state["step"] + 1)


def a2a_bytes(placement, group_expert_counts, token_bytes: int):
    """All-to-all payload: tokens whose source group != expert's shard."""
    G, E = group_expert_counts.shape
    on_shard = placement[None, :] == jnp.arange(G)[:, None]  # (G, E)
    remote = jnp.where(on_shard, 0.0,
                       group_expert_counts.astype(jnp.float32)).sum()
    return remote * token_bytes


def evaluate(cfg: GaiaMoEConfig, state) -> Tuple[dict, jax.Array]:
    """Heuristic #1 + symmetric balancing over experts.

    Returns (new_state, n_migrations). Keeps E/G experts per shard by
    pairwise swap grants (bal.symmetric_grants)."""
    E, G = cfg.num_experts, cfg.num_groups
    window = state["traffic"].sum(axis=0)  # (G, E)
    placement = state["placement"]
    t = state["step"]

    local = jnp.take_along_axis(window.T, placement[:, None], 1)[:, 0]
    ext = window.T.at[jnp.arange(E), placement].set(0.0)  # (E, G)
    eps = ext.max(axis=-1)
    dest = ext.argmax(axis=-1).astype(jnp.int32)
    alpha = eps / jnp.maximum(local, 1.0)
    eligible = (t - state["last_mig"]) >= cfg.mt
    cand = eligible & (alpha > cfg.mf) & (eps > 0)

    cmat = bal.candidate_matrix(cand, placement, dest, G)
    grants = bal.symmetric_grants(cmat)
    admit = bal.select_migrations(cand, placement, dest, alpha, grants, G)
    new_placement = jnp.where(admit, dest, placement)
    state = dict(state,
                 placement=new_placement,
                 last_mig=jnp.where(admit, t, state["last_mig"]))
    return state, admit.sum()


def placement_permutation(placement_shard, num_experts: int):
    """Convert an expert->shard map into the expert->segment permutation
    the MoE layer consumes (models/moe.py). Segments are shard-major, so
    with E/G experts per shard (enforced by the symmetric balancer) the
    segment's owner on the model axis == the expert's assigned shard.

    Returns (perm (E,), inv (E,)): perm[e] = segment of expert e;
    inv[s] = expert served by segment s."""
    order = jnp.argsort(placement_shard, stable=True)  # segment -> expert
    perm = jnp.zeros((num_experts,), jnp.int32).at[order].set(
        jnp.arange(num_experts, dtype=jnp.int32))
    return perm, order.astype(jnp.int32)


def migration_bytes(n_migrations, d_model: int, d_expert: int,
                    bytes_per_param: int = 2):
    """MigComm for expert moves (3 SwiGLU matrices per expert)."""
    return n_migrations * 3 * d_model * d_expert * bytes_per_param


# ---------------------------------------------------------------------------
# Physical migration (the paper's serialized SE-state transfer, Eq. 6)
# ---------------------------------------------------------------------------
#
# Expert weights are STORED in segment order (models/moe.py): segment s of
# the (sharded) expert axis holds the weights of the expert currently
# placed there. A placement change therefore physically permutes rows of
# every expert-axis leaf (weights + optimizer state) ONCE — the cross-
# shard rows of that permutation are MigComm. The per-step graph never
# gathers weights.


def migration_index(perm_old, order_new):
    """Row index for the segment-ordered store after a placement change.

    perm_old[e] = old segment of expert e; order_new[s] = expert that the
    new placement puts on segment s. stored_new[s] = stored_old[idx[s]].
    """
    return perm_old[order_new]


def apply_migration(expert_leaf, idx, expert_axis: int = 0):
    """Permute the expert axis of one leaf: out[s] = leaf[idx[s]]."""
    return jnp.take(expert_leaf, idx, axis=expert_axis)


def apply_migration_stacked(stacked_leaf, idx_per_layer):
    """(L, E, ...) leaf with per-layer (L, E) indices."""
    return jax.vmap(lambda w, i: jnp.take(w, i, axis=0))(
        stacked_leaf, idx_per_layer)


def count_moves(idx_per_layer):
    """Number of experts that physically changed segment."""
    E = idx_per_layer.shape[-1]
    return (idx_per_layer != jnp.arange(E)[None, :]).sum()


def maybe_update(cfg: GaiaMoEConfig, state, group_expert_counts):
    """Per-step driver: observe traffic; every `interval` steps evaluate.

    jit-friendly (lax.cond on the interval)."""
    state = observe(cfg, state, group_expert_counts)

    def do(s):
        s2, n = evaluate(cfg, s)
        return s2, n

    def skip(s):
        return s, jnp.int32(0)

    return jax.lax.cond(state["step"] % cfg.interval == 0, do, skip, state)

from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: F401
from repro.runtime.watchdog import Watchdog  # noqa: F401

"""Fault-tolerant training driver.

Wires together: data pipeline (deterministic, resumable), jitted train
step, checkpoint manager (async atomic saves), watchdog (straggler/hang
detection) and elastic restart (reshape onto a different mesh via the
checkpoint's unsharded arrays).

Restart contract (tested in tests/test_fault_tolerance.py): killing the
trainer at any step and restarting from the latest checkpoint replays
the identical token stream and reproduces the uninterrupted run's
parameters bit-exactly (the step function is deterministic).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, make_pipeline
from repro.runtime.watchdog import Watchdog


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_save: bool = True
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: TrainerConfig, step_fn: Callable,
                 init_state: Callable[[], tuple], data_cfg: DataConfig,
                 log: Callable[[str], None] = print):
        """step_fn(params, opt_state, extras, batch) ->
        (params, opt_state, extras, metrics); init_state() builds the
        step-0 (params, opt_state, extras)."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.init_state = init_state
        self.data_cfg = data_cfg
        self.ckpt = CheckpointManager(cfg.checkpoint_dir)
        self.watchdog = Watchdog()
        self.log = log

    # ------------------------------------------------------------------
    def run(self, fail_at: Optional[int] = None) -> Dict[str, Any]:
        """Run (or resume) training. `fail_at` injects a crash after the
        given global step completes — used by the fault-tolerance tests."""
        start = self.ckpt.latest_step()
        if start is None:
            params, opt_state, extras = self.init_state()
            step0 = 0
            self.log("[trainer] cold start")
        else:
            like = jax.eval_shape(self.init_state)
            (params, opt_state, extras), step0 = self.ckpt.restore(like)
            self.log(f"[trainer] resumed from step {step0}")
        data = make_pipeline(self.data_cfg, start_step=step0)

        metrics = {}
        for step in range(step0, self.cfg.total_steps):
            batch = next(data)
            t0 = time.time()
            params, opt_state, extras, metrics = self.step_fn(
                params, opt_state, extras, batch)
            jax.block_until_ready(metrics)
            verdict = self.watchdog.observe(step, time.time() - t0)
            if verdict != "ok":
                self.log(f"[watchdog] step {step}: {verdict} "
                         f"(ema {self.watchdog.ema:.3f}s)")
            if (step + 1) % self.cfg.log_every == 0:
                loss = float(metrics.get("loss", float("nan")))
                self.log(f"[trainer] step {step + 1} loss {loss:.4f}")
            if (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, (params, opt_state, extras),
                               blocking=not self.cfg.async_save)
            if fail_at is not None and step + 1 >= fail_at:
                self.ckpt.wait()
                raise RuntimeError(f"injected failure at step {step + 1}")
        self.ckpt.wait()
        self.ckpt.save(self.cfg.total_steps, (params, opt_state, extras))
        return {"params": params, "opt_state": opt_state, "extras": extras,
                "metrics": metrics,
                "stragglers": self.watchdog.stragglers}

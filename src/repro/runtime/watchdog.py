"""Straggler & hang detection for the training loop.

At multi-pod scale the common failure modes are (a) a host that dies
(step never completes) and (b) a straggler that silently stretches every
step. The watchdog tracks an EMA of step wall-time; a step exceeding
``hang_factor x EMA`` trips the hang callback (checkpoint-and-restart in
the trainer), and per-step times above ``straggler_factor x EMA`` are
logged/counted so the scheduler layer can evict the slow host on the
next elastic reshape. On real clusters the per-HOST timings come from
the coordinator's heartbeat service; here the same logic is driven by
the single-process step clock and unit-tested with injected delays.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass
class Watchdog:
    ema_alpha: float = 0.2
    straggler_factor: float = 2.0
    hang_factor: float = 5.0
    min_samples: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    ema: float = 0.0
    n: int = 0
    stragglers: int = 0

    def observe(self, step: int, dt: float) -> str:
        """Feed one step duration. Returns 'ok' | 'straggler' | 'hang'."""
        if self.n < self.min_samples:
            self.ema = dt if self.n == 0 else (
                self.ema_alpha * dt + (1 - self.ema_alpha) * self.ema)
            self.n += 1
            return "ok"
        verdict = "ok"
        if dt > self.hang_factor * self.ema:
            verdict = "hang"
        elif dt > self.straggler_factor * self.ema:
            verdict = "straggler"
            self.stragglers += 1
            if self.on_straggler:
                self.on_straggler(step, dt, self.ema)
        # stragglers pollute the EMA less (clamped update)
        self.ema = (self.ema_alpha * min(dt, 2 * self.ema)
                    + (1 - self.ema_alpha) * self.ema)
        self.n += 1
        return verdict

    def deadline(self) -> float:
        """Suggested per-step deadline (for async collectives timeouts)."""
        return self.hang_factor * self.ema if self.n >= self.min_samples \
            else float("inf")

"""Structured event log: typed, step-stamped records through pluggable
sinks.

Events replace the engine's scattered loud-overflow signals with one
queryable stream. Two sources feed it:

* **synthesized** — ``repro.obs.ledger.Telemetry`` scans every drained
  ledger block host-side and emits ``migration_burst`` / ``repartition``
  / ``grid_overflow`` / ``shard_overflow`` records (threshold rules in
  ObsConfig); because they derive from the ring drain they carry exact
  step stamps even though the host only hears from the device every
  ``drain_every`` steps;
* **direct** — host-side actors call ``EventLog.emit`` themselves:
  ``Engine.arrive``/``Engine.depart`` (churn batches) and the MF
  self-tuner (``tuner_move``).

Sinks are deliberately tiny: anything with an ``emit(dict)`` method
works. ``MemorySink`` backs ``Engine.events()``; ``JsonlSink`` writes
one JSON object per line (the artifact format the nightly CI job
uploads); ``StdoutSink`` is for interactive poking.
"""
from __future__ import annotations

import dataclasses
import json
import sys
from collections import deque
from typing import Any, IO

#: the closed vocabulary of event kinds (kept in sync with DESIGN.md
#: §Observability; tests assert emitted kinds stay inside it)
EVENT_KINDS = (
    "migration_burst",   # per-step migrations >= obs.mig_burst
    "repartition",       # a periodic global repartition moved >= 1 SE
    "grid_overflow",     # oracle proximity capacity clamp tripped
    "shard_overflow",    # sharded halo/migration capacity clamp tripped
    "arrive",            # Engine.arrive admitted a batch
    "depart",            # Engine.depart retired a batch
    "tuner_move",        # MF self-tuner accepted a new MF
)


@dataclasses.dataclass(frozen=True)
class Event:
    """One telemetry event: a kind from EVENT_KINDS, the absolute engine
    step it describes (not the step the host heard about it), and a
    flat JSON-able payload."""

    step: int
    kind: str
    data: dict[str, Any]

    def as_dict(self) -> dict[str, Any]:
        return {"step": self.step, "kind": self.kind, **self.data}


class MemorySink:
    """Bounded in-memory sink; backs ``Engine.events()``."""

    def __init__(self, capacity: int = 65536):
        self.records: deque[Event] = deque(maxlen=capacity)

    def emit(self, event: Event) -> None:
        self.records.append(event)

    def __len__(self) -> int:
        return len(self.records)


class JsonlSink:
    """Append events as JSON Lines to a path or an open file object."""

    def __init__(self, path_or_file: str | IO[str]):
        if isinstance(path_or_file, str):
            self._fh = open(path_or_file, "a", encoding="utf-8")
            self._owns = True
        else:
            self._fh = path_or_file
            self._owns = False

    def emit(self, event: Event) -> None:
        json.dump(event.as_dict(), self._fh, separators=(",", ":"))
        self._fh.write("\n")

    def close(self) -> None:
        self._fh.flush()
        if self._owns:
            self._fh.close()


class StdoutSink:
    def emit(self, event: Event) -> None:
        json.dump(event.as_dict(), sys.stdout, separators=(",", ":"))
        sys.stdout.write("\n")


class EventLog:
    """Fans events out to every attached sink.

    Always carries a MemorySink (so ``Engine.events()`` works without
    configuration); extra sinks are user-supplied. Unknown kinds raise:
    the vocabulary is closed on purpose so downstream consumers can
    switch on ``kind`` exhaustively.
    """

    def __init__(self, sinks=None, capacity: int = 65536):
        self.memory = MemorySink(capacity)
        self.sinks = [self.memory] + list(sinks or [])

    def emit(self, kind: str, step: int, **data: Any) -> Event:
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {kind!r} "
                             f"(known: {EVENT_KINDS})")
        ev = Event(step=int(step), kind=kind, data=data)
        for sink in self.sinks:
            sink.emit(ev)
        return ev

    def records(self, kind: str | None = None) -> list[Event]:
        evs = list(self.memory.records)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        return evs

    def close(self) -> None:
        for sink in self.sinks:
            close = getattr(sink, "close", None)
            if close is not None:
                close()

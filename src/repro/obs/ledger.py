"""Per-step metrics ledger: device ring buffer -> host accumulator.

Device side (wired in `engine._compiled_window_cached` /
`lp_shard._compiled_window_sharded` when ``cfg.obs.enabled``): every
step writes one fixed-shape f32 row — the counters the step already
computes (LCR, msgs, migrations, overflow, halo bytes, pop) plus the
per-LP slot load — into slot ``t % drain_every`` of a
``(drain_every, K)`` ring carried through the scan. When the ring wraps
(``(t+1) % drain_every == 0``) a single async ``jax.debug.callback``
ships the whole block to the host. The scan itself is never broken: one
unordered callback per ``drain_every`` steps, no per-step host sync, no
change to the memoized single-scan architecture. Windows whose length
is not a multiple of ``drain_every`` leave a partial ring; the window
runner flushes that tail host-side from the ring it carries out of the
scan (`flush_tail`).

Host side: :class:`Telemetry` owns the :class:`MetricsLedger` (bounded
row history + O(1) streaming summaries) and the
:class:`~repro.obs.events.EventLog`, and synthesizes threshold events
(migration bursts, repartitions, overflow alarms) from each drained
block — with exact step stamps, because the stamps travel in the rows.

This module must stay import-free of `repro.core.engine` (the engine
imports it); everything here takes the engine config duck-typed.
"""
from __future__ import annotations

import time
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.core.stats import StreamingStats
from repro.obs.events import EventLog

#: scalar step metrics every execution layer reports, in ledger column
#: order (after the leading "step" stamp column)
_BASE_KEYS = ("lcr", "local_msgs", "remote_msgs", "migrations",
              "heu_evals", "repartitions")


def ledger_keys(cfg) -> tuple[str, ...]:
    """Ordered column names of one ledger row for this engine config.

    Layout: step stamp, the layer-shared scalar counters, the layer's
    overflow/wire extras, the open-world population, then the per-LP
    slot load (``lp_load_i`` — live SEs hosted by LP i). The tuple is
    static per config, so the device row and every host consumer agree
    by construction."""
    keys = ["step", *_BASE_KEYS]
    if cfg.sharding == "lp_device":
        keys += ["halo_frac", "bytes_on_wire", "shard_overflow"]
    else:
        keys += ["grid_overflow"]
    if cfg.open_world:
        keys += ["pop"]
    keys += [f"lp_load_{i}" for i in range(cfg.abm.n_lp)]
    return tuple(keys)


def ledger_row(cfg, state, metrics, t):
    """Build the (K,) f32 device row for step ``t`` from the post-step
    state and the step's metrics dict. Trace-time only — runs inside
    the jitted scan body, so it must stay shape-static.

    Per-LP load is derived on device (free slots — oracle ``lp < 0``,
    sharded ``gid < 0`` — bucket into the dropped row L), everything
    else reuses counters the step already computed."""
    L = cfg.abm.n_lp
    lp = state["lp"]
    dead = (state["gid"] < 0) if "gid" in state else (lp < 0)
    load = jnp.bincount(jnp.where(dead, L, lp), length=L + 1)[:L]
    cols = [jnp.asarray(t, jnp.float32)]
    for k in ledger_keys(cfg)[1:]:
        if k.startswith("lp_load_"):
            break
        cols.append(jnp.asarray(metrics[k], jnp.float32))
    return jnp.concatenate([jnp.stack(cols), load.astype(jnp.float32)])


class MetricsLedger:
    """Host accumulator for drained ledger rows.

    Keeps a bounded row history (``capacity`` newest rows — a resident
    engine can run forever) plus unbounded O(1) streaming summaries per
    column (`repro.core.stats.StreamingStats`), so `summary()` reflects
    the whole run even after old rows age out. Rows arrive from an
    unordered `jax.debug.callback`; each row carries its own step stamp
    in column 0, so consumers never depend on arrival order (in
    practice blocks arrive monotonically from the sequential scan)."""

    def __init__(self, keys: tuple[str, ...], capacity: int = 65536):
        self.keys = tuple(keys)
        self._idx = {k: i for i, k in enumerate(self.keys)}
        self._rows: deque[np.ndarray] = deque(maxlen=capacity)
        self._streams = {k: StreamingStats() for k in self.keys
                         if k != "step"}
        self.n_total = 0
        self.last_drain_s: float | None = None

    def append_block(self, block: np.ndarray) -> None:
        """Ingest a (B, K) block of rows (B >= 1)."""
        block = np.asarray(block, np.float64)
        if block.ndim != 2 or block.shape[1] != len(self.keys):
            raise ValueError(f"ledger block shape {block.shape} does not "
                             f"match {len(self.keys)} columns")
        for row in block:
            self._rows.append(row)
            for k, s in self._streams.items():
                s.add(row[self._idx[k]])
        self.n_total += len(block)
        self.last_drain_s = time.time()

    def __len__(self) -> int:
        return len(self._rows)

    def rows(self) -> np.ndarray:
        """(T, K) array of the retained row history (oldest first)."""
        if not self._rows:
            return np.zeros((0, len(self.keys)), np.float64)
        return np.stack(self._rows)

    def column(self, key: str) -> np.ndarray:
        return self.rows()[:, self._idx[key]]

    def as_dict(self) -> dict[str, np.ndarray]:
        rows = self.rows()
        return {k: rows[:, i] for i, k in enumerate(self.keys)}

    def latest(self) -> dict[str, float]:
        """The newest row as {column: value} ({} while empty)."""
        if not self._rows:
            return {}
        row = self._rows[-1]
        return {k: float(row[i]) for i, k in enumerate(self.keys)}

    def summary(self) -> dict[str, dict[str, float]]:
        """Whole-run mean/std/ci95/n per column (streaming: not limited
        to the retained history)."""
        return {k: s.as_dict() for k, s in self._streams.items()
                if s.n > 0}


class Telemetry:
    """One engine's telemetry session: ledger + event log + thresholds.

    Receives drained device blocks (via `repro.obs.runtime`, which
    routes the shared compiled executables' callbacks to whichever
    session is current), files the rows, and synthesizes threshold
    events. Host-side actors (`Engine.arrive`/`depart`, the MF tuner)
    emit directly through :meth:`emit`."""

    def __init__(self, cfg, sinks=None):
        self.cfg = cfg
        self.keys = ledger_keys(cfg)
        self._idx = {k: i for i, k in enumerate(self.keys)}
        self.ledger = MetricsLedger(self.keys, capacity=cfg.obs.history)
        self.events = EventLog(sinks, capacity=cfg.obs.history)
        self.dropped_blocks = 0  # blocks that arrived with no session

    # -- device-side feeds (called from jax.debug.callback) ----------------
    def on_block(self, ring: np.ndarray, t_last: int) -> None:
        """A full ring flushed at step ``t_last``: slot i holds step
        ``t_last - drain_every + 1 + i`` (flushes happen exactly when
        the ring wraps, so slots are already in step order)."""
        de = self.cfg.obs.drain_every
        self._ingest_stamped(np.asarray(ring),
                             range(int(t_last) - de + 1, int(t_last) + 1))

    def on_tail(self, ring: np.ndarray, t_start: int, t_end: int) -> None:
        """Flush the partial ring a window carried out of its scan:
        steps in ``[max(t_start, t_end - t_end % drain_every), t_end)``
        never hit a wrap flush; their slots are ``t % drain_every``."""
        de = self.cfg.obs.drain_every
        lo = max(int(t_start), int(t_end) - int(t_end) % de)
        steps = range(lo, int(t_end))
        if not steps:
            return
        ring = np.asarray(ring)
        self._ingest_stamped(np.stack([ring[t % de] for t in steps]), steps)

    def _ingest_stamped(self, block: np.ndarray, steps) -> None:
        """File only the rows whose on-device step stamp (column 0)
        matches the step the slot is supposed to hold. The ring
        initializes to -1 and windows need not align to drain_every, so
        a flush can see never-written or previous-window slots — the
        stamp check drops exactly those (a window's first wrap flush
        after a short predecessor window, the tail after a wrap, etc.)
        without any cross-window bookkeeping."""
        keep = [i for i, t in enumerate(steps) if block[i, 0] == t]
        if not keep:
            return
        self._ingest(block[keep] if len(keep) != len(block) else block)

    def _ingest(self, block: np.ndarray) -> None:
        self.ledger.append_block(block)
        if self.cfg.obs.events:
            self._synthesize(block)

    # -- event synthesis ---------------------------------------------------
    def _synthesize(self, block: np.ndarray) -> None:
        ix = self._idx
        burst = self.cfg.obs.mig_burst
        for row in block:
            step = int(row[ix["step"]])
            migs = int(row[ix["migrations"]])
            reparts = int(row[ix["repartitions"]])
            if migs >= burst:
                self.emit("migration_burst", step,
                          migrations=migs, repartitions=reparts)
            if reparts > 0:
                self.emit("repartition", step, moved=reparts)
            if "grid_overflow" in ix and row[ix["grid_overflow"]] > 0:
                self.emit("grid_overflow", step)
            if "shard_overflow" in ix and row[ix["shard_overflow"]] > 0:
                self.emit("shard_overflow", step)

    def emit(self, kind: str, step: int, **data) -> None:
        self.events.emit(kind, step, **data)

    # -- host-facing views -------------------------------------------------
    def summary(self) -> dict[str, dict[str, float]]:
        return self.ledger.summary()

    def close(self) -> None:
        self.events.close()

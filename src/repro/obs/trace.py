"""Step-phase trace timelines: Chrome-trace / Perfetto JSON export.

The fused engine scans are opaque to wall-clock phase attribution (XLA
fuses the whole step body), so the trace executor here runs a window
*phase by phase*: each phase of `engine.step_phases` (oracle) or
`lp_shard._sharded_phases` (sharded, one jit(shard_map) program per
phase — `lp_shard.sharded_trace_phases`) is dispatched as its own jitted
call and timed host-side with `block_until_ready`. The recorder emits
one complete-event ("ph": "X") span per (device, phase, step) in the
Chrome trace-event format, so `benchmarks/run.py --trace` produces a
JSON that chrome://tracing and https://ui.perfetto.dev open directly.

Phase-split execution reproduces the step semantics (the phases are the
very functions the fused step composes) but is a *profiling* surface,
not a bit-identity one: XLA fuses differently across the cut points, so
traced runs are not asserted byte-equal to the fused scan, and the
timings include per-phase dispatch overhead the fused scan amortizes
away (DESIGN.md §Observability).

This module imports the execution layers lazily (function-local): the
engine imports `repro.obs` submodules, and `repro.obs.__init__` re-
exports this module's entry points.
"""
from __future__ import annotations

import json
import time
from typing import Optional


class TraceRecorder:
    """Collects Chrome trace events; one timeline row (tid) per device.

    `ts`/`dur` are microseconds relative to the recorder's creation, the
    trace-event format's native unit.
    """

    def __init__(self, n_dev: int = 1, process_name: str = "gaia-engine"):
        self.n_dev = n_dev
        self.events: list[dict] = []
        self._t0 = time.perf_counter()
        self.events.append({"ph": "M", "pid": 0, "tid": 0,
                            "name": "process_name",
                            "args": {"name": process_name}})
        for d in range(n_dev):
            self.events.append({"ph": "M", "pid": 0, "tid": d,
                                "name": "thread_name",
                                "args": {"name": f"device {d}"}})

    def add_span(self, name: str, step: int, t_start: float, t_end: float,
                 dev_args: Optional[list] = None) -> None:
        """One phase span, replicated onto every device row (single-
        process SPMD executes all devices inside one XLA program, so
        per-device wall time is not separable — per-device *data* rides
        in `dev_args`, one dict per device)."""
        ts = (t_start - self._t0) * 1e6
        dur = (t_end - t_start) * 1e6
        for d in range(self.n_dev):
            args = {"step": step}
            if dev_args is not None:
                args.update(dev_args[d])
            self.events.append({"ph": "X", "cat": "step", "name": name,
                                "pid": 0, "tid": d, "ts": ts, "dur": dur,
                                "args": args})

    def as_dict(self) -> dict:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.as_dict(), fh)
        return path

    def phase_summary(self) -> dict:
        """Per-phase wall-time stats over the recorded steps (seconds):
        {phase: {"mean": s, "total": s, "n": spans}} — device 0's row
        only (spans are replicated across device rows)."""
        acc: dict[str, list[float]] = {}
        for ev in self.events:
            if ev.get("ph") == "X" and ev["tid"] == 0:
                acc.setdefault(ev["name"], []).append(ev["dur"] / 1e6)
        return {k: {"mean": sum(v) / len(v), "total": sum(v), "n": len(v)}
                for k, v in acc.items()}


def _dev_args(px, n_dev: int) -> list:
    """Per-device span payload from the sharded phase context: the
    per-device counters present at this point of the step."""
    import numpy as np
    out = [dict() for _ in range(n_dev)]
    for key in ("n_valid", "halo_n"):
        if key in px:
            vals = np.asarray(px[key])
            for d in range(n_dev):
                out[d][key] = int(vals[d])
    return out


def trace_steps(state, cfg, n_steps: int, recorder: TraceRecorder,
                mf=None, warmup: int = 2):
    """Advance `state` by `warmup + n_steps` steps phase-by-phase,
    recording one span per (device, phase, step) for the last `n_steps`
    (the warmup steps absorb per-phase compilation — two by default,
    because input shardings settle after the first wrapped step and
    trigger one more specialization — so spans measure steady-state
    execution). Returns the advanced state."""
    if cfg.sharding == "lp_device":
        return _trace_steps_sharded(state, cfg, n_steps, recorder, mf,
                                    warmup)
    return _trace_steps_oracle(state, cfg, n_steps, recorder, mf, warmup)


def _trace_steps_oracle(state, cfg, n_steps, recorder, mf, warmup):
    import jax
    import jax.numpy as jnp
    from repro.core.engine import step_phases

    phases = [(name, jax.jit(fn)) for name, fn in step_phases(cfg)]
    mf_val = jnp.float32(cfg.heuristic.mf if mf is None else mf)
    for i in range(warmup + n_steps):
        record = i >= warmup
        px = {"st": state, "mf": mf_val}
        step_no = int(state["t"])
        for name, fn in phases:
            t0 = time.perf_counter()
            px = fn(px)
            jax.block_until_ready(px)
            if record:
                recorder.add_span(name, step_no, t0, time.perf_counter())
        state = px["new_state"]
    return state


def _trace_steps_sharded(state, cfg, n_steps, recorder, mf, warmup):
    import jax
    import jax.numpy as jnp
    from repro.core.engine import window_key_cfg
    from repro.parallel import lp_shard

    key_cfg = window_key_cfg(cfg)
    spec = lp_shard.make_shard_spec(key_cfg)
    mesh = lp_shard.make_mesh(spec)
    phases = lp_shard.sharded_trace_phases(key_cfg, spec, mesh)
    fkeys = list(lp_shard._field_specs(spec))
    mf_val = jnp.float32(cfg.heuristic.mf if mf is None else mf)
    for i in range(warmup + n_steps):
        record = i >= warmup
        key, k_move, k_send = jax.random.split(state["key"], 3)
        px = {"f": {k: state[k] for k in fkeys},
              "k_move": jax.random.key_data(k_move),
              "k_send": jax.random.key_data(k_send),
              "t": state["t"], "mf": mf_val}
        step_no = int(state["t"])
        for name, fn in phases:
            t0 = time.perf_counter()
            px = fn(px)
            jax.block_until_ready(px)
            if record:
                recorder.add_span(name, step_no, t0, time.perf_counter(),
                                  dev_args=_dev_args(px, spec.n_dev))
        state = dict(px["f"], key=key, t=state["t"] + 1)
    return state


def trace_run(cfg, seed: int = 0, n_steps: Optional[int] = None,
              warmup: int = 2):
    """Initialize an engine state for `cfg`, trace `n_steps` (default
    cfg.timesteps) phase-by-phase, and return the populated
    :class:`TraceRecorder`."""
    import jax
    from repro.core.engine import _init_engine, window_key_cfg

    if n_steps is None:
        n_steps = cfg.timesteps
    if cfg.sharding == "lp_device":
        from repro.parallel import lp_shard
        spec = lp_shard.make_shard_spec(window_key_cfg(cfg))
        state = lp_shard.init_sharded(jax.random.key(seed), cfg, spec)
        n_dev = spec.n_dev
    else:
        state = _init_engine(jax.random.key(seed), cfg)
        n_dev = 1
    recorder = TraceRecorder(n_dev=n_dev)
    trace_steps(state, cfg, n_steps, recorder, warmup=warmup)
    return recorder

"""Runtime telemetry for the GAIA engine (DESIGN.md §Observability).

Three pillars, all off by default (`ObsConfig.enabled = False` — a
telemetry-off config shares compiled executables with a config that
never heard of telemetry, and telemetry-on never perturbs PRNG streams
or results):

* **metrics ledger** (`ledger`): a fixed-shape on-device ring buffer of
  per-step counters, drained asynchronously to the host every
  `drain_every` steps via one unordered `jax.debug.callback` — the
  memoized single-scan architecture is never broken per step;
* **event log** (`events`): typed, step-stamped records (migration
  bursts, repartitions, overflow alarms, churn batches, tuner moves)
  through pluggable sinks (memory / JSONL / stdout);
* **trace timelines** (`trace`): Chrome-trace/Perfetto JSON spans of the
  step phases per device, from a phase-by-phase trace executor.

`core.service.Engine.metrics()/events()/prometheus()` is the serving
surface; `benchmarks/run.py --trace` the profiling one.
"""
from repro.obs.config import ObsConfig
from repro.obs.events import (EVENT_KINDS, Event, EventLog, JsonlSink,
                              MemorySink, StdoutSink)
from repro.obs.ledger import MetricsLedger, Telemetry, ledger_keys
from repro.obs.prom import prometheus_text
from repro.obs import runtime
from repro.obs.trace import TraceRecorder, trace_run, trace_steps

__all__ = [
    "ObsConfig", "EVENT_KINDS", "Event", "EventLog", "JsonlSink",
    "MemorySink", "StdoutSink", "MetricsLedger", "Telemetry",
    "ledger_keys", "prometheus_text", "runtime", "TraceRecorder",
    "trace_run", "trace_steps",
]

"""Telemetry session routing for shared compiled executables.

The engine memoizes compiled window scans per config
(`engine._compiled_window_cached`), so one executable serves every
`Engine` instance with that config — its embedded
``jax.debug.callback`` closures therefore cannot capture a particular
ledger. Instead the callbacks reference the module-level functions
here, which route to whichever :class:`~repro.obs.ledger.Telemetry`
session is *current*. `core.service.Engine` marks its session current
before every windowed device call; plain one-shot runners (`engine.run`
with an obs-enabled config) do the same around the run.

Single-process, one-active-engine-at-a-time assumption (documented in
DESIGN.md §Observability): "current" is a plain module global, last
setter wins, and interleaving *steps* of two telemetry-enabled engines
is supported because each re-asserts its session at every call —
concurrent stepping from multiple threads is not. Blocks that arrive
with no session are counted, not filed.
"""
from __future__ import annotations

import contextlib

import numpy as np

_CURRENT = None
dropped_blocks = 0


def set_current(tele) -> None:
    """Make `tele` (a Telemetry or None) the routing target."""
    global _CURRENT
    _CURRENT = tele


def get_current():
    return _CURRENT


@contextlib.contextmanager
def use(tele):
    """Scope a Telemetry as current (tests and one-shot runners)."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tele
    try:
        yield tele
    finally:
        _CURRENT = prev


def on_block(ring, t_last) -> None:
    """`jax.debug.callback` target: a full (drain_every, K) ring
    flushed at step `t_last`. Must stay a module-level function — the
    callback identity is part of the executable."""
    global dropped_blocks
    tele = _CURRENT
    if tele is None:
        dropped_blocks += 1
        return
    tele.on_block(np.asarray(ring), int(t_last))


def flush_tail(ring, t_start, t_end) -> None:
    """Host-side flush of the partial ring a window carried out of its
    scan (window length not a multiple of drain_every). Waits for every
    in-flight wrap callback first so ledger rows file in step order."""
    tele = _CURRENT
    if tele is None:
        return
    import jax
    jax.effects_barrier()
    tele.on_tail(np.asarray(ring), int(t_start), int(t_end))


def emit_event(kind: str, step: int, **data) -> None:
    """Host-side event emission into the current session, if any (the
    MF self-tuner and other engine-agnostic call sites use this)."""
    tele = _CURRENT
    if tele is not None:
        tele.emit(kind, step, **data)

"""Prometheus text-format rendering of a telemetry session.

One function, no client library: the exposition format for gauges is
plain text (`# TYPE name gauge` + `name{label="v"} value` lines), which
is all a scrape endpoint or a textfile-collector drop needs. Rendered
from the ledger's latest row + streaming summaries, so it is O(columns)
regardless of run length.
"""
from __future__ import annotations

_PREFIX = "gaia"


def _san(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def prometheus_text(tele, extra: dict | None = None) -> str:
    """Render a :class:`~repro.obs.ledger.Telemetry` session as
    Prometheus text exposition. Emits, per ledger column, the latest
    per-step value (`gaia_<col>`) and the whole-run mean
    (`gaia_<col>_mean`); per-LP loads fold into one metric with an `lp`
    label. `extra` appends caller gauges (e.g. the service's replica
    count) verbatim."""
    out = []

    def gauge(name, value, labels=""):
        name = f"{_PREFIX}_{_san(name)}"
        out.append(f"# TYPE {name} gauge")
        out.append(f"{name}{labels} {value:g}")

    latest = tele.ledger.latest()
    for col, val in latest.items():
        if col.startswith("lp_load_"):
            continue
        gauge(col, val)
    loads = [(col[len("lp_load_"):], val) for col, val in latest.items()
             if col.startswith("lp_load_")]
    if loads:
        name = f"{_PREFIX}_lp_load"
        out.append(f"# TYPE {name} gauge")
        for lp, val in loads:
            out.append(f'{name}{{lp="{lp}"}} {val:g}')
    for col, st in tele.summary().items():
        if col.startswith("lp_load_"):
            continue
        gauge(f"{col}_mean", st["mean"])
    gauge("ledger_rows_total", tele.ledger.n_total)
    gauge("events_total", len(tele.events.records()))
    for kind in sorted({e.kind for e in tele.events.records()}):
        n = sum(1 for e in tele.events.records() if e.kind == kind)
        gauge("events", n, labels=f'{{kind="{kind}"}}')
    for name, value in (extra or {}).items():
        gauge(name, value)
    return "\n".join(out) + "\n"

"""Telemetry configuration.

``ObsConfig`` rides on :class:`repro.core.engine.EngineConfig` as the
``obs`` field. It must stay a frozen (hashable) dataclass: the compiled
window/scan executables are memoized on the whole ``EngineConfig``, and
an *enabled* telemetry config legitimately changes the traced program
(the ring-buffer write + drain callback are real ops), so it has to be
part of the cache key. A *disabled* config, by contrast, is normalized
to the default ``ObsConfig()`` inside ``window_key_cfg`` so every
telemetry-off variant shares one cache entry — that identity is the
"zero-op-when-off" invariant and is asserted by tests/test_obs.py.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Runtime telemetry knobs (ledger + event log + trace).

    enabled      master switch; False means the compiled step/scan is
                 bit-for-bit the untelemetered program (no extra ops)
    drain_every  ring-buffer depth in steps: the on-device ledger ring
                 holds ``drain_every`` rows and is flushed to host via
                 one async ``jax.debug.callback`` per ``drain_every``
                 steps (never per step), so the jitted scan stays whole
    events       synthesize structured events (migration_burst /
                 repartition / overflow alarms) host-side from drained
                 ledger rows; direct emissions (arrive/depart batches,
                 tuner moves) are host events and ignore this flag
    mig_burst    migrations-per-step threshold at or above which a
                 ``migration_burst`` event is emitted
    history      host-side ledger capacity in rows (oldest dropped) so
                 a resident engine's telemetry memory stays bounded
    """

    enabled: bool = False
    drain_every: int = 10
    events: bool = True
    mig_burst: int = 1
    history: int = 65536

    def __post_init__(self):
        if self.drain_every < 1:
            raise ValueError(
                f"obs.drain_every must be >= 1, got {self.drain_every}")
        if self.mig_burst < 1:
            raise ValueError(
                f"obs.mig_burst must be >= 1, got {self.mig_burst}")
        if self.history < 1:
            raise ValueError(
                f"obs.history must be >= 1, got {self.history}")

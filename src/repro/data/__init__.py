from repro.data.pipeline import DataConfig, SyntheticLM, make_pipeline  # noqa: F401

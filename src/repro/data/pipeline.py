"""Deterministic, restart-safe data pipeline.

Batches are a pure function of (seed, step) — after a checkpoint/restart
the trainer replays the exact token stream without any saved iterator
state (the property the fault-tolerance test asserts). A background
prefetch thread keeps `prefetch` batches ahead of the training loop so
host-side generation overlaps device compute.

The synthetic LM task is a noisy Markov chain over the vocab. Default
order 1 (next = a fixed linear bijection of the current token): bigram
structure a model learns within tens of steps — cross-entropy falls from
ln(V) toward the task entropy in examples/train_lm.py. Order 2 is the
hard mode ((31a+17b+7) mod V — modular arithmetic, grokking-speed
learning; used where a *deterministic stream* matters more than a
learnable one), with zero external data deps either way.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    prefetch: int = 2
    structure: float = 0.9  # P(follow the markov rule) vs uniform noise
    order: int = 1  # 1: learnable bigram bijection; 2: modular arithmetic


class SyntheticLM:
    """Markov stream. Order 1: next = (31*a + 7) % V (a bijection when
    gcd(31, V) = 1 — bigram stats, fast to learn). Order 2:
    next = (31*a + 17*b + 7) % V. Both with prob `structure`, else
    uniform."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, V, B)
        toks[:, 1] = rng.integers(0, V, B)
        noise = rng.random((B, S)) >= cfg.structure
        rand = rng.integers(0, V, (B, S))
        for t in range(2, S):
            if cfg.order == 1:
                nxt = (toks[:, t - 1] * 31 + 7) % V
            else:
                nxt = (toks[:, t - 1] * 31 + toks[:, t - 2] * 17 + 7) % V
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks,
                "loss_mask": np.ones((B, S), np.float32)}


def make_pipeline(cfg: DataConfig, start_step: int = 0,
                  source: Optional[SyntheticLM] = None) -> Iterator[dict]:
    """Prefetching iterator over batches, resumable at `start_step`."""
    src = source or SyntheticLM(cfg)
    q: "queue.Queue" = queue.Queue(maxsize=max(cfg.prefetch, 1))
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(src.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    th = threading.Thread(target=worker, daemon=True)
    th.start()

    def gen():
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    return gen()

"""Deterministic, restart-safe data pipeline.

Batches are a pure function of (seed, step) — after a checkpoint/restart
the trainer replays the exact token stream without any saved iterator
state (the property the fault-tolerance test asserts). A background
prefetch thread keeps `prefetch` batches ahead of the training loop so
host-side generation overlaps device compute.

The synthetic LM task is a noisy Markov chain over the vocab. Default
order 1 (next = a fixed linear bijection of the current token): bigram
structure a model learns within tens of steps — cross-entropy falls from
ln(V) toward the task entropy in examples/train_lm.py. Order 2 is the
hard mode ((31a+17b+7) mod V — modular arithmetic, grokking-speed
learning; used where a *deterministic stream* matters more than a
learnable one), with zero external data deps either way.
"""
from __future__ import annotations

import dataclasses
import queue
import sys
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    prefetch: int = 2
    structure: float = 0.9  # P(follow the markov rule) vs uniform noise
    order: int = 1  # 1: learnable bigram bijection; 2: modular arithmetic


class SyntheticLM:
    """Markov stream. Order 1: next = (31*a + 7) % V (a bijection when
    gcd(31, V) = 1 — bigram stats, fast to learn). Order 2:
    next = (31*a + 17*b + 7) % V. Both with prob `structure`, else
    uniform."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, V, B)
        toks[:, 1] = rng.integers(0, V, B)
        noise = rng.random((B, S)) >= cfg.structure
        rand = rng.integers(0, V, (B, S))
        for t in range(2, S):
            if cfg.order == 1:
                nxt = (toks[:, t - 1] * 31 + 7) % V
            else:
                nxt = (toks[:, t - 1] * 31 + toks[:, t - 2] * 17 + 7) % V
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks,
                "loss_mask": np.ones((B, S), np.float32)}


def make_pipeline(cfg: DataConfig, start_step: int = 0,
                  source: Optional[SyntheticLM] = None) -> Iterator[dict]:
    """Prefetching iterator over batches, resumable at `start_step`."""
    src = source or SyntheticLM(cfg)
    q: "queue.Queue" = queue.Queue(maxsize=max(cfg.prefetch, 1))
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(src.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    th = threading.Thread(target=worker, daemon=True)
    th.start()

    def gen():
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    return gen()


# ---------------------------------------------------------------------------
# Mobility traces (backing store for the `trace` mobility model)
# ---------------------------------------------------------------------------
#
# A trace is a dense (T, N, 2) float32 frame stack: frame t holds the
# position of every SE at integer step t, already on the torus
# ([0, area) per axis). The engine replays frames verbatim — replay is
# bit-equal to the stack by construction, so the round-trip contract
# (generator -> writer -> loader -> replay) is byte-exact. Irregularly
# timestamped sources (GPS/taxi logs) go through `resample_trace`,
# which torus-lerps onto the integer step grid and returns the *exact*
# sample row whenever a step time coincides with a sample time.
#
# Traces are data, not config: `ABMConfig` stays hashable (the compiled
# -scan memo keys on it) by referring to a trace via `trace_name`, a
# key into the process-wide registry below. The frames resolve at trace
# time and become jit constants.


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Parameters of the synthetic commuter-trace generator."""
    n_se: int
    area: float
    timesteps: int          # number of frames T (steps 0..T-1)
    speed: float = 10.0     # max per-step displacement the commute obeys
    n_hubs: int = 6         # shared destinations (taxi-stand analogue)
    seed: int = 0


class Trace:
    """An in-memory position trace: ``frames`` (T, N, 2) float32 on the
    ``area`` torus. Derived quantities (per-step displacement bound,
    peak cell occupancy) are computed lazily and cached — they size the
    halo dilation radius and the proximity-grid capacity exactly."""

    def __init__(self, frames: np.ndarray, area: float):
        frames = np.asarray(frames, np.float32)
        if frames.ndim != 3 or frames.shape[2] != 2 or frames.shape[0] < 1:
            raise ValueError(
                f"trace frames must be (T>=1, N, 2); got {frames.shape}")
        if not (np.isfinite(frames).all()
                and (frames >= 0).all() and (frames < area).all()):
            raise ValueError(
                "trace frames must be finite and inside [0, area) on "
                "both axes (fold external data onto the torus first)")
        self.frames = frames
        self.area = float(area)
        self._occ_cache: dict = {}
        self._disp_cache: dict = {}

    @property
    def timesteps(self) -> int:
        return int(self.frames.shape[0])

    @property
    def n_se(self) -> int:
        return int(self.frames.shape[1])

    def max_step_displacement(self, include_seam: bool = False) -> float:
        """Exact torus-aware max |Δpos| between consecutive frames.
        Sizes the sharded halo's dilation radius — exact-or-loud, no
        heuristic bound. ``include_seam`` adds the frames[-1] ->
        frames[0] jump, which only the `loop` replay policy ever takes
        (a commute rarely closes at the trace boundary, so the seam
        can dominate — hold/exact replays must not pay for it)."""
        key = bool(include_seam)
        if key not in self._disp_cache:
            f = self.frames.astype(np.float64)
            nxt = np.concatenate([f[1:], f[:1]], axis=0) if include_seam \
                else f[1:]
            if nxt.shape[0] == 0:
                self._disp_cache[key] = 0.0
            else:
                d = nxt - f[:nxt.shape[0]] if not include_seam else nxt - f
                half = self.area / 2.0
                d = (d + half) % self.area - half
                self._disp_cache[key] = float(np.sqrt((d * d).sum(-1)).max())
        return self._disp_cache[key]

    def peak_cell_occupancy(self, ncell: int) -> int:
        """Max SEs in any cell of an (ncell, ncell) uniform grid over
        the area, across ALL frames — the exact capacity bound for the
        proximity grid when replaying this trace."""
        key = int(ncell)
        if key not in self._occ_cache:
            cell = self.area / ncell
            ix = np.clip((self.frames[..., 0] / cell).astype(np.int64),
                         0, ncell - 1)
            iy = np.clip((self.frames[..., 1] / cell).astype(np.int64),
                         0, ncell - 1)
            flat = ix * ncell + iy  # (T, N)
            peak = 0
            for t in range(flat.shape[0]):
                peak = max(peak, int(np.bincount(
                    flat[t], minlength=ncell * ncell).max()))
            self._occ_cache[key] = peak
        return self._occ_cache[key]


def synthetic_trace(spec: TraceSpec) -> Trace:
    """Deterministic commuter trace: every SE shuttles between a home
    and one of ``n_hubs`` hubs along the torus-shortest path (a
    triangle wave with per-SE period and phase), never moving more
    than ``spec.speed`` per step. Hubs concentrate SEs — the workload
    is clustered like taxi data, not uniform — and commutes routinely
    cross the torus seam, so replay exercises wrap handling."""
    rng = np.random.default_rng(spec.seed)
    n, area, T = spec.n_se, float(spec.area), int(spec.timesteps)
    homes = rng.random((n, 2)) * area
    hubs = rng.random((max(spec.n_hubs, 1), 2)) * area
    target = hubs[rng.integers(0, len(hubs), n)]
    half = area / 2.0
    d = (target - homes + half) % area - half  # torus-shortest commute
    dist = np.sqrt((d * d).sum(-1))
    # round-trip period: out leg covers |d| in P/2 steps at <= speed
    period = np.maximum(2.0 * np.ceil(dist / max(spec.speed, 1e-9)), 2.0)
    phase = rng.integers(0, period.astype(np.int64) + 1, n)
    t = np.arange(T, dtype=np.float64)
    u = ((t[:, None] + phase[None, :]) % period[None, :]) / period[None, :]
    frac = 1.0 - np.abs(2.0 * u - 1.0)  # triangle 0 -> 1 -> 0
    frames = (homes[None] + frac[..., None] * d[None]) % area
    return Trace(frames.astype(np.float32), area)


def save_trace(trace: Trace, path: str) -> str:
    """Write a trace as .npz (float32 frames + area). Round-trips
    bit-exactly through `load_trace`."""
    np.savez(path, frames=trace.frames,
             area=np.float32(trace.area))
    return path if path.endswith(".npz") else path + ".npz"


def load_trace(path: str) -> Trace:
    with np.load(path) as z:
        return Trace(z["frames"], float(z["area"]))


def resample_trace(times, positions, area: float, n_steps: int) -> Trace:
    """Map an irregularly timestamped position log onto the integer
    step grid 0..n_steps-1 by torus-aware linear interpolation.

    ``times`` (S,) must be strictly increasing and bracket the step
    grid (times[0] <= 0, times[-1] >= n_steps-1) — exact-or-loud, no
    silent extrapolation. When a step time coincides with a sample
    time the sample row is returned verbatim (bit-equal), so a log
    recorded *at* integer steps resamples to itself exactly."""
    times = np.asarray(times, np.float64)
    positions = np.asarray(positions, np.float32)
    if times.ndim != 1 or positions.shape[:1] != times.shape:
        raise ValueError("times (S,) must index positions (S, N, 2)")
    if not (np.diff(times) > 0).all():
        raise ValueError("trace timestamps must be strictly increasing")
    if times[0] > 0 or times[-1] < n_steps - 1:
        raise ValueError(
            f"trace samples [{times[0]}, {times[-1]}] do not cover the "
            f"step grid [0, {n_steps - 1}] — trim n_steps or extend the "
            "log (resample never extrapolates)")
    grid = np.arange(n_steps, dtype=np.float64)
    hi = np.clip(np.searchsorted(times, grid, side="left"),
                 1, len(times) - 1)
    lo = hi - 1
    exact = times[hi] == grid
    frac = ((grid - times[lo]) /
            (times[hi] - times[lo])).astype(np.float64)
    half = float(area) / 2.0
    p0 = positions[lo].astype(np.float64)
    delta = (positions[hi].astype(np.float64) - p0 + half) % area - half
    lerp = ((p0 + frac[:, None, None] * delta) % area).astype(np.float32)
    frames = np.where(exact[:, None, None], positions[hi], lerp)
    return Trace(frames, float(area))


#: process-wide trace registry; `ABMConfig.trace_name` keys into it so
#: the engine config stays hashable for the compiled-scan memo
_TRACES: dict[str, Trace] = {}


def register_trace(name: str, trace: Trace) -> Trace:
    """Bind ``name`` -> ``trace``. Rebinding a live name drops the
    engine's compiled-program caches: the frames are baked into traced
    programs as constants, so a stale cache would silently replay the
    old trace."""
    if not name:
        raise ValueError("trace name must be non-empty")
    prev = _TRACES.get(name)
    _TRACES[name] = trace
    if prev is not None and prev is not trace:
        eng = sys.modules.get("repro.core.engine")
        if eng is not None:
            eng.clear_compiled_caches()
    return trace


def get_trace(name: str) -> Trace:
    if name not in _TRACES:
        raise KeyError(
            f"trace {name!r} is not registered (known: "
            f"{sorted(_TRACES)}); call repro.data.pipeline."
            "register_trace(name, trace) before building the engine")
    return _TRACES[name]


def trace_names() -> list:
    return sorted(_TRACES)

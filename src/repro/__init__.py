"""Reproduction of "The Simulation Model Partitioning Problem: an
Adaptive Solution Based on Self-Clustering" (cs.DC 2016) in JAX/Pallas.

Subpackages: core (GAIA engine + heuristics + neighbor search), kernels
(Pallas TPU hot spots), plus the beyond-paper scaling stack (models,
parallel, optim, runtime, launch, data, configs, checkpoint). See
README.md for the paper -> module map.
"""

"""Tiled online-softmax attention kernel (beyond-paper model stack)."""
from repro.kernels.flash_attention.flash_attention import (  # noqa: F401
    flash_attention)
from repro.kernels.flash_attention.ref import attention_ref  # noqa: F401

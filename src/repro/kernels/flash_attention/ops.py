"""Public wrapper: model-facing layout adapters for the flash kernel."""
from __future__ import annotations


from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref  # noqa: F401


def flash_attention_bhsd(q, k, v, causal: bool = True, interpret: bool = True):
    """(B,H,S,D) layout wrapper (KV pre-expanded to H heads)."""
    B, H, S, D = q.shape
    out = flash_attention(q.reshape(B * H, S, D),
                          k.reshape(B * H, k.shape[2], D),
                          v.reshape(B * H, v.shape[2], D),
                          causal=causal, interpret=interpret)
    return out.reshape(B, H, S, D)

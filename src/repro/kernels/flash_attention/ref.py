"""Pure-jnp oracle for the flash-attention kernel (exact softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q,k,v: (BH, S, D) (heads pre-flattened, KV pre-expanded)."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, k.shape[1]), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

"""Pallas TPU flash-attention forward (causal / bidirectional).

Grid (BH, nq, nk), nk innermost: the fp32 accumulator and the running
max/denominator tiles live in VMEM scratch across the whole KV sweep of
one query block (HBM->VMEM traffic is O(S) per query block, the flash
invariant). Causal scheduling skips fully-masked KV blocks with pl.when —
on TPU the skipped grid step costs only the (tiny) control iteration, so
causal attention does ~half the MXU work of the masked dense loop (this
is the kernel counterpart of the jnp path's `causal_skip`).

Block sizes default to (256 q x 512 kv) x d_head<=128: working set
~(256+512)*128*2B for q/k/v tiles + 256*128*4B acc ~= 0.5 MiB, far under
the ~16 MiB VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, bq: int, bk: int, nk: int, scale: float):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (not causal) or (j * bk <= i * bq + bq - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 256,
                    bk: int = 512, interpret: bool = True):
    """q,k,v: (BH, S, D) with KV already group-expanded. Returns (BH,S,D)."""
    import math
    BH, S, D = q.shape
    Skv = k.shape[1]
    bq = math.gcd(S, min(bq, S))  # largest block <= bq that divides S
    bk = math.gcd(Skv, min(bk, Skv))
    assert S % bq == 0 and Skv % bk == 0, (S, bq, Skv, bk)
    nq, nk = S // bq, Skv // bk
    kern = functools.partial(_kernel, causal=causal, bq=bq, bk=bk, nk=nk,
                             scale=D ** -0.5)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

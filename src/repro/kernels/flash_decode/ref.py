"""Oracle for the GQA flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_ref(q, k_cache, v_cache, pos):
    """q: (B, Hq, D); caches: (B, S, Hkv, D); attend to positions <= pos."""
    B, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    k = jnp.repeat(k_cache.transpose(0, 2, 1, 3), G, axis=1)  # (B,H,S,D)
    v = jnp.repeat(v_cache.transpose(0, 2, 1, 3), G, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    s = jnp.where(jnp.arange(S)[None, None, :] <= pos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)

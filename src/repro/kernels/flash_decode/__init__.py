"""Single-token decode attention kernel with GQA (beyond-paper stack)."""
from repro.kernels.flash_decode.flash_decode import flash_decode  # noqa: F401
from repro.kernels.flash_decode.ref import decode_ref  # noqa: F401

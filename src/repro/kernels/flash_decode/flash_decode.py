"""Pallas TPU flash-decode: one query token vs a long KV cache.

Decode is memory-bound: the whole job is streaming the KV cache through
VMEM once at full HBM bandwidth. Grid (B*Hkv, nk): for each KV head the
G = Hq/Hkv grouped query rows ride along as a (G, D) tile, so GQA
expansion happens in-register instead of materializing repeated KV in
HBM (the decisive difference from the GPU kernel, which shuffles within
a warp; see DESIGN.md §Adaptations). Positions beyond `pos` are masked,
which also makes the kernel safe for ring-buffer caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bk: int, nk: int, scale: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0]
    # skip blocks entirely beyond the valid prefix
    @pl.when(j * bk <= pos)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (G, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode(q, k_cache, v_cache, pos, bk: int = 512,
                 interpret: bool = True):
    """q: (B, Hq, D); caches: (B, S, Hkv, D); pos: scalar int32.

    Returns (B, Hq, D)."""
    B, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    qg = q.reshape(B, Hkv, G, D).reshape(B * Hkv, G, D)
    kc = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vc = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))

    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, nk=nk, scale=D ** -0.5),
        grid=(B * Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qg, kc, vc)
    return out.reshape(B, H, D)

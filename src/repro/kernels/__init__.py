"""Pallas TPU kernels for the reproduction's compute hot spots.

Each kernel lives in its own subpackage with three files:

  <name>.py  the Pallas kernel (and its tiling/fusion rationale)
  ops.py     the jit'd public entry points
  ref.py     the pure-jnp oracle the kernel must match bit-for-bit or
             within dtype tolerance (tests/test_kernels.py)

Subpackages:

- proximity: the paper's §5.1 hot spot — fused toroidal-distance +
  range-test + per-sender LP histogram. Two variants: a dense O(N^2)
  sweep (MXU histogram) and a cell-list candidate version (O(N*C),
  fed by repro.core.neighbors). See DESIGN.md §Adaptations.
- flash_attention: tiled online-softmax attention (beyond-paper stack)
- flash_decode: single-token decode attention with GQA
- moe_gate: fused top-k gating for the MoE layer

All kernels accept `interpret=True` (the default used in tests and on
CPU): the kernel body executes per tile on the host, which checks
correctness everywhere but is slow — never benchmark interpret mode
(DESIGN.md §Adaptations, interpret-mode caveat).
"""

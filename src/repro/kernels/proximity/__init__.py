"""Proximity/LP-histogram kernels for the §5.1 hot spot."""
from repro.kernels.proximity.ops import (  # noqa: F401
    proximity_lp_counts, proximity_lp_counts_grid, proximity_lp_counts_ref)

"""Pallas TPU kernel: proximity LP histogram over cell-list candidates.

The dense kernel (proximity.py) sweeps all N^2 pairs; this one only sees
the cell-list candidates produced by core/neighbors.py — each sender's
3x3 neighborhood, a (N, C) gather with C = 9 * cell capacity — so the
work drops from O(N^2) to O(N*C).

The jnp side does the binning and the candidate gather (sort-by-cell is
a global data movement XLA already does well); the kernel fuses what is
per-pair: wrapped per-axis deltas, the range test, validity/sender
masking, and the per-sender LP histogram. Unlike the dense kernel the
histogram cannot ride the MXU here — candidate LPs differ per *row*, so
there is no shared (BJ, L) one-hot operand — instead the kernel keeps
the candidate LP tile in VMEM and does L masked VPU reductions (L is
tiny: the paper uses 4–9 LPs). See DESIGN.md §Adaptations.

Grid: (N/BI, C/BC); the candidate-tile loop is the innermost
(sequential) dim so each sender tile's accumulator stays resident in
VMEM across its whole candidate sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.neighbors import GridSpec, candidate_table

BI = 256  # sender tile (rows)
BC = 256  # candidate tile (cols)


def _kernel(px_ref, py_ref, sender_ref, cx_ref, cy_ref, clp_ref, valid_ref,
            out_ref, *, area: float, rng2: float, n_lp_pad: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dx = jnp.abs(px_ref[...] - cx_ref[...])  # (BI, BC)
    dy = jnp.abs(py_ref[...] - cy_ref[...])
    dx = jnp.minimum(dx, area - dx)
    dy = jnp.minimum(dy, area - dy)
    within = (dx * dx + dy * dy) <= rng2
    mask = (within.astype(jnp.float32) * valid_ref[...]
            * sender_ref[...])  # (BI, BC) in {0, 1}
    clp = clp_ref[...]
    # per-row candidate LPs -> no shared one-hot operand for the MXU;
    # L masked VPU reductions instead (L is single-digit)
    cols = [jnp.sum(mask * (clp == l), axis=1, keepdims=True)
            for l in range(n_lp_pad)]
    out_ref[...] += jnp.concatenate(cols, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("n_lp", "area", "rng", "spec",
                                    "interpret"))
def proximity_lp_counts_grid(pos, lp, sender_mask, n_lp: int, area: float,
                             rng: float, spec: GridSpec,
                             interpret: bool = True):
    """Grid-candidate twin of proximity_lp_counts — bit-identical counts.

    `spec` must satisfy the cell-list contract (cell side >= rng,
    capacity >= max cell occupancy); use neighbors.make_grid_spec.
    """
    n = pos.shape[0]
    cand, _ = candidate_table(pos, spec)  # (N, 9 * capacity)
    valid = (cand >= 0) & (cand != jnp.arange(n, dtype=jnp.int32)[:, None])
    j = jnp.clip(cand, 0, n - 1)
    cx, cy = pos[j, 0], pos[j, 1]  # (N, C)
    clp = lp[j].astype(jnp.float32)

    bi, bc = min(BI, n), min(BC, cand.shape[1])
    pad_n = -n % bi
    pad_c = -cand.shape[1] % bc
    pad2 = lambda a, v: jnp.pad(a, ((0, pad_n), (0, pad_c)),
                                constant_values=v)
    cx, cy, clp = pad2(cx, 0.0), pad2(cy, 0.0), pad2(clp, 0.0)
    valid = pad2(valid.astype(jnp.float32), 0.0)
    px = jnp.pad(pos[:, 0:1], ((0, pad_n), (0, 0)))
    py = jnp.pad(pos[:, 1:2], ((0, pad_n), (0, 0)))
    sender = jnp.pad(sender_mask.astype(jnp.float32)[:, None],
                     ((0, pad_n), (0, 0)))
    np_, cp = n + pad_n, cand.shape[1] + pad_c
    lp_pad = max(n_lp, 8)

    out = pl.pallas_call(
        functools.partial(_kernel, area=float(area), rng2=float(rng) ** 2,
                          n_lp_pad=lp_pad),
        grid=(np_ // bi, cp // bc),
        in_specs=[
            pl.BlockSpec((bi, 1), lambda i, c: (i, 0)),
            pl.BlockSpec((bi, 1), lambda i, c: (i, 0)),
            pl.BlockSpec((bi, 1), lambda i, c: (i, 0)),
            pl.BlockSpec((bi, bc), lambda i, c: (i, c)),
            pl.BlockSpec((bi, bc), lambda i, c: (i, c)),
            pl.BlockSpec((bi, bc), lambda i, c: (i, c)),
            pl.BlockSpec((bi, bc), lambda i, c: (i, c)),
        ],
        out_specs=pl.BlockSpec((bi, lp_pad), lambda i, c: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, lp_pad), jnp.float32),
        interpret=interpret,
    )(px, py, sender, cx, cy, clp, valid)
    return out[:n, :n_lp].astype(jnp.int32)

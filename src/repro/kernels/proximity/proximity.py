"""Pallas TPU kernel: fused toroidal-distance + range test + LP histogram.

The paper's hot spot is O(N^2) proximity interaction matching (§5.1);
this kernel tiles SEs into (BI x BJ) blocks held in VMEM, computes the
wrapped per-axis deltas on the VPU, and accumulates the per-sender LP
histogram as a masked (BI x BJ) @ (BJ x L) matmul on the MXU — so the
histogram reduction rides the systolic array rather than scatter units
(the GPU-native formulation would use atomics; see DESIGN.md
§Adaptations).

Grid: (N/BI, N/BJ); the j-loop is the innermost (sequential) dim so the
accumulator tile stays resident in VMEM across the whole j sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BI = 256  # tile side: sender rows and recipient cols share it


def _kernel(pos_i_ref, pos_j_ref, lp_onehot_ref, sender_ref, iota_i_ref,
            iota_j_ref, out_ref, *, area: float, rng2: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pi = pos_i_ref[...]  # (BI, 2)
    pj = pos_j_ref[...]  # (BJ, 2)
    dx = jnp.abs(pi[:, 0:1] - pj[:, 0:1].T)  # (BI, BJ)
    dy = jnp.abs(pi[:, 1:2] - pj[:, 1:2].T)
    dx = jnp.minimum(dx, area - dx)
    dy = jnp.minimum(dy, area - dy)
    within = (dx * dx + dy * dy) <= rng2
    not_self = iota_i_ref[...][:, 0:1] != iota_j_ref[...][:, 0:1].T
    sender = sender_ref[...][:, 0:1] != 0
    mask = (within & not_self & sender).astype(jnp.float32)
    # LP histogram on the MXU: (BI,BJ) @ (BJ,L)
    out_ref[...] += jnp.dot(mask, lp_onehot_ref[...],
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_lp", "area", "rng",
                                             "interpret"))
def proximity_lp_counts(pos, lp, sender_mask, n_lp: int, area: float,
                        rng: float, interpret: bool = True):
    n = pos.shape[0]
    bi = bj = min(BI, n)
    pad = -n % bi
    # pad to a whole number of tiles: padded recipients get lp = -1 (an
    # all-zero one-hot row, so they never count); padded senders are 0
    pos = jnp.pad(pos, ((0, pad), (0, 0)))
    lp = jnp.pad(lp, (0, pad), constant_values=-1)
    sender_mask = jnp.pad(sender_mask, (0, pad))
    np_ = n + pad
    lp_pad = max(n_lp, 8)
    onehot = jax.nn.one_hot(lp, lp_pad, dtype=jnp.float32)
    iota = jnp.arange(np_, dtype=jnp.int32)[:, None]
    sender = sender_mask.astype(jnp.int32)[:, None]

    out = pl.pallas_call(
        functools.partial(_kernel, area=float(area), rng2=float(rng) ** 2),
        grid=(np_ // bi, np_ // bj),
        in_specs=[
            pl.BlockSpec((bi, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bj, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((bj, lp_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((bi, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bi, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bi, lp_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, lp_pad), jnp.float32),
        interpret=interpret,
    )(pos, pos, onehot, sender, iota, iota)
    return out[:n, :n_lp].astype(jnp.int32)

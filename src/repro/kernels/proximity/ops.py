"""jit'd public wrappers for the proximity kernels.

proximity_lp_counts       dense-sweep kernel (O(N^2) pairs, MXU histogram)
proximity_lp_counts_grid  cell-list kernel (O(N*C) candidate pairs)
proximity_lp_counts_ref   pure-jnp oracle
"""
from repro.kernels.proximity.grid import proximity_lp_counts_grid  # noqa: F401
from repro.kernels.proximity.proximity import proximity_lp_counts  # noqa: F401
from repro.kernels.proximity.ref import proximity_lp_counts_ref  # noqa: F401

"""jit'd public wrapper for the proximity kernel."""
from repro.kernels.proximity.proximity import proximity_lp_counts  # noqa: F401
from repro.kernels.proximity.ref import proximity_lp_counts_ref  # noqa: F401

"""Pure-jnp oracle for the proximity/LP-histogram kernels.

Delegates to the single canonical dense implementation in
repro.core.neighbors so the parity contract has exactly one source of
truth for the per-pair math.
"""
from __future__ import annotations

from repro.core.neighbors import dense_lp_counts


def proximity_lp_counts_ref(pos, lp, sender_mask, n_lp: int, area: float,
                            rng: float):
    """counts[i, l] = #{j != i : toroidal_dist(i,j) <= rng, lp[j] == l},
    zeroed for non-senders. pos: (N,2) f32; lp: (N,) i32."""
    return dense_lp_counts(pos, lp, sender_mask, n_lp, area, rng)

"""Pure-jnp oracle for the proximity/LP-histogram kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def proximity_lp_counts_ref(pos, lp, sender_mask, n_lp: int, area: float,
                            rng: float):
    """counts[i, l] = #{j != i : toroidal_dist(i,j) <= rng, lp[j] == l},
    zeroed for non-senders. pos: (N,2) f32; lp: (N,) i32."""
    n = pos.shape[0]
    d = jnp.abs(pos[:, None, :] - pos[None, :, :])
    d = jnp.minimum(d, area - d)
    in_range = (d[..., 0] ** 2 + d[..., 1] ** 2) <= rng * rng
    in_range = in_range & ~jnp.eye(n, dtype=bool) & sender_mask[:, None]
    onehot = jax.nn.one_hot(lp, n_lp, dtype=jnp.float32)
    return (in_range.astype(jnp.float32) @ onehot).astype(jnp.int32)

"""Pallas TPU fused MoE gate: softmax + (biased) top-k + expert histogram.

One pass over a (bt, E) logit tile in VMEM produces the top-k weights/
ids (k sequential argmax sweeps on the VPU — k <= 8, E <= 512, so the
sweep is cheap relative to the HBM read of the logits) and accumulates
the per-expert token histogram with a mask matmul on the MXU. Fusing the
histogram in-kernel is what feeds GAIA-MoE its traffic matrix without a
second pass over the routing tensors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logits_ref, bias_ref, top_p_ref, top_e_ref, counts_ref, *,
            k: int, norm_topk: bool, nt: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = logits_ref[...].astype(jnp.float32)  # (bt, E)
    E = x.shape[-1]
    mx = x.max(axis=-1, keepdims=True)
    ex = jnp.exp(x - mx)
    probs = ex / ex.sum(axis=-1, keepdims=True)
    sel = probs + bias_ref[...]

    remaining = sel
    hist = jnp.zeros_like(probs)
    ps, es = [], []
    for _ in range(k):
        idx = remaining.argmax(axis=-1)  # (bt,)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, remaining.shape, 1)
                  == idx[:, None])
        ps.append(jnp.sum(jnp.where(onehot, probs, 0.0), axis=-1))
        es.append(idx.astype(jnp.int32))
        hist = hist + onehot.astype(jnp.float32)
        remaining = jnp.where(onehot, -jnp.inf, remaining)
    top_p = jnp.stack(ps, axis=-1)  # (bt, k)
    if norm_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    top_p_ref[...] = top_p
    top_e_ref[...] = jnp.stack(es, axis=-1)
    counts_ref[...] += hist.sum(axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("k", "norm_topk", "bt",
                                             "interpret"))
def moe_gate(logits, k: int, bias=None, norm_topk: bool = True,
             bt: int = 512, interpret: bool = True):
    """logits: (T, E) f32 -> (top_p (T,k) f32, top_e (T,k) i32,
    counts (E,) i32)."""
    T, E = logits.shape
    bt = min(bt, T)
    assert T % bt == 0, (T, bt)
    nt = T // bt
    if bias is None:
        bias = jnp.zeros((E,), jnp.float32)
    top_p, top_e, counts = pl.pallas_call(
        functools.partial(_kernel, k=k, norm_topk=norm_topk, nt=nt),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((bt, E), lambda t: (t, 0)),
            pl.BlockSpec((1, E), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, k), lambda t: (t, 0)),
            pl.BlockSpec((bt, k), lambda t: (t, 0)),
            pl.BlockSpec((1, E), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.float32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((1, E), jnp.float32),
        ],
        interpret=interpret,
    )(logits, bias[None, :])
    return top_p, top_e, counts[0].astype(jnp.int32)

"""Fused top-k MoE gating kernel (beyond-paper stack)."""
from repro.kernels.moe_gate.moe_gate import moe_gate  # noqa: F401
from repro.kernels.moe_gate.ref import moe_gate_ref  # noqa: F401

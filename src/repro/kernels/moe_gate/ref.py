"""Oracle for the fused MoE gate (softmax + top-k + histogram)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gate_ref(logits, k: int, bias=None, norm_topk: bool = True):
    """logits: (T, E) f32. Returns (top_p (T,k), top_e (T,k), counts (E,))."""
    probs = jax.nn.softmax(logits, axis=-1)
    sel = probs if bias is None else probs + bias[None, :]
    _, top_e = jax.lax.top_k(sel, k)
    top_p = jnp.take_along_axis(probs, top_e, axis=-1)
    if norm_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    counts = jnp.bincount(top_e.reshape(-1), length=logits.shape[-1])
    return top_p, top_e.astype(jnp.int32), counts.astype(jnp.int32)

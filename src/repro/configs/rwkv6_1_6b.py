"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay
[arXiv:2404.05892]. Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, chunk=128, decay_lora=64, mix_lora=32),
    supports_long_context=True,
    source="arXiv:2404.05892",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256,
        rwkv=RWKVConfig(head_dim=16, chunk=16, decay_lora=8, mix_lora=4),
        supports_long_context=True,
    )

"""SeamlessM4T-medium — encoder-decoder multimodal backbone
[arXiv:2308.11596; hf]. The speech/text frontend is a STUB:
input_specs() provides precomputed frame embeddings for the encoder.
n_layers applies to each of encoder and decoder (12 + 12).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    encoder_decoder=True, embed_frontend=True,
    source="arXiv:2308.11596",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="seamless-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256,
        encoder_decoder=True, embed_frontend=True,
    )

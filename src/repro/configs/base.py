"""Architecture / shape / run configuration for the repro framework.

Every assigned architecture gets one module in this package defining a
``CONFIG`` (full published scale, exercised only via the dry-run) and a
``smoke_config()`` (reduced same-family config that runs one real step on
CPU in the test suite).

The shape grid (train_4k / prefill_32k / decode_32k / long_500k) is shared
by all LM-family architectures; per-arch applicability of ``long_500k`` is
recorded on the config (``supports_long_context``) and documented in
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (seq_len x global_batch + which step it lowers)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_expert: int = 0  # per-expert FFN hidden dim
    num_shared_experts: int = 0
    d_shared: int = 0  # shared-expert FFN hidden dim
    first_k_dense: int = 0  # leading layers that stay dense (DeepSeek-V3)
    capacity_factor: float = 1.25
    norm_topk_prob: bool = True
    # aux-loss-free balancing (DeepSeek-V3): learned per-expert bias added to
    # routing scores, updated outside the gradient.
    aux_free_bias: bool = True


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block parameters (Zamba2)."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    chunk: int = 128
    # decay LoRA ranks (RWKV-6 "Finch" data-dependent decay)
    decay_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- family-specific sub-configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # encoder-decoder (seamless-m4t): n_layers applies to each side
    encoder_decoder: bool = False
    # hybrid (zamba2): shared attention block applied every `shared_every`
    # mamba layers, weights shared across invocations.
    shared_every: int = 0
    # vlm: number of vision-frontend tokens prepended (patch embeds are a stub)
    n_vision_tokens: int = 0
    # audio/vlm stub frontend: inputs are precomputed frame/patch embeddings
    embed_frontend: bool = False
    # multi-token prediction depth (DeepSeek-V3 MTP) — extra loss head
    mtp_depth: int = 0
    # does full attention appear anywhere? (decides long_500k applicability)
    supports_long_context: bool = False
    has_decoder: bool = True
    source: str = ""

    # ---------------- derived -----------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards 16-way cleanly."""
        v = self.vocab_size
        return ((v + 255) // 256) * 256

    def shapes(self) -> Tuple[str, ...]:
        """Shape cells applicable to this architecture."""
        cells = ["train_4k", "prefill_32k"]
        if self.has_decoder:
            cells.append("decode_32k")
            if self.supports_long_context:
                cells.append("long_500k")
        return tuple(cells)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for reporting
        and for the MODEL_FLOPS roofline term."""
        d, v = self.d_model, self.padded_vocab
        n = v * d * (1 if self.tie_embeddings else 2)  # embed + lm head
        n += self._block_params() * self.n_layers * (2 if self.encoder_decoder else 1)
        if self.shared_every:
            n += self._attn_params() + 3 * self.d_model * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        dense_block = self._attn_params()
        act = self.padded_vocab * d * 2
        routed = 3 * d * m.d_expert * m.top_k
        shared = 3 * d * m.d_shared * m.num_shared_experts
        router = d * m.num_experts
        moe_layers = self.n_layers - m.first_k_dense
        act += moe_layers * (dense_block + routed + shared + router)
        act += m.first_k_dense * (dense_block + 3 * d * self.d_ff)
        return act

    def _attn_params(self) -> int:
        d = self.d_model
        hd = self.resolved_head_dim
        if self.mla is not None:
            c = self.mla
            qh = c.qk_nope_head_dim + c.qk_rope_head_dim
            p = d * c.q_lora_rank + c.q_lora_rank * self.n_heads * qh
            p += d * (c.kv_lora_rank + c.qk_rope_head_dim)
            p += c.kv_lora_rank * self.n_heads * (c.qk_nope_head_dim + c.v_head_dim)
            p += self.n_heads * c.v_head_dim * d
            return p
        if self.family == "ssm" and self.rwkv is not None:
            # rwkv6 time-mix: r,k,v,g,o projections + decay loras
            return (5 * d * d + d * self.rwkv.decay_lora * 2
                    + 5 * d * self.rwkv.mix_lora * 2)
        if self.ssm is not None:
            di = self.ssm.expand * d
            return d * (2 * di + 2 * self.n_heads * self.ssm.d_state) + di * d
        return d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

    def _block_params(self) -> int:
        d = self.d_model
        if self.ssm is not None:
            # hybrid (zamba2): mamba blocks carry no MLP; d_ff lives in the
            # shared attention block, counted once in param_count().
            return self._attn_params()
        if self.family == "ssm" and self.rwkv is not None:
            return self._attn_params() + 2 * d * self.d_ff + d * self.d_ff
        if self.moe is not None:
            m = self.moe
            ff = (3 * d * m.d_expert * m.num_experts
                  + 3 * d * m.d_shared * m.num_shared_experts)
            ff += d * m.num_experts
            return self._attn_params() + ff
        return self._attn_params() + 3 * d * self.d_ff


# registry is populated by repro.configs.__init__

"""DeepSeek-V3 671B — MLA + 1 shared / 256 routed top-8 MoE + MTP
[arXiv:2412.19437; hf].

d_ff=2048 is the per-expert hidden dim; the first 3 layers use a dense
FFN of 18432 (per the released config). MTP depth 1.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab_size=129280, rope_theta=1e4,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                  num_shared_experts=1, d_shared=2048, first_k_dense=3,
                  norm_topk_prob=True, aux_free_bias=True),
    mtp_depth=1,
    source="arXiv:2412.19437",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=32,
                      num_shared_experts=1, d_shared=32, first_k_dense=1,
                      aux_free_bias=True),
        mtp_depth=1,
    )

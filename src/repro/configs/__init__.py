"""Config registry: ``get_arch(name)`` / ``ARCHS`` / ``SHAPES``.

Each assigned architecture lives in its own module with a full-scale
``CONFIG`` and a reduced ``smoke_config()``.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES  # noqa: F401

_ARCH_MODULES = (
    "yi_9b",
    "tinyllama_1_1b",
    "yi_6b",
    "qwen2_7b",
    "qwen3_moe_30b_a3b",
    "deepseek_v3_671b",
    "rwkv6_1_6b",
    "internvl2_2b",
    "seamless_m4t_medium",
    "zamba2_1_2b",
)

ARCHS: Dict[str, ArchConfig] = {}
_SMOKES = {}

for _m in _ARCH_MODULES:
    mod = importlib.import_module(f"repro.configs.{_m}")
    ARCHS[mod.CONFIG.name] = mod.CONFIG
    _SMOKES[mod.CONFIG.name] = mod.smoke_config


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke(name: str) -> ArchConfig:
    return _SMOKES[name]()


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]

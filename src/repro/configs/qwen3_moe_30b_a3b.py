"""Qwen3-30B-A3B — 128-expert top-8 MoE, GQA [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab_size=151936, rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=768,
                  num_shared_experts=0, d_shared=0,
                  norm_topk_prob=True, aux_free_bias=False),
    source="hf:Qwen/Qwen3-30B-A3B",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=256,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=64,
                      norm_topk_prob=True, aux_free_bias=False),
    )

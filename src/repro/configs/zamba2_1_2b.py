"""Zamba2-1.2B — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]. Hybrid: runs the long_500k cell (attention KV
is sequence-sharded at decode). The shared transformer block (full
attention + MLP, weights shared across invocations) is applied every
6 mamba layers; the per-invocation LoRA adapters of the released model
are omitted (see DESIGN.md §Adaptations).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    shared_every=6, supports_long_context=True,
    source="arXiv:2411.15242",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
        shared_every=2, supports_long_context=True,
    )

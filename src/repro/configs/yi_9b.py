"""Yi-9B — llama-arch dense GQA [arXiv:2403.04652; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab_size=64000, rope_theta=1e4,
    source="arXiv:2403.04652",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="yi-9b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256,
    )

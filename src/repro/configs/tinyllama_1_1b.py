"""TinyLlama-1.1B — llama2-arch small dense GQA [arXiv:2401.02385; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab_size=32000, rope_theta=1e4,
    source="arXiv:2401.02385",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="tinyllama-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256,
    )

"""Qwen2-7B — dense GQA with QKV bias [arXiv:2407.10671; hf].

28 heads do not divide the 16-way model axis; the sharding layer
falls back to sequence-sharded attention for this arch (see
repro/parallel/sharding.py and DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    source="arXiv:2407.10671",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-smoke", family="dense",
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8,
        d_ff=128, vocab_size=256, qkv_bias=True,
    )

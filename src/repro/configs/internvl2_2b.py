"""InternVL2-2B — InternViT frontend (STUB) + InternLM2-1.8B backbone
[arXiv:2404.16821; hf]. input_specs() provides precomputed patch
embeddings; the LM backbone is implemented in full.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92553, rope_theta=1e6,
    n_vision_tokens=256, embed_frontend=True,
    source="arXiv:2404.16821",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, n_vision_tokens=8, embed_frontend=True,
    )

"""Mamba2 SSD block [arXiv:2405.21060] — chunked scan, TPU-native.

The SSD recurrence has a *scalar* per-head decay, so the chunked form is
pure matmuls (MXU-friendly), unlike RWKV6's per-channel decay:

    h_t = a_t h_{t-1} + (b_t x_t^T)        h: (P, N) per head
    y_t = c_t^T h_t + D x_t

Chunked (chunk c, A = cumsum(log a)):
    intra:  Y = ((C B^T) . L) X        L[t,i] = exp(A_t - A_i), i <= t
    inter:  Y += (C . exp(A)) h_0
    state:  h_c = exp(A_c) h_0 + sum_i exp(A_c - A_i) b_i x_i^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DT, _init, init_rmsnorm, rmsnorm
from repro.parallel.ctx import ParallelCtx


def init_mamba2(key, d: int, cfg):
    s = cfg.ssm
    di = s.expand * d
    H = di // s.head_dim
    ks = jax.random.split(key, 5)
    return {
        "ln": init_rmsnorm(d),
        # fused in_proj: [z (di), x (di), B (N), C (N), dt (H)]
        "w_in": _init(ks[0], (d, 2 * di + 2 * s.d_state + H)),
        "conv_w": _init(ks[1], (s.d_conv, di + 2 * s.d_state), scale=0.5),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "ln_y": init_rmsnorm(di),
        "w_out": _init(ks[2], (di, d)),
    }


def _ssd_chunked(xh, bh, ch, dt, A_log, h0, chunk: int, unroll: bool = False):
    """xh: (B,S,H,P); bh,ch: (B,S,N); dt: (B,S,H); h0: (B,H,P,N)."""
    B, S, H, P = xh.shape
    N = bh.shape[-1]
    c = min(chunk, S)
    nc = S // c
    a = -jnp.exp(A_log)[None, None, :] * dt  # log decay (B,S,H), <= 0
    xs = (xh * dt[..., None]).reshape(B, nc, c, H, P).transpose(1, 0, 3, 2, 4)
    bs = bh.reshape(B, nc, c, N).transpose(1, 0, 2, 3)
    cs = ch.reshape(B, nc, c, N).transpose(1, 0, 2, 3)
    As = a.reshape(B, nc, c, H).transpose(1, 0, 3, 2)  # (nc,B,H,c)

    def step(h, inp):
        xc, bc, cc, ac = inp  # (B,H,c,P), (B,c,N), (B,c,N), (B,H,c)
        Ac = jnp.cumsum(ac, axis=-1)  # (B,H,c)
        # intra-chunk
        cb = jnp.einsum("btn,bin->bti", cc, bc)[:, None, :, :]  # (B,1,c,c)
        L = jnp.exp(Ac[:, :, :, None] - Ac[:, :, None, :])
        L = jnp.where(jnp.tril(jnp.ones((c, c), bool))[None, None], L, 0.0)
        y = jnp.einsum("bhti,bhip->bhtp", cb * L, xc)
        # inter-chunk (state h enters each position with decay exp(A_t))
        y += jnp.einsum("btn,bhpn,bht->bhtp", cc, h, jnp.exp(Ac))
        # state update
        decay_to_end = jnp.exp(Ac[:, :, -1:] - Ac)  # (B,H,c)
        h = jnp.exp(Ac[:, :, -1])[..., None, None] * h + jnp.einsum(
            "bhtp,btn,bht->bhpn", xc, bc, decay_to_end)
        return h, y

    inp = (xs.astype(jnp.float32), bs.astype(jnp.float32),
           cs.astype(jnp.float32), As.astype(jnp.float32))
    if unroll:
        h = h0.astype(jnp.float32)
        ylist = []
        for i in range(nc):
            h, yi = step(h, tuple(t[i] for t in inp))
            ylist.append(yi)
        ys = jnp.stack(ylist, axis=0)
    else:
        h, ys = jax.lax.scan(step, h0.astype(jnp.float32), inp)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, P)
    return y, h


def mamba2_fwd(p, x, carry, *, cfg, px: ParallelCtx, batch_entry,
               decode: bool = False):
    """x: (B,S,d). carry: dict(ssm (B,H,P,N), conv (B,d_conv-1,ch)).

    decode=True runs the exact single-step recurrence (S must be 1).
    """
    s = cfg.ssm
    B, S, D = x.shape
    di = s.expand * D
    H = di // s.head_dim
    P, N = s.head_dim, s.d_state
    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", xn, p["w_in"].astype(COMPUTE_DT))
    z, xr, bc, dt = jnp.split(proj, [di, 2 * di, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xr, bc], axis=-1)  # (B,S,di+2N)

    # causal depthwise conv over the sequence, with carried tail state
    tail = carry["conv"]  # (B, d_conv-1, ch)
    seq = jnp.concatenate([tail.astype(COMPUTE_DT), conv_in], axis=1)
    kw = p["conv_w"].astype(COMPUTE_DT)  # (d_conv, ch)
    conv = sum(seq[:, i:i + S, :] * kw[i][None, None, :]
               for i in range(s.d_conv))
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(COMPUTE_DT)
    new_tail = seq[:, S:S + s.d_conv - 1, :]

    xr, bh, ch = jnp.split(conv, [di, di + N], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    xh = xr.reshape(B, S, H, P)
    h_entry = px.shard_if(H, px.model_axis)
    xh = px.constrain(xh, batch_entry, None, h_entry, None)

    if decode:
        a = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dtv[:, 0])  # (B,H)
        h0 = carry["ssm"].astype(jnp.float32)
        kv = jnp.einsum("bhp,bn->bhpn",
                        (xh[:, 0] * dtv[:, 0, :, None]).astype(jnp.float32),
                        bh[:, 0].astype(jnp.float32))
        h1 = a[..., None, None] * h0 + kv
        y = jnp.einsum("bn,bhpn->bhp", ch[:, 0].astype(jnp.float32), h1)
        y = y[:, None].reshape(B, 1, H, P)
        hS = h1
    else:
        y, hS = _ssd_chunked(xh, bh, ch, dtv, p["A_log"], carry["ssm"],
                             s.chunk, unroll=px.scan_unroll)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(COMPUTE_DT)
    y = rmsnorm(p["ln_y"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DT)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(COMPUTE_DT))
    out = px.constrain(out, batch_entry, None, None)
    return x + out, {"ssm": hS, "conv": new_tail}

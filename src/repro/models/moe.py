"""Mixture-of-Experts FFN with expert parallelism and GAIA placement hooks.

Dispatch is sort-based ("dropping" style, as in MaxText): token/expert
slots are ranked within their expert segment and slots beyond the static
capacity are dropped. The (E, C, d) slot buffer is sharded over the model
axis on E (expert parallelism); the gather from data-sharded tokens into
expert-sharded slots is where GSPMD materializes the all-to-all.

GAIA integration (the paper's self-clustering, adapted — see
repro/core/gaia_moe.py): ``placement`` is a permutation of experts to
EP ranks. The layer applies it by permuting the router's expert ids, so
hot experts migrate between shards without touching weight layouts; the
per-(shard, expert) traffic statistics the heuristic needs come back in
the metrics dict.

aux-loss-free balancing (DeepSeek-V3): a non-gradient per-expert bias is
added to the routing scores for selection only; its update happens in the
train step from the returned counts.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DT, _init
from repro.parallel.ctx import ParallelCtx


def init_moe(key, d: int, cfg_moe):
    m = cfg_moe
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, m.num_experts), scale=0.02,
                        dtype=jnp.float32),
        "w_gate": _init(ks[1], (m.num_experts, d, m.d_expert)),
        "w_up": _init(ks[2], (m.num_experts, d, m.d_expert)),
        "w_down": _init(ks[3], (m.num_experts, m.d_expert, d)),
    }
    if m.num_shared_experts:
        f = m.d_shared * m.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _init(kk[0], (d, f)),
            "w_up": _init(kk[1], (d, f)),
            "w_down": _init(kk[2], (f, d)),
        }
    return p


def _capacity(tokens: int, m) -> int:
    c = int(m.capacity_factor * tokens * m.top_k / m.num_experts)
    return max(8, (c + 7) // 8 * 8)


def moe_fwd(p, x, *, m, px: ParallelCtx, batch_entry,
            router_bias: Optional[jax.Array] = None,
            placement: Optional[jax.Array] = None):
    """x: (B, S, d). Returns (out, metrics).

    router_bias: (E,) aux-free balancing bias (selection only, no grad).
    placement: (E,) permutation: expert e is served by slot placement[e]
      (GAIA expert migration — reorders segments in the (E,C,d) buffer).
    """
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    T = B * S
    C = _capacity(T, m)
    # Flattening (B@data, S@model[SP], D) into (T, D) would force GSPMD to
    # materialize batch-unsharded compromises; move the model axis to D
    # first so every dispatch intermediate stays (lead@data, D@model).
    x = px.constrain(x, batch_entry, None, px.shard_if(D, px.model_axis))
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    select = probs if router_bias is None else probs + jax.lax.stop_gradient(
        router_bias)[None, :]
    _, top_e = jax.lax.top_k(select, K)  # (T, K) expert ids
    top_p = jnp.take_along_axis(probs, top_e, axis=-1)
    if m.norm_topk_prob:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    top_p = top_p.astype(COMPUTE_DT)

    if placement is not None:
        # GAIA expert migration: placement[e] = buffer segment serving
        # expert e. Weights are STORED in segment order (w_gate[s] holds
        # the weights of the expert currently placed on segment s), so the
        # per-step graph only remaps routing ids — the physical weight
        # movement (MigComm, Eq. 6) happens once per migration event in
        # gaia_moe.apply_migration, exactly like the paper's serialized
        # SE-state transfer, NOT as a per-step gather.
        seg_e = placement[top_e]
    else:
        seg_e = top_e
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]

    # ---- grouped sort-based dispatch ------------------------------------
    # One group per data shard: every sort/scatter below is batched over
    # the (sharded) group dim and therefore device-local. The only
    # cross-device movement is the (G,E,C,D) buffer constraint — which is
    # exactly the MoE all-to-all.
    ep_axes = px.ep_axes
    use_2d = (px.ep2d and ep_axes is not None
              and E % px.axis_size(ep_axes) == 0)
    if use_2d:
        # 2-D EP: one global dispatch group; the (E, C, D) slot buffer
        # shards E over (data x model) jointly, so expert weights are
        # never gathered — tokens travel (the all-to-all), weights don't.
        G = 1
        g_entry = None
        e_entry = ep_axes
    else:
        G = px.axis_size(batch_entry) if batch_entry is not None else 1
        g_entry = batch_entry
        e_entry = px.shard_if(E, px.model_axis)
    Tg = T // G
    C = max(2 * K, _capacity(Tg, m))
    # The per-group scatter buffer (E*C+1, D) is large (E*C can exceed Tg
    # by the capacity slack); keep its D dim model-sharded until the
    # (G,E,C,D) constraint flips the sharding to expert-parallel — this is
    # a (D-shard -> E-shard) all-to-all instead of materializing the full
    # buffer per device.
    d_entry = px.shard_if(D, px.model_axis)

    def grp(x):
        return x.reshape(G, Tg, *x.shape[1:])

    flat_e = grp(seg_e).reshape(G, Tg * K)
    flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(Tg), K), (G, Tg * K))
    flat_w = grp(top_p).reshape(G, Tg * K)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, -1)
    st = jnp.take_along_axis(flat_t, order, -1)
    sw = jnp.take_along_axis(flat_w, order, -1)
    counts = jax.vmap(lambda fe: jnp.bincount(fe, length=E))(flat_e)
    seg_start = jnp.cumsum(counts, -1) - counts
    pos_in_e = (jnp.arange(Tg * K, dtype=jnp.int32)[None, :]
                - jnp.take_along_axis(seg_start, se, -1).astype(jnp.int32))
    keep = pos_in_e < C
    dest = jnp.where(keep, se * C + pos_in_e, E * C)

    xg = px.constrain(grp(xt), g_entry, None, d_entry)  # (G, Tg, D)
    scatter = jax.vmap(
        lambda d_, t_, x_: jnp.zeros((E * C + 1, D), COMPUTE_DT)
        .at[d_].set(x_[t_]))
    buf = px.constrain(scatter(dest, st, xg), g_entry, None, d_entry)
    h = buf[:, : E * C].reshape(G, E, C, D)
    h = px.constrain(h, g_entry, e_entry, None, None)  # <- the all-to-all

    # ---- expert FFN (SwiGLU): E over model axis; under fsdp the weights
    # are additionally d-sharded over data and gathered just-in-time ----
    g_ = jnp.einsum("gecd,edf->gecf", h, w_gate.astype(COMPUTE_DT))
    u = jnp.einsum("gecd,edf->gecf", h, w_up.astype(COMPUTE_DT))
    g_ = px.constrain(g_, g_entry, e_entry, None, None)
    hmid = jax.nn.silu(g_.astype(jnp.float32)).astype(COMPUTE_DT) * u
    y = jnp.einsum("gecf,efd->gecd", hmid, w_down.astype(COMPUTE_DT))
    y = px.constrain(y, g_entry, e_entry, None, None)

    # ---- combine (reverse all-to-all + weighted scatter-add) ------------
    y_flat = y.reshape(G, E * C, D)
    y_flat = px.constrain(y_flat, g_entry, None, d_entry)
    safe = jnp.minimum(dest, E * C - 1)
    gather = jax.vmap(lambda yf, d_: yf[d_])
    contrib = jnp.where(keep[..., None],
                        sw[..., None] * gather(y_flat, safe), 0.0)
    out = jax.vmap(
        lambda t_, c_: jnp.zeros((Tg, D), COMPUTE_DT).at[t_].add(c_))(
        st, contrib)
    out = px.constrain(out, g_entry, None, d_entry).reshape(B, S, D)
    out = px.constrain(out, batch_entry, px.seq_entry(S), None)
    # (G, E) traffic by *segment*; re-index to expert ids for GAIA/bias
    # (expert e is served by segment placement[e]).
    gcounts = counts if placement is None else counts[:, placement]
    counts = gcounts.sum(0)

    if "shared" in p:
        from repro.models.layers import mlp_fwd
        out = out + mlp_fwd(p["shared"], x, px, batch_entry)

    # load-balance aux loss (switch-style) + routing stats for GAIA
    frac_tokens = counts.astype(jnp.float32) / (T * K)
    frac_probs = probs.mean(axis=0)
    aux_loss = E * jnp.sum(jax.lax.stop_gradient(frac_tokens) * frac_probs)
    dropped = jnp.sum(jnp.where(keep, 0, 1))
    metrics = {
        "expert_counts": jnp.bincount(top_e.reshape(-1), length=E),
        "group_expert_counts": gcounts,
        "moe_aux_loss": aux_loss,
        "moe_dropped": dropped,
    }
    return out, metrics

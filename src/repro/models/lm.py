"""Decoder-only LM assembly: init / loss / prefill / decode for every
decoder-only family (dense, MoE, MLA+MoE, RWKV6, Mamba2-hybrid, VLM).

Layers are stacked (L, ...) and driven by ``lax.scan`` so the compiled
HLO contains one block body regardless of depth; training wraps the body
in ``jax.checkpoint`` (remat). Encoder-decoder (seamless) lives in
repro/models/encdec.py.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models import mamba2 as m2
from repro.models import rwkv6 as r6
from repro.models.layers import (COMPUTE_DT, _init, chunked_xent,
                                 embed_fwd, init_embed, init_rmsnorm,
                                 lm_head_fwd, rmsnorm, softmax_xent)
from repro.parallel.ctx import ParallelCtx

MTP_WEIGHT = 0.3
MOE_AUX_WEIGHT = 1e-2


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(key, cfg) -> Dict[str, Any]:
    if cfg.encoder_decoder:
        from repro.models.encdec import init_encdec
        return init_encdec(key, cfg)
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "embed": init_embed(ks[0], cfg.padded_vocab, cfg.d_model,
                            cfg.tie_embeddings),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if cfg.rwkv is not None:
        lk = jax.random.split(ks[1], cfg.n_layers)
        p["layers"] = jax.vmap(lambda k: r6.init_rwkv_block(k, cfg.d_model, cfg))(lk)
    elif cfg.ssm is not None:  # zamba2 hybrid
        lk = jax.random.split(ks[1], cfg.n_layers)
        p["layers"] = jax.vmap(lambda k: m2.init_mamba2(k, cfg.d_model, cfg))(lk)
        p["shared_block"] = blocks.init_shared_block(ks[2], cfg)
    elif cfg.moe is not None:
        fk = cfg.moe.first_k_dense
        if fk:
            dk = jax.random.split(ks[3], fk)
            p["dense_layers"] = jax.vmap(
                lambda k: blocks.init_tf_block(k, cfg, moe_layer=False))(dk)
        lk = jax.random.split(ks[1], cfg.n_layers - fk)
        p["layers"] = jax.vmap(
            lambda k: blocks.init_tf_block(k, cfg, moe_layer=True))(lk)
    else:
        lk = jax.random.split(ks[1], cfg.n_layers)
        p["layers"] = jax.vmap(
            lambda k: blocks.init_tf_block(k, cfg, moe_layer=False))(lk)
    if cfg.n_vision_tokens:
        p["vision_proj"] = _init(ks[4], (cfg.d_model, cfg.d_model))
    if cfg.mtp_depth:
        p["mtp"] = {
            "proj": _init(ks[5], (2 * cfg.d_model, cfg.d_model)),
            "block": blocks.init_tf_block(ks[6], cfg, moe_layer=False),
            "norm": init_rmsnorm(cfg.d_model),
        }
    return p


def init_extras(cfg) -> Dict[str, Any]:
    """Mutable non-gradient state: aux-free router bias + GAIA placement."""
    if cfg.moe is None:
        return {}
    n_moe = cfg.n_layers - cfg.moe.first_k_dense
    E = cfg.moe.num_experts
    return {
        "router_bias": jnp.zeros((n_moe, E), jnp.float32),
        "placement": jnp.tile(jnp.arange(E, dtype=jnp.int32), (n_moe, 1)),
    }


# ---------------------------------------------------------------------------
# Backbone forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg, px, batch_entry):
    x = embed_fwd(params["embed"], batch["tokens"], px, batch_entry)
    if cfg.n_vision_tokens and "vision_embeds" in batch:
        v = jnp.einsum("bvd,de->bve", batch["vision_embeds"].astype(COMPUTE_DT),
                       params["vision_proj"].astype(COMPUTE_DT))
        x = jnp.concatenate([v, x[:, cfg.n_vision_tokens:, :]], axis=1)
    return px.constrain(x, batch_entry, px.seq_entry(x.shape[1]), None)


def _maybe_remat(fn, px, train):
    if train and px.remat != "none":
        policy = (jax.checkpoint_policies.checkpoint_dots
                  if px.remat == "dots" else None)
        return jax.checkpoint(fn, policy=policy)
    return fn


def backbone_fwd(params, x, cfg, px: ParallelCtx, batch_entry, extras,
                 *, train: bool, collect_cache: bool = False):
    """Returns (h, cache_or_None, metrics)."""
    B, S, _ = x.shape

    if cfg.rwkv is not None:
        H, N = cfg.n_heads, cfg.rwkv.head_dim
        zero = {
            "state": jnp.zeros((B, H, N, N), jnp.float32),
            "shift_a": jnp.zeros((B, cfg.d_model), COMPUTE_DT),
            "shift_f": jnp.zeros((B, cfg.d_model), COMPUTE_DT),
        }

        def body(xcur, p_layer):
            out, carry = r6.rwkv_block_fwd(p_layer, xcur, zero, cfg=cfg,
                                           px=px, batch_entry=batch_entry)
            return out, (carry if collect_cache else 0)

        h, caches = jax.lax.scan(_maybe_remat(body, px, train), x,
                                 params["layers"])
        return h, (caches if collect_cache else None), {}

    if cfg.ssm is not None:  # zamba2
        s = cfg.ssm
        di = s.expand * cfg.d_model
        H = di // s.head_dim
        n_inv = (cfg.n_layers + cfg.shared_every - 1) // cfg.shared_every
        d2 = 2 * cfg.d_model
        hd2 = d2 // cfg.n_heads
        emb0 = x
        zero_m = {
            "ssm": jnp.zeros((B, H, s.head_dim, s.d_state), jnp.float32),
            "conv": jnp.zeros((B, s.d_conv - 1, di + 2 * s.d_state), COMPUTE_DT),
        }
        k_stack = jnp.zeros((n_inv, B, S, cfg.n_kv_heads, hd2), COMPUTE_DT)
        v_stack = jnp.zeros_like(k_stack)

        def body(carry, xs):
            xcur, ks, vs, inv = carry
            p_m, i = xs

            def with_shared(args):
                xc, ks, vs, inv = args
                if collect_cache:
                    xc, kv = blocks.shared_block_fwd(
                        params["shared_block"], xc, emb0, cfg=cfg, px=px,
                        batch_entry=batch_entry, return_kv=True)
                    ks = jax.lax.dynamic_update_slice_in_dim(
                        ks, kv[0].astype(COMPUTE_DT)[None], inv, 0)
                    vs = jax.lax.dynamic_update_slice_in_dim(
                        vs, kv[1].astype(COMPUTE_DT)[None], inv, 0)
                else:
                    xc, _ = blocks.shared_block_fwd(
                        params["shared_block"], xc, emb0, cfg=cfg, px=px,
                        batch_entry=batch_entry)
                return xc, ks, vs, inv + 1

            xcur, ks, vs, inv = jax.lax.cond(
                i % cfg.shared_every == 0, with_shared, lambda a: a,
                (xcur, ks, vs, inv))
            xcur, mcarry = m2.mamba2_fwd(p_m, xcur, zero_m, cfg=cfg, px=px,
                                         batch_entry=batch_entry)
            return (xcur, ks, vs, inv), (mcarry if collect_cache else 0)

        (h, ks, vs, _), mstates = jax.lax.scan(
            _maybe_remat(body, px, train), (x, k_stack, v_stack, jnp.int32(0)),
            (params["layers"], jnp.arange(cfg.n_layers)))
        cache = ({"mamba": mstates, "attn_k": ks, "attn_v": vs}
                 if collect_cache else None)
        return h, cache, {}

    # ---- transformer stacks (dense / moe / mla) -------------------------
    metrics: Dict[str, Any] = {}
    cache_parts = []
    sp = px.seq_entry(S)

    def run_stack(xcur, stack, moe_stack: bool):
        rb = extras.get("router_bias") if moe_stack else None
        pl = extras.get("placement") if moe_stack else None

        def body(xc, xs):
            if moe_stack and rb is not None:
                p_layer, rb_row, pl_row = xs
            else:
                p_layer, rb_row, pl_row = xs, None, None
            out, kv, met = blocks.tf_block_fwd(
                p_layer, xc, cfg=cfg, px=px, batch_entry=batch_entry,
                router_bias=rb_row, placement=pl_row,
                return_kv=collect_cache)
            out = px.constrain(out, batch_entry, sp, None)
            ys = {}
            if collect_cache:
                ys["kv"] = kv
            if moe_stack and met:
                ys["counts"] = met["expert_counts"]
                ys["aux"] = met["moe_aux_loss"]
                ys["dropped"] = met["moe_dropped"]
            return out, ys

        xs = (stack, rb, pl) if (moe_stack and rb is not None) else stack
        return jax.lax.scan(_maybe_remat(body, px, train), xcur, xs)

    if cfg.moe is not None and cfg.moe.first_k_dense:
        x, ys = run_stack(x, params["dense_layers"], False)
        if collect_cache:
            cache_parts.append(("dense", ys["kv"]))
    x, ys = run_stack(x, params["layers"], cfg.moe is not None)
    if collect_cache:
        cache_parts.append(("main", ys["kv"]))
    if cfg.moe is not None and "counts" in ys:
        metrics["expert_counts"] = ys["counts"]  # (Lmoe, E)
        metrics["moe_aux_loss"] = ys["aux"].mean()
        metrics["moe_dropped"] = ys["dropped"].sum()

    cache = None
    if collect_cache:
        cache = {name: kv for name, kv in cache_parts}
    return x, cache, metrics


# ---------------------------------------------------------------------------
# Loss (train)
# ---------------------------------------------------------------------------


def loss_fn(params, batch, extras, cfg, px: ParallelCtx):
    tokens = batch["tokens"]
    B, S = tokens.shape
    batch_entry = px.batch_spec(B)
    x = _embed_inputs(params, batch, cfg, px, batch_entry)
    h, _, metrics = backbone_fwd(params, x, cfg, px, batch_entry, extras,
                                 train=True)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(tokens, jnp.float32)
    if px.loss_chunk:
        tot, cnt = chunked_xent(h[:, :-1], params["embed"], tokens[:, 1:],
                                mask[:, 1:], px, batch_entry, px.loss_chunk)
        loss = tot / jnp.maximum(cnt, 1.0)
    else:
        logits = lm_head_fwd(params["embed"], h, px, batch_entry)
        loss = softmax_xent(logits[:, :-1], tokens[:, 1:], mask[:, 1:])
    metrics["xent"] = loss

    if cfg.mtp_depth and "mtp" in params:
        # Multi-token prediction (DeepSeek-V3): predict t+2 from
        # concat(h_t, emb(tok_{t+1})) through one extra block.
        emb_next = embed_fwd(params["embed"], tokens[:, 1:], px, batch_entry)
        hin = jnp.concatenate([rmsnorm(params["mtp"]["norm"], h[:, :-1],
                                       cfg.norm_eps), emb_next], axis=-1)
        hm = jnp.einsum("bsd,de->bse", hin,
                        params["mtp"]["proj"].astype(COMPUTE_DT))
        hm, _, _ = blocks.tf_block_fwd(params["mtp"]["block"], hm, cfg=cfg,
                                       px=px, batch_entry=batch_entry)
        if px.loss_chunk:
            tot, cnt = chunked_xent(hm[:, :-1], params["embed"],
                                    tokens[:, 2:], mask[:, 2:], px,
                                    batch_entry, px.loss_chunk)
            mtp_loss = tot / jnp.maximum(cnt, 1.0)
        else:
            lm2 = lm_head_fwd(params["embed"], hm, px, batch_entry)
            mtp_loss = softmax_xent(lm2[:, :-1], tokens[:, 2:], mask[:, 2:])
        metrics["mtp_loss"] = mtp_loss
        loss = loss + MTP_WEIGHT * mtp_loss

    if "moe_aux_loss" in metrics:
        loss = loss + MOE_AUX_WEIGHT * metrics["moe_aux_loss"]
    return loss, metrics


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def prefill(params, batch, cfg, px: ParallelCtx, cache_len: int):
    """Run the full prompt, return (cache, last_logits).

    Attention caches are allocated at ``cache_len`` (>= prompt length) and
    laid out sequence-sharded (see cache_specs)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    batch_entry = px.batch_spec(B)
    x = _embed_inputs(params, batch, cfg, px, batch_entry)
    h, cache, _ = backbone_fwd(params, x, cfg, px, batch_entry,
                               init_extras(cfg), train=False,
                               collect_cache=True)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = lm_head_fwd(params["embed"], h[:, -1:, :], px, batch_entry)
    cache = _pad_cache_to(cache, cfg, px, cache_len, batch_entry)
    return cache, logits


def _pad_cache_to(cache, cfg, px, cache_len, batch_entry):
    """Pad prefill caches along the sequence dim up to cache_len."""
    def pad(path_leaf):
        return path_leaf

    if cfg.rwkv is not None or cfg.encoder_decoder:
        return cache

    def pad_seq(arr, axis):
        S = arr.shape[axis]
        if S >= cache_len:
            return arr
        pad_width = [(0, 0)] * arr.ndim
        pad_width[axis] = (0, cache_len - S)
        return jnp.pad(arr, pad_width)

    if cfg.ssm is not None:
        cache["attn_k"] = pad_seq(cache["attn_k"], 2)
        cache["attn_v"] = pad_seq(cache["attn_v"], 2)
        return cache
    out = {}
    for name, kv in cache.items():
        if cfg.mla is not None:
            out[name] = pad_seq(kv, 2)  # latent (L,B,S,r)
        else:
            out[name] = {"k": pad_seq(kv[0], 2), "v": pad_seq(kv[1], 2)}
    return out


def decode_step(params, cache, tokens, pos, extras, cfg, px: ParallelCtx):
    """One greedy decode step. tokens: (B,) int32; pos: scalar int32.

    Returns (new_cache, logits (B, V))."""
    B = tokens.shape[0]
    batch_entry = px.batch_spec(B)
    x = embed_fwd(params["embed"], tokens[:, None], px, batch_entry)

    if cfg.rwkv is not None:
        def body(xc, xs):
            p_layer, c = xs
            out, c2 = r6.rwkv_decode_step(p_layer, xc, c, cfg=cfg, px=px,
                                          batch_entry=batch_entry)
            return out, c2
        h, new_cache = jax.lax.scan(body, x, (params["layers"], cache))

    elif cfg.ssm is not None:
        emb0 = x
        seq_entry = _decode_seq_entry(cfg, cache, px, B)

        def body(carry, xs):
            xc, ks, vs, inv = carry
            p_m, mcache, i = xs

            def with_shared(args):
                xc, ks, vs, inv = args
                c = {"k": jax.lax.dynamic_index_in_dim(ks, inv, 0, False),
                     "v": jax.lax.dynamic_index_in_dim(vs, inv, 0, False)}
                xc, c = blocks.shared_block_decode(
                    params["shared_block"], xc, emb0, c, pos, cfg=cfg, px=px,
                    batch_entry=batch_entry, seq_entry=seq_entry)
                ks = jax.lax.dynamic_update_slice_in_dim(ks, c["k"][None], inv, 0)
                vs = jax.lax.dynamic_update_slice_in_dim(vs, c["v"][None], inv, 0)
                return xc, ks, vs, inv + 1

            xc, ks, vs, inv = jax.lax.cond(i % cfg.shared_every == 0,
                                           with_shared, lambda a: a,
                                           (xc, ks, vs, inv))
            xc, m2c = m2.mamba2_fwd(p_m, xc, mcache, cfg=cfg, px=px,
                                    batch_entry=batch_entry, decode=True)
            return (xc, ks, vs, inv), m2c

        (h, ks, vs, _), mstates = jax.lax.scan(
            body, (x, cache["attn_k"], cache["attn_v"], jnp.int32(0)),
            (params["layers"], cache["mamba"], jnp.arange(cfg.n_layers)))
        new_cache = {"mamba": mstates, "attn_k": ks, "attn_v": vs}

    else:
        seq_entry = _decode_seq_entry(cfg, cache, px, B)
        new_cache = {}

        def run_stack(xc, stack, stack_cache, moe_stack):
            rb = extras.get("router_bias") if moe_stack else None
            pl = extras.get("placement") if moe_stack else None

            def body(xcur, xs):
                if moe_stack and rb is not None:
                    p_layer, c, rb_row, pl_row = xs
                else:
                    (p_layer, c), rb_row, pl_row = xs, None, None
                out, c2 = blocks.tf_block_decode(
                    p_layer, xcur, c, pos, cfg=cfg, px=px,
                    batch_entry=batch_entry, seq_entry=seq_entry,
                    router_bias=rb_row, placement=pl_row)
                return out, c2

            xs = ((stack, stack_cache, rb, pl) if (moe_stack and rb is not None)
                  else (stack, stack_cache))
            return jax.lax.scan(body, xc, xs)

        xcur = x
        if cfg.moe is not None and cfg.moe.first_k_dense:
            xcur, c2 = run_stack(xcur, params["dense_layers"], cache["dense"],
                                 False)
            new_cache["dense"] = c2
        xcur, c2 = run_stack(xcur, params["layers"], cache["main"],
                             cfg.moe is not None)
        new_cache["main"] = c2
        h = xcur

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = lm_head_fwd(params["embed"], h, px, batch_entry)[:, 0, :]
    return new_cache, logits


def _decode_seq_entry(cfg, cache, px, batch: int):
    if cfg.mla is not None:
        S = cache["main"].shape[2]
    elif cfg.ssm is not None:
        S = cache["attn_k"].shape[2]
    else:
        S = cache["main"]["k"].shape[2]
    # batch=1 (long_500k): the KV sequence is the only shardable dim, so
    # spread it over every mesh axis; otherwise batch owns the data axes
    # and the sequence shards over the model axis only.
    if batch == 1:
        return px.seq_mega_spec(S)
    return px.shard_if(S, px.model_axis)

"""RWKV-6 "Finch" — attention-free time-mix with data-dependent decay
[arXiv:2404.05892], adapted to TPU as a chunked recurrence.

Recurrence (per head, state S in R^{N x N}):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Chunked form (chunk c): with l_t = cumsum(log w) inside the chunk,
    o_t  = (r_t . exp(l_{t-1})) @ S_0
         + sum_{i<t} [sum_n r_tn k_in exp(l_{t-1,n} - l_{i,n})] v_i
         + (r_t . u . k_t) v_t
    S_c  = diag(exp(l_c)) S_0 + sum_i (k_i . exp(l_c - l_i))^T v_i
Every exponent is <= 0, so the chunked form is unconditionally stable —
this is the TPU adaptation of the CUDA wkv kernel's running-max trick
(see DESIGN.md §Adaptations). The intra-chunk term is O(c^2 N) per head
and maps to the MXU via one (c,c) matmul per channel group.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DT, _init, init_rmsnorm, rmsnorm
from repro.parallel.ctx import ParallelCtx


def init_rwkv_block(key, d: int, cfg):
    r = cfg.rwkv
    ks = jax.random.split(key, 12)
    H = cfg.n_heads
    N = r.head_dim
    return {
        "ln_attn": init_rmsnorm(d),
        "ln_ffn": init_rmsnorm(d),
        # token-shift data-dependent mix (lora): 5 targets r,k,v,w,g
        "mix_base": jnp.zeros((5, d), COMPUTE_DT),
        "mix_lora_a": _init(ks[0], (d, 5 * cfg.rwkv.mix_lora)),
        "mix_lora_b": _init(ks[1], (5, cfg.rwkv.mix_lora, d), scale=0.01),
        # projections
        "t_r": _init(ks[2], (d, d)),
        "t_k": _init(ks[3], (d, d)),
        "t_v": _init(ks[4], (d, d)),
        "t_g": _init(ks[5], (d, d)),
        "t_o": _init(ks[6], (d, d)),
        # data-dependent decay lora
        "w_base": jnp.full((d,), -6.0, jnp.float32),
        "decay_a": _init(ks[7], (d, r.decay_lora)),
        "decay_b": _init(ks[8], (r.decay_lora, d), scale=0.01),
        "bonus_u": jnp.zeros((H, N), jnp.float32),
        "ln_x": init_rmsnorm(d),
        # channel mix
        "ck": _init(ks[9], (d, cfg.d_ff)),
        "cv": _init(ks[10], (cfg.d_ff, d)),
        "cr": _init(ks[11], (d, d)),
    }


def _time_shift(x, last):
    """Shift right by one along S; position 0 takes `last` (B, d)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _mix_rkvwg(p, xn, last, px, batch_entry):
    """Data-dependent token-shift interpolation -> r,k,v,w,g inputs."""
    xs = _time_shift(xn, last)
    delta = xs - xn
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", xn, p["mix_lora_a"].astype(COMPUTE_DT)))
    lora = lora.reshape(*lora.shape[:-1], 5, -1)
    mixes = p["mix_base"].astype(COMPUTE_DT) + jnp.einsum(
        "bsir,ird->bsid", lora, p["mix_lora_b"].astype(COMPUTE_DT))
    # x_i = xn + delta * mix_i   for i in r,k,v,w,g
    return xn[:, :, None, :] + delta[:, :, None, :] * mixes


def rwkv_time_mix(p, xn, state, shift_last, *, cfg, px: ParallelCtx,
                  batch_entry):
    """Chunked RWKV6 time-mix.

    xn: (B,S,d) normed input; state: (B,H,N,N); shift_last: (B,d).
    Returns (out, new_state, new_shift_last).
    """
    B, S, D = xn.shape
    H, N = cfg.n_heads, cfg.rwkv.head_dim
    c = min(cfg.rwkv.chunk, S)
    assert S % c == 0, (S, c)
    mixed = _mix_rkvwg(p, xn, shift_last, px, batch_entry)
    xr, xk, xv, xw, xg = [mixed[:, :, i, :] for i in range(5)]
    r = jnp.einsum("bsd,de->bse", xr, p["t_r"].astype(COMPUTE_DT))
    k = jnp.einsum("bsd,de->bse", xk, p["t_k"].astype(COMPUTE_DT))
    v = jnp.einsum("bsd,de->bse", xv, p["t_v"].astype(COMPUTE_DT))
    g = jnp.einsum("bsd,de->bse", xg, p["t_g"].astype(COMPUTE_DT))
    # log-decay in (-inf, 0): logw = -exp(w_base + lora)
    wl = jnp.einsum("bsd,dr->bsr", xw, p["decay_a"].astype(COMPUTE_DT))
    logw = -jnp.exp(p["w_base"][None, None, :]
                    + jnp.einsum("bsr,rd->bsd", jnp.tanh(wl),
                                 p["decay_b"].astype(COMPUTE_DT)).astype(jnp.float32))

    def heads(x):
        return x.reshape(B, S, H, N).transpose(0, 2, 1, 3)  # (B,H,S,N)

    h_entry = px.shard_if(H, px.model_axis)
    rh = px.constrain(heads(r), batch_entry, h_entry, None, None).astype(jnp.float32)
    kh = px.constrain(heads(k), batch_entry, h_entry, None, None).astype(jnp.float32)
    vh = px.constrain(heads(v), batch_entry, h_entry, None, None).astype(jnp.float32)
    lw = px.constrain(heads(logw), batch_entry, h_entry, None, None)
    u = p["bonus_u"][None, :, None, :]

    nc = S // c
    rh, kh, vh, lw = [t.reshape(B, H, nc, c, N).transpose(2, 0, 1, 3, 4)
                      for t in (rh, kh, vh, lw)]

    def chunk_step(S0, inp):
        rc, kc, vc, lwc = inp  # (B,H,c,N)
        l = jnp.cumsum(lwc, axis=2)  # (B,H,c,N), decreasing
        l_prev = l - lwc  # l_{t-1}
        # intra-chunk: A[t,i] = sum_n r_tn k_in exp(l_{t-1,n} - l_{i,n}), i<t
        expo = l_prev[:, :, :, None, :] - l[:, :, None, :, :]  # (B,H,t,i,N)
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)[None, None, :, :, None]
        A = jnp.sum(jnp.where(tri, jnp.exp(expo), 0.0)
                    * rc[:, :, :, None, :] * kc[:, :, None, :, :], axis=-1)
        o = jnp.einsum("bhti,bhin->bhtn", A, vc)
        # diagonal bonus: (r_t . u . k_t) v_t
        o += jnp.sum(rc * u * kc, axis=-1, keepdims=True) * vc
        # state contribution
        o += jnp.einsum("bhtn,bhnm->bhtm", rc * jnp.exp(l_prev), S0)
        # state update
        kd = kc * jnp.exp(l[:, :, -1:, :] - l)  # (B,H,c,N)
        S1 = jnp.exp(l[:, :, -1, :])[..., None] * S0 + jnp.einsum(
            "bhtn,bhtm->bhnm", kd, vc)
        return S1, o

    if px.scan_unroll:
        st = state.astype(jnp.float32)
        olist = []
        for i in range(nc):
            st, o = chunk_step(st, (rh[i], kh[i], vh[i], lw[i]))
            olist.append(o)
        state, outs = st, jnp.stack(olist, axis=0)
    else:
        state, outs = jax.lax.scan(chunk_step, state.astype(jnp.float32),
                                   (rh, kh, vh, lw))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, N)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, D)
    out = rmsnorm(p["ln_x"], out.astype(COMPUTE_DT))
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DT)
    y = jnp.einsum("bsd,de->bse", out, p["t_o"].astype(COMPUTE_DT))
    return (px.constrain(y, batch_entry, None, None), state,
            xn[:, -1, :])


def rwkv_channel_mix(p, xn, shift_last, *, px: ParallelCtx, batch_entry):
    xs = _time_shift(xn, shift_last)
    # rwkv6 channel mix uses a fixed 0.5 shift-mix for simplicity here
    xk = 0.5 * (xn + xs)
    k = jnp.einsum("bsd,df->bsf", xk, p["ck"].astype(COMPUTE_DT))
    k = px.constrain(k, batch_entry, None,
                     px.shard_if(p["ck"].shape[-1], px.model_axis))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(COMPUTE_DT)
    kv = jnp.einsum("bsf,fd->bsd", k, p["cv"].astype(COMPUTE_DT))
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xk, p["cr"].astype(COMPUTE_DT)).astype(jnp.float32)
    ).astype(COMPUTE_DT)
    return px.constrain(r * kv, batch_entry, None, None), xn[:, -1, :]


def rwkv_block_fwd(p, x, carry, *, cfg, px: ParallelCtx, batch_entry):
    """carry: dict(state (B,H,N,N), shift_a (B,d), shift_f (B,d))."""
    xn = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    y, state, sa = rwkv_time_mix(p, xn, carry["state"], carry["shift_a"],
                                 cfg=cfg, px=px, batch_entry=batch_entry)
    x = x + y
    xf = rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
    y2, sf = rwkv_channel_mix(p, xf, carry["shift_f"], px=px,
                              batch_entry=batch_entry)
    x = x + y2
    return x, {"state": state, "shift_a": sa, "shift_f": sf}


def rwkv_decode_step(p, x, carry, *, cfg, px: ParallelCtx, batch_entry):
    """Single-token recurrent step (S=1): exact recurrence, O(N^2)/head."""
    B = x.shape[0]
    H, N = cfg.n_heads, cfg.rwkv.head_dim
    xn = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    mixed = _mix_rkvwg(p, xn, carry["shift_a"], px, batch_entry)
    xr, xk, xv, xw, xg = [mixed[:, :, i, :] for i in range(5)]
    r = (xr @ p["t_r"].astype(COMPUTE_DT)).reshape(B, H, N).astype(jnp.float32)
    k = (xk @ p["t_k"].astype(COMPUTE_DT)).reshape(B, H, N).astype(jnp.float32)
    v = (xv @ p["t_v"].astype(COMPUTE_DT)).reshape(B, H, N).astype(jnp.float32)
    g = xg @ p["t_g"].astype(COMPUTE_DT)
    wl = jnp.tanh(xw @ p["decay_a"].astype(COMPUTE_DT)) \
        @ p["decay_b"].astype(COMPUTE_DT)
    w = jnp.exp(-jnp.exp(p["w_base"][None, None, :] + wl.astype(jnp.float32)))
    w = w.reshape(B, H, N)
    S0 = carry["state"].astype(jnp.float32)
    kv = k[..., :, None] * v[..., None, :]  # (B,H,N,N)
    o = jnp.einsum("bhn,bhnm->bhm", r, S0 + p["bonus_u"][None, :, :, None] * kv)
    S1 = w[..., :, None] * S0 + kv
    out = o.reshape(B, 1, H * N).astype(COMPUTE_DT)
    out = rmsnorm(p["ln_x"], out)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DT)
    y = out @ p["t_o"].astype(COMPUTE_DT)
    x = x + y
    xf = rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
    y2, sf = rwkv_channel_mix(p, xf, carry["shift_f"], px=px,
                              batch_entry=batch_entry)
    x = x + y2
    return x, {"state": S1, "shift_a": xn[:, -1, :], "shift_f": sf}

"""Per-layer block assembly for each architecture family."""
from __future__ import annotations

import types

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import (COMPUTE_DT, init_mlp, init_rmsnorm, mlp_fwd,
                                 rmsnorm)
from repro.parallel.ctx import ParallelCtx


def attn_cfg_view(cfg, d_model=None, n_heads=None, n_kv=None, head_dim=None):
    """A lightweight view with the attention-relevant fields overridden
    (used by zamba2's shared block, which attends at 2*d_model)."""
    v = types.SimpleNamespace()
    v.n_heads = n_heads or cfg.n_heads
    v.n_kv_heads = n_kv or cfg.n_kv_heads
    v.rope_theta = cfg.rope_theta
    v.norm_eps = cfg.norm_eps
    hd = head_dim or ((d_model or cfg.d_model) // v.n_heads)
    v.resolved_head_dim = hd
    return v


# ---------------------------------------------------------------------------
# Dense / MoE transformer block
# ---------------------------------------------------------------------------


def init_tf_block(key, cfg, moe_layer: bool):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p = {"ln1": init_rmsnorm(d), "ln2": init_rmsnorm(d)}
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(ks[0], d, cfg.n_heads, cfg.mla)
    else:
        p["attn"] = attn.init_gqa(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.resolved_head_dim, cfg.qkv_bias)
    if moe_layer:
        p["moe"] = moe_mod.init_moe(ks[1], d, cfg.moe)
    else:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff)
    return p


def tf_block_fwd(p, x, *, cfg, px: ParallelCtx, batch_entry, causal=True,
                 router_bias=None, placement=None, return_kv=False):
    """Full-sequence block (train / prefill). Returns (x, kv_or_None, metrics)."""
    sp = px.seq_entry(x.shape[1])
    xa = rmsnorm(p["ln1"], x, cfg.norm_eps)
    kv = None
    if cfg.mla is not None:
        if return_kv:
            y, kv = attn.mla_fwd(p["attn"], xa, cfg=cfg, px=px,
                                 batch_entry=batch_entry, return_latent=True)
        else:
            y = attn.mla_fwd(p["attn"], xa, cfg=cfg, px=px,
                             batch_entry=batch_entry)
    else:
        if return_kv:
            y, kv = attn.gqa_fwd(p["attn"], xa, cfg=cfg, px=px, causal=causal,
                                 batch_entry=batch_entry, return_kv=True)
        else:
            y = attn.gqa_fwd(p["attn"], xa, cfg=cfg, px=px, causal=causal,
                             batch_entry=batch_entry)
    x = px.constrain(x + y, batch_entry, sp, None)
    xm = rmsnorm(p["ln2"], x, cfg.norm_eps)
    metrics = {}
    if "moe" in p:
        y2, metrics = moe_mod.moe_fwd(p["moe"], xm, m=cfg.moe, px=px,
                                      batch_entry=batch_entry,
                                      router_bias=router_bias,
                                      placement=placement)
    else:
        y2 = mlp_fwd(p["mlp"], xm, px, batch_entry)
    return x + y2, kv, metrics


def tf_block_decode(p, x, cache, pos, *, cfg, px: ParallelCtx, batch_entry,
                    seq_entry, router_bias=None, placement=None):
    """Single-token block step. cache: {"k","v"} or MLA latent array."""
    xa = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        y, cache = attn.mla_decode(p["attn"], xa, cache, pos, cfg=cfg, px=px,
                                   batch_entry=batch_entry, seq_entry=seq_entry)
    else:
        y, cache = attn.gqa_decode(p["attn"], xa, cache, pos, cfg=cfg, px=px,
                                   batch_entry=batch_entry, seq_entry=seq_entry)
    x = x + y
    xm = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        y2, _ = moe_mod.moe_fwd(p["moe"], xm, m=cfg.moe, px=px,
                                batch_entry=batch_entry,
                                router_bias=router_bias, placement=placement)
    else:
        y2 = mlp_fwd(p["mlp"], xm, px, batch_entry)
    return x + y2, cache


# ---------------------------------------------------------------------------
# Zamba2 shared attention block (weights shared across invocations)
# ---------------------------------------------------------------------------


def init_shared_block(key, cfg):
    d2 = 2 * cfg.d_model
    ks = jax.random.split(key, 4)
    from repro.models.layers import _init
    acfg = attn_cfg_view(cfg, d_model=d2)
    return {
        "ln1": init_rmsnorm(d2),
        "ln2": init_rmsnorm(d2),
        "attn": attn.init_gqa(ks[0], d2, cfg.n_heads, cfg.n_kv_heads,
                              acfg.resolved_head_dim, False),
        "mlp": init_mlp(ks[1], d2, cfg.d_ff),
        "w_down": _init(ks[2], (d2, cfg.d_model)),
    }


def shared_block_fwd(p, h, emb0, *, cfg, px, batch_entry, return_kv=False):
    d2cfg = attn_cfg_view(cfg, d_model=2 * cfg.d_model)
    xin = jnp.concatenate([h, emb0], axis=-1)
    xa = rmsnorm(p["ln1"], xin, cfg.norm_eps)
    kv = None
    if return_kv:
        y, kv = attn.gqa_fwd(p["attn"], xa, cfg=d2cfg, px=px, causal=True,
                             batch_entry=batch_entry, return_kv=True)
    else:
        y = attn.gqa_fwd(p["attn"], xa, cfg=d2cfg, px=px, causal=True,
                         batch_entry=batch_entry)
    xin = xin + y
    xm = rmsnorm(p["ln2"], xin, cfg.norm_eps)
    xin = xin + mlp_fwd(p["mlp"], xm, px, batch_entry)
    delta = jnp.einsum("bsd,de->bse", xin, p["w_down"].astype(COMPUTE_DT))
    return h + px.constrain(delta, batch_entry, None, None), kv


def shared_block_decode(p, h, emb0, cache, pos, *, cfg, px, batch_entry,
                        seq_entry):
    d2cfg = attn_cfg_view(cfg, d_model=2 * cfg.d_model)
    xin = jnp.concatenate([h, emb0], axis=-1)
    xa = rmsnorm(p["ln1"], xin, cfg.norm_eps)
    y, cache = attn.gqa_decode(p["attn"], xa, cache, pos, cfg=d2cfg, px=px,
                               batch_entry=batch_entry, seq_entry=seq_entry)
    xin = xin + y
    xm = rmsnorm(p["ln2"], xin, cfg.norm_eps)
    xin = xin + mlp_fwd(p["mlp"], xm, px, batch_entry)
    delta = jnp.einsum("bsd,de->bse", xin, p["w_down"].astype(COMPUTE_DT))
    return h + px.constrain(delta, batch_entry, None, None), cache

"""Encoder-decoder backbone (seamless-m4t-medium).

The speech/text frontend is a stub per the assignment: the encoder
consumes precomputed frame embeddings (B, S, d_frame). Encoder blocks are
bidirectional self-attention + MLP; decoder blocks add causal self-attn
and cross-attn over the encoder output. RoPE replaces the released
model's relative-position scheme (DESIGN.md §Adaptations).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (COMPUTE_DT, _init, embed_fwd, init_embed,
                                 init_mlp, init_rmsnorm, lm_head_fwd,
                                 mlp_fwd, rmsnorm, softmax_xent)
from repro.parallel.ctx import ParallelCtx

FRAME_DIM = 1024  # stub frontend output dim


def _init_enc_block(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_rmsnorm(cfg.d_model), "ln2": init_rmsnorm(cfg.d_model),
        "attn": attn.init_gqa(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.resolved_head_dim, False),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def _init_dec_block(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model), "ln2": init_rmsnorm(cfg.d_model),
        "ln3": init_rmsnorm(cfg.d_model),
        "self_attn": attn.init_gqa(ks[0], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.resolved_head_dim,
                                   False),
        "cross_attn": attn.init_gqa(ks[1], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.resolved_head_dim,
                                    False),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff),
    }


def init_encdec(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    ek = jax.random.split(ks[0], cfg.n_layers)
    dk = jax.random.split(ks[1], cfg.n_layers)
    return {
        "src_proj": _init(ks[2], (FRAME_DIM, cfg.d_model)),
        "embed": init_embed(ks[3], cfg.padded_vocab, cfg.d_model),
        "enc_layers": jax.vmap(lambda k: _init_enc_block(k, cfg))(ek),
        "dec_layers": jax.vmap(lambda k: _init_dec_block(k, cfg))(dk),
        "enc_norm": init_rmsnorm(cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def encode(params, frames, cfg, px: ParallelCtx, batch_entry, train=False):
    x = jnp.einsum("bsf,fd->bsd", frames.astype(COMPUTE_DT),
                   params["src_proj"].astype(COMPUTE_DT))
    x = px.constrain(x, batch_entry, None, None)

    def body(xc, p_layer):
        xa = rmsnorm(p_layer["ln1"], xc, cfg.norm_eps)
        xc = xc + attn.gqa_fwd(p_layer["attn"], xa, cfg=cfg, px=px,
                               causal=False, batch_entry=batch_entry)
        xm = rmsnorm(p_layer["ln2"], xc, cfg.norm_eps)
        return xc + mlp_fwd(p_layer["mlp"], xm, px, batch_entry), 0

    fn = body
    if train and px.remat != "none":
        fn = jax.checkpoint(body)
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block_full(p, x, enc_kv, cfg, px, batch_entry, collect_cache):
    xa = rmsnorm(p["ln1"], x, cfg.norm_eps)
    kv = None
    if collect_cache:
        y, kv = attn.gqa_fwd(p["self_attn"], xa, cfg=cfg, px=px, causal=True,
                             batch_entry=batch_entry, return_kv=True)
    else:
        y = attn.gqa_fwd(p["self_attn"], xa, cfg=cfg, px=px, causal=True,
                         batch_entry=batch_entry)
    x = x + y
    xc = rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + attn.gqa_fwd(p["cross_attn"], xc, cfg=cfg, px=px, causal=False,
                         batch_entry=batch_entry, kv_override=enc_kv)
    xm = rmsnorm(p["ln3"], x, cfg.norm_eps)
    return x + mlp_fwd(p["mlp"], xm, px, batch_entry), kv


def _enc_cross_kv(p_layer, enc_out, cfg, px, batch_entry):
    """Project encoder output to this decoder layer's cross K/V."""
    k = jnp.einsum("bsd,dhk->bhsk", enc_out,
                   p_layer["cross_attn"]["wk"].astype(COMPUTE_DT))
    v = jnp.einsum("bsd,dhk->bhsk", enc_out,
                   p_layer["cross_attn"]["wv"].astype(COMPUTE_DT))
    return k, v


def encdec_loss(params, batch, extras, cfg, px: ParallelCtx):
    frames, tokens = batch["frames"], batch["tokens"]
    B, S = tokens.shape
    batch_entry = px.batch_spec(B)
    enc_out = encode(params, frames, cfg, px, batch_entry, train=True)
    x = embed_fwd(params["embed"], tokens, px, batch_entry)

    def body(xc, p_layer):
        kv = _enc_cross_kv(p_layer, enc_out, cfg, px, batch_entry)
        out, _ = _dec_block_full(p_layer, xc, kv, cfg, px, batch_entry, False)
        return out, 0

    fn = jax.checkpoint(body) if px.remat != "none" else body
    x, _ = jax.lax.scan(fn, x, params["dec_layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head_fwd(params["embed"], x, px, batch_entry)
    mask = batch.get("loss_mask")
    loss = softmax_xent(logits[:, :-1], tokens[:, 1:],
                        mask[:, 1:] if mask is not None else None)
    return loss, {"xent": loss}


def encdec_prefill(params, batch, cfg, px: ParallelCtx, cache_len: int):
    """Encode the source and precompute per-layer cross K/V; allocate an
    empty self-attention cache of cache_len."""
    frames = batch["frames"]
    B = frames.shape[0]
    batch_entry = px.batch_spec(B)
    enc_out = encode(params, frames, cfg, px, batch_entry)

    def body(_, p_layer):
        k, v = _enc_cross_kv(p_layer, enc_out, cfg, px, batch_entry)
        return 0, {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}

    _, cross = jax.lax.scan(body, 0, params["dec_layers"])
    L, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    self_cache = {
        "k": jnp.zeros((L, B, cache_len, Hkv, Dh), COMPUTE_DT),
        "v": jnp.zeros((L, B, cache_len, Hkv, Dh), COMPUTE_DT),
    }
    # BOS logits
    logits = lm_head_fwd(params["embed"],
                         rmsnorm(params["final_norm"],
                                 enc_out[:, -1:, :], cfg.norm_eps),
                         px, batch_entry)
    return {"self": self_cache, "cross": cross}, logits


def encdec_decode(params, cache, tokens, pos, extras, cfg, px: ParallelCtx):
    B = tokens.shape[0]
    batch_entry = px.batch_spec(B)
    x = embed_fwd(params["embed"], tokens[:, None], px, batch_entry)
    S_self = cache["self"]["k"].shape[2]
    S_cross = cache["cross"]["k"].shape[2]
    seq_entry = px.shard_if(S_self, px.model_axis)
    cross_entry = px.shard_if(S_cross, px.model_axis)

    def body(xc, xs):
        p_layer, self_c, cross_c = xs
        xa = rmsnorm(p_layer["ln1"], xc, cfg.norm_eps)
        y, self_c = attn.gqa_decode(p_layer["self_attn"], xa, self_c, pos,
                                    cfg=cfg, px=px, batch_entry=batch_entry,
                                    seq_entry=seq_entry)
        xc = xc + y
        xb = rmsnorm(p_layer["ln2"], xc, cfg.norm_eps)
        # cross attention: cache is read-only, attend over full source
        y, _ = attn.gqa_decode(p_layer["cross_attn"], xb, cross_c,
                               jnp.int32(S_cross - 1), cfg=cfg, px=px,
                               batch_entry=batch_entry, seq_entry=cross_entry,
                               cross=True)
        xc = xc + y
        xm = rmsnorm(p_layer["ln3"], xc, cfg.norm_eps)
        xc = xc + mlp_fwd(p_layer["mlp"], xm, px, batch_entry)
        return xc, self_c

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self"], cache["cross"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head_fwd(params["embed"], x, px, batch_entry)[:, 0, :]
    return {"self": new_self, "cross": cache["cross"]}, logits

"""Shared building blocks: norms, MLPs, RoPE, embeddings, cross-entropy.

All modules are functional: ``init_*`` returns a param dict, ``*_fwd``
consumes it. Params are stored bf16; norms/softmax/losses compute fp32.

REPRO_FORCE_F32=1 switches params+compute to fp32 (same shapes). Used by
the dry-run memory probe: XLA:CPU emulates bf16 via f32 buffers, so a
bf16 compile OVERSTATES the TPU footprint; an f32 compile has no
emulation converts and its peak/2 bounds the true bf16 peak (intentional
f32 buffers — softmax stats, norms — are small). See dryrun.py.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.parallel.ctx import ParallelCtx

_FORCE_F32 = os.environ.get("REPRO_FORCE_F32", "0") == "1"
PARAM_DT = jnp.float32 if _FORCE_F32 else jnp.bfloat16
COMPUTE_DT = jnp.float32 if _FORCE_F32 else jnp.bfloat16


def _init(key, shape, scale=None, dtype=PARAM_DT):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), PARAM_DT)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _init(k1, (d, f)),
        "w_up": _init(k2, (d, f)),
        "w_down": _init(k3, (f, d)),
    }


def mlp_fwd(p, x, px: ParallelCtx, batch_entry=None):
    """SwiGLU. Hidden dim sharded over the model axis (Megatron TP)."""
    f = p["w_gate"].shape[-1]
    fspec = px.shard_if(f, px.model_axis)
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(COMPUTE_DT))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(COMPUTE_DT))
    h = px.constrain(h, batch_entry, None, fspec)
    h = jax.nn.silu(h.astype(jnp.float32)).astype(COMPUTE_DT) * u
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(COMPUTE_DT))
    # reduce-scatter into the sequence-parallel layout (never a full-S
    # unsharded residual)
    return px.constrain(out, batch_entry, px.seq_entry(out.shape[1]), None)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head / loss
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d: int, tie: bool = False):
    k1, k2 = jax.random.split(key)
    p = {"embedding": _init(k1, (vocab, d), scale=0.02)}
    if not tie:
        p["lm_head"] = _init(k2, (d, vocab))
    return p


def embed_fwd(p, tokens, px: ParallelCtx, batch_entry=None):
    out = jnp.take(p["embedding"].astype(COMPUTE_DT), tokens, axis=0)
    return px.constrain(out, batch_entry, px.seq_entry(out.shape[1]), None)


def lm_head_fwd(p, x, px: ParallelCtx, batch_entry=None):
    w = p.get("lm_head")
    if w is None:
        w = p["embedding"].T
    v = w.shape[-1]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(COMPUTE_DT))
    return px.constrain(logits, batch_entry, None, px.shard_if(v, px.model_axis))


def chunked_xent(h, p_embed, labels, mask, px: ParallelCtx, batch_entry,
                 chunk: int = 1024):
    """Sequence-chunked cross-entropy: the (B, chunk, V) logits are
    (re)computed per chunk under jax.checkpoint, so the full (B, S, V)
    fp32 logit tensor never materializes (§Perf: memory-term iteration).

    Returns (sum_nll, sum_mask) so the caller can normalize."""
    w = p_embed.get("lm_head")
    if w is None:
        w = p_embed["embedding"].T
    B, S, D = h.shape
    c = min(chunk, S)
    n = S // c
    rem = S - n * c
    vspec = px.shard_if(w.shape[-1], px.model_axis)

    @jax.checkpoint
    def piece(hc, lc, mc):
        logits = jnp.einsum("bsd,dv->bsv", hc, w.astype(COMPUTE_DT))
        logits = px.constrain(logits, batch_entry, None, vspec)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mc), jnp.sum(mc)

    def body(carry, inp):
        tot, cnt = carry
        hc, lc, mc = inp
        s, k = piece(hc, lc, mc)
        return (tot + s, cnt + k), None

    resh = lambda x: x[:, : n * c].reshape(B, n, c, *x.shape[2:]).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)),
        (resh(h), resh(labels), resh(mask.astype(jnp.float32))))
    if rem:
        s, k = piece(h[:, n * c:], labels[:, n * c:],
                     mask[:, n * c:].astype(jnp.float32))
        tot, cnt = tot + s, cnt + k
    return tot, cnt


def softmax_xent(logits, labels, mask=None):
    """Cross-entropy in fp32 over a (possibly vocab-sharded) last dim.

    Reductions over the sharded vocab dim lower to small all-reduces under
    GSPMD, so the full logit tensor is never gathered.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)

"""Attention: GQA (train/prefill/decode) and MLA (DeepSeek-V3).

Three distribution layouts, chosen by the sharding policy:

* heads-sharded (default TP): query heads split over the model axis; KV
  heads split when divisible, else replicated (GQA with few KV heads).
  Train/prefill use a blockwise-online-softmax ("flash") formulation in
  pure jnp — this is also the oracle for the Pallas kernels.
* sequence-sharded (qwen2: 28 heads % 16 != 0): query positions split
  over the model axis, KV replicated per block (GSPMD all-gathers).
* decode: KV cache sequence-sharded over the model axis (and over every
  axis for long_500k); partial softmax stats combine via the small
  all-reduces GSPMD inserts for reductions over a sharded dim. This is
  flash-decode, expressed in the partitioner rather than by hand.

``causal_skip=True`` unrolls query blocks in Python so each block scans
only its own KV prefix — the exact lower triangle, ~2x fewer FLOPs than
the masked single-scan baseline (§Perf iteration 1).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DT, _init, apply_rope
from repro.parallel.ctx import ParallelCtx

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_gqa(key, d: int, n_heads: int, n_kv: int, head_dim: int, bias: bool):
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, n_heads, head_dim)),
        "wk": _init(ks[1], (d, n_kv, head_dim)),
        "wv": _init(ks[2], (d, n_kv, head_dim)),
        "wo": _init(ks[3], (n_heads, head_dim, d)),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), COMPUTE_DT)
        p["bk"] = jnp.zeros((n_kv, head_dim), COMPUTE_DT)
        p["bv"] = jnp.zeros((n_kv, head_dim), COMPUTE_DT)
    return p


def init_mla(key, d: int, n_heads: int, c):
    """c: MLAConfig."""
    ks = jax.random.split(key, 6)
    qh = c.qk_nope_head_dim + c.qk_rope_head_dim
    return {
        "w_dq": _init(ks[0], (d, c.q_lora_rank)),
        "w_uq": _init(ks[1], (c.q_lora_rank, n_heads, qh)),
        "w_dkv": _init(ks[2], (d, c.kv_lora_rank + c.qk_rope_head_dim)),
        "w_uk": _init(ks[3], (c.kv_lora_rank, n_heads, c.qk_nope_head_dim)),
        "w_uv": _init(ks[4], (c.kv_lora_rank, n_heads, c.v_head_dim)),
        "wo": _init(ks[5], (n_heads, c.v_head_dim, d)),
    }


# ---------------------------------------------------------------------------
# Blockwise attention primitives (jnp flash — oracle for the Pallas kernel)
# ---------------------------------------------------------------------------


def _online_block(q, k, v, m, l, acc, mask=None):
    """One online-softmax update. q:(...,qb,D) k,v:(...,kb,D)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "...qk,...kd->...qd", p.astype(COMPUTE_DT), v
    ).astype(jnp.float32)
    return m_new, l_new, acc_new


def _expand_kv(k, n_heads: int):
    """(B,Hkv,S,D) -> (B,Hq,S,D) by group repetition."""
    n_kv = k.shape[1]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=1)


def flash_heads(q, k, v, *, causal: bool, px: ParallelCtx, batch_entry,
                head_entry) -> jax.Array:
    """Head-sharded blockwise attention.

    q: (B, Hq, S, D); k,v: (B, Hq, Skv, D) (already group-expanded).
    With ``px.causal_skip`` each query block only scans its KV prefix.
    """
    B, H, S, Dk = q.shape
    Dv = v.shape[-1]
    Skv = k.shape[2]
    S_orig, Skv_orig = S, Skv
    qb = min(px.q_block, S)
    kb = min(px.kv_block, Skv)
    if S % qb:  # pad queries to a block multiple (MTP runs on S-1)
        pad = qb * math.ceil(S / qb) - S
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        S = q.shape[2]
    if Skv % kb:
        pad = kb * math.ceil(Skv / kb) - Skv
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Skv = k.shape[2]
    nq = S // qb
    nk = Skv // kb

    def scan_kv_prefix(qi, qblk, n_blocks, offset_blocks=0):
        """Online-softmax over kv blocks [offset, offset+n_blocks)."""
        kpre = jax.lax.dynamic_slice_in_dim(k, offset_blocks * kb, n_blocks * kb, 2)
        vpre = jax.lax.dynamic_slice_in_dim(v, offset_blocks * kb, n_blocks * kb, 2)
        kpre = kpre.reshape(B, H, n_blocks, kb, Dk).transpose(2, 0, 1, 3, 4)
        vpre = vpre.reshape(B, H, n_blocks, kb, Dv).transpose(2, 0, 1, 3, 4)
        m0 = jnp.full((B, H, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qb), jnp.float32)
        a0 = jnp.zeros((B, H, qb, Dv), jnp.float32)
        qpos = qi * qb + jnp.arange(qb)

        def step(carry, j, kj, vj):
            m, l, acc = carry
            kpos = (offset_blocks + j) * kb + jnp.arange(kb)
            mask = None
            if causal:
                mask = qpos[:, None] >= kpos[None, :]
            if Skv != Skv_orig:
                valid = (kpos < Skv_orig)[None, :]
                mask = valid if mask is None else (mask & valid)
            return _online_block(qblk, kj, vj, m, l, acc, mask)

        if px.scan_unroll:
            carry = (m0, l0, a0)
            for j in range(n_blocks):
                carry = step(carry, j, kpre[j], vpre[j])
            m, l, acc = carry
        else:
            def body(carry, kv_j):
                (mla, j) = carry
                kj, vj = kv_j
                return ((step(mla, j, kj, vj), j + 1), None)

            ((m, l, acc), _), _ = jax.lax.scan(
                body, ((m0, l0, a0), jnp.int32(0)), (kpre, vpre))
        return (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)

    outs = []
    for qi in range(nq):
        qblk = jax.lax.dynamic_slice_in_dim(q, qi * qb, qb, 2)
        if causal and px.causal_skip:
            # exact lower triangle: this q block sees kv blocks [0 .. hi)
            hi = min(nk, math.ceil(((qi + 1) * qb) / kb))
            outs.append(scan_kv_prefix(qi, qblk, hi))
        else:
            outs.append(scan_kv_prefix(qi, qblk, nk))
    out = jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]
    out = out[:, :, :S_orig, :]
    return px.constrain(out, batch_entry, head_entry, None, None)


def flash_seq(q, k, v, *, causal: bool, px: ParallelCtx, batch_entry):
    """Sequence-sharded attention (qwen2 fallback: Hq % model != 0).

    q: (B, Hq, S, D) with S sharded over the model axis; k, v replicated
    (GSPMD all-gathers them once per layer). Online softmax over KV blocks.
    """
    B, H, S, D = q.shape
    Skv = k.shape[2]
    kb = min(px.kv_block, Skv)
    nk = Skv // kb
    q = px.constrain(q, batch_entry, None, px.shard_if(S, px.model_axis), None)
    kpre = k.reshape(B, H, nk, kb, D).transpose(2, 0, 1, 3, 4)
    vpre = v.reshape(B, H, nk, kb, D).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(S)
    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, D), jnp.float32)

    def step(carry, j, kj, vj):
        m, l, acc = carry
        kpos = j * kb + jnp.arange(kb)
        mask = (qpos[:, None] >= kpos[None, :]) if causal else None
        return _online_block(q, kj, vj, m, l, acc, mask)

    if px.scan_unroll:
        carry = (m0, l0, a0)
        for j in range(nk):
            carry = step(carry, j, kpre[j], vpre[j])
        m, l, acc = carry
    else:
        def body(carry, kv_j):
            (mla, j) = carry
            kj, vj = kv_j
            return ((step(mla, j, kj, vj), j + 1), None)

        ((m, l, acc), _), _ = jax.lax.scan(body, ((m0, l0, a0), jnp.int32(0)),
                                           (kpre, vpre))
    out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
    return px.constrain(out, batch_entry, None,
                        px.shard_if(S, px.model_axis), None)


def decode_attend(q, k_cache, v_cache, pos, *, px: ParallelCtx, batch_entry,
                  seq_entry):
    """Single-token decode against a sequence-sharded KV cache.

    q: (B, Hq, D); caches: (B, Skv, Hkv, D) with Skv sharded (flash-decode:
    each shard computes partial stats; GSPMD's all-reduces over the sharded
    Skv dim combine them exactly).
    """
    B, H, D = q.shape
    Skv, Hkv = k_cache.shape[1], k_cache.shape[2]
    scale = D ** -0.5
    k = _expand_kv(k_cache.transpose(0, 2, 1, 3), H)  # (B,Hq,Skv,D)
    v = _expand_kv(v_cache.transpose(0, 2, 1, 3), H)
    s = jnp.einsum("bhd,bhkd->bhk", q, k).astype(jnp.float32) * scale
    valid = jnp.arange(Skv)[None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    s = px.constrain(s, batch_entry, None, seq_entry)
    p = jax.nn.softmax(s, axis=-1)  # reductions over sharded Skv -> psum
    out = jnp.einsum("bhk,bhkd->bhd", p.astype(COMPUTE_DT), v)
    return px.constrain(out, batch_entry, None, None)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------


def _project_qkv(p, x, rope_theta, positions, px, batch_entry, *, n_heads):
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(COMPUTE_DT))
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(COMPUTE_DT))
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(COMPUTE_DT))
    if "bq" in p:
        q = q + p["bq"].astype(COMPUTE_DT)[None, :, None, :]
        k = k + p["bk"].astype(COMPUTE_DT)[None, :, None, :]
        v = v + p["bv"].astype(COMPUTE_DT)[None, :, None, :]
    if rope_theta:
        q = apply_rope(q, positions[:, None, :], rope_theta)
        k = apply_rope(k, positions[:, None, :], rope_theta)
    return q, k, v


def gqa_fwd(p, x, *, cfg, px: ParallelCtx, causal: bool, batch_entry,
            positions=None, kv_override=None, return_kv: bool = False):
    """Full-sequence GQA attention (train / prefill).

    kv_override: (k, v) from an encoder for cross-attention.
    return_kv: also return (k, v) laid out (B, S, Hkv, D) for the cache.
    """
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, x, cfg.rope_theta, positions, px, batch_entry,
                           n_heads=H)
    if kv_override is not None:
        k, v = kv_override
    head_entry = px.shard_if(H, px.model_axis)
    kv_entry = px.shard_if(Hkv, px.model_axis)
    if px.seq_shard_attn or head_entry is None:
        k = px.constrain(k, batch_entry, None, None, None)
        v = px.constrain(v, batch_entry, None, None, None)
        out = flash_seq(q, _expand_kv(k, H), _expand_kv(v, H), causal=causal,
                        px=px, batch_entry=batch_entry)
    else:
        q = px.constrain(q, batch_entry, head_entry, None, None)
        k = px.constrain(k, batch_entry, kv_entry, None, None)
        v = px.constrain(v, batch_entry, kv_entry, None, None)
        out = flash_heads(q, _expand_kv(k, H), _expand_kv(v, H), causal=causal,
                          px=px, batch_entry=batch_entry, head_entry=head_entry)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(COMPUTE_DT))
    # land directly in the sequence-parallel layout (reduce-scatter, not
    # all-reduce): never materialize a full-S unsharded residual
    y = px.constrain(y, batch_entry, px.seq_entry(S), None)
    if return_kv:
        return y, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
    return y


def gqa_decode(p, x, cache, pos, *, cfg, px: ParallelCtx, batch_entry,
               seq_entry, cross: bool = False):
    """One-token decode. x: (B, 1, d). cache: dict(k,v): (B,Smax,Hkv,Dh).

    Returns (y, new_cache). For cross-attention (enc-dec) the cache is
    read-only.
    """
    B = x.shape[0]
    H, Dh = cfg.n_heads, cfg.resolved_head_dim
    positions = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos
    q, k, v = _project_qkv(p, x, cfg.rope_theta, positions, px, batch_entry,
                           n_heads=H)
    if not cross:
        k_new = k.transpose(0, 2, 1, 3).astype(cache["k"].dtype)  # (B,1,Hkv,D)
        v_new = v.transpose(0, 2, 1, 3).astype(cache["v"].dtype)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos_scalar(pos), 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos_scalar(pos), 1)
        cache = {"k": ck, "v": cv}
    out = decode_attend(q[:, :, 0, :], cache["k"], cache["v"],
                        pos_scalar(pos), px=px, batch_entry=batch_entry,
                        seq_entry=seq_entry)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(COMPUTE_DT))[:, None, :]
    return px.constrain(y, batch_entry, None, None), cache


def pos_scalar(pos):
    return pos if pos.ndim == 0 else pos.reshape(-1)[0]


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------


def mla_fwd(p, x, *, cfg, px: ParallelCtx, batch_entry, positions=None,
            return_latent: bool = False):
    """MLA train/prefill: materialize per-head K/V from the latent, then
    run head-sharded flash (128 heads divide the model axis)."""
    c = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(COMPUTE_DT))
    q = jnp.einsum("bsr,rhk->bhsk", cq, p["w_uq"].astype(COMPUTE_DT))
    q_nope, q_rope = jnp.split(q, [c.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions[:, None, :], cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(COMPUTE_DT))
    ckv, k_rope = jnp.split(ckv_full, [c.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, None, :, :], positions[:, None, :],
                        cfg.rope_theta)  # (B,1,S,rope)
    k_nope = jnp.einsum("bsr,rhk->bhsk", ckv, p["w_uk"].astype(COMPUTE_DT))
    v = jnp.einsum("bsr,rhk->bhsk", ckv, p["w_uv"].astype(COMPUTE_DT))

    head_entry = px.shard_if(H, px.model_axis)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, H, S, c.qk_rope_head_dim))], -1)
    qf = px.constrain(qf, batch_entry, head_entry, None, None)
    kf = px.constrain(kf, batch_entry, head_entry, None, None)
    v = px.constrain(v, batch_entry, head_entry, None, None)
    out = flash_heads(qf, kf, v, causal=True, px=px, batch_entry=batch_entry,
                      head_entry=head_entry)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(COMPUTE_DT))
    y = px.constrain(y, batch_entry, px.seq_entry(S), None)
    if return_latent:
        return y, ckv_full  # (B,S, kv_rank + rope) — the decode cache line
    return y


def mla_decode(p, x, cache, pos, *, cfg, px: ParallelCtx, batch_entry,
               seq_entry):
    """MLA decode with weight absorption: scores live in the latent space,
    cache is (B, Smax, kv_rank + rope) — 576 floats/token, head-free."""
    c = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    positions = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(COMPUTE_DT))
    q = jnp.einsum("bsr,rhk->bhsk", cq, p["w_uq"].astype(COMPUTE_DT))
    q_nope, q_rope = jnp.split(q[:, :, 0, :], [c.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope[:, :, None, :], positions[:, None, :],
                        cfg.rope_theta)[:, :, 0, :]

    new_line = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(COMPUTE_DT))
    rp = pos_scalar(pos)
    new_rope = apply_rope(
        new_line[:, :, c.kv_lora_rank:][:, None, :, :],
        positions[:, None, :], cfg.rope_theta)[:, 0]
    new_line = jnp.concatenate([new_line[:, :, :c.kv_lora_rank], new_rope], -1)
    cache = jax.lax.dynamic_update_slice_in_dim(
        cache, new_line.astype(cache.dtype), rp, 1)

    lat, k_rope = cache[..., :c.kv_lora_rank], cache[..., c.kv_lora_rank:]
    # absorb W_uk into q: (B,H,nope) x (r,H,nope) -> (B,H,r)
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope, p["w_uk"].astype(COMPUTE_DT))
    scale = (c.qk_nope_head_dim + c.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, lat.astype(COMPUTE_DT))
         + jnp.einsum("bhk,bsk->bhs", q_rope, k_rope.astype(COMPUTE_DT)))
    s = s.astype(jnp.float32) * scale
    valid = jnp.arange(cache.shape[1])[None, None, :] <= rp
    s = jnp.where(valid, s, NEG_INF)
    # Skv is model-sharded (flash-decode): heads stay replicated here
    s = px.constrain(s, batch_entry, None, seq_entry)
    pw = jax.nn.softmax(s, axis=-1).astype(COMPUTE_DT)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", pw, lat.astype(COMPUTE_DT))
    out = jnp.einsum("bhr,rhk->bhk", ctx_lat, p["w_uv"].astype(COMPUTE_DT))
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(COMPUTE_DT))[:, None, :]
    return px.constrain(y, batch_entry, None, None), cache

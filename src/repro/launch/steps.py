"""Step builders: train_step / prefill_step / serve_step.

``build_train_step`` wires together the model loss, microbatched gradient
accumulation (lax.scan, fp32 accumulators), AdamW with fp32 master
weights (ZeRO-1 sharded), aux-free MoE router-bias updates and the GAIA
expert-placement state. The returned StepBundle carries everything the
dry-run / trainer needs to jit with explicit shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import specs as specs_mod
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.optim.adafactor import (adafactor_apply, adafactor_init,
                                   adafactor_lean_apply, adafactor_lean_init)
from repro.optim.adamw import AdamWConfig, adamw_apply, adamw_init
from repro.parallel import sharding as shard_mod
from repro.parallel.ctx import ParallelCtx

BIAS_LR = 1e-3  # aux-free router bias update rate (DeepSeek-V3)


@dataclasses.dataclass
class StepBundle:
    fn: Callable
    in_sds: tuple
    in_specs: tuple
    out_specs: Any
    donate: tuple = ()


def _param_sds(cfg: ArchConfig):
    return jax.eval_shape(
        lambda k: lm_mod.init_params(k, cfg), jax.random.key(0))


def _extras_sds(cfg: ArchConfig):
    return jax.eval_shape(lambda: lm_mod.init_extras(cfg))


def extras_specs(cfg, px):
    if cfg.moe is None:
        return {}
    return {"router_bias": P(), "placement": P()}


def model_fns(cfg: ArchConfig):
    """(loss_fn, prefill_fn, decode_fn) for this architecture family."""
    if cfg.encoder_decoder:
        return (encdec_mod.encdec_loss, encdec_mod.encdec_prefill,
                encdec_mod.encdec_decode)
    return lm_mod.loss_fn, lm_mod.prefill, lm_mod.decode_step


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def _update_router_bias(extras, metrics):
    """Aux-loss-free balancing: push the selection bias of overloaded
    experts down, underloaded up (sign update, DeepSeek-V3 §2.1.2)."""
    if "expert_counts" not in metrics or "router_bias" not in extras:
        return extras
    counts = metrics["expert_counts"].astype(jnp.float32)  # (Lmoe, E)
    mean = counts.mean(axis=-1, keepdims=True)
    bias = extras["router_bias"] + BIAS_LR * jnp.sign(mean - counts)
    return dict(extras, router_bias=bias)


def build_train_step(cfg: ArchConfig, shape: ShapeConfig, px: ParallelCtx,
                     opt: Optional[AdamWConfig] = None) -> StepBundle:
    opt = opt or AdamWConfig()
    loss_fn, _, _ = model_fns(cfg)
    M = px.num_microbatches
    assert shape.global_batch % M == 0, (shape.global_batch, M)
    opt_init, opt_apply = {
        "adamw": (adamw_init, adamw_apply),
        "adafactor": (adafactor_init, adafactor_apply),
        "adafactor_lean": (adafactor_lean_init, adafactor_lean_apply),
    }[px.optimizer]
    gdt = jnp.bfloat16 if px.grad_dtype == "bf16" else jnp.float32

    p_sds = _param_sds(cfg)
    p_spec = shard_mod.param_specs(p_sds, px)
    # ZeRO-2: gradient accumulators live sharded over the data axes (the
    # constraint makes GSPMD reduce-scatter each microbatch's grads).
    g_spec = jax.tree.map(lambda s, l: shard_mod.zero1_spec(s, l.shape, px),
                          p_spec, p_sds)

    def train_step(params, opt_state, extras, batch):
        def to_micro(x):
            return x.reshape(M, x.shape[0] // M, *x.shape[1:])

        mb = jax.tree.map(to_micro, batch)

        def constrain_g(tree):
            if px.mesh is None:
                return tree
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, jax.sharding.NamedSharding(px.mesh, s)), tree, g_spec)

        g0 = constrain_g(jax.tree.map(lambda p: jnp.zeros(p.shape, gdt),
                                      params))

        def micro(carry, b):
            gacc, ex = carry
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, b, ex, cfg, px), has_aux=True)(params)
            ex = _update_router_bias(ex, metrics)
            gacc = constrain_g(jax.tree.map(
                lambda a, g: a + g.astype(gdt), gacc, grads))
            scalars = {k: v for k, v in metrics.items()
                       if getattr(v, "ndim", None) == 0}
            scalars["loss"] = loss
            return (gacc, ex), scalars

        (gsum, extras), scalars = jax.lax.scan(micro, (g0, extras), mb)
        grads = jax.tree.map(lambda g: g / M, gsum)
        params, opt_state, om = opt_apply(opt, grads, opt_state, params)
        metrics = jax.tree.map(lambda x: x.mean(), scalars)
        metrics.update(om)
        return params, opt_state, extras, metrics

    # --- jit signature -----------------------------------------------------
    o_sds = jax.eval_shape(opt_init, p_sds)
    o_spec = shard_mod.opt_specs(p_spec, p_sds, px, zero1=px.zero1,
                                 factored=px.optimizer.startswith("adafactor"),
                                 lean=(px.optimizer == "adafactor_lean"))
    e_sds = _extras_sds(cfg)
    e_spec = extras_specs(cfg, px)
    b_sds, b_spec = specs_mod.train_batch_specs(cfg, shape, px)
    metrics_spec = None  # replicated scalars

    out_specs = (p_spec, o_spec, e_spec, metrics_spec)
    return StepBundle(
        fn=train_step,
        in_sds=(p_sds, o_sds, e_sds, b_sds),
        in_specs=(p_spec, o_spec, e_spec, b_spec),
        out_specs=out_specs,
        donate=(0, 1, 2),
    )


# ---------------------------------------------------------------------------
# Prefill / serve
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ArchConfig, shape: ShapeConfig,
                       px: ParallelCtx) -> StepBundle:
    _, prefill_fn, _ = model_fns(cfg)

    def prefill_step(params, batch):
        return prefill_fn(params, batch, cfg, px, cache_len=shape.seq_len)

    p_sds = _param_sds(cfg)
    p_spec = shard_mod.param_specs(p_sds, px)
    b_sds, b_spec = specs_mod.prefill_batch_specs(cfg, shape, px)
    cache_sds, cache_spec = specs_mod.cache_specs(cfg, shape, px)
    logits_spec = None
    return StepBundle(
        fn=prefill_step,
        in_sds=(p_sds, b_sds),
        in_specs=(p_spec, b_spec),
        out_specs=(cache_spec, logits_spec),
    )


def build_serve_step(cfg: ArchConfig, shape: ShapeConfig,
                     px: ParallelCtx) -> StepBundle:
    _, _, decode_fn = model_fns(cfg)

    def serve_step(params, extras, cache, tokens, pos):
        new_cache, logits = decode_fn(params, cache, tokens, pos, extras,
                                      cfg, px)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return new_cache, next_tokens

    p_sds = _param_sds(cfg)
    p_spec = shard_mod.param_specs(p_sds, px)
    e_sds = _extras_sds(cfg)
    e_spec = extras_specs(cfg, px)
    d_sds, d_spec = specs_mod.decode_input_specs(cfg, shape, px)
    return StepBundle(
        fn=serve_step,
        in_sds=(p_sds, e_sds, d_sds["cache"], d_sds["tokens"], d_sds["pos"]),
        in_specs=(p_spec, e_spec, d_spec["cache"], d_spec["tokens"],
                  d_spec["pos"]),
        out_specs=(d_spec["cache"], P(px.batch_spec(shape.global_batch))),
        donate=(2,),
    )


def build_step(cfg, shape, px) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, shape, px)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, px)
    return build_serve_step(cfg, shape, px)

"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the 512-device placeholder env
var must be set by the entrypoint (dryrun.py) before the first jax call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, tp: int = 16):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips).

    Axes: "pod" (slow inter-pod links — DP/DiLoCo/pipeline only),
    "data" (batch), "model" (TP/EP/sequence).

    ``tp`` re-splits the 256 intra-pod chips between the data and model
    axes (a §Perf hillclimbing knob: TP degree trades TP-gather volume
    against DP-gradient volume). tp=16 is the assignment's baseline mesh.
    """
    assert 256 % tp == 0, tp
    shape = (2, 256 // tp, tp) if multi_pod else (256 // tp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the SPMD
partitioner must accept every sharding, the compiled module's memory
analysis must fit the chip, and the roofline terms (§Roofline) are
derived from cost_analysis + the collective ops parsed out of the
post-partitioning HLO.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch yi-9b --shape train_4k --mesh single --out results/dryrun
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.parallel import sharding as shard_mod
from repro.parallel.ctx import make_ctx

# TPU v5e hardware constants (targets; this container is CPU-only)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,512,1024]{...}' -> bytes. Tuples handled by the caller."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def parse_collectives(hlo_text: str):
    """Sum per-device output bytes of every collective op in the SPMD
    (post-partitioning) HLO, bucketed by op kind."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    # lines look like:  %x = bf16[8,128]{1,0} all-gather(...), replica_groups=
    pat = re.compile(
        r"=\s+(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z\-]+)(?:-start)?\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        shape_part, op = m.groups()
        if op.endswith("-done"):
            continue
        op = op.replace("-start", "")
        if op not in out:
            continue
        if shape_part.startswith("("):
            inner = re.findall(r"[a-z0-9]+\[[0-9,]*\][^,)]*", shape_part)
            b = sum(_shape_bytes(s) for s in inner)
        else:
            b = _shape_bytes(shape_part)
        out[op] += b
        counts[op] += 1
    return out, counts


def _per_dev_shape(shape, spec, mesh, *, data_unsharded=False):
    """Per-device dims of a leaf under `spec` on `mesh`."""
    dims = list(shape)
    entries = list(spec) + [None] * (len(dims) - len(spec))
    for i, e in enumerate(entries):
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is None:
                continue
            if data_unsharded and a != "model":
                continue
            dims[i] //= mesh.shape[a]
    return tuple(dims)


def bf16_emulation_correction(hlo_text, in_sds, in_specs, mesh) -> dict:
    """XLA:CPU emulates bf16 by materializing f32 copies of bf16 buffers
    (absent pre-backend; never emitted by the TPU backend). Quantify the
    inflation so §Dry-run can report a TPU-corrected peak.

    f32 tensors whose dims equal a bf16 input leaf's per-device dims (or
    the leaf with data axes unsharded — the FSDP all-gather) are emulation
    buffers running at 2x the width the TPU backend would use. We subtract
    HALF their size: exact for working buffers (f32 here, bf16 on TPU),
    conservative for pure input copies (cost 0 on TPU). The corrected
    number is therefore still an upper bound.
    """
    full, half = {}, {}
    leaves = jax.tree.leaves(in_sds)
    specs = jax.tree.leaves(in_specs, is_leaf=lambda x: x is None or
                            isinstance(x, jax.sharding.PartitionSpec))
    if len(specs) != len(leaves):  # spec tree uses None for replicated
        specs = [jax.sharding.PartitionSpec()] * len(leaves)
    for leaf, spec in zip(leaves, specs):
        if leaf.dtype != jnp.bfloat16:
            continue
        spec = spec or jax.sharding.PartitionSpec()
        full[_per_dev_shape(leaf.shape, spec, mesh)] = True
        g = _per_dev_shape(leaf.shape, spec, mesh, data_unsharded=True)
        half.setdefault(g, True)
    seen = set()
    sub_full = sub_half = 0
    for m in re.finditer(
            r"%?([\w.\-]+)\s+=\s+f32\[([0-9,]*)\]\S*\s+(\w+)", hlo_text):
        name, dims_s, op = m.groups()
        if op not in ("convert", "fusion", "copy", "all-gather",
                      "all-gather-start", "bitcast"):
            continue
        base = name.split(".")[0]
        dims = tuple(int(d) for d in dims_s.split(",")) if dims_s else ()
        if (base, dims) in seen:
            continue
        size = 4
        for d in dims:
            size *= d
        if dims in full:
            seen.add((base, dims))
            sub_full += size // 2
        elif dims in half:
            seen.add((base, dims))
            sub_half += size // 2
    return {"bf16_emulation_bytes": sub_full + sub_half,
            "input_shaped_inflation": sub_full, "gather_inflation": sub_half}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode: D=batch
    tokens per step. Train counts fwd+bwd (6), prefill/decode fwd (2)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    toks = shape.tokens if shape.kind == "prefill" else shape.global_batch
    return 2.0 * n * toks


def make_cell(arch: str, shape_name: str, mesh_kind: str, px_overrides=None):
    """(cfg, shape, mesh, px) with the production policy for this cell."""
    px_overrides = dict(px_overrides or {})
    tp = px_overrides.pop("tp", 16)
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"), tp=tp)
    huge = cfg.param_count() > 100e9  # deepseek-v3: FSDP + adafactor + bf16
    kw = dict(
        seq_shard_attn=(cfg.n_heads % mesh.shape["model"] != 0),
        num_microbatches=(max(1, shape.global_batch //
                              (mesh.devices.size // mesh.shape["model"]))
                          if shape.kind == "train" else 1),
        fsdp=huge,
        optimizer="adafactor_lean" if huge else "adamw",
        grad_dtype="bf16" if huge else "f32",
        loss_chunk=1024 if huge else 0,
    )
    kw.update(px_overrides or {})
    px = make_ctx(mesh, **kw)
    return cfg, shape, mesh, px


def run_cell(arch: str, shape_name: str, mesh_kind: str, px_overrides=None):
    cfg = get_arch(arch)
    if shape_name not in cfg.shapes():
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "inapplicable (see DESIGN.md §Arch-applicability)"}
    cfg, shape, mesh, px = make_cell(arch, shape_name, mesh_kind,
                                     px_overrides)
    bundle = build_step(cfg, shape, px)
    in_sh = jax.tree.map(
        lambda s: shard_mod.to_shardings(s, px), bundle.in_specs,
        is_leaf=lambda x: x is None or isinstance(x, jax.sharding.PartitionSpec))
    out_sh = jax.tree.map(
        lambda s: shard_mod.to_shardings(s, px), bundle.out_specs,
        is_leaf=lambda x: x is None or isinstance(x, jax.sharding.PartitionSpec))

    t0 = time.time()
    jitted = jax.jit(bundle.fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=bundle.donate)
    lowered = jitted.lower(*bundle.in_sds)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll_bytes, coll_counts = parse_collectives(hlo)

    chips = mesh.devices.size
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    cbytes_dev = float(sum(coll_bytes.values()))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = cbytes_dev / LINK_BW
    mflops = model_flops(cfg, shape)
    mflops_dev = mflops / chips
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    bound = max(t_compute, t_memory, t_coll)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": cbytes_dev,
        "collective_breakdown": coll_bytes,
        "collective_counts": coll_counts,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mflops,
        "useful_flop_ratio": (mflops_dev / flops_dev) if flops_dev else 0.0,
        "roofline_fraction": (t_compute / bound) if bound else 0.0,
        "arg_bytes_per_dev": mem.argument_size_in_bytes,
        "out_bytes_per_dev": mem.output_size_in_bytes,
        "temp_bytes_per_dev": mem.temp_size_in_bytes,
        "code_bytes": mem.generated_code_size_in_bytes,
        "peak_bytes_per_dev": (mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               - mem.alias_size_in_bytes
                               + mem.temp_size_in_bytes),
    }
    # XLA:CPU inflates bf16 buffers to f32 (emulation); correct toward the
    # TPU backend, which compiles bf16 natively. Both numbers reported.
    corr = bf16_emulation_correction(hlo, bundle.in_sds, bundle.in_specs,
                                     mesh)
    result.update(corr)
    result["peak_bytes_per_dev_tpu_est"] = (
        result["peak_bytes_per_dev"] - corr["bf16_emulation_bytes"])
    return result


def run_components(arch: str, shape_name: str, mesh_kind: str,
                   px_overrides=None):
    """Phase-2 roofline: per-loop-body component costing (launch/costs.py)
    with known trip-count multipliers — corrects XLA cost_analysis'
    count-while-bodies-once undercount."""
    from repro.launch import costs as costs_mod
    cfg = get_arch(arch)
    if shape_name not in cfg.shapes():
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped"}
    cfg, shape, mesh, px = make_cell(arch, shape_name, mesh_kind,
                                     px_overrides)
    out = costs_mod.component_costs(cfg, shape, px, parse_collectives)
    chips = mesh.devices.size
    mflops = model_flops(cfg, shape)
    t_c = out["flops"] / PEAK_FLOPS
    t_m = out["bytes"] / HBM_BW
    t_l = out["collective_bytes"] / LINK_BW
    bound = max(t_c, t_m, t_l)
    out.update({
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "chips": chips,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_l,
        "dominant": max((("compute", t_c), ("memory", t_m),
                         ("collective", t_l)), key=lambda kv: kv[1])[0],
        "model_flops": mflops,
        "useful_flop_ratio": (mflops / chips / out["flops"])
        if out["flops"] else 0.0,
        "roofline_fraction": (t_c / bound) if bound else 0.0,
    })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--components", action="store_true",
                    help="component-pass roofline instead of the full step")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--causal-skip", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--q-block", type=int, default=0)
    ap.add_argument("--kv-block", type=int, default=0)
    ap.add_argument("--zero1", type=int, default=1)
    ap.add_argument("--tp", type=int, default=0,
                    help="TP degree (re-splits the 256 intra-pod chips)")
    ap.add_argument("--seq-parallel", type=int, default=-1)
    ap.add_argument("--ep2d", type=int, default=-1)
    ap.add_argument("--fsdp", type=int, default=-1)
    ap.add_argument("--optimizer", default="")
    ap.add_argument("--grad-dtype", default="")
    ap.add_argument("--loss-chunk", type=int, default=-1)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    ov = {"remat": args.remat, "causal_skip": bool(args.causal_skip),
          "zero1": bool(args.zero1)}
    if args.tp:
        ov["tp"] = args.tp
    if args.seq_parallel >= 0:
        ov["seq_parallel"] = bool(args.seq_parallel)
    if args.ep2d >= 0:
        ov["ep2d"] = bool(args.ep2d)
    if args.fsdp >= 0:
        ov["fsdp"] = bool(args.fsdp)
    if args.optimizer:
        ov["optimizer"] = args.optimizer
    if args.grad_dtype:
        ov["grad_dtype"] = args.grad_dtype
    if args.loss_chunk >= 0:
        ov["loss_chunk"] = args.loss_chunk
    if args.microbatches:
        ov["num_microbatches"] = args.microbatches
    if args.q_block:
        ov["q_block"] = args.q_block
    if args.kv_block:
        ov["kv_block"] = args.kv_block

    if args.components:
        res = run_components(args.arch, args.shape, args.mesh, ov)
        res["overrides"] = dict(ov)
        os.makedirs(args.out, exist_ok=True)
        tag = f"_{args.tag}" if args.tag else ""
        path = os.path.join(
            args.out, f"{args.arch}_{args.shape}_{args.mesh}{tag}_comp.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        if res["status"] == "ok":
            print(f"[components] {args.arch} x {args.shape} x {args.mesh}: "
                  f"dominant={res['dominant']} t=(c {res['t_compute_s']:.3e},"
                  f" m {res['t_memory_s']:.3e}, coll "
                  f"{res['t_collective_s']:.3e}) "
                  f"useful={res['useful_flop_ratio']:.3f}")
        else:
            print(f"[components] {args.arch} x {args.shape} x {args.mesh}: "
                  f"{res['status']}")
        return 0

    res = run_cell(args.arch, args.shape, args.mesh, ov)
    res["overrides"] = {k: v for k, v in ov.items()}
    os.makedirs(args.out, exist_ok=True)
    tag = f"_{args.tag}" if args.tag else ""
    path = os.path.join(args.out,
                        f"{args.arch}_{args.shape}_{args.mesh}{tag}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    if res["status"] == "ok":
        print(f"[dryrun] {args.arch} x {args.shape} x {args.mesh}: OK "
              f"compile={res['compile_s']}s dominant={res['dominant']} "
              f"t=(c {res['t_compute_s']:.3e}, m {res['t_memory_s']:.3e}, "
              f"coll {res['t_collective_s']:.3e}) "
              f"useful={res['useful_flop_ratio']:.2f} "
              f"peak/dev={res['peak_bytes_per_dev']/2**30:.2f}GiB "
              f"(tpu-est {res['peak_bytes_per_dev_tpu_est']/2**30:.2f}GiB)")
    else:
        print(f"[dryrun] {args.arch} x {args.shape} x {args.mesh}: "
              f"{res['status']} ({res.get('reason','')})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

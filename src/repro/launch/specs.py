"""ShapeDtypeStruct stand-ins + PartitionSpecs for every model input.

``input_specs(arch, shape)`` is the single source of truth the dry-run,
trainer and server all build their jit signatures from. No allocation
happens here — everything is ShapeDtypeStruct (the shannon/kernels
pattern: weak-type-correct, shardable, zero bytes).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.encdec import FRAME_DIM
from repro.models.layers import COMPUTE_DT
from repro.parallel.ctx import ParallelCtx

SDS = jax.ShapeDtypeStruct


def _batch_P(px: ParallelCtx, b: int, *rest) -> P:
    return P(px.batch_spec(b), *rest)


# ---------------------------------------------------------------------------
# Batch inputs
# ---------------------------------------------------------------------------


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig, px: ParallelCtx
                      ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    B, S = shape.global_batch, shape.seq_len
    sds = {"tokens": SDS((B, S), jnp.int32),
           "loss_mask": SDS((B, S), jnp.float32)}
    spec = {"tokens": _batch_P(px, B, None),
            "loss_mask": _batch_P(px, B, None)}
    if cfg.encoder_decoder:
        sds["frames"] = SDS((B, S, FRAME_DIM), COMPUTE_DT)
        spec["frames"] = _batch_P(px, B, None, None)
    if cfg.n_vision_tokens:
        sds["vision_embeds"] = SDS((B, cfg.n_vision_tokens, cfg.d_model),
                                   COMPUTE_DT)
        spec["vision_embeds"] = _batch_P(px, B, None, None)
    return sds, spec


def prefill_batch_specs(cfg, shape, px):
    B, S = shape.global_batch, shape.seq_len
    if cfg.encoder_decoder:
        return ({"frames": SDS((B, S, FRAME_DIM), COMPUTE_DT)},
                {"frames": _batch_P(px, B, None, None)})
    sds = {"tokens": SDS((B, S), jnp.int32)}
    spec = {"tokens": _batch_P(px, B, None)}
    if cfg.n_vision_tokens:
        sds["vision_embeds"] = SDS((B, cfg.n_vision_tokens, cfg.d_model),
                                   COMPUTE_DT)
        spec["vision_embeds"] = _batch_P(px, B, None, None)
    return sds, spec


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, px: ParallelCtx):
    """(sds_tree, spec_tree) for the KV/state cache at shape.seq_len."""
    B, S = shape.global_batch, shape.seq_len
    b = px.batch_spec(B)
    seq = (px.seq_mega_spec(S) if B == 1
           else px.shard_if(S, px.model_axis))
    L, d = cfg.n_layers, cfg.d_model

    if cfg.encoder_decoder:
        Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
        kv = lambda: SDS((L, B, S, Hkv, Dh), COMPUTE_DT)
        sp = P(None, b, seq, None, None)
        return ({"self": {"k": kv(), "v": kv()},
                 "cross": {"k": kv(), "v": kv()}},
                {"self": {"k": sp, "v": sp}, "cross": {"k": sp, "v": sp}})

    if cfg.rwkv is not None:
        H, N = cfg.n_heads, cfg.rwkv.head_dim
        h_entry = px.shard_if(H, px.model_axis)
        return ({"state": SDS((L, B, H, N, N), jnp.float32),
                 "shift_a": SDS((L, B, d), COMPUTE_DT),
                 "shift_f": SDS((L, B, d), COMPUTE_DT)},
                {"state": P(None, b, h_entry, None, None),
                 "shift_a": P(None, b, None), "shift_f": P(None, b, None)})

    if cfg.ssm is not None:  # zamba2
        s = cfg.ssm
        di = s.expand * d
        H = di // s.head_dim
        n_inv = (L + cfg.shared_every - 1) // cfg.shared_every
        hd2 = 2 * d // cfg.n_heads
        ch = di + 2 * s.d_state
        h_entry = px.shard_if(H, px.model_axis)
        return ({"mamba": {"ssm": SDS((L, B, H, s.head_dim, s.d_state),
                                      jnp.float32),
                           "conv": SDS((L, B, s.d_conv - 1, ch), COMPUTE_DT)},
                 "attn_k": SDS((n_inv, B, S, cfg.n_kv_heads, hd2), COMPUTE_DT),
                 "attn_v": SDS((n_inv, B, S, cfg.n_kv_heads, hd2), COMPUTE_DT)},
                {"mamba": {"ssm": P(None, b, h_entry, None, None),
                           "conv": P(None, b, None, None)},
                 "attn_k": P(None, b, seq, None, None),
                 "attn_v": P(None, b, seq, None, None)})

    if cfg.mla is not None:  # deepseek: latent line cache
        r = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        fk = cfg.moe.first_k_dense if cfg.moe else 0
        out_sds = {"main": SDS((L - fk, B, S, r), COMPUTE_DT)}
        out_sp = {"main": P(None, b, seq, None)}
        if fk:
            out_sds["dense"] = SDS((fk, B, S, r), COMPUTE_DT)
            out_sp["dense"] = P(None, b, seq, None)
        return out_sds, out_sp

    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    fk = cfg.moe.first_k_dense if cfg.moe else 0
    kv = lambda n: {"k": SDS((n, B, S, Hkv, Dh), COMPUTE_DT),
                    "v": SDS((n, B, S, Hkv, Dh), COMPUTE_DT)}
    sp = {"k": P(None, b, seq, None, None), "v": P(None, b, seq, None, None)}
    out_sds = {"main": kv(L - fk)}
    out_sp = {"main": sp}
    if fk:
        out_sds["dense"] = kv(fk)
        out_sp["dense"] = dict(sp)
    return out_sds, out_sp


def decode_input_specs(cfg, shape, px):
    B = shape.global_batch
    cache_sds, cache_sp = cache_specs(cfg, shape, px)
    sds = {"cache": cache_sds,
           "tokens": SDS((B,), jnp.int32),
           "pos": SDS((), jnp.int32)}
    spec = {"cache": cache_sp,
            "tokens": P(px.batch_spec(B)),
            "pos": P()}
    return sds, spec


def input_specs(cfg: ArchConfig, shape: ShapeConfig, px: ParallelCtx):
    """Dispatch on the shape kind. Returns (sds_tree, spec_tree)."""
    if shape.kind == "train":
        return train_batch_specs(cfg, shape, px)
    if shape.kind == "prefill":
        return prefill_batch_specs(cfg, shape, px)
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape, px)
    raise ValueError(shape.kind)

"""Roofline component costing (phase 2 of the dry-run).

XLA's HLO cost analysis counts a while-loop body ONCE, so the aggregate
flops of a scan-over-layers train step undercount by ~L x M. This pass
decomposes the step into its loop bodies, lowers each ONE body with the
production shardings and all inner scans unrolled (px.scan_unroll), and
recomposes:

  train:   L x M x grad(block)  +  M x grad(embed+head+loss)  +  1 x opt
  prefill: L x fwd(block)       +  1 x head
  decode:  L x decode(block)    +  1 x (embed+head)

Each component is a real SPMD lowering on the production mesh, so its
per-device flops/bytes AND its collectives (parsed from the partitioned
HLO) are exact; the multipliers are the known trip counts.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import blocks as blocks_mod
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models import mamba2 as m2
from repro.models import rwkv6 as r6
from repro.models.layers import COMPUTE_DT, chunked_xent, lm_head_fwd, \
    rmsnorm, softmax_xent
from repro.optim.adafactor import (adafactor_apply, adafactor_init,
                                   adafactor_lean_apply, adafactor_lean_init)
from repro.optim.adamw import AdamWConfig, adamw_apply, adamw_init
from repro.parallel import sharding as shard_mod
from repro.parallel.ctx import ParallelCtx

SDS = jax.ShapeDtypeStruct


def _lower_component(fn, args_sds, args_specs, px, parse_collectives):
    shardings = jax.tree.map(
        lambda s: NamedSharding(px.mesh, s) if isinstance(s, P) else s,
        args_specs, is_leaf=lambda x: isinstance(x, P) or x is None)
    jitted = jax.jit(fn, in_shardings=shardings)
    compiled = jitted.lower(*args_sds).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll, _ = parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(sum(coll.values())),
    }


def _layer_subtree(p_sds, key):
    """Strip the leading stack dim from params[key]."""
    return jax.tree.map(lambda s: SDS(s.shape[1:], s.dtype), p_sds[key])


def component_plan(cfg: ArchConfig, shape: ShapeConfig, px: ParallelCtx
                   ) -> List[Tuple[str, Any, Any, Any, float]]:
    """[(name, fn, args_sds, args_specs, multiplier)] for this cell."""
    M = px.num_microbatches if shape.kind == "train" else 1
    B = shape.global_batch // M
    S = shape.seq_len
    d = cfg.d_model
    be = px.batch_spec(B)
    x_sds = SDS((B, S, d), COMPUTE_DT)
    x_spec = P(be, None, None)
    tok_sds = SDS((B, S), jnp.int32)
    p_sds = jax.eval_shape(lambda k: lm_mod.init_params(k, cfg),
                           jax.random.key(0))
    p_spec_full = shard_mod.param_specs(p_sds, px)
    train = shape.kind == "train"
    plan = []

    if shape.kind == "decode":
        return _decode_plan(cfg, shape, px, p_sds, be)

    def grad_of(f):
        if not train:
            return f
        if px.remat == "none":
            ck = f
        else:
            policy = (jax.checkpoint_policies.checkpoint_dots
                      if px.remat == "dots" else None)
            ck = jax.checkpoint(f, policy=policy)

        def g(p, *a):
            return jax.grad(
                lambda pp, *aa: ck(pp, *aa).astype(
                    jnp.float32).sum())(p, *a)
        return g

    def block_component(name, key, fn, mult, extra_sds=(), extra_specs=()):
        lp_sds = _layer_subtree(p_sds, key)
        lp_spec = shard_mod.param_specs(lp_sds, px)
        plan.append((name, grad_of(fn) if train else fn,
                     (lp_sds, x_sds) + tuple(extra_sds),
                     (lp_spec, x_spec) + tuple(extra_specs), mult))

    if cfg.encoder_decoder:
        def enc_fn(p, x):
            xa = rmsnorm(p["ln1"], x, cfg.norm_eps)
            from repro.models import attention as attn
            x = x + attn.gqa_fwd(p["attn"], xa, cfg=cfg, px=px, causal=False,
                                 batch_entry=be)
            from repro.models.layers import mlp_fwd
            xm = rmsnorm(p["ln2"], x, cfg.norm_eps)
            return x + mlp_fwd(p["mlp"], xm, px, be)

        def dec_fn(p, x):
            kv = encdec_mod._enc_cross_kv(p, x, cfg, px, be)
            out, _ = encdec_mod._dec_block_full(p, x, kv, cfg, px, be, False)
            return out

        block_component("enc_block", "enc_layers", enc_fn, cfg.n_layers * M)
        block_component("dec_block", "dec_layers", dec_fn, cfg.n_layers * M)
    elif cfg.rwkv is not None:
        def fn(p, x):
            B_ = x.shape[0]
            zero = {"state": jnp.zeros((B_, cfg.n_heads, cfg.rwkv.head_dim,
                                        cfg.rwkv.head_dim), jnp.float32),
                    "shift_a": jnp.zeros((B_, d), COMPUTE_DT),
                    "shift_f": jnp.zeros((B_, d), COMPUTE_DT)}
            return r6.rwkv_block_fwd(p, x, zero, cfg=cfg, px=px,
                                     batch_entry=be)[0]
        block_component("rwkv_block", "layers", fn, cfg.n_layers * M)
    elif cfg.ssm is not None:
        s = cfg.ssm
        di = s.expand * d

        def fn(p, x):
            B_ = x.shape[0]
            zero = {"ssm": jnp.zeros((B_, di // s.head_dim, s.head_dim,
                                      s.d_state), jnp.float32),
                    "conv": jnp.zeros((B_, s.d_conv - 1, di + 2 * s.d_state),
                                      COMPUTE_DT)}
            return m2.mamba2_fwd(p, x, zero, cfg=cfg, px=px,
                                 batch_entry=be)[0]
        block_component("mamba_block", "layers", fn, cfg.n_layers * M)

        def shared_fn(p, x):
            return blocks_mod.shared_block_fwd(p, x, x, cfg=cfg, px=px,
                                               batch_entry=be)[0]
        n_inv = (cfg.n_layers + cfg.shared_every - 1) // cfg.shared_every
        lp_sds = p_sds["shared_block"]
        lp_spec = shard_mod.param_specs(lp_sds, px)
        plan.append(("shared_block",
                     grad_of(shared_fn) if train else shared_fn,
                     (lp_sds, x_sds), (lp_spec, x_spec), n_inv * M))
    else:
        def tf_fn(p, x, rb=None, pl_=None):
            return blocks_mod.tf_block_fwd(p, x, cfg=cfg, px=px,
                                           batch_entry=be, router_bias=rb,
                                           placement=pl_)[0]
        if cfg.moe is not None:
            fk = cfg.moe.first_k_dense
            E = cfg.moe.num_experts
            rb_sds = SDS((E,), jnp.float32)
            pl_sds = SDS((E,), jnp.int32)
            block_component("moe_block", "layers", tf_fn,
                            (cfg.n_layers - fk) * M,
                            extra_sds=(rb_sds, pl_sds),
                            extra_specs=(P(), P()))
            if fk:
                block_component("dense_block", "dense_layers",
                                lambda p, x: tf_fn(p, x), fk * M)
            if cfg.mtp_depth:
                lp_sds = p_sds["mtp"]["block"]
                lp_spec = shard_mod.param_specs(lp_sds, px)
                plan.append(("mtp_block",
                             grad_of(lambda p, x: tf_fn(p, x)) if train
                             else (lambda p, x: tf_fn(p, x)),
                             (lp_sds, x_sds), (lp_spec, x_spec), 1 * M))
        else:
            block_component("tf_block", "layers", tf_fn, cfg.n_layers * M)

    # ---- head / loss ------------------------------------------------------
    emb_sds = p_sds["embed"]
    emb_spec = shard_mod.param_specs(emb_sds, px)
    if train:
        def head_fn(pe, h, toks):
            mask = jnp.ones_like(toks, jnp.float32)
            if px.loss_chunk:
                tot, cnt = chunked_xent(h, pe, toks, mask, px, be,
                                        px.loss_chunk)
                return tot / jnp.maximum(cnt, 1.0)
            logits = lm_head_fwd(pe, h, px, be)
            return softmax_xent(logits, toks, mask)

        def head_grad(pe, h, toks):
            return jax.grad(lambda a, b: head_fn(a, b, toks),
                            argnums=(0, 1))(pe, h)
        n_heads_passes = (1 + (1 if cfg.mtp_depth else 0)) * M
        plan.append(("head_loss", head_grad, (emb_sds, x_sds, tok_sds),
                     (emb_spec, x_spec, P(be, None)), n_heads_passes))

        # optimizer over the FULL param tree (no loops inside)
        opt_init, opt_apply = {
            "adamw": (adamw_init, adamw_apply),
            "adafactor": (adafactor_init, adafactor_apply),
            "adafactor_lean": (adafactor_lean_init, adafactor_lean_apply),
        }[px.optimizer]
        o_sds = jax.eval_shape(opt_init, p_sds)
        o_spec = shard_mod.opt_specs(
            p_spec_full, p_sds, px, zero1=px.zero1,
            factored=px.optimizer.startswith("adafactor"),
            lean=(px.optimizer == "adafactor_lean"))
        gdt = jnp.bfloat16 if px.grad_dtype == "bf16" else jnp.float32
        g_sds = jax.tree.map(lambda s: SDS(s.shape, gdt), p_sds)
        g_spec = jax.tree.map(
            lambda s, l: shard_mod.zero1_spec(s, l.shape, px),
            p_spec_full, p_sds)

        def opt_fn(g, o, p):
            return opt_apply(AdamWConfig(), g, o, p)[0]
        plan.append(("optimizer", opt_fn, (g_sds, o_sds, p_sds),
                     (g_spec, o_spec, p_spec_full), 1.0))
    else:
        def head_fn(pe, h):
            return lm_head_fwd(pe, h[:, -1:, :], px, be)
        plan.append(("head", head_fn, (emb_sds, x_sds),
                     (emb_spec, x_spec), 1.0))
    return plan


def _decode_plan(cfg, shape, px, p_sds, be):
    """Per-layer decode components (one token vs the cache)."""
    from repro.launch import specs as specs_mod
    B = shape.global_batch
    d = cfg.d_model
    x1_sds = SDS((B, 1, d), COMPUTE_DT)
    x1_spec = P(be, None, None)
    cache_sds, cache_spec = specs_mod.cache_specs(cfg, shape, px)
    pos_sds, pos_spec = SDS((), jnp.int32), P()
    strip = lambda t: jax.tree.map(lambda s: SDS(s.shape[1:], s.dtype), t)
    strip_sp = lambda t: jax.tree.map(
        lambda s: P(*s[1:]), t, is_leaf=lambda x: isinstance(x, P))
    plan = []

    if cfg.encoder_decoder:
        def fn(p, x, self_c, cross_c, pos):
            from repro.models import attention as attn
            from repro.models.layers import mlp_fwd
            S_self = self_c["k"].shape[1]
            seq_entry = px.shard_if(S_self, px.model_axis)
            xa = rmsnorm(p["ln1"], x, cfg.norm_eps)
            y, self_c = attn.gqa_decode(p["self_attn"], xa, self_c, pos,
                                        cfg=cfg, px=px, batch_entry=be,
                                        seq_entry=seq_entry)
            x = x + y
            xb = rmsnorm(p["ln2"], x, cfg.norm_eps)
            y, _ = attn.gqa_decode(p["cross_attn"], xb, cross_c,
                                   jnp.int32(S_self - 1), cfg=cfg, px=px,
                                   batch_entry=be, seq_entry=seq_entry,
                                   cross=True)
            x = x + y
            xm = rmsnorm(p["ln3"], x, cfg.norm_eps)
            return x + mlp_fwd(p["mlp"], xm, px, be)
        plan.append(("dec_block_decode", fn,
                     (_layer_subtree(p_sds, "dec_layers"), x1_sds,
                      strip(cache_sds["self"]), strip(cache_sds["cross"]),
                      pos_sds),
                     (shard_mod.param_specs(_layer_subtree(p_sds,
                                                           "dec_layers"), px),
                      x1_spec, strip_sp(cache_spec["self"]),
                      strip_sp(cache_spec["cross"]), pos_spec),
                     cfg.n_layers))
    elif cfg.rwkv is not None:
        def fn(p, x, c):
            return r6.rwkv_decode_step(p, x, c, cfg=cfg, px=px,
                                       batch_entry=be)[0]
        plan.append(("rwkv_decode", fn,
                     (_layer_subtree(p_sds, "layers"), x1_sds,
                      strip(cache_sds)),
                     (shard_mod.param_specs(_layer_subtree(p_sds, "layers"),
                                            px), x1_spec,
                      strip_sp(cache_spec)), cfg.n_layers))
    elif cfg.ssm is not None:
        def fn(p, x, c):
            return m2.mamba2_fwd(p, x, c, cfg=cfg, px=px, batch_entry=be,
                                 decode=True)[0]
        plan.append(("mamba_decode", fn,
                     (_layer_subtree(p_sds, "layers"), x1_sds,
                      strip(cache_sds["mamba"])),
                     (shard_mod.param_specs(_layer_subtree(p_sds, "layers"),
                                            px), x1_spec,
                      strip_sp(cache_spec["mamba"])), cfg.n_layers))

        def shfn(p, x, k, v, pos):
            seq_entry = (px.seq_mega_spec(k.shape[1]) if B == 1
                         else px.shard_if(k.shape[1], px.model_axis))
            return blocks_mod.shared_block_decode(
                p, x, x, {"k": k, "v": v}, pos, cfg=cfg, px=px,
                batch_entry=be, seq_entry=seq_entry)[0]
        n_inv = (cfg.n_layers + cfg.shared_every - 1) // cfg.shared_every
        ksds = SDS(cache_sds["attn_k"].shape[1:], cache_sds["attn_k"].dtype)
        ksp = P(*cache_spec["attn_k"][1:])
        plan.append(("shared_decode", shfn,
                     (p_sds["shared_block"], x1_sds, ksds, ksds, pos_sds),
                     (shard_mod.param_specs(p_sds["shared_block"], px),
                      x1_spec, ksp, ksp, pos_spec), n_inv))
    else:
        def fn(p, x, c, pos, rb=None, pl_=None):
            S_c = c.shape[1] if cfg.mla is not None else c["k"].shape[1]
            seq_entry = (px.seq_mega_spec(S_c) if B == 1
                         else px.shard_if(S_c, px.model_axis))
            return blocks_mod.tf_block_decode(
                p, x, c, pos, cfg=cfg, px=px, batch_entry=be,
                seq_entry=seq_entry, router_bias=rb, placement=pl_)[0]
        fk = cfg.moe.first_k_dense if cfg.moe else 0
        main_c = strip(cache_sds["main"])
        main_sp = strip_sp(cache_spec["main"])
        extra_sds, extra_sp = (), ()
        fn_use = fn
        if cfg.moe is not None:
            E = cfg.moe.num_experts
            extra_sds = (SDS((E,), jnp.float32), SDS((E,), jnp.int32))
            extra_sp = (P(), P())
        plan.append(("block_decode", fn_use,
                     (_layer_subtree(p_sds, "layers"), x1_sds, main_c,
                      pos_sds) + extra_sds,
                     (shard_mod.param_specs(_layer_subtree(p_sds, "layers"),
                                            px), x1_spec, main_sp,
                      pos_spec) + extra_sp, cfg.n_layers - fk))
        if fk:
            plan.append(("dense_block_decode",
                         lambda p, x, c, pos: fn(p, x, c, pos),
                         (_layer_subtree(p_sds, "dense_layers"), x1_sds,
                          strip(cache_sds["dense"]), pos_sds),
                         (shard_mod.param_specs(
                             _layer_subtree(p_sds, "dense_layers"), px),
                          x1_spec, strip_sp(cache_spec["dense"]), pos_spec),
                         fk))

    emb_sds = p_sds["embed"]
    emb_spec = shard_mod.param_specs(emb_sds, px)

    def head_fn(pe, h):
        return lm_head_fwd(pe, h, px, be)
    plan.append(("head", head_fn, (emb_sds, x1_sds), (emb_spec, x1_spec),
                 1.0))
    return plan


def component_costs(cfg, shape, px, parse_collectives) -> Dict[str, Any]:
    """Lower every component; return per-component and recomposed costs."""
    import dataclasses as dc
    px_u = dc.replace(px, scan_unroll=True)
    plan = component_plan(cfg, shape, px_u)
    out = {"components": {}}
    tot = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
    for name, fn, sds, specs, mult in plan:
        c = _lower_component(fn, sds, specs, px_u, parse_collectives)
        out["components"][name] = dict(c, multiplier=mult)
        for k in tot:
            tot[k] += c[k] * mult
    out.update(tot)
    return out

"""Multi-host launcher for the sharded engine (jax.distributed).

Within one process, `sharding="lp_device"` runs the "lp" mesh over
`--xla_force_host_platform_device_count` host threads — exact for
equivalence testing, but every "device" shares the process's cores, so
D>1 wall-clock measures orchestration overhead rather than speedup
(see benchmarks/exp5_sharded.py's honest-measurement note). This module
boots the *same* engine across P processes (one per host, or one per
core): `jax.distributed.initialize` wires them into a single JAX
runtime whose global device list concatenates every process's local
devices, the "lp" mesh spans all of them, and lp_shard's collectives
(psum / all_to_all / all_gather) move real bytes between processes —
the sparse halo's `bytes_on_wire` becomes physical traffic and D>1
measures real parallelism.

Launch P processes with identical arguments except --process-id:

    PYTHONPATH=src python -m repro.parallel.multihost \\
        --coordinator 10.0.0.1:9911 --processes 2 --process-id 0 ...
    PYTHONPATH=src python -m repro.parallel.multihost \\
        --coordinator 10.0.0.1:9911 --processes 2 --process-id 1 ...

or use --spawn to fork all P ranks locally from one command (smoke
testing). Process 0 prints aggregate counters as a ``RESULT {json}``
line — the exp5 harness idiom.

Capability gate: the CPU backend in current jaxlib cannot *execute*
cross-process computations ("Multiprocess computations aren't
implemented on the CPU backend") even though distributed init and the
global device list work. Rather than hang or crash mid-scan, the
launcher probes a 1-element psum right after mesh construction and
exits with code 3 and a clear message when the backend refuses —
multi-process runs need a GPU/TPU backend (or a jaxlib with CPU
cross-process collectives); single-process runs (--processes 1) work
everywhere and still exercise this exact code path.

Bit-identity note: every process builds the identical initial state
from the shared seed (init_sharded is deterministic), keeps only its
own slot rows, and assembles the global sharded arrays from them —
so a P-process run computes exactly what the single-process mesh of
the same total device count computes, which is bit-identical to the
single-device oracle (tests/test_sharding.py).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_UNSUPPORTED_EXIT = 3  # backend cannot run cross-process computations


def _build_config(args):
    from repro.core.abm import ABMConfig
    from repro.core.engine import EngineConfig
    from repro.core.heuristics import HeuristicConfig
    return EngineConfig(
        abm=ABMConfig(n_se=args.n_se, n_lp=args.n_lp, area=10_000.0,
                      speed=11.0, interaction_range=250.0, p_interact=0.2,
                      mobility=args.mobility),
        heuristic=HeuristicConfig(mf=1.2, mt=10),
        gaia_on=not args.gaia_off, timesteps=args.steps,
        sharding="lp_device", n_devices=0,  # 0 = all global devices
        mig_capacity=max(512, args.n_se // 4))


def _globalize(state, spec, mesh):
    """Turn the (identical-on-every-process) host state into global
    sharded arrays: each process keeps the slot rows its local devices
    own and `host_local_array_to_global_array` stitches the shards.
    Device order in the mesh is process-major (jax.devices()), so a
    process's share is one contiguous slot range."""
    import jax
    from jax.experimental import multihost_utils
    from repro.parallel import lp_shard

    fspecs = lp_shard._field_specs(spec)
    pid, nproc = jax.process_index(), jax.process_count()

    def to_global(v, pspec):
        sharded_axis = next(
            (i for i, ax in enumerate(pspec) if ax == "lp"), None)
        if sharded_axis is not None:
            share = v.shape[sharded_axis] // nproc
            v = jax.lax.slice_in_dim(v, pid * share, (pid + 1) * share,
                                     axis=sharded_axis)
        return multihost_utils.host_local_array_to_global_array(
            jax.device_get(v), mesh, pspec)

    from jax.sharding import PartitionSpec as P
    out = {k: to_global(v, fspecs.get(k, P())) for k, v in state.items()}
    return out


def _fetch_series(series):
    """Metrics come back replicated (out_specs P()), but a global array
    spanning non-addressable devices refuses np.asarray; pull the local
    replica instead."""
    import jax
    from jax.experimental import multihost_utils
    import numpy as np

    def fetch(v):
        if getattr(v, "is_fully_addressable", True):
            return np.asarray(v)
        return np.asarray(multihost_utils.process_allgather(v))
    return {k: fetch(v) for k, v in series.items()}


def _probe_collectives(mesh) -> bool:
    """One tiny psum over the global mesh: returns False when the
    backend cannot execute cross-process computations (current CPU
    jaxlib), instead of letting the first real scan die mid-flight."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    try:
        fn = jax.jit(shard_map(
            lambda x: jax.lax.psum(x, "lp"), mesh=mesh,
            in_specs=P(), out_specs=P(), check_rep=False))
        jax.block_until_ready(fn(jnp.float32(1.0)))
        return True
    except Exception as e:  # jaxlib raises XlaRuntimeError
        print(f"[multihost] collective probe failed: {e}", file=sys.stderr)
        return False


def run_distributed(args) -> int:
    import jax

    if args.processes > 1:
        jax.distributed.initialize(coordinator_address=args.coordinator,
                                   num_processes=args.processes,
                                   process_id=args.process_id)
    import jax.numpy as jnp
    from repro.core.engine import window_key_cfg
    from repro.parallel import lp_shard

    cfg = _build_config(args)
    spec = lp_shard.make_shard_spec(cfg)
    mesh = lp_shard.make_mesh(spec)
    pid = jax.process_index()
    if pid == 0:
        print(f"[multihost] {jax.process_count()} process(es), "
              f"{jax.device_count()} global devices, mesh lp={spec.n_dev}, "
              f"{spec.cap} slots/device, backend={jax.default_backend()}")
    if args.processes > 1 and not _probe_collectives(mesh):
        print(f"[multihost] backend {jax.default_backend()!r} cannot run "
              "cross-process computations; rerun with --processes 1 or on "
              "a GPU/TPU cluster", file=sys.stderr)
        return _UNSUPPORTED_EXIT

    state = lp_shard.init_sharded(jax.random.key(args.seed), cfg, spec)
    if args.processes > 1:
        state = _globalize(state, spec, mesh)
    scan = lp_shard._compiled_window_sharded(window_key_cfg(cfg), args.steps)
    mf = jnp.float32(cfg.heuristic.mf)
    state, series = jax.block_until_ready(scan(state, mf))  # compile+run
    t0 = time.time()
    state, series = jax.block_until_ready(scan(state, mf))
    dt = (time.time() - t0) / args.steps
    counters = lp_shard._series_counters(_fetch_series(series))
    if pid == 0:
        out = dict(processes=args.processes, devices=jax.device_count(),
                   n_se=args.n_se, n_lp=args.n_lp, steps=args.steps,
                   per_step_s=round(dt, 4),
                   bytes_on_wire=counters["bytes_on_wire"],
                   mean_halo_frac=round(counters["mean_halo_frac"], 4),
                   mean_lcr=round(counters["mean_lcr"], 4),
                   migrations=counters["migrations"],
                   shard_overflow=counters["shard_overflow"])
        print("RESULT " + json.dumps(out), flush=True)
    return 0


def _spawn_ranks(args) -> int:
    """Fork all P ranks of this launcher locally (smoke testing): rank 0
    runs in children too so the parent can aggregate exit codes."""
    import subprocess
    procs = []
    env = dict(os.environ)
    if args.local_devices > 0:
        env["XLA_FLAGS"] = (
            f"{env.get('XLA_FLAGS', '')} "
            f"--xla_force_host_platform_device_count="
            f"{args.local_devices}").strip()
    base = [sys.executable, "-m", "repro.parallel.multihost",
            "--coordinator", args.coordinator,
            "--processes", str(args.processes),
            "--n-se", str(args.n_se), "--n-lp", str(args.n_lp),
            "--steps", str(args.steps), "--seed", str(args.seed),
            "--mobility", args.mobility]
    if args.gaia_off:
        base.append("--gaia-off")
    for rank in range(args.processes):
        procs.append(subprocess.Popen(base + ["--process-id", str(rank)],
                                      env=env))
    codes = [p.wait() for p in procs]
    if any(c == _UNSUPPORTED_EXIT for c in codes):
        return _UNSUPPORTED_EXIT
    return max(codes)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="run the sharded GAIA engine across jax.distributed "
                    "processes")
    ap.add_argument("--coordinator", default="127.0.0.1:9911",
                    help="process-0 address:port for jax.distributed")
    ap.add_argument("--processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--spawn", action="store_true",
                    help="fork all --processes ranks locally (smoke test)")
    ap.add_argument("--local-devices", type=int, default=0,
                    help="force this many host-platform devices per "
                         "process (XLA pins the count at first jax init, "
                         "so the launcher re-execs itself with XLA_FLAGS "
                         "set when needed)")
    ap.add_argument("--n-se", type=int, default=10_000)
    ap.add_argument("--n-lp", type=int, default=8)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mobility", default="rwp")
    ap.add_argument("--gaia-off", action="store_true")
    args = ap.parse_args(argv)

    if args.spawn:
        return _spawn_ranks(args)
    if (args.local_devices > 0 and argv is None
            and os.environ.get("_MULTIHOST_REEXEC") != "1"):
        # `python -m` imports the repro.parallel package (and with it
        # jax) before main() runs, and XLA pins the host device count at
        # first init — so apply the flag by re-exec'ing this launcher
        env = dict(os.environ, _MULTIHOST_REEXEC="1")
        env["XLA_FLAGS"] = (
            f"{env.get('XLA_FLAGS', '')} "
            f"--xla_force_host_platform_device_count="
            f"{args.local_devices}").strip()
        os.execve(sys.executable,
                  [sys.executable, "-m", "repro.parallel.multihost"]
                  + sys.argv[1:], env)
    return run_distributed(args)


if __name__ == "__main__":
    sys.exit(main())

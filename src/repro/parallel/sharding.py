"""Name-based partition rules for parameter/optimizer/cache pytrees.

Specs are derived from leaf names + shapes so one rule set covers every
architecture. Stacked layer params (leading L dim from the scan stack)
get a None prefix automatically. Dims that don't divide the axis size
fall back to replication (e.g. 4 KV heads on a 16-way model axis).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.ctx import ParallelCtx

# leaf name -> (trailing-rank, spec builder over trailing dims)
# m = model axis entry maker: m(dim) -> axis or None


def _rules(px: ParallelCtx):
    m = lambda d: px.shard_if(d, px.model_axis)

    def rule(name, shape, in_moe: bool):
        n = name
        if n in ("embedding",):  # (V, D)
            return (m(shape[-2]), None)
        if n in ("lm_head",):  # (D, V)
            return (None, m(shape[-1]))
        if n in ("wq", "wk", "wv"):  # (d, H, Dh)
            return (None, m(shape[-2]), None)
        if n in ("bq", "bk", "bv"):  # (H, Dh)
            return (m(shape[-2]), None)
        if n == "wo" and len(shape) >= 3:  # (H, Dh, d)
            return (m(shape[-3]), None, None)
        def fsdp_entry(dim):
            # fsdp: shard the expert contraction dim over the data axes;
            # GSPMD all-gathers the (small) per-layer slice just-in-time
            # and reduce-scatters its grads (deepseek-v3).
            if not px.fsdp or not px.batch_axes:
                return None
            ba = tuple(px.batch_axes) if len(px.batch_axes) > 1 \
                else px.batch_axes[0]
            return px.shard_if(dim, ba)

        def expert_entry(dim):
            # 2-D EP: experts shard over (data x model) jointly, one (or
            # few) experts per device — weights never gathered (px.ep2d)
            if px.ep2d and px.ep_axes is not None \
                    and dim % px.axis_size(px.ep_axes) == 0:
                return px.ep_axes
            return m(dim)

        # MoE expert weights are identified by their dict path ("moe" key,
        # outside the dense "shared" sub-dict) — NOT by shape, which is
        # ambiguous once layers are stacked: stacked dense (L, d, f) looks
        # exactly like per-layer experts (E, d, f).
        if n in ("w_gate", "w_up"):
            if in_moe:  # experts (E, d, f): EP over model, fsdp over d
                ee = expert_entry(shape[-3])
                fs = None if isinstance(ee, tuple) else fsdp_entry(shape[-2])
                return (ee, fs, None)
            return (None, m(shape[-1]))  # dense (d, f): column parallel
        if n == "w_down":
            if in_moe:  # experts (E, f, d)
                ee = expert_entry(shape[-3])
                fs = None if isinstance(ee, tuple) else fsdp_entry(shape[-2])
                return (ee, fs, None)
            return (m(shape[-2]), None)  # dense (f, d) / zamba (2d, d)
        if n == "router":  # (d, E)
            return (None, m(shape[-1]))
        # MLA
        if n in ("w_dq", "w_dkv"):
            return (None, None)
        if n in ("w_uq", "w_uk", "w_uv"):  # (r, H, hd)
            return (None, m(shape[-2]), None)
        # rwkv6
        if n in ("t_r", "t_k", "t_v", "t_g"):  # (d, d) -> column parallel
            return (None, m(shape[-1]))
        if n == "t_o":  # (d, d) -> row parallel
            return (m(shape[-2]), None)
        if n == "ck":  # (d, ff)
            return (None, m(shape[-1]))
        if n == "cv":  # (ff, d)
            return (m(shape[-2]), None)
        # mamba2
        if n == "w_in":  # (d, 2di+2N+H)
            return (None, m(shape[-1]))
        if n == "w_out":  # (di, d)
            return (m(shape[-2]), None)
        if n == "proj":  # mtp (2d, d)
            return (m(shape[-2]), None)
        return None  # replicate

    return rule


def param_specs(params_shape: Any, px: ParallelCtx):
    """Map a pytree of ShapeDtypeStructs (or arrays) to PartitionSpecs.

    With ``px.fsdp`` every spec is additionally extended with data-axis
    sharding on its largest free dim (ZeRO-3/FSDP semantics: GSPMD
    all-gathers weights at use, reduce-scatters their grads)."""
    rule = _rules(px)

    def visit(path, leaf):
        name = None
        for k in reversed(path):
            if isinstance(k, jax.tree_util.DictKey):
                name = str(k.key)
                break
        keys = {str(k.key) for k in path
                if isinstance(k, jax.tree_util.DictKey)}
        in_moe = "moe" in keys and "shared" not in keys
        shape = leaf.shape
        trailing = rule(name, shape, in_moe) if name else None
        if trailing is None:
            spec = P()
        else:
            prefix = (None,) * (len(shape) - len(trailing))
            spec = P(*(prefix + tuple(trailing)))
        if px.fsdp:
            spec = zero1_spec(spec, shape, px)
        return spec

    return jax.tree_util.tree_map_with_path(visit, params_shape)


def zero1_spec(spec: P, shape, px: ParallelCtx) -> P:
    """Extend a param spec with data-axis sharding on the largest
    unsharded, divisible dim (ZeRO-1 optimizer-state partitioning)."""
    if px.mesh is None or not px.batch_axes:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None:
                used.add(a)
    avail = [a for a in px.batch_axes if a not in used]
    if not avail:
        return spec
    # try the whole group first, then suffixes (innermost axes first)
    for lo in range(len(avail)):
        group = tuple(avail[lo:])
        size = 1
        for a in group:
            size *= px.mesh.shape[a]
        best, best_dim = -1, -1
        for i, (e, d) in enumerate(zip(entries, shape)):
            if e is None and d % size == 0 and d > best:
                best, best_dim = d, i
        if best_dim >= 0:
            entries[best_dim] = group if len(group) > 1 else group[0]
            return P(*entries)
    return spec


def opt_specs(param_specs_tree, params_shape, px: ParallelCtx,
              zero1: bool = True, factored: bool = False,
              lean: bool = False):
    """Optimizer-state specs matching adamw_init/adafactor_init/
    adafactor_lean_init."""
    def one(spec, leaf):
        return zero1_spec(spec, leaf.shape, px) if zero1 else spec

    mv = jax.tree.map(one, param_specs_tree, params_shape)
    if not factored:
        return {"m": mv, "v": mv, "master": mv, "step": P()}

    def drop(spec, leaf, axis_from_end):
        # vr drops the last dim, vc the second-to-last (see adafactor_init)
        shape = leaf.shape
        if len(shape) < 2 or shape[-1] <= 1 or shape[-2] <= 1:
            return P() if axis_from_end == 2 else spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        del entries[len(shape) - axis_from_end]
        return P(*entries)

    vr = jax.tree.map(lambda s, l: drop(one(s, l), l, 1),
                      param_specs_tree, params_shape)
    vc = jax.tree.map(lambda s, l: drop(one(s, l), l, 2),
                      param_specs_tree, params_shape)
    if lean:
        return {"vr": vr, "vc": vc, "step": P()}
    return {"m": mv, "vr": vr, "vc": vc, "master": mv, "step": P()}


def to_shardings(spec_tree, px: ParallelCtx):
    if px.mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(px.mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

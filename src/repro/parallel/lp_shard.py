"""LP-per-device sharded execution of the GAIA engine (shard_map).

The single-device engine (`core/engine.py`) vectorizes every LP inside
one `lax.scan`, so "remote delivery" is purely an accounting fiction.
This module makes the distribution physical: LPs are mapped onto a 1-D
JAX device mesh (axis "lp"), and **each device owns the SE rows of its
LPs** — positions, waypoints, heuristic windows, migration state all
live in per-device slot buffers. Per step:

  * proximity/interaction counts are computed per-shard over a **sparse
    neighbor-only halo**: each device knows, one step in advance, which
    grid cells every device may query (the `halo_need` bitmaps, see
    below), packs exactly the boundary rows each peer needs into
    fixed-capacity per-pair buffers, and exchanges them with a single
    `all_to_all`. The PR-1 cell-list grid is then built over the local
    view (own rows + received halo) and each shard resolves only its
    own rows against its 3x3 candidate blocks. No position all-gather:
    what moves is the exchange set GAIA is shrinking, and the
    `bytes_on_wire` / `wire_flows` metrics count it exactly.
  * **halo-need double buffer** (the comm/compute overlap): the bitmap
    that steers step t+1's exchange is computed and psum-reduced at the
    tail of step t — per-device cell occupancy (plus the cells of rows
    pending migration toward each destination device) dilated by
    1 + ceil(max per-step displacement / cell). The dilation makes the
    one-step-stale footprint a sound superset of the true need (every
    in-range neighbor is guaranteed present in the receiver's view —
    tests/test_halo_exchange.py), and it removes the same-step global
    agreement round: the only same-step collective the proximity path
    needs is the one payload all_to_all, issued right after the (cheap)
    row-local mobility update so asynchronous-collective backends can
    overlap it with the independent own-row binning work.
  * LCR numerators/denominators, the candidate matrix, and all Eq. 5/6
    counters are accumulated across devices with `psum`.
  * GAIA migrations are **actual resharding ops**: when a migration's
    protocol delay elapses and the destination LP lives on another
    device, the SE's full state row (including its heuristic window) is
    packed into a fixed-capacity per-device migration buffer,
    all-gathered, and scattered into a free slot on the destination
    shard. The source slot is vacated (gid = lp = -1).

Bit-identity with the single-device oracle (the §4.2 transparency
invariant, extended to the execution layer): `sharding="lp_device"`
produces byte-identical states, series, and migration sequences to
`sharding="none"` on the same seed — see DESIGN.md §Neighbor-only halo
exchange for why each step phase preserves this exactly, and
tests/test_sharding.py + tests/test_halo_exchange.py for the enforced
contract. Three fixed capacities (slots per device, migration-buffer
rows, halo rows per device pair) must bound the true maxima for the
contract to hold; overflow is surfaced per step in the
`shard_overflow` metric (and asserted zero in the equivalence tests),
mirroring the cell-list grid's capacity discipline.

Wire accounting (`bytes_on_wire`, per-step; `wire_flows`, the per
(src dev, dst dev) byte matrix): JAX collectives still move fixed-size
buffers, so the numbers count the *useful* slots — packed halo rows at
12 B (pos + lp), admitted cross-device migration rows at their full
row size (state row + ring window), and, for the paths that still
reconstruct id-order state (flock mobility, the periodic repartition
hook), the valid rows of those gathers. Control-plane reductions (the
need bitmaps, free-slot counts, the psum'd counters) are excluded —
they are O(cells + LP^2), independent of the SE population. This is
exactly the traffic a ragged transport would put on the wire, so the
metric is the physical realization of what `halo_frac` only measured.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import balance as bal
from repro.core import heuristics as heu
from repro.core import neighbors
from repro.core import partition as part
from repro.core.abm import (epidemic_draws, epidemic_row_update,
                            epidemic_send_prob, init_abm,
                            max_step_displacement, mobility_row_apply,
                            mobility_row_draws, mobility_step,
                            row_local_mobility)
from repro.core.engine import COMPILED_CACHE_SIZE
from repro.obs import ledger as obs_ledger
from repro.obs import runtime as obs_runtime

#: per-SE state rows that migrate with an SE between shards ("mob" is
#: the per-SE mobility state: member offset / heading; "epi" the
#: workload infection flag — full-row packed)
_ROW_FIELDS = ("pos", "waypoint", "mob", "last_mig", "ptr", "since_eval",
               "epi", "gid")

#: bytes per halo row on the wire: pos (2 x f32) + lp (i32) — all a
#: receiver needs to resolve proximity + LP histograms against the row
HALO_ROW_BYTES = 12


def _halo_row_bytes(cfg) -> int:
    """Bytes per halo row for this config: the epidemic workload ships
    one extra i32 per row (the infectious-sender label the receiver's
    exposure sweep reads)."""
    return HALO_ROW_BYTES + (4 if cfg.abm.workload == "epidemic" else 0)


def _mig_row_bytes(window: int, n_lp: int, epidemic: bool = False) -> int:
    """Bytes per migrated SE row: the 8 _ROW_FIELDS (pos/waypoint/mob
    2 x f32 each, last_mig/ptr/since_eval/epi/gid i32) + dst i32 + the
    (window, n_lp) i32 heuristic ring rows that travel with the SE. The
    `epi` flag only counts for epidemic runs — it is carried (zero)
    either way, but a ragged transport would elide a constant column."""
    return 44 + (4 if epidemic else 0) + 4 * window * n_lp


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Static geometry of the LP-per-device layout."""
    n_dev: int  # devices on the "lp" mesh axis
    n_lp: int
    n_se: int
    cap: int  # SE slots per device (must bound max per-device population)
    mig_cap: int  # migration-buffer rows per device per step
    halo_cap: int  # halo rows per (src, dst) device pair per step
    grid: Optional[neighbors.GridSpec]  # local-view cell list (live SEs)

    @property
    def n_slots(self) -> int:
        return self.n_dev * self.cap


def dev_of_lp(lp, spec: ShardSpec):
    """Block LP->device map: device d owns a contiguous LP range."""
    return (lp * spec.n_dev) // spec.n_lp


def _sparse_halo(spec: ShardSpec) -> bool:
    """Does this layout run the neighbor-only exchange? Needs a grid
    (footprints are cell bitmaps) and a second device to talk to."""
    return spec.grid is not None and spec.n_dev > 1


def _dilation_radius(spec: ShardSpec, abm) -> int:
    """Cells of Chebyshev dilation that turn step-t occupancy into a
    sound step-t+1 need set: 1 for the 3x3 proximity block + the cell
    shift bound of one mobility step (a move of at most `disp` per axis
    crosses at most floor(disp/cell) + 1 cell boundaries)."""
    return 2 + int(max_step_displacement(abm) // spec.grid.cell)


def make_shard_spec(cfg) -> ShardSpec:
    """Resolve the sharded layout for an EngineConfig (sharding="lp_device")."""
    abm = cfg.abm
    n, L = abm.n_se, abm.n_lp
    avail = len(jax.devices())
    d = cfg.n_devices if cfg.n_devices > 0 else avail
    if d > avail:
        raise ValueError(f"n_devices={d} but only {avail} JAX devices are "
                         "visible (XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=... must be set before jax init)")
    d = min(d, L)  # never more devices than LPs
    backend = abm.resolved_backend()
    if backend.startswith("pallas"):
        raise NotImplementedError(
            f"sharding='lp_device' supports proximity_backend 'grid' and "
            f"'dense', not {backend!r} (the Pallas kernels are per-device "
            "TPU kernels; run them under sharding='none')")
    budget_mb = cfg.abm.mem_budget_mb  # engine knob propagates into abm
    if cfg.shard_capacity > 0:
        cap = cfg.shard_capacity
    elif d == 1:
        cap = n
    else:
        # 2x the balanced share: covers symmetric balance exactly and
        # asymmetric drift up to a 2/d capacity share; override via
        # EngineConfig.shard_capacity for more skewed profiles.
        cap = min(n, -(-2 * n // d) + 8)
    # a device can never have more than `cap` same-step leavers, so an
    # explicit mig_capacity above that is clamped (not an error)
    if cfg.mig_capacity > 0:
        mig_cap = min(cap, cfg.mig_capacity)
    else:
        mig_cap = min(cap, max(32, cap // 2))
        if budget_mb > 0 and d > 1:
            # budgeted auto: the all-gathered migration buffer is
            # (d * mig_cap) rows of _mig_row_bytes each per device —
            # give it a quarter of the budget. Exact-or-loud: a
            # same-step leaver burst beyond the buffer defers rows and
            # raises shard_overflow, never drops SEs.
            w = cfg.heuristic.kappa if cfg.heuristic.kind == 1 \
                else cfg.heuristic.omega
            rows = (budget_mb << 18) // (d * _mig_row_bytes(
                w, L, abm.workload == "epidemic"))
            mig_cap = min(mig_cap, max(16, rows))
    grid = None
    if backend == "grid":
        # the mobility-aware oracle geometry: the local view (own rows +
        # received halo) only ever tables *live* SEs (build_grid masks
        # dead slots/padding), so the per-cell bound for the n true SEs
        # applies as-is — no pad allowance, roughly halving the 3x3
        # candidate width vs. tabling all n_dev*cap slots
        grid = abm.grid_spec()
    if grid is None or d == 1:
        halo_cap = 1  # no exchange: dense fallback / single device
    elif cfg.halo_capacity > 0:
        halo_cap = min(cfg.halo_capacity, cap)
    elif budget_mb > 0:
        # budgeted auto instead of the worst case: send + recv buffers
        # are 2 * d * halo_cap rows of HALO_ROW_BYTES per device — give
        # them a quarter of the budget. Safe-by-alarm, not by bound: a
        # peer needing more rows than this from one device trips
        # shard_overflow (exact-or-loud), and GAIA's clustering is what
        # keeps real needs far below the worst case.
        rows = (budget_mb << 18) // (2 * d * _halo_row_bytes(cfg))
        halo_cap = min(cap, max(32, rows))
    else:
        # a peer can need every row a device owns (e.g. the random
        # initial partition scatters each LP across the whole torus), so
        # only cap itself is safe for arbitrary partitions; tighten via
        # EngineConfig.halo_capacity (or a mem_budget_mb) once GAIA has
        # clustered the shards
        halo_cap = cap
    return ShardSpec(n_dev=d, n_lp=L, n_se=n, cap=cap, mig_cap=mig_cap,
                     halo_cap=halo_cap, grid=grid)


def make_mesh(spec: ShardSpec) -> Mesh:
    return Mesh(np.array(jax.devices()[:spec.n_dev]), ("lp",))


# ---------------------------------------------------------------------------
# halo-need bitmaps
# ---------------------------------------------------------------------------


def halo_need_bitmaps(pos, valid, pending_dst, spec: ShardSpec, abm):
    """(n_dev, ncell^2) bool: cells whose occupants device d may query
    *next* step — its dilated spatial footprint.

    Device d's footprint is the set of cells occupied by its valid
    slots, plus the cells of every row currently pending migration
    toward one of d's LPs (the row lands on d when its delay elapses,
    and d's own bitmap cannot know about it in advance), Chebyshev-
    dilated by `_dilation_radius` (3x3 proximity + one step of motion).
    A superset is always sound — rows sent but not queried cost wire
    bytes, never correctness.

    This global slot-major version seeds `init_sharded` and serves as
    the reference the property tests check against; `_shard_step`
    computes the identical bitmaps distributedly (each device
    contributes its rows, psum ORs them) at the tail of every step.
    """
    g = spec.grid
    ncells = g.ncell * g.ncell
    dev = jnp.arange(pos.shape[0], dtype=jnp.int32) // spec.cap
    cell = neighbors.cell_ids(pos, g)
    safe_cell = jnp.where(valid, cell, ncells)  # invalid -> dropped
    contrib = jnp.zeros((spec.n_dev, ncells), bool)
    contrib = contrib.at[dev, safe_cell].set(True, mode="drop")
    pend = valid & (pending_dst >= 0)
    pdev = dev_of_lp(jnp.maximum(pending_dst, 0), spec)
    contrib = contrib.at[jnp.where(pend, pdev, spec.n_dev),
                         safe_cell].set(True, mode="drop")
    return neighbors.dilate_mask(
        contrib.reshape(spec.n_dev, g.ncell, g.ncell),
        _dilation_radius(spec, abm)).reshape(spec.n_dev, ncells)


# ---------------------------------------------------------------------------
# init / unshard
# ---------------------------------------------------------------------------


def init_sharded(key, cfg, spec: ShardSpec):
    """Slot-major engine state: device d owns slots [d*cap, (d+1)*cap).

    Consumes the PRNG exactly like `engine.init_engine` (same k1/k2
    split), so SE i's initial position/waypoint/LP are bit-identical to
    the oracle's row i. Empty slots get spread-out pad positions from an
    independent stream (they must not pile into one grid cell) and
    lp = gid = -1. Under the sparse halo the state also carries the
    initial `halo_need` bitmaps (the double buffer's first entry),
    computed from the initial placement by `halo_need_bitmaps`.
    """
    n, L, S = spec.n_se, spec.n_lp, spec.n_slots
    k1, k2 = jax.random.split(key)
    st = init_abm(k1, cfg.abm)
    hst = heu.init_state(cfg.heuristic, n, L)

    lp = np.asarray(st["lp"])
    dev = np.asarray(dev_of_lp(jnp.asarray(lp), spec))
    counts = np.bincount(dev, minlength=spec.n_dev)
    if counts.max() > spec.cap:
        raise ValueError(
            f"initial per-device population {counts.max()} exceeds "
            f"shard_capacity {spec.cap}; raise EngineConfig.shard_capacity")
    order = np.argsort(dev, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    rank = np.arange(n) - starts[dev[order]]
    slot_of_se = np.empty(n, np.int64)
    slot_of_se[order] = dev[order] * spec.cap + rank

    k_pad = jax.random.fold_in(key, 0x5107)
    pad_pos = jax.random.uniform(k_pad, (S, 2), maxval=cfg.abm.area)

    def scat(x, fill):
        out = jnp.full((S,) + x.shape[1:], fill, x.dtype)
        return out.at[slot_of_se].set(x)

    ring = jnp.zeros((hst["ring"].shape[0], S, L), hst["ring"].dtype)
    ring = ring.at[:, slot_of_se, :].set(hst["ring"])
    state = {
        "pos": pad_pos.at[slot_of_se].set(st["pos"]),
        "waypoint": pad_pos.at[slot_of_se].set(st["waypoint"]),
        "mob": jnp.zeros((S, 2), jnp.float32).at[slot_of_se].set(st["mob"]),
        "mob_g": st["mob_g"],  # global mobility rows: replicated
        "lp": scat(st["lp"], -1),
        "epi": scat(st["epi"], 0),
        "gid": scat(jnp.arange(n, dtype=jnp.int32), -1),
        "pending_dst": jnp.full((S,), -1, jnp.int32),
        "pending_eta": jnp.full((S,), -1, jnp.int32),
        "ring": ring,
        "ptr": scat(hst["ptr"], 0),
        "since_eval": scat(hst["since_eval"], 0),
        "last_mig": scat(hst["last_mig"], -10**6),
        "key": k2,
        "t": jnp.int32(0),
    }
    live = cfg.initial_live()
    if cfg.open_world and live < n:
        # open world: ids [live, n) start as free slots (gid = lp = -1),
        # mirroring the oracle's lp < 0 dead mask. Every SE was scattered
        # first so the initial placement (and the live prefix's bits)
        # matches the oracle's row-for-row.
        dead = state["gid"] >= live
        state["gid"] = jnp.where(dead, -1, state["gid"])
        state["lp"] = jnp.where(dead, -1, state["lp"])
    if _sparse_halo(spec):
        state["halo_need"] = halo_need_bitmaps(
            state["pos"], state["gid"] >= 0, state["pending_dst"], spec,
            cfg.abm)
    return state


def unshard_state(state, spec: ShardSpec):
    """Scatter slot-major state back to gid-order — the oracle's layout,
    so sharded and single-device final states compare byte-for-byte.
    The `halo_need` double buffer is execution-layer plumbing with no
    oracle counterpart, so it is dropped here."""
    n = spec.n_se
    gid = state["gid"]
    tgt = jnp.where(gid >= 0, gid, n)  # -1 -> out of bounds -> dropped

    def scat(x):
        out = jnp.zeros((n,) + x.shape[1:], x.dtype)
        return out.at[tgt].set(x, mode="drop")

    ring = jnp.zeros((state["ring"].shape[0], n, spec.n_lp),
                     state["ring"].dtype)
    ring = ring.at[:, tgt, :].set(state["ring"], mode="drop")
    return {
        "pos": scat(state["pos"]),
        "waypoint": scat(state["waypoint"]),
        "mob": scat(state["mob"]),
        "mob_g": state["mob_g"],
        "lp": scat(state["lp"]),
        "epi": scat(state["epi"]),
        "pending_dst": scat(state["pending_dst"]),
        "pending_eta": scat(state["pending_eta"]),
        "ring": ring,
        "ptr": scat(state["ptr"]),
        "since_eval": scat(state["since_eval"]),
        "last_mig": scat(state["last_mig"]),
        "key": state["key"],
        "t": state["t"],
    }


# ---------------------------------------------------------------------------
# one sharded timestep
# ---------------------------------------------------------------------------


def _apply_arrivals(f, t, cfg, spec: ShardSpec, me):
    """Complete in-flight migrations: local ones flip `lp` in place;
    cross-device ones are packed, all-gathered, and scattered into free
    destination slots (the resharding op). Returns (fields, overflow,
    mig_wire) where mig_wire is the (n_dev, n_dev) byte matrix of the
    admitted cross-device rows — the state transfer a ragged transport
    would put on the wire this step (replicated: every device computes
    the same admission decision, so the same matrix).

    Overflow never destroys SEs: a leaver that does not fit the
    migration buffer, or whose destination has no free slot this step,
    keeps its slot and its pending state and retries next step (the
    arrival test is `eta <= t`). Every device computes the same
    admission decision from the gathered buffer + gathered free counts,
    so source vacates exactly the rows the destination inserts. The
    deferral still diverges from the single-device oracle (which has no
    capacity limits), so `shard_overflow` stays an equivalence alarm —
    but the simulation remains population-preserving and valid."""
    B = spec.mig_cap
    due = ((f["pending_eta"] >= 0) & (f["pending_eta"] <= t)
           & (f["gid"] >= 0))
    dst = f["pending_dst"]
    dst_dev = dev_of_lp(jnp.maximum(dst, 0), spec)
    stay = due & (dst_dev == me)
    leave = due & (dst_dev != me)

    f = dict(f)
    f["lp"] = jnp.where(stay, dst, f["lp"])
    f["pending_dst"] = jnp.where(stay, -1, f["pending_dst"])
    f["pending_eta"] = jnp.where(stay, -1, f["pending_eta"])

    # pack leavers into the fixed migration buffer, gather-style (a
    # scatter over all cap slots would serialize on CPU): stable argsort
    # puts leaver slot ids first in ascending slot order
    leaver_slots = jnp.argsort(~leave, stable=True)[:B]
    n_leave = leave.sum()
    is_row = jnp.arange(B) < n_leave
    mig_overflow = n_leave > B

    def pack(x, fill):
        v = x[leaver_slots]
        shape = (B,) + (1,) * (v.ndim - 1)
        return jnp.where(is_row.reshape(shape), v, fill)

    buf = {k: pack(f[k], 0) for k in _ROW_FIELDS if k != "gid"}
    buf["gid"] = pack(f["gid"], -1)
    buf["dst"] = pack(dst, -1)
    # gather the leavers' ring rows on the slot axis (no full transpose)
    buf["ring"] = jnp.where(is_row[:, None, None],
                            jnp.moveaxis(f["ring"][:, leaver_slots, :], 1, 0),
                            0)  # (B, w, L)

    # exchange; admission is decided identically on every device
    g = {k: jax.lax.all_gather(v, "lp", axis=0, tiled=True)
         for k, v in buf.items()}  # (n_dev*B, ...)
    free = f["gid"] < 0
    free_counts = jax.lax.all_gather(free.sum(), "lp")  # (n_dev,)
    g_dev = dev_of_lp(jnp.maximum(g["dst"], 0), spec)
    g_valid = g["gid"] >= 0
    # rank of each buffer row among rows bound for the same destination
    per_dev = (g_valid[None, :]
               & (g_dev[None, :] == jnp.arange(spec.n_dev)[:, None]))
    rank = (jnp.cumsum(per_dev, axis=1) - 1)[g_dev, jnp.arange(g_dev.shape[0])]
    admitted = g_valid & (rank < free_counts[g_dev])
    cap_overflow = (g_valid & ~admitted).any()

    # the admitted cross-device rows are the priced migration payload
    src_dev = jnp.arange(spec.n_dev * B, dtype=jnp.int32) // B
    crossed = admitted & (g_dev != src_dev)
    mig_wire = jnp.zeros((spec.n_dev, spec.n_dev), jnp.int32).at[
        src_dev, g_dev].add(crossed.astype(jnp.int32)
                            * _mig_row_bytes(
                                f["ring"].shape[0], spec.n_lp,
                                cfg.abm.workload == "epidemic"))

    # vacate exactly the admitted leavers (deferred rows keep slot +
    # pending state); their ring rows go stale rather than zeroed —
    # stale rows are inert: evaluate() masks by valid, and arrivals
    # overwrite the whole row
    adm_local = admitted[me * B + jnp.arange(B)]
    vacate = jnp.zeros_like(leave).at[leaver_slots].set(
        is_row & adm_local, mode="drop")
    f["gid"] = jnp.where(vacate, -1, f["gid"])
    f["lp"] = jnp.where(vacate, -1, f["lp"])
    f["pending_dst"] = jnp.where(vacate, -1, f["pending_dst"])
    f["pending_eta"] = jnp.where(vacate, -1, f["pending_eta"])
    f["last_mig"] = jnp.where(vacate, -10**6, f["last_mig"])
    f["ptr"] = jnp.where(vacate, 0, f["ptr"])
    f["since_eval"] = jnp.where(vacate, 0, f["since_eval"])

    # insert admitted rows bound for this device into its free slots.
    # NOTE: free slots were counted before vacating, so a slot freed by
    # this step's departures is never handed to this step's arrivals —
    # both sides of the admission decision see the same free count.
    mine = admitted & (g_dev == me)
    free_order = jnp.argsort(~free, stable=True)  # free slots first, asc
    arr_rank = jnp.cumsum(mine) - 1
    target = jnp.where(
        mine, free_order[jnp.clip(arr_rank, 0, spec.cap - 1)], spec.cap)

    for k in _ROW_FIELDS:
        f[k] = f[k].at[target].set(g[k], mode="drop")
    f["lp"] = f["lp"].at[target].set(g["dst"], mode="drop")
    f["pending_dst"] = f["pending_dst"].at[target].set(-1, mode="drop")
    f["pending_eta"] = f["pending_eta"].at[target].set(-1, mode="drop")
    f["ring"] = f["ring"].at[:, target, :].set(
        jnp.moveaxis(g["ring"], 0, 1), mode="drop")
    overflow = mig_overflow | cap_overflow
    return f, overflow, mig_wire


def _gather_row_bytes(cfg) -> int:
    """Static per-valid-row byte price of the id-order reconstruction
    gathers a step performs (flock mobility and/or the periodic
    repartition hook) — the exact accumulation the fused step used to
    compute inline, now shared by the fused and traced paths."""
    row_local = row_local_mobility(cfg.abm)
    grb = 0 if row_local else 20  # flock: pos + mob + gid
    if cfg.repartition_every > 0:
        # post-mobility pos + gid per valid row; gid rides the flock
        # gather when one already happened, leaving pos only
        grb += 12 if row_local else 8
        if part.uses_prev(part.from_engine(cfg)):
            grb += 4  # hysteresis backends read the id-order map too
    return grb


def _sharded_phases(cfg, spec: ShardSpec):
    """Ordered (name, fn, adds) phase decomposition of the per-device
    step body. Each fn maps a phase-context dict `px` (per-SE fields
    under "f", plus intermediates earlier phases added) to the grown
    dict; `adds` names the keys the phase introduces (the trace wrapper
    uses it to derive per-phase shard_map out_specs — see
    `sharded_trace_phases`). `_shard_step` composes the phases fused, so
    the compiled scan is the historical program."""
    abm = cfg.abm
    n, L, C = spec.n_se, spec.n_lp, spec.cap
    D = spec.n_dev

    def ph_migrate(px):
        # 1. complete in-flight migrations (the resharding op)
        me = jax.lax.axis_index("lp")
        f, reshard_overflow, wire = _apply_arrivals(
            dict(px["f"]), px["t"], cfg, spec, me)
        valid = f["gid"] >= 0
        safe_gid = jnp.clip(f["gid"], 0, n - 1)
        n_valid = valid.sum()
        all_valid = jax.lax.psum(n_valid, "lp")
        return dict(px, f=f, wire=wire, reshard_overflow=reshard_overflow,
                    valid=valid, safe_gid=safe_gid, n_valid=n_valid,
                    all_valid=all_valid)

    def ph_mobility(px):
        # 2. model evolution. The row-local models (rwp/hotspot/group)
        # factor into full-array id-order draws + an elementwise apply:
        # each device computes the same draw arrays, gathers its rows by
        # SE id, and moves them in place — every SE sees the same
        # randomness wherever it is hosted (bit-identity), and no
        # position leaves the device. Flock reads global cell aggregates
        # (a float scatter-add whose reduction order must match the
        # oracle), so each device reconstructs the id-order arrays from
        # an all-gather, advances them with the *same* `mobility_step`
        # the oracle runs, and takes its own rows back — bit-identity by
        # construction (see DESIGN.md).
        f = dict(px["f"])
        valid, safe_gid = px["valid"], px["safe_gid"]
        k_move = jax.random.wrap_key_data(px["k_move"])
        k_send = jax.random.wrap_key_data(px["k_send"])
        out = dict(px)
        if row_local_mobility(abm):
            draws, mob_g = mobility_row_draws(k_move, n, f["mob_g"], abm)
            my_draws = {k: v[safe_gid] for k, v in draws.items()}
            new_pos, new_wp = mobility_row_apply(f["pos"], f["waypoint"],
                                                 f["mob"], my_draws, abm)
            f["pos"] = jnp.where(valid[:, None], new_pos, f["pos"])
            f["waypoint"] = jnp.where(valid[:, None], new_wp, f["waypoint"])
            f["mob_g"] = mob_g
        else:
            pos_all = jax.lax.all_gather(f["pos"], "lp", axis=0, tiled=True)
            mob_all = jax.lax.all_gather(f["mob"], "lp", axis=0, tiled=True)
            gid_all = jax.lax.all_gather(f["gid"], "lp", axis=0, tiled=True)
            tgt = jnp.where(gid_all >= 0, gid_all, n)  # pads -> dropped
            pos_n = jnp.zeros((n, 2), f["pos"].dtype).at[tgt].set(
                pos_all, mode="drop")
            mob_n = jnp.zeros((n, 2), f["mob"].dtype).at[tgt].set(
                mob_all, mode="drop")
            wp_n = jnp.zeros((n, 2), jnp.float32)  # unused by flock
            # open world: the flock aggregates must exclude dead ids
            # exactly like the oracle's valid mask (live rows scatter
            # True; dead ids stay False — only live rows ride the gather)
            valid_n = jnp.zeros((n,), bool).at[tgt].set(
                True, mode="drop") if cfg.open_world else None
            pos_n, _, mob_n, mob_g = mobility_step(k_move, pos_n, wp_n,
                                                   mob_n, f["mob_g"], abm,
                                                   valid=valid_n)
            f["pos"] = jnp.where(valid[:, None], pos_n[safe_gid], f["pos"])
            f["mob"] = jnp.where(valid[:, None], mob_n[safe_gid], f["mob"])
            f["mob_g"] = mob_g
            out["gid_all"] = gid_all  # shared by the repartition hook
        if abm.workload == "epidemic":
            # mirror of engine.ph_mobility's boosted sender draw: the
            # full-size id-order uniforms are gathered by SE id, the
            # per-row threshold reads the slot's own infection flag —
            # same randomness, same comparison, wherever the row lives
            u = jax.random.uniform(k_send, (n,))[safe_gid]
            sender = valid & (u < epidemic_send_prob(f["epi"], abm))
        else:
            sender = valid & jax.random.bernoulli(
                k_send, abm.p_interact, (n,))[safe_gid]
        out.update(f=f, sender=sender)
        return out

    def ph_halo(px):
        # 3. halo exchange: assemble the local proximity view. Epidemic
        # runs ship one extra label per row — 1 on infectious rows that
        # sent this step, 0 on other live rows, -1 on padding — so the
        # receiver's exposure sweep (ph_workload) reads the same labels
        # the oracle builds in id order.
        me = jax.lax.axis_index("lp")
        f, valid, wire = px["f"], px["valid"], px["wire"]
        epidemic = abm.workload == "epidemic"
        if epidemic:
            own_labels = jnp.where(
                valid, ((f["epi"] > 0) & px["sender"]).astype(jnp.int32),
                -1)
        halo_overflow = jnp.bool_(False)
        halo_n = jnp.int32(0)
        if spec.grid is not None:
            gspec = spec.grid
            nc = gspec.ncell
            ncells = nc * nc
            cellC = neighbors.cell_ids(f["pos"], gspec)
            if D > 1:
                hc = spec.halo_cap
                # pack, per peer, exactly the rows its (one-step-stale,
                # dilation-covered) need bitmap asks for
                need = f["halo_need"]  # (D, ncells), negotiated at t-1
                want = need[:, jnp.where(valid, cellC, 0)]  # (D, C)
                send = want & valid[None, :] & \
                    (jnp.arange(D, dtype=jnp.int32) != me)[:, None]
                cnt = send.sum(axis=1)
                order = jnp.argsort(~send, axis=1, stable=True)[:, :hc]
                is_row = jnp.arange(hc)[None, :] < cnt[:, None]
                send_pos = jnp.where(is_row[..., None], f["pos"][order], 0.0)
                send_lp = jnp.where(is_row, f["lp"][order], -1)
                halo_overflow = (cnt > hc).any()
                # the one same-step collective of the proximity path
                recv_pos = jax.lax.all_to_all(send_pos, "lp", split_axis=0,
                                              concat_axis=0, tiled=True)
                recv_lp = jax.lax.all_to_all(send_lp, "lp", split_axis=0,
                                             concat_axis=0, tiled=True)
                view_pos = jnp.concatenate([f["pos"],
                                            recv_pos.reshape(D * hc, 2)])
                view_lp = jnp.concatenate([f["lp"], recv_lp.reshape(D * hc)])
                if epidemic:
                    send_eis = jnp.where(is_row, own_labels[order], -1)
                    recv_eis = jax.lax.all_to_all(
                        send_eis, "lp", split_axis=0, concat_axis=0,
                        tiled=True)
                    view_eis = jnp.concatenate(
                        [own_labels, recv_eis.reshape(D * hc)])
                packed = jnp.minimum(cnt, hc)
                wire = wire + jax.lax.psum(
                    jnp.zeros((D, D), jnp.int32).at[me].set(
                        packed * _halo_row_bytes(cfg)), "lp")
                # exact halo (the pre-existing halo_frac semantics):
                # received rows inside this shard's true 3x3 need *now*.
                # Exchange soundness guarantees every such row was
                # received, so the sparse path measures the same quantity
                # the full-gather transport did — trajectories stay
                # baseline-comparable.
                occ = jnp.zeros((ncells,), bool).at[
                    jnp.where(valid, cellC, ncells)].set(True, mode="drop")
                exact = neighbors.dilate_mask(occ.reshape(nc, nc),
                                              1).reshape(-1)
                cellR = neighbors.cell_ids(recv_pos.reshape(D * hc, 2),
                                           gspec)
                halo_n = ((recv_lp.reshape(-1) >= 0) & exact[cellR]).sum()
            else:
                view_pos, view_lp = f["pos"], f["lp"]
                if epidemic:
                    view_eis = own_labels
            out = dict(px, wire=wire, cellC=cellC, view_pos=view_pos,
                       view_lp=view_lp, halo_overflow=halo_overflow,
                       halo_n=halo_n)
            if epidemic:
                out["view_eis"] = view_eis
            return out
        # dense fallback (world too small to tessellate): the original
        # full-gather transport — every position/LP to every device
        pos_g = jax.lax.all_gather(f["pos"], "lp", axis=0, tiled=True)
        lp_g = jax.lax.all_gather(f["lp"], "lp", axis=0, tiled=True)
        halo_n = px["all_valid"] - px["n_valid"]  # every remote needed
        if D > 1:
            vcnt = jax.lax.all_gather(px["n_valid"], "lp")  # (D,)
            wire = wire + (vcnt[:, None] * _halo_row_bytes(cfg)
                           * (1 - jnp.eye(D, dtype=jnp.int32)))
        out = dict(px, wire=wire, pos_g=pos_g, lp_g=lp_g,
                   halo_overflow=halo_overflow, halo_n=halo_n)
        if epidemic:
            out["eis_g"] = jax.lax.all_gather(own_labels, "lp", axis=0,
                                              tiled=True)
        return out

    def ph_proximity(px):
        # 3a. per-shard proximity counts over the assembled view
        f, valid, sender = px["f"], px["valid"], px["sender"]
        if spec.grid is not None:
            gspec = spec.grid
            ncells = gspec.ncell * gspec.ncell
            grid = neighbors.build_grid(px["view_pos"], gspec,
                                        valid=px["view_lp"] >= 0,
                                        with_table=False)
            # visit local rows in cell-sorted order (same trick as the
            # engine path: the CSR segment gathers get spatial locality);
            # integer counts scatter back to slot order exactly
            row_order = jnp.argsort(jnp.where(valid, px["cellC"], ncells),
                                    stable=True).astype(jnp.int32)
            out = neighbors.rows_grid_counts(
                px["view_pos"], px["view_lp"], L, abm.area,
                abm.interaction_range, gspec, grid, f["pos"][row_order],
                row_order, sender[row_order],
                neighbors.chunk_entries(abm.mem_budget_mb))
            counts = jnp.zeros((C, L), jnp.int32).at[row_order].set(out)
            grid_overflow = grid["overflow"]
        else:
            me = jax.lax.axis_index("lp")
            my_idx = me * C + jnp.arange(C, dtype=jnp.int32)
            counts = neighbors.rows_dense_counts(
                px["pos_g"], px["lp_g"], L, abm.area, abm.interaction_range,
                f["pos"], my_idx, sender)
            grid_overflow = jnp.bool_(False)
        return dict(px, counts=counts, grid_overflow=grid_overflow)

    def ph_workload(px):
        # 3c. epidemic diffusion: mirror of engine.ph_workload over the
        # halo view — exposure is one more 2-class candidate walk (the
        # shipped `view_eis` labels stand in for the oracle's id-order
        # label array), and the SI/SIS transition rides full-size
        # id-order draws gathered by SE id, so a row transitions on the
        # same randomness wherever it is hosted (bit-identity)
        f = dict(px["f"])
        valid, safe_gid = px["valid"], px["safe_gid"]
        epi = f["epi"]
        qmask = valid & (epi == 0)
        if spec.grid is not None:
            gspec = spec.grid
            ncells = gspec.ncell * gspec.ncell
            grid = neighbors.build_grid(px["view_pos"], gspec,
                                        valid=px["view_lp"] >= 0,
                                        with_table=False)
            row_order = jnp.argsort(jnp.where(valid, px["cellC"], ncells),
                                    stable=True).astype(jnp.int32)
            out = neighbors.rows_grid_counts(
                px["view_pos"], px["view_eis"], 2, abm.area,
                abm.interaction_range, gspec, grid, f["pos"][row_order],
                row_order, qmask[row_order],
                neighbors.chunk_entries(abm.mem_budget_mb))
            exposure = jnp.zeros((C, 2), jnp.int32).at[row_order].set(
                out)[:, 1]
            ovf = grid["overflow"]
        else:
            me = jax.lax.axis_index("lp")
            my_idx = me * C + jnp.arange(C, dtype=jnp.int32)
            exposure = neighbors.rows_dense_counts(
                px["pos_g"], px["eis_g"], 2, abm.area,
                abm.interaction_range, f["pos"], my_idx, qmask)[:, 1]
            ovf = jnp.bool_(False)
        draws = epidemic_draws(jax.random.wrap_key_data(px["k_move"]),
                               n, abm)
        my_draws = {k: v[safe_gid] for k, v in draws.items()}
        new_epi = epidemic_row_update(epi, exposure, my_draws, abm)
        f["epi"] = jnp.where(valid, new_epi, f["epi"])
        infected = jax.lax.psum(((f["epi"] > 0) & valid).sum(), "lp")
        return dict(px, f=f, infected=infected,
                    grid_overflow=px["grid_overflow"] | ovf)

    def ph_account(px):
        # 3b. communication accounting: the per-pair flow matrix is
        # integer, so the cross-shard psum is exactly the oracle's
        # id-order scatter-add, and the scalar LCR terms derive from it
        # (single source of truth, same as engine.step). Rows of invalid
        # slots are zero (non-senders); their safe_lp=0 rows add nothing.
        f = px["f"]
        safe_lp = jnp.clip(f["lp"], 0, L - 1)
        flows = jax.lax.psum(
            jnp.zeros((L, L), jnp.int32).at[safe_lp].add(px["counts"]),
            "lp")
        local = jnp.trace(flows)
        total = flows.sum()
        return dict(px, safe_lp=safe_lp, flows=flows, local=local,
                    total=total, remote=total - local,
                    migs=jnp.int32(0), n_evals=jnp.int32(0),
                    mig_flows=jnp.zeros((L, L), jnp.int32),
                    reparts=jnp.int32(0))

    def ph_repartition(px):
        # mirror of engine.step's hook: reconstruct the id-order
        # positions (a gather the sparse halo no longer performs), run
        # the *same* partition function on every device, and take this
        # shard's rows back — bit-identity with the oracle by
        # construction, like the mobility models. The gathers (a
        # collective, so they may not live inside the cond) run every
        # step; the reconstruction + partition math fires on
        # repartition steps.
        from repro.core.engine import REPART_SALT
        f = dict(px["f"])
        valid, safe_gid, safe_lp = px["valid"], px["safe_gid"], px["safe_lp"]
        t = px["t"]
        pcfg = part.from_engine(cfg)
        if "gid_all" in px:
            gid_all = px["gid_all"]  # gid rode the flock gather
        else:
            gid_all = jax.lax.all_gather(f["gid"], "lp", axis=0, tiled=True)
        rep_pos = jax.lax.all_gather(f["pos"], "lp", axis=0, tiled=True)
        rep_lp = None
        if part.uses_prev(pcfg):
            # hysteresis backends read the current id-order map too; the
            # gather (a collective: outside the cond) is only paid — and
            # only priced — when the backend actually consumes it
            rep_lp = jax.lax.all_gather(f["lp"], "lp", axis=0, tiled=True)
        k_rep = jax.random.fold_in(jax.random.wrap_key_data(px["k_move"]),
                                   REPART_SALT)
        do = (t > 0) & (t % cfg.repartition_every == 0)

        def _recompute():
            tgt = jnp.where(gid_all >= 0, gid_all, n)  # pads -> dropped
            pos_n = jnp.zeros((n, 2), f["pos"].dtype).at[tgt].set(
                rep_pos, mode="drop")
            prev = None
            if rep_lp is not None:
                # every live SE appears in the gather, so the scatter
                # rebuilds exactly the oracle's `lp` (bit-identity)
                prev = jnp.full((n,), -1, jnp.int32).at[tgt].set(
                    rep_lp, mode="drop")
            # open world: dead ids carry zero weight (and zero position —
            # the oracle zeroes them too, so both layers feed the
            # partitioner byte-identical inputs)
            weights = jnp.zeros((n,), jnp.float32).at[tgt].set(
                1.0, mode="drop") if cfg.open_world else \
                jnp.ones((n,), jnp.float32)
            new_lp_n = part.partition(k_rep, pos_n, weights, pcfg,
                                      prev=prev)
            return new_lp_n[safe_gid]

        new_lp = jax.lax.cond(do, _recompute, lambda: f["lp"])
        move = valid & (new_lp != f["lp"]) & (f["pending_dst"] < 0)
        f["pending_dst"] = jnp.where(move, new_lp, f["pending_dst"])
        f["pending_eta"] = jnp.where(move, t + cfg.migration_delay,
                                     f["pending_eta"])
        f["last_mig"] = jnp.where(move, t, f["last_mig"])
        reparts = jax.lax.psum(move.sum(), "lp")
        mig_flows = px["mig_flows"] + jax.lax.psum(
            jnp.zeros((L, L), jnp.int32).at[safe_lp, new_lp].add(
                move.astype(jnp.int32)), "lp")
        return dict(px, f=f, reparts=reparts, migs=px["migs"] + reparts,
                    mig_flows=mig_flows)

    def ph_heuristic(px):
        # 4/5. self-clustering: window update + evaluation are
        # row-local; the balancer's inputs are psum'd so every device
        # sees the same grants and the per-pair selection stays
        # shard-local (a pair's candidates all live on the shard owning
        # the source LP)
        f = dict(px["f"])
        valid, safe_lp, t = px["valid"], px["safe_lp"], px["t"]
        hstate = {k: f[k] for k in ("ring", "ptr", "since_eval",
                                    "last_mig")}
        hstate = heu.update_window(cfg.heuristic, hstate, px["counts"],
                                   px["sender"], t)
        cand, dest, alpha, hstate, n_eval_loc = heu.evaluate(
            cfg.heuristic, hstate, f["lp"], t, valid=valid, mf=px["mf"])
        n_evals = jax.lax.psum(n_eval_loc, "lp")
        cand = cand & (f["pending_dst"] < 0)
        cmat = jax.lax.psum(bal.candidate_matrix(cand, safe_lp, dest, L),
                            "lp")
        if cfg.balance == "asymmetric":
            cap_sh = jnp.asarray(cfg.effective_capacity(), jnp.float32)
            current = jax.lax.psum(
                jnp.bincount(jnp.where(valid, f["lp"], L),
                             length=L + 1)[:L], "lp")
            grants = bal.asymmetric_grants(cmat, current, cap_sh)
        else:
            grants = bal.symmetric_grants(cmat)
        admit = bal.select_migrations(cand, safe_lp, dest, alpha, grants,
                                      L, tiebreak=f["gid"])
        f["pending_dst"] = jnp.where(admit, dest, f["pending_dst"])
        f["pending_eta"] = jnp.where(admit, t + cfg.migration_delay,
                                     f["pending_eta"])
        hstate = dict(hstate,
                      last_mig=jnp.where(admit, t, hstate["last_mig"]))
        f.update(hstate)
        migs = px["migs"] + jax.lax.psum(admit.sum(), "lp")
        mig_flows = px["mig_flows"] + jax.lax.psum(
            jnp.zeros((L, L), jnp.int32).at[safe_lp, dest].add(
                admit.astype(jnp.int32)), "lp")
        return dict(px, f=f, n_evals=n_evals, migs=migs,
                    mig_flows=mig_flows)

    def ph_finalize(px):
        me = jax.lax.axis_index("lp")
        f = dict(px["f"])
        valid, wire = px["valid"], px["wire"]
        grb = _gather_row_bytes(cfg)
        if grb and D > 1:
            # id-order reconstruction gathers (flock / repartition):
            # their valid rows are real row payload, priced like the
            # halo rows (integer add — placement after the heuristic
            # phase leaves the sum exactly the historical value)
            vcnt = jax.lax.all_gather(px["n_valid"], "lp")  # (D,)
            wire = wire + (vcnt[:, None] * grb
                           * (1 - jnp.eye(D, dtype=jnp.int32)))

        # 6. negotiate step t+1's halo on step t's tail (the double
        # buffer): each device contributes its post-mobility occupancy
        # plus the cells of rows pending toward each destination, psum
        # ORs the bitmaps, and the dilation (3x3 + one step of motion)
        # makes the stale footprint a sound superset of tomorrow's true
        # need. This is the only global agreement the exchange requires,
        # and it overlaps this step's compute instead of stalling the
        # next step's head.
        if _sparse_halo(spec):
            nc = spec.grid.ncell
            ncells = nc * nc
            pend = valid & (f["pending_dst"] >= 0)
            pdev = dev_of_lp(jnp.maximum(f["pending_dst"], 0), spec)
            safe_cell = jnp.where(valid, px["cellC"], ncells)
            contrib = jnp.zeros((D, ncells), bool)
            contrib = contrib.at[jnp.full((C,), me), safe_cell].set(
                True, mode="drop")
            contrib = contrib.at[jnp.where(pend, pdev, D), safe_cell].set(
                True, mode="drop")
            occ_all = jax.lax.psum(contrib.astype(jnp.int32), "lp") > 0
            f["halo_need"] = neighbors.dilate_mask(
                occ_all.reshape(D, nc, nc),
                _dilation_radius(spec, abm)).reshape(D, ncells)

        local, total = px["local"], px["total"]
        halo_total = jax.lax.psum(px["halo_n"], "lp").astype(jnp.float32)
        remote_slots = ((D - 1) * px["all_valid"]).astype(jnp.float32)
        overflow = jax.lax.psum(
            (px["reshard_overflow"] | px["grid_overflow"]
             | px["halo_overflow"]).astype(jnp.int32), "lp")
        metrics = {
            "local_msgs": local.astype(jnp.float32),
            "remote_msgs": px["remote"].astype(jnp.float32),
            "migrations": px["migs"].astype(jnp.float32),
            "heu_evals": px["n_evals"].astype(jnp.float32),
            "lcr": local.astype(jnp.float32)
                   / jnp.maximum(total.astype(jnp.float32), 1.0),
            "lp_flows": px["flows"],
            "mig_flows": px["mig_flows"],
            "repartitions": px["reparts"].astype(jnp.float32),
            # mean remote agents a shard actually needs (its halo), as a
            # fraction of all remote agents — GAIA's clustering drives
            # this down, and the sparse exchange realizes the saving on
            # the wire
            "halo_frac": halo_total / jnp.maximum(remote_slots, 1.0),
            # exact per-step bytes of useful row payload exchanged
            # (packed halo rows + admitted cross-device migrations +
            # id-order reconstruction gathers); wire_flows is its per
            # device-pair breakdown, priced by costmodel.wct_env
            "bytes_on_wire": wire.sum().astype(jnp.float32),
            "wire_flows": wire,
            "shard_overflow": (overflow > 0).astype(jnp.float32),
        }
        if cfg.open_world:
            # live population (post-arrival), mirroring engine.step
            metrics["pop"] = px["all_valid"].astype(jnp.float32)
        if abm.workload == "epidemic":
            metrics["infected"] = px["infected"].astype(jnp.float32)
        return dict(px, f=f, metrics=metrics)

    halo_adds = (("cellC", "view_pos", "view_lp") if spec.grid is not None
                 else ("pos_g", "lp_g")) + ("halo_overflow", "halo_n")
    if abm.workload == "epidemic":
        halo_adds += (("view_eis",) if spec.grid is not None
                      else ("eis_g",))
    phases = [
        ("migrate", ph_migrate,
         ("wire", "reshard_overflow", "valid", "safe_gid", "n_valid",
          "all_valid")),
        ("mobility", ph_mobility,
         ("sender",) if row_local_mobility(abm) else ("sender", "gid_all")),
        ("halo_exchange", ph_halo, halo_adds),
        ("proximity", ph_proximity, ("counts", "grid_overflow")),
        ("accounting", ph_account,
         ("safe_lp", "flows", "local", "total", "remote", "migs",
          "n_evals", "mig_flows", "reparts")),
    ]
    if abm.workload == "epidemic":
        phases.insert(4, ("workload", ph_workload, ("infected",)))
    if cfg.repartition_every > 0:
        phases.append(("repartition", ph_repartition, ()))
    if cfg.gaia_on:
        phases.append(("heuristic", ph_heuristic, ()))
    phases.append(("finalize", ph_finalize, ("metrics",)))
    return phases


def _shard_step(f, k_move, k_send, t, mf, cfg, spec: ShardSpec):
    """Per-device body of one timestep (runs under shard_map). `mf` is
    the dynamic Migration Factor (see engine.run_window). The body is
    the fused composition of `_sharded_phases`; named scopes annotate
    profiler timelines without adding ops."""
    px = {"f": f, "k_move": k_move, "k_send": k_send, "t": t, "mf": mf}
    for name, fn, _ in _sharded_phases(cfg, spec):
        with jax.named_scope(f"step.{name}"):
            px = fn(px)
    return px["f"], px["metrics"]


_FIELD_SPECS = {
    "pos": P("lp"), "waypoint": P("lp"), "mob": P("lp"),
    "mob_g": P(),  # global mobility rows: replicated on every device
    "lp": P("lp"), "gid": P("lp"), "epi": P("lp"),
    "pending_dst": P("lp"), "pending_eta": P("lp"), "ring": P(None, "lp"),
    "ptr": P("lp"), "since_eval": P("lp"), "last_mig": P("lp"),
}

_METRIC_SPECS = {k: P() for k in
                 ("local_msgs", "remote_msgs", "migrations", "heu_evals",
                  "lcr", "lp_flows", "mig_flows", "repartitions",
                  "halo_frac", "bytes_on_wire", "wire_flows",
                  "shard_overflow")}


def _field_specs(spec: ShardSpec):
    """Per-SE field specs for this layout; the sparse halo adds the
    replicated `halo_need` double buffer to the carried state."""
    specs = dict(_FIELD_SPECS)
    if _sparse_halo(spec):
        specs["halo_need"] = P()
    return specs


def _metric_specs(cfg):
    """Metric output specs: open-world runs add the `pop` series,
    epidemic runs the `infected` series."""
    specs = dict(_METRIC_SPECS)
    if cfg.open_world:
        specs["pop"] = P()
    if cfg.abm.workload == "epidemic":
        specs["infected"] = P()
    return specs


def _batch_field_specs(spec: ShardSpec):
    """Batched replicas: a leading (unsharded) replica axis in front of
    every per-SE field's spec — the "lp" mesh axis keeps sharding the
    slot dimension, replicas ride along inside each shard."""
    return {k: P(None, *v) for k, v in _field_specs(spec).items()}


# ---------------------------------------------------------------------------
# per-phase trace execution (repro.obs.trace drives this)
# ---------------------------------------------------------------------------

#: phase-context keys that are per-device *scalars* inside the shard_map
#: body; at the jit boundary they travel as (D,) arrays sharded P("lp")
#: (the trace wrapper reshapes () <-> (1,) per device)
_PER_DEV = frozenset({"reshard_overflow", "halo_overflow", "grid_overflow",
                      "halo_n", "n_valid"})

#: phase-context keys whose leading axis is the per-device slot (or
#: view/cell) dimension — sharded P("lp") at the jit boundary
_SHARDED_PX = frozenset({"valid", "safe_gid", "sender", "counts",
                         "safe_lp", "cellC", "view_pos", "view_lp",
                         "view_eis"})


def _px_spec(key, cfg, spec: ShardSpec):
    """PartitionSpec of one phase-context entry at the jit boundary.
    Everything not explicitly sharded is replicated (psum'd counters,
    all-gathered id-order arrays, the raw key data, t, mf, wire)."""
    if key == "f":
        return _field_specs(spec)
    if key == "metrics":
        return _metric_specs(cfg)
    if key in _PER_DEV or key in _SHARDED_PX:
        return P("lp")
    return P()


def _wrap_phase(fn, in_keys, out_keys, cfg, spec: ShardSpec, mesh: Mesh):
    """Jit one phase as its own shard_map program over the full phase
    context, so the trace executor can time it in isolation. Per-device
    scalars cross the boundary as (1,)-per-device arrays."""
    in_specs = {k: _px_spec(k, cfg, spec) for k in in_keys}
    out_specs = {k: _px_spec(k, cfg, spec) for k in out_keys}

    def inner(px):
        px = {k: (v.reshape(()) if k in _PER_DEV else v)
              for k, v in px.items()}
        out = fn(px)
        return {k: (out[k].reshape((1,)) if k in _PER_DEV else out[k])
                for k in out_keys}

    return jax.jit(shard_map(inner, mesh=mesh, in_specs=(in_specs,),
                             out_specs=out_specs, check_rep=False))


def sharded_trace_phases(cfg, spec: ShardSpec, mesh: Mesh):
    """Ordered (name, jitted_fn) per-phase programs for the trace
    executor: each phase of `_sharded_phases` wrapped as its own
    jit(shard_map) over the accumulated phase context. Phase-split
    execution reproduces the step's semantics but is a profiling
    surface, not a bit-identity one — XLA fuses differently across the
    cut points, so traced runs are not asserted byte-equal to the fused
    scan (DESIGN.md §Observability)."""
    keys = frozenset({"f", "k_move", "k_send", "t", "mf"})
    wrapped = []
    for name, fn, adds in _sharded_phases(cfg, spec):
        out_keys = keys | set(adds)
        wrapped.append((name, _wrap_phase(fn, sorted(keys),
                                          sorted(out_keys), cfg, spec,
                                          mesh)))
        keys = out_keys
    return wrapped


def step_sharded(state, cfg, spec: ShardSpec, mesh: Mesh, mf=None):
    """One sharded timestep. Same contract as `engine.step`, on
    slot-major state; metrics additionally report halo_frac,
    bytes_on_wire, wire_flows and shard_overflow."""
    if mf is None:
        mf = jnp.float32(cfg.heuristic.mf)
    key, k_move, k_send = jax.random.split(state["key"], 3)
    fspecs = _field_specs(spec)
    fields = {k: state[k] for k in fspecs}
    fn = shard_map(
        partial(_shard_step, cfg=cfg, spec=spec),
        mesh=mesh,
        in_specs=(fspecs, P(), P(), P(), P()),
        out_specs=(fspecs, _metric_specs(cfg)),
        check_rep=False,  # psum'd outputs are replicated by construction
    )
    new_fields, metrics = fn(fields, jax.random.key_data(k_move),
                             jax.random.key_data(k_send), state["t"], mf)
    new_state = dict(new_fields, key=key, t=state["t"] + 1)
    return new_state, metrics


def step_sharded_batch(state, cfg, spec: ShardSpec, mesh: Mesh, mfs):
    """One timestep of R stacked replicas: `jax.vmap` of the per-device
    body *inside* `shard_map`, so each device advances its shard of all
    R replicas in one pass and the collectives batch across the replica
    axis. Because the vmapped body is the very `_shard_step` the
    single-replica path runs, per-seed bit-identity with the oracle is
    inherited rather than re-proven (tests/test_replicas.py). `mfs` is
    the (R,) per-replica Migration Factor vector."""
    ks = jax.vmap(lambda k: jax.random.split(k, 3))(state["key"])
    key, k_move, k_send = ks[:, 0], ks[:, 1], ks[:, 2]
    fspecs = _field_specs(spec)
    fields = {k: state[k] for k in fspecs}
    fn = shard_map(
        jax.vmap(partial(_shard_step, cfg=cfg, spec=spec),
                 in_axes=(0, 0, 0, 0, 0)),
        mesh=mesh,
        in_specs=(_batch_field_specs(spec), P(), P(), P(), P()),
        out_specs=(_batch_field_specs(spec), _metric_specs(cfg)),
        check_rep=False,
    )
    new_fields, metrics = fn(fields, jax.random.key_data(k_move),
                             jax.random.key_data(k_send), state["t"], mfs)
    return dict(new_fields, key=key, t=state["t"] + 1), metrics


# ---------------------------------------------------------------------------
# open-world churn ops (mirror engine.oracle_arrive / oracle_depart)
# ---------------------------------------------------------------------------


def _vacate_slots(f, hit):
    """Free the slots in `hit`: gid = lp = -1 plus a full slot-history
    reset (ring included, matching engine.oracle_depart — a reused slot
    carries nothing of its previous occupant)."""
    f = dict(f)
    f["gid"] = jnp.where(hit, -1, f["gid"])
    f["lp"] = jnp.where(hit, -1, f["lp"])
    f["pending_dst"] = jnp.where(hit, -1, f["pending_dst"])
    f["pending_eta"] = jnp.where(hit, -1, f["pending_eta"])
    f["last_mig"] = jnp.where(hit, -10**6, f["last_mig"])
    f["ptr"] = jnp.where(hit, 0, f["ptr"])
    f["since_eval"] = jnp.where(hit, 0, f["since_eval"])
    f["epi"] = jnp.where(hit, 0, f["epi"])
    f["ring"] = jnp.where(hit[None, :, None], 0, f["ring"])
    return f


def _shard_depart(f, ids, spec: ShardSpec):
    """Per-device body: vacate the slots holding global ids `ids`
    ((B,) replicated; -1 = padding). Returns (fields, found) with
    `found` the psum'd (B,) per-id located mask — the facade's
    exact-or-loud check against the requested batch."""
    eq = (f["gid"][:, None] == ids[None, :]) & (f["gid"] >= 0)[:, None]
    hit = eq.any(axis=1)
    found = jax.lax.psum(eq.any(axis=0).astype(jnp.int32), "lp") > 0
    return _vacate_slots(f, hit), found


def _shard_arrive(f, ids, pos, wp, mob, epi, lps, cfg, spec: ShardSpec):
    """Per-device body: insert B SEs (all args replicated; ids = -1 is
    padding). Each device claims the arrivals whose destination LP it
    owns and packs them into its free slots in ascending-slot order.
    Returns (fields, admitted): refusals (no free slot on the owning
    device) write nothing, and `admitted` is the psum'd (B,) per-arrival
    mask — the facade raises on any refusal, exact-or-loud, naming
    shard_capacity. Admitted arrival cells are OR'd (dilated) into the
    owning device's halo-need bitmap so the very next step's exchange
    already covers them."""
    me = jax.lax.axis_index("lp")
    real = ids >= 0
    mine = real & (dev_of_lp(jnp.maximum(lps, 0), spec) == me)
    free = f["gid"] < 0
    free_order = jnp.argsort(~free, stable=True)  # free slots first, asc
    arr_rank = jnp.cumsum(mine) - 1
    admitted = mine & (arr_rank < free.sum())
    target = jnp.where(admitted,
                       free_order[jnp.clip(arr_rank, 0, spec.cap - 1)],
                       spec.cap)

    f = dict(f)
    f["pos"] = f["pos"].at[target].set(pos, mode="drop")
    f["waypoint"] = f["waypoint"].at[target].set(wp, mode="drop")
    f["mob"] = f["mob"].at[target].set(mob, mode="drop")
    f["epi"] = f["epi"].at[target].set(epi, mode="drop")
    f["gid"] = f["gid"].at[target].set(ids, mode="drop")
    f["lp"] = f["lp"].at[target].set(lps, mode="drop")
    f["pending_dst"] = f["pending_dst"].at[target].set(-1, mode="drop")
    f["pending_eta"] = f["pending_eta"].at[target].set(-1, mode="drop")
    f["ring"] = f["ring"].at[:, target, :].set(0, mode="drop")
    f["ptr"] = f["ptr"].at[target].set(0, mode="drop")
    f["since_eval"] = f["since_eval"].at[target].set(0, mode="drop")
    f["last_mig"] = f["last_mig"].at[target].set(-10**6, mode="drop")
    adm = jax.lax.psum(admitted.astype(jnp.int32), "lp") > 0

    if _sparse_halo(spec):
        # the negotiated need bitmaps predate this arrival; OR its
        # dilated cell into the owner's footprint so step t+1's exchange
        # is sound without waiting a step (departures only shrink the
        # true need, so their stale superset stays sound untouched)
        g = spec.grid
        ncells = g.ncell * g.ncell
        cell = neighbors.cell_ids(pos, g)
        contrib = jnp.zeros((spec.n_dev, ncells), bool)
        dev = dev_of_lp(jnp.maximum(lps, 0), spec)
        contrib = contrib.at[jnp.where(real, dev, spec.n_dev),
                             cell].set(True, mode="drop")
        contrib = jax.lax.psum(contrib.astype(jnp.int32), "lp") > 0
        f["halo_need"] = f["halo_need"] | neighbors.dilate_mask(
            contrib.reshape(spec.n_dev, g.ncell, g.ncell),
            _dilation_radius(spec, cfg.abm)).reshape(spec.n_dev, ncells)
    return f, adm


@functools.lru_cache(maxsize=COMPILED_CACHE_SIZE)
def _compiled_depart_sharded(key_cfg):
    spec = make_shard_spec(key_cfg)
    mesh = make_mesh(spec)
    fspecs = _field_specs(spec)
    fn = shard_map(partial(_shard_depart, spec=spec), mesh=mesh,
                   in_specs=(fspecs, P()), out_specs=(fspecs, P()),
                   check_rep=False)
    return jax.jit(fn), spec


@functools.lru_cache(maxsize=COMPILED_CACHE_SIZE)
def _compiled_arrive_sharded(key_cfg):
    spec = make_shard_spec(key_cfg)
    mesh = make_mesh(spec)
    fspecs = _field_specs(spec)
    fn = shard_map(partial(_shard_arrive, cfg=key_cfg, spec=spec),
                   mesh=mesh,
                   in_specs=(fspecs, P(), P(), P(), P(), P(), P()),
                   out_specs=(fspecs, P()), check_rep=False)
    return jax.jit(fn), spec


def depart_sharded(state, cfg, ids):
    """Vacate the slots of global ids `ids` (-1 = padding). Returns
    (state, found): the (B,) per-id located mask."""
    from repro.core.engine import strip_obs, window_key_cfg
    fn, spec = _compiled_depart_sharded(window_key_cfg(strip_obs(cfg)))
    fields = {k: state[k] for k in _field_specs(spec)}
    new_fields, found = fn(fields, jnp.asarray(ids, jnp.int32))
    return dict(new_fields, key=state["key"], t=state["t"]), found


def arrive_sharded(state, cfg, ids, rows):
    """Insert SEs with global ids `ids` (-1 = padding) into free slots
    of the devices owning rows["lp"]. Returns (state, admitted): the
    (B,) per-arrival admission mask — refused arrivals wrote nothing
    (see Engine.arrive for the loud path)."""
    from repro.core.engine import strip_obs, window_key_cfg
    fn, spec = _compiled_arrive_sharded(window_key_cfg(strip_obs(cfg)))
    fields = {k: state[k] for k in _field_specs(spec)}
    pos = jnp.asarray(rows["pos"], jnp.float32)
    new_fields, adm = fn(
        fields, jnp.asarray(ids, jnp.int32), pos,
        jnp.asarray(rows.get("waypoint", pos), jnp.float32),
        jnp.asarray(rows.get("mob", jnp.zeros_like(pos)), jnp.float32),
        jnp.asarray(rows.get("epi", jnp.zeros(pos.shape[:1], jnp.int32)),
                    jnp.int32),
        jnp.asarray(rows["lp"], jnp.int32))
    return dict(new_fields, key=state["key"], t=state["t"]), adm


# ---------------------------------------------------------------------------
# runners (mirror engine.run / engine.run_window)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=COMPILED_CACHE_SIZE)
def _compiled_window_sharded(key_cfg, n_steps: int):
    # mirror of engine._compiled_window: one jitted scan per config
    # shape, MF dynamic (key_cfg comes pre-normalized via
    # engine.window_key_cfg, so MF sweeps share one executable)
    spec = make_shard_spec(key_cfg)
    mesh = make_mesh(spec)

    if not key_cfg.obs.enabled:
        def fn(state, mf):
            def body(s, _):
                return step_sharded(s, key_cfg, spec, mesh, mf=mf)
            return jax.lax.scan(body, state, None, length=n_steps)
        return jax.jit(fn)

    # telemetry on: same ring-drain design as engine._compiled_window,
    # living at the jit level *outside* shard_map — the metrics the row
    # reads are psum-replicated and the slot-major state is globally
    # addressable here, so the callback executes once per wrap (not per
    # device) under single-process SPMD
    de = key_cfg.obs.drain_every
    n_cols = len(obs_ledger.ledger_keys(key_cfg))

    def fn(state, mf):
        def body(carry, _):
            s, ring = carry
            s2, m = step_sharded(s, key_cfg, spec, mesh, mf=mf)
            t = s["t"]
            ring = ring.at[t % de].set(
                obs_ledger.ledger_row(key_cfg, s2, m, t))
            jax.lax.cond(
                (t + 1) % de == 0,
                lambda r, tt: jax.debug.callback(obs_runtime.on_block,
                                                 r, tt, ordered=False),
                lambda r, tt: None,
                ring, t)
            return (s2, ring), m
        ring0 = jnp.full((de, n_cols), -1.0, jnp.float32)
        (s, ring), series = jax.lax.scan(body, (state, ring0), None,
                                         length=n_steps)
        return s, ring, series
    return jax.jit(fn)


def _scan_sharded(state, cfg, n_steps: int, mf=None):
    from repro.core.engine import window_key_cfg
    mf_val = jnp.float32(cfg.heuristic.mf if mf is None else mf)
    if cfg.obs.enabled:
        t0 = int(state["t"])
        state, ring, series = _compiled_window_sharded(
            window_key_cfg(cfg), n_steps)(state, mf_val)
        obs_runtime.flush_tail(ring, t0, t0 + n_steps)
        return state, series
    return _compiled_window_sharded(window_key_cfg(cfg), n_steps)(
        state, mf_val)


def _series_counters(series):
    from repro.core.engine import series_counters
    counters = series_counters(series)
    counters["mean_halo_frac"] = float(series["halo_frac"].mean())
    counters["shard_overflow"] = float(series["shard_overflow"].sum())
    wf = np.asarray(series["wire_flows"], np.int64)
    counters["bytes_on_wire"] = float(wf.sum())
    counters["wire_flows"] = wf.sum(axis=0).tolist()
    return counters


def run_window_sharded(state, cfg, n_steps: int, mf=None):
    state, series = _scan_sharded(state, cfg, n_steps, mf=mf)
    return state, _series_counters(series)


def run_sharded(key, cfg):
    """Sharded mirror of `engine.run`: returns (final_state, series,
    counters) with the final state unsharded back to gid-order, so
    callers (and the equivalence tests) see the oracle's layout."""
    from repro.core.engine import _migration_ratio
    spec = make_shard_spec(cfg)
    st = init_sharded(key, cfg, spec)
    st, series = _scan_sharded(st, cfg, cfg.timesteps)
    counters = _series_counters(series)
    counters["migration_ratio"] = _migration_ratio(counters, cfg)  # Eq. 8
    return unshard_state(st, spec), series, counters


# ---------------------------------------------------------------------------
# batched multi-replica runners (mirror engine.run_batch/run_window_batch)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=COMPILED_CACHE_SIZE)
def _compiled_batch_sharded(key_cfg, n_steps: int):
    # mirror of engine._compiled_batch: one jitted batched scan per
    # config shape, per-replica MF dynamic (jit re-specializes per
    # replica count)
    spec = make_shard_spec(key_cfg)
    mesh = make_mesh(spec)

    def fn(state, mfs):
        def body(s, _):
            return step_sharded_batch(s, key_cfg, spec, mesh, mfs)
        return jax.lax.scan(body, state, None, length=n_steps)
    return jax.jit(fn)


def _scan_batch_sharded(states, cfg, n_steps: int, mf=None):
    # batched scans are un-instrumented (strip_obs): the ledger covers
    # the single-replica resident paths
    from repro.core.engine import _mf_vector, strip_obs, window_key_cfg
    n_rep = states["t"].shape[0]
    return _compiled_batch_sharded(window_key_cfg(strip_obs(cfg)), n_steps)(
        states, _mf_vector(cfg, mf, n_rep))


def _batch_replica_counters(series, n_rep: int):
    from repro.core.engine import replica_series
    return [_series_counters(replica_series(series, r))
            for r in range(n_rep)]


def run_window_batch_sharded(states, cfg, n_steps: int, mf=None):
    states, series = _scan_batch_sharded(states, cfg, n_steps, mf=mf)
    return states, _batch_replica_counters(series, states["t"].shape[0])


def unshard_batch(states, spec: ShardSpec):
    """Unshard each replica of a stacked slot-major state back to the
    oracle's gid-order layout (stacked again on the replica axis)."""
    from repro.core.engine import stack_states
    n_rep = states["t"].shape[0]
    return stack_states([
        unshard_state({k: v[r] for k, v in states.items()}, spec)
        for r in range(n_rep)])


def run_batch_sharded(cfg, seeds):
    """Sharded mirror of `engine.run_batch`: R replicas vmapped inside
    each shard, final states unsharded to gid-order per replica — so
    sharded replicas compare byte-for-byte against oracle replicas."""
    from repro.core.engine import (_migration_ratio, replica_keys,
                                   stack_states)
    spec = make_shard_spec(cfg)
    states = stack_states([init_sharded(k, cfg, spec)
                           for k in replica_keys(seeds)])
    states, series = _scan_batch_sharded(states, cfg, cfg.timesteps)
    reps = _batch_replica_counters(series, len(seeds))
    for c in reps:
        c["migration_ratio"] = _migration_ratio(c, cfg)  # Eq. 8
    return unshard_batch(states, spec), series, reps

from repro.parallel.ctx import ParallelCtx, make_ctx  # noqa: F401
from repro.parallel.lp_shard import (  # noqa: F401
    ShardSpec, make_shard_spec, run_sharded)

from repro.parallel.ctx import ParallelCtx, make_ctx  # noqa: F401

"""Pipeline parallelism (GPipe schedule) over a mesh axis.

For the multi-pod mesh the natural stage axis is "pod": each pod holds a
contiguous slice of layers, microbatches stream across the (slow)
inter-pod links via collective_permute, and the bubble fraction is
(P-1)/(P-1+M). Expressed with shard_map: the stage body runs its local
layer slice; `ppermute` hands activations to the next stage.

This module is the library feature + tests; the default dry-run configs
use pod-axis data parallelism (better MFU at 2 pods — see DESIGN.md
§Parallelism for the trade-off), and the trainer can opt in with
--pipeline pod.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn: Callable, mesh, axis: str, n_micro: int):
    """Build a pipelined forward: y = stages(x), stages split over `axis`.

    stage_fn(stage_params, x) -> y applies ONE stage's layers.
    Inputs: stage_params pytree with leading stage dim (sharded over
    `axis`); x (n_micro, B_m, ...) replicated. Output replicated.
    """
    n_stage = mesh.shape[axis]

    def body(params, xs):
        stage = jax.lax.axis_index(axis)
        params = jax.tree.map(lambda p: p[0], params)  # this stage's slice
        n_ticks = n_micro + n_stage - 1
        buf = jnp.zeros_like(xs[0])  # current activation holding slot
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when valid)
            take = jnp.clip(t, 0, n_micro - 1)
            inject = jnp.where(stage == 0,
                               jnp.float32(t < n_micro), 0.0)
            x_in = jnp.where(inject > 0, xs[take], buf)
            y = stage_fn(params, x_in)
            # pass activations down the pipe
            perm = [(i, (i + 1) % n_stage) for i in range(n_stage)]
            nxt = jax.lax.ppermute(y, axis, perm)
            # last stage emits microbatch t - (n_stage - 1)
            emit_idx = jnp.clip(t - (n_stage - 1), 0, n_micro - 1)
            is_emit = jnp.logical_and(stage == n_stage - 1,
                                      t >= n_stage - 1)
            outs = jax.lax.cond(
                is_emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, emit_idx, 0),
                lambda o: o, outs)
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(n_ticks))
        # broadcast results from the last stage to everyone
        outs = jax.lax.psum(
            jnp.where(stage == n_stage - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    in_specs = (P(axis), P())
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)

"""Parallel execution context.

``ParallelCtx`` carries the mesh and the axis-naming/layout policy through
the model code. Model code never hard-codes axis names; it asks the ctx for
sharding constraints, and the ctx degrades gracefully to a no-op on a
single-device mesh (smoke tests) or when a dimension does not divide the
axis size (e.g. 4 KV heads on a 16-way model axis, or qwen2's 28 query
heads -> sequence-sharded attention fallback).

Axis convention (see launch/mesh.py):
    single-pod : ("data", "model")            = (16, 16)
    multi-pod  : ("pod", "data", "model")     = (2, 16, 16)

Batch is sharded over ("pod","data"); tensor-parallel dims over "model".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisEntry = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Optional[Mesh]
    batch_axes: Tuple[str, ...]  # ("pod","data") or ("data",) or ()
    model_axis: Optional[str]  # "model" or None
    # --- layout / schedule policy knobs (hillclimbed in EXPERIMENTS.md §Perf)
    seq_shard_attn: bool = False  # qwen2 fallback: shard S instead of heads
    # sequence parallelism (Megatron SP): the residual stream stays
    # S-sharded over the model axis between blocks; GSPMD all-gathers at
    # the TP boundary and reduce-scatters back. Cuts per-device activation
    # residency by model_size (decisive for prefill_32k on big d_model).
    seq_parallel: bool = True
    num_microbatches: int = 1
    remat: str = "full"  # "none" | "full" | "dots"
    zero1: bool = True
    use_pallas: bool = False
    # attention flash block sizes (jnp reference path)
    q_block: int = 512
    kv_block: int = 1024
    # causal scheduling: skip fully-masked KV blocks (§Perf iteration)
    causal_skip: bool = True
    # unroll inner scans (cost-analysis lowering only: XLA's HLO cost
    # analysis counts while bodies once, so the roofline component pass
    # lowers single layers with loops unrolled)
    scan_unroll: bool = False
    # FSDP-style weight sharding: every param additionally shards its
    # largest free dim over the data axes (GSPMD all-gathers at use).
    # Required for >=100B-param models on 16GB chips (deepseek-v3).
    fsdp: bool = False
    # 2-D expert parallelism: experts shard over (data x model) jointly
    # (deepseek: 256 experts over 256 ranks = 1 expert/device, weights
    # never gathered; tokens move via all-to-all instead). Falls back to
    # grouped EP when E doesn't divide the joint axis size. §Perf knob.
    ep2d: bool = False
    # gradient accumulator dtype ("f32" | "bf16")
    grad_dtype: str = "f32"
    # sequence-chunked cross-entropy (0 = off): avoids materializing the
    # full (B,S,V) fp32 logits; logits recomputed per chunk in the bwd
    loss_chunk: int = 0
    # optimizer: "adamw" | "adafactor" (factored 2nd moment, bf16 momentum)
    optimizer: str = "adamw"

    # ------------------------------------------------------------------
    @property
    def data_size(self) -> int:
        if self.mesh is None:
            return 1
        if not self.batch_axes:
            return 1
        return math.prod(self.mesh.shape[a] for a in self.batch_axes)

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def n_devices(self) -> int:
        return 1 if self.mesh is None else self.mesh.devices.size

    @property
    def ep_axes(self) -> AxisEntry:
        """Expert-parallel axes: innermost data axis + model axis (pods
        replicate experts; their grads all-reduce over the pod links)."""
        if self.mesh is None or self.model_axis is None:
            return None
        if self.batch_axes:
            return (self.batch_axes[-1], self.model_axis)
        return self.model_axis

    # ------------------------------------------------------------------
    def constrain(self, x: jax.Array, *spec: AxisEntry) -> jax.Array:
        """with_sharding_constraint; silently a no-op without a mesh."""
        if self.mesh is None:
            return x
        assert len(spec) == x.ndim, (spec, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec))
        )

    def sharding(self, *spec: AxisEntry) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*spec))

    # ------------------------------------------------------------------
    def axis_size(self, entry: AxisEntry) -> int:
        if entry is None or self.mesh is None:
            return 1
        if isinstance(entry, str):
            return self.mesh.shape[entry]
        return math.prod(self.mesh.shape[a] for a in entry)

    def shard_if(self, dim: int, entry: AxisEntry) -> AxisEntry:
        """Return `entry` if `dim` divides its total size, else None."""
        n = self.axis_size(entry)
        return entry if (n > 1 and dim % n == 0) else None

    def batch_spec(self, batch: int) -> AxisEntry:
        """Largest prefix of the batch axes that divides `batch`."""
        if self.mesh is None or not self.batch_axes:
            return None
        axes = []
        prod = 1
        for a in self.batch_axes:
            if batch % (prod * self.mesh.shape[a]) == 0:
                axes.append(a)
                prod *= self.mesh.shape[a]
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]

    def seq_entry(self, seq: int) -> AxisEntry:
        """Sequence-parallel residual sharding (None when off/indivisible)."""
        if not self.seq_parallel:
            return None
        return self.shard_if(seq, self.model_axis)

    def seq_mega_spec(self, seq: int) -> AxisEntry:
        """Shard a long sequence over every available axis (long_500k KV)."""
        if self.mesh is None:
            return None
        axes = tuple(self.batch_axes) + ((self.model_axis,) if self.model_axis else ())
        prod = math.prod(self.mesh.shape[a] for a in axes)
        if axes and seq % prod == 0:
            return axes
        return self.shard_if(seq, self.model_axis)


def make_ctx(mesh: Optional[Mesh], **kw) -> ParallelCtx:
    """Build a ParallelCtx from a mesh created by launch.mesh."""
    if mesh is None:
        return ParallelCtx(mesh=None, batch_axes=(), model_axis=None, **kw)
    names = mesh.axis_names
    if names == ("pod", "data", "model"):
        return ParallelCtx(mesh, ("pod", "data"), "model", **kw)
    if names == ("data", "model"):
        return ParallelCtx(mesh, ("data",), "model", **kw)
    if names == ("data",):
        return ParallelCtx(mesh, ("data",), None, **kw)
    raise ValueError(f"unrecognized mesh axes {names}")

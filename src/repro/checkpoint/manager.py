"""Fault-tolerant checkpointing.

Design points (scaled-down but faithful to multi-pod practice):

* atomic commit: write to ``step_N.tmp/``, fsync, then rename — a crash
  mid-save never corrupts the latest checkpoint; restore picks the
  newest *committed* step.
* integrity: every array file carries a content hash in the manifest;
  restore verifies before handing weights to the trainer.
* async save: ``save(..., blocking=False)`` snapshots to host memory
  synchronously (cheap) and writes in a background thread, overlapping
  the next training steps — the trainer only blocks if a second save
  starts before the first finished.
* elastic reshape: arrays are stored unsharded (np), so a restart may
  build a different mesh (fewer/more healthy hosts) and reshard on load:
  ``restore(..., shardings=new_shardings)``.
* retention: keep the last `keep` checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return max(steps) if steps else None

    def _path(self, step: int, tmp=False):
        return os.path.join(self.dir, f"step_{step}" + (".tmp" if tmp else ""))

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True):
        """Checkpoint `tree` at `step`. With blocking=False the device->
        host snapshot happens now, the file writes in the background."""
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._thread is not None:
            self._thread.join()  # one async save in flight at a time

        def write():
            tmp = self._path(step, tmp=True)
            final = self._path(step)
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            leaves, treedef = _flatten(host)
            manifest = {"step": step, "n": len(leaves),
                        "treedef": str(treedef), "files": []}
            for i, leaf in enumerate(leaves):
                arr = np.asarray(leaf)
                fp = os.path.join(tmp, f"leaf_{i}.npy")
                np.save(fp, arr)
                with open(fp, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                manifest["files"].append(
                    {"i": i, "sha256": digest, "dtype": str(arr.dtype),
                     "shape": list(arr.shape)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(s for s in (self.latest_step(),) if s is not None)
        all_steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs). `shardings` (optional pytree) enables elastic
        resharding onto a different mesh than the one that saved."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = _flatten(like)
        if len(leaves_like) != manifest["n"]:
            raise ValueError(
                f"checkpoint has {manifest['n']} leaves, expected "
                f"{len(leaves_like)} — architecture mismatch?")
        leaves = []
        for meta in manifest["files"]:
            fp = os.path.join(path, f"leaf_{meta['i']}.npy")
            with open(fp, "rb") as f:
                raw = f.read()
            if hashlib.sha256(raw).hexdigest() != meta["sha256"]:
                raise IOError(f"checksum mismatch in {fp}")
            leaves.append(np.load(fp))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None
                else jnp_asarray(x), tree, shardings)
        return tree, step


def jnp_asarray(x):
    import jax.numpy as jnp
    return jnp.asarray(x)

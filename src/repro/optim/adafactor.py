"""Adafactor [arXiv:1804.04235] with bf16 momentum and fp32 master weights.

The second moment is rank-factored over the last two dims (row/col means),
cutting optimizer state from 12 bytes/param (AdamW fp32 m+v) to
~6 bytes/param (fp32 master + bf16 m + negligible factored v). This is
what makes deepseek-v3-671b training *fit* on the 512-chip mesh — see
EXPERIMENTS.md §Dry-run capacity notes.

``adafactor_lean_*`` is the single-pod 671B variant: classic Adafactor
(beta1=0, no momentum buffer) with NO fp32 master — bf16 params are
updated directly with *stochastic rounding* (unbiased; the standard
recipe for sub-fp32 weight training, cf. Gopher / DeepSeek-V3's own
low-precision recipes). State drops to the factored second moment only
(~0.01 bytes/param), so weights+grads+state = ~4 bytes/param: 671B fits
in 256 x 16 GiB with room for activations. Accuracy trade recorded in
DESIGN.md §Deviations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, global_norm, lr_at


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def vrow(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p.shape)
                else jnp.zeros(p.shape, jnp.float32))

    def vcol(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p.shape) else jnp.zeros((1,), jnp.float32))

    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
        "vr": jax.tree.map(vrow, params),
        "vc": jax.tree.map(vcol, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_apply(c: AdamWConfig, grads, state, params,
                    decay: float = 0.999):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(c, step)

    def upd(g, m, vr, vc, w):
        g = g.astype(jnp.float32) * scale
        g2 = jnp.square(g) + 1e-30
        if _factored(g.shape):
            vr = decay * vr + (1 - decay) * g2.mean(axis=-1)
            vc = decay * vc + (1 - decay) * g2.mean(axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(axis=-1)[..., None, None], 1e-30))
            u = g * jax.lax.rsqrt(denom + 1e-30)
        else:
            vr = decay * vr + (1 - decay) * g2
            u = g * jax.lax.rsqrt(vr + 1e-30)
        # update clipping (RMS<=1) per the paper
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        m32 = c.beta1 * m.astype(jnp.float32) + (1 - c.beta1) * u
        w = w - lr * (m32 + c.weight_decay * w)
        return m32.astype(jnp.bfloat16), vr, vc, w

    flat_g, treedef = jax.tree.flatten(grads)
    fm = treedef.flatten_up_to(state["m"])
    fvr = treedef.flatten_up_to(state["vr"])
    fvc = treedef.flatten_up_to(state["vc"])
    fw = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, vr, vc, w)
           for g, m, vr, vc, w in zip(flat_g, fm, fvr, fvc, fw)]
    new_state = {
        "m": treedef.unflatten([o[0] for o in out]),
        "vr": treedef.unflatten([o[1] for o in out]),
        "vc": treedef.unflatten([o[2] for o in out]),
        "master": treedef.unflatten([o[3] for o in out]),
        "step": step,
    }
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype),
                              new_state["master"], params)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# Lean variant: no master, no momentum, stochastic-rounding bf16 updates
# ---------------------------------------------------------------------------


def _stochastic_round_bf16(key, x32):
    """Unbiased fp32 -> bf16 rounding: add uniform 16-bit noise below the
    bf16 mantissa, truncate. E[round(x)] = x."""
    bits = jax.lax.bitcast_convert_type(x32, jnp.uint32)
    noise = jax.random.bits(key, x32.shape, jnp.uint32) & jnp.uint32(0xFFFF)
    trunc = (bits + noise) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(trunc, jnp.float32).astype(
        jnp.bfloat16)


def adafactor_lean_init(params):
    def vrow(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p.shape)
                else jnp.zeros(p.shape, jnp.float32))

    def vcol(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p.shape) else jnp.zeros((1,), jnp.float32))

    return {
        "vr": jax.tree.map(vrow, params),
        "vc": jax.tree.map(vcol, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_lean_apply(c: AdamWConfig, grads, state, params,
                         decay: float = 0.999):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(c, step)
    base_key = jax.random.fold_in(jax.random.key(17), step)

    def upd(i, g, vr, vc, w):
        g = g.astype(jnp.float32) * scale
        g2 = jnp.square(g) + 1e-30
        if _factored(g.shape):
            vr = decay * vr + (1 - decay) * g2.mean(axis=-1)
            vc = decay * vc + (1 - decay) * g2.mean(axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(axis=-1)[..., None, None], 1e-30))
            u = g * jax.lax.rsqrt(denom + 1e-30)
        else:
            vr = decay * vr + (1 - decay) * g2
            u = g * jax.lax.rsqrt(vr + 1e-30)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        w32 = w.astype(jnp.float32)
        w32 = w32 - lr * (u + c.weight_decay * w32)
        if w.dtype == jnp.bfloat16:
            w = _stochastic_round_bf16(jax.random.fold_in(base_key, i), w32)
        else:
            w = w32.astype(w.dtype)
        return vr, vc, w

    flat_g, treedef = jax.tree.flatten(grads)
    fvr = treedef.flatten_up_to(state["vr"])
    fvc = treedef.flatten_up_to(state["vc"])
    fw = treedef.flatten_up_to(params)
    out = [upd(i, g, vr, vc, w)
           for i, (g, vr, vc, w) in enumerate(zip(flat_g, fvr, fvc, fw))]
    new_state = {
        "vr": treedef.unflatten([o[0] for o in out]),
        "vc": treedef.unflatten([o[1] for o in out]),
        "step": step,
    }
    new_params = treedef.unflatten([o[2] for o in out])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

"""DiLoCo-style cross-pod training (arXiv:2311.08105).

Each pod runs H local AdamW steps on its own data; every H steps the
pods exchange only the parameter *delta* (not per-step gradients) and an
outer Nesterov-momentum optimizer applies the pod-averaged delta to the
global weights. Cross-pod traffic drops by H-x versus synchronous DP —
the natural fit for the production mesh's weak pod links, and exactly
the GAIA trade at another level: pay rare bulk communication (outer
sync ~ migration) to avoid constant fine-grained remote traffic.

The outer step composes with the q8 compressed all-reduce in
optim/compress.py for a further 4x on the delta payload.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DiLoCoConfig:
    inner_steps: int = 50  # H
    outer_lr: float = 0.7
    outer_momentum: float = 0.9  # Nesterov


def diloco_init(params):
    return {
        "global": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "velocity": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params),
    }


def outer_step(cfg: DiLoCoConfig, state, pod_params_mean) -> Tuple[Any, Any]:
    """Apply the outer Nesterov update given the POD-AVERAGED inner
    parameters after H local steps.

    Returns (new_state, new_start_params) — every pod restarts its inner
    loop from the updated global weights."""
    delta = jax.tree.map(
        lambda g, p: g - p.astype(jnp.float32),
        state["global"], pod_params_mean)  # outer "gradient"
    vel = jax.tree.map(
        lambda v, d: cfg.outer_momentum * v + d, state["velocity"], delta)
    new_global = jax.tree.map(
        lambda g, v, d: g - cfg.outer_lr * (cfg.outer_momentum * v + d),
        state["global"], vel, delta)
    new_state = {"global": new_global, "velocity": vel}
    return new_state, new_global

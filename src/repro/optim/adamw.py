"""AdamW with fp32 master weights, global-norm clipping and a linear
warmup + cosine schedule. Optimizer state is ZeRO-1 sharded over the data
axes (see repro/parallel/sharding.py:zero1_spec): m/v/master carry an
extra data-axis sharding on their largest divisible dim, and GSPMD's
reduce-scatter/all-gather around the update IS the ZeRO-1 schedule.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp



@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def lr_at(c: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = c.lr * step / max(c.warmup_steps, 1)
    t = jnp.clip((step - c.warmup_steps)
                 / max(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 * c.lr + 0.9 * c.lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < c.warmup_steps, warm, cos)


def adamw_init(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_apply(c: AdamWConfig, grads, state, params):
    """Returns (new_params bf16, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(c, step)
    b1c = 1 - c.beta1 ** step.astype(jnp.float32)
    b2c = 1 - c.beta2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = c.beta1 * m + (1 - c.beta1) * g
        v = c.beta2 * v + (1 - c.beta2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        w = w - lr * (mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * w)
        return m, v, w

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_w = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), new_w, params)
    new_state = {"m": new_m, "v": new_v, "master": new_w, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

"""Gradient compression for the slow cross-pod links.

int8 block-quantized all-reduce with error feedback: before the pod
all-reduce each leaf is scaled per 256-value block to int8; the
quantization residual is carried in an error-feedback buffer and added
back the next step, so the compressed trajectory converges to the
uncompressed one (EF-SGD, arXiv:1901.09847). Cross-pod payload drops 4x
(fp32 -> int8 + 1 fp32 scale per 256 values) while intra-pod ICI still
carries full-precision reductions.

The collective is expressed with shard_map over the pod axis — inside
the body the leaf is one pod's partial gradient and jax.lax.psum is the
explicit cross-pod collective being compressed.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

BLOCK = 256


def quantize_q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. Returns (q, scale)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_q8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def ef_q8_step(g: jax.Array, e: jax.Array, axis_name: str, n: int):
    """One error-feedback compressed reduction of a single leaf.

    g: this pod's gradient; e: this pod's residual from the last step.
    Returns (mean over pods of dequantized grads, new residual)."""
    target = g.astype(jnp.float32) + e
    q, scale = quantize_q8(target)
    deq = dequantize_q8(q, scale, g.shape)
    new_e = target - deq  # residual never leaves the pod
    mean = jax.lax.psum(deq, axis_name) / n
    return mean.astype(g.dtype), new_e


def q8_cross_pod_mean(grads: Any, err: Any, mesh, pod_axis: str = "pod"):
    """Compressed mean over the pod axis for a pytree of *stacked*
    per-pod gradients: every leaf has leading dim n_pods, sharded over
    `pod_axis`. Residuals `err` have the same stacked layout (fp32).

    Returns (mean_grads stacked+replicated-content, new_err)."""
    n = mesh.shape[pod_axis]

    def body(gt, et):
        def one(g, e):
            m, ne = ef_q8_step(g[0], e[0], pod_axis, n)
            return m[None], ne[None]

        out = jax.tree.map(one, gt, et)
        mean = jax.tree.map(lambda pr: pr[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        new_e = jax.tree.map(lambda pr: pr[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return mean, new_e

    spec = P(pod_axis)
    fn = shard_map(body, mesh=mesh, in_specs=(spec, spec),
                   out_specs=(spec, spec), check_rep=False)
    return fn(grads, err)

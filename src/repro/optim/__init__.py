from repro.optim.adamw import AdamWConfig, adamw_init, adamw_apply  # noqa: F401

"""Run a non-uniform mobility scenario and price it on every
execution-environment preset.

The hotspot workload concentrates SEs into K dense blobs chasing moving
attractors — sustained non-uniform density, the case where GAIA's
self-clustering has to prove itself beyond uniform RWP. The same engine
counters are then priced on each ExecutionEnvironment (shared-memory /
LAN / two-site WAN / heterogeneous speeds) with the per-LP-pair cost
layer: the environment changes what the clustering is *worth*, not what
the simulation does.

    PYTHONPATH=src python examples/scenarios_run.py [hotspot|group|flock]
"""
import dataclasses
import sys

import numpy as np

from repro.core import costmodel as cm
from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig
from repro.core.service import Engine
from repro.core.heuristics import HeuristicConfig


def main(mobility: str = "hotspot"):
    cfg = EngineConfig(
        abm=ABMConfig(n_se=1000, n_lp=4, area=3162.0, speed=3.5,
                      interaction_range=250.0, p_interact=0.2,
                      mobility=mobility, n_groups=8, group_radius=250.0),
        heuristic=HeuristicConfig(mf=1.2, mt=10),
        gaia_on=True, timesteps=300)
    print(f"scenario: {mobility}")
    results = {}
    for gaia in (True, False):
        _, series, counters = Engine(
            dataclasses.replace(cfg, gaia_on=gaia)).run(seed=0)
        results[gaia] = counters
        lcr = np.asarray(series["lcr"])
        tag = "GAIA on " if gaia else "GAIA off"
        print(f"  {tag}: LCR {lcr[:50].mean():.3f} -> {lcr[-50:].mean():.3f}"
              f"  migrations {counters['migrations']:.0f}"
              f"  grid overflow steps {counters['grid_overflow']:.0f}")

    print(f"{'environment':12s} {'TEC off':>10s} {'TEC on':>10s} {'gain':>8s}")
    for kind in ("shm", "lan", "wan2", "hetero"):
        env = cm.make_env(kind, cfg.abm.n_lp)
        tec = {g: cm.wct_env(results[g], cm.DISTRIBUTED, env, cfg.timesteps,
                             interaction_bytes=100)["TEC"]
               for g in (True, False)}
        gain = (tec[False] - tec[True]) / tec[False]
        print(f"{env.name:12s} {tec[False]:10.3f} {tec[True]:10.3f} "
              f"{gain:+8.1%}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "hotspot")

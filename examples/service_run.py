"""Drive the resident engine service: open-world churn + live queries.

A closed-world run answers one question ("what happened over T steps");
the resident `Engine` keeps the simulation *on device* so a caller can
interleave stepping with entity churn and state queries — the
simulation-as-a-service shape of the paper's motivating scenario
(entities joining and leaving a running distributed simulation, GAIA
re-clustering around them).

    PYTHONPATH=src python examples/service_run.py
"""
import dataclasses

import numpy as np

from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig
from repro.core.heuristics import HeuristicConfig
from repro.core.service import Engine, ReplicaService


def main():
    cfg = EngineConfig(
        abm=ABMConfig(n_se=1000, n_lp=4, area=3162.0, speed=3.5,
                      interaction_range=250.0, p_interact=0.2),
        heuristic=HeuristicConfig(mf=1.2, mt=10),
        gaia_on=True, open_world=True, n_active=800, timesteps=0)
    rng = np.random.default_rng(0)

    e = Engine(cfg).init(seed=0)
    print(f"resident engine up: population {e.population()} "
          f"of {cfg.abm.n_se} slots")

    # phase 1: steady stepping
    e.step(50)
    print(f"after 50 steps: LCR {e.query_lcr():.3f}")

    # phase 2: churn — a burst of arrivals clustered in one corner,
    # departures sampled uniformly, stepping throughout
    for round_ in range(5):
        victims = rng.choice(e.live_ids(), 40, replace=False)
        e.depart(victims)
        ids = e.arrive({"pos": rng.uniform(0, cfg.abm.area / 4,
                                           (40, 2))})
        e.step(10)
        print(f"churn round {round_}: departed 40, admitted {len(ids)} "
              f"(e.g. ids {ids[:3]}...), population {e.population()}, "
              f"LCR {e.query_lcr():.3f}")

    # phase 3: device-state queries
    corner = e.query_region((0.0, 0.0, cfg.abm.area / 4, cfg.abm.area / 4))
    probe = corner[:3]
    hood = e.query_neighbors(probe)
    print(f"{len(corner)} SEs in the corner quadrant; neighbors of "
          f"{probe}: {[len(v) for v in hood.values()]} each")

    m = e.metrics()
    print(f"cumulative: {m['migrations']:.0f} migrations, "
          f"mean LCR {m['mean_lcr']:.3f}, "
          f"mean population {m.get('mean_pop', float('nan')):.0f}")

    # bonus: multiplex several closed-world requests over the replica
    # batch axis — each request's counters match its solo run exactly
    svc_cfg = dataclasses.replace(cfg, open_world=False, n_active=0,
                                  timesteps=60)
    svc = ReplicaService(svc_cfg, n_slots=2)
    rids = [svc.submit(seed=s, steps=60) for s in range(4)]
    results = svc.drain()
    print("service drain:",
          {r: f"{results[r]['migrations']:.0f} migs" for r in rids})


if __name__ == "__main__":
    main()

"""Serving driver: batched greedy decoding from a small MoE LM with GAIA
adaptive expert placement running online.

Each decode step routes tokens to experts; GAIA watches the per-group
traffic matrix and migrates experts toward the data-parallel groups that
use them (the paper's self-clustering with SE=expert, LP=EP shard),
paying MigComm only when the α=ε/ι heuristic clears MF.

    PYTHONPATH=src python examples/serve_moe.py
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig, ShapeConfig
from repro.core import gaia_moe as gm
from repro.launch.steps import build_serve_step
from repro.models import lm as lm_mod
from repro.parallel.ctx import make_ctx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg = ArchConfig(name="moe-serve", family="moe", n_layers=4,
                     d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
                     vocab_size=512,
                     moe=MoEConfig(num_experts=16, top_k=2, d_expert=64,
                                   capacity_factor=2.0))
    px = make_ctx(None, q_block=32, kv_block=32)
    Smax = args.prompt_len + args.gen
    shape = ShapeConfig("serve", Smax, args.batch, "decode")

    params = lm_mod.init_params(jax.random.key(0), cfg)
    extras = lm_mod.init_extras(cfg)

    # prefill the prompts
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0, 500)
    cache, logits = lm_mod.prefill(params, {"tokens": prompts}, cfg, px,
                                   cache_len=Smax)
    tokens = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

    gaia_cfg = gm.GaiaMoEConfig(num_experts=16, num_groups=4, mf=1.2,
                                mt=8, window=4, interval=8)
    gstate = gm.init_state(gaia_cfg)

    decode = jax.jit(build_serve_step(cfg, shape, px).fn)

    n_layers_moe = cfg.n_layers
    out_tokens = [tokens]
    migrations = 0
    t0 = time.time()
    for step in range(args.gen):
        pos = jnp.int32(args.prompt_len + step)
        cache, tokens = decode(params, extras, cache, tokens, pos)
        out_tokens.append(tokens)
        # observe routing traffic (toy: synthesize per-group counts from
        # token ids so the demo is deterministic without layer taps)
        grp = jnp.arange(args.batch) % gaia_cfg.num_groups
        hot = tokens % gaia_cfg.num_experts
        traffic = jnp.zeros((gaia_cfg.num_groups, 16)).at[grp, hot].add(10.0)
        gstate, n = gm.maybe_update(gaia_cfg, gstate, traffic)
        if int(n):
            # physical migration: permute expert weights + routing table
            perm, order = gm.placement_permutation(gstate["placement"], 16)
            idx = jnp.tile(gm.migration_index(
                jnp.arange(16, dtype=jnp.int32), order), (n_layers_moe, 1))
            for kname in ("w_gate", "w_up", "w_down"):
                params["layers"]["moe"][kname] = gm.apply_migration_stacked(
                    params["layers"]["moe"][kname], idx)
            extras = dict(extras, placement=jnp.tile(perm[None],
                                                     (n_layers_moe, 1)))
            migrations += int(n)
            print(f"  step {step:3d}: migrated {int(n)} experts "
                  f"(MigComm {gm.migration_bytes(int(n), cfg.d_model, 64)/1e6:.2f} MB)")
    dt = time.time() - t0
    gen = jnp.stack(out_tokens, axis=1)
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.1f}s "
          f"({args.batch*args.gen/dt:.0f} tok/s), "
          f"{migrations} expert migrations")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()

"""Static vs. periodic vs. adaptive partitioning on the hotspot scenario.

The paper's headline comparison, runnable on a laptop: the same
clustered workload (K dense blobs chasing moving attractors) is run
with

  * a static map from each partitioning backend
    (ABMConfig.partitioner: random / stripe / kmeans / bestresponse),
  * a periodic global kmeans repartition
    (EngineConfig.repartition_every — deltas ride the migration
    machinery and are priced like migrations), and
  * GAIA's adaptive self-clustering on top of a random start,

then every run is priced on the LAN environment with the per-LP-pair
cost layer (wct_env), so "which partitioner wins" is a wall-clock
statement, not an LCR aesthetic.

    PYTHONPATH=src python examples/partition_run.py [hotspot|group|flock]
"""
import dataclasses
import sys

from repro.core import costmodel as cm
from repro.core.abm import ABMConfig
from repro.core.engine import EngineConfig
from repro.core.service import Engine
from repro.core.heuristics import HeuristicConfig


def main(mobility: str = "hotspot"):
    base = EngineConfig(
        abm=ABMConfig(n_se=1000, n_lp=4, area=3162.0, speed=3.5,
                      interaction_range=250.0, p_interact=0.2,
                      mobility=mobility, n_groups=8, group_radius=250.0),
        heuristic=HeuristicConfig(mf=1.2, mt=10),
        gaia_on=False, timesteps=300)
    env = cm.make_env("lan", base.abm.n_lp)
    print(f"scenario: {mobility}  ({base.abm.n_se} SEs, "
          f"{base.timesteps} steps, TEC priced on '{env.name}')")

    runs = [(f"{b}/static", dataclasses.replace(
        base, abm=dataclasses.replace(base.abm, partitioner=b)))
        for b in ("random", "stripe", "kmeans", "bestresponse")]
    runs.append(("kmeans/periodic", dataclasses.replace(
        base, abm=dataclasses.replace(base.abm, partitioner="kmeans"),
        repartition_every=50)))
    runs.append(("random/GAIA", dataclasses.replace(base, gaia_on=True)))

    print(f"{'mode':18s} {'LCR':>6s} {'migs':>7s} {'TEC(lan)':>10s}")
    for name, cfg in runs:
        _, _, c = Engine(cfg).run(seed=0)
        tec = cm.wct_env(c, cm.DISTRIBUTED, env, cfg.timesteps,
                         interaction_bytes=100, migration_bytes=256)["TEC"]
        print(f"{name:18s} {c['mean_lcr']:6.3f} {c['migrations']:7.0f} "
              f"{tec:10.3f}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "hotspot")

"""Run the GAIA engine sharded LP-per-device and watch the halo shrink.

The sharded backend needs multiple devices *before* jax initializes; on
a CPU box, fake them:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/sharded_run.py

Each device owns the SE rows of its LPs; GAIA migrations physically
reshard SE state between devices. The run is bit-identical to
sharding="none" on the same seed — what changes is WHERE the work and
the state live, and the halo_frac / bytes_on_wire metrics show the
fraction of remote agents each shard actually needs — and the bytes
the neighbor-only exchange actually moves — falling as GAIA clusters
the model.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.abm import ABMConfig  # noqa: E402
from repro.core.engine import EngineConfig  # noqa: E402
from repro.core.service import Engine  # noqa: E402
from repro.core.heuristics import HeuristicConfig  # noqa: E402


def main():
    cfg = EngineConfig(
        abm=ABMConfig(n_se=1000, n_lp=4, area=3162.0, speed=3.5,
                      interaction_range=250.0, p_interact=0.2),
        heuristic=HeuristicConfig(mf=1.2, mt=10),
        gaia_on=True, timesteps=200, sharding="lp_device")
    print(f"devices: {jax.devices()}")
    st, series, counters = Engine(cfg).run(seed=0)
    lcr = np.asarray(series["lcr"])
    halo = np.asarray(series["halo_frac"])
    wire = np.asarray(series["bytes_on_wire"])
    for w in range(0, cfg.timesteps, 40):
        print(f"steps {w:4d}-{w + 39:4d}  LCR {lcr[w:w + 40].mean():.3f}  "
              f"halo_frac {halo[w:w + 40].mean():.3f}  "
              f"wire {wire[w:w + 40].mean():8.0f} B/step")
    print(f"migrations: {counters['migrations']:.0f}  "
          f"mean LCR: {counters['mean_lcr']:.3f}  "
          f"shard overflow steps: {counters['shard_overflow']:.0f}")
    print("final per-LP populations:",
          np.bincount(np.asarray(st["lp"]), minlength=cfg.abm.n_lp))


if __name__ == "__main__":
    main()

"""Quickstart: adaptive self-clustering on the paper's evaluation model.

Runs the GAIA engine (10k-SE scaled down to 1k for a laptop CPU) with
the adaptive partitioning OFF and ON, and prints the paper's headline
numbers: Local Communication Ratio, migrations, and the estimated
wall-clock gain on the two calibrated testbeds (Eq. 5/6).

    PYTHONPATH=src python examples/quickstart.py

For GAIA measured against partitioners that actually try (static and
periodically recomputed stripe/kmeans/bestresponse maps), see
examples/partition_run.py.
"""
from repro.core.abm import ABMConfig
from repro.core.costmodel import SETUPS, wct
from repro.core.engine import EngineConfig
from repro.core.service import Engine
from repro.core.heuristics import HeuristicConfig
from repro.core.stats import summarize


def main():
    abm = ABMConfig(n_se=1000, n_lp=4, area=3162.0, speed=11.0,
                    interaction_range=250.0, p_interact=0.2)
    ts = 400
    print(f"ABM: {abm.n_se} SEs on {abm.n_lp} LPs, RWP speed {abm.speed}, "
          f"{ts} timesteps")

    results = {}
    for gaia in (False, True):
        cfg = EngineConfig(abm=abm, heuristic=HeuristicConfig(mf=1.2, mt=10),
                           gaia_on=gaia, timesteps=ts)
        _, _, counters = Engine(cfg).run(seed=0)
        results[gaia] = counters
        tag = "GAIA ON " if gaia else "GAIA OFF"
        print(f"  {tag}: LCR={counters['mean_lcr']:.3f} "
              f"migrations={int(counters['migrations'])} "
              f"(MR {counters['migration_ratio']:.1f})")

    print("\nEstimated wall-clock (cost model, interaction 1 KiB, "
          "SE state 32 B):")
    for name, params in SETUPS.items():
        off = wct(results[False], params, abm.n_lp, ts,
                  interaction_bytes=1024, migration_bytes=32)["TEC"]
        on = wct(results[True], params, abm.n_lp, ts,
                 interaction_bytes=1024, migration_bytes=32)["TEC"]
        print(f"  {name:<12} OFF {off:8.2f}s  ON {on:8.2f}s  "
              f"gain {100*(off-on)/off:+.1f}%")

    # single seeds are anecdotes: run 5 replicas in ONE batched pass
    # (vmap over the seed axis — replica r is bit-identical to a
    # sequential run on seed r) and report a confidence interval
    cfg = EngineConfig(abm=abm, heuristic=HeuristicConfig(mf=1.2, mt=10),
                       gaia_on=True, timesteps=ts)
    _, _, reps = Engine(cfg).run(seeds=range(5))
    lcr = summarize(reps)["mean_lcr"]
    print(f"\nGAIA ON over {lcr['n']} batched replicas: "
          f"LCR = {lcr['mean']:.3f} ± {lcr['ci95']:.3f} (95% CI)")


if __name__ == "__main__":
    main()

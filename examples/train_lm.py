"""End-to-end training driver: a small LM on the synthetic Markov task,
through the full production stack — data pipeline, jitted train step,
AdamW+schedule, atomic/async checkpoints, watchdog, crash-safe resume.

    PYTHONPATH=src python examples/train_lm.py               # ~12M params
    PYTHONPATH=src python examples/train_lm.py --full        # ~100M params
    PYTHONPATH=src python examples/train_lm.py --resume      # restart demo

Cross-entropy on the order-2 Markov stream falls from ~ln(V) toward the
task's conditional entropy within a few hundred steps.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.pipeline import DataConfig
from repro.launch.steps import build_train_step
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.ctx import make_ctx
from repro.runtime.trainer import Trainer, TrainerConfig


def small_cfg(full: bool) -> ArchConfig:
    if full:  # ~100M params
        return ArchConfig(name="lm-100m", family="dense", n_layers=10,
                          d_model=640, n_heads=10, n_kv_heads=5,
                          d_ff=2560, vocab_size=2048)
    # vocab sized so the order-2 Markov task is learnable within a few
    # hundred steps (contexts ~ V^2 must be << tokens seen)
    return ArchConfig(name="lm-11m", family="dense", n_layers=6,
                      d_model=256, n_heads=8, n_kv_heads=4,
                      d_ff=1024, vocab_size=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a crash after N steps (restart demo)")
    args = ap.parse_args()

    cfg = small_cfg(args.full)
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    px = make_ctx(None, q_block=64, kv_block=64)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda k: lm_mod.init_params(k, cfg),
                       jax.random.key(0))))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch {args.batch}x{args.seq}")

    # early grad norms on a fresh LM are ~50-100: a clip of 1.0 would
    # crush the effective lr, so clip loosely and decay lightly
    bundle = build_train_step(cfg, shape, px,
                              opt=AdamWConfig(lr=3e-3, warmup_steps=20,
                                              total_steps=args.steps,
                                              clip_norm=10.0,
                                              weight_decay=0.01))

    def init_state():
        params = lm_mod.init_params(jax.random.key(0), cfg)
        return params, adamw_init(params), lm_mod.init_extras(cfg)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=0)
    tr_cfg = TrainerConfig(total_steps=args.steps, checkpoint_every=100,
                           checkpoint_dir=args.ckpt, log_every=20)
    trainer = Trainer(tr_cfg, jax.jit(bundle.fn, donate_argnums=(0, 1)),
                      init_state, data_cfg)
    t0 = time.time()
    out = trainer.run(fail_at=args.fail_at or None)
    dt = time.time() - t0
    loss = float(out["metrics"]["loss"])
    toks = args.steps * args.batch * args.seq
    print(f"done: final loss {loss:.3f} (uniform {float(jnp.log(cfg.vocab_size)):.3f}) "
          f"in {dt:.0f}s ({toks/dt:.0f} tok/s, "
          f"{6*n_params*toks/dt/1e9:.1f} GFLOP/s)")
    assert loss < 0.8 * jnp.log(cfg.vocab_size), "no learning happened?"


if __name__ == "__main__":
    main()

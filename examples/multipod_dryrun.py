"""Launcher example: lower + compile one production cell and print its
roofline terms — the per-cell core of the multi-pod dry-run campaign.

    PYTHONPATH=src python examples/multipod_dryrun.py \
        [--arch tinyllama-1.1b] [--shape train_4k] [--mesh multi]

NOTE: must be a fresh process (the 512 placeholder devices are pinned at
first jax init — this is why dryrun.py sets XLA_FLAGS on lines 1-2).
"""
import runpy
import sys

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv += ["--arch", "tinyllama-1.1b"]
    if not any(a.startswith("--shape") for a in argv):
        argv += ["--shape", "train_4k"]
    if not any(a.startswith("--mesh") for a in argv):
        argv += ["--mesh", "multi"]
    sys.argv = ["repro.launch.dryrun"] + argv
    runpy.run_module("repro.launch.dryrun", run_name="__main__")
